#!/usr/bin/env python
"""Render the per-node predicted-vs-observed drift table for a tower.

Builds a conv tower, prices it (measured ``--profile`` with analytic
fallback, or pure analytic), solves the PBQP selection, runs the
instrumented executable (:class:`repro.obs.drift.InstrumentedNet`) and
prints one row per modeled term — node kernels and edge transforms —
with predicted ms, observed EWMA ms, the observed/predicted ratio and
the EWMA drift score, flagging entries outside the threshold:

  python tools/obs_report.py --shape 3x16x16 --depth 3 --runs 4 \
      --profile profile.json

``--recalibrate`` writes the flagged observations back into the
profile (only those — see docs/observability.md#recalibration) and
saves it, which rotates the profile's content hash and invalidates
every cached plan priced by the stale entries.

``--trace summary``: instead of measuring, summarize a span JSONL file
written by ``repro.launch.serve --trace`` (count/total/p50 per span
name):

  python tools/obs_report.py --trace-file trace.jsonl

``--metrics-file``: render the *degradation* report from a stats
snapshot written by ``repro.launch.serve --metrics-json`` — fallback-
ladder rung counts, quarantined primitives, shed/requeued requests —
the reliability-layer events (docs/reliability.md) that belong next to
the drift table when debugging a fleet serving below-optimal plans:

  python tools/obs_report.py --metrics-file metrics.json
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
for p in (str(ROOT), str(ROOT / "src")):
    if p not in sys.path:
        sys.path.insert(0, p)


def _shape(s: str):
    c, h, w = (int(v) for v in s.lower().split("x"))
    return (c, h, w)


def drift_table(args) -> int:
    import numpy as np

    from repro.calibrate.model import CalibratedCostModel
    from repro.calibrate.profile import HardwareProfile
    from repro.core.plan import compile_plan
    from repro.core.selection import select_pbqp
    from repro.obs.drift import DriftDetector, InstrumentedNet
    from repro.serving.towers import conv_stack

    if args.profile and pathlib.Path(args.profile).exists():
        profile = HardwareProfile.load(args.profile)
    else:
        profile = HardwareProfile.new()
    cost = CalibratedCostModel(profile, check_device=not args.no_check)
    net = conv_stack(args.shape, depth=args.depth, width=args.width,
                     k=args.k)
    sel = select_pbqp(net, cost)
    cnet = compile_plan(sel, net.init_params(args.seed))
    inst = InstrumentedNet(cnet)
    det = DriftDetector(cost, threshold=args.threshold)
    x = np.random.default_rng(args.seed).normal(
        size=args.shape).astype(np.float32)
    for _ in range(args.runs):
        _, timings = inst(x)
        det.observe(sel, timings)

    rows = det.report()
    hdr = (f"{'node':<14} {'primitive':<26} {'layout':<12} "
           f"{'pred ms':>9} {'obs ms':>9} {'ratio':>7} {'drift':>7}  flag")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        print(f"{r['node']:<14} {r['primitive']:<26} {r['layout']:<12} "
              f"{r['predicted_ms']:>9.4f} {r['observed_ms']:>9.4f} "
              f"{r['ratio']:>7.2f} {r['drift']:>7.3f}  "
              f"{'DRIFT' if r['flagged'] else 'ok'}")
    rec = det.recommendation()
    print(f"\nplan: observed/predicted = {rec['plan_ratio']:.2f} over "
          f"{rec['runs']} runs "
          f"({'within' if rec['plan_within_threshold'] else 'OUTSIDE'} "
          f"threshold {args.threshold})")
    if rec["recalibrate"]:
        print(f"recommend recalibration of: {', '.join(rec['flagged'])}")
        if args.recalibrate and args.profile:
            keys = det.recalibrate(profile)
            profile.save(args.profile)
            print(f"recalibrated {len(keys)} entries -> {args.profile} "
                  f"(content hash now {profile.content_hash()})")
    if args.json:
        pathlib.Path(args.json).write_text(json.dumps(
            {"rows": rows, "recommendation": rec}, indent=2))
        print(f"report written to {args.json}")
    return 1 if (rec["recalibrate"] and args.strict) else 0


def trace_summary(args) -> int:
    spans = {}
    with open(args.trace_file) as fh:
        for line in fh:
            rec = json.loads(line)
            spans.setdefault(rec["name"], []).append(rec["dur_s"])
    print(f"{'span':<16} {'count':>7} {'total ms':>10} {'p50 ms':>9} "
          f"{'max ms':>9}")
    for name, durs in sorted(spans.items(),
                             key=lambda kv: -sum(kv[1])):
        durs.sort()
        print(f"{name:<16} {len(durs):>7} {sum(durs)*1e3:>10.2f} "
              f"{durs[len(durs) // 2]*1e3:>9.3f} {durs[-1]*1e3:>9.3f}")
    return 0


def degradation_report(args) -> int:
    """Reliability-event table from a server stats snapshot."""
    with open(args.metrics_file) as fh:
        s = json.load(fh)

    def g(key, default=0):
        return s.get(key, default)

    total = sum(int(g(f"ladder_{r}"))
                for r in ("exact", "anytime", "greedy", "reference"))
    print("fallback ladder (selections per rung)")
    print(f"{'rung':<12} {'count':>7} {'share':>8}")
    for rung in ("exact", "anytime", "greedy", "reference"):
        n = int(g(f"ladder_{rung}"))
        share = n / total if total else 0.0
        print(f"{rung:<12} {n:>7} {share:>7.1%}")
    print(f"\nquarantine: {int(g('quarantines'))} trips, "
          f"{int(g('kernel_failures'))} kernel failures")
    active = g("quarantined", [])
    for entry in active:
        print(f"  active: {entry}")
    if not active:
        print("  active: none")
    print(f"shed: {int(g('shed_requests'))} requests rejected at "
          f"admission")
    print(f"workers: {int(g('worker_deaths'))} deaths, "
          f"{int(g('worker_requeues'))} requests re-queued")
    print(f"plan cache: {int(g('plan_cache_corrupt'))} corrupt entries "
          f"deleted; compile: {int(g('compile_retries'))} retries, "
          f"{int(g('compile_fallbacks'))} plan demotions")
    demoted = int(g("ladder_demotions"))
    flag = demoted or active or int(g("shed_requests"))
    print(f"\n{'DEGRADED' if flag else 'healthy'}: "
          f"{demoted} below-exact selections, "
          f"{len(active)} active quarantines")
    return 1 if (flag and args.strict) else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="predicted-vs-observed drift table / trace summary")
    ap.add_argument("--shape", type=_shape, default=(3, 16, 16),
                    help="input CxHxW (default 3x16x16)")
    ap.add_argument("--depth", type=int, default=3)
    ap.add_argument("--width", type=int, default=8)
    ap.add_argument("--k", type=int, default=3)
    ap.add_argument("--runs", type=int, default=4,
                    help="instrumented passes folded into the EWMA")
    ap.add_argument("--threshold", type=float, default=2.0,
                    help="flag ratio (entries outside [1/t, t] drift)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--profile", default=None,
                    help="HardwareProfile JSON pricing the plan")
    ap.add_argument("--no-check", action="store_true",
                    help="skip the profile device fingerprint check")
    ap.add_argument("--recalibrate", action="store_true",
                    help="write flagged observations back to --profile")
    ap.add_argument("--json", default=None,
                    help="also write the report as JSON")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 when recalibration is recommended")
    ap.add_argument("--trace-file", default=None,
                    help="summarize a span JSONL instead of measuring")
    ap.add_argument("--metrics-file", default=None,
                    help="render the degradation report from a stats "
                         "snapshot (repro.launch.serve --metrics-json)")
    args = ap.parse_args(argv)
    if args.trace_file:
        return trace_summary(args)
    if args.metrics_file:
        return degradation_report(args)
    return drift_table(args)


if __name__ == "__main__":
    sys.exit(main())
