#!/usr/bin/env python
"""Markdown link checker for docs/ and README (no external deps).

Checks every relative ``[text](target)`` link in the given markdown
files/directories:

* the target file must exist (relative to the containing file);
* a ``#fragment`` must match a heading anchor in the target markdown
  file (GitHub-style slug: lowercase, punctuation stripped, spaces to
  dashes).

External (``http(s)://``, ``mailto:``) links are not fetched.  Exits
non-zero listing every broken link — CI's docs job and
tests/test_docs.py both run this.

  python tools/check_md_links.py docs README.md
"""
from __future__ import annotations

import pathlib
import re
import sys
from typing import List

#: inline links, skipping images; tolerates one level of nested parens
LINK_RE = re.compile(r"(?<!\!)\[[^\]]+\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*?)\s*#*\s*$")


def slugify(heading: str) -> str:
    """GitHub-style heading anchor."""
    s = re.sub(r"[`*_~]", "", heading.strip().lower())
    s = re.sub(r"[^\w\- ]", "", s)
    return s.replace(" ", "-")


def anchors_of(md: pathlib.Path) -> set:
    out = set()
    in_code = False
    for line in md.read_text().splitlines():
        if line.lstrip().startswith("```"):
            in_code = not in_code
            continue
        if in_code:
            continue
        m = HEADING_RE.match(line)
        if m:
            out.add(slugify(m.group(1)))
    return out


def check_file(md: pathlib.Path) -> List[str]:
    errors = []
    text = md.read_text()
    # strip fenced code blocks so example links aren't checked
    text = re.sub(r"```.*?```", "", text, flags=re.S)
    for target in LINK_RE.findall(text):
        if re.match(r"^[a-z][a-z0-9+.-]*:", target):  # http:, mailto:, ...
            continue
        path_part, _, frag = target.partition("#")
        dest = md if not path_part else \
            (md.parent / path_part).resolve()
        if not dest.exists():
            errors.append(f"{md}: broken link -> {target} "
                          f"(no such file {dest})")
            continue
        if frag and dest.suffix == ".md":
            if slugify(frag) not in anchors_of(dest):
                errors.append(f"{md}: broken anchor -> {target} "
                              f"(no heading #{frag} in {dest.name})")
    return errors


def main(argv: List[str]) -> int:
    files: List[pathlib.Path] = []
    for arg in argv or ["docs", "README.md"]:
        p = pathlib.Path(arg)
        files.extend(sorted(p.rglob("*.md")) if p.is_dir() else [p])
    if not files:
        print("no markdown files found", file=sys.stderr)
        return 2
    errors = []
    for md in files:
        errors.extend(check_file(md))
    for e in errors:
        print(e, file=sys.stderr)
    print(f"checked {len(files)} files: "
          f"{'OK' if not errors else f'{len(errors)} broken links'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
