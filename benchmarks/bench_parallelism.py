"""Full-parallelism benchmark: tp and pp as first-class PBQP choices.

Four sections, one JSON document (written to benchmarks/results/):

1. **mixed_vs_dp** — the headline: ``bottleneck_tower`` (a
   weight-bandwidth-bound body behind a thin head) compiled three
   ways for the same batch on 8 fake CPU devices: unsharded, the best
   pure data-parallel plan (``mesh_axes={"data": 8}``), and the
   solver's mixed plan on a ``data=2 x model=4`` mesh — which shards
   the fat body convs tensor-parallel while the head stays dp.
   Records predicted and measured time for all three, outputs verified
   identical.  The CI gate asserts the mixed plan both matches and
   measures faster than pure dp.
2. **flip** — the fabric-speed sweep: the same solves repeated with
   the inter-device link slowed by 2000x.  Slow links make the tp
   all-gather and the pipeline's stage-boundary sends expensive, so
   placements flip back toward dp/rep — the distributed twin of the
   paper's layout-flip tables, now over the full placement alphabet
   {rep, dp, tp, pp<stage>}.
3. **bnb** — branch-and-bound work on the enlarged choice space:
   solver node/prune counters for the {dp, rep} space vs the full
   {rep, dp, tp} product, and for the pipeline space, so the cost of
   the richer domain is measured rather than guessed.
4. **cache_roundtrip** — a mixed tp+dp plan and a pipeline plan
   through the JSON disk tier (serialize/parse cycle included):
   structured placements must survive byte-identically.

Run (the script forces 8 fake CPU devices before jax initialises):

  PYTHONPATH=src python -m benchmarks.bench_parallelism
"""
from __future__ import annotations

import argparse
import json
import pathlib
import statistics
import tempfile
import time

N_DEVICES = 8


def _force_fake_devices() -> None:
    from repro.launch.mesh import force_host_devices
    force_host_devices(N_DEVICES)


def _headline_net(batch: int):
    from repro.serving.towers import bottleneck_tower
    return bottleneck_tower((4, 16, 16)).with_batch(batch)


def _pipeline_net(batch: int):
    from repro.serving.towers import uniform_stack
    return uniform_stack((8, 8, 8), depth=6).with_batch(batch)


def _throughput(fn, x, params, reps: int) -> float:
    """Median seconds per invocation (warmed)."""
    import jax
    for _ in range(3):
        jax.block_until_ready(fn(x, params))
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(x, params))
        times.append(time.perf_counter() - t0)
    return statistics.median(times)


def _kind_counts(sel) -> dict:
    from repro.core.selection import Placement
    counts: dict = {}
    for ch in sel.choices.values():
        k = Placement.parse(ch.placement).kind
        counts[k] = counts.get(k, 0) + 1
    return counts


def bench_mixed_vs_dp(batch: int, reps: int, seed: int = 0) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.costs import AnalyticCostModel
    from repro.core.plan import compile_plan
    from repro.core.selection import select_pbqp
    from repro.launch.mesh import make_mesh_compat, mesh_fingerprint

    cm = AnalyticCostModel()
    net = _headline_net(batch)
    params = net.init_params(seed)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(batch, 4, 16, 16)).astype(np.float32))

    mesh_dp = make_mesh_compat((N_DEVICES,), ("data",))
    mesh_2d = make_mesh_compat((2, 4), ("data", "model"))

    sel_plain = select_pbqp(net, cm)
    sel_dp = select_pbqp(net, cm, mesh_axes={"data": N_DEVICES})
    sel_mix = select_pbqp(net, cm, mesh_axes={"data": 2, "model": 4})

    cn_plain = compile_plan(sel_plain, params, batch=batch)
    cn_dp = compile_plan(sel_dp, params, batch=batch, mesh=mesh_dp)
    cn_mix = compile_plan(sel_mix, params, batch=batch, mesh=mesh_2d)

    out_p = cn_plain(x)
    match_dp = all(np.allclose(np.asarray(cn_dp(x)[k]),
                               np.asarray(out_p[k]),
                               rtol=2e-3, atol=2e-3) for k in out_p)
    match_mix = all(np.allclose(np.asarray(cn_mix(x)[k]),
                                np.asarray(out_p[k]),
                                rtol=2e-3, atol=2e-3) for k in out_p)

    t_plain = _throughput(cn_plain.fn, x, cn_plain.params, reps)
    t_dp = _throughput(cn_dp.fn, x, cn_dp.params, reps)
    t_mix = _throughput(cn_mix.fn, x, cn_mix.params, reps)

    return {
        "devices": N_DEVICES, "batch": batch,
        "mesh_dp": mesh_fingerprint(mesh_dp),
        "mesh_mixed": mesh_fingerprint(mesh_2d),
        "mesh_mode_dp": cn_dp.mesh_mode,
        "mesh_mode_mixed": cn_mix.mesh_mode,
        "placement_kinds_dp": _kind_counts(sel_dp),
        "placement_kinds_mixed": _kind_counts(sel_mix),
        "tp_nodes": cn_mix.tp_nodes,
        "dp_nodes": cn_mix.dp_nodes,
        "outputs_match_dp": bool(match_dp),
        "outputs_match": bool(match_mix),
        # solver currency: per-device time of the optimum per space
        "predicted_plain_s": sel_plain.predicted_cost,
        "predicted_dp_s": sel_dp.predicted_cost,
        "predicted_mixed_s": sel_mix.predicted_cost,
        "predicted_speedup_vs_dp": sel_dp.predicted_cost /
        max(sel_mix.predicted_cost, 1e-30),
        # honest wall clock on this host's fake-device mesh
        "measured_plain_s": t_plain,
        "measured_dp_s": t_dp,
        "measured_mixed_s": t_mix,
        "measured_speedup": t_dp / max(t_mix, 1e-12),
        "measured_speedup_vs_plain": t_plain / max(t_mix, 1e-12),
    }


def bench_flip(batch: int) -> dict:
    """Placement tables across a fabric-speed sweep: slow links price
    the tp all-gather and pp stage sends out of the optimum."""
    from repro.core.costs import CPU_SPEC, AnalyticCostModel, HardwareSpec
    from repro.core.selection import select_pbqp

    def _spec(link):
        return HardwareSpec(
            name="cpu-swept-fabric", peak_flops=CPU_SPEC.peak_flops,
            mem_bw=CPU_SPEC.mem_bw, link_bw=link,
            family_eff=CPU_SPEC.family_eff,
            family_setup=CPU_SPEC.family_setup)

    fabrics = {"fast": CPU_SPEC.link_bw, "slow": CPU_SPEC.link_bw / 2000}
    net_mix = _headline_net(batch)
    net_pp = _pipeline_net(batch)
    tables: dict = {"mixed": {}, "pipeline": {}}
    costs: dict = {"mixed": {}, "pipeline": {}}
    for name, link in fabrics.items():
        cm = AnalyticCostModel(_spec(link))
        sel_m = select_pbqp(net_mix, cm,
                            mesh_axes={"data": 2, "model": 4})
        sel_p = select_pbqp(net_pp, cm, mesh_axes={"stage": 4})
        tables["mixed"][name] = {nid: str(ch.placement)
                                 for nid, ch in sel_m.choices.items()}
        tables["pipeline"][name] = {nid: str(ch.placement)
                                    for nid, ch in sel_p.choices.items()}
        costs["mixed"][name] = sel_m.predicted_cost
        costs["pipeline"][name] = sel_p.predicted_cost
    flips = {
        fixture: [
            {"node": nid, "fast": tab["fast"][nid],
             "slow": tab["slow"][nid]}
            for nid in tab["fast"] if tab["fast"][nid] != tab["slow"][nid]]
        for fixture, tab in tables.items()}
    return {
        "devices": N_DEVICES, "batch": batch,
        "fabric_link_bw": fabrics,
        "placements": tables,
        "predicted_costs": costs,
        "node_flips": flips,
        "n_flips": {k: len(v) for k, v in flips.items()},
    }


def bench_bnb(batch: int) -> dict:
    """Solver work on the enlarged choice space: the counters answer
    'what did tp and pp cost the branch-and-bound search?'."""
    from repro.core.costs import AnalyticCostModel
    from repro.core.selection import select_pbqp

    cm = AnalyticCostModel()
    spaces = {
        "layout_only": (_headline_net(batch), None),
        "dp_rep": (_headline_net(batch), {"data": N_DEVICES}),
        "dp_tp_rep": (_headline_net(batch), {"data": 2, "model": 4}),
        "pipeline": (_pipeline_net(batch), {"stage": 4}),
    }
    rows = {}
    for name, (net, axes) in spaces.items():
        t0 = time.perf_counter()
        sel = select_pbqp(net, cm, mesh_axes=axes)
        rows[name] = {
            "mesh_axes": axes,
            "predicted_s": sel.predicted_cost,
            "solve_wall_s": time.perf_counter() - t0,
            "stats": dict(sel.solver_stats),
        }
    return {"devices": N_DEVICES, "batch": batch, "spaces": rows}


def bench_cache_roundtrip(batch: int) -> dict:
    """Structured placements through the JSON disk tier and back."""
    from repro.core.costs import AnalyticCostModel
    from repro.core.selection import Placement, select_pbqp
    from repro.serving import (PlanDiskCache, plan_key,
                               selection_from_payload,
                               selection_to_payload)

    cm = AnalyticCostModel()
    fixtures = {
        "mixed": (_headline_net(batch), {"data": 2, "model": 4}),
        "pipeline": (_pipeline_net(batch), {"stage": 4}),
    }
    rows = {}
    with tempfile.TemporaryDirectory() as td:
        cache = PlanDiskCache(pathlib.Path(td))
        for name, (net, axes) in fixtures.items():
            sel = select_pbqp(net, cm, mesh_axes=axes)
            key = plan_key(net.fingerprint(), f"b{batch}-{name}",
                           cm.version())
            cache.put(key, selection_to_payload(sel))
            back = selection_from_payload(
                json.loads(json.dumps(cache.get(key))), net)
            ok = all(
                back.choices[nid].placement == ch.placement
                and isinstance(back.choices[nid].placement, Placement)
                for nid, ch in sel.choices.items())
            ok = ok and abs(back.predicted_cost - sel.predicted_cost) \
                <= 1e-12 + 1e-9 * abs(sel.predicted_cost)
            rows[name] = {
                "ok": bool(ok),
                "placements": sorted({str(c.placement)
                                      for c in sel.choices.values()}),
            }
    return {"batch": batch, "fixtures": rows,
            "ok": all(r["ok"] for r in rows.values())}


def main():
    _force_fake_devices()
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--reps", type=int, default=7)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--only", default=None,
                    choices=("mixed_vs_dp", "flip", "bnb",
                             "cache_roundtrip"))
    args = ap.parse_args()

    sections = {
        "mixed_vs_dp": lambda: bench_mixed_vs_dp(
            args.batch, args.reps, args.seed),
        "flip": lambda: bench_flip(args.batch),
        "bnb": lambda: bench_bnb(args.batch),
        "cache_roundtrip": lambda: bench_cache_roundtrip(args.batch),
    }
    result = {"benchmark": "parallelism"}
    for name, fn in sections.items():
        if args.only is None or args.only == name:
            result[name] = fn()
    doc = json.dumps(result, indent=2)
    print(doc)
    out = pathlib.Path(__file__).parent / "results"
    out.mkdir(exist_ok=True)
    name = "parallelism.json" if args.only is None \
        else f"parallelism_{args.only}.json"
    (out / name).write_text(doc)


if __name__ == "__main__":
    main()
