"""Roofline table assembly from multi-pod dry-run artifacts.

Reads the per-cell JSON files produced by ``repro.launch.dryrun`` and
derives the three roofline terms (see EXPERIMENTS.md §Roofline):

  compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
  memory term     = HLO_bytes / (chips * HBM_bw)
  collective term = collective_bytes / (chips * link_bw)

Quantities in the artifacts are PER DEVICE (the compiled HLO is the
per-device program), so the formulas reduce to per-device quantities
over per-chip rates.

Hardware constants: TPU v5e — 197 TFLOP/s bf16/chip, 819 GB/s HBM,
~50 GB/s/link ICI.

Memory-term caveat (measured, see EXPERIMENTS.md §Dry-run): XLA-CPU
``bytes accessed`` reflects CPU fusion boundaries and over-counts TPU
HBM traffic by an order of magnitude (every operand of every unfused op
counts at full size).  We therefore report BOTH:

  memory_s_hlo      — the raw cost_analysis value (upper bound)
  memory_s          — analytic first-principles traffic:
      train:   4 passes over resident params (fwd read, bwd read, grad
               write, optimizer read+write amortised) + activation
               write+read of ~14 residual-stream tensors per layer
               (x2 under remat: saved + recomputed)
      prefill: 1 param pass + activation traffic + KV-cache write
      decode:  1 param pass + KV-cache read (+write of 1 token) — the
               classic decode HBM roofline

The bottleneck/dominant term uses the analytic memory term; both appear
in the table.
"""
from __future__ import annotations

import json
import pathlib
from typing import List, Optional

from repro.core.costs import TPU_V5E_SPEC

# Single source of truth for TPU v5e rates is core.costs.TPU_V5E_SPEC;
# the roofline uses the raw bf16 peak (the spec stores the halved f32
# proxy that selection prices matmuls with).
PEAK_FLOPS = TPU_V5E_SPEC.peak_flops * 2
HBM_BW = TPU_V5E_SPEC.mem_bw
LINK_BW = TPU_V5E_SPEC.link_bw

ARTIFACT_DIR = pathlib.Path(__file__).resolve().parent / "results" / \
    "dryrun"


def _analytic_memory_bytes(rec: dict) -> Optional[float]:
    """Per-device HBM traffic estimate for one step (see module doc)."""
    try:
        from repro.configs import ARCHS, SHAPES
        cfg = ARCHS[rec["arch"]]
        shape = SHAPES[rec["shape"]]
    except Exception:
        return None
    chips = rec["n_devices"]
    p_bytes = rec["params_total"] * 2 / chips       # bf16, fully sharded
    d = cfg.d_model
    kind = shape.kind
    if kind == "train":
        b, t = shape.global_batch, shape.seq_len
        act = b * t * d * 2 / chips
        n_tensors = 14 * cfg.n_layers
        return 4 * p_bytes + 2 * act * n_tensors * 2  # x2 remat
    if kind == "prefill":
        b, t = shape.global_batch, shape.seq_len
        act = b * t * d * 2 / chips
        kv = (cfg.n_layers * b * t * 2 *
              max(cfg.n_kv_heads, 1) *
              (cfg.resolved_head_dim if cfg.n_heads else 0) * 2) / chips
        return p_bytes + act * 14 * cfg.n_layers + kv
    # decode: params + cache read per emitted token
    b, t = shape.global_batch, shape.seq_len
    hd = cfg.resolved_head_dim if cfg.n_heads else 0
    kv = (cfg.n_layers * b * t * 2 * max(cfg.n_kv_heads, 0) * hd * 2) \
        / chips
    if cfg.family in ("ssm", "hybrid"):
        d_inner = cfg.ssm_expand * d
        h = d_inner // cfg.ssm_headdim
        n_mamba = cfg.n_layers if cfg.family == "ssm" else \
            cfg.n_layers * (cfg.attn_every - 1) // cfg.attn_every
        kv_attn_layers = 0 if cfg.family == "ssm" else \
            cfg.n_layers // cfg.attn_every
        kv = (kv_attn_layers * b * t * 2 * cfg.n_kv_heads *
              (cfg.resolved_head_dim if cfg.n_heads else 0) * 2) / chips
        kv += n_mamba * b * h * cfg.ssm_state * cfg.ssm_headdim * 4 / chips
    # active params only stream for MoE decode (top_k experts hit)
    p_stream = rec["params_active"] * 2 / chips if cfg.n_experts \
        else p_bytes
    return p_stream + kv


def roofline_terms(rec: dict) -> dict:
    chips = rec["n_devices"]
    compute = rec["flops_total"] / PEAK_FLOPS
    memory_hlo = rec["bytes_total"] / HBM_BW
    mem_analytic_b = _analytic_memory_bytes(rec)
    memory = (mem_analytic_b / HBM_BW) if mem_analytic_b else memory_hlo
    collective = rec["collective_bytes_total"] / LINK_BW
    terms = {"compute": compute, "memory": memory, "collective": collective}
    bottleneck = max(terms, key=terms.get)
    dominant = terms[bottleneck]
    model_time = rec["model_flops"] / (chips * PEAK_FLOPS)
    frac = model_time / max(dominant, 1e-30)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "rules_mode": rec.get("rules_mode", "pbqp"),
        "compute_s": compute, "memory_s": memory,
        "memory_s_hlo": memory_hlo, "collective_s": collective,
        "bottleneck": bottleneck, "dominant_s": dominant,
        "model_flops": rec["model_flops"],
        "hlo_flops_total": rec["flops_total"] * chips,
        "useful_flop_ratio": rec["model_flops"] /
            max(rec["flops_total"] * chips, 1.0),
        "roofline_fraction": frac,
    }


def roofline_rows(art_dir: pathlib.Path = ARTIFACT_DIR) -> List[dict]:
    rows = []
    if not art_dir.exists():
        return rows
    for f in sorted(art_dir.glob("*.json")):
        rec = json.loads(f.read_text())
        if rec.get("status") != "ok":
            continue
        rows.append(roofline_terms(rec))
    return rows
