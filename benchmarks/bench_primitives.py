"""Autotuned-variant benchmark: does widening the primitive space pay?

Runs the full tuning pipeline (generate -> price -> prune -> catalog)
with the tile-aware analytic TPU model, installs the surviving variants
into the registry, and re-solves two reference towers:

  * ``pointwise512`` — a compute-bound stack of 1x1 convolutions
    (c=m=512), the regime where block-tiling actually moves the
    roofline and generated GEMM variants should win nodes outright;
  * ``conv64`` — a conventional 3x3 feature tower whose early layers
    are bandwidth-bound, where the tuned registry must not regress
    the solved cost (variants that cannot win anywhere are pruned).

Emits benchmarks/results/BENCH_primitives.json with the gates CI
checks: registry size stays above the paper's ">70 primitives" claim,
the solved-vs-naive gap strictly widens on at least one tower, at
least three generated variants win PBQP assignments, and solving over
the widened space costs at most 5x the base solve.

  PYTHONPATH=src python -m benchmarks.bench_primitives
"""
from __future__ import annotations

import json
import pathlib
import sys
import time

GATE_MIN_REGISTRY = 70
GATE_MIN_VARIANT_WINS = 3
GATE_MAX_SOLVE_RATIO = 5.0


def _towers():
    from repro.serving.towers import conv_tower, uniform_stack
    return {
        "pointwise512": uniform_stack((512, 32, 32), depth=4, k=1),
        "conv64": conv_tower((64, 64, 64), depth=3, width=64),
    }


def _solve_time(net, cost, reps: int = 3) -> float:
    from repro.core.selection import select_pbqp
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        select_pbqp(net, cost)
        best = min(best, time.perf_counter() - t0)
    return best


def _choices(result):
    out = []
    for node, ch in sorted(result.choices.items()):
        if ch.primitive is not None:
            out.append({"node": node, "primitive": ch.primitive.name})
    return out


def bench_primitives(batches=(1, 8)) -> dict:
    """Tune, install, re-solve; returns the BENCH_primitives payload."""
    from repro.autotune import tune
    from repro.calibrate.sweep import scenario_grid, scenarios_from_net
    from repro.core.costs import AnalyticCostModel, TPU_V5E_SPEC
    from repro.core.primitives import build_registry, clear_extensions, \
        registry
    from repro.core.selection import select_pbqp, select_sum2d

    cost = AnalyticCostModel(TPU_V5E_SPEC, include_tpu_only=True)
    towers = _towers()

    clear_extensions()
    n_base = len(registry())
    rows = {"benchmark": "primitives",
            "registry_base": n_base,
            "registry_handwritten": len(build_registry()),
            "paper_claim_min_primitives": GATE_MIN_REGISTRY,
            "towers": {}}

    scns = list(scenario_grid("default"))
    base = {}
    for name, net in towers.items():
        scns.extend(scenarios_from_net(net, batches=batches))
        naive = select_sum2d(net, cost)
        solved = select_pbqp(net, cost)
        base[name] = {
            "naive_cost": naive.predicted_cost,
            "solved_cost": solved.predicted_cost,
            "gap": naive.predicted_cost / solved.predicted_cost,
            "solve_s": _solve_time(net, cost),
            "choices": _choices(solved),
        }

    t0 = time.perf_counter()
    res = tune(scns, measure_mode="analytic")
    tune_s = time.perf_counter() - t0
    rows.update(variants_generated=res.generated,
                variants_surviving=res.surviving,
                variants_pruned=res.pruned,
                survivors=res.catalog.survivors(),
                kernel_only_winners=len(res.catalog.kernels),
                catalog_content=res.catalog.content_hash(),
                tune_s=tune_s,
                measurements=res.sweep["measured"] + res.sweep["skipped"])

    res.catalog.install()
    try:
        from .paper_tables import primitive_registry_comparison
        rows["registry_tuned"] = len(registry())
        rows["registry_comparison"] = primitive_registry_comparison()
        total_wins = 0
        any_gap_widened = False
        worst_ratio = 0.0
        for name, net in towers.items():
            b = base[name]
            solved = select_pbqp(net, cost)
            choices = _choices(solved)
            wins = sum(1 for c in choices if "@" in c["primitive"])
            total_wins += wins
            gap = b["naive_cost"] / solved.predicted_cost
            solve_s = _solve_time(net, cost)
            ratio = solve_s / b["solve_s"]
            worst_ratio = max(worst_ratio, ratio)
            any_gap_widened |= gap > b["gap"]
            rows["towers"][name] = {
                "naive_cost": b["naive_cost"],
                "solved_cost_base": b["solved_cost"],
                "solved_cost_tuned": solved.predicted_cost,
                "gap_base": b["gap"],
                "gap_tuned": gap,
                "variant_wins": wins,
                "solve_s_base": b["solve_s"],
                "solve_s_tuned": solve_s,
                "solve_ratio": ratio,
                "choices_base": b["choices"],
                "choices_tuned": choices,
            }
    finally:
        clear_extensions()

    rows["variant_wins_total"] = total_wins
    rows["gates"] = {
        "registry_min_70": rows["registry_tuned"] >= GATE_MIN_REGISTRY,
        "gap_strictly_widens": any_gap_widened,
        "variant_wins_min_3": total_wins >= GATE_MIN_VARIANT_WINS,
        "solve_ratio_max_5x": worst_ratio <= GATE_MAX_SOLVE_RATIO,
    }
    rows["gates_ok"] = all(rows["gates"].values())
    return rows


def main() -> int:
    rows = bench_primitives()
    out = pathlib.Path(__file__).parent / "results"
    out.mkdir(exist_ok=True)
    path = out / "BENCH_primitives.json"
    path.write_text(json.dumps(rows, indent=2, default=str))
    print(f"registry: {rows['registry_base']} base -> "
          f"{rows['registry_tuned']} tuned "
          f"(paper claim: >{rows['paper_claim_min_primitives']})")
    print(f"variants: {rows['variants_generated']} generated, "
          f"{rows['variants_surviving']} surviving, "
          f"{rows['variants_pruned']} pruned "
          f"({rows['measurements']} measurements, "
          f"{rows['tune_s']:.1f}s)")
    for name, t in rows["towers"].items():
        print(f"{name}: gap {t['gap_base']:.3f} -> {t['gap_tuned']:.3f}"
              f" | variant wins {t['variant_wins']}"
              f" | solve {t['solve_s_base']*1e3:.1f} -> "
              f"{t['solve_s_tuned']*1e3:.1f} ms "
              f"({t['solve_ratio']:.2f}x)")
    for g, ok in rows["gates"].items():
        print(f"gate {g}: {'ok' if ok else 'FAIL'}")
    print(f"-> {path}")
    return 0 if rows["gates_ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
