"""Calibration benchmark: analytic vs measured PBQP selection.

Answers the two questions the calibration subsystem exists for, with
real on-device measurements (not synthetic tables):

1. **Selection deltas** — calibrate a HardwareProfile over the exact
   scenario buckets of small serving towers, then solve the PBQP under
   the analytic roofline and under the measured table.  Per network:
   which conv nodes changed primitive, and what each model predicts the
   network costs.  On any real machine the measured ranking diverges
   from the roofline, so at least one network flips at least one node.

2. **Recalibration invalidates cached plans** — serve through a
   :class:`~repro.serving.server.PlanServer` backed by the measured
   profile with a persistent plan-cache dir, then recalibrate (perturb
   the table, as a re-sweep on drifted hardware would) and open a new
   server on the *same* dir: the cost-model version key must miss, so
   the second server re-solves instead of reusing the stale plan, while
   an identical profile reuses it (zero solves).

Emits one JSON document (also written to benchmarks/results/
calibration.json):

  PYTHONPATH=src python -m benchmarks.bench_calibration
  PYTHONPATH=src python -m benchmarks.bench_calibration --reps 3
"""
from __future__ import annotations

import argparse
import json
import pathlib
import tempfile

import numpy as np


def _towers():
    from repro.serving import conv_tower
    return {
        "tower_d1w16": lambda: conv_tower((8, 16, 16), depth=1, width=16),
        "tower_d2w8": lambda: conv_tower((4, 32, 32), depth=2, width=8),
    }


def calibrate(reps: int, min_time: float, verbose: bool):
    from repro.calibrate import HardwareProfile, plan_sweep, run_sweep, \
        scenarios_from_net

    scns = []
    for build in _towers().values():
        scns.extend(scenarios_from_net(build()))
    # fused-pair measurements would multiply this benchmark's on-device
    # sweep several-fold; it measures the calibration machinery itself,
    # so stick to the prim/dt items (bench_plan_cache's fusion section
    # covers fused-edge pricing)
    items = plan_sweep(scns, fused=False)
    profile = HardwareProfile.new(reps=reps, min_time=min_time)

    def progress(i, n, item, t):
        if verbose:
            print(f"  [{i + 1}/{n}] {item.label}: {t * 1e3:.3f} ms")

    report = run_sweep(profile, items, progress=progress)
    return profile, {"buckets": len(scns), **report}


def selection_deltas(profile) -> dict:
    from repro.calibrate import CalibratedCostModel
    from repro.core.costs import AnalyticCostModel
    from repro.core.selection import select_pbqp

    analytic = AnalyticCostModel()
    out = {}
    for name, build in _towers().items():
        calibrated = CalibratedCostModel(profile, fallback=analytic)
        net = build()
        sa = select_pbqp(net, analytic)
        sc = select_pbqp(net, calibrated)
        deltas = []
        for node in net.conv_nodes():
            a = sa.choices[node.id].primitive.name
            c = sc.choices[node.id].primitive.name
            if a != c:
                deltas.append({"node": node.id, "scenario": node.scn.key(),
                               "analytic": a, "measured": c})
        out[name] = {
            "conv_nodes": len(net.conv_nodes()),
            "changed_nodes": len(deltas),
            "deltas": deltas,
            "analytic_predicted_s": sa.predicted_cost,
            "measured_predicted_s": sc.predicted_cost,
            "cost_model_coverage": calibrated.coverage(),
        }
    return out


def invalidation(profile) -> dict:
    """Same cache dir, three servers: v1, v1 again, recalibrated v2."""
    from repro.calibrate import CalibratedCostModel
    from repro.serving import PlanServer, conv_tower

    builder = lambda s: conv_tower(s, depth=2, width=8)
    x = np.random.default_rng(0).normal(size=(4, 20, 20)).astype(np.float32)

    def serve_once(prof):
        srv = PlanServer(builder, CalibratedCostModel(prof),
                         cache_dir=d, lru_capacity=2)
        srv.infer(x)
        stats = srv.stats()
        srv.close()
        return stats

    with tempfile.TemporaryDirectory() as d:
        cold = serve_once(profile)
        warm = serve_once(profile)          # identical profile: disk hit
        recal = profile.from_payload(profile.to_payload())
        rng = np.random.default_rng(1)      # drifted re-measurement
        recal.entries = {k: v * float(rng.uniform(0.5, 2.0))
                         for k, v in recal.entries.items()}
        fresh = serve_once(recal)

    return {
        "v1_version": CalibratedCostModel(profile).version(),
        "v2_version": CalibratedCostModel(recal).version(),
        "cold_solves": cold["solves"],
        "same_profile_solves": warm["solves"],
        "same_profile_disk_hits": warm["plan_disk_hits"],
        "recalibrated_solves": fresh["solves"],
        "recalibration_invalidates": fresh["solves"] > 0
        and warm["solves"] == 0,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--reps", type=int, default=2)
    ap.add_argument("--min-time", type=float, default=2e-3)
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args()

    profile, sweep_report = calibrate(args.reps, args.min_time, args.verbose)
    result = {
        "benchmark": "calibration",
        "device": profile.device,
        "profile_entries": len(profile),
        "profile_content": profile.content_hash(),
        "sweep": sweep_report,
        "selection": selection_deltas(profile),
        "invalidation": invalidation(profile),
    }
    result["any_network_changed"] = any(
        n["changed_nodes"] > 0 for n in result["selection"].values())
    doc = json.dumps(result, indent=2)
    print(doc)
    out = pathlib.Path(__file__).parent / "results"
    out.mkdir(exist_ok=True)
    (out / "calibration.json").write_text(doc)


if __name__ == "__main__":
    main()
