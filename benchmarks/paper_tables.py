"""Benchmarks reproducing the paper's tables and figures.

  * Figures 5/6/7 + Tables 2/3 -> ``strategy_comparison``: whole-network
    inference time per selection strategy (SUM2D baseline, local-optimal
    canonical layout, per-family best, PBQP) per network.
  * Figure 4 -> ``selection_map``: the per-layer primitive the PBQP
    optimum picks for AlexNet.
  * Section 5.4 -> ``solver_overhead``: PBQP solve time per network.

CPU notes: this container is the "general purpose platform" of the
paper (the TPU is priced by the analytic model + dry-run roofline).  XLA
CPU uses all cores, matching the paper's multithreaded configuration.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.convnets import NETWORKS
from repro.core.costs import AnalyticCostModel, CostModel, ProfiledCostModel
from repro.core.plan import compile_plan, measure
from repro.core.selection import (
    SelectionResult, select_family_best, select_local_optimal, select_pbqp,
    select_sum2d,
)

FAMILIES = ["direct", "im2", "kn2", "winograd", "fft"]


def strategies(net, cost: CostModel) -> Dict[str, SelectionResult]:
    out = {"sum2d": select_sum2d(net, cost),
           "local_opt": select_local_optimal(net, cost)}
    for fam in FAMILIES:
        out[fam] = select_family_best(net, cost, fam)
    out["pbqp"] = select_pbqp(net, cost)
    return out


def strategy_comparison(net_names: List[str], cost: CostModel, *,
                        scale: float = 1.0, reps: int = 5,
                        run: bool = True) -> List[dict]:
    """Tables 2/3 + Figures 5/6/7 analogue."""
    rows = []
    for name in net_names:
        net = NETWORKS[name](scale)
        params = net.init_params(seed=0)
        rng = np.random.default_rng(0)
        x = rng.normal(size=net.nodes["data"].out_shape).astype(np.float32)
        sels = strategies(net, cost)
        base_t = None
        ref_out = None
        for sname, sel in sels.items():
            row = {"net": net.name, "strategy": sname,
                   "predicted_ms": sel.predicted_cost * 1e3,
                   "optimal": sel.optimal}
            if run:
                cn = compile_plan(sel, params)
                t = measure(cn, x, reps=reps)
                row["measured_ms"] = t["mean_s"] * 1e3
                out = cn(x)
                if ref_out is None:
                    ref_out = out
                    base_t = t["mean_s"]
                else:
                    for k in ref_out:
                        np.testing.assert_allclose(
                            np.asarray(out[k]), np.asarray(ref_out[k]),
                            rtol=5e-3, atol=5e-3)
                row["speedup_vs_sum2d"] = base_t / t["mean_s"]
            rows.append(row)
        if isinstance(cost, ProfiledCostModel):
            cost.flush()
    return rows


def selection_map(net_name: str, cost: CostModel,
                  scale: float = 1.0) -> List[dict]:
    """Figure 4 analogue: which primitive each conv layer gets."""
    net = NETWORKS[net_name](scale)
    sel = select_pbqp(net, cost)
    rows = []
    for node in net.conv_nodes():
        ch = sel.choices[node.id]
        rows.append({
            "net": net.name, "layer": node.id,
            "scenario": node.scn.key(),
            "primitive": ch.primitive.name,
            "family": ch.primitive.family,
            "layout": f"{ch.l_in}->{ch.l_out}",
        })
    return rows


def solver_overhead(net_names: List[str], cost: CostModel,
                    scale: float = 1.0) -> List[dict]:
    """Section 5.4: solve time must be < 1 s per network."""
    rows = []
    for name in net_names:
        net = NETWORKS[name](scale)
        # warm the cost cache so we time the solver, not the profiler
        _ = select_sum2d(net, cost)
        _ = select_pbqp(net, cost)
        t0 = time.perf_counter()
        sel = select_pbqp(net, cost)
        dt = time.perf_counter() - t0
        rows.append({"net": net.name, "solve_s": dt,
                     "optimal": sel.optimal,
                     "n_convs": len(net.conv_nodes()),
                     "stats": dict(sel.solver_stats)})
    return rows


def primitive_registry_comparison() -> dict:
    """Section 2's scale claim: the paper's cost matrices span "over 70
    primitives" per layer.  Reports where this reproduction stands —
    the hand-written registry alone, and with any installed autotune
    extension (repro.launch.tune) — so the EXPERIMENTS tables can show
    the comparison row."""
    from repro.core.primitives import (
        build_registry, extension_token, registry,
    )
    prims = registry()
    by_family: Dict[str, int] = {}
    for p in prims:
        by_family[p.family] = by_family.get(p.family, 0) + 1
    return {"paper_claim": ">70",
            "handwritten": len(build_registry()),
            "total": len(prims),
            "autotuned": sum(1 for p in prims if p.params),
            "extension_token": extension_token(),
            "by_family": dict(sorted(by_family.items()))}
