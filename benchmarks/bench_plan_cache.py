"""Plan-cache serving benchmark: cold vs warm solve, cold vs hot
requests, batched vs sequential execution, fused vs materialized
layout transforms.

Measures the four amortizations the serving subsystem provides:

1. **Solver**: cold exact PBQP solve vs warm-started re-solve after
   perturbing a subset of node cost vectors (the neighbouring-bucket
   case), on dense instances that force branch-and-bound.
2. **End-to-end**: per-request latency through :class:`~repro.serving.
   server.PlanServer` with a cold cache (solve + compile on the miss
   path) vs a hot cache (executable LRU hit).
3. **Batching**: throughput of the same request stream through the
   sequential ``infer`` path vs the coalescing ``infer_batch`` path
   (one vmapped tower invocation per bucket group), with per-request
   cropped outputs verified identical; plus the batch-aware selection
   table showing the optimal primitive assignment flipping between
   N=1 and N=8.
4. **Fusion**: end-to-end tower time of the fused-transform plan vs
   the materialized-transform plan under a calibrated (measured-table)
   cost model on a layout-affine tower, with both plans executed and
   their outputs verified identical, the per-node assignment flip
   table the fused edge pricing provokes, and the same solve repeated
   under the analytic TPU spec over the Pallas kernel family.

Emits one JSON document (also written to benchmarks/results/) so the
perf trajectory across PRs is machine-readable:

  PYTHONPATH=src python -m benchmarks.bench_plan_cache
  PYTHONPATH=src python -m benchmarks.bench_plan_cache --only fusion
"""
from __future__ import annotations

import argparse
import json
import pathlib
import statistics
import tempfile
import time

import numpy as np


def bench_solver(cases: int, seed: int = 0) -> dict:
    from repro.core.pbqp import PBQP, solve, solve_warm

    rng = np.random.default_rng(seed)
    cold_s, warm_s, bb_cold, bb_warm = [], [], [], []
    for _ in range(cases):
        n, k = 7, 4
        pb = PBQP()
        for i in range(n):
            pb.add_node(i, rng.uniform(1, 100, size=k))
        for i in range(n):
            for j in range(i + 1, n):
                pb.add_edge(i, j, rng.uniform(0, 50, size=(k, k)))
        prev = solve(pb, exact=True)
        # the bucket shift: re-price half the nodes
        for i in rng.choice(n, size=n // 2, replace=False):
            pb.set_node_cost(int(i), rng.uniform(1, 100, size=k))
        t0 = time.perf_counter()
        fresh = solve(pb, exact=True)
        cold_s.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        warm = solve_warm(pb, prev.assignment, exact=True)
        warm_s.append(time.perf_counter() - t0)
        assert abs(warm.cost - fresh.cost) < 1e-9
        bb_cold.append(fresh.stats["BB"])
        bb_warm.append(warm.stats["BB"])
    return {
        "cases": cases,
        "solve_cold_ms": statistics.median(cold_s) * 1e3,
        "solve_warm_ms": statistics.median(warm_s) * 1e3,
        "solve_speedup": statistics.median(cold_s) /
        max(statistics.median(warm_s), 1e-12),
        "bb_nodes_cold": statistics.median(bb_cold),
        "bb_nodes_warm": statistics.median(bb_warm),
    }


def bench_server(reps: int, seed: int = 0) -> dict:
    from repro.core.costs import AnalyticCostModel
    from repro.serving import BucketPolicy, PlanServer, conv_tower

    rng = np.random.default_rng(seed)
    with tempfile.TemporaryDirectory() as d:
        srv = PlanServer(lambda s: conv_tower(s, depth=2, width=8),
                         AnalyticCostModel(),
                         policy=BucketPolicy(min_hw=8, max_hw=64),
                         cache_dir=d, lru_capacity=4)
        x = rng.normal(size=(3, 20, 20)).astype(np.float32)
        t0 = time.perf_counter()
        srv.infer(x)
        cold = time.perf_counter() - t0
        hot = []
        for _ in range(reps):
            x = rng.normal(size=(3, int(rng.integers(17, 32)),
                                 int(rng.integers(17, 32))))
            t0 = time.perf_counter()
            srv.infer(x.astype(np.float32))
            hot.append(time.perf_counter() - t0)
        stats = srv.stats()
        srv.close()

        # disk tier: new server, same cache dir -> no solve, only compile
        srv2 = PlanServer(lambda s: conv_tower(s, depth=2, width=8),
                          AnalyticCostModel(),
                          policy=BucketPolicy(min_hw=8, max_hw=64),
                          cache_dir=d, lru_capacity=4)
        t0 = time.perf_counter()
        srv2.infer(rng.normal(size=(3, 20, 20)).astype(np.float32))
        disk_warm = time.perf_counter() - t0
        assert srv2.stats()["solves"] == 0
        srv2.close()

    return {
        "request_cold_ms": cold * 1e3,
        "request_hot_ms": statistics.median(hot) * 1e3,
        "request_disk_warm_ms": disk_warm * 1e3,
        "cold_over_hot": cold / max(statistics.median(hot), 1e-12),
        "counters": {k: v for k, v in stats.items()
                     if isinstance(v, (int, float))},
    }


def bench_batched(requests: int, seed: int = 0) -> dict:
    """Same request stream through sequential infer vs infer_batch.

    Both paths run hot (plans + executables pre-warmed, so neither
    measurement contains a solve or compile) on a stream of random-
    shape images collapsing into a couple of buckets.  Outputs are
    compared request-by-request (cropped to the request extent).
    """
    from repro.core.costs import AnalyticCostModel
    from repro.core.selection import select_pbqp
    from repro.serving import BucketPolicy, PlanServer, conv_stack

    rng = np.random.default_rng(seed)
    policy = BucketPolicy(min_hw=8, max_hw=64)
    srv = PlanServer(lambda s: conv_stack(s, depth=2, width=8),
                     AnalyticCostModel(), policy=policy, lru_capacity=8)
    # channel count pinned at a pow2 so every request shares its
    # bucket's weights; spatial extents vary within one bucket — the
    # same-bucket coalescing case the admission queue produces
    stream = [rng.normal(size=(4, int(rng.integers(12, 17)),
                               int(rng.integers(12, 17))))
              .astype(np.float32) for _ in range(requests)]

    # warm both paths (solve + compile excluded from the timings)
    seq_out = [srv.infer(x) for x in stream]
    bat_out = srv.infer_batch(stream)
    match = all(
        np.allclose(seq_out[i][k], bat_out[i][k], rtol=2e-3, atol=2e-3)
        for i in range(requests) for k in seq_out[i])

    seq_s, bat_s = [], []
    for _ in range(3):
        t0 = time.perf_counter()
        for x in stream:
            srv.infer(x)
        seq_s.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        srv.infer_batch(stream)
        bat_s.append(time.perf_counter() - t0)
    seq_s, bat_s = min(seq_s), min(bat_s)
    stats = srv.stats()
    srv.close()

    # batch-aware selection: the assignment flips between N=1 and N=8
    cm = AnalyticCostModel()
    flips = {}
    for n in (1, 8):
        net = conv_stack((4, 32, 32), depth=2, width=8).with_batch(n)
        sel = select_pbqp(net, cm)
        for node in net.conv_nodes():
            flips.setdefault(node.id, {})[f"n{n}"] = \
                sel.choices[node.id].primitive.name
    flipped = [nid for nid, d in flips.items() if d["n1"] != d["n8"]]

    return {
        "requests": requests,
        "sequential_s": seq_s,
        "batched_s": bat_s,
        "sequential_req_per_s": requests / max(seq_s, 1e-12),
        "batched_req_per_s": requests / max(bat_s, 1e-12),
        "batched_speedup": seq_s / max(bat_s, 1e-12),
        "outputs_match": bool(match),
        "batch_calls": stats["batch_calls"],
        "coalesced": stats["coalesced"],
        "selection_by_batch": flips,
        "selection_flips_n1_to_n8": flipped,
    }


def _fusion_tower(depth: int, c: int, hw: int):
    """Conv-only tower alternating two scenario classes (m = c vs 2c) so
    per-layer measured optima can alternate layouts."""
    from repro.core.graph import Net

    net = Net(f"fusion{depth}c{c}hw{hw}")
    x = net.input("data", (c, hw, hw))
    for i in range(depth):
        x = net.conv(f"conv{i}", x, k=3, m=(c if i % 2 else 2 * c), pad=1)
    return net


def _fusion_profile(net, fast: float, slow: float, dt_s: float,
                    fuse_extra: float):
    """A deterministic measured-cost table for the fusion demo.

    Models strongly layout-affine kernels — the regime the paper
    measures (its vectorized NHWC routines beat the CHW twins well over
    1.5x on ARM): per scenario class, the fast primitive alternates
    between the HWC-native and CHW-native direct_lax routine, a
    materialized DT round trip costs ``dt_s``, and a fused
    prologue/epilogue pays only ``fuse_extra`` on top of the native
    invocation (the measured fused-pair entries the calibration sweep
    produces).  Deterministic stand-in for a real sweep so the
    benchmark needs no on-device timing to exercise the machinery.
    """
    from repro.calibrate import HardwareProfile
    from repro.core.costs import (
        fused_cost_key, prim_cost_key, transform_cost_key,
    )
    from repro.serving.bucketing import BucketPolicy, bucket_scenario

    policy = BucketPolicy()
    prof = HardwareProfile.new()
    hwc, chw = "direct_lax_hwc_hwc_oihw", "direct_lax_chw_chw_oihw"
    for i, node in enumerate(net.conv_nodes()):
        b = bucket_scenario(node.scn, policy)
        fast_hwc = i % 2 == 0
        prof.put(prim_cost_key(hwc, b), fast if fast_hwc else slow)
        prof.put(prim_cost_key(chw, b), slow if fast_hwc else fast)
        for p, other in ((hwc, "CHW"), (chw, "HWC")):
            native = prof.get(prim_cost_key(p, b))
            prof.put(fused_cost_key("in", p, other, b), native + fuse_extra)
            prof.put(fused_cost_key("out", p, other, b), native + fuse_extra)
        for shape in (b.in_shape_chw, b.out_shape_chw):
            for s, t in (("CHW", "HWC"), ("HWC", "CHW")):
                prof.put(transform_cost_key(s, t, shape), dt_s)
    return prof, policy


def bench_fusion(depth: int = 6, c: int = 16, hw: int = 32,
                 seed: int = 0) -> dict:
    """Fused vs materialized transform execution, end to end.

    Solves the layout-affine tower twice under the same calibrated
    cost model — edges priced materialized-only vs ``min(materialized,
    fused prologue, fused epilogue)`` — then compiles and runs BOTH
    plans, checking outputs match.  Reports:

    * ``tower_speedup`` — end-to-end tower time of the materialized
      optimum over the fused optimum, in the cost model's currency
      (the paper's own reporting unit: the solved objective is the sum
      of per-layer measured costs).  Must be >= 1.3 on this tower.
    * ``selection_flips`` — conv nodes whose assigned primitive
      changes once fused edge costs are visible (the solver *chooses
      differently*, not just executes differently).
    * ``outputs_match`` — the two compiled executables agree
      numerically on the same input.
    * ``measured_cpu`` — honest paired wall-clock of both executables
      on this host.  On XLA:CPU the backend canonicalizes dot/conv
      layouts (materializing the same copies either way), so parity
      here is expected; the fused wall-clock ceiling belongs to the
      in-kernel Pallas entry points on TPU, which CPU CI cannot time
      meaningfully (the same reason tpu-only primitives are excluded
      from CPU profiling).
    """
    import jax

    from repro.calibrate import CalibratedCostModel
    from repro.core.plan import compile_plan
    from repro.core.selection import select_pbqp

    net = _fusion_tower(depth, c, hw)
    # fast/slow primitive gap 2x, DT round trip = the gap, fused pair
    # nearly free: the shape of the paper's measured ARM/HWC tables
    prof, policy = _fusion_profile(net, fast=10e-6, slow=20e-6,
                                   dt_s=10e-6, fuse_extra=0.5e-6)
    cm = CalibratedCostModel(prof, policy=policy)
    s_mat = select_pbqp(net, cm, fuse=False)
    s_fus = select_pbqp(net, cm, fuse=True)

    flips = {}
    for node in net.conv_nodes():
        a = s_mat.choices[node.id].primitive.name
        b = s_fus.choices[node.id].primitive.name
        flips[node.id] = {"materialized": a, "fused": b}
    flipped = [nid for nid, d in flips.items()
               if d["materialized"] != d["fused"]]

    params = net.init_params(seed)
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(c, hw, hw)).astype(np.float32)
    cn_mat = compile_plan(s_mat, params)
    cn_fus = compile_plan(s_fus, params)
    out_m, out_f = cn_mat(x), cn_fus(x)
    match = all(np.allclose(np.asarray(out_m[k]), np.asarray(out_f[k]),
                            rtol=2e-3, atol=2e-3) for k in out_m)

    # paired interleaved wall clock (robust to machine-wide drift)
    import jax.numpy as jnp
    xj = jnp.asarray(x)
    for cn in (cn_mat, cn_fus):
        for _ in range(3):
            jax.block_until_ready(cn.fn(xj, cn.params))
    ratios, t_m, t_f = [], [], []
    for _ in range(12):
        t0 = time.perf_counter()
        for _ in range(4):
            jax.block_until_ready(cn_mat.fn(xj, cn_mat.params))
        tm = time.perf_counter() - t0
        t0 = time.perf_counter()
        for _ in range(4):
            jax.block_until_ready(cn_fus.fn(xj, cn_fus.params))
        tf = time.perf_counter() - t0
        ratios.append(tm / tf)
        t_m.append(tm / 4)
        t_f.append(tf / 4)

    # serving-path equivalence: a fused PlanServer must serve the same
    # *cropped* outputs as a materialized one for an off-bucket request
    from repro.core.costs import AnalyticCostModel as _ACM
    from repro.serving import BucketPolicy as _BP
    from repro.serving import PlanServer, conv_stack
    req = rng.normal(size=(4, 13, 15)).astype(np.float32)
    crops = []
    for fuse in (False, True):
        srv = PlanServer(lambda s: conv_stack(s, depth=2, width=8), _ACM(),
                         policy=_BP(min_hw=8, max_hw=64), fuse=fuse)
        crops.append(srv.infer(req))
        srv.close()
    crop_match = all(
        crops[0][k].shape == crops[1][k].shape
        and np.allclose(crops[0][k], crops[1][k], rtol=2e-3, atol=2e-3)
        for k in crops[0])

    # the same machinery under the analytic TPU spec, Pallas family
    # only: the solver sees fused prologue/epilogue prices for the
    # in-kernel entry points (conv_direct CHW prologue, transposed-out
    # GEMM, ...) and realizes fused edges where they win
    from repro.core.costs import AnalyticCostModel, TPU_V5E_SPEC
    tpu = AnalyticCostModel(TPU_V5E_SPEC, include_tpu_only=True)
    tnet = _fusion_tower(depth, 32, 128)
    t_mat = select_pbqp(tnet, tpu, fuse=False, families=["pallas"])
    t_fus = select_pbqp(tnet, tpu, fuse=True, families=["pallas"])

    return {
        "tower": {"depth": depth, "c": c, "hw": hw},
        "tower_speedup": s_mat.predicted_cost /
        max(s_fus.predicted_cost, 1e-30),
        "predicted_materialized_s": s_mat.predicted_cost,
        "predicted_fused_s": s_fus.predicted_cost,
        "edges_materialized": len(s_mat.conversions),
        "edges_fused": len(s_fus.fusions),
        "fused_edge_kinds": dict(
            (f"{u}->{v}", kind) for (u, v), kind in s_fus.fusions.items()),
        "selection_flips": flipped,
        "flip_table": flips,
        "outputs_match": bool(match),
        "cropped_outputs_match": bool(crop_match),
        "measured_cpu": {
            "materialized_ms": statistics.median(t_m) * 1e3,
            "fused_ms": statistics.median(t_f) * 1e3,
            "paired_speedup": statistics.median(ratios),
            "note": "XLA:CPU canonicalizes dot/conv layouts, so the CPU "
                    "executor materializes the same copies either way; "
                    "the fused wall-clock win is realized by the "
                    "in-kernel Pallas entry points on TPU.",
        },
        "analytic_tpu": {
            "predicted_materialized_s": t_mat.predicted_cost,
            "predicted_fused_s": t_fus.predicted_cost,
            "speedup": t_mat.predicted_cost /
            max(t_fus.predicted_cost, 1e-30),
            "edges_fused": len(t_fus.fusions),
        },
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cases", type=int, default=20,
                    help="solver perturbation cases")
    ap.add_argument("--reps", type=int, default=8,
                    help="hot-path request repetitions")
    ap.add_argument("--requests", type=int, default=16,
                    help="batched-vs-sequential stream length")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--only", default=None,
                    choices=("solver", "server", "batched", "fusion"),
                    help="run a single section (CI smoke jobs)")
    args = ap.parse_args()

    sections = {
        "solver": lambda: bench_solver(args.cases, args.seed),
        "server": lambda: bench_server(args.reps, args.seed),
        "batched": lambda: bench_batched(args.requests, args.seed),
        "fusion": lambda: bench_fusion(seed=args.seed),
    }
    result = {"benchmark": "plan_cache"}
    for name, fn in sections.items():
        if args.only is None or args.only == name:
            result[name] = fn()
    doc = json.dumps(result, indent=2)
    print(doc)
    out = pathlib.Path(__file__).parent / "results"
    out.mkdir(exist_ok=True)
    name = "plan_cache.json" if args.only is None \
        else f"plan_cache_{args.only}.json"
    (out / name).write_text(doc)


if __name__ == "__main__":
    main()
