"""Plan-cache serving benchmark: cold vs warm solve, cold vs hot
requests, batched vs sequential execution.

Measures the three amortizations the serving subsystem provides:

1. **Solver**: cold exact PBQP solve vs warm-started re-solve after
   perturbing a subset of node cost vectors (the neighbouring-bucket
   case), on dense instances that force branch-and-bound.
2. **End-to-end**: per-request latency through :class:`~repro.serving.
   server.PlanServer` with a cold cache (solve + compile on the miss
   path) vs a hot cache (executable LRU hit).
3. **Batching**: throughput of the same request stream through the
   sequential ``infer`` path vs the coalescing ``infer_batch`` path
   (one vmapped tower invocation per bucket group), with per-request
   cropped outputs verified identical; plus the batch-aware selection
   table showing the optimal primitive assignment flipping between
   N=1 and N=8.

Emits one JSON document (also written to benchmarks/results/) so the
perf trajectory across PRs is machine-readable:

  PYTHONPATH=src python -m benchmarks.bench_plan_cache
  PYTHONPATH=src python -m benchmarks.bench_plan_cache --cases 10
"""
from __future__ import annotations

import argparse
import json
import pathlib
import statistics
import tempfile
import time

import numpy as np


def bench_solver(cases: int, seed: int = 0) -> dict:
    from repro.core.pbqp import PBQP, solve, solve_warm

    rng = np.random.default_rng(seed)
    cold_s, warm_s, bb_cold, bb_warm = [], [], [], []
    for _ in range(cases):
        n, k = 7, 4
        pb = PBQP()
        for i in range(n):
            pb.add_node(i, rng.uniform(1, 100, size=k))
        for i in range(n):
            for j in range(i + 1, n):
                pb.add_edge(i, j, rng.uniform(0, 50, size=(k, k)))
        prev = solve(pb, exact=True)
        # the bucket shift: re-price half the nodes
        for i in rng.choice(n, size=n // 2, replace=False):
            pb.set_node_cost(int(i), rng.uniform(1, 100, size=k))
        t0 = time.perf_counter()
        fresh = solve(pb, exact=True)
        cold_s.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        warm = solve_warm(pb, prev.assignment, exact=True)
        warm_s.append(time.perf_counter() - t0)
        assert abs(warm.cost - fresh.cost) < 1e-9
        bb_cold.append(fresh.stats["BB"])
        bb_warm.append(warm.stats["BB"])
    return {
        "cases": cases,
        "solve_cold_ms": statistics.median(cold_s) * 1e3,
        "solve_warm_ms": statistics.median(warm_s) * 1e3,
        "solve_speedup": statistics.median(cold_s) /
        max(statistics.median(warm_s), 1e-12),
        "bb_nodes_cold": statistics.median(bb_cold),
        "bb_nodes_warm": statistics.median(bb_warm),
    }


def bench_server(reps: int, seed: int = 0) -> dict:
    from repro.core.costs import AnalyticCostModel
    from repro.serving import BucketPolicy, PlanServer, conv_tower

    rng = np.random.default_rng(seed)
    with tempfile.TemporaryDirectory() as d:
        srv = PlanServer(lambda s: conv_tower(s, depth=2, width=8),
                         AnalyticCostModel(),
                         policy=BucketPolicy(min_hw=8, max_hw=64),
                         cache_dir=d, lru_capacity=4)
        x = rng.normal(size=(3, 20, 20)).astype(np.float32)
        t0 = time.perf_counter()
        srv.infer(x)
        cold = time.perf_counter() - t0
        hot = []
        for _ in range(reps):
            x = rng.normal(size=(3, int(rng.integers(17, 32)),
                                 int(rng.integers(17, 32))))
            t0 = time.perf_counter()
            srv.infer(x.astype(np.float32))
            hot.append(time.perf_counter() - t0)
        stats = srv.stats()
        srv.close()

        # disk tier: new server, same cache dir -> no solve, only compile
        srv2 = PlanServer(lambda s: conv_tower(s, depth=2, width=8),
                          AnalyticCostModel(),
                          policy=BucketPolicy(min_hw=8, max_hw=64),
                          cache_dir=d, lru_capacity=4)
        t0 = time.perf_counter()
        srv2.infer(rng.normal(size=(3, 20, 20)).astype(np.float32))
        disk_warm = time.perf_counter() - t0
        assert srv2.stats()["solves"] == 0
        srv2.close()

    return {
        "request_cold_ms": cold * 1e3,
        "request_hot_ms": statistics.median(hot) * 1e3,
        "request_disk_warm_ms": disk_warm * 1e3,
        "cold_over_hot": cold / max(statistics.median(hot), 1e-12),
        "counters": {k: v for k, v in stats.items()
                     if isinstance(v, (int, float))},
    }


def bench_batched(requests: int, seed: int = 0) -> dict:
    """Same request stream through sequential infer vs infer_batch.

    Both paths run hot (plans + executables pre-warmed, so neither
    measurement contains a solve or compile) on a stream of random-
    shape images collapsing into a couple of buckets.  Outputs are
    compared request-by-request (cropped to the request extent).
    """
    from repro.core.costs import AnalyticCostModel
    from repro.core.selection import select_pbqp
    from repro.serving import BucketPolicy, PlanServer, conv_stack

    rng = np.random.default_rng(seed)
    policy = BucketPolicy(min_hw=8, max_hw=64)
    srv = PlanServer(lambda s: conv_stack(s, depth=2, width=8),
                     AnalyticCostModel(), policy=policy, lru_capacity=8)
    # channel count pinned at a pow2 so every request shares its
    # bucket's weights; spatial extents vary within one bucket — the
    # same-bucket coalescing case the admission queue produces
    stream = [rng.normal(size=(4, int(rng.integers(12, 17)),
                               int(rng.integers(12, 17))))
              .astype(np.float32) for _ in range(requests)]

    # warm both paths (solve + compile excluded from the timings)
    seq_out = [srv.infer(x) for x in stream]
    bat_out = srv.infer_batch(stream)
    match = all(
        np.allclose(seq_out[i][k], bat_out[i][k], rtol=2e-3, atol=2e-3)
        for i in range(requests) for k in seq_out[i])

    seq_s, bat_s = [], []
    for _ in range(3):
        t0 = time.perf_counter()
        for x in stream:
            srv.infer(x)
        seq_s.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        srv.infer_batch(stream)
        bat_s.append(time.perf_counter() - t0)
    seq_s, bat_s = min(seq_s), min(bat_s)
    stats = srv.stats()
    srv.close()

    # batch-aware selection: the assignment flips between N=1 and N=8
    cm = AnalyticCostModel()
    flips = {}
    for n in (1, 8):
        net = conv_stack((4, 32, 32), depth=2, width=8).with_batch(n)
        sel = select_pbqp(net, cm)
        for node in net.conv_nodes():
            flips.setdefault(node.id, {})[f"n{n}"] = \
                sel.choices[node.id].primitive.name
    flipped = [nid for nid, d in flips.items() if d["n1"] != d["n8"]]

    return {
        "requests": requests,
        "sequential_s": seq_s,
        "batched_s": bat_s,
        "sequential_req_per_s": requests / max(seq_s, 1e-12),
        "batched_req_per_s": requests / max(bat_s, 1e-12),
        "batched_speedup": seq_s / max(bat_s, 1e-12),
        "outputs_match": bool(match),
        "batch_calls": stats["batch_calls"],
        "coalesced": stats["coalesced"],
        "selection_by_batch": flips,
        "selection_flips_n1_to_n8": flipped,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cases", type=int, default=20,
                    help="solver perturbation cases")
    ap.add_argument("--reps", type=int, default=8,
                    help="hot-path request repetitions")
    ap.add_argument("--requests", type=int, default=16,
                    help="batched-vs-sequential stream length")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    result = {
        "benchmark": "plan_cache",
        "solver": bench_solver(args.cases, args.seed),
        "server": bench_server(args.reps, args.seed),
        "batched": bench_batched(args.requests, args.seed),
    }
    doc = json.dumps(result, indent=2)
    print(doc)
    out = pathlib.Path(__file__).parent / "results"
    out.mkdir(exist_ok=True)
    (out / "plan_cache.json").write_text(doc)


if __name__ == "__main__":
    main()
