"""Chaos benchmark: the PR 7 Poisson trace under a scheduled fault storm.

Four sections, each a falsifiable reliability claim (docs/reliability.md):

* **storm** — replay ONE open-loop Poisson arrival trace (the
  bench_load machinery) twice: fault-free, then under a deterministic
  :class:`~repro.reliability.FaultInjector` plan that corrupts plan-
  cache files, fails compiles, NaN-poisons kernel invocations, shrinks
  a solve budget, and kills a worker slot mid-dispatch.  Gates:

  - ``zero_wrong_outputs`` — every request that completes under the
    storm is output-identical (allclose) to its fault-free twin.  A
    chaos layer that serves wrong answers fast is worse than one that
    fails loudly; this is the non-negotiable gate.
  - ``availability`` ≥ 99% — faults degrade (retry, requeue,
    quarantine + re-solve), they don't refuse.
  - ``recovery_s`` bounded — after the storm drains, every bucket
    serves again within the recovery budget (including any quarantine
    re-solve + recompile it still owes).

* **quarantine** — the circuit-breaker lifecycle end to end on a
  persistent cache: healthy plan on disk -> injected kernel NaN on its
  optimal primitive -> breaker trips, cache key rotates, warm-started
  re-solve *excludes* the primitive, the request still answers
  correctly -> release -> the rotation token vanishes and the bucket
  recovers its original plan as a disk *hit* (no re-solve).

* **anytime** — the solve deadline on the PR 8 parallelism tower
  (``bottleneck_tower`` over a dp×tp mesh): a deadline-armed solve must
  price within 1.1× of exact.  Reductions solve the tower outright, so
  the binding-deadline case is exercised on dense random PBQP instances
  (the B&B-heavy shape tests/test_warm_start.py uses) with the deadline
  pre-expired — the pure best-so-far completion, the worst anytime can
  do — gated at mean ≤ 1.1× exact across seeds.

Results land in ``benchmarks/results/chaos.json`` with a ``gates``
section CI's chaos-smoke job asserts on:

  PYTHONPATH=src python -m benchmarks.bench_chaos
"""
from __future__ import annotations

import argparse
import json
import pathlib
import tempfile
import threading
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from benchmarks.bench_load import SHAPES, gen_trace

#: the storm: every fault site fires at a scheduled, deterministic tick
#: (docs/reliability.md has the taxonomy; windows are [start, start+count))
STORM_PLAN = ",".join([
    "plan_cache:corrupt@0+2",   # 2 prewarm disk reads hit torn files
    "compile:raise@0+2",        # first compile fails twice, retries win
    "solve:raise@0+1",          # one solve fails: greedy-rung demotion
    "kernel:nan@6+1",           # NaN-poison two invocations mid-storm:
    "kernel:nan@14+1",          # breaker trips, banned re-solve, retry
    "worker:raise@3+1",         # one worker slot dies, group requeues
])

ANYTIME_SEEDS = (0, 1, 2, 3, 4)


def _make_server(cache_dir=None, fault_plan: Optional[str] = None,
                 seed: int = 0):
    from repro.core.costs import AnalyticCostModel
    from repro.reliability import FaultInjector, parse_fault_plan
    from repro.serving import BucketPolicy, PlanServer, conv_tower

    injector = FaultInjector(parse_fault_plan(fault_plan), seed=seed) \
        if fault_plan else None
    policy = BucketPolicy(min_hw=8, max_hw=32, max_n=4)
    return PlanServer(lambda s: conv_tower(s, depth=2, width=4),
                      AnalyticCostModel(), policy=policy,
                      lru_capacity=16, cache_dir=cache_dir,
                      fault_injector=injector,
                      compile_backoff_s=0.005)


def _prewarm(srv) -> None:
    from repro.serving import bucket_shape
    buckets = {bucket_shape(s, srv.policy) for s in SHAPES}
    batches = {srv.policy.bucket_n(n)
               for n in range(1, srv.policy.max_n + 1)}
    for f in [srv.prefetch(b, n=nb) for b in buckets for nb in batches]:
        f.result()


def _replay_collect(trace, submit, timeout: float = 180.0
                    ) -> List[Optional[Dict[str, np.ndarray]]]:
    """Open-loop replay that keeps each request's *outputs* (None on
    failure) — the storm's correctness gate compares them elementwise
    against the fault-free run's."""
    futs: List[Optional[object]] = [None] * len(trace)
    done = threading.Event()
    remaining = [len(trace)]
    lock = threading.Lock()

    def arm(fut):
        def cb(_f):
            with lock:
                remaining[0] -= 1
                if remaining[0] == 0:
                    done.set()
        fut.add_done_callback(cb)
        return fut

    t0 = time.perf_counter()
    for i, (at, x) in enumerate(trace):
        now = time.perf_counter() - t0
        if at > now:
            time.sleep(at - now)
        try:
            futs[i] = arm(submit(x))
        except Exception:
            futs[i] = None  # shed/refused at admission
            with lock:
                remaining[0] -= 1
                if remaining[0] == 0:
                    done.set()
    done.wait(timeout=timeout)
    outs: List[Optional[Dict[str, np.ndarray]]] = []
    for f in futs:
        if f is None:
            outs.append(None)
            continue
        try:
            outs.append(f.result(timeout=1.0))
        except Exception:
            outs.append(None)
    return outs


def _run_trace(trace, cache_dir, fault_plan: Optional[str]
               ) -> Tuple[List[Optional[Dict]], Dict]:
    from repro.serving import ContinuousScheduler
    srv = _make_server(cache_dir=cache_dir, fault_plan=fault_plan)
    _prewarm(srv)
    sched = ContinuousScheduler(srv, batch_window_s=0.005)
    outs = _replay_collect(trace, sched.submit)
    # recovery probe: after the storm drains, every bucket must serve
    # again — including any quarantine re-solve + recompile still owed
    t0 = time.perf_counter()
    probes_ok = True
    rng = np.random.default_rng(7)
    for shape in SHAPES:
        try:
            probe = srv.infer(
                rng.normal(size=shape).astype(np.float32))
            probes_ok &= all(np.isfinite(v).all()
                             for v in probe.values())
        except Exception:
            probes_ok = False
    recovery_s = time.perf_counter() - t0
    stats = sched.stats()
    stats["recovery_s"] = recovery_s
    stats["recovery_probes_ok"] = probes_ok
    sched.close()
    srv.close()
    return outs, stats


def storm_section(rate: float, requests: int, seed: int) -> Dict:
    trace = gen_trace(rate, requests, seed)
    with tempfile.TemporaryDirectory() as cache_dir:
        # populate the disk tier first so the storm's prewarm actually
        # READS plans — that is where the corrupt-file faults land
        seed_srv = _make_server(cache_dir=cache_dir)
        _prewarm(seed_srv)
        seed_srv.close()
        base_outs, base_stats = _run_trace(trace, cache_dir, None)
        storm_outs, storm_stats = _run_trace(trace, cache_dir,
                                             STORM_PLAN)

    completed = sum(o is not None for o in storm_outs)
    availability = completed / len(trace)
    wrong = 0
    for b, s in zip(base_outs, storm_outs):
        if s is None or b is None:
            continue
        for nid in b:
            if not np.allclose(b[nid], s[nid], rtol=1e-3, atol=1e-5):
                wrong += 1
                break
    counters = {k: storm_stats[k] for k in (
        "plan_cache_corrupt", "compile_retries", "compile_fallbacks",
        "kernel_failures", "quarantines", "worker_deaths",
        "worker_requeues", "ladder_exact", "ladder_anytime",
        "ladder_greedy", "ladder_reference", "shed_requests")}
    return {
        "requests": len(trace),
        "completed": completed,
        "availability": availability,
        "wrong_outputs": wrong,
        "faults_fired": {k: v for k, v in counters.items() if v},
        "recovery_s": storm_stats["recovery_s"],
        "recovery_probes_ok": storm_stats["recovery_probes_ok"],
        "baseline_completed": sum(o is not None for o in base_outs),
        "quarantined_after": storm_stats["quarantined"],
    }


def quarantine_section() -> Dict:
    """Trip -> banned re-solve -> correct answer -> release -> disk-hit
    recovery, on one bucket with a persistent cache."""
    x = np.random.default_rng(3).normal(size=(3, 16, 16)) \
        .astype(np.float32)
    with tempfile.TemporaryDirectory() as cache_dir:
        srv = _make_server(cache_dir=cache_dir)
        healthy = srv.infer(x)
        sel0 = srv.plan_for(x.shape)
        prims0 = sorted({c.primitive.name
                         for c in sel0.choices.values() if c.primitive})
        srv.close()

        target = prims0[0]
        srv = _make_server(cache_dir=cache_dir,
                           fault_plan=f"kernel:nan@0+1~{target}")
        out = srv.infer(x)
        s = srv.stats()
        sel1 = srv.plan_for(x.shape)
        prims1 = sorted({c.primitive.name
                         for c in sel1.choices.values() if c.primitive})
        correct = all(np.allclose(healthy[k], out[k],
                                  rtol=1e-3, atol=1e-5) for k in healthy)
        tripped = s["quarantines"] >= 1
        banned_excluded = target not in prims1

        hits_before = srv.stats()["plan_disk_hits"]
        released = srv.release_quarantine(target, x.shape)
        sel2 = srv.plan_for(x.shape)
        prims2 = sorted({c.primitive.name
                         for c in sel2.choices.values() if c.primitive})
        disk_recovered = \
            srv.stats()["plan_disk_hits"] == hits_before + 1
        srv.close()
    ok = (correct and tripped and banned_excluded and released
          and prims2 == prims0 and disk_recovered)
    return {
        "target": target,
        "healthy_prims": prims0,
        "quarantined_prims": prims1,
        "recovered_prims": prims2,
        "output_correct_during_quarantine": correct,
        "tripped": tripped,
        "banned_excluded": banned_excluded,
        "released": released,
        "recovered_via_disk_hit": disk_recovered,
        "cycle_ok": ok,
    }


def anytime_section() -> Dict:
    """Deadline-armed solves: the tower (reductions finish it — the
    deadline must not perturb the optimum) and dense B&B-heavy
    instances with the deadline pre-expired (worst-case anytime)."""
    from repro.core.costs import AnalyticCostModel
    from repro.core.pbqp import PBQP, solve
    from repro.core.selection import select_pbqp
    from repro.serving.towers import bottleneck_tower

    cm = AnalyticCostModel()
    net = bottleneck_tower((4, 16, 16)).with_batch(16)
    axes = {"data": 2, "model": 4}
    t0 = time.perf_counter()
    exact = select_pbqp(net, cm, mesh_axes=axes)
    exact_s = time.perf_counter() - t0
    capped = select_pbqp(net, cm, mesh_axes=axes,
                         deadline_s=max(exact_s * 0.25, 0.01))
    tower_ratio = capped.predicted_cost / exact.predicted_cost

    def dense(seed: int, n: int = 9, k: int = 4) -> PBQP:
        rng = np.random.default_rng(seed)
        pb = PBQP()
        for i in range(n):
            pb.add_node(i, rng.uniform(1, 100, size=k))
        for i in range(n):
            for j in range(i + 1, n):
                pb.add_edge(i, j, rng.uniform(0, 50, size=(k, k)))
        return pb

    ratios = []
    deadline_fired = 0
    for seed in ANYTIME_SEEDS:
        pb = dense(seed)
        ex = solve(pb, exact=True)
        # deadline_s=0: already expired at entry — branch-and-bound is
        # skipped entirely and the RN heuristic completes best-so-far;
        # deterministic (no wall-clock race) and the worst anytime case
        an = solve(pb, exact=True, deadline_s=0.0)
        assert not an.optimal
        deadline_fired += int(an.stats.get("DEADLINE", 0))
        ratios.append(an.cost / ex.cost)
    return {
        "tower_exact_cost": exact.predicted_cost,
        "tower_deadline_cost": capped.predicted_cost,
        "tower_ratio": tower_ratio,
        "tower_exact_s": exact_s,
        "dense_ratios": ratios,
        "dense_mean_ratio": float(np.mean(ratios)),
        "dense_max_ratio": float(np.max(ratios)),
        "deadline_fired": deadline_fired,
    }


def bench_chaos(rate: float, requests: int, seed: int) -> Dict:
    storm = storm_section(rate, requests, seed)
    quar = quarantine_section()
    anyt = anytime_section()
    gates = {
        "zero_wrong_outputs": storm["wrong_outputs"] == 0,
        "availability": storm["availability"],
        "availability_ok": storm["availability"] >= 0.99,
        "recovery_s": storm["recovery_s"],
        "recovery_ok": storm["recovery_s"] < 60.0
        and storm["recovery_probes_ok"],
        "faults_exercised": storm["faults_fired"].get(
            "kernel_failures", 0) >= 1
        and storm["faults_fired"].get("worker_deaths", 0) >= 1
        and storm["faults_fired"].get("plan_cache_corrupt", 0) >= 1
        and storm["faults_fired"].get("ladder_greedy", 0) >= 1,
        "quarantine_cycle_ok": quar["cycle_ok"],
        "anytime_tower_ok": anyt["tower_ratio"] <= 1.1,
        "anytime_dense_ok": anyt["dense_mean_ratio"] <= 1.1
        and anyt["deadline_fired"] == len(ANYTIME_SEEDS),
    }
    gates["all"] = all(v for k, v in gates.items()
                       if isinstance(v, (bool, np.bool_)))
    return {
        "benchmark": "chaos",
        "rate": rate,
        "requests": requests,
        "seed": seed,
        "storm_plan": STORM_PLAN,
        "storm": storm,
        "quarantine": quar,
        "anytime": anyt,
        "gates": gates,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arrival-rate", type=float, default=60.0)
    ap.add_argument("--requests", type=int, default=80)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    rows = bench_chaos(args.arrival_rate, args.requests, args.seed)
    out = pathlib.Path(args.out) if args.out else \
        pathlib.Path(__file__).parent / "results" / "chaos.json"
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(rows, indent=2, default=str))
    st = rows["storm"]
    print(f"storm: {st['completed']}/{st['requests']} completed "
          f"(availability {st['availability']:.2%}), "
          f"{st['wrong_outputs']} wrong outputs, "
          f"recovery {st['recovery_s']:.2f}s")
    print(f"  faults fired: {st['faults_fired']}")
    q = rows["quarantine"]
    print(f"quarantine: {q['target']} tripped -> re-solve "
          f"{'excluded it' if q['banned_excluded'] else 'FAILED'}, "
          f"release -> "
          f"{'disk-hit recovery' if q['recovered_via_disk_hit'] else 'NO recovery'}")
    a = rows["anytime"]
    print(f"anytime: tower ratio {a['tower_ratio']:.3f}, dense mean "
          f"{a['dense_mean_ratio']:.3f} (max {a['dense_max_ratio']:.3f})"
          f" over {len(ANYTIME_SEEDS)} seeds")
    print(f"gates: {rows['gates']}")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
