"""Open-loop Poisson load benchmark: continuous batching vs tick-flush.

Replays ONE arrival trace — exponential interarrivals at a configured
rate over a mixed scenario set (two spatial buckets) — against two
admission disciplines over identical, pre-warmed :class:`~repro.serving.
server.PlanServer` instances:

* **tick**  — the barrier-flush baseline of PR 3: producers
  ``enqueue()``, a flusher thread calls ``flush()`` every ``tick_ms``.
  Batch size is whatever arrived in one tick, and a request admitted
  right after a flush waits a whole tick before anything launches.
* **continuous** — the :class:`~repro.serving.scheduler.
  ContinuousScheduler`: requests carry the SLO as a deadline, bucket
  groups launch on the full/deadline/window triggers, and the elastic
  controller resizes the worker pool under backlog.

Arrivals are *open-loop* (sender sleeps to the trace's timestamps, never
waits for completions), so both disciplines face the same offered load
regardless of how fast they serve it — the difference shows up in the
latency distribution, not the arrival process.  Per-request latency is
completion minus *arrival* (queueing included), measured identically in
both modes via future done-callbacks.

Emits p50/p95/p99 latency, goodput (fraction of requests completing
inside the SLO) and throughput per mode to
``benchmarks/results/load.json``; the headline claim —
``continuous_beats_tick_p99`` — is what CI's load-smoke job gates on,
alongside a goodput floor.

  PYTHONPATH=src python -m benchmarks.bench_load
  PYTHONPATH=src python -m benchmarks.bench_load \\
      --arrival-rate 50 --requests 200 --slo-ms 250
"""
from __future__ import annotations

import argparse
import json
import pathlib
import threading
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

#: request mix: two spatial buckets under the bench policy (16 and 32)
SHAPES: Tuple[Tuple[int, int, int], ...] = (
    (3, 14, 14), (3, 16, 16), (3, 24, 24), (3, 30, 30))


def make_server():
    from repro.core.costs import AnalyticCostModel
    from repro.serving import BucketPolicy, PlanServer, conv_tower

    policy = BucketPolicy(min_hw=8, max_hw=32, max_n=4)
    return PlanServer(lambda s: conv_tower(s, depth=2, width=4),
                      AnalyticCostModel(), policy=policy,
                      lru_capacity=16)


def gen_trace(rate: float, n: int, seed: int
              ) -> List[Tuple[float, np.ndarray]]:
    """(arrival_s, image) pairs — the SAME trace replays in both modes,
    so offered load is equal by construction."""
    rng = np.random.default_rng(seed)
    t = 0.0
    trace = []
    for _ in range(n):
        t += float(rng.exponential(1.0 / rate))
        shape = SHAPES[int(rng.integers(len(SHAPES)))]
        trace.append((t, rng.normal(size=shape).astype(np.float32)))
    return trace


def _prewarm(srv, policy) -> None:
    """Compile every (bucket, batch-bucket) the trace can hit, so cold
    XLA compiles (seconds) never pollute millisecond-scale latency."""
    from repro.serving import bucket_shape
    buckets = {bucket_shape(s, policy) for s in SHAPES}
    batches = [policy.bucket_n(n) for n in range(1, policy.max_n + 1)]
    futs = [srv.prefetch(b, n=nb) for b in buckets for nb in set(batches)]
    for f in futs:
        f.result()


def _replay(trace, submit) -> Tuple[List[float], threading.Event]:
    """Open-loop sender: submit each request at its trace timestamp;
    record completion latency (done - arrival) via callbacks."""
    lat: List[Optional[float]] = [None] * len(trace)
    done = threading.Event()
    remaining = [len(trace)]
    lock = threading.Lock()
    t0 = time.perf_counter()

    def finish(i: int, t_arr: float):
        def cb(_fut):
            lat[i] = time.perf_counter() - t_arr
            with lock:
                remaining[0] -= 1
                if remaining[0] == 0:
                    done.set()
        return cb

    for i, (at, x) in enumerate(trace):
        now = time.perf_counter() - t0
        if at > now:
            time.sleep(at - now)
        t_arr = time.perf_counter()
        submit(x).add_done_callback(finish(i, t_arr))
    return lat, done  # type: ignore[return-value]


def _summary(lat: List[float], slo_s: float, wall_s: float) -> Dict:
    a = np.asarray(lat, np.float64)
    return {
        "p50_ms": float(np.percentile(a, 50)) * 1e3,
        "p95_ms": float(np.percentile(a, 95)) * 1e3,
        "p99_ms": float(np.percentile(a, 99)) * 1e3,
        "mean_ms": float(a.mean()) * 1e3,
        "goodput": float((a <= slo_s).mean()),
        "throughput_rps": len(lat) / wall_s,
        "wall_s": wall_s,
    }


def run_tick(trace, slo_s: float, tick_s: float) -> Dict:
    """Barrier-flush baseline: enqueue + a fixed-cadence flusher."""
    srv = make_server()
    _prewarm(srv, srv.policy)
    stop = threading.Event()

    def flusher():
        while not stop.is_set():
            time.sleep(tick_s)
            srv.flush()
        srv.flush()  # drain the tail

    th = threading.Thread(target=flusher, daemon=True)
    th.start()
    t0 = time.perf_counter()
    lat, done = _replay(trace, srv.enqueue)
    done.wait(timeout=120)
    wall = time.perf_counter() - t0
    stop.set()
    th.join(timeout=10)
    out = _summary(lat, slo_s, wall)
    s = srv.stats()
    out["batch_calls"] = s["batch_calls"]
    out["coalesced"] = s["coalesced"]
    srv.close()
    return out


def run_continuous(trace, slo_s: float, window_s: float) -> Dict:
    """Continuous batching with the SLO as a per-request deadline."""
    from repro.runtime.elastic import ElasticController
    from repro.serving import ContinuousScheduler

    srv = make_server()
    _prewarm(srv, srv.policy)
    sched = ContinuousScheduler(
        srv, batch_window_s=window_s, slo_s=slo_s,
        elastic=ElasticController(min_workers=1, max_workers=4))
    t0 = time.perf_counter()
    lat, done = _replay(trace, sched.submit)
    done.wait(timeout=120)
    wall = time.perf_counter() - t0
    out = _summary(lat, slo_s, wall)
    s = sched.stats()
    for k in ("sched_batches", "sched_full_launches",
              "sched_deadline_launches", "sched_window_launches",
              "worker_resizes", "coalesced"):
        out[k] = s[k]
    out["goodput_counters"] = s["goodput"]
    sched.close()
    srv.close()
    return out


def bench_load(arrival_rate: float, requests: int, slo_ms: float,
               seed: int, tick_ms: float, window_ms: float) -> Dict:
    trace = gen_trace(arrival_rate, requests, seed)
    slo_s = slo_ms / 1e3
    tick = run_tick(trace, slo_s, tick_ms / 1e3)
    cont = run_continuous(trace, slo_s, window_ms / 1e3)
    return {
        "benchmark": "load",
        "arrival_rate": arrival_rate,
        "requests": requests,
        "slo_ms": slo_ms,
        "tick_ms": tick_ms,
        "window_ms": window_ms,
        "seed": seed,
        "tick": tick,
        "continuous": cont,
        "continuous_beats_tick_p99": cont["p99_ms"] < tick["p99_ms"],
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arrival-rate", type=float, default=40.0,
                    help="offered load, requests/s (Poisson)")
    ap.add_argument("--requests", type=int, default=150)
    ap.add_argument("--slo-ms", type=float, default=250.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--tick-ms", type=float, default=50.0,
                    help="baseline flush cadence")
    ap.add_argument("--window-ms", type=float, default=5.0,
                    help="continuous scheduler batching window")
    ap.add_argument("--out", default=None,
                    help="results path (default benchmarks/results/"
                         "load.json)")
    args = ap.parse_args()
    rows = bench_load(args.arrival_rate, args.requests, args.slo_ms,
                      args.seed, args.tick_ms, args.window_ms)
    out = pathlib.Path(args.out) if args.out else \
        pathlib.Path(__file__).parent / "results" / "load.json"
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(rows, indent=2))
    for mode in ("tick", "continuous"):
        r = rows[mode]
        print(f"{mode:>10}: p50={r['p50_ms']:.1f}ms "
              f"p95={r['p95_ms']:.1f}ms p99={r['p99_ms']:.1f}ms "
              f"goodput={r['goodput']:.2%} "
              f"({r['throughput_rps']:.1f} req/s)")
    print(f"continuous beats tick on p99: "
          f"{rows['continuous_beats_tick_p99']}")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
