"""Benchmark driver.  One function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (plus a human-readable
summary on stderr).  Results are also written to benchmarks/results/.

Modes:
  python -m benchmarks.run                 # default: profiled costs,
                                           # CPU-feasible resolutions
  python -m benchmarks.run --analytic      # deterministic cost model
  python -m benchmarks.run --full          # paper-resolution networks
  python -m benchmarks.run --nets alexnet googlenet
  python -m benchmarks.run --roofline-only # just the dry-run roofline

The profiled mode measures every (primitive, scenario) pair once and
caches to ~/.cache/repro_profile.json — first run is slow (layerwise
profiling, same as the paper), subsequent runs are seconds.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys


def _emit(rows, fname: str):
    out = pathlib.Path(__file__).parent / "results"
    out.mkdir(exist_ok=True)
    (out / fname).write_text(json.dumps(rows, indent=2, default=str))


def _csv(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.2f},{derived}")


def run_paper_tables(args) -> None:
    from repro.core.costs import AnalyticCostModel, ProfiledCostModel

    from .paper_tables import selection_map, solver_overhead, \
        strategy_comparison

    cost = AnalyticCostModel() if args.analytic else ProfiledCostModel()
    scale = 1.0 if args.full else args.scale

    # ---- Tables 2/3 + Figures 5/6/7 ----
    rows = strategy_comparison(args.nets, cost, scale=scale,
                               reps=args.reps, run=not args.no_run)
    _emit(rows, "strategy_comparison.json")
    by_net = {}
    for r in rows:
        by_net.setdefault(r["net"], {})[r["strategy"]] = r
    for net, sts in by_net.items():
        for st, r in sts.items():
            us = r.get("measured_ms", r["predicted_ms"]) * 1e3
            sp = r.get("speedup_vs_sum2d", None)
            _csv(f"table2_3/{net}/{st}", us,
                 f"speedup_vs_sum2d={sp:.2f}" if sp else "predicted")
    # paper claims, checked live:
    for net, sts in by_net.items():
        key = "measured_ms" if "measured_ms" in next(iter(sts.values())) \
            else "predicted_ms"
        best_fam = min((sts[f][key] for f in
                        ["direct", "im2", "kn2", "winograd", "fft"]))
        ok1 = sts["pbqp"][key] <= sts["local_opt"][key] * 1.05
        ok2 = sts["pbqp"][key] <= best_fam * 1.05
        print(f"# claim[{net}]: pbqp<=local_opt: {ok1}; "
              f"pbqp<=best_family: {ok2}", file=sys.stderr)

    # ---- Figure 4 ----
    smap = selection_map("alexnet", cost,
                         scale=1.0 if args.full else args.scale)
    _emit(smap, "selection_map.json")
    for r in smap:
        _csv(f"fig4/{r['net']}/{r['layer']}", 0.0,
             f"{r['primitive']}({r['layout']})")

    # ---- Section 5.4 ----
    so = solver_overhead(args.nets, cost,
                         scale=1.0 if args.full else args.scale)
    _emit(so, "solver_overhead.json")
    for r in so:
        _csv(f"sec5.4_solver/{r['net']}", r["solve_s"] * 1e6,
             f"optimal={r['optimal']},n_convs={r['n_convs']}")
    if hasattr(cost, "flush"):
        cost.flush()


def run_roofline(args) -> None:
    from .roofline import roofline_rows
    rows = roofline_rows()
    if not rows:
        print("# no dry-run artifacts found — run "
              "`python -m repro.launch.dryrun` first", file=sys.stderr)
        return
    _emit(rows, "roofline.json")
    for r in rows:
        _csv(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
             r["dominant_s"] * 1e6,
             f"bound={r['bottleneck']};frac={r['roofline_fraction']:.3f}")


def run_observability(args) -> None:
    """Observability section: tracing overhead on the serving hot path
    and the drift-detection round trip (benchmarks/bench_observability
    sections, folded into results/observability.json)."""
    from .bench_observability import bench_drift, bench_metrics, \
        bench_overhead

    rows = {"benchmark": "observability",
            "overhead": bench_overhead(),
            "drift": bench_drift(),
            "metrics": bench_metrics()}
    _emit(rows, "observability.json")
    o, d = rows["overhead"], rows["drift"]
    _csv("obs/trace_overhead", o["instrumented_ms"] * 1e3,
         f"overhead_pct={o['overhead_pct']:.2f}")
    _csv("obs/drift_recalibration", 0.0,
         f"stale_ratio={d['stale_plan_ratio']:.2f};"
         f"final_ratio={d['final_plan_ratio']:.2f};"
         f"converged={d['final_converged']}")


def run_primitives(args) -> None:
    """Autotuned-variant section: tune, install, re-solve the reference
    towers (benchmarks/bench_primitives, folded into
    results/BENCH_primitives.json)."""
    from .bench_primitives import bench_primitives

    rows = bench_primitives()
    _emit(rows, "BENCH_primitives.json")
    _csv("primitives/registry", 0.0,
         f"base={rows['registry_base']};tuned={rows['registry_tuned']};"
         f"claim>={rows['paper_claim_min_primitives']}")
    _csv("primitives/variants", rows["tune_s"] * 1e6,
         f"generated={rows['variants_generated']};"
         f"surviving={rows['variants_surviving']};"
         f"pruned={rows['variants_pruned']}")
    for name, t in rows["towers"].items():
        _csv(f"primitives/{name}", t["solve_s_tuned"] * 1e6,
             f"gap={t['gap_base']:.3f}->{t['gap_tuned']:.3f};"
             f"wins={t['variant_wins']};"
             f"solve_ratio={t['solve_ratio']:.2f}")
    print(f"# gates: {rows['gates']}", file=sys.stderr)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--nets", nargs="+",
                    default=["alexnet", "googlenet", "vgg-a", "vgg-d"])
    ap.add_argument("--analytic", action="store_true")
    ap.add_argument("--full", action="store_true",
                    help="paper-resolution inputs (slow on CPU)")
    ap.add_argument("--scale", type=float, default=0.5)
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--no-run", action="store_true",
                    help="selection only; skip whole-net measurement")
    ap.add_argument("--roofline-only", action="store_true")
    ap.add_argument("--skip-roofline", action="store_true")
    ap.add_argument("--observability-only", action="store_true")
    ap.add_argument("--skip-observability", action="store_true")
    ap.add_argument("--primitives-only", action="store_true")
    ap.add_argument("--skip-primitives", action="store_true")
    args = ap.parse_args()

    if args.observability_only:
        run_observability(args)
        return
    if args.primitives_only:
        run_primitives(args)
        return
    if not args.roofline_only:
        run_paper_tables(args)
    if not args.skip_roofline:
        run_roofline(args)
    if not args.skip_observability:
        run_observability(args)
    if not args.skip_primitives:
        run_primitives(args)


if __name__ == "__main__":
    main()
