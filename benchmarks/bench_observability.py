"""Observability benchmark: instrumentation overhead, drift detection
end to end, and metric-update cost.

Three sections (CI's ``observability`` job asserts on the JSON):

1. **overhead** — the serving hot path (``PlanServer.infer`` on a hot
   bucket) with tracing enabled vs disabled, interleaved in blocks so
   machine drift hits both arms equally.  The acceptance gate is
   instrumented overhead < 5%: tracing must be cheap enough to leave on.
2. **drift** — the full recalibration workflow against a deliberately
   stale profile: calibrate a tower's profile from instrumented
   observations to a fixed point, perturb the converged node entries 8x
   *down* (a stale-fast profile attracts the solver to exactly the
   mis-priced primitives — perturbing up would just make it avoid
   them), re-solve, and assert the perturbed nodes are flagged, only
   flagged entries are recalibrated, the profile content hash (and with
   it every plan-cache key) rotates, and the re-converged plan's
   predicted total lands within the drift threshold of observed.
3. **metrics** — ns/op of registry counter increments and histogram
   records (single-threaded), plus a threaded-hammer exactness check.

  PYTHONPATH=src python -m benchmarks.bench_observability
  PYTHONPATH=src python -m benchmarks.bench_observability --only drift
"""
from __future__ import annotations

import argparse
import json
import pathlib
import statistics
import time

import numpy as np

#: restricted primitive pool for the drift demo: the explore loop
#: re-prices a primitive only once the solver selects it, so a bounded
#: candidate set bounds the rounds to convergence (see
#: repro.obs.drift.RestrictedCostModel)
DRIFT_ALLOWED = ("direct_lax_chw_chw_oihw", "direct_lax_hwc_hwc_hwio",
                 "wino2d_f2x3_chw")


def bench_overhead(reps: int = 60, blocks: int = 6, seed: int = 0) -> dict:
    from repro.core.costs import AnalyticCostModel
    from repro.obs.trace import configure
    from repro.serving import BucketPolicy, PlanServer
    from repro.serving.towers import conv_stack

    srv = PlanServer(lambda s: conv_stack(s, depth=3, width=8),
                     AnalyticCostModel(), policy=BucketPolicy())
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(3, 16, 16)).astype(np.float32)
    srv.infer(x)  # solve + compile + warm the bucket

    def run_block(n: int) -> float:
        t0 = time.perf_counter()
        for _ in range(n):
            srv.infer(x)
        return (time.perf_counter() - t0) / n

    sink: list = []
    off, on = [], []
    try:
        for _ in range(blocks):
            configure(enabled=False)
            off.append(run_block(reps))
            configure(sink, enabled=True)
            on.append(run_block(reps))
    finally:
        configure(enabled=False)
        srv.close()
    off_s, on_s = statistics.median(off), statistics.median(on)
    return {
        "reps": reps, "blocks": blocks,
        "uninstrumented_ms": off_s * 1e3,
        "instrumented_ms": on_s * 1e3,
        "overhead_pct": (on_s / off_s - 1.0) * 100.0,
        "spans_emitted": len(sink),
    }


def bench_drift(seed: int = 0, threshold: float = 2.0,
                runs: int = 4) -> dict:
    from repro.calibrate.model import CalibratedCostModel
    from repro.calibrate.profile import HardwareProfile
    from repro.core.plan import compile_plan
    from repro.core.selection import select_pbqp
    from repro.obs.drift import (DriftDetector, InstrumentedNet,
                                 RestrictedCostModel, recalibration_loop)
    from repro.serving.bucketing import bucket_key
    from repro.serving.plan_cache import plan_key
    from repro.serving.towers import conv_stack

    shape = (3, 16, 16)
    net = conv_stack(shape, depth=3, width=8, k=3)
    params = net.init_params(seed)
    x = np.random.default_rng(seed).normal(size=shape).astype(np.float32)

    # phase 1: calibrate from live instrumented traffic to a fixed point
    profile = HardwareProfile.new()
    base = recalibration_loop(net, params, x, profile,
                              allowed=DRIFT_ALLOWED, threshold=threshold,
                              runs=runs)
    det0 = base["detector"]

    # phase 2: make the profile deliberately stale — the converged
    # plan's node entries 8x too FAST (entries the analytic fallback
    # already priced accurately are seeded from the prediction first)
    perturbed_nodes, perturbed_keys = [], []
    hash_before = profile.content_hash()
    for e in det0.entries.values():
        if e.kind != "node":
            continue
        old = profile.get(e.profile_key)
        profile.put(e.profile_key,
                    (old if old is not None else e.predicted_s) / 8.0)
        perturbed_nodes.append(e.nid)
        perturbed_keys.append(e.profile_key)
    hash_stale = profile.content_hash()

    # phase 3: the stale-fast entries attract the re-solve; the
    # detector must flag exactly the mis-predicted nodes
    cost = RestrictedCostModel(CalibratedCostModel(profile), DRIFT_ALLOWED)
    sel = select_pbqp(net, cost)
    inst = InstrumentedNet(compile_plan(sel, params))
    det = DriftDetector(cost, threshold=threshold)
    for _ in range(runs):
        _, timings = inst(x)
        det.observe(sel, timings)
    flagged = sorted(e.nid for e in det.flagged())
    stale_ratio = det.plan_ratio()

    # phase 4: recalibrate ONLY the flagged entries, re-converge
    updated = det.recalibrate(profile)
    post = recalibration_loop(net, params, x, profile,
                              allowed=DRIFT_ALLOWED, threshold=threshold,
                              runs=runs, max_rounds=4)
    det_post = post["detector"]

    bkey = bucket_key(shape, 1)
    return {
        "threshold": threshold,
        "calibration_rounds": len(base["rounds"]),
        "calibrated_converged": base["converged"],
        "calibrated_plan_ratio": det0.plan_ratio(),
        "perturbed_nodes": sorted(perturbed_nodes),
        "perturbed_keys": sorted(perturbed_keys),
        "stale_plan_ratio": stale_ratio,
        "flagged_nodes": flagged,
        "all_perturbed_flagged":
            set(perturbed_nodes) <= set(flagged),
        "recalibrated_keys": sorted(updated),
        "recalibrated_only_flagged":
            set(updated) <= {e.profile_key for e in det.flagged()},
        "profile_hash_rotated": hash_before != hash_stale !=
            profile.content_hash(),
        "plan_key_rotated":
            plan_key(net.fingerprint(), bkey, "v" + hash_stale) !=
            plan_key(net.fingerprint(), bkey, "v" + profile.content_hash()),
        "final_plan_ratio": det_post.plan_ratio(),
        "final_within_threshold": det_post.plan_within_threshold(),
        "final_converged": post["converged"],
        "rounds": base["rounds"] + post["rounds"],
    }


def bench_metrics(ops: int = 100_000, threads: int = 8,
                  per_thread: int = 20_000) -> dict:
    import threading

    from repro.obs.metrics import MetricsRegistry

    reg = MetricsRegistry()
    c = reg.counter("bench_counter")
    h = reg.histogram("bench_hist")
    t0 = time.perf_counter()
    for _ in range(ops):
        c.add()
    counter_ns = (time.perf_counter() - t0) / ops * 1e9
    t0 = time.perf_counter()
    for i in range(ops):
        h.record(1e-6 * (i % 1000 + 1))
    hist_ns = (time.perf_counter() - t0) / ops * 1e9

    hammer = reg.counter("hammer")
    ts = [threading.Thread(
        target=lambda: [hammer.add() for _ in range(per_thread)])
        for _ in range(threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    return {
        "counter_add_ns": counter_ns,
        "histogram_record_ns": hist_ns,
        "hammer_threads": threads,
        "hammer_expected": threads * per_thread,
        "hammer_observed": hammer.value,
        "hammer_exact": hammer.value == threads * per_thread,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--reps", type=int, default=60,
                    help="hot-path infer() calls per overhead block")
    ap.add_argument("--blocks", type=int, default=6)
    ap.add_argument("--runs", type=int, default=4,
                    help="instrumented passes per drift round")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--only", default=None,
                    choices=("overhead", "drift", "metrics"),
                    help="run a single section (CI smoke jobs)")
    args = ap.parse_args()

    sections = {
        "overhead": lambda: bench_overhead(args.reps, args.blocks,
                                           args.seed),
        "drift": lambda: bench_drift(args.seed, runs=args.runs),
        "metrics": lambda: bench_metrics(),
    }
    result = {"benchmark": "observability"}
    for name, fn in sections.items():
        if args.only is None or args.only == name:
            result[name] = fn()
    doc = json.dumps(result, indent=2)
    print(doc)
    out = pathlib.Path(__file__).parent / "results"
    out.mkdir(exist_ok=True)
    name = "observability.json" if args.only is None \
        else f"observability_{args.only}.json"
    (out / name).write_text(doc)


if __name__ == "__main__":
    main()
