"""Sharded-plan benchmark: the unified choice space on a device mesh.

Three sections, one JSON document (written to benchmarks/results/):

1. **data_parallel** — the serving tower compiled twice for the same
   batch: the plain single-device batched executable vs the
   mesh-sharded executable the placement-solved plan produces
   (``compile_plan(mesh=...)`` on 8 fake CPU devices).  Records
   predicted (cost-model currency) and measured wall-clock throughput
   for both, with outputs verified identical.
2. **placement_flip** — the same tower solved across a fabric-speed
   sweep (``HardwareSpec.link_bw``): the per-node placement table and
   the edges where the solver's choice flips, i.e. where it trades a
   resharding collective against replicated compute.  This is the
   distributed twin of the paper's layout-flip tables.
3. **serving** — a hot request stream through a mesh-aware
   :class:`~repro.serving.server.PlanServer` vs a plain one
   (``infer_batch`` both sides), outputs compared per request.

Run (the script forces 8 fake CPU devices before jax initialises):

  PYTHONPATH=src python -m benchmarks.bench_sharding
"""
from __future__ import annotations

import argparse
import json
import pathlib
import statistics
import time

N_DEVICES = 8


def _force_fake_devices() -> None:
    from repro.launch.mesh import force_host_devices
    force_host_devices(N_DEVICES)


def _tower(batch: int):
    from repro.serving.towers import conv_stack
    return conv_stack((8, 64, 64), depth=3, width=16).with_batch(batch)


def _throughput(fn, x, params, reps: int) -> float:
    """Median seconds per invocation (warmed)."""
    import jax
    for _ in range(3):
        jax.block_until_ready(fn(x, params))
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(x, params))
        times.append(time.perf_counter() - t0)
    return statistics.median(times)


def bench_data_parallel(batch: int, reps: int, seed: int = 0) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.costs import AnalyticCostModel
    from repro.core.plan import compile_plan
    from repro.core.selection import select_pbqp
    from repro.launch.mesh import make_mesh_compat, mesh_fingerprint

    mesh = make_mesh_compat((N_DEVICES,), ("data",))
    cm = AnalyticCostModel()
    net = _tower(batch)
    sel_mesh = select_pbqp(net, cm, mesh_axes={"data": N_DEVICES})
    sel_plain = select_pbqp(net, cm)
    params = net.init_params(seed)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(
        size=(batch, 8, 64, 64)).astype(np.float32))

    cn_mesh = compile_plan(sel_mesh, params, batch=batch, mesh=mesh)
    cn_plain = compile_plan(sel_plain, params, batch=batch)
    out_m, out_p = cn_mesh(x), cn_plain(x)
    match = all(np.allclose(np.asarray(out_m[k]), np.asarray(out_p[k]),
                            rtol=2e-3, atol=2e-3) for k in out_m)

    t_mesh = _throughput(cn_mesh.fn, x, cn_mesh.params, reps)
    t_plain = _throughput(cn_plain.fn, x, cn_plain.params, reps)

    return {
        "devices": N_DEVICES, "batch": batch,
        "mesh": mesh_fingerprint(mesh),
        "mesh_mode": cn_mesh.mesh_mode,
        "dp_nodes": cn_mesh.dp_nodes,
        "outputs_match": bool(match),
        # solver currency: per-device time of the optimum under each
        # choice space — the >1x gain the placement axis buys on paper
        "predicted_plain_s": sel_plain.predicted_cost,
        "predicted_sharded_s": sel_mesh.predicted_cost,
        "predicted_speedup": sel_plain.predicted_cost /
        max(sel_mesh.predicted_cost, 1e-30),
        # honest wall clock on this host's fake-device mesh
        "measured_plain_s": t_plain,
        "measured_sharded_s": t_mesh,
        "measured_plain_img_per_s": batch / max(t_plain, 1e-12),
        "measured_sharded_img_per_s": batch / max(t_mesh, 1e-12),
        "measured_speedup": t_plain / max(t_mesh, 1e-12),
    }


def bench_placement_flip(batch: int) -> dict:
    """Solve the tower across a fabric-speed sweep and tabulate where
    placements flip: slow links make collectives (the dp -> caller
    delivery gather, any dp -> rep edge) expensive enough that the
    solver prefers replicated compute."""
    from repro.core.costs import CPU_SPEC, AnalyticCostModel, HardwareSpec
    from repro.core.selection import select_pbqp

    net = _tower(batch)
    fabrics = {"fast": CPU_SPEC.link_bw, "slow": CPU_SPEC.link_bw / 2000}
    tables = {}
    for name, link in fabrics.items():
        spec = HardwareSpec(
            name=f"cpu-{name}-fabric", peak_flops=CPU_SPEC.peak_flops,
            mem_bw=CPU_SPEC.mem_bw, link_bw=link,
            family_eff=CPU_SPEC.family_eff,
            family_setup=CPU_SPEC.family_setup)
        sel = select_pbqp(net, AnalyticCostModel(spec),
                          mesh_axes={"data": N_DEVICES})
        tables[name] = {nid: ch.placement
                        for nid, ch in sel.choices.items()}
    flips = [nid for nid in tables["fast"]
             if tables["fast"][nid] != tables["slow"][nid]]
    edge_flips = [
        {"edge": f"{src}->{dst}",
         "fast": f"{tables['fast'][src]}->{tables['fast'][dst]}",
         "slow": f"{tables['slow'][src]}->{tables['slow'][dst]}"}
        for (src, dst) in net.edges()
        if (tables["fast"][src], tables["fast"][dst]) !=
           (tables["slow"][src], tables["slow"][dst])]
    return {
        "devices": N_DEVICES, "batch": batch,
        "fabric_link_bw": fabrics,
        "placements": tables,
        "node_flips": flips,
        "edge_flips": edge_flips,
        "dp_nodes_fast": sum(1 for p in tables["fast"].values()
                             if p == "dp"),
        "dp_nodes_slow": sum(1 for p in tables["slow"].values()
                             if p == "dp"),
    }


def bench_serving(requests: int, reps: int, seed: int = 0) -> dict:
    import numpy as np

    from repro.core.costs import AnalyticCostModel
    from repro.launch.mesh import make_mesh_compat
    from repro.serving import BucketPolicy, PlanServer, conv_stack

    mesh = make_mesh_compat((N_DEVICES,), ("data",))
    policy = BucketPolicy(min_hw=8, max_hw=64)
    build = lambda s: conv_stack(s, depth=3, width=16)
    rng = np.random.default_rng(seed)
    stream = [rng.normal(size=(8, int(rng.integers(40, 64)),
                               int(rng.integers(40, 64))))
              .astype(np.float32) for _ in range(requests)]

    results = {}
    outs = {}
    for name, mesh_arg in (("plain", None), ("sharded", mesh)):
        srv = PlanServer(build, AnalyticCostModel(), policy=policy,
                         lru_capacity=8, mesh=mesh_arg)
        outs[name] = srv.infer_batch(stream)  # warm: solve+compile here
        times = []
        for _ in range(reps):
            t0 = time.perf_counter()
            srv.infer_batch(stream)
            times.append(time.perf_counter() - t0)
        t = statistics.median(times)
        s = srv.stats()
        results[name] = {
            "stream_s": t,
            "req_per_s": requests / max(t, 1e-12),
            "mesh_compiles": s["mesh_compiles"],
            "batch_calls": s["batch_calls"],
        }
        srv.close()
    match = all(
        np.allclose(outs["plain"][i][k], outs["sharded"][i][k],
                    rtol=2e-3, atol=2e-3)
        for i in range(requests) for k in outs["plain"][i])
    return {
        "devices": N_DEVICES, "requests": requests,
        "outputs_match": bool(match),
        "plain": results["plain"],
        "sharded": results["sharded"],
        "serving_speedup": results["plain"]["stream_s"] /
        max(results["sharded"]["stream_s"], 1e-12),
    }


def main():
    _force_fake_devices()
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--only", default=None,
                    choices=("data_parallel", "placement_flip", "serving"))
    args = ap.parse_args()

    sections = {
        "data_parallel": lambda: bench_data_parallel(
            args.batch, args.reps, args.seed),
        "placement_flip": lambda: bench_placement_flip(args.batch),
        "serving": lambda: bench_serving(
            args.requests, args.reps, args.seed),
    }
    result = {"benchmark": "sharding"}
    for name, fn in sections.items():
        if args.only is None or args.only == name:
            result[name] = fn()
    doc = json.dumps(result, indent=2)
    print(doc)
    out = pathlib.Path(__file__).parent / "results"
    out.mkdir(exist_ok=True)
    name = "sharding.json" if args.only is None \
        else f"sharding_{args.only}.json"
    (out / name).write_text(doc)


if __name__ == "__main__":
    main()
