"""Render the EXPERIMENTS.md tables from dry-run artifacts.

  PYTHONPATH=src python -m benchmarks.make_tables [--section all]
"""
from __future__ import annotations

import argparse
import json
import pathlib

from .roofline import ARTIFACT_DIR, roofline_rows, roofline_terms


def fmt(x, digits=3):
    if x == 0:
        return "0"
    if x < 1e-3 or x >= 1e4:
        return f"{x:.2e}"
    return f"{x:.{digits}g}"


def dryrun_table():
    print("| arch | shape | mesh | status | HBM args+temp/dev | "
          "collective bytes/dev | compile |")
    print("|---|---|---|---|---|---|---|")
    for f in sorted(ARTIFACT_DIR.glob("*.json")):
        if "__hc_" in f.name or "megatron" in f.name:
            continue
        r = json.loads(f.read_text())
        if r["status"] == "skipped":
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                  f"skipped¹ | — | — | — |")
            continue
        if r["status"] != "ok":
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                  f"ERROR | — | — | — |")
            continue
        mem = r["memory"]
        hbm = (mem["argument_bytes"] + mem["temp_bytes"]) / 2 ** 30
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
              f"{hbm:.1f} GiB | {fmt(r['collective_bytes_total'])} | "
              f"{r['compile_s']:.0f}s |")


def roofline_table(mesh="16x16"):
    rows = [r for r in roofline_rows() if r["mesh"] == mesh]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    print("| arch | shape | compute s | memory s (analytic) | "
          "memory s (HLO ub) | collective s | bottleneck | "
          "useful-FLOP ratio | roofline frac |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        print(f"| {r['arch']} | {r['shape']} | {fmt(r['compute_s'])} | "
              f"{fmt(r['memory_s'])} | {fmt(r['memory_s_hlo'])} | "
              f"{fmt(r['collective_s'])} | {r['bottleneck']} | "
              f"{r['useful_flop_ratio']:.3f} | "
              f"{r['roofline_fraction']:.4f} |")


def hillclimb_table():
    cells = {
        "mistral-nemo-12b__train_4k__16x16": [
            ("baseline (PBQP rules, dense causal attn, full remat)", ""),
            ("+ chunked-causal attention (8 chunks)", "__hc_chunked"),
            ("+ dots remat (8 chunks)", "__hc_chunked_dots"),
            ("+ 4-chunk causal + dots remat", "__hc_chunked4_dots"),
            ("H7 (refuted): KV-head pad 8->16", "__hc_kvpad"),
        ],
        "kimi-k2-1t-a32b__train_4k__16x16": [
            ("baseline (gather-dispatch MoE)", ""),
            ("+ shard_map EP all-to-all dispatch", "__hc_a2a"),
            ("+ chunked-causal attention", "__hc_a2a_chunked"),
        ],
        "whisper-large-v3__train_4k__16x16": [
            ("baseline (head_dim TP — mispriced cost table)", ""),
            ("re-solved PBQP after cost-table fix (attn:rep)",
             "__hc_resel"),
            ("+ dots remat", "__hc_resel_dots"),
        ],
        "llava-next-34b__train_4k__16x16": [
            ("baseline (head_dim TP — mispriced cost table)", ""),
            ("re-solved PBQP (transfer of the whisper fix)",
             "__hc_resel"),
        ],
    }
    for base, variants in cells.items():
        print(f"\n**{base.replace('__', ' / ')}**\n")
        print("| step | compute s | memory s | collective s | dominant | "
              "roofline frac |")
        print("|---|---|---|---|---|---|")
        for label, tag in variants:
            p = ARTIFACT_DIR / f"{base}{tag}.json"
            if not p.exists():
                print(f"| {label} | — | — | — | — | — |")
                continue
            r = json.loads(p.read_text())
            if r["status"] != "ok":
                print(f"| {label} | ERROR | | | | |")
                continue
            t = roofline_terms(r)
            print(f"| {label} | {fmt(t['compute_s'])} | "
                  f"{fmt(t['memory_s'])} | {fmt(t['collective_s'])} | "
                  f"{fmt(t['dominant_s'])} ({t['bottleneck']}) | "
                  f"{t['roofline_fraction']:.4f} |")


def observability_table():
    """Summarize benchmarks/results/observability.json (written by
    bench_observability / run.py): the tracing-overhead gate and the
    drift round trip."""
    p = pathlib.Path(__file__).parent / "results" / "observability.json"
    if not p.exists():
        print("(no observability.json — run "
              "`python -m benchmarks.bench_observability` first)")
        return
    d = json.loads(p.read_text())
    o = d.get("overhead")
    if o:
        print("| hot path | ms/call | overhead | gate |")
        print("|---|---|---|---|")
        print(f"| tracing off | {o['uninstrumented_ms']:.3f} | — | — |")
        print(f"| tracing on | {o['instrumented_ms']:.3f} | "
              f"{o['overhead_pct']:.2f}% | "
              f"{'ok (<5%)' if o['overhead_pct'] < 5 else 'FAIL'} |")
    dr = d.get("drift")
    if dr:
        print("\n| drift round trip | value |")
        print("|---|---|")
        print(f"| calibration rounds | {dr['calibration_rounds']} |")
        print(f"| perturbed entries | {len(dr['perturbed_keys'])} |")
        print(f"| stale plan obs/pred | {dr['stale_plan_ratio']:.2f} |")
        print(f"| flagged == perturbed | "
              f"{dr['all_perturbed_flagged']} |")
        print(f"| recalibrated only flagged | "
              f"{dr['recalibrated_only_flagged']} |")
        print(f"| plan keys rotated | {dr['plan_key_rotated']} |")
        print(f"| final plan obs/pred | {dr['final_plan_ratio']:.2f} "
              f"(within threshold: {dr['final_within_threshold']}) |")


def primitives_table():
    """Summarize benchmarks/results/BENCH_primitives.json (written by
    bench_primitives / run.py): the autotuned-variant gates and the
    paper's ">70 primitives" comparison row."""
    p = pathlib.Path(__file__).parent / "results" / \
        "BENCH_primitives.json"
    if not p.exists():
        print("(no BENCH_primitives.json — run "
              "`python -m benchmarks.bench_primitives` first)")
        return
    d = json.loads(p.read_text())
    print("| registry | primitives |")
    print("|---|---|")
    print(f"| paper claim (Section 2) | "
          f"{d.get('paper_claim_min_primitives', 70)}+ |")
    print(f"| hand-written | {d['registry_handwritten']} |")
    print(f"| + autotuned survivors | {d['registry_tuned']} "
          f"({d['variants_surviving']} of {d['variants_generated']} "
          f"generated; {d['variants_pruned']} dominated) |")
    print("\n| tower | gap naive/solved | variant wins | solve time |")
    print("|---|---|---|---|")
    for name, t in sorted(d["towers"].items()):
        print(f"| {name} | {t['gap_base']:.3f} -> "
              f"{t['gap_tuned']:.3f} | {t['variant_wins']} | "
              f"{t['solve_s_base']*1e3:.1f} -> "
              f"{t['solve_s_tuned']*1e3:.1f} ms "
              f"({t['solve_ratio']:.2f}x) |")
    gates = d.get("gates", {})
    print("\n| gate | status |")
    print("|---|---|")
    for g, ok in sorted(gates.items()):
        print(f"| {g} | {'ok' if ok else 'FAIL'} |")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--section", default="all",
                    choices=["all", "dryrun", "roofline", "hillclimb",
                             "observability", "primitives"])
    args = ap.parse_args()
    if args.section in ("all", "dryrun"):
        print("## Dry-run matrix\n")
        dryrun_table()
    if args.section in ("all", "roofline"):
        print("\n## Roofline (single-pod 16x16)\n")
        roofline_table()
    if args.section in ("all", "hillclimb"):
        print("\n## Hillclimbs\n")
        hillclimb_table()
    if args.section in ("all", "observability"):
        print("\n## Observability\n")
        observability_table()
    if args.section in ("all", "primitives"):
        print("\n## Autotuned primitives\n")
        primitives_table()


if __name__ == "__main__":
    main()
