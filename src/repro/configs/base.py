"""Config dataclasses for the LM-family architectures and run shapes."""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES"]


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 -> d_model // n_heads

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1          # MoE replaces dense FFN every n-th layer
    capacity_factor: float = 1.25

    # --- attention features ---
    sliding_window: int = 0     # gemma2 local layers
    local_global_period: int = 0  # alternate local/global every n layers
    logit_softcap: float = 0.0  # final-logit softcap (gemma2)
    attn_softcap: float = 0.0   # attention-logit softcap (gemma2)
    rope_theta: float = 1e4
    qk_norm: bool = False

    # --- SSM (mamba2) ---
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    attn_every: int = 0         # hybrid: 1 attention layer per n blocks

    # --- encoder-decoder (whisper) ---
    enc_layers: int = 0
    enc_seq: int = 0            # encoder sequence length (frontend stub)

    # --- modality frontend stubs ---
    frontend: str = "none"      # none | audio | vision
    n_patches: int = 0          # vision stub: patch embeddings per image

    # --- block structure ---
    post_norms: bool = False      # gemma2 sandwich norms
    parallel_block: bool = False  # command-r parallel attn+FFN

    # --- misc ---
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    #: True if the arch supports the long_500k shape (sub-quadratic path)
    sub_quadratic: bool = False
    #: reference/source for the config (provenance)
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    def scaled_down(self, **kw) -> "ModelConfig":
        """Reduced config of the same family for CPU smoke tests."""
        defaults = dict(
            n_layers=min(self.n_layers, 2 * max(1, self.local_global_period,
                                                self.attn_every,
                                                self.moe_every)),
            d_model=128,
            n_heads=min(self.n_heads, 4),
            n_kv_heads=min(self.n_kv_heads or 1, 2),
            d_ff=256,
            vocab=512,
            head_dim=32 if self.head_dim else 0,
            enc_layers=min(self.enc_layers, 2),
            enc_seq=min(self.enc_seq, 64) if self.enc_seq else 0,
            n_patches=min(self.n_patches, 16) if self.n_patches else 0,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            # drop-free capacity so decode == forward exactly in tests
            capacity_factor=float(max(4, min(self.n_experts, 4)))
                if self.n_experts else 1.25,
            sliding_window=min(self.sliding_window, 16)
                if self.sliding_window else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_headdim=16 if self.ssm_state else 64,
            name=self.name + "-smoke",
        )
        defaults.update(kw)
        return replace(self, **defaults)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
