"""Kimi K2 1T-A32B [arXiv:2501.kimi2] — trillion-parameter MoE.

61L, d_model 7168, 64 heads / 8 KV, expert d_ff 2048, vocab 163840,
MoE with 384 experts, top-8 routing (paper-table config).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=2048,              # per-expert FFN width
    vocab=163840,
    head_dim=112,           # 64 * 112 = 7168
    n_experts=384,
    top_k=8,
    moe_every=1,
    sub_quadratic=False,
    source="arXiv:2501.kimi2",
)
