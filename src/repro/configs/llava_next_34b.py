"""LLaVA-NeXT 34B [hf:llava-hf/llava-v1.6-*] — VLM backbone.

60L, d_model 7168, 56 heads / 8 KV, d_ff 20480, vocab 64000.  The
anyres-tiling vision tower is a STUB: input_specs() provides
precomputed patch embeddings (B, n_patches, d_model) interleaved with
text tokens.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab=64000,
    head_dim=128,
    n_patches=2880,          # anyres: up to 5 tiles x 576 patches
    frontend="vision",
    sub_quadratic=False,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf (scaled per 34B card)",
)
