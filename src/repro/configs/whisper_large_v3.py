"""Whisper-large-v3 [arXiv:2212.04356] — encoder-decoder, audio.

32+32L, d_model 1280, 20 heads (MHA: kv=20), d_ff 5120, vocab 51866.
The conv frontend is a STUB: input_specs() provides precomputed frame
embeddings of shape (B, enc_seq, d_model).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="encdec",
    n_layers=32,            # decoder layers
    enc_layers=32,
    enc_seq=1500,           # 30 s of audio at 50 Hz after the conv stub
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab=51866,
    head_dim=64,
    frontend="audio",
    sub_quadratic=False,
    source="arXiv:2212.04356",
)
