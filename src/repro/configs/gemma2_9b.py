"""Gemma2-9B [arXiv:2408.00118].

42L, d_model 3584, 16 heads / 8 KV, head_dim 256, d_ff 14336,
vocab 256000.  Alternating local (sliding-window 4096) / global
attention, attention + final-logit soft-capping.

long_500k: runs — half the layers are sliding-window (bounded KV), and
decode-time global attention is linear per token; we mark it
sub-quadratic for the decode-only long-context shape (see
docs/distributed.md §CPU-world testing of pod-world claims for why
full-attention architectures skip that shape).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    d_ff=14336,
    vocab=256000,
    head_dim=256,
    sliding_window=4096,
    local_global_period=2,   # local, global, local, ...
    attn_softcap=50.0,
    logit_softcap=30.0,
    post_norms=True,
    tie_embeddings=True,
    sub_quadratic=True,
    source="arXiv:2408.00118",
)
