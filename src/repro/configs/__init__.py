"""Architecture config registry: ``get_config(arch_id)``.

One module per assigned architecture with the exact published
configuration, plus the paper's own CNNs (see repro/convnets/).
"""
from __future__ import annotations

from .base import SHAPES, ModelConfig, ShapeConfig
from .mistral_nemo_12b import CONFIG as mistral_nemo_12b
from .command_r_35b import CONFIG as command_r_35b
from .tinyllama_1_1b import CONFIG as tinyllama_1_1b
from .gemma2_9b import CONFIG as gemma2_9b
from .whisper_large_v3 import CONFIG as whisper_large_v3
from .kimi_k2_1t_a32b import CONFIG as kimi_k2_1t_a32b
from .grok_1_314b import CONFIG as grok_1_314b
from .llava_next_34b import CONFIG as llava_next_34b
from .jamba_v0_1_52b import CONFIG as jamba_v0_1_52b
from .mamba2_2_7b import CONFIG as mamba2_2_7b

ARCHS = {
    c.name: c for c in [
        mistral_nemo_12b, command_r_35b, tinyllama_1_1b, gemma2_9b,
        whisper_large_v3, kimi_k2_1t_a32b, grok_1_314b, llava_next_34b,
        jamba_v0_1_52b, mamba2_2_7b,
    ]
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {list(ARCHS)}")
    return ARCHS[name]


def cells():
    """All (arch, shape) dry-run cells, with inapplicable ones marked."""
    out = []
    for aname, cfg in ARCHS.items():
        for sname, shp in SHAPES.items():
            skip = None
            if sname == "long_500k" and not cfg.sub_quadratic:
                skip = "long_500k needs sub-quadratic attention " \
                       "(pure full-attention arch) — see docs/distributed.md"
            out.append((aname, sname, skip))
    return out


__all__ = ["ARCHS", "SHAPES", "get_config", "cells", "ModelConfig",
           "ShapeConfig"]
