"""Mamba2-2.7B [arXiv:2405.21060] — attention-free SSM (SSD).

64L, d_model 2560, d_ff 0 (no FFN; the Mamba block IS the mixer),
vocab 50280, ssm_state 128, headdim 64, expand 2.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_conv=4,
    tie_embeddings=True,
    sub_quadratic=True,
    source="arXiv:2405.21060",
)
