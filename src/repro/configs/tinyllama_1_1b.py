"""TinyLlama-1.1B [arXiv:2401.02385] — llama2-architecture small model.

22L, d_model 2048, 32 heads / 4 KV (GQA), d_ff 5632, vocab 32000.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b",
    family="dense",
    n_layers=22,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=5632,
    vocab=32000,
    head_dim=64,
    rope_theta=1e4,
    sub_quadratic=False,
    source="arXiv:2401.02385",
)
