"""Cohere Command-R 35B [hf:CohereForAI/c4ai-command-r-v01].

Dense decoder, 40L, d_model 8192, 64 heads / 8 KV (GQA), d_ff 22528,
vocab 256000, no biases.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22528,
    vocab=256000,
    head_dim=128,
    rope_theta=8e6,
    tie_embeddings=True,
    parallel_block=True,
    sub_quadratic=False,
    source="hf:CohereForAI/c4ai-command-r-v01",
)
