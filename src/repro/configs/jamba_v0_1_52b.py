"""Jamba v0.1 52B [arXiv:2403.19887] — Mamba + attention hybrid MoE.

32 blocks, d_model 4096, 32 heads / 8 KV, d_ff 14336, vocab 65536.
1 attention layer per 8 blocks (1:7 attn:mamba), MoE (16 experts,
top-2) every other block.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=65536,
    head_dim=128,
    n_experts=16,
    top_k=2,
    moe_every=2,
    attn_every=8,            # block index 4 of each 8-block period (attn)
    ssm_state=16,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_conv=4,
    sub_quadratic=True,
    source="arXiv:2403.19887",
)
