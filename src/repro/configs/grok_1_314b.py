"""Grok-1 314B [hf:xai-org/grok-1] — 8-expert top-2 MoE.

64L, d_model 6144, 48 heads / 8 KV, d_ff 32768, vocab 131072.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    vocab=131072,
    head_dim=128,
    n_experts=8,
    top_k=2,
    moe_every=1,
    sub_quadratic=False,
    source="hf:xai-org/grok-1",
)
