"""Mistral-Nemo-12B [hf:mistralai/Mistral-Nemo-Base-2407].

Dense decoder, 40L, d_model 5120, 32 heads / 8 KV (GQA), d_ff 14336,
vocab 131072, head_dim 128, 128k context.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=131072,
    head_dim=128,
    rope_theta=1e6,
    sub_quadratic=False,
    source="hf:mistralai/Mistral-Nemo-Base-2407",
)
