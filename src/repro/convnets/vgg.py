"""VGG configurations A-E (Simonyan & Zisserman 2014, Table 1).

The paper benchmarks VGG-A..E; C includes the 1x1 convolutions.  Models
other than D/E were reconstructed by hand "exactly following" the
publication — as are these.
"""
from __future__ import annotations

from ..core.graph import Net, fc, maxpool, relu, softmax

# stage channel plans; "1" suffix marks the 1x1 convs of config C
_CFG = {
    "A": [[64], [128], [256, 256], [512, 512], [512, 512]],
    "B": [[64, 64], [128, 128], [256, 256], [512, 512], [512, 512]],
    "C": [[64, 64], [128, 128], [256, 256, "256x1"],
          [512, 512, "512x1"], [512, 512, "512x1"]],
    "D": [[64, 64], [128, 128], [256, 256, 256], [512, 512, 512],
          [512, 512, 512]],
    "E": [[64, 64], [128, 128], [256, 256, 256, 256],
          [512, 512, 512, 512], [512, 512, 512, 512]],
}


def vgg(cfg: str = "D", scale: float = 1.0) -> Net:
    cfg = cfg.upper()
    r = max(int(224 * scale), 32)
    net = Net(f"vgg-{cfg.lower()}{'' if scale == 1.0 else f'@{r}'}")
    x = net.input("data", (3, r, r))
    for si, stage in enumerate(_CFG[cfg], start=1):
        for ci, spec in enumerate(stage, start=1):
            if isinstance(spec, str):  # C's 1x1 convs
                m = int(spec.split("x")[0])
                k, pad = 1, 0
            else:
                m, k, pad = spec, 3, 1
            x = net.conv(f"conv{si}_{ci}", x, k=k, m=m, pad=pad)
            x = net.op(f"relu{si}_{ci}", [x], relu())
        x = net.op(f"pool{si}", [x], maxpool(2, 2))
    x = net.op("fc6", [x], fc(4096, relu_after=True))
    x = net.op("fc7", [x], fc(4096, relu_after=True))
    x = net.op("fc8", [x], fc(1000))
    net.op("prob", [x], softmax())
    return net
