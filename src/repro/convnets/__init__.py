"""The paper's benchmark networks: AlexNet, VGG A-E, GoogleNet."""
from .alexnet import alexnet
from .googlenet import googlenet
from .vgg import vgg

NETWORKS = {
    "alexnet": lambda scale=1.0: alexnet(scale),
    "vgg-a": lambda scale=1.0: vgg("A", scale),
    "vgg-b": lambda scale=1.0: vgg("B", scale),
    "vgg-c": lambda scale=1.0: vgg("C", scale),
    "vgg-d": lambda scale=1.0: vgg("D", scale),
    "vgg-e": lambda scale=1.0: vgg("E", scale),
    "googlenet": lambda scale=1.0: googlenet(scale),
}

__all__ = ["alexnet", "vgg", "googlenet", "NETWORKS"]
