"""AlexNet (Krizhevsky et al. 2012), single-tower Caffe topology.

``scale`` shrinks the input resolution (227 -> 227*scale) for fast
CI-scale runs; scale=1.0 is the paper's benchmark configuration.
"""
from __future__ import annotations

from ..core.graph import Net, fc, lrn, maxpool, relu, softmax


def alexnet(scale: float = 1.0) -> Net:
    r = max(int(227 * scale), 35)
    net = Net(f"alexnet{'' if scale == 1.0 else f'@{r}'}")
    x = net.input("data", (3, r, r))
    x = net.conv("conv1", x, k=11, m=96, stride=4, pad=0)
    x = net.op("relu1", [x], relu())
    x = net.op("norm1", [x], lrn())
    x = net.op("pool1", [x], maxpool(3, 2))
    x = net.conv("conv2", x, k=5, m=256, pad=2)
    x = net.op("relu2", [x], relu())
    x = net.op("norm2", [x], lrn())
    x = net.op("pool2", [x], maxpool(3, 2))
    x = net.conv("conv3", x, k=3, m=384, pad=1)
    x = net.op("relu3", [x], relu())
    x = net.conv("conv4", x, k=3, m=384, pad=1)
    x = net.op("relu4", [x], relu())
    x = net.conv("conv5", x, k=3, m=256, pad=1)
    x = net.op("relu5", [x], relu())
    x = net.op("pool5", [x], maxpool(3, 2))
    x = net.op("fc6", [x], fc(4096, relu_after=True))
    x = net.op("fc7", [x], fc(4096, relu_after=True))
    x = net.op("fc8", [x], fc(1000))
    net.op("prob", [x], softmax())
    return net
