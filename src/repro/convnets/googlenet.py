"""GoogleNet / Inception-v1 (Szegedy et al. 2015), main tower.

The inception joins are the paper's Figure 3 motivation: concat nodes
with 4 producers whose layout choices must co-adapt — the DAG case where
greedy selection breaks and PBQP shines.
"""
from __future__ import annotations

from ..core.graph import Net, concat, fc, global_avgpool, lrn, maxpool, \
    relu, softmax

# (1x1, 3x3reduce, 3x3, 5x5reduce, 5x5, pool_proj)
_INCEPTION = {
    "3a": (64, 96, 128, 16, 32, 32),
    "3b": (128, 128, 192, 32, 96, 64),
    "4a": (192, 96, 208, 16, 48, 64),
    "4b": (160, 112, 224, 24, 64, 64),
    "4c": (128, 128, 256, 24, 64, 64),
    "4d": (112, 144, 288, 32, 64, 64),
    "4e": (256, 160, 320, 32, 128, 128),
    "5a": (256, 160, 320, 32, 128, 128),
    "5b": (384, 192, 384, 48, 128, 128),
}


def _inception(net: Net, name: str, x: str,
               p1, p3r, p3, p5r, p5, pp) -> str:
    b1 = net.conv(f"i{name}_1x1", x, k=1, m=p1, pad=0)
    b1 = net.op(f"i{name}_relu1", [b1], relu())
    b3 = net.conv(f"i{name}_3x3r", x, k=1, m=p3r, pad=0)
    b3 = net.op(f"i{name}_relu3r", [b3], relu())
    b3 = net.conv(f"i{name}_3x3", b3, k=3, m=p3, pad=1)
    b3 = net.op(f"i{name}_relu3", [b3], relu())
    b5 = net.conv(f"i{name}_5x5r", x, k=1, m=p5r, pad=0)
    b5 = net.op(f"i{name}_relu5r", [b5], relu())
    b5 = net.conv(f"i{name}_5x5", b5, k=5, m=p5, pad=2)
    b5 = net.op(f"i{name}_relu5", [b5], relu())
    bp = net.op(f"i{name}_pool", [x], maxpool(3, 1, pad=1))
    bp = net.conv(f"i{name}_poolproj", bp, k=1, m=pp, pad=0)
    bp = net.op(f"i{name}_relupp", [bp], relu())
    return net.op(f"i{name}_concat", [b1, b3, b5, bp], concat())


def googlenet(scale: float = 1.0) -> Net:
    r = max(int(224 * scale), 32)
    net = Net(f"googlenet{'' if scale == 1.0 else f'@{r}'}")
    x = net.input("data", (3, r, r))
    x = net.conv("conv1", x, k=7, m=64, stride=2, pad=3)
    x = net.op("relu1", [x], relu())
    x = net.op("pool1", [x], maxpool(3, 2, pad=1))
    x = net.op("norm1", [x], lrn())
    x = net.conv("conv2r", x, k=1, m=64, pad=0)
    x = net.op("relu2r", [x], relu())
    x = net.conv("conv2", x, k=3, m=192, pad=1)
    x = net.op("relu2", [x], relu())
    x = net.op("norm2", [x], lrn())
    x = net.op("pool2", [x], maxpool(3, 2, pad=1))
    x = _inception(net, "3a", x, *_INCEPTION["3a"])
    x = _inception(net, "3b", x, *_INCEPTION["3b"])
    x = net.op("pool3", [x], maxpool(3, 2, pad=1))
    x = _inception(net, "4a", x, *_INCEPTION["4a"])
    x = _inception(net, "4b", x, *_INCEPTION["4b"])
    x = _inception(net, "4c", x, *_INCEPTION["4c"])
    x = _inception(net, "4d", x, *_INCEPTION["4d"])
    x = _inception(net, "4e", x, *_INCEPTION["4e"])
    x = net.op("pool4", [x], maxpool(3, 2, pad=1))
    x = _inception(net, "5a", x, *_INCEPTION["5a"])
    x = _inception(net, "5b", x, *_INCEPTION["5b"])
    x = net.op("gap", [x], global_avgpool())
    x = net.op("fc", [x], fc(1000))
    net.op("prob", [x], softmax())
    return net
