import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST precede any other import (jax locks the device
count at first init).  The 512 placeholder host devices exist only in
this process — smoke tests and benches see 1 device.

Per cell:
  1. build the production mesh (16x16 single-pod / 2x16x16 multi-pod)
  2. solve the sharding PBQP (repro.core.sharding_select) -> Rules
  3. jit(step).lower(**input_specs(arch)).compile()
  4. record memory_analysis / cost_analysis / per-opcode collective
     bytes parsed from the compiled per-device HLO
  5. repeat at scan-unroll=2: cost_analysis counts a while-loop body
     ONCE regardless of trip count, so quantities are reconstructed as
       total = outside + n_super * body,   body = Q(u2) - Q(u1)
     (clamped at 0; exact for collectives, near-exact for flops/bytes
     modulo fusion differences — both raw measurements are recorded).

Usage:
  python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k
  python -m repro.launch.dryrun --all [--jobs 3] [--multi-pod both]
"""
import argparse
import json
import pathlib
import re
import sys
import time
import traceback

ARTIFACT_DIR = pathlib.Path(__file__).resolve().parents[3] / \
    "benchmarks" / "results" / "dryrun"

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}
_TYPE_RE = re.compile(
    r"(pred|bf16|f16|f32|f64|s8|s16|s32|s64|u8|u16|u32|u64|c64|c128)"
    r"\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")


def _type_bytes(type_str: str) -> int:
    total = 0
    for m in _TYPE_RE.finditer(type_str):
        dims = [int(d) for d in m.group(2).split(",") if d]
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[m.group(1)]
    return total


def parse_collectives(hlo_text: str):
    """Sum operand bytes of every collective op (per-device shapes)."""
    sizes = {}
    lines = hlo_text.splitlines()
    for ln in lines:
        m = _DEF_RE.match(ln)
        if not m:
            continue
        name, rhs = m.groups()
        # type is everything up to the opcode token
        op_m = re.search(r"([a-z][\w\-]*)\(", rhs)
        if not op_m:
            continue
        type_str = rhs[:op_m.start()]
        sizes[name] = _type_bytes(type_str)
    out = {c: {"count": 0, "bytes": 0} for c in _COLLECTIVES}
    for ln in lines:
        m = _DEF_RE.match(ln)
        if not m:
            continue
        name, rhs = m.groups()
        op_m = re.search(r"([a-z][\w\-]*)\(", rhs)
        if not op_m:
            continue
        op = op_m.group(1)
        base = re.sub(r"\.\d+$", "", op)
        # match e.g. all-reduce, all-gather-start, all-reduce-scatter? no:
        core = None
        for c in _COLLECTIVES:
            if base == c or base == c + "-start":
                core = c
                break
        if core is None:
            continue
        args = re.findall(r"%([\w\.\-]+)", rhs[op_m.end():])
        b = sum(sizes.get(a, 0) for a in args)
        out[core]["count"] += 1
        out[core]["bytes"] += b
    return out


def _opt_state_specs(opt_kind: str, pspecs, psds):
    """Specs for the optimizer state, mirroring the optimizer's own
    structure decisions (adafactor factored() rule included)."""
    import jax
    from jax.sharding import PartitionSpec as P
    if opt_kind == "adamw":
        return {"m": pspecs, "v": pspecs, "count": P()}

    def fac(spec, sds):
        parts = list(spec) + [None] * (len(sds.shape) - len(list(spec)))
        shp = sds.shape
        if len(shp) >= 2 and shp[-1] >= 128 and shp[-2] >= 128:
            return {"r": P(*parts[:-1]), "c": P(*(parts[:-2] + parts[-1:]))}
        return {"v": P(*parts)}

    isleaf = lambda s: isinstance(s, type(P()))
    return {"f": jax.tree.map(fac, pspecs, psds, is_leaf=isleaf),
            "count": P()}


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             rules_mode: str = "pbqp", unroll: int = 1,
             donate: bool = True, extra_rules=None, variant=None):
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    from ..configs import SHAPES, get_config
    from ..core.sharding_select import select_rules
    from ..models import (
        MEGATRON_RULES, ModelRuntime, ShardingPlan, decode_step, loss_fn,
        param_count, active_param_count,
    )
    from ..models.model import param_defs
    from ..models.sharding import pspecs_from_defs, shapestructs_from_defs
    from ..optim.optimizers import for_config
    from .inputs import batch_axes, input_specs
    from .mesh import make_production_mesh, mesh_shape_dict

    cfg = get_config(arch)
    if variant and "kv_heads_pad" in variant:
        # Megatron-style KV-head replication: pad GQA kv heads up to the
        # TP width so the KV projections shard instead of replicating
        # (physically each rank owns one duplicated head; §Perf H7)
        import dataclasses as _dc
        variant = dict(variant)
        cfg = _dc.replace(cfg, n_kv_heads=int(variant.pop("kv_heads_pad")))
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mshape = mesh_shape_dict(mesh)
    n_dev = int(mesh.devices.size)

    report = {}
    if rules_mode == "pbqp":
        rules, report = select_rules(cfg, shape, mshape)
    elif rules_mode == "megatron":
        rules = MEGATRON_RULES
    else:
        raise ValueError(rules_mode)
    if extra_rules:
        rules = rules.with_(**extra_rules)
    rules = rules.restrict(mesh.axis_names)
    plan = ShardingPlan(mesh=mesh, rules=rules)

    # SSD chunking: python-unrolled for the dry-run so cost_analysis
    # sees every chunk, bounded at <= 32 HLO copies PER SUPERBLOCK
    # (jamba's 7-mamba superblock would otherwise explode compile time)
    from ..models.blocks import layer_kinds
    n_mamba = sum(1 for k in layer_kinds(cfg) if k["mixer"] == "mamba")
    t_eff = shape.seq_len if shape.kind != "decode" else 1
    chunk = max(256, t_eff * max(n_mamba, 1) // 32) if t_eff > 1 else 256
    rt_kw = dict(attn_impl="xla", remat=(shape.kind == "train"),
                 unroll=unroll, chunk=chunk, unroll_chunks=(t_eff > 1))
    if variant:
        rt_kw.update(variant)
    rt = ModelRuntime(**rt_kw)

    defs = param_defs(cfg)
    pspecs = pspecs_from_defs(defs, rules)
    psds = shapestructs_from_defs(defs, jnp.bfloat16)
    psds = jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, sp)),
        psds, pspecs)

    in_specs, in_axes = input_specs(cfg, shape)
    in_pspecs = batch_axes(in_axes, rules)
    in_sds = jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, sp)),
        in_specs, in_pspecs)

    if shape.kind == "train":
        opt = for_config(cfg)
        opt_kind = "adamw" if param_count(cfg) < 2e11 else "adafactor"
        ostate_shape = jax.eval_shape(opt.init, psds)
        ospecs = _opt_state_specs(opt_kind, pspecs, psds)
        osds = jax.tree.map(
            lambda s, sp: jax.ShapeDtypeStruct(
                s.shape, s.dtype, sharding=NamedSharding(mesh, sp)),
            ostate_shape, ospecs,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))

        def step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(
                lambda p: loss_fn(cfg, p, batch, plan, rt))(params)
            new_p, new_s = opt.update(grads, opt_state, params)
            return loss, new_p, new_s

        args = (psds, osds, in_sds)
        donate_argnums = (0, 1) if donate else ()
    elif shape.kind == "prefill":
        from ..models import prefill as prefill_fn

        def step(params, batch):
            return prefill_fn(cfg, params, batch, plan, rt)

        args = (psds, in_sds)
        donate_argnums = ()
    else:  # decode
        def step(params, cache, tokens, cross_kv=None):
            pos = shape.seq_len - 1
            return decode_step(cfg, params, cache, tokens, pos, plan, rt,
                               cross_kv=cross_kv)

        extra = ()
        if cfg.family == "encdec":
            extra = (in_sds["cross_kv"],)
        args = (psds, in_sds["cache"], in_sds["tokens"]) + extra
        donate_argnums = (1,) if donate else ()

    t0 = time.time()
    with mesh:
        lowered = jax.jit(step, donate_argnums=donate_argnums).lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        txt = compiled.as_text()
    colls = parse_collectives(txt)

    n_tok = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                  else 1)
    n_active = active_param_count(cfg)
    mf = (6 if shape.kind == "train" else 2) * n_active * n_tok

    return {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "rules_mode": rules_mode, "unroll": unroll,
        "variant": dict(variant) if variant else {},
        "n_devices": n_dev,
        "status": "ok",
        "flops_per_device": float(ca.get("flops", -1)),
        "bytes_per_device": float(ca.get("bytes accessed", -1)),
        "collectives": colls,
        "collective_bytes_per_device": int(
            sum(v["bytes"] for v in colls.values())),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
        },
        "model_flops": float(mf),
        "params_total": param_count(cfg),
        "params_active": n_active,
        "n_super": _n_super(cfg),
        "sharding_report": report,
        "lower_s": t_lower, "compile_s": t_compile,
    }


def _n_super(cfg):
    from ..models.blocks import n_super
    return n_super(cfg)


def _combine_unrolls(r1, r2):
    """Reconstruct whole-program totals from unroll=1/2 measurements."""
    n = r1["n_super"]
    out = dict(r1)

    def derive(q1, q2):
        body = max(q2 - q1, 0.0)
        outside = max(q1 - body, 0.0)
        return outside + n * body

    out["flops_total"] = derive(r1["flops_per_device"],
                                r2["flops_per_device"])
    out["bytes_total"] = derive(r1["bytes_per_device"],
                                r2["bytes_per_device"])
    colls = {}
    tot = 0
    for c in r1["collectives"]:
        b1 = r1["collectives"][c]["bytes"]
        b2 = r2["collectives"][c]["bytes"]
        n1 = r1["collectives"][c]["count"]
        n2 = r2["collectives"][c]["count"]
        colls[c] = {"bytes": derive(b1, b2),
                    "count": derive(n1, n2)}
        tot += colls[c]["bytes"]
    out["collectives_total"] = colls
    out["collective_bytes_total"] = tot
    out["raw_unroll1"] = {k: r1[k] for k in
                          ("flops_per_device", "bytes_per_device",
                           "collective_bytes_per_device")}
    out["raw_unroll2"] = {k: r2[k] for k in
                          ("flops_per_device", "bytes_per_device",
                           "collective_bytes_per_device")}
    return out


def run_and_save(arch, shape_name, multi_pod, rules_mode="pbqp",
                 out_dir=ARTIFACT_DIR, extra_rules=None, tag="",
                 variant=None):
    out_dir = pathlib.Path(out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    name = f"{arch}__{shape_name}__{mesh_tag}"
    if rules_mode != "pbqp":
        name += f"__{rules_mode}"
    if tag:
        name += f"__{tag}"
    path = out_dir / f"{name}.json"
    try:
        r1 = run_cell(arch, shape_name, multi_pod=multi_pod,
                      rules_mode=rules_mode, unroll=1,
                      extra_rules=extra_rules, variant=variant)
        r2 = run_cell(arch, shape_name, multi_pod=multi_pod,
                      rules_mode=rules_mode, unroll=2,
                      extra_rules=extra_rules, variant=variant)
        rec = _combine_unrolls(r1, r2)
    except Exception as e:  # record failures as artifacts too
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
               "rules_mode": rules_mode, "status": "error",
               "error": repr(e), "traceback": traceback.format_exc()}
    path.write_text(json.dumps(rec, indent=2))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--rules", default="pbqp",
                    choices=["pbqp", "megatron"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=str(ARTIFACT_DIR))
    ap.add_argument("--tag", default="",
                    help="artifact suffix for variant runs")
    ap.add_argument("--variant", default="",
                    help="comma list of ModelRuntime overrides, e.g. "
                         "attn_impl=xla_chunked,remat_policy=dots")
    args = ap.parse_args()
    variant = {}
    for kv in filter(None, args.variant.split(",")):
        k, v = kv.split("=")
        variant[k] = v == "True" if v in ("True", "False") else v

    if args.all:
        # in-process loop (subprocess fan-out is in tools/run_dryruns.py)
        from ..configs import cells
        for arch, shape_name, skip in cells():
            for mp in (False, True):
                if skip:
                    continue
                rec = run_and_save(arch, shape_name, mp, args.rules,
                                   args.out)
                print(f"{arch}/{shape_name}/{rec['mesh']}: "
                      f"{rec['status']}", flush=True)
        return

    rec = run_and_save(args.arch, args.shape, args.multi_pod, args.rules,
                       args.out, tag=args.tag, variant=variant or None)
    print(json.dumps({k: v for k, v in rec.items()
                      if k not in ("traceback",)}, indent=2))
    if rec["status"] != "ok":
        print(rec.get("traceback", ""), file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
