"""On-device cost-table calibration CLI.

Sweeps the primitive library (and optionally the standalone Pallas
kernels) across a grid of scenario buckets, timing each on this device,
and writes/extends a versioned HardwareProfile JSON:

  PYTHONPATH=src python -m repro.launch.calibrate --out hw.json
  PYTHONPATH=src python -m repro.launch.calibrate --out hw.json \\
      --grid small --families direct im2 winograd
  PYTHONPATH=src python -m repro.launch.calibrate --out hw.json \\
      --net vgg-a --scale 0.25           # exactly one network's buckets
  PYTHONPATH=src python -m repro.launch.calibrate --out hw.json --dry-run

Sweeps are resumable: an existing ``--out`` profile is extended (covered
keys are skipped, progress is saved every ``--save-every`` entries), so
interrupting and re-running continues where it stopped.  ``--dry-run``
prints the sweep plan and coverage without timing anything — CI uses it
as a smoke test.  Serve with the result via
``python -m repro.launch.serve --profile hw.json`` (see
docs/calibration.md for how recalibration invalidates cached plans).
"""
from __future__ import annotations

import argparse
import collections
import sys
import time


def _plan(args):
    from ..calibrate import plan_sweep, scenario_grid, scenarios_from_net
    from ..serving import BucketPolicy

    policy = BucketPolicy()
    batches = tuple(args.batches)
    if args.net:
        from ..convnets import NETWORKS
        scns = []
        for name in args.net:
            scns.extend(scenarios_from_net(NETWORKS[name](args.scale),
                                           policy=policy, batches=batches))
    else:
        scns = scenario_grid(args.grid, policy=policy, batches=batches)

    import jax
    on_tpu = jax.devices()[0].platform == "tpu"
    kernels = on_tpu if args.kernels == "auto" else args.kernels == "on"
    # tpu-only *primitives* follow the platform, never the --kernels
    # flag: a CPU sweep of them would store interpret-mode noise that
    # CalibratedCostModel could then serve as real costs.
    exclude = () if on_tpu else ("tpu-only",)
    items = plan_sweep(scns, families=args.families or None,
                       exclude_tags=exclude, dt=not args.no_dt,
                       kernels=kernels, fused=not args.no_fused,
                       policy=policy)
    return scns, items


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="calibrate on-device cost tables for PBQP selection")
    ap.add_argument("--out", required=True,
                    help="HardwareProfile JSON to create or extend")
    ap.add_argument("--grid", default="small",
                    choices=("tiny", "small", "default"),
                    help="named scenario-bucket grid")
    ap.add_argument("--net", nargs="*", default=None,
                    help="calibrate exactly these networks' buckets "
                         "(alexnet, vgg-a..e, googlenet) instead of a grid")
    ap.add_argument("--scale", type=float, default=0.25,
                    help="network scale factor for --net")
    ap.add_argument("--batches", nargs="+", type=int, default=[1],
                    help="minibatch buckets to sweep (e.g. 1 4 16); "
                         "batched entries time the whole vmapped "
                         "invocation, pricing the batched serving path")
    ap.add_argument("--families", nargs="*", default=None,
                    help="restrict to these primitive families")
    ap.add_argument("--kernels", default="auto",
                    choices=("auto", "on", "off"),
                    help="standalone Pallas kernel microbenchmarks "
                         "(auto: only on TPU)")
    ap.add_argument("--no-dt", action="store_true",
                    help="skip layout-transform measurements")
    ap.add_argument("--no-fused", action="store_true",
                    help="skip fused (primitive, layout) pair "
                         "measurements — fused-edge pricing then falls "
                         "back to the analytic discount")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--min-time", type=float, default=5e-3,
                    help="minimum timed seconds per repetition")
    ap.add_argument("--max-entries", type=int, default=None,
                    help="stop after N new measurements (resume later)")
    ap.add_argument("--save-every", type=int, default=20)
    ap.add_argument("--fresh", action="store_true",
                    help="ignore an existing --out profile")
    ap.add_argument("--dry-run", action="store_true",
                    help="print the sweep plan and coverage; measure "
                         "nothing, write nothing")
    args = ap.parse_args(argv)

    import pathlib

    from ..calibrate import HardwareProfile, device_fingerprint, registry_hash

    scns, items = _plan(args)

    out = pathlib.Path(args.out)
    profile = None
    if out.exists() and not args.fresh:
        profile = HardwareProfile.load(out)
        if profile.device != device_fingerprint():
            print(f"error: {out} was measured on {profile.device!r}, this "
                  f"process is {device_fingerprint()!r}; use --fresh or a "
                  f"different --out", file=sys.stderr)
            return 2
        if profile.registry != registry_hash():
            print(f"note: primitive registry changed since {out} was "
                  f"created; uncovered additions will be measured",
                  file=sys.stderr)
        if (profile.reps, profile.min_time) != (args.reps, args.min_time):
            print(f"note: measurement discipline changes from "
                  f"reps={profile.reps} min_time={profile.min_time} to "
                  f"reps={args.reps} min_time={args.min_time}; the "
                  f"profile records the latest sweep's discipline",
                  file=sys.stderr)
            if not args.dry_run:
                profile.reps, profile.min_time = args.reps, args.min_time
    if profile is None:
        profile = HardwareProfile.new(reps=args.reps,
                                      min_time=args.min_time)

    by_kind = collections.Counter(it.kind for it in items)
    covered = profile.covered(it.key for it in items)
    print(f"sweep plan: {len(scns)} scenario buckets, {len(items)} "
          f"measurements ({dict(by_kind)}), {covered} already covered, "
          f"{len(items) - covered} to go")
    print(f"device {device_fingerprint()} | registry {registry_hash()} "
          f"| reps={args.reps} min_time={args.min_time}")

    if args.dry_run:
        fam = collections.Counter(it.label.split(":")[0] for it in items
                                  if it.kind == "prim")
        for f, n in sorted(fam.items()):
            print(f"  prim family {f:<10} {n:4d} measurements")
        for it in items[:5]:
            print(f"  e.g. {it.label}")
        print("dry run: nothing measured, nothing written")
        return 0

    t0 = time.perf_counter()

    def progress(i, n, item, t):
        el = time.perf_counter() - t0
        eta = el / (i + 1) * (n - i - 1)
        print(f"[{i + 1}/{n}] {item.label}: {t * 1e3:.3f} ms "
              f"(elapsed {el:.0f}s, eta {eta:.0f}s)")

    from ..calibrate import run_sweep
    report = run_sweep(profile, items, reps=args.reps,
                       min_time=args.min_time, save_path=out,
                       save_every=args.save_every,
                       max_entries=args.max_entries, progress=progress)
    print(f"measured {report['measured']}, skipped {report['skipped']} "
          f"covered, {report['remaining']} remaining -> {out} "
          f"({len(profile)} entries, content {profile.content_hash()})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
