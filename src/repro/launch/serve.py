"""Serving driver: continuous batching over a reduced model.

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --requests 8 --max-new 12
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=96)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from ..configs import get_config
    from ..models import init_params
    from ..runtime import Request, ServeLoop

    cfg = get_config(args.arch).scaled_down()
    params = init_params(cfg, jax.random.key(args.seed), jnp.float32)
    loop = ServeLoop(cfg, params, max_batch=args.max_batch,
                     max_seq=args.max_seq)
    rng = np.random.default_rng(args.seed)
    reqs = [Request(rid=i,
                    prompt=rng.integers(
                        0, cfg.vocab,
                        size=int(rng.integers(4, 24))).astype(np.int32),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    t0 = time.perf_counter()
    loop.run(reqs)
    dt = time.perf_counter() - t0
    tok = sum(len(r.tokens) for r in reqs)
    print(f"served {len(reqs)} requests, {tok} tokens in {dt:.2f}s "
          f"({tok/dt:.1f} tok/s)")
    for r in reqs[:3]:
        print(f"  req {r.rid}: prompt[{len(r.prompt)}] -> {r.tokens} "
              f"({r.latency_s*1e3:.0f} ms)")


if __name__ == "__main__":
    main()
