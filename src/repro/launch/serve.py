"""Serving driver: continuous batching over a reduced model.

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --requests 8 --max-new 12

With ``--vision-every N`` every Nth request carries a random image that
is encoded into prompt tokens through the plan-cache serving subsystem
(bucketed PBQP selection + compiled-executable reuse); plan-cache
hit/miss/latency counters are printed at the end.  ``--plan-cache-dir``
persists the PBQP plans across runs.

``--profile <path>`` prices the PBQP selection from a measured
HardwareProfile (built by ``python -m repro.launch.calibrate``) instead
of the analytic roofline; uncovered buckets fall back analytically, and
a recalibrated profile automatically invalidates previously persisted
plans through the cost-model version key (docs/calibration.md).

``--catalog <path>`` installs the surviving autotuned Pallas variants
from a VariantCatalog (built by ``python -m repro.launch.tune``) into
the primitive registry before serving: bucket solves can then assign
tuned block configurations, and the catalog content hash is folded
into every cost-model version — so swapping catalogs invalidates
persisted plans exactly like recalibration does (docs/autotune.md).

``--slo-ms`` attaches a deadline to every vision request: the
continuous-batching scheduler (docs/serving.md) launches partial
batches early when slack runs out, and goodput (the deadline-met
fraction) prints with the scheduler stats.  ``--arrival-rate`` replays
the request set as an open-loop Poisson arrival process instead of
queueing everything up front.

``--mesh dp=2,tp=2,stage=2`` serves the vision tower mesh-sharded:
bucket solves gain the device-placement axis over the named topology
(dp on the ``data`` axis, tensor-parallel weight sharding on
``model``, pipeline stages on ``stage`` — any subset, size-1 axes
dropped) and batched invocations run sharded over the resulting mesh
(fake CPU devices are forced when the host has fewer —
docs/distributed.md).  ``--dp-mesh N`` is the back-compat shorthand
for ``--mesh dp=N``.

Observability (docs/observability.md): ``--trace PATH`` writes one
JSON line per span (admit/flush/queue_wait/infer_batch/plan/
pbqp.solve/compile/execute/crop) for the whole run; ``--metrics-dump``
prints the plan server's Prometheus text exposition, and phase latency
percentiles (p50/p95/p99 per phase and batch bucket) print with the
plan-cache stats either way.
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=96)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--vision-every", type=int, default=0,
                    help="every Nth request carries an image (0: none)")
    ap.add_argument("--plan-cache-dir", default=None,
                    help="persist PBQP plans here (vision path)")
    ap.add_argument("--profile", default=None,
                    help="measured HardwareProfile JSON driving PBQP "
                         "selection (see repro.launch.calibrate)")
    ap.add_argument("--catalog", default=None,
                    help="VariantCatalog JSON (repro.launch.tune): "
                         "install its surviving autotuned variants as "
                         "selectable primitives before serving; the "
                         "catalog hash rotates every plan-cache key")
    ap.add_argument("--image-tokens", type=int, default=4)
    ap.add_argument("--slo-ms", type=float, default=0.0,
                    help="vision SLO in ms: image requests carry a "
                         "deadline and the continuous scheduler "
                         "launches partial batches before it lapses "
                         "(0: no deadline); goodput prints at the end")
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="open-loop Poisson arrival rate in req/s "
                         "(0: all requests queued up front)")
    ap.add_argument("--mesh", default=None, metavar="SPEC",
                    help="serve the vision tower sharded over a device "
                         "mesh, e.g. 'dp=2,tp=2' or 'stage=4' (axes: "
                         "dp/tp/stage; fake CPU devices forced as "
                         "needed)")
    ap.add_argument("--dp-mesh", type=int, default=0,
                    help="back-compat shorthand for --mesh dp=N "
                         "(0: single device)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write request-scoped trace spans as JSONL")
    ap.add_argument("--metrics-dump", action="store_true",
                    help="print the Prometheus text exposition of the "
                         "plan server's metrics registry at the end")
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="write the plan server's stats snapshot as "
                         "JSON (feed to tools/obs_report.py "
                         "--metrics-file for the degradation table)")
    ap.add_argument("--fault-plan", default=None, metavar="SPEC",
                    help="chaos fault plan (docs/reliability.md): a "
                         "JSON file of fault specs or an inline DSL "
                         "like 'kernel:nan@5+3~winograd,compile:"
                         "raise@0+2'; faults fire deterministically "
                         "and degradations are counted, not fatal")
    ap.add_argument("--solve-deadline-ms", type=float, default=0.0,
                    help="wall-clock budget per PBQP solve: branch-and-"
                         "bound becomes anytime and returns its best "
                         "incumbent at the deadline (0: exact, no "
                         "deadline)")
    ap.add_argument("--shed", action="store_true",
                    help="deadline-aware load shedding: reject vision "
                         "requests at admission when the modeled "
                         "backlog makes their SLO unmeetable (shed "
                         "images run unbatched instead; needs "
                         "--slo-ms)")
    args = ap.parse_args()
    if args.trace:
        from ..obs.trace import configure
        tracer = configure(args.trace, enabled=True)
    if args.profile and args.vision_every <= 0:
        ap.error("--profile prices the vision plan path; it needs "
                 "--vision-every > 0 to have any effect")
    if args.catalog and args.vision_every <= 0:
        ap.error("--catalog extends the vision primitive registry; it "
                 "needs --vision-every > 0 to have any effect")
    if args.mesh and args.dp_mesh > 1:
        ap.error("--dp-mesh is the shorthand for --mesh dp=N; pass "
                 "one or the other")
    if args.dp_mesh > 1:
        args.mesh = f"dp={args.dp_mesh}"
    mesh_spec = None
    if args.mesh:
        if args.vision_every <= 0:
            ap.error("--mesh shards the vision plan path; it needs "
                     "--vision-every > 0 to have any effect")
        from .mesh import force_host_devices, parse_mesh_spec
        mesh_spec = parse_mesh_spec(args.mesh)
        n_dev = 1
        for s in mesh_spec[0]:
            n_dev *= s
        # must happen before jax initialises its backends
        force_host_devices(n_dev)

    import jax
    import jax.numpy as jnp
    import numpy as np

    from ..configs import get_config
    from ..models import init_params
    from ..runtime import Request, ServeLoop

    cfg = get_config(args.arch).scaled_down()
    params = init_params(cfg, jax.random.key(args.seed), jnp.float32)

    plan_server = None
    if args.vision_every > 0:
        from ..core.costs import AnalyticCostModel
        from ..serving import BucketPolicy, PlanServer, conv_tower
        if args.catalog:
            from ..autotune import VariantCatalog
            catalog = VariantCatalog.load(args.catalog)
            n_inst = catalog.install()
            print(f"catalog {args.catalog}: installed {n_inst} "
                  f"autotuned variants (content "
                  f"{catalog.content_hash()})")
        policy = BucketPolicy(min_hw=8, max_hw=128)
        cost_model = AnalyticCostModel()
        if args.profile:
            from ..calibrate import CalibratedCostModel, HardwareProfile
            cost_model = CalibratedCostModel(
                HardwareProfile.load(args.profile), fallback=cost_model,
                policy=policy)
        mesh = None
        if mesh_spec is not None:
            from .mesh import make_mesh_compat
            mesh = make_mesh_compat(*mesh_spec)
        injector = None
        if args.fault_plan:
            from ..reliability import FaultInjector, parse_fault_plan
            injector = FaultInjector(parse_fault_plan(args.fault_plan),
                                     seed=args.seed)
        plan_server = PlanServer(
            lambda s: conv_tower(s, depth=2, width=8),
            cost_model,
            policy=policy, mesh=mesh,
            cache_dir=args.plan_cache_dir, lru_capacity=4,
            fault_injector=injector,
            solve_deadline_s=args.solve_deadline_ms / 1e3
            if args.solve_deadline_ms > 0 else None)

    slo_s = args.slo_ms / 1e3 if args.slo_ms > 0 else None
    scheduler = None
    if args.shed:
        if plan_server is None or slo_s is None:
            ap.error("--shed needs --vision-every > 0 and --slo-ms > 0 "
                     "(shedding is deadline-aware admission control)")
        from ..serving.scheduler import ContinuousScheduler
        scheduler = ContinuousScheduler(plan_server, slo_s=slo_s,
                                        shed=True)
    loop = ServeLoop(cfg, params, max_batch=args.max_batch,
                     max_seq=args.max_seq, plan_server=plan_server,
                     image_tokens=args.image_tokens,
                     scheduler=scheduler, slo_s=slo_s)
    rng = np.random.default_rng(args.seed)
    reqs = []
    arrival = 0.0
    for i in range(args.requests):
        pixels = None
        if plan_server is not None and i % args.vision_every == 0:
            hw = int(rng.integers(12, 40))
            pixels = rng.normal(size=(3, hw, hw)).astype(np.float32)
        if args.arrival_rate > 0:
            # open-loop Poisson process: exponential interarrivals
            arrival += float(rng.exponential(1.0 / args.arrival_rate))
        reqs.append(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab,
                                size=int(rng.integers(4, 24)))
            .astype(np.int32),
            max_new_tokens=args.max_new, pixels=pixels,
            arrival_s=arrival))
    t0 = time.perf_counter()
    loop.run(reqs)
    dt = time.perf_counter() - t0
    tok = sum(len(r.tokens) for r in reqs)
    print(f"served {len(reqs)} requests, {tok} tokens in {dt:.2f}s "
          f"({tok/dt:.1f} tok/s)")
    for r in reqs[:3]:
        print(f"  req {r.rid}: prompt[{len(r.prompt)}] -> {r.tokens} "
              f"({r.latency_s*1e3:.0f} ms)")
    if plan_server is not None:
        s = loop.scheduler.stats() if loop.scheduler is not None \
            else plan_server.stats()
        if args.slo_ms > 0:
            print(f"scheduler: {s['sched_batches']} batches "
                  f"(full={s['sched_full_launches']} "
                  f"deadline={s['sched_deadline_launches']} "
                  f"window={s['sched_window_launches']})"
                  f" | goodput={s['goodput']:.2%}"
                  f" ({s['deadline_met']}/{s['deadline_met'] + s['deadline_miss']}"
                  f" deadlines met)"
                  f" | workers={s['sched_workers']}"
                  f" resizes={s['worker_resizes']}")
        print("plan cache: "
              f"{s['requests']} vision requests over {s['buckets']} buckets"
              f" | solves={s['solves']} (warm={s['warm_solves']})"
              f" compiles={s['compiles']}"
              f" | plan hits={s['plan_hits']} exec hits={s['exec_hits']}"
              f" | batched calls={s['batch_calls']}"
              f" (+{s['coalesced']} coalesced,"
              f" {s['mesh_compiles']} mesh-sharded)"
              f" | solve {s['solve_s']*1e3:.0f} ms"
              f" compile {s['compile_s']*1e3:.0f} ms"
              f" execute {s['execute_s']*1e3:.0f} ms")
        for phase, q in sorted(s.get("phases", {}).items()):
            print(f"  {phase}: n={q['count']} "
                  f"p50={q['p50']*1e3:.2f}ms p95={q['p95']*1e3:.2f}ms "
                  f"p99={q['p99']*1e3:.2f}ms")
        if s["ladder_demotions"] or s["quarantines"] or \
                s["shed_requests"] or s["plan_cache_corrupt"] or \
                s["worker_deaths"]:
            print("degradations: "
                  f"ladder exact={s['ladder_exact']} "
                  f"anytime={s['ladder_anytime']} "
                  f"greedy={s['ladder_greedy']} "
                  f"reference={s['ladder_reference']}"
                  f" | quarantines={s['quarantines']}"
                  f" (active: {', '.join(s['quarantined']) or 'none'})"
                  f" | shed={s['shed_requests']}"
                  f" corrupt plans={s['plan_cache_corrupt']}"
                  f" worker deaths={s['worker_deaths']}"
                  f" (requeued {s['worker_requeues']})"
                  f" | kernel failures={s['kernel_failures']}"
                  f" compile retries={s['compile_retries']}")
        if args.metrics_json:
            import json
            with open(args.metrics_json, "w") as fh:
                json.dump(s, fh, indent=1, default=str)
            print(f"metrics snapshot written to {args.metrics_json}")
        if args.metrics_dump:
            print(plan_server.metrics_text(), end="")
        if args.profile:
            cov = cost_model.coverage()
            print(f"calibrated costs: {cov['table_hits']} table hits, "
                  f"{cov['fallback_hits']} analytic fallbacks "
                  f"({cov['table_rate']:.0%} measured)")
        loop.close()
        if scheduler is not None:
            scheduler.close()
        plan_server.close()
    if args.trace:
        tracer.flush()
        print(f"trace spans written to {args.trace}")


if __name__ == "__main__":
    main()
