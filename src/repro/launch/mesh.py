"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module does not touch jax device state — critical because the dry-run
must set XLA_FLAGS before jax initialises.
"""
from __future__ import annotations

import jax

from ..core.plan import mesh_shape_dict  # re-export: single definition

__all__ = ["make_mesh_compat", "make_production_mesh", "make_cpu_mesh",
           "mesh_shape_dict", "mesh_fingerprint", "force_host_devices"]


def force_host_devices(n: int) -> None:
    """Ensure XLA_FLAGS requests at least ``n`` fake host devices.

    Must run before jax initialises its backends (flags are read at
    backend init, not at ``import jax``).  A pre-existing
    ``--xla_force_host_platform_device_count`` with a *smaller* count
    is replaced — the caller's mesh needs ``n`` — while a larger one is
    kept; on real accelerator hosts the flag only affects the unused
    CPU platform, so forcing is always safe.  Single home for this
    mangling: the serve CLI and the sharding benchmark both route
    through here.
    """
    import os
    import re
    flags = os.environ.get("XLA_FLAGS", "")
    m = re.search(r"--xla_force_host_platform_device_count=(\d+)", flags)
    if m:
        if int(m.group(1)) >= n:
            return
        flags = flags.replace(
            m.group(0), f"--xla_force_host_platform_device_count={n}")
    else:
        flags = (f"{flags} "
                 f"--xla_force_host_platform_device_count={n}").strip()
    os.environ["XLA_FLAGS"] = flags


def make_mesh_compat(shape, axis_names):
    """``jax.make_mesh`` across jax versions.

    ``axis_types`` (and ``jax.sharding.AxisType``) only exist in newer
    jax releases; on older ones every axis is implicitly Auto, which is
    the only mode this repo uses — so fall back to the plain call.
    """
    try:
        return jax.make_mesh(
            shape, axis_names,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names))
    except (AttributeError, TypeError):
        return jax.make_mesh(shape, axis_names)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256-chip single pod; 2x16x16 = 512-chip two-pod mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_cpu_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many (fake) devices the test process has."""
    return make_mesh_compat((data, model), ("data", "model"))


def mesh_fingerprint(mesh) -> str:
    """Stable cache-key component for a mesh: platform + axis topology.

    Device *ids* are deliberately excluded — the same topology on a
    different pod (or a restarted fake-device process) solves identical
    placement PBQPs, so its persisted plans stay valid.
    """
    if mesh is None:
        return "none"
    axes = "x".join(f"{n}{s}" for n, s in
                    zip(mesh.axis_names, mesh.devices.shape))
    platform = mesh.devices.flat[0].platform
    return f"{platform}-{axes}"
