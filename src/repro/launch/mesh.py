"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module does not touch jax device state — critical because the dry-run
must set XLA_FLAGS before jax initialises.
"""
from __future__ import annotations

from typing import Dict

import jax

__all__ = ["make_mesh_compat", "make_production_mesh", "make_cpu_mesh",
           "mesh_shape_dict"]


def make_mesh_compat(shape, axis_names):
    """``jax.make_mesh`` across jax versions.

    ``axis_types`` (and ``jax.sharding.AxisType``) only exist in newer
    jax releases; on older ones every axis is implicitly Auto, which is
    the only mode this repo uses — so fall back to the plain call.
    """
    try:
        return jax.make_mesh(
            shape, axis_names,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names))
    except (AttributeError, TypeError):
        return jax.make_mesh(shape, axis_names)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256-chip single pod; 2x16x16 = 512-chip two-pod mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_cpu_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many (fake) devices the test process has."""
    return make_mesh_compat((data, model), ("data", "model"))


def mesh_shape_dict(mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
