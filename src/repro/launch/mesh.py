"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module does not touch jax device state — critical because the dry-run
must set XLA_FLAGS before jax initialises.
"""
from __future__ import annotations

import jax

from ..core.plan import mesh_shape_dict  # re-export: single definition

__all__ = ["make_mesh_compat", "make_production_mesh", "make_cpu_mesh",
           "mesh_shape_dict", "mesh_fingerprint", "force_host_devices",
           "parse_mesh_spec"]

#: CLI parallelism names -> mesh axis names.  The CLI speaks the
#: paper's vocabulary (dp/tp/stage); the mesh speaks jax's
#: (data/model/stage).
_MESH_AXIS_ALIASES = {"dp": "data", "data": "data",
                      "tp": "model", "model": "model",
                      "pp": "stage", "stage": "stage"}


def parse_mesh_spec(spec: str):
    """Parse ``"dp=2,tp=2,stage=2"`` into ``(shape, axis_names)``.

    Accepts both CLI aliases (dp/tp/pp) and raw axis names
    (data/model/stage), in any order; size-1 axes are dropped (a
    1-wide group is just replication — the solver prices it
    identically, see ``core.costs.send_time``).  Axis order is
    canonicalized to (data, model, stage) so equivalent specs
    fingerprint identically.
    """
    sizes = {}
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        if "=" not in part:
            raise ValueError(f"mesh spec entry {part!r} is not "
                             f"<axis>=<size> (spec: {spec!r})")
        name, _, val = part.partition("=")
        axis = _MESH_AXIS_ALIASES.get(name.strip().lower())
        if axis is None:
            raise ValueError(
                f"unknown mesh axis {name.strip()!r} — use "
                f"dp/tp/stage (spec: {spec!r})")
        try:
            size = int(val)
        except ValueError:
            raise ValueError(f"mesh axis {name.strip()!r} has non-"
                             f"integer size {val!r}") from None
        if size < 1:
            raise ValueError(f"mesh axis {name.strip()!r} has size "
                             f"{size} < 1")
        if axis in sizes:
            raise ValueError(f"mesh axis {axis!r} given twice in "
                             f"{spec!r}")
        sizes[axis] = size
    canon = [(a, sizes[a]) for a in ("data", "model", "stage")
             if sizes.get(a, 1) > 1]
    if not canon:
        raise ValueError(f"mesh spec {spec!r} names no axis wider "
                         f"than 1 device")
    return tuple(s for _, s in canon), tuple(a for a, _ in canon)


def force_host_devices(n: int) -> None:
    """Ensure XLA_FLAGS requests at least ``n`` fake host devices.

    Must run before jax initialises its backends (flags are read at
    backend init, not at ``import jax``).  A pre-existing
    ``--xla_force_host_platform_device_count`` with a *smaller* count
    is replaced — the caller's mesh needs ``n`` — while a larger one is
    kept; on real accelerator hosts the flag only affects the unused
    CPU platform, so forcing is always safe.  Single home for this
    mangling: the serve CLI and the sharding benchmark both route
    through here.
    """
    import os
    import re
    flags = os.environ.get("XLA_FLAGS", "")
    m = re.search(r"--xla_force_host_platform_device_count=(\d+)", flags)
    if m:
        if int(m.group(1)) >= n:
            return
        flags = flags.replace(
            m.group(0), f"--xla_force_host_platform_device_count={n}")
    else:
        flags = (f"{flags} "
                 f"--xla_force_host_platform_device_count={n}").strip()
    os.environ["XLA_FLAGS"] = flags


def make_mesh_compat(shape, axis_names):
    """``jax.make_mesh`` across jax versions.

    ``axis_types`` (and ``jax.sharding.AxisType``) only exist in newer
    jax releases; on older ones every axis is implicitly Auto, which is
    the only mode this repo uses — so fall back to the plain call.
    """
    try:
        return jax.make_mesh(
            shape, axis_names,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names))
    except (AttributeError, TypeError):
        return jax.make_mesh(shape, axis_names)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256-chip single pod; 2x16x16 = 512-chip two-pod mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_cpu_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many (fake) devices the test process has."""
    return make_mesh_compat((data, model), ("data", "model"))


def mesh_fingerprint(mesh) -> str:
    """Stable cache-key component for a mesh: platform + axis topology.

    Device *ids* are deliberately excluded — the same topology on a
    different pod (or a restarted fake-device process) solves identical
    placement PBQPs, so its persisted plans stay valid.
    """
    if mesh is None:
        return "none"
    axes = "x".join(f"{n}{s}" for n, s in
                    zip(mesh.axis_names, mesh.devices.shape))
    platform = mesh.devices.flat[0].platform
    return f"{platform}-{axes}"
