"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module does not touch jax device state — critical because the dry-run
must set XLA_FLAGS before jax initialises.
"""
from __future__ import annotations

from typing import Dict

import jax

__all__ = ["make_production_mesh", "make_cpu_mesh", "mesh_shape_dict"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256-chip single pod; 2x16x16 = 512-chip two-pod mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_cpu_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many (fake) devices the test process has."""
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)


def mesh_shape_dict(mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
