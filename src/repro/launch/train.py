"""End-to-end training driver.

  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --steps 200 --d-model 512 --layers 8 --seq 256 --batch 8

Runs a reduced (CPU-feasible) config of the selected architecture
through the fault-tolerant loop with checkpointing; on a TPU fleet the
same driver runs the full config on the production mesh with the
PBQP-selected sharding rules (--mesh production).
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=0,
                    help="0 = family-preserving default")
    ap.add_argument("--full-config", action="store_true",
                    help="use the published config (TPU-scale)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax.numpy as jnp

    from ..configs import get_config
    from ..configs.base import ShapeConfig
    from ..optim import adamw, warmup_cosine
    from ..runtime import TrainLoopConfig, train

    cfg = get_config(args.arch)
    if not args.full_config:
        kw = dict(d_model=args.d_model,
                  d_ff=args.d_model * (0 if cfg.family == "ssm" else 3),
                  vocab=min(cfg.vocab, 8192),
                  n_heads=min(cfg.n_heads, 8) or 0,
                  n_kv_heads=min(cfg.n_kv_heads, 4) or 0,
                  head_dim=64 if cfg.head_dim else 0)
        if args.layers:
            kw["n_layers"] = args.layers
        cfg = cfg.scaled_down(**kw)
    shape = ShapeConfig("cli", seq_len=args.seq, global_batch=args.batch,
                        kind="train")
    opt = adamw(warmup_cosine(args.lr, 20, args.steps))
    metrics = []
    st = train(cfg, shape, opt,
               loop=TrainLoopConfig(total_steps=args.steps,
                                    ckpt_every=args.ckpt_every,
                                    ckpt_dir=args.ckpt_dir),
               seed=args.seed, dtype=jnp.float32, metrics_out=metrics)
    from ..models import param_count as _pc
    print(f"finished at step {st.step}; params={_pc(cfg)/1e6:.1f}M; "
          f"final loss {metrics[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
