"""ShapeDtypeStruct stand-ins for every model input (no allocation).

``input_specs(cfg, shape)`` returns (specs, logical_axes): the same
pattern as the smoke tests' real batches but weight-free, shardable and
allocation-free — consumed by jit(...).lower(**specs) in the dry-run.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from ..models.model import init_cache

__all__ = ["input_specs", "batch_axes"]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeConfig, *,
                dtype=jnp.bfloat16) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """Returns (specs, axes): pytree of ShapeDtypeStruct + matching
    logical-axis tuples for sharding resolution."""
    b, t = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        t_text = t
        specs: Dict[str, Any] = {}
        axes: Dict[str, Any] = {}
        if cfg.family == "vlm":
            t_text = t - cfg.n_patches
            specs["patches"] = _sds((b, cfg.n_patches, cfg.d_model), dtype)
            axes["patches"] = ("batch", "seq", "d_model")
        if cfg.family == "encdec":
            specs["frames"] = _sds((b, cfg.enc_seq, cfg.d_model), dtype)
            axes["frames"] = ("batch", "enc_seq", "d_model")
        specs["tokens"] = _sds((b, t_text), jnp.int32)
        axes["tokens"] = ("batch", "seq")
        if shape.kind == "train":
            specs["labels"] = _sds((b, t_text), jnp.int32)
            axes["labels"] = ("batch", "seq")
        return specs, axes

    # decode: one new token against a seq_len KV cache
    cache = jax.eval_shape(lambda: init_cache(cfg, b, t, dtype))

    def cache_axes(path, leaf):
        key = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if key in ("k", "v"):      # (L, B, S, Hkv, hd)
            return ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
        if key == "ssm":           # (L, B, H, N, P)
            return ("layers", "batch", "ssm_heads", None, None)
        return ("layers", "batch", None, None)  # conv state

    cache_ax = jax.tree_util.tree_map_with_path(cache_axes, cache)
    specs = {"cache": cache, "tokens": _sds((b, 1), jnp.int32)}
    axes = {"cache": cache_ax, "tokens": ("batch", "seq")}
    if cfg.family == "encdec":
        specs["cross_kv"] = _sds((b, cfg.enc_seq, cfg.d_model), dtype)
        axes["cross_kv"] = ("batch", "enc_seq", "d_model")
    return specs, axes


def batch_axes(axes_tree, rules):
    """Resolve logical axes -> PartitionSpecs for the input pytree."""
    return jax.tree.map(
        lambda ax: rules.spec(ax),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x))
