"""Autotuning CLI: sweep Pallas variant spaces, prune, write a catalog.

Enumerates every kernel package's tunable block/tile/unroll space
(docs/autotune.md), measures each valid configuration per scenario
bucket through the calibration machinery, prunes Pareto-dominated
variants, and writes the winners as a versioned VariantCatalog JSON:

  PYTHONPATH=src python -m repro.launch.tune --catalog variants.json
  PYTHONPATH=src python -m repro.launch.tune --catalog variants.json \\
      --grid small --kernels matmul conv_im2col
  PYTHONPATH=src python -m repro.launch.tune --catalog variants.json \\
      --net vgg-a --scale 0.25 --batches 1 8
  PYTHONPATH=src python -m repro.launch.tune --catalog variants.json \\
      --dry-run

Sweeps are resumable exactly like calibration: measurements accumulate
in a HardwareProfile (``--profile``, defaults next to the catalog),
covered keys are skipped on re-run, and ``--budget N`` caps how many
new measurements one invocation performs before writing a catalog from
whatever is covered so far.  ``--measure analytic`` prices candidates
with the tile-aware analytic TPU model (the default off-TPU, where
interpret-mode timings are noise); ``--measure real`` times kernels on
this device.  Serve with the result via
``python -m repro.launch.serve --catalog variants.json``.
"""
from __future__ import annotations

import argparse
import collections
import sys
import time


def _scenarios(args):
    from ..calibrate import scenario_grid, scenarios_from_net
    from ..serving import BucketPolicy

    policy = BucketPolicy()
    batches = tuple(args.batches)
    if args.net:
        from ..convnets import NETWORKS
        scns = []
        for name in args.net:
            scns.extend(scenarios_from_net(NETWORKS[name](args.scale),
                                           policy=policy, batches=batches))
    else:
        scns = scenario_grid(args.grid, policy=policy, batches=batches)
    return scns, policy


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="autotune Pallas variant spaces and write a "
                    "VariantCatalog of PBQP-registrable winners")
    ap.add_argument("--catalog", required=True,
                    help="VariantCatalog JSON to write")
    ap.add_argument("--profile", default=None,
                    help="HardwareProfile JSON holding the tuning "
                         "measurements (default: <catalog>.profile.json; "
                         "an existing one resumes the sweep)")
    ap.add_argument("--grid", default="small",
                    choices=("tiny", "small", "default"),
                    help="named scenario-bucket grid")
    ap.add_argument("--net", nargs="*", default=None,
                    help="tune exactly these networks' buckets "
                         "(alexnet, vgg-a..e, googlenet) instead of a grid")
    ap.add_argument("--scale", type=float, default=0.25,
                    help="network scale factor for --net")
    ap.add_argument("--batches", nargs="+", type=int, default=[1],
                    help="minibatch buckets to sweep")
    ap.add_argument("--kernels", nargs="*", default=None,
                    help="restrict to these kernel packages (matmul, "
                         "conv_direct, conv_im2col, winograd_gemm, "
                         "flash_attention, layout_transform)")
    ap.add_argument("--max-per-kernel", type=int, default=None,
                    help="cap the configurations tried per kernel "
                         "(first N of the enumeration; smoke tests)")
    ap.add_argument("--measure", default="auto",
                    choices=("auto", "real", "analytic"),
                    help="price candidates by on-device timing (real) "
                         "or the tile-aware analytic TPU model "
                         "(auto: real on TPU, analytic elsewhere)")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--min-time", type=float, default=5e-3,
                    help="minimum timed seconds per repetition")
    ap.add_argument("--budget", type=int, default=None,
                    help="stop after N new measurements (resume later; "
                         "the catalog is still written from covered "
                         "entries)")
    ap.add_argument("--save-every", type=int, default=20)
    ap.add_argument("--fresh", action="store_true",
                    help="ignore an existing --profile")
    ap.add_argument("--dry-run", action="store_true",
                    help="print the variant pool and sweep plan; "
                         "measure nothing, write nothing")
    args = ap.parse_args(argv)

    import pathlib

    from ..autotune import plan_only, tune
    from ..calibrate import HardwareProfile, device_fingerprint

    scns, policy = _scenarios(args)
    variants, items, index = plan_only(
        scns, kernels=args.kernels, max_per_kernel=args.max_per_kernel,
        policy=policy)

    by_kind = collections.Counter(it.kind for it in items)
    print(f"tune plan: {len(variants)} candidate variants, "
          f"{len(items)} measurements ({dict(by_kind)})")
    if args.dry_run:
        by_kernel = collections.Counter(
            e[1].name.split("@")[0] if e[0] == "prim"
            else f"kernel:{e[1].kernel}" for e in index.values())
        for k, n in sorted(by_kernel.items()):
            print(f"  {k:<24} {n:4d} measurements")
        for it in items[:5]:
            print(f"  e.g. {it.label}")
        print("dry run: nothing measured, nothing written")
        return 0

    cat_path = pathlib.Path(args.catalog)
    prof_path = pathlib.Path(args.profile) if args.profile \
        else cat_path.with_suffix(".profile.json")
    profile = None
    if prof_path.exists() and not args.fresh:
        profile = HardwareProfile.load(prof_path)
        if profile.device != device_fingerprint():
            print(f"error: {prof_path} was measured on "
                  f"{profile.device!r}, this process is "
                  f"{device_fingerprint()!r}; use --fresh or a "
                  f"different --profile", file=sys.stderr)
            return 2
        print(f"resuming from {prof_path} ({len(profile)} entries)")

    t0 = time.perf_counter()

    def progress(i, n, item, t):
        el = time.perf_counter() - t0
        eta = el / (i + 1) * (n - i - 1)
        print(f"[{i + 1}/{n}] {item.label}: {t * 1e3:.3f} ms "
              f"(elapsed {el:.0f}s, eta {eta:.0f}s)")

    res = tune(scns, kernels=args.kernels,
               max_per_kernel=args.max_per_kernel,
               measure_mode=args.measure, profile=profile,
               profile_path=prof_path, budget=args.budget,
               reps=args.reps, min_time=args.min_time,
               save_every=args.save_every, policy=policy,
               progress=progress)
    res.profile.save(prof_path)
    res.catalog.save(cat_path)
    print(f"measured {res.sweep['measured']}, skipped "
          f"{res.sweep['skipped']} covered, {res.sweep['remaining']} "
          f"remaining -> {prof_path}")
    print(f"catalog: {res.generated} generated, {res.surviving} "
          f"surviving, {res.pruned} pruned, "
          f"{len(res.catalog.kernels)} kernel-only winners -> "
          f"{cat_path} (content {res.catalog.content_hash()})")
    for name in res.catalog.survivors():
        print(f"  + {name}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
