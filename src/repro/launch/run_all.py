"""Fan out the full (arch x shape x mesh) dry-run matrix as subprocesses.

Each cell runs in its own process (fault isolation + fresh XLA device
state); results land in benchmarks/results/dryrun/*.json.  Skipped
cells (long_500k on pure full-attention archs) get a marker artifact.

  PYTHONPATH=src python -m repro.launch.run_all [--jobs 3] [--force]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import subprocess
import sys
import time
from concurrent.futures import ThreadPoolExecutor, as_completed

from ..configs import cells
from .dryrun import ARTIFACT_DIR


def _run_one(arch, shape, multi_pod, out_dir, timeout=3600):
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", arch, "--shape", shape, "--out", str(out_dir)]
    if multi_pod:
        cmd.append("--multi-pod")
    t0 = time.time()
    try:
        proc = subprocess.run(cmd, capture_output=True, text=True,
                              timeout=timeout)
        ok = proc.returncode == 0
        err = proc.stderr[-2000:] if not ok else ""
    except subprocess.TimeoutExpired:
        ok, err = False, f"timeout after {timeout}s"
    return arch, shape, mesh_tag, ok, time.time() - t0, err


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", type=int, default=3)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=str(ARTIFACT_DIR))
    ap.add_argument("--meshes", default="both",
                    choices=["both", "single", "multi"])
    ap.add_argument("--timeout", type=int, default=3600)
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    meshes = {"both": [False, True], "single": [False],
              "multi": [True]}[args.meshes]
    work = []
    for arch, shape, skip in cells():
        for mp in meshes:
            tag = "2x16x16" if mp else "16x16"
            path = out_dir / f"{arch}__{shape}__{tag}.json"
            if skip:
                path.write_text(json.dumps({
                    "arch": arch, "shape": shape, "mesh": tag,
                    "status": "skipped", "reason": skip}, indent=2))
                continue
            if path.exists() and not args.force:
                rec = json.loads(path.read_text())
                if rec.get("status") == "ok":
                    continue
            work.append((arch, shape, mp))

    print(f"{len(work)} cells to run on {args.jobs} workers", flush=True)
    fails = []
    with ThreadPoolExecutor(max_workers=args.jobs) as ex:
        futs = {ex.submit(_run_one, a, s, m, out_dir, args.timeout):
                (a, s, m) for a, s, m in work}
        for fut in as_completed(futs):
            arch, shape, mesh_tag, ok, dt, err = fut.result()
            status = "ok" if ok else "FAIL"
            print(f"[{status}] {arch}/{shape}/{mesh_tag} ({dt:.0f}s)",
                  flush=True)
            if not ok:
                fails.append((arch, shape, mesh_tag, err))
    for f in fails:
        print("FAILED:", f[:3], "\n", f[3][-500:], file=sys.stderr)
    print(f"done: {len(work) - len(fails)}/{len(work)} ok", flush=True)


if __name__ == "__main__":
    main()
