from .compression import (
    compressed_psum_tree, dequantize_int8, quantize_int8,
)
from .optimizers import (
    Optimizer, adafactor, adamw, clip_by_global_norm, for_config,
    global_norm, warmup_cosine,
)

__all__ = [
    "Optimizer", "adamw", "adafactor", "clip_by_global_norm", "for_config",
    "global_norm", "warmup_cosine", "quantize_int8", "dequantize_int8",
    "compressed_psum_tree",
]
