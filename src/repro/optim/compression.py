"""Gradient compression for cross-pod synchronisation.

int8 stochastic-rounding quantisation with per-tensor scales: the
pseudo-gradient exchanged between pods in the DiLoCo-style outer loop
(runtime/train_loop.py) shrinks 2-4x, which matters on the
data-center-network "pod" axis where links are an order of magnitude
slower than intra-pod ICI.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["quantize_int8", "dequantize_int8", "compressed_psum_tree"]


def quantize_int8(x, key=None):
    scale = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32))), 1e-12) / 127.0
    scaled = x.astype(jnp.float32) / scale
    if key is not None:  # stochastic rounding: unbiased compression
        noise = jax.random.uniform(key, x.shape, jnp.float32, -0.5, 0.5)
        scaled = scaled + noise
    q = jnp.clip(jnp.round(scaled), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum_tree(tree, axis_name: str, key=None):
    """Quantised all-reduce over ``axis_name`` (use inside shard_map).

    A shared scale is agreed via a scalar pmax first (cheap), so the
    int8 payload — the only large message — dequantises exactly:
    int8 accumulated in int32 (no overflow below 2^23 participants).
    Returns the mean over the axis.
    """
    n = jax.lax.psum(1, axis_name)
    leaves, tdef = jax.tree.flatten(tree)
    keys = (jax.random.split(key, len(leaves)) if key is not None
            else [None] * len(leaves))
    out = []
    for leaf, k in zip(leaves, keys):
        local_max = jnp.max(jnp.abs(leaf.astype(jnp.float32)))
        scale = jnp.maximum(jax.lax.pmax(local_max, axis_name),
                            1e-12) / 127.0
        scaled = leaf.astype(jnp.float32) / scale
        if k is not None:  # stochastic rounding: unbiased
            noise = jax.random.uniform(k, leaf.shape, jnp.float32,
                                       -0.5, 0.5)
            scaled = scaled + noise
        q = jnp.clip(jnp.round(scaled), -127, 127).astype(jnp.int8)
        acc = jax.lax.psum(q.astype(jnp.int32), axis_name)
        out.append((acc.astype(jnp.float32) * scale / n).astype(leaf.dtype))
    return tdef.unflatten(out)
