"""Optimizers (pure-pytree, optax-style API surface).

AdamW for <=100B models; Adafactor (factored second moment) for the
300B-1T archs where Adam state would not fit HBM (see EXPERIMENTS.md
§Dry-run memory notes).  Both compose with global-norm clipping and the
warmup+cosine schedule.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["adamw", "adafactor", "clip_by_global_norm",
           "warmup_cosine", "Optimizer"]


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jnp.ndarray], Tuple[Any, Any]]
    #: state bytes per parameter (for memory accounting in the dry-run)
    state_bytes_per_param: float = 8.0


def warmup_cosine(peak_lr: float, warmup: int, total: int,
                  floor: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 *
                         (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale)
                        .astype(g.dtype), tree), norm


def adamw(lr_fn, *, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, clip_norm: float = 1.0) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, _unused_step=None):
        grads, _ = clip_by_global_norm(grads, clip_norm)
        count = state["count"] + 1
        lr = lr_fn(count)
        bc1 = 1 - b1 ** count.astype(jnp.float32)
        bc2 = 1 - b2 ** count.astype(jnp.float32)

        def upd(p, g, m, v):
            g32 = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g32
            v = b2 * v + (1 - b2) * g32 * g32
            step = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            step = step + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state["m"])
        flat_v = tdef.flatten_up_to(state["v"])
        out = [upd(p, g, m, v) for p, g, m, v
               in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_m = tdef.unflatten([o[1] for o in out])
        new_v = tdef.unflatten([o[2] for o in out])
        return new_p, {"m": new_m, "v": new_v, "count": count}

    return Optimizer(init, update, state_bytes_per_param=8.0)


def adafactor(lr_fn, *, eps: float = 1e-30, clip_norm: float = 1.0,
              weight_decay: float = 0.0, min_dim: int = 128) -> Optimizer:
    """Factored second-moment optimizer (Shazeer & Stern 2018): matrices
    keep only row/col statistics — O(n+m) state instead of O(nm)."""
    def factored(p):
        return p.ndim >= 2 and p.shape[-1] >= min_dim and \
            p.shape[-2] >= min_dim

    def init(params):
        def one(p):
            if factored(p):
                return {"r": jnp.zeros(p.shape[:-1], jnp.float32),
                        "c": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                       jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"f": jax.tree.map(one, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, _unused_step=None):
        grads, _ = clip_by_global_norm(grads, clip_norm)
        count = state["count"] + 1
        lr = lr_fn(count)
        beta = 1.0 - count.astype(jnp.float32) ** -0.8

        def upd(p, g, f):
            g32 = g.astype(jnp.float32)
            g2 = g32 * g32 + eps
            if factored(p):
                r = beta * f["r"] + (1 - beta) * jnp.mean(g2, axis=-1)
                c = beta * f["c"] + (1 - beta) * jnp.mean(g2, axis=-2)
                rc = r / jnp.maximum(
                    jnp.mean(r, axis=-1, keepdims=True), eps)
                vhat = rc[..., None] * c[..., None, :]
                nf = {"r": r, "c": c}
            else:
                v = beta * f["v"] + (1 - beta) * g2
                vhat = v
                nf = {"v": v}
            step = g32 * jax.lax.rsqrt(vhat + eps)
            step = step + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype), nf

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_f = tdef.flatten_up_to(state["f"])
        out = [upd(p, g, f) for p, g, f in zip(flat_p, flat_g, flat_f)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_f = tdef.unflatten([o[1] for o in out])
        return new_p, {"f": new_f, "count": count}

    return Optimizer(init, update, state_bytes_per_param=0.1)


def for_config(cfg, *, peak_lr=3e-4, warmup=100, total=10000) -> Optimizer:
    """Memory-aware default: Adafactor for >=200B-parameter archs."""
    from ..models.model import param_count
    lr = warmup_cosine(peak_lr, warmup, total)
    if param_count(cfg) >= 2e11:
        return adafactor(lr)
    return adamw(lr)
