"""Batched serving loop: continuous batching over a KV-cache decode step.

Requests arrive with prompts of varying length; the scheduler packs up
to ``max_batch`` active sequences, prefills new arrivals into free
slots, and runs one fused decode step per tick for all active slots.
Finished sequences (EOS or length budget) free their slot immediately —
the slot-level continuous batching that production LM servers use.

A request may additionally carry an image (``Request.pixels``, logical
C x H x W).  When the loop is constructed with a :class:`~repro.serving.
server.PlanServer`, the image is run through the server's
PBQP-selected conv tower at admission time — bucket lookup, cached plan,
cached executable — and the resulting feature vector is quantized into
``image_tokens`` pseudo-tokens prepended to the prompt.  That is the
bridge between the paper's primitive-selection machinery and the LM
serving path: vision preprocessing rides the plan cache, so a hot bucket
costs one executable call, not a PBQP solve + XLA compile.

Admission is *micro-batched*: every image admitted in the same tick is
enqueued on the server's admission queue and one ``flush()`` coalesces
all pending same-bucket images into a single batched tower invocation
(``PlanServer.infer_batch``) — N images admitted together cost one
executable call, not N.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import (
    ModelRuntime, ShardingPlan, decode_step, init_cache, prefill,
)

__all__ = ["Request", "ServeLoop"]


@dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (T,) int32
    max_new_tokens: int = 16
    eos_id: int = -1             # -1: never
    #: optional image (C, H, W) handled by the loop's PlanServer
    pixels: Optional[np.ndarray] = None
    # outputs
    tokens: List[int] = field(default_factory=list)
    done: bool = False
    latency_s: float = 0.0


class ServeLoop:
    def __init__(self, cfg, params, *, max_batch: int = 4,
                 max_seq: int = 128, plan: Optional[ShardingPlan] = None,
                 rt: ModelRuntime = ModelRuntime(),
                 plan_server=None, image_tokens: int = 4):
        self.cfg = cfg
        self.params = params
        self.plan = plan or ShardingPlan(mesh=None)
        self.rt = rt
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.plan_server = plan_server
        self.image_tokens = image_tokens
        dtype = jax.tree.leaves(params)[0].dtype
        self.cache = init_cache(cfg, max_batch, max_seq, dtype)
        self.slot_req: List[Optional[Request]] = [None] * max_batch
        self.slot_pos = np.zeros(max_batch, np.int32)
        self.queue: List[Request] = []
        self._compile()

    def _compile(self):
        cfg, plan, rt = self.cfg, self.plan, self.rt

        def one_step(params, cache, tokens, positions):
            """Per-slot decode: positions differ per slot, so attention
            uses per-slot cache indices via vmap over the batch axis."""
            def single(p_cache, tok, pos):
                # re-insert the batch axis (position 1, after layers)
                c1 = jax.tree.map(lambda x: x[:, None], p_cache)
                logits, c1 = decode_step(cfg, params, c1, tok[None, None],
                                         pos, plan, rt)
                return logits[0, 0], jax.tree.map(lambda x: x[:, 0], c1)

            # move batch axis to front of each cache leaf for vmap
            cache_b = jax.tree.map(lambda x: jnp.moveaxis(x, 1, 0), cache)
            logits, cache_b = jax.vmap(single)(cache_b, tokens, positions)
            cache = jax.tree.map(lambda x: jnp.moveaxis(x, 0, 1), cache_b)
            return logits, cache

        self._step = jax.jit(one_step)

    # -----------------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _encode_pixels(self, req: Request, outs: Dict[str, np.ndarray]):
        """Vision-token bridge: conv-tower features -> prompt tokens.

        The tower's top activations are quantized by rank: the indices of
        the ``image_tokens`` largest features (mod vocab) become pseudo-
        tokens.  Deterministic per image, so a repeated image yields a
        repeated prefix — and the whole thing is one plan-cache lookup
        once the image's bucket is hot."""
        v = np.concatenate([np.asarray(o, np.float32).ravel()
                            for o in outs.values()])
        k = min(self.image_tokens, v.size)
        toks = (np.argsort(v)[-k:][::-1] % self.cfg.vocab).astype(np.int32)
        prompt = np.asarray(req.prompt, np.int32)
        # a prompt that fit before must still fit with the vision prefix:
        # drop the oldest text tokens, never the image tokens
        budget = self.max_seq - req.max_new_tokens - 1 - k
        if budget < len(prompt):
            prompt = prompt[len(prompt) - max(budget, 0):]
        req.prompt = np.concatenate([toks, prompt])
        req.pixels = None

    def _admit(self):
        free = [s for s in range(self.max_batch)
                if self.slot_req[s] is None]
        admitted = []
        for slot in free:
            if not self.queue:
                break
            req = self.queue.pop(0)
            req._t0 = time.perf_counter()
            admitted.append((slot, req))
        if not admitted:
            return
        # Micro-batch the tick's vision work: enqueue every admitted
        # image, then one flush -> all same-bucket images share ONE
        # batched tower invocation instead of one call each.  The
        # admit span parents that flush (and its queue_wait/execute
        # children) to this admission tick in the trace.
        vision: Dict[int, Any] = {}
        if self.plan_server is not None:
            from ..obs.trace import get_tracer
            with get_tracer().span("admit", requests=len(admitted)):
                for slot, req in admitted:
                    if req.pixels is not None:
                        vision[slot] = self.plan_server.enqueue(req.pixels)
                if vision:
                    self.plan_server.flush()
        for slot, req in admitted:
            if slot in vision:
                self._encode_pixels(req, vision[slot].result())
            t = len(req.prompt)
            logits, cache1 = prefill(
                self.cfg, self.params,
                {"tokens": jnp.asarray(req.prompt[None])},
                self.plan, self.rt, max_seq=self.max_seq)
            # write the prefilled cache into this slot
            def put(full, new, slot=slot):
                return full.at[:, slot:slot + 1].set(
                    new.astype(full.dtype))
            self.cache = jax.tree.map(put, self.cache, cache1)
            nxt = int(jnp.argmax(logits[0, -1]))
            req.tokens.append(nxt)
            self.slot_req[slot] = req
            self.slot_pos[slot] = t

    def _tick(self):
        tokens = np.zeros(self.max_batch, np.int32)
        for s, req in enumerate(self.slot_req):
            if req is not None:
                tokens[s] = req.tokens[-1]
        logits, self.cache = self._step(
            self.params, self.cache, jnp.asarray(tokens),
            jnp.asarray(self.slot_pos))
        logits = np.asarray(logits)
        for s, req in enumerate(self.slot_req):
            if req is None:
                continue
            nxt = int(np.argmax(logits[s]))
            req.tokens.append(nxt)
            self.slot_pos[s] += 1
            if (len(req.tokens) >= req.max_new_tokens or
                    nxt == req.eos_id or
                    self.slot_pos[s] >= self.max_seq - 1):
                req.done = True
                req.latency_s = time.perf_counter() - req._t0
                self.slot_req[s] = None

    def run(self, requests: List[Request], max_ticks: int = 10_000
            ) -> List[Request]:
        for r in requests:
            self.submit(r)
        ticks = 0
        while (self.queue or any(self.slot_req)) and ticks < max_ticks:
            self._admit()
            self._tick()
            ticks += 1
        return requests
