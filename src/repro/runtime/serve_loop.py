"""Batched serving loop: continuous batching over a KV-cache decode step.

Requests arrive with prompts of varying length; the scheduler packs up
to ``max_batch`` active sequences, prefills new arrivals into free
slots, and runs one fused decode step per tick for all active slots.
Finished sequences (EOS or length budget) free their slot immediately —
the slot-level continuous batching that production LM servers use.

A request may additionally carry an image (``Request.pixels``, logical
C x H x W).  When the loop is constructed with a :class:`~repro.serving.
server.PlanServer`, the image is run through the server's
PBQP-selected conv tower at admission time — bucket lookup, cached plan,
cached executable — and the resulting feature vector is quantized into
``image_tokens`` pseudo-tokens prepended to the prompt.  That is the
bridge between the paper's primitive-selection machinery and the LM
serving path: vision preprocessing rides the plan cache, so a hot bucket
costs one executable call, not a PBQP solve + XLA compile.

Admission rides the *continuous-batching* scheduler
(:class:`repro.serving.scheduler.ContinuousScheduler`): every admitted
image is submitted as an individual request (optionally carrying the
loop's SLO deadline) and the scheduler coalesces co-batchable images
into in-flight bucket groups — same-tick same-bucket images still share
ONE batched tower invocation (the scheduler's batching window sees them
arrive together), but images can now also coalesce *across* ticks, a
partial batch launches early when a deadline's slack runs out, and the
worker pool resizes under load (docs/serving.md).

Requests may carry an ``arrival_s`` offset, which :meth:`ServeLoop.run`
honours as an *open-loop* arrival process: a request is invisible to
admission until its arrival time passes, so offered load is independent
of service rate — exactly how the load benchmark
(benchmarks/bench_load.py) drives the serving stack.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import (
    ModelRuntime, ShardingPlan, decode_step, init_cache, prefill,
)

__all__ = ["Request", "ServeLoop"]


@dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (T,) int32
    max_new_tokens: int = 16
    eos_id: int = -1             # -1: never
    #: optional image (C, H, W) handled by the loop's PlanServer
    pixels: Optional[np.ndarray] = None
    #: open-loop arrival offset (seconds from run() start); the loop
    #: does not see the request before this
    arrival_s: float = 0.0
    # outputs
    tokens: List[int] = field(default_factory=list)
    done: bool = False
    latency_s: float = 0.0
    #: submit -> admission wait (queueing the loop itself induced)
    wait_s: float = 0.0


class ServeLoop:
    def __init__(self, cfg, params, *, max_batch: int = 4,
                 max_seq: int = 128, plan: Optional[ShardingPlan] = None,
                 rt: ModelRuntime = ModelRuntime(),
                 plan_server=None, image_tokens: int = 4,
                 scheduler=None, slo_s: Optional[float] = None,
                 elastic=None):
        self.cfg = cfg
        self.params = params
        self.plan = plan or ShardingPlan(mesh=None)
        self.rt = rt
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.plan_server = plan_server
        self.image_tokens = image_tokens
        #: vision SLO handed to every scheduler submission (None: no
        #: deadline; requests launch on the full/window triggers only)
        self.slo_s = slo_s
        self.scheduler = scheduler
        self._owns_scheduler = False
        if scheduler is None and plan_server is not None:
            # lazy import keeps runtime importable without the serving
            # package's optional deps, mirroring the plan_server param
            from ..serving.scheduler import ContinuousScheduler
            self.scheduler = ContinuousScheduler(
                plan_server, slo_s=slo_s, elastic=elastic)
            self._owns_scheduler = True
        dtype = jax.tree.leaves(params)[0].dtype
        self.cache = init_cache(cfg, max_batch, max_seq, dtype)
        self.slot_req: List[Optional[Request]] = [None] * max_batch
        self.slot_pos = np.zeros(max_batch, np.int32)
        self.queue: List[Request] = []
        self._compile()

    def _compile(self):
        cfg, plan, rt = self.cfg, self.plan, self.rt

        def one_step(params, cache, tokens, positions):
            """Per-slot decode: positions differ per slot, so attention
            uses per-slot cache indices via vmap over the batch axis."""
            def single(p_cache, tok, pos):
                # re-insert the batch axis (position 1, after layers)
                c1 = jax.tree.map(lambda x: x[:, None], p_cache)
                logits, c1 = decode_step(cfg, params, c1, tok[None, None],
                                         pos, plan, rt)
                return logits[0, 0], jax.tree.map(lambda x: x[:, 0], c1)

            # move batch axis to front of each cache leaf for vmap
            cache_b = jax.tree.map(lambda x: jnp.moveaxis(x, 1, 0), cache)
            logits, cache_b = jax.vmap(single)(cache_b, tokens, positions)
            cache = jax.tree.map(lambda x: jnp.moveaxis(x, 0, 1), cache_b)
            return logits, cache

        self._step = jax.jit(one_step)

    # -----------------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _encode_pixels(self, req: Request, outs: Dict[str, np.ndarray]):
        """Vision-token bridge: conv-tower features -> prompt tokens.

        The tower's top activations are quantized by rank: the indices of
        the ``image_tokens`` largest features (mod vocab) become pseudo-
        tokens.  Deterministic per image, so a repeated image yields a
        repeated prefix — and the whole thing is one plan-cache lookup
        once the image's bucket is hot."""
        v = np.concatenate([np.asarray(o, np.float32).ravel()
                            for o in outs.values()])
        k = min(self.image_tokens, v.size)
        toks = (np.argsort(v)[-k:][::-1] % self.cfg.vocab).astype(np.int32)
        prompt = np.asarray(req.prompt, np.int32)
        # a prompt that fit before must still fit with the vision prefix:
        # drop the oldest text tokens, never the image tokens
        budget = self.max_seq - req.max_new_tokens - 1 - k
        if budget < len(prompt):
            prompt = prompt[len(prompt) - max(budget, 0):]
        req.prompt = np.concatenate([toks, prompt])
        req.pixels = None

    def _admit(self):
        free = [s for s in range(self.max_batch)
                if self.slot_req[s] is None]
        admitted = []
        for slot in free:
            if not self.queue:
                break
            req = self.queue.pop(0)
            req._t0 = time.perf_counter()
            req.wait_s = req._t0 - getattr(req, "_t_arrived", req._t0)
            admitted.append((slot, req))
        if not admitted:
            return
        # Continuous-batch the tick's vision work: every admitted image
        # is submitted to the scheduler, which coalesces co-batchable
        # requests into in-flight bucket groups — same-tick same-bucket
        # images arrive within its batching window and still share ONE
        # batched tower invocation, but coalescing is no longer bounded
        # by the tick barrier, and SLO-carrying requests can force a
        # partial batch out early.  The admit span ties the tick's
        # submissions together in the trace (execution spans live on
        # the scheduler's worker threads).
        vision: Dict[int, Any] = {}
        if self.scheduler is not None:
            from concurrent.futures import Future as _Future

            from ..obs.trace import get_tracer
            from ..reliability import ShedError
            with get_tracer().span("admit", requests=len(admitted)):
                for slot, req in admitted:
                    if req.pixels is not None:
                        try:
                            vision[slot] = self.scheduler.submit(
                                req.pixels)
                        except ShedError:
                            # shed at admission (scheduler built with
                            # shed=True and the modeled backlog makes
                            # the SLO unmeetable): this loop cannot
                            # drop a request, so the typed "no" routes
                            # the image around the overloaded batcher
                            # onto the direct unbatched path instead
                            fut: _Future = _Future()
                            fut.set_result(
                                self.plan_server.infer(req.pixels))
                            vision[slot] = fut
        for slot, req in admitted:
            if slot in vision:
                self._encode_pixels(req, vision[slot].result())
            t = len(req.prompt)
            logits, cache1 = prefill(
                self.cfg, self.params,
                {"tokens": jnp.asarray(req.prompt[None])},
                self.plan, self.rt, max_seq=self.max_seq)
            # write the prefilled cache into this slot
            def put(full, new, slot=slot):
                return full.at[:, slot:slot + 1].set(
                    new.astype(full.dtype))
            self.cache = jax.tree.map(put, self.cache, cache1)
            nxt = int(jnp.argmax(logits[0, -1]))
            req.tokens.append(nxt)
            self.slot_req[slot] = req
            self.slot_pos[slot] = t

    def _tick(self):
        tokens = np.zeros(self.max_batch, np.int32)
        for s, req in enumerate(self.slot_req):
            if req is not None:
                tokens[s] = req.tokens[-1]
        logits, self.cache = self._step(
            self.params, self.cache, jnp.asarray(tokens),
            jnp.asarray(self.slot_pos))
        logits = np.asarray(logits)
        for s, req in enumerate(self.slot_req):
            if req is None:
                continue
            nxt = int(np.argmax(logits[s]))
            req.tokens.append(nxt)
            self.slot_pos[s] += 1
            if (len(req.tokens) >= req.max_new_tokens or
                    nxt == req.eos_id or
                    self.slot_pos[s] >= self.max_seq - 1):
                req.done = True
                req.latency_s = time.perf_counter() - req._t0
                self.slot_req[s] = None

    def run(self, requests: List[Request], max_ticks: int = 10_000
            ) -> List[Request]:
        """Serve ``requests`` to completion (open-loop arrivals).

        Requests become visible to admission only once their
        ``arrival_s`` offset has elapsed — an open-loop arrival
        process, so offered load does not slow down when the loop is
        busy.  The default ``arrival_s=0`` recovers the closed-loop
        behaviour (everything queued up front).
        """
        pending = sorted(requests, key=lambda r: r.arrival_s)
        t0 = time.perf_counter()
        ticks = 0
        i = 0
        while ((i < len(pending) or self.queue or any(self.slot_req))
               and ticks < max_ticks):
            now = time.perf_counter() - t0
            while i < len(pending) and pending[i].arrival_s <= now:
                req = pending[i]
                req._t_arrived = time.perf_counter()
                self.submit(req)
                i += 1
            if not self.queue and not any(self.slot_req):
                # idle until the next arrival; sleeping (not ticking)
                # keeps the wait off the tick budget
                time.sleep(max(min(pending[i].arrival_s - now, 0.005),
                               0.0))
                continue
            self._admit()
            self._tick()
            ticks += 1
        return requests

    def close(self) -> None:
        """Release the scheduler if this loop created it (drains any
        queued vision work first)."""
        if self._owns_scheduler and self.scheduler is not None:
            self.scheduler.close()
            self.scheduler = None
