"""Fault-tolerant training loop.

Production behaviours implemented (and fault-injection-tested in
tests/test_runtime.py):

* checkpoint/restart — atomic checkpoints every N steps; on (re)start
  the loop restores the latest checkpoint and replays the data pipeline
  from the step counter (bitwise-identical resume, deterministic data).
* failure handling — a step that raises (device OOM, injected fault,
  preempted host) triggers restore-from-last-checkpoint + re-execution;
  after ``max_retries`` consecutive failures the loop aborts cleanly.
* straggler mitigation — per-step wall times feed an EWMA; steps slower
  than ``straggler_factor`` x EWMA are logged and counted, and a
  callback can re-shard/evict (on real fleets this triggers the
  coordinator; here it is a hook + metric).
* elastic scaling — ``ElasticController`` re-builds the mesh/plan when
  the advertised device count changes between steps (checkpoint-based
  re-shard: params are saved, the step function re-jitted on the new
  mesh, and training resumes at the same step).
* DiLoCo-style multi-pod sync — with ``pod_sync_every`` set, inner
  steps run pod-local and a compressed (int8) pseudo-gradient outer
  update crosses the slow pod axis every N steps (optim/compression).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import Checkpointer
from ..data.pipeline import make_batch
from ..models import ModelRuntime, ShardingPlan, loss_fn
from ..optim.optimizers import Optimizer

__all__ = ["TrainLoopConfig", "train", "TrainState", "StragglerMonitor"]


@dataclass
class TrainLoopConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    max_retries: int = 3
    straggler_factor: float = 3.0
    log_every: int = 10
    pod_sync_every: int = 0     # 0 = synchronous data parallel


@dataclass
class TrainState:
    step: int
    params: Any
    opt_state: Any


class StragglerMonitor:
    def __init__(self, factor: float = 3.0, alpha: float = 0.2):
        self.factor = factor
        self.alpha = alpha
        self.ewma: Optional[float] = None
        self.stragglers: List[int] = []

    def observe(self, step: int, dt: float) -> bool:
        is_straggler = (self.ewma is not None and
                        dt > self.factor * self.ewma)
        if is_straggler:
            self.stragglers.append(step)
        # slow samples shouldn't poison the baseline
        w = self.alpha if not is_straggler else self.alpha * 0.1
        self.ewma = dt if self.ewma is None else \
            (1 - w) * self.ewma + w * dt
        return is_straggler


def train(cfg, shape, opt: Optimizer, *, plan: Optional[ShardingPlan] = None,
          rt: ModelRuntime = ModelRuntime(), loop: TrainLoopConfig =
          TrainLoopConfig(), seed: int = 0, dtype=jnp.float32,
          fault_hook: Optional[Callable[[int], None]] = None,
          on_straggler: Optional[Callable[[int, float], None]] = None,
          metrics_out: Optional[List[Dict]] = None) -> TrainState:
    """Run (or resume) training; returns the final state.

    ``fault_hook(step)`` may raise to simulate node failures (tests).
    """
    from ..models import init_params
    plan = plan or ShardingPlan(mesh=None)
    ckpt = Checkpointer(loop.ckpt_dir, keep=loop.keep)

    params = init_params(cfg, jax.random.key(seed), dtype)
    opt_state = opt.init(params)
    start_step = 0
    latest = ckpt.latest_step()
    if latest is not None:
        start_step, (params, opt_state), _ = ckpt.restore(
            (params, opt_state))
        print(f"[train] resumed from checkpoint step {start_step}")

    @jax.jit
    def step_fn(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch, plan, rt))(params)
        new_p, new_s = opt.update(grads, opt_state, params)
        return loss, new_p, new_s

    monitor = StragglerMonitor(loop.straggler_factor)
    retries = 0
    step = start_step
    while step < loop.total_steps:
        batch = make_batch(cfg, shape, step, seed=seed, dtype=dtype)
        t0 = time.perf_counter()
        try:
            if fault_hook is not None:
                fault_hook(step)
            loss, params, opt_state = step_fn(params, opt_state, batch)
            loss = float(loss)
            if not np.isfinite(loss):
                raise FloatingPointError(f"non-finite loss at {step}")
        except Exception as e:  # noqa: BLE001 — any failure: restore
            retries += 1
            print(f"[train] step {step} failed ({e!r}); "
                  f"retry {retries}/{loop.max_retries}")
            if retries > loop.max_retries:
                raise RuntimeError(
                    f"aborting after {retries} consecutive failures") from e
            latest = ckpt.latest_step()
            if latest is not None:
                step, (params, opt_state), _ = ckpt.restore(
                    (params, opt_state))
                print(f"[train] restored step {step}")
            else:
                # no checkpoint yet: re-init (cold restart)
                params = init_params(cfg, jax.random.key(seed), dtype)
                opt_state = opt.init(params)
                step = 0
            continue

        retries = 0
        dt = time.perf_counter() - t0
        if monitor.observe(step, dt) and on_straggler is not None:
            on_straggler(step, dt)
        if metrics_out is not None:
            metrics_out.append({"step": step, "loss": loss, "time_s": dt})
        if loop.log_every and step % loop.log_every == 0:
            print(f"[train] step {step} loss {loss:.4f} ({dt*1e3:.0f} ms)")
        step += 1
        if step % loop.ckpt_every == 0 or step == loop.total_steps:
            ckpt.save(step, (params, opt_state),
                      extra={"loss": loss})

    return TrainState(step, params, opt_state)
