"""GPipe-style pipeline parallelism over a mesh axis via shard_map.

The model's superblocks are split into S stages along the ``stage``
mesh axis; microbatches stream through with collective_permute boundary
transfers.  The schedule is the classic GPipe fill-drain loop expressed
as a ``lax.fori_loop`` over T = n_micro + S - 1 ticks — every tick each
stage computes one microbatch (or idles in the ramp) and the boundary
activations rotate by one stage.

At 1000+ node scale this maps pipeline stages onto the slow inter-pod
axis (stage boundary traffic is tiny: one (micro_b, t, d) tensor per
tick) while TP/DP stay on fast intra-pod ICI — the standard production
topology.  Used by examples/pipeline_parallel.py and
tests/test_distributed.py (4-device CPU mesh).
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

__all__ = ["pipeline_apply", "pipeline_ticks"]


def pipeline_ticks(s: int, n_micro: int) -> int:
    """Fill-drain tick count of the GPipe schedule: ``n_micro + s - 1``
    (the bubble term the solver's pp node costs scale by — see
    ``selection.PlacementPricing``)."""
    if s < 1 or n_micro < 1:
        raise ValueError(f"need s >= 1 and n_micro >= 1, got "
                         f"s={s} n_micro={n_micro}")
    return n_micro + s - 1


def pipeline_apply(mesh: Mesh, stage_fn: Callable, stage_params,
                   x, *, n_micro: int, axis: str = "stage"):
    """Run ``y = stage_S(...stage_1(x))`` pipelined over ``axis``.

    stage_fn(params_for_stage, x_micro) -> y_micro (same shape).
    stage_params: pytree with a leading stage axis (sharded over axis).
    x: (n_micro, micro_b, ...) microbatched input (replicated).
    """
    s = mesh.shape[axis]
    t_total = pipeline_ticks(s, n_micro)

    def per_stage(params, xs):
        stage = jax.lax.axis_index(axis)
        params = jax.tree.map(lambda p: p[0], params)  # local stage slice
        buf = jnp.zeros_like(xs)     # output accumulator (n_micro, ...)
        carry = jnp.zeros_like(xs[0])

        def tick(t, state):
            carry, buf = state
            m = t - stage            # microbatch index at this stage
            # stage 0 reads its input from xs; others from the carry
            inp = jnp.where(stage == 0,
                            xs[jnp.clip(m, 0, n_micro - 1)], carry)
            active = jnp.logical_and(m >= 0, m < n_micro)
            out = stage_fn(params, inp)
            out = jnp.where(active, out, carry)
            # last stage banks its result
            buf = jax.lax.cond(
                jnp.logical_and(active, stage == s - 1),
                lambda b: b.at[jnp.clip(m, 0, n_micro - 1)].set(out),
                lambda b: b, buf)
            # rotate boundary activations forward one stage
            nxt = jax.lax.ppermute(
                out, axis, [(i, (i + 1) % s) for i in range(s)])
            return (nxt, buf)

        _, buf = jax.lax.fori_loop(0, t_total, tick, (carry, buf))
        # only the last stage holds real outputs; broadcast to all
        buf = jax.lax.psum(
            jnp.where(stage == s - 1, buf, jnp.zeros_like(buf)), axis)
        return buf

    fn = shard_map(
        per_stage, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        check_rep=False)
    return fn(stage_params, x)
