from .elastic import ElasticController
from .pipeline_parallel import pipeline_apply
from .serve_loop import Request, ServeLoop
from .train_loop import (
    StragglerMonitor, TrainLoopConfig, TrainState, train,
)

__all__ = [
    "ElasticController", "pipeline_apply", "Request", "ServeLoop",
    "StragglerMonitor", "TrainLoopConfig", "TrainState", "train",
]
