"""Elastic scaling: re-mesh and resume when the fleet size changes.

On a real cluster the coordinator advertises the healthy device set;
when it changes (node failure, capacity grant) the controller
checkpoints, rebuilds the mesh + sharding rules for the new shape, and
re-jits.  Parameters move via the checkpoint (host DRAM) path — the
standard preemption-safe resize.  Tested on CPU by shrinking a fake
device mesh (tests/test_distributed.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Optional, Tuple

import jax

from ..models.sharding import Rules, ShardingPlan

__all__ = ["ElasticController"]


@dataclass
class ElasticController:
    """Tracks the device pool; yields (mesh, plan) per generation."""

    make_mesh: Callable[[int], object]      # n_devices -> Mesh
    make_rules: Callable[[Dict[str, int]], Rules]
    generation: int = 0
    _last_n: Optional[int] = None

    def current(self) -> Tuple[object, ShardingPlan, bool]:
        """Returns (mesh, plan, changed)."""
        n = len(jax.devices())
        changed = self._last_n is not None and n != self._last_n
        if changed:
            self.generation += 1
        self._last_n = n
        mesh = self.make_mesh(n)
        shape = dict(zip(mesh.axis_names, mesh.devices.shape))
        rules = self.make_rules(shape).restrict(mesh.axis_names)
        return mesh, ShardingPlan(mesh=mesh, rules=rules), changed
