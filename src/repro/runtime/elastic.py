"""Elastic scaling: re-mesh on fleet changes, resize workers on load.

Two elasticity axes live here:

* **Device elasticity** — on a real cluster the coordinator advertises
  the healthy device set; when it changes (node failure, capacity
  grant) the controller checkpoints, rebuilds the mesh + sharding rules
  for the new shape, and re-jits.  Parameters move via the checkpoint
  (host DRAM) path — the standard preemption-safe resize.  Tested on
  CPU by shrinking a fake device mesh (tests/test_distributed.py).

* **Worker elasticity** — the serving side: the continuous-batching
  scheduler (:mod:`repro.serving.scheduler`) asks
  :meth:`ElasticController.desired_workers` for a concurrency target
  each dispatch round.  Backlog per worker above ``scale_up_backlog``
  grows the pool one worker at a time (immediately — queueing delay is
  what SLOs die of); sustained calm (``cooldown`` consecutive
  observations below ``scale_down_backlog``) shrinks it, so a burst
  does not flap the pool.  The scheduler applies the target to its
  launch slots and mirrors it into :meth:`~repro.serving.server.
  PlanServer.resize_workers` so prefetch parallelism tracks load too.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Optional, Tuple

from ..models.sharding import Rules, ShardingPlan

__all__ = ["ElasticController"]


@dataclass
class ElasticController:
    """Tracks the device pool and the serving worker pool.

    ``current()`` yields (mesh, plan, changed) per generation for the
    training path; ``desired_workers()`` is the serving-side policy.
    Both bump ``generation`` when they change the world, so callers can
    cheaply detect "something resized since I last looked".
    """

    make_mesh: Optional[Callable[[int], object]] = None  # n_devices -> Mesh
    make_rules: Optional[Callable[[Dict[str, int]], Rules]] = None
    generation: int = 0
    #: worker-pool bounds for :meth:`desired_workers`
    min_workers: int = 1
    max_workers: int = 4
    #: queued+inflight work per worker that triggers a scale-up
    scale_up_backlog: float = 2.0
    #: backlog per worker below which an observation counts as "calm"
    scale_down_backlog: float = 0.5
    #: consecutive calm observations required before scaling down
    cooldown: int = 3
    _last_n: Optional[int] = None
    _workers: int = 0
    _calm: int = 0

    def __post_init__(self) -> None:
        if self.min_workers < 1 or self.max_workers < self.min_workers:
            raise ValueError(
                f"bad worker bounds [{self.min_workers}, "
                f"{self.max_workers}]")
        if not self._workers:
            self._workers = self.min_workers

    # -----------------------------------------------------------------
    # device elasticity (training / mesh path)
    # -----------------------------------------------------------------
    def current(self) -> Tuple[object, ShardingPlan, bool]:
        """Returns (mesh, plan, changed)."""
        if self.make_mesh is None or self.make_rules is None:
            raise RuntimeError(
                "ElasticController.current() needs make_mesh/make_rules "
                "(this controller was built for worker elasticity only)")
        import jax

        n = len(jax.devices())
        changed = self._last_n is not None and n != self._last_n
        if changed:
            self.generation += 1
        self._last_n = n
        mesh = self.make_mesh(n)
        shape = dict(zip(mesh.axis_names, mesh.devices.shape))
        rules = self.make_rules(shape).restrict(mesh.axis_names)
        return mesh, ShardingPlan(mesh=mesh, rules=rules), changed

    # -----------------------------------------------------------------
    # worker elasticity (serving path)
    # -----------------------------------------------------------------
    @property
    def workers(self) -> int:
        """Current worker-pool target (between min/max bounds)."""
        return self._workers

    def desired_workers(self, queued: int, inflight: int) -> int:
        """One observation of load -> the new worker-pool target.

        ``queued`` is work waiting to be launched, ``inflight`` work
        already running.  Scale-up is immediate (one worker per call —
        the caller polls every dispatch round, so a sustained burst
        ramps to ``max_workers`` in a few rounds); scale-down waits for
        ``cooldown`` consecutive calm observations so a gap between
        bursts does not thrash the pool.
        """
        pressure = (queued + inflight) / max(self._workers, 1)
        if pressure > self.scale_up_backlog:
            self._calm = 0
            if self._workers < self.max_workers:
                self._workers += 1
                self.generation += 1
        elif pressure < self.scale_down_backlog:
            self._calm += 1
            if self._calm >= self.cooldown and \
                    self._workers > self.min_workers:
                self._workers -= 1
                self.generation += 1
                self._calm = 0
        else:
            self._calm = 0
        return self._workers
