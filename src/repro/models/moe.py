"""Mixture-of-Experts FFN with sort-based token dispatch.

TPU adaptation: instead of the GShard (B,T,E,C) one-hot dispatch einsum
(whose dispatch tensor is enormous at kimi scale), tokens are sorted by
destination expert and gathered into a capacity-bounded (E, C, D)
buffer.  Under expert-parallel sharding (experts -> "model" axis) XLA
lowers the gather/scatter to the expert all-to-all; the buffer is
explicitly annotated so the partitioner keeps it expert-sharded.
Overflow tokens beyond capacity are dropped (standard capacity-factor
semantics); gates renormalise over the kept top-k.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from .sharding import PDef, ShardingPlan


def moe_defs(cfg) -> Dict[str, PDef]:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": PDef((d, e), ("d_model", "experts")),
        "w1": PDef((e, d, f), ("experts", "d_model", "d_ff")),
        "w3": PDef((e, d, f), ("experts", "d_model", "d_ff")),
        "w2": PDef((e, f, d), ("experts", "d_ff", "d_model")),
    }


def capacity(cfg, n_tokens: int) -> int:
    c = int(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts) + 1
    return max(8, -(-c // 8) * 8)  # round up to 8 for lane alignment


def moe_ffn(cfg, p, x, plan: ShardingPlan):
    """x: (B, T, D) -> (B, T, D)."""
    b, t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    n = b * t
    c = capacity(cfg, n)
    xf = x.reshape(n, d)

    logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32), p["router"]
                        .astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)          # (N, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # ---- sort-based dispatch ----
    pair_expert = expert_idx.reshape(-1)                     # (N*K,)
    order = jnp.argsort(pair_expert, stable=True)
    sorted_e = pair_expert[order]
    # rank of each pair within its expert segment
    seg_start = jnp.searchsorted(sorted_e, sorted_e, side="left")
    rank = jnp.arange(n * k) - seg_start
    keep = rank < c
    dest = jnp.where(keep, sorted_e * c + rank, e * c)        # OOB -> drop
    src_token = order // k
    src_gate = gate_vals.reshape(-1)[order]

    buf = jnp.zeros((e * c, d), x.dtype).at[dest].set(
        xf[src_token], mode="drop")
    buf = plan.constrain(buf.reshape(e, c, d), "experts", None, "d_model")

    # ---- expert computation (per-expert gated FFN) ----
    h = jnp.einsum("ecd,edf->ecf", buf, p["w1"])
    h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", buf, p["w3"])
    h = plan.constrain(h, "experts", None, "d_ff")
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w2"])
    out_buf = plan.constrain(out_buf, "experts", None, "d_model")
    out_flat = out_buf.reshape(e * c, d)

    # ---- combine ----
    contrib = jnp.where(keep[:, None],
                        out_flat[jnp.minimum(dest, e * c - 1)], 0.0)
    y = jnp.zeros((n, d), x.dtype).at[src_token].add(
        contrib * src_gate[:, None].astype(x.dtype))
    y = y.reshape(b, t, d)
    return plan.constrain(y, "batch", "seq", "d_model")


def _local_dispatch(cfg, p, xf, c):
    """Shared sort-based dispatch on a device-local token slab.

    Returns (buf (E, C, D) dispatched tokens, combine metadata)."""
    n, d = xf.shape
    e, k = cfg.n_experts, cfg.top_k
    logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
    pair_expert = expert_idx.reshape(-1)
    order = jnp.argsort(pair_expert, stable=True)
    sorted_e = pair_expert[order]
    rank = jnp.arange(n * k) - jnp.searchsorted(sorted_e, sorted_e,
                                                side="left")
    keep = rank < c
    dest = jnp.where(keep, sorted_e * c + rank, e * c)
    src_token = order // k
    src_gate = gate_vals.reshape(-1)[order]
    buf = jnp.zeros((e * c, d), xf.dtype).at[dest].set(
        xf[src_token], mode="drop")
    return buf.reshape(e, c, d), (keep, dest, src_token, src_gate)


def _local_combine(cfg, out_flat, meta, n, d, dtype):
    e, c = cfg.n_experts, out_flat.shape[0] // cfg.n_experts
    keep, dest, src_token, src_gate = meta
    contrib = jnp.where(keep[:, None],
                        out_flat[jnp.minimum(dest, e * c - 1)], 0.0)
    y = jnp.zeros((n, d), dtype).at[src_token].add(
        contrib * src_gate[:, None].astype(dtype))
    return y


def moe_ffn_alltoall(cfg, p, x, plan: ShardingPlan):
    """Expert-parallel MoE with explicit all-to-alls (shard_map).

    §Perf hillclimb for the kimi cell: the gather-based dispatch above
    makes the SPMD partitioner all-gather the token slab (hundreds of
    TB/step at kimi scale).  Here routing runs on a (batch x seq)-local
    slab per device; the only cross-device traffic is two all-to-alls of
    the capacity-bounded dispatch buffer — the textbook GShard EP
    schedule, sized top_k * tokens * d_model.

    Requires a mesh with a "model" axis; seq divisible by |model|.
    """
    mesh = plan.mesh
    b, t, d = x.shape
    e = cfg.n_experts
    tp = mesh.shape["model"]
    e_local = e // tp
    t_local = t // tp
    n_local = b * t_local
    c = capacity(cfg, n_local)
    # per (dest-shard, local-expert) capacity such that E*C splits evenly
    assert (e * c) % tp == 0

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def body(xl, router, w1, w3, w2):
        # xl: (b_local, t_local, d); experts weights local: (E_local,...)
        xf = xl.reshape(-1, d)
        buf, meta = _local_dispatch(
            cfg, {"router": router}, xf, c)          # (E, C, d)
        # group by destination shard and exchange
        buf = buf.reshape(tp, e_local * c, d)
        buf = jax.lax.all_to_all(buf, "model", split_axis=0,
                                 concat_axis=0, tiled=False)
        # buf: (tp source shards, e_local * c, d)
        buf = buf.reshape(tp, e_local, c, d)
        h = jnp.einsum("secd,edf->secf", buf, w1)
        h = jax.nn.silu(h) * jnp.einsum("secd,edf->secf", buf, w3)
        out = jnp.einsum("secf,efd->secd", h, w2)    # (tp, e_local, c, d)
        out = out.reshape(tp, e_local * c, d)
        out = jax.lax.all_to_all(out, "model", split_axis=0,
                                 concat_axis=0, tiled=False)
        out_flat = out.reshape(e * c, d)
        y = _local_combine(cfg, out_flat, meta, xf.shape[0], d, xl.dtype)
        return y.reshape(xl.shape)

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(data_axes or None, "model", None),
                  P(None, None), P("model", None, None),
                  P("model", None, None), P("model", None, None)),
        out_specs=P(data_axes or None, "model", None),
        check_rep=False)
    return fn(x, p["router"], p["w1"], p["w3"], p["w2"])


def aux_load_balance_loss(cfg, logits):
    """Switch-style load-balance auxiliary (returned by train paths)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    e = cfg.n_experts
    frac = jnp.mean(probs, axis=tuple(range(probs.ndim - 1)))
    top1 = jnp.argmax(probs, axis=-1)
    hard = jnp.mean(jax.nn.one_hot(top1, e, dtype=jnp.float32),
                    axis=tuple(range(probs.ndim - 1)))
    return e * jnp.sum(frac * hard)
