"""Mamba2 (SSD — state-space duality) block, chunked formulation.

The SSD recurrence per head h (scalar decay a_t = exp(A * dt_t)):

    S_t = a_t * S_{t-1} + dt_t * B_t (x) x_t         (N x P state)
    y_t = C_t . S_t + D * x_t

computed chunk-parallel (arXiv 2405.21060 §6): within a chunk of Q
tokens the output is an attention-like (Q x Q) masked matmul
("duality"); across chunks a short scan carries the (H, N, P) state.
The chunk loop is a ``lax.scan`` for training and a Python loop
(``unroll_chunks=True``) for the dry-run so the HLO exposes every
chunk's FLOPs to cost_analysis.

Decode is the O(1) recurrence on a carried state — this is why the SSM
archs run the long_500k shape that full attention cannot.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .sharding import PDef, ShardingPlan


def mamba_dims(cfg) -> Tuple[int, int, int, int]:
    d_inner = cfg.ssm_expand * cfg.d_model
    heads = d_inner // cfg.ssm_headdim
    return d_inner, heads, cfg.ssm_headdim, cfg.ssm_state


def mamba_defs(cfg) -> Dict[str, PDef]:
    d = cfg.d_model
    d_inner, h, p_, n = mamba_dims(cfg)
    conv_dim = d_inner + 2 * n
    return {
        # packed projection: [z (d_inner), x (d_inner), B (N), C (N), dt (H)]
        "in_proj": PDef((d, 2 * d_inner + 2 * n + h), ("d_model", "ssm_heads")),
        "conv_w": PDef((cfg.ssm_conv, conv_dim), (None, "ssm_heads")),
        "conv_b": PDef((conv_dim,), ("ssm_heads",), init="zeros"),
        "a_log": PDef((h,), ("ssm_heads",), init="ones"),
        "d_skip": PDef((h,), ("ssm_heads",), init="ones"),
        "dt_bias": PDef((h,), ("ssm_heads",), init="zeros"),
        "norm_w": PDef((d_inner,), ("ssm_heads",), init="ones"),
        "out_proj": PDef((d_inner, d), ("ssm_heads", "d_model")),
    }


def _split(cfg, proj):
    d_inner, h, p_, n = mamba_dims(cfg)
    z = proj[..., :d_inner]
    xbc = proj[..., d_inner:2 * d_inner + 2 * n]
    dt = proj[..., 2 * d_inner + 2 * n:]
    return z, xbc, dt


def _causal_conv(xbc, w, b, *, state=None):
    """Depthwise causal conv over time.  xbc: (B, T, C); w: (K, C).

    With ``state`` (B, K-1, C) given (decode), returns (y, new_state).
    """
    k = w.shape[0]
    if state is not None:
        window = jnp.concatenate([state, xbc], axis=1)  # (B, K-1+T, C)
        new_state = window[:, -(k - 1):, :]
        y = sum(window[:, i:i + xbc.shape[1], :] * w[i]
                for i in range(k))
        return jax.nn.silu(y + b), new_state
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    y = sum(pad[:, i:i + xbc.shape[1], :] * w[i] for i in range(k))
    return jax.nn.silu(y + b), None


def _gated_rmsnorm(y, z, w, eps=1e-5):
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1,
                   keepdims=True)
    return (y.astype(jnp.float32) * jax.lax.rsqrt(var + eps)).astype(
        y.dtype) * w


def ssd_chunked(xh, dt, a_neg, B_, C_, *, chunk: int, unroll: bool,
                init_state=None):
    """Chunk-parallel SSD.

    xh: (B, T, H, P); dt: (B, T, H); a_neg: (H,) (negative decay rates);
    B_, C_: (B, T, N).  Returns (y (B,T,H,P), final_state (B,H,N,P)).
    """
    b, t, h, p_ = xh.shape
    n = B_.shape[-1]
    q = min(chunk, t)
    t_orig = t
    if t % q:
        # zero-pad: dt=0 => decay exp(0)=1 and zero increment, so padded
        # positions are exactly neutral for the state
        pad = q - t % q
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0)))
        t = t + pad
    nc = t // q

    # per-token log decay  l_t = a_neg * dt_t  (<= 0)
    ldec = a_neg[None, None, :] * dt                     # (B, T, H)
    xc = xh.reshape(b, nc, q, h, p_)
    dtc = dt.reshape(b, nc, q, h)
    lc = ldec.reshape(b, nc, q, h)
    Bc = B_.reshape(b, nc, q, n)
    Cc = C_.reshape(b, nc, q, n)
    cum = jnp.cumsum(lc, axis=2)                         # (B, nc, Q, H)

    def chunk_out(ci, state):
        """state: (B, H, N, P) entering chunk ci."""
        cumi = cum[:, ci]                                # (B, Q, H)
        li = lc[:, ci]
        # intra-chunk duality: M[t,s] = (C_t.B_s) exp(cum_t - cum_s) dt_s
        seg = cumi[:, :, None, :] - cumi[:, None, :, :]  # (B, Q, Q, H)
        tri = jnp.tril(jnp.ones((q, q), bool))[None, :, :, None]
        # mask BEFORE exp: exp of the (positive) upper triangle overflows
        # and would poison gradients through the where
        gamma = jnp.exp(jnp.where(tri, seg, -1e30))
        cb = jnp.einsum("bqn,bsn->bqs", Cc[:, ci].astype(jnp.float32),
                        Bc[:, ci].astype(jnp.float32))
        m = cb[:, :, :, None] * gamma * dtc[:, ci][:, None, :, :]
        y_intra = jnp.einsum("bqsh,bshp->bqhp", m,
                             xc[:, ci].astype(jnp.float32))
        # inter-chunk: contribution of entering state
        y_inter = jnp.einsum("bqn,bhnp,bqh->bqhp",
                             Cc[:, ci].astype(jnp.float32), state,
                             jnp.exp(cumi))
        # chunk state update
        decay_to_end = jnp.exp(cumi[:, -1:, :] - cumi)   # (B, Q, H)
        s_new = jnp.einsum("bsn,bshp,bsh,bsh->bhnp",
                           Bc[:, ci].astype(jnp.float32),
                           xc[:, ci].astype(jnp.float32),
                           dtc[:, ci], decay_to_end)
        state = state * jnp.exp(cumi[:, -1])[..., None, None] + s_new
        return (y_intra + y_inter).astype(xh.dtype), state

    state = init_state if init_state is not None else \
        jnp.zeros((b, h, n, p_), jnp.float32)
    if unroll:
        ys = []
        for ci in range(nc):
            y, state = chunk_out(ci, state)
            ys.append(y)
        y = jnp.stack(ys, axis=1)
    else:
        def body(st, ci):
            y, st = chunk_out(ci, st)
            return st, y
        state, y = jax.lax.scan(body, state, jnp.arange(nc))
        y = jnp.swapaxes(y, 0, 1)                        # (B, nc, Q, H, P)
    return y.reshape(b, t, h, p_)[:, :t_orig], state


def mamba_block(cfg, p, x, plan: ShardingPlan, *, chunk: int = 256,
                unroll_chunks: bool = False, ssm_state=None,
                conv_state=None, decode: bool = False):
    """x: (B, T, D) -> (B, T, D).  decode=True carries (ssm, conv) state."""
    d_inner, h, p_, n = mamba_dims(cfg)
    proj = jnp.einsum("btd,de->bte", x, p["in_proj"])
    z, xbc, dt = _split(cfg, proj)
    dt = jax.nn.softplus(dt + p["dt_bias"])              # (B, T, H)
    raw_xbc = xbc
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"],
                                 state=conv_state if decode else None)
    if not decode:
        # prefill/train: the conv state is the last K-1 raw inputs
        kc = cfg.ssm_conv - 1
        if raw_xbc.shape[1] >= kc:
            new_conv = raw_xbc[:, -kc:, :]
        else:
            new_conv = jnp.pad(raw_xbc,
                               ((0, 0), (kc - raw_xbc.shape[1], 0), (0, 0)))
    xin = xbc[..., :d_inner]
    B_ = xbc[..., d_inner:d_inner + n]
    C_ = xbc[..., d_inner + n:]
    xh = xin.reshape(*xin.shape[:-1], h, p_)
    xh = plan.constrain(xh, "batch", "seq", "ssm_heads", None)
    a_neg = -jnp.exp(p["a_log"].astype(jnp.float32))

    if decode:
        # single-token recurrence (T == 1)
        dt1 = dt[:, 0]                                   # (B, H)
        decay = jnp.exp(a_neg[None] * dt1)               # (B, H)
        upd = jnp.einsum("bn,bhp,bh->bhnp", B_[:, 0].astype(jnp.float32),
                         xh[:, 0].astype(jnp.float32), dt1)
        state = ssm_state * decay[..., None, None] + upd
        y = jnp.einsum("bn,bhnp->bhp", C_[:, 0].astype(jnp.float32),
                       state)[:, None].astype(x.dtype)
        new_state = state
        y = y.reshape(x.shape[0], 1, h, p_)
    else:
        y, new_state = ssd_chunked(xh, dt, a_neg, B_, C_, chunk=chunk,
                                   unroll=unroll_chunks,
                                   init_state=ssm_state)
    y = y + xh * p["d_skip"][None, None, :, None]
    y = y.reshape(*x.shape[:-1], d_inner)
    y = _gated_rmsnorm(y, z, p["norm_w"])
    out = jnp.einsum("bte,ed->btd", y, p["out_proj"])
    out = plan.constrain(out, "batch", "seq", "d_model")
    return out, (new_state, new_conv)
