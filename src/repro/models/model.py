"""Full language-model assembly: embeddings -> scanned superblocks ->
head; train / prefill / decode entry points for every assigned
architecture (dense, MoE, SSM, hybrid, enc-dec, VLM).
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass, replace
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .blocks import (
    apply_superblock, block_defs, empty_cache, layer_kinds, n_super,
    stack_defs,
)
from .sharding import (
    PDef, Rules, ShardingPlan, init_from_defs, pspecs_from_defs,
    shapestructs_from_defs,
)

__all__ = ["ModelRuntime", "param_defs", "init_params", "param_pspecs",
           "forward_train", "loss_fn", "prefill", "decode_step",
           "init_cache", "encode"]


@dataclass(frozen=True)
class ModelRuntime:
    """Execution knobs (the LM-side primitive/variant choices)."""
    attn_impl: str = "xla"        # "xla" | "xla_chunked" | "flash"
    remat: bool = False           # activation checkpointing per block
    remat_policy: str = "full"    # "full" | "dots" (save matmul outputs)
    unroll: int = 1               # scan unroll (dry-run accounting)
    chunk: int = 256              # SSD chunk size
    unroll_chunks: bool = False   # python-unroll SSD chunks (dry-run)
    moe_impl: str = "gather"      # "gather" | "alltoall" (shard_map EP)


# ----------------------------------------------------------------------
def param_defs(cfg) -> Dict[str, Any]:
    d, v = cfg.d_model, cfg.vocab
    defs: Dict[str, Any] = {
        "embed": PDef((v, d), ("vocab", "d_model")),
        "blocks": stack_defs(block_defs(cfg), n_super(cfg)),
        "final_norm": PDef((d,), ("d_model",), init="ones"),
    }
    if not cfg.tie_embeddings:
        defs["lm_head"] = PDef((d, v), ("d_model", "vocab"))
    if cfg.family == "encdec":
        enc_cfg = replace(cfg, family="dense", n_layers=cfg.enc_layers,
                          local_global_period=0)
        defs["enc_blocks"] = stack_defs(block_defs(enc_cfg),
                                        n_super(enc_cfg))
        defs["enc_norm"] = PDef((d,), ("d_model",), init="ones")
        defs["enc_pos"] = PDef((cfg.enc_seq, d), ("enc_seq", "d_model"),
                               scale=0.02)
    if cfg.family == "vlm":
        defs["patch_proj"] = PDef((d, d), ("d_model", "d_model"))
    return defs


def init_params(cfg, key, dtype=jnp.bfloat16):
    return init_from_defs(param_defs(cfg), key, dtype)


def param_pspecs(cfg, rules: Rules):
    return pspecs_from_defs(param_defs(cfg), rules)


def param_shapestructs(cfg, dtype=jnp.bfloat16):
    return shapestructs_from_defs(param_defs(cfg), dtype)


def param_count(cfg) -> int:
    leaves = jax.tree.leaves(param_defs(cfg),
                             is_leaf=lambda x: isinstance(x, PDef))
    return int(sum(np.prod(p.shape) for p in leaves))


def active_param_count(cfg) -> int:
    """Parameters touched per token (MoE: top_k of n_experts)."""
    total = param_count(cfg)
    if not cfg.n_experts:
        return total
    leaves = jax.tree.leaves(param_defs(cfg)["blocks"],
                             is_leaf=lambda x: isinstance(x, PDef))
    expert = int(sum(np.prod(p.shape) for p in leaves
                     if "experts" in p.axes))
    inactive = expert * (1 - cfg.top_k / cfg.n_experts)
    return int(total - inactive)


# ----------------------------------------------------------------------
def _embed(cfg, params, tokens, plan: ShardingPlan):
    h = jnp.take(params["embed"], tokens, axis=0)
    if cfg.post_norms:  # gemma2 embedding scaling
        h = h * jnp.asarray(np.sqrt(cfg.d_model), h.dtype)
    return plan.constrain(h, "batch", "seq", "d_model")


def _head(cfg, params, h, plan: ShardingPlan):
    h = h.astype(jnp.float32)
    w = (params["embed"].T if cfg.tie_embeddings else
         params["lm_head"]).astype(jnp.float32)
    logits = jnp.einsum("btd,dv->btv", h, w)
    if cfg.logit_softcap > 0:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return plan.constrain(logits, "batch", "seq", "vocab")


def _run_blocks(cfg, blocks_params, h, *, positions, plan, rt: ModelRuntime,
                cache=None, cache_index=None, decode=False, cross_kv=None,
                causal=True):
    def body(carry, xs):
        if cache is None:
            pblk = xs
            out, _ = apply_superblock(
                cfg, pblk, carry, positions=positions, plan=plan,
                attn_impl=rt.attn_impl, chunk=rt.chunk,
                unroll_chunks=rt.unroll_chunks, moe_impl=rt.moe_impl,
                cross_kv=cross_kv, decode=False)
            return out, None
        pblk, cblk = xs
        out, ncache = apply_superblock(
            cfg, pblk, carry, positions=positions, plan=plan,
            cache=cblk, cache_index=cache_index, decode=decode,
            attn_impl=rt.attn_impl, chunk=rt.chunk,
            unroll_chunks=rt.unroll_chunks, moe_impl=rt.moe_impl,
            cross_kv=cross_kv)
        return out, ncache

    if rt.remat:
        if rt.remat_policy == "dots":
            # selective remat: keep MXU outputs, recompute elementwise
            fn = jax.checkpoint(
                body,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        else:
            fn = jax.checkpoint(body)
    else:
        fn = body
    xs = blocks_params if cache is None else (blocks_params, cache)
    h, caches = jax.lax.scan(fn, h, xs, unroll=rt.unroll)
    return h, caches


# ----------------------------------------------------------------------
def encode(cfg, params, frames, plan: ShardingPlan, rt: ModelRuntime):
    """Whisper encoder over precomputed frame embeddings (conv stub)."""
    enc_cfg = replace(cfg, family="dense", n_layers=cfg.enc_layers,
                      local_global_period=0)
    h = frames + params["enc_pos"][None, :frames.shape[1], :].astype(
        frames.dtype)
    h = plan.constrain(h, "batch", "enc_seq", "d_model")

    def body(carry, pblk):
        out, _ = apply_superblock(
            enc_cfg, pblk, carry,
            positions=jnp.arange(frames.shape[1])[None],
            plan=plan, attn_impl=rt.attn_impl)
        return out, None

    # encoder is bidirectional: patch causal=False through a wrapper
    def body_bidir(carry, pblk):
        from .common import attention, ffn, rms_norm
        h2 = carry
        p = pblk["layer0"]
        x = rms_norm(h2, p["norm1"], cfg.norm_eps)
        a, _ = attention(enc_cfg, p["attn"], x,
                         positions=jnp.arange(frames.shape[1])[None],
                         plan=plan, causal=False, attn_impl=rt.attn_impl)
        h2 = h2 + a
        x = rms_norm(h2, p["norm2"], cfg.norm_eps)
        h2 = h2 + ffn(p["ffn"], x, plan)
        return h2, None

    fn = jax.checkpoint(body_bidir) if rt.remat else body_bidir
    h, _ = jax.lax.scan(fn, h, params["enc_blocks"], unroll=rt.unroll)
    from .common import rms_norm as _rn
    return _rn(h, params["enc_norm"], cfg.norm_eps)


def _prepare_inputs(cfg, params, batch, plan, rt):
    """Returns (h, positions, cross_kv, label_offset)."""
    cross_kv = None
    if cfg.family == "encdec":
        cross_kv = encode(cfg, params, batch["frames"], plan, rt)
        tokens = batch["tokens"]
        h = _embed(cfg, params, tokens, plan)
        positions = jnp.arange(tokens.shape[1])[None]
        return h, positions, cross_kv, 0
    if cfg.family == "vlm":
        patches = jnp.einsum("bpd,de->bpe", batch["patches"],
                             params["patch_proj"])
        text = _embed(cfg, params, batch["tokens"], plan)
        h = jnp.concatenate([patches.astype(text.dtype), text], axis=1)
        positions = jnp.arange(h.shape[1])[None]
        return h, positions, None, patches.shape[1]
    tokens = batch["tokens"]
    h = _embed(cfg, params, tokens, plan)
    positions = jnp.arange(tokens.shape[1])[None]
    return h, positions, None, 0


def forward_train(cfg, params, batch, plan: ShardingPlan,
                  rt: ModelRuntime = ModelRuntime()):
    """Full forward -> logits over the label positions."""
    from .common import rms_norm
    h, positions, cross_kv, off = _prepare_inputs(cfg, params, batch,
                                                  plan, rt)
    h, _ = _run_blocks(cfg, params["blocks"], h, positions=positions,
                       plan=plan, rt=rt, cross_kv=cross_kv)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    if off:
        h = h[:, off:, :]   # VLM: logits over text positions only
    return _head(cfg, params, h, plan)


def loss_fn(cfg, params, batch, plan: ShardingPlan,
            rt: ModelRuntime = ModelRuntime()):
    logits = forward_train(cfg, params, batch, plan, rt)
    labels = batch["labels"]
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None],
                               axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    nll = (logz - gold) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)


# ----------------------------------------------------------------------
def init_cache(cfg, batch: int, max_seq: int, dtype=jnp.bfloat16):
    """Stacked decode cache: every leaf gets the n_super leading axis."""
    one = empty_cache(cfg, batch, max_seq, dtype)
    n = n_super(cfg)
    return jax.tree.map(
        lambda x: jnp.zeros((n,) + x.shape, x.dtype), one)


def prefill(cfg, params, batch, plan: ShardingPlan,
            rt: ModelRuntime = ModelRuntime(), max_seq: Optional[int] = None):
    """Process a prompt, returning (last-position logits, filled cache)."""
    from .common import rms_norm
    tokens = batch["tokens"]
    b, t = tokens.shape
    h, positions, cross_kv, off = _prepare_inputs(cfg, params, batch,
                                                  plan, rt)
    # the hidden sequence may exceed the token count (VLM patch prefix)
    max_seq = max(max_seq or 0, h.shape[1])
    cache = init_cache(cfg, b, max_seq, h.dtype)
    # prefill fills the cache by running the train-style forward and
    # writing k/v at [0, t); implemented via cache_index=None + donated
    # cache (attention writes the full prompt kv in one shot)
    def write(c, kv):
        return jax.lax.dynamic_update_slice_in_dim(c, kv, 0, axis=1)

    h2, caches = _run_blocks(cfg, params["blocks"], h,
                             positions=positions, plan=plan, rt=rt,
                             cache=jax.tree.map(lambda c: c, cache),
                             cache_index=None, decode=False,
                             cross_kv=cross_kv)
    h2 = rms_norm(h2, params["final_norm"], cfg.norm_eps)
    logits = _head(cfg, params, h2[:, -1:, :], plan)
    # merge written kv (length t) into the max_seq cache
    def merge(full, new):
        if new.shape == full.shape:
            return new
        return jax.lax.dynamic_update_slice(
            full, new.astype(full.dtype), (0,) * new.ndim)

    cache = jax.tree.map(merge, cache, caches)
    return logits, cache


def decode_step(cfg, params, cache, tokens, pos, plan: ShardingPlan,
                rt: ModelRuntime = ModelRuntime(), cross_kv=None):
    """One decode step.  tokens: (B, 1); pos: scalar int32 (current
    length).  Returns (logits (B, 1, V), updated cache)."""
    from .common import rms_norm
    h = _embed(cfg, params, tokens, plan)
    positions = jnp.full((1, 1), pos, jnp.int32)
    h, cache = _run_blocks(cfg, params["blocks"], h, positions=positions,
                           plan=plan, rt=rt, cache=cache,
                           cache_index=jnp.asarray(pos, jnp.int32)
                           .reshape(1), decode=True, cross_kv=cross_kv)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    return _head(cfg, params, h, plan), cache
