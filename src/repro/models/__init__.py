"""LM-family model zoo (pure JAX, scan-over-superblocks)."""
from .model import (
    ModelRuntime, decode_step, encode, forward_train, init_cache,
    init_params, loss_fn, param_count, active_param_count, param_defs,
    param_pspecs, param_shapestructs, prefill,
)
from .sharding import (
    MEGATRON_RULES, REPLICATED_RULES, Rules, ShardingPlan,
)

__all__ = [
    "ModelRuntime", "decode_step", "encode", "forward_train", "init_cache",
    "init_params", "loss_fn", "param_count", "active_param_count",
    "param_defs", "param_pspecs", "param_shapestructs", "prefill",
    "Rules", "ShardingPlan", "MEGATRON_RULES", "REPLICATED_RULES",
]
