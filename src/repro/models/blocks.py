"""Per-family superblock definitions.

Every architecture is expressed as a *superblock* of ``period`` layers
repeated ``n_layers / period`` times via ``lax.scan`` over stacked
parameters — this keeps the HLO O(1) in depth (compile-time critical for
the 61-layer/384-expert dry-runs) and is what the roofline's
unroll-differencing accounting relies on.

Layer kinds within a superblock:
  dense:   [attn+ffn]                       (gemma2: [local, global])
  moe:     [attn+moe_ffn]
  hybrid:  jamba 8-block period, attention at index 4, MoE every 2nd
  ssm:     [mamba]
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import attention, attention_defs, ffn, ffn_defs, rms_norm
from .mamba import mamba_block, mamba_defs, mamba_dims
from .moe import moe_defs, moe_ffn
from .sharding import PDef, ShardingPlan


def layer_kinds(cfg) -> List[Dict[str, Any]]:
    """The layer pattern of one superblock; len == period."""
    fam = cfg.family
    if fam == "ssm":
        return [{"mixer": "mamba", "ffn": "none"}]
    if fam == "hybrid":
        out = []
        for i in range(cfg.attn_every):
            out.append({
                "mixer": "attn" if i == cfg.attn_every // 2 else "mamba",
                "ffn": "moe" if (i % cfg.moe_every == 1) else "dense",
                "window": 0,
            })
        return out
    if fam == "moe":
        return [{"mixer": "attn", "ffn": "moe", "window": 0}
                for _ in range(cfg.moe_every)]
    # dense / encdec / vlm decoders
    period = max(1, cfg.local_global_period)
    out = []
    for i in range(period):
        local = cfg.local_global_period > 0 and i % 2 == 0
        out.append({"mixer": "attn", "ffn": "dense",
                    "window": cfg.sliding_window if local else 0})
    return out


def n_super(cfg) -> int:
    period = len(layer_kinds(cfg))
    assert cfg.n_layers % period == 0, (cfg.name, cfg.n_layers, period)
    return cfg.n_layers // period


# ----------------------------------------------------------------------
def block_defs(cfg) -> Dict[str, Dict[str, PDef]]:
    """PDefs for ONE superblock (unstacked)."""
    d = cfg.d_model
    defs: Dict[str, Dict[str, PDef]] = {}
    for i, kind in enumerate(layer_kinds(cfg)):
        b: Dict[str, Any] = {"norm1": PDef((d,), ("d_model",), init="ones")}
        if kind["mixer"] == "attn":
            b["attn"] = attention_defs(cfg)
        else:
            b["mamba"] = mamba_defs(cfg)
        if cfg.family == "encdec":
            b["norm_x"] = PDef((d,), ("d_model",), init="ones")
            b["cross"] = attention_defs(cfg)
        if kind["ffn"] != "none" and not cfg.parallel_block:
            b["norm2"] = PDef((d,), ("d_model",), init="ones")
        if kind["ffn"] == "dense":
            b["ffn"] = ffn_defs(cfg)
        elif kind["ffn"] == "moe":
            b["moe"] = moe_defs(cfg)
        if cfg.post_norms:
            b["post_norm1"] = PDef((d,), ("d_model",), init="ones")
            if kind["ffn"] != "none":
                b["post_norm2"] = PDef((d,), ("d_model",), init="ones")
        defs[f"layer{i}"] = b
    return defs


def stack_defs(defs, n: int):
    """Add the scanned 'layers' leading axis to every PDef."""
    return jax.tree.map(
        lambda p: PDef((n,) + p.shape, ("layers",) + p.axes, p.init,
                       p.scale),
        defs, is_leaf=lambda x: isinstance(x, PDef))


# ----------------------------------------------------------------------
def empty_cache(cfg, batch: int, max_seq: int, dtype) -> Dict[str, Any]:
    """Per-superblock decode cache (unstacked shapes; stacked by model)."""
    cache: Dict[str, Any] = {}
    for i, kind in enumerate(layer_kinds(cfg)):
        if kind["mixer"] == "attn":
            hd = cfg.resolved_head_dim
            # NOTE: sliding-window layers also keep a full-length linear
            # cache (window masking handles semantics); a rotary buffer
            # is a memory optimisation left to the §Perf hillclimb.
            cache[f"layer{i}"] = {
                "k": jnp.zeros((batch, max_seq, cfg.n_kv_heads, hd), dtype),
                "v": jnp.zeros((batch, max_seq, cfg.n_kv_heads, hd), dtype),
            }
        else:
            d_inner, h, p_, n = mamba_dims(cfg)
            conv_dim = d_inner + 2 * n
            cache[f"layer{i}"] = {
                "ssm": jnp.zeros((batch, h, n, p_), jnp.float32),
                "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim),
                                  dtype),
            }
    return cache


def apply_superblock(cfg, params, h, *, positions, plan: ShardingPlan,
                     cache=None, cache_index=None, decode: bool = False,
                     attn_impl: str = "xla", chunk: int = 256,
                     unroll_chunks: bool = False, moe_impl: str = "gather",
                     cross_kv=None):
    """Run one superblock.  Returns (h, new_cache)."""
    new_cache: Dict[str, Any] = {}
    for i, kind in enumerate(layer_kinds(cfg)):
        p = params[f"layer{i}"]
        c = cache.get(f"layer{i}") if cache else None
        resid = h
        x = rms_norm(h, p["norm1"], cfg.norm_eps)
        if kind["mixer"] == "attn":
            window = kind.get("window", 0)
            kv = (c["k"], c["v"]) if c else None
            attn_out, nkv = attention(
                cfg, p["attn"], x, positions=positions, plan=plan,
                causal=True, window=window, kv_cache=kv,
                cache_index=cache_index, attn_impl=attn_impl)
            if nkv is not None:
                new_cache[f"layer{i}"] = {"k": nkv[0], "v": nkv[1]}
            mix_out = attn_out
        else:
            mix_out, (nssm, nconv) = mamba_block(
                cfg, p["mamba"], x, plan, chunk=chunk,
                unroll_chunks=unroll_chunks,
                ssm_state=c["ssm"] if (c and decode) else None,
                conv_state=c["conv"] if (c and decode) else None,
                decode=decode)
            if c is not None:
                new_cache[f"layer{i}"] = {
                    "ssm": nssm if nssm is not None else c["ssm"],
                    "conv": nconv if nconv is not None else c["conv"],
                }
        if cfg.post_norms:
            mix_out = rms_norm(mix_out, p["post_norm1"], cfg.norm_eps)

        if cfg.family == "encdec" and cross_kv is not None:
            h = resid + mix_out
            resid = h
            x = rms_norm(h, p["norm_x"], cfg.norm_eps)
            mix_out, _ = attention(cfg, p["cross"], x, positions=positions,
                                   plan=plan, causal=False,
                                   xk=cross_kv, attn_impl="xla")

        if cfg.parallel_block and kind["ffn"] == "dense":
            ff_out = ffn(p["ffn"], x, plan)
            h = resid + mix_out + ff_out
            continue

        h = resid + mix_out
        if kind["ffn"] == "none":
            continue
        resid = h
        x = rms_norm(h, p["norm2"], cfg.norm_eps)
        if kind["ffn"] == "dense":
            ff_out = ffn(p["ffn"], x, plan)
        elif (moe_impl == "alltoall" and plan.mesh is not None
              and "model" in plan.mesh.axis_names
              and cfg.n_experts % plan.mesh.shape["model"] == 0
              and x.shape[1] % plan.mesh.shape["model"] == 0):
            from .moe import moe_ffn_alltoall
            ff_out = moe_ffn_alltoall(cfg, p["moe"], x, plan)
        else:
            ff_out = moe_ffn(cfg, p["moe"], x, plan)
        if cfg.post_norms:
            ff_out = rms_norm(ff_out, p["post_norm2"], cfg.norm_eps)
        h = resid + ff_out
    return h, (new_cache if cache is not None else None)
