"""Shared model components: norms, rotary embeddings, GQA attention
(train/prefill/decode paths, sliding window, softcap, cross-attention).

Attention is itself a *primitive choice* at this level: ``attn_impl``
selects between the XLA einsum path and the Pallas flash kernel — the
LM-side analogue of the paper's per-layer primitive selection (the
sharding/impl PBQP in repro/core/sharding_select.py prices both).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .sharding import PDef, ShardingPlan

NEG_INF = -1e30


def rms_norm(x, w, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def layer_norm(x, w, b, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w + b


def rope(x, positions, theta: float):
    """x: (..., T, H, D even); positions: (..., T)."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (np.arange(0, half) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., T, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                          axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------
def attention_defs(cfg, d_model: Optional[int] = None) -> Dict[str, PDef]:
    d = d_model or cfg.d_model
    hd = cfg.resolved_head_dim
    return {
        "wq": PDef((d, cfg.n_heads, hd), ("d_model", "heads", "head_dim")),
        "wk": PDef((d, cfg.n_kv_heads, hd),
                   ("d_model", "kv_heads", "head_dim")),
        "wv": PDef((d, cfg.n_kv_heads, hd),
                   ("d_model", "kv_heads", "head_dim")),
        "wo": PDef((cfg.n_heads, hd, d), ("heads", "head_dim", "d_model")),
    }


def _mask(lq, lk, *, causal: bool, window: int, q_offset=0):
    qpos = q_offset + jax.lax.broadcasted_iota(jnp.int32, (lq, lk), 0)
    kpos = jax.lax.broadcasted_iota(jnp.int32, (lq, lk), 1)
    m = jnp.ones((lq, lk), bool)
    if causal:
        m = jnp.logical_and(m, qpos >= kpos)
    if window > 0:
        m = jnp.logical_and(m, qpos - kpos < window)
    return m


def dot_attention(q, k, v, *, scale, causal, window, softcap, q_offset=0,
                  kv_valid=None):
    """q: (B, Tq, H, D); k, v: (B, Tk, Hkv, D) — XLA einsum path."""
    b, tq, h, d = q.shape
    tk, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    qg = q.reshape(b, tq, hkv, g, d)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    m = _mask(tq, tk, causal=causal, window=window, q_offset=q_offset)
    if kv_valid is not None:
        m = jnp.logical_and(
            m, (jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 1)
                < kv_valid))
    s = jnp.where(m, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(b, tq, h, d).astype(q.dtype)


def chunked_causal_attention(q, k, v, *, scale, softcap, chunk: int = 0):
    """Causal attention computing only the lower-triangular chunk pairs.

    The XLA-path analogue of flash attention's fully-masked-block skip:
    query chunk i attends to KV [0, (i+1)*chunk) only, so score FLOPs
    drop from T^2 to T^2/2 (+ diagonal overhead) — visible directly in
    the dry-run's cost_analysis (§Perf hillclimb, hypothesis H1).
    """
    b, t, h, d = q.shape
    hkv = k.shape[2]
    g = h // hkv
    if chunk <= 0:
        # 4 chunks: 62.5% of dense score FLOPs, and few enough chunk
        # boundaries that backward-pass dk/dv grad-psums stay cheap
        # (§Perf H2 iteration 2: 8 chunks won on FLOPs but lost on
        # collectives)
        chunk = max(512, t // 4)
    nc = max(t // chunk, 1)
    chunk = t // nc
    outs = []
    for i in range(nc):
        qi = q[:, i * chunk:(i + 1) * chunk]
        kv_len = (i + 1) * chunk
        ki = k[:, :kv_len]
        vi = v[:, :kv_len]
        qg = qi.reshape(b, chunk, hkv, g, d)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                       ki.astype(jnp.float32)) * scale
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        qpos = i * chunk + jax.lax.broadcasted_iota(
            jnp.int32, (chunk, kv_len), 0)
        kpos = jax.lax.broadcasted_iota(jnp.int32, (chunk, kv_len), 1)
        s = jnp.where(qpos >= kpos, s, NEG_INF)
        pr = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgqs,bskd->bqkgd", pr, vi.astype(jnp.float32))
        outs.append(o.reshape(b, chunk, h, d).astype(q.dtype))
    return jnp.concatenate(outs, axis=1)


def attention(cfg, p, x, *, positions, plan: ShardingPlan,
              causal: bool = True, window: int = 0,
              kv_cache: Optional[Tuple] = None,
              cache_index=None,
              xk: Optional[jax.Array] = None,
              attn_impl: str = "xla"):
    """Full attention layer: projections + rope + attention + out proj.

    kv_cache: (k_cache, v_cache) of (B, S, Hkv, D); with ``cache_index``
    given, the new k/v are written at that position (decode) and
    attention runs against the cache.  ``xk``: cross-attention source
    (whisper decoder).  Returns (out, new_cache).
    """
    hd = cfg.resolved_head_dim
    scale = hd ** -0.5
    src = x if xk is None else xk
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"])
    if xk is None:  # rope only for self-attention
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions if cache_index is None else
                 cache_index[..., None], cfg.rope_theta)
    q = plan.constrain(q, "batch", "seq", "heads", "head_dim")
    k = plan.constrain(k, "batch", "seq", "kv_heads", "head_dim")
    v = plan.constrain(v, "batch", "seq", "kv_heads", "head_dim")

    new_cache = None
    kv_valid = None
    q_offset = 0
    if kv_cache is not None:
        ck, cv = kv_cache
        if cache_index is not None:
            # decode: write new kv at cache_index (scalar per batch)
            idx = cache_index.reshape(-1)[0]
            ck = jax.lax.dynamic_update_slice_in_dim(ck, k, idx, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(cv, v, idx, axis=1)
            k, v = ck, cv
            kv_valid = idx + 1
            q_offset = idx
            causal = False  # masking handled via kv_valid
            new_cache = (ck, cv)
        else:
            # prefill: the freshly-computed prompt k/v ARE the cache
            new_cache = (k, v)

    if attn_impl == "flash" and cache_index is None:
        from ..kernels.flash_attention import flash_attention
        o = flash_attention(
            jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
            jnp.swapaxes(v, 1, 2), scale=scale, causal=causal,
            window=window, softcap=cfg.attn_softcap)
        o = jnp.swapaxes(o, 1, 2)
    elif (attn_impl == "xla_chunked" and cache_index is None and causal
          and window == 0 and xk is None and q.shape[1] >= 1024):
        o = chunked_causal_attention(q, k, v, scale=scale,
                                     softcap=cfg.attn_softcap)
    else:
        o = dot_attention(q, k, v, scale=scale, causal=causal,
                          window=window, softcap=cfg.attn_softcap,
                          q_offset=q_offset, kv_valid=kv_valid)
    o = plan.constrain(o, "batch", "seq", "heads", "head_dim")
    out = jnp.einsum("bthk,hkd->btd", o, p["wo"])
    return plan.constrain(out, "batch", "seq", "d_model"), new_cache


# ----------------------------------------------------------------------
def ffn_defs(cfg, d_model: Optional[int] = None, gated: bool = True):
    d = d_model or cfg.d_model
    f = cfg.d_ff
    defs = {
        "w1": PDef((d, f), ("d_model", "d_ff")),
        "w2": PDef((f, d), ("d_ff", "d_model")),
    }
    if gated:
        defs["w3"] = PDef((d, f), ("d_model", "d_ff"))
    return defs


def ffn(p, x, plan: ShardingPlan, act=jax.nn.silu):
    h = jnp.einsum("btd,df->btf", x, p["w1"])
    if "w3" in p:
        h = act(h) * jnp.einsum("btd,df->btf", x, p["w3"])
    else:
        h = act(h)
    h = plan.constrain(h, "batch", "seq", "d_ff")
    out = jnp.einsum("btf,fd->btd", h, p["w2"])
    return plan.constrain(out, "batch", "seq", "d_model")
