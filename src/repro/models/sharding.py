"""Logical-axis sharding plans — the LM-side 'data layout' abstraction.

This is the paper's idea lifted to the distributed level: a tensor's
"layout" on a TPU pod is its PartitionSpec, primitives are the
implementation choices per layer, and transitions between differently-
sharded producers/consumers cost collective time (the DT-graph edges of
the datacenter).  Models annotate tensors with *logical* axes; a
:class:`Rules` mapping (logical axis -> mesh axis) resolves annotations
to concrete PartitionSpecs.  ``repro.core.sharding_select`` chooses the
rules with the same PBQP machinery the paper uses for CPU layouts.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]

__all__ = ["Rules", "ShardingPlan", "PDef", "init_from_defs",
           "pspecs_from_defs", "MEGATRON_RULES", "REPLICATED_RULES"]


@dataclass(frozen=True)
class Rules:
    """Logical axis -> mesh axis mapping (MaxText-style rules)."""

    table: Tuple[Tuple[str, MeshAxes], ...] = ()

    def get(self, logical: str) -> MeshAxes:
        for k, v in self.table:
            if k == logical:
                return v
        return None

    def spec(self, axes: Sequence[Optional[str]]) -> P:
        used = set()
        parts = []
        for a in axes:
            m = self.get(a) if a else None
            # a mesh axis may appear at most once in a PartitionSpec
            if m is None:
                parts.append(None)
                continue
            ms = (m,) if isinstance(m, str) else tuple(m)
            ms = tuple(x for x in ms if x not in used)
            used.update(ms)
            parts.append(ms if len(ms) > 1 else (ms[0] if ms else None))
        return P(*parts)

    def with_(self, **kw) -> "Rules":
        table = dict(self.table)
        table.update(kw)
        return Rules(tuple(table.items()))

    def restrict(self, mesh_axes) -> "Rules":
        """Drop mesh axes that don't exist on this mesh (e.g. 'pod' on
        the single-pod 16x16 mesh)."""
        mesh_axes = set(mesh_axes)

        def fix(v):
            if v is None:
                return None
            vs = (v,) if isinstance(v, str) else tuple(v)
            vs = tuple(x for x in vs if x in mesh_axes)
            if not vs:
                return None
            return vs[0] if len(vs) == 1 else vs

        return Rules(tuple((k, fix(v)) for k, v in self.table))

    def feasible(self, axes: Sequence[Optional[str]],
                 shape: Sequence[int], mesh_shape: Dict[str, int]) -> bool:
        """Divisibility check: each sharded dim must divide evenly."""
        for a, n in zip(axes, shape):
            m = self.get(a) if a else None
            if m is None:
                continue
            ms = (m,) if isinstance(m, str) else m
            total = int(np.prod([mesh_shape[x] for x in ms]))
            if n % total:
                return False
        return True


#: canonical fixed-rule baselines (the LM analogue of the paper's
#: "local optimal": one canonical layout everywhere)
MEGATRON_RULES = Rules((
    ("batch", ("pod", "data")),
    ("seq", None),
    ("d_model", None),
    ("heads", "model"),
    ("kv_heads", "model"),
    ("head_dim", None),
    ("d_ff", "model"),
    ("experts", "model"),
    ("vocab", "model"),
    ("layers", None),
    ("ssm_heads", "model"),
    ("ssm_state", None),
    ("enc_seq", None),
    ("kv_seq", None),
))

REPLICATED_RULES = Rules((
    ("batch", ("pod", "data")),
))


@dataclass
class ShardingPlan:
    """Resolved plan: mesh + rules (+ per-annotation overrides)."""

    mesh: Optional[Mesh] = None
    rules: Rules = MEGATRON_RULES
    overrides: Dict[str, P] = field(default_factory=dict)

    def constrain(self, x, *axes: Optional[str], name: str = ""):
        """Annotate an activation with logical axes -> sharding hint."""
        if self.mesh is None:
            return x
        spec = self.overrides.get(name) if name else None
        if spec is None:
            spec = self.rules.spec(axes)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))

    def sharding(self, spec: P) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, spec)


# ----------------------------------------------------------------------
# parameter definitions: single source of truth for shapes, logical
# axes, initialisation, and shardings
# ----------------------------------------------------------------------
@dataclass(frozen=True)
class PDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"   # normal | zeros | ones | scaled
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.axes)


def _init_leaf(key, d: PDef, dtype):
    import jax.numpy as jnp
    if d.init == "zeros":
        return jnp.zeros(d.shape, dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, dtype)
    fan_in = d.shape[0] if len(d.shape) > 1 else d.shape[0]
    std = d.scale / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, d.shape, jnp.float32) * std).astype(dtype)


def init_from_defs(defs, key, dtype):
    """defs: nested dict of PDef -> same-structure dict of arrays."""
    leaves, treedef = jax.tree.flatten(
        defs, is_leaf=lambda x: isinstance(x, PDef))
    keys = jax.random.split(key, len(leaves))
    vals = [_init_leaf(k, d, dtype) for k, d in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, vals)


def pspecs_from_defs(defs, rules: Rules):
    return jax.tree.map(lambda d: rules.spec(d.axes), defs,
                        is_leaf=lambda x: isinstance(x, PDef))


def shapestructs_from_defs(defs, dtype):
    return jax.tree.map(lambda d: jax.ShapeDtypeStruct(d.shape, dtype),
                        defs, is_leaf=lambda x: isinstance(x, PDef))
