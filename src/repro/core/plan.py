"""Execution of an instantiated DNN: the paper's "simple code generator
which emitted calls to primitive operations" — here it builds a single
jit'd function that walks the DAG in topological order, invoking the
selected primitive per conv layer and the explicit layout-conversion
chains the legalizer inserted on illegal edges.

With ``mesh=`` the generator emits a *mesh-sharded* executable
realizing every node's solved device placement (the
``Choice.placement`` axis of ``select_pbqp(..., mesh_axes=...)``),
one lowering per placement family:

* **dp / rep only** — ``dp`` nodes run batch-sharded over the mesh's
  batch axes (``data`` x ``model``, flattened), ``rep`` replicated.
  All-``dp`` plans take a ``shard_map`` fast path; mixed plans compile
  with one ``NamedSharding`` constraint per node so GSPMD inserts
  exactly the resharding collectives the PBQP edges priced.
* **any tp node** — an explicit-collective ``shard_map`` walker:
  ``tp`` convs run with their output-channel weight slab sharded over
  the ``model`` axis and an intra-group channel ``all_gather`` after
  the call; form changes between dp/tp/rep values are emitted as the
  same gathers and slices the edge costs priced.
* **pp plan** — contiguous stage runs lower onto
  :func:`~repro.runtime.pipeline_parallel.pipeline_apply`
  (the GPipe fill-drain schedule over the ``stage`` axis), with stage
  boundaries wired in logical CHW exactly as the solver priced them.

Runs on real pods and on fake CPU devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``) alike; see
docs/distributed.md.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..obs.metrics import default_registry
from ..obs.trace import get_tracer
from .graph import Net
from .layouts import LAYOUT_BY_NAME
from .primitives import convert_layout
from .selection import Placement, SelectionResult, pp_microbatches

__all__ = ["compile_plan", "CompiledNet", "measure", "compile_count",
           "mesh_shape_dict"]


def mesh_shape_dict(mesh) -> Dict[str, int]:
    """Axis name -> size for a jax Mesh.  Single definition —
    ``launch.mesh`` re-exports it for CLI-side callers."""
    return dict(zip(mesh.axis_names, mesh.devices.shape))

#: process-wide count of compile_plan() calls — executable construction is
#: the expensive step the serving LRU exists to amortise, so tests and the
#: plan-cache benchmark assert on this.  Backed by the obs registry's
#: locked Counter: PlanServer.prefetch compiles from an executor, and the
#: old ``global n; n += 1`` lost increments under that concurrency.
_COMPILE_COUNTER = default_registry().counter("compile_plan_calls")


def compile_count() -> int:
    return _COMPILE_COUNTER.value


@dataclass
class CompiledNet:
    sel: SelectionResult
    fn: Callable                      # (x, params) -> outputs dict
    params: Dict[str, Any]            # packed per-node parameters
    build_s: float = 0.0              # wall time of weight packing + wiring
    #: minibatch the executable was compiled for: 1 -> (C, H, W) in/out,
    #: > 1 -> (N, C, H, W) in and a leading N axis on every output
    batch: int = 1
    #: edges executed as fused prologues/epilogues instead of
    #: materialized convert_layout dispatches (observability for tests
    #: and the fusion benchmark)
    fused_edges: int = 0
    #: mesh the executable is sharded over (None: single device)
    mesh: Optional[Any] = None
    #: nodes realized batch-sharded over the mesh's batch axes
    dp_nodes: int = 0
    #: "shard_map" (all-dp fast path) | "gspmd" (per-node constraints)
    #: | "tp_shard_map" (explicit-collective tp walker) | "pipeline"
    #: (GPipe stage schedule) | "" (no mesh)
    mesh_mode: str = ""
    #: nodes realized weight-sharded over the mesh's model axis
    tp_nodes: int = 0
    #: nodes realized as pipeline stages over the mesh's stage axis
    pp_nodes: int = 0
    #: per-conv-node maker callables (fusion-resolved wire layouts) —
    #: kept so obs.drift.InstrumentedNet can rebuild the same walk with
    #: per-node timing.  None only on hand-constructed instances.
    makers: Optional[Dict[str, Callable]] = None

    def __call__(self, x):
        return self.fn(jnp.asarray(x), self.params)


def compile_plan(sel: SelectionResult, raw_params: Dict[str, Dict],
                 jit: bool = True, fuse_across_layers: bool = False,
                 batch: int = 1, mesh: Optional[Any] = None) -> CompiledNet:
    """``fuse_across_layers=False`` (default) inserts optimization
    barriers between primitive calls: the paper's code generator emits
    *calls into a library of routines*, so no cross-layer fusion exists
    and per-layer profiled costs compose additively.  Letting XLA fuse
    across layers (True) breaks that additivity — useful as an extra
    baseline, but it is a different system than the paper's.

    ``batch > 1`` builds a *batched* executable: the single-image
    program is vmapped over a leading batch axis, so one invocation runs
    the whole tower for N images — per-image dispatch/packing overhead
    is paid once, which is exactly the amortization the batch-aware
    cost model prices (``Scenario.n``).  Input becomes (N, C, H, W) and
    every output gains a leading N axis.

    **Transform fusion pass.**  Edges the selection realized as fused
    (``sel.fusions``, see :func:`~repro.core.selection.select_pbqp` with
    ``fuse=True``) get no ``convert_layout`` dispatch at all: the
    consumer's maker is built via ``Primitive.make_fused`` to read the
    producer's layout in its prologue (kind ``"in"``), or the producer's
    to emit the consumer's layout in its epilogue (kind ``"out"``).  The
    fused call executes as ONE region — under the default per-layer
    barriers the transform can never be split back out into an HBM
    round trip.  The pass is orthogonal to ``fuse_across_layers`` and
    ``batch``: fused makers are emitted regardless of barrier placement
    and are vmap-safe, so all flag combinations compose.

    **Mesh-sharded executables.**  ``mesh`` (with ``batch > 1``)
    realizes the plan's device placements: nodes whose
    :class:`~repro.core.selection.Choice` carries ``placement="dp"``
    run batch-sharded over the mesh's ``data`` axis, ``"rep"`` nodes
    replicated.  An all-``dp`` plan compiles through ``shard_map`` (one
    per-shard vmapped program per device — the pure data-parallel fast
    path); any plan with a ``rep`` node compiles the batched program
    with one ``NamedSharding`` constraint per node, so GSPMD inserts
    exactly the resharding collectives the selection's edge costs
    priced.  Input is (N, C, H, W) as for any batched executable;
    callers pass host arrays and receive global (gathered-on-read)
    outputs, so a mesh executable is a drop-in for the single-device
    batched one (verified output-identical in tests/test_distributed.py).
    """
    _COMPILE_COUNTER.add()
    if batch < 1:
        raise ValueError(f"batch must be >= 1, got {batch}")
    if mesh is not None and batch < 2:
        raise ValueError("mesh-sharded executables are batched: pass "
                         "batch >= 2 (a single image cannot be sharded "
                         "over the data axis)")
    net = sel.net
    dp_nodes = tp_nodes = pp_nodes = 0
    d_mesh = 1
    batch_axes: tuple = ()
    if mesh is not None:
        mesh_shape = mesh_shape_dict(mesh)
        # dp shards the batch over ALL non-stage axes (data x model),
        # mirroring the solver's pricing (selection._mesh_dims)
        batch_axes = tuple(a for a in ("data", "model")
                           if a in mesh_shape)
        for a in batch_axes:
            d_mesh *= int(mesh_shape[a])
        kinds = {nid: Placement.parse(ch.placement).kind
                 for nid, ch in sel.choices.items()}
        dp_nodes = sum(1 for k in kinds.values() if k == "dp")
        tp_nodes = sum(1 for k in kinds.values() if k == "tp")
        pp_nodes = sum(1 for k in kinds.values() if k == "pp")
        if dp_nodes and (d_mesh <= 1 or batch % d_mesh):
            raise ValueError(
                f"plan has {dp_nodes} dp nodes but mesh {mesh_shape} "
                f"cannot shard batch {batch} over its batch axes "
                f"{batch_axes}")
        if tp_nodes:
            d_tp = int(mesh_shape.get("model", 1))
            d_data = int(mesh_shape.get("data", 1))
            if d_tp <= 1:
                raise ValueError(
                    f"plan has {tp_nodes} tp nodes but mesh "
                    f"{mesh_shape} has no 'model' axis to shard "
                    f"weights over")
            if batch % d_data:
                raise ValueError(
                    f"tp plans keep the batch data-sharded: batch "
                    f"{batch} does not divide over the 'data' axis "
                    f"of {mesh_shape}")
        if pp_nodes:
            if "stage" not in mesh_shape:
                raise ValueError(
                    f"plan has {pp_nodes} pp nodes but mesh "
                    f"{mesh_shape} has no 'stage' axis")
            if pp_nodes != len(net.order):
                raise ValueError(
                    "pipeline plans are all-or-nothing: "
                    f"{pp_nodes}/{len(net.order)} nodes carry a pp "
                    "placement")
    t0 = time.perf_counter()

    # fusion pass: effective wire layouts per conv node.  Kind "in"
    # means the consumer reads the producer's declared l_out; kind
    # "out" means the (single-consumer) producer emits the consumer's
    # l_in.  Selection guarantees an edge is fused or converted, never
    # both, so the two maps cannot conflict.
    fusions = sel.fusions
    eff_in: Dict[str, str] = {}
    eff_out: Dict[str, str] = {}
    for (src, dst), kind in fusions.items():
        if kind == "in":
            eff_in[dst] = sel.choices[src].l_out
        elif kind == "out":
            eff_out[src] = sel.choices[dst].l_in
        else:
            raise ValueError(f"unknown fusion kind {kind!r} on edge "
                             f"({src}, {dst})")

    packed: Dict[str, Any] = {}
    makers: Dict[str, Callable] = {}
    for nid in net.order:
        node = net.nodes[nid]
        ch = sel.choices[nid]
        if node.kind == "conv":
            p = raw_params[nid]
            if mesh is not None and kinds[nid] == "tp":
                # tp conv: slice the raw output-channel slab into d_tp
                # shards, pack each at the shard scenario, and stack —
                # the executor shards the stacked leading axis over the
                # mesh's 'model' axis so each device packs 1/d_tp of
                # the weights.  Fusion is never offered on tp edges,
                # so the maker wires the primitive's own l_in/l_out.
                if node.scn.m % d_tp:
                    raise ValueError(
                        f"tp node {nid}: m={node.scn.m} does not "
                        f"divide over d_tp={d_tp}")
                msh = node.scn.m // d_tp
                scn_tp = node.scn.with_(m=msh)
                shards = [ch.primitive.prepare(
                              scn_tp, p["w"][i * msh:(i + 1) * msh],
                              p["b"][i * msh:(i + 1) * msh])
                          for i in range(d_tp)]
                packed[nid] = jax.tree.map(
                    lambda *xs: jnp.stack(xs), *shards)
                makers[nid] = ch.primitive.make_fused(
                    scn_tp, l_in=ch.l_in, l_out=ch.l_out)
                continue
            packed[nid] = ch.primitive.prepare(node.scn, p["w"], p["b"])
            makers[nid] = ch.primitive.make_fused(
                node.scn, l_in=eff_in.get(nid, ch.l_in),
                l_out=eff_out.get(nid, ch.l_out))
        elif node.kind == "op" and nid in raw_params:
            packed[nid] = jax.tree.map(jnp.asarray, raw_params[nid])

    # Batched executables compile without the per-layer barriers: (a)
    # optimization_barrier has no vmap batching rule, and (b) the
    # barriers exist to keep per-layer *profiled* costs additive — a
    # measurement-methodology concern, while the batched path is a
    # throughput path where cross-layer fusion is desirable.
    barrier = (lambda v: v) if fuse_across_layers or batch > 1 else \
        (lambda v: jax.lax.optimization_barrier(v))

    if mesh is not None:
        if pp_nodes:
            fn = _build_pipeline_fn(sel, net, makers, mesh, batch, jit)
            mode = "pipeline"
        elif tp_nodes:
            fn = _build_tp_fn(sel, net, makers, packed, mesh, batch,
                              jit)
            mode = "tp_shard_map"
        else:
            fn, mode = _build_mesh_fn(sel, net, makers, mesh,
                                      batch_axes, d_mesh, dp_nodes, jit)
        cnet = CompiledNet(sel, fn, packed,
                           build_s=time.perf_counter() - t0, batch=batch,
                           fused_edges=len(fusions), mesh=mesh,
                           dp_nodes=dp_nodes, mesh_mode=mode,
                           makers=makers, tp_nodes=tp_nodes,
                           pp_nodes=pp_nodes)
    else:
        run = _image_walker(sel, net, makers, barrier)
        if batch > 1:
            run = jax.vmap(run, in_axes=(0, None))
        fn = jax.jit(run) if jit else run
        cnet = CompiledNet(sel, fn, packed,
                           build_s=time.perf_counter() - t0,
                           batch=batch, fused_edges=len(fusions),
                           makers=makers)
    get_tracer().emit("compile", t0, time.perf_counter(),
                      nodes=len(net.order), batch=batch,
                      fused_edges=cnet.fused_edges,
                      mesh_mode=cnet.mesh_mode, dp_nodes=cnet.dp_nodes,
                      tp_nodes=cnet.tp_nodes, pp_nodes=cnet.pp_nodes)
    return cnet


def _image_walker(sel: SelectionResult, net: Net,
                  makers: Dict[str, Callable],
                  barrier: Callable = lambda v: v) -> Callable:
    """The per-image DAG walk every executable variant shares: invoke
    the selected primitive per conv node, the op function per op node,
    the legalizer's conversion chains per mismatched edge, then convert
    outputs to logical CHW.  ``barrier`` wraps per-layer results (the
    paper's no-cross-layer-fusion discipline; identity for batched and
    mesh executables)."""
    def run(x, params):
        vals: Dict[str, Any] = {}
        for nid in net.order:
            node = net.nodes[nid]
            if node.kind == "input":
                vals[nid] = x  # inputs arrive in logical CHW
                continue
            ins = []
            for src in node.inputs:
                v = vals[src]
                chain = sel.conversions.get((src, nid))
                if chain:
                    for a, b in zip(chain, chain[1:]):
                        v = barrier(convert_layout(v, a, b))
                ins.append(v)
            if node.kind == "conv":
                vals[nid] = barrier(makers[nid](ins[0], params[nid]))
            else:
                layout = LAYOUT_BY_NAME[sel.choices[nid].l_in]
                vals[nid] = node.op.fn(ins, layout, params.get(nid))
        return {nid: convert_layout(vals[nid], sel.choices[nid].l_out,
                                    "CHW")
                for nid in net.outputs()}
    return run


def _build_mesh_fn(sel: SelectionResult, net: Net, makers: Dict[str,
                   Callable], mesh, batch_axes: tuple, d_mesh: int,
                   dp_nodes: int, jit: bool):
    """Emit the mesh-sharded executable for a {dp, rep} plan.

    ``dp`` shards the batch over *all* the mesh's batch axes
    (``batch_axes`` — ``data`` and, when present, ``model`` — exactly
    the flattening the solver priced), so a pure-dp plan costs and runs
    the same on an ``(8,)`` and a ``(2, 4)`` mesh.  Two modes (both
    barrier-free, like every batched executable):

    * ``shard_map`` — every node is ``dp``: split the batch once over
      the batch axes and run the vmapped per-shard program
      (:func:`_image_walker`, the same walk the single-device
      executable runs) on each device.  No partitioner in the loop;
      the pure data-parallel serving fast path.
    * ``gspmd`` — mixed placements: run the batched program with one
      ``NamedSharding`` constraint per node, so GSPMD inserts exactly
      the resharding collectives the selection's edge costs priced
      (``dp -> rep``: all-gather; ``rep -> dp``: a local slice).  This
      walker is the batched per-node-vmap variant of the walk — the
      constraints must land on whole-batch values, so it cannot reuse
      the vmapped per-image program.
    """
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    dp_spec = P(batch_axes) if batch_axes else P()
    if dp_nodes == len(net.order) and d_mesh > 1:
        from jax.experimental.shard_map import shard_map
        inner = jax.vmap(_image_walker(sel, net, makers),
                         in_axes=(0, None))
        fn = shard_map(inner, mesh=mesh, in_specs=(dp_spec, P()),
                       out_specs=dp_spec)
        return (jax.jit(fn) if jit else fn), "shard_map"

    def spec_of(nid: str) -> "NamedSharding":
        pl = sel.choices[nid].placement
        return NamedSharding(mesh, dp_spec if pl == "dp" else P())

    def run_batched(x, params):
        vals: Dict[str, Any] = {}
        for nid in net.order:
            node = net.nodes[nid]
            ch = sel.choices[nid]
            if node.kind == "input":
                v = x
            else:
                ins = []
                for src in node.inputs:
                    vi = vals[src]
                    chain = sel.conversions.get((src, nid))
                    if chain:
                        for a, b in zip(chain, chain[1:]):
                            vi = jax.vmap(
                                lambda t, a=a, b=b:
                                convert_layout(t, a, b))(vi)
                    ins.append(vi)
                if node.kind == "conv":
                    v = jax.vmap(makers[nid], in_axes=(0, None))(
                        ins[0], params[nid])
                else:
                    layout = LAYOUT_BY_NAME[ch.l_in]
                    p = params.get(nid)
                    v = jax.vmap(
                        lambda *xs, op=node.op, lay=layout, p=p:
                        op.fn(list(xs), lay, p))(*ins)
            vals[nid] = jax.lax.with_sharding_constraint(v, spec_of(nid))
        return {nid: jax.vmap(
                    lambda t, lo=sel.choices[nid].l_out:
                    convert_layout(t, lo, "CHW"))(vals[nid])
                for nid in net.outputs()}

    return (jax.jit(run_batched) if jit else run_batched), "gspmd"


def _build_tp_fn(sel: SelectionResult, net: Net,
                 makers: Dict[str, Callable], packed: Dict[str, Any],
                 mesh, batch: int, jit: bool):
    """Explicit-collective ``shard_map`` walker for plans with tp nodes.

    Every value inside the walker carries one of three *forms* — how its
    leading batch axis is laid out across the mesh:

    * ``dp``  — ``batch / (d_data * d_tp)`` rows per device (sharded
      over all batch axes);
    * ``ds``  — ``batch / d_data`` rows per device (sharded over
      ``data`` only, replicated across ``model``) — the working form of
      tp nodes, whose parallelism lives in the weight shards;
    * ``rep`` — the full batch everywhere.

    Form changes are emitted as exactly the collectives the solver's
    edge costs priced (``dp -> rep``/``dp -> ds``/``ds -> rep``:
    tiled all-gathers; the reverse directions: local slices).  A tp
    conv runs its maker on the device's weight shard (1/d_tp of the
    output channels), converts to logical CHW, all-gathers the channel
    axis across ``model``, and converts back — the intra-group
    collective the node's setup cost carried.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh_shape = mesh_shape_dict(mesh)
    d_data = int(mesh_shape.get("data", 1))
    d_tp = int(mesh_shape["model"])
    batch_axes = tuple(a for a in ("data", "model") if a in mesh_shape)

    kind_of = {nid: Placement.parse(sel.choices[nid].placement).kind
               for nid in net.order}
    FORM = {"dp": "dp", "tp": "ds", "rep": "rep"}
    form_of = {nid: FORM[kind_of[nid]] for nid in net.order}
    rows = {"dp": batch // (d_data * d_tp), "ds": batch // d_data,
            "rep": batch}

    def _reform(v, src, dst):
        if src == dst or rows[src] == rows[dst]:
            return v
        if src == "dp" and dst == "rep":
            return jax.lax.all_gather(v, batch_axes, axis=0, tiled=True)
        if src == "dp" and dst == "ds":
            return jax.lax.all_gather(v, "model", axis=0, tiled=True)
        if src == "ds" and dst == "rep":
            return jax.lax.all_gather(v, "data", axis=0, tiled=True)
        # remaining directions drop rows: purely local slices
        i = jax.lax.axis_index("data") if d_data > 1 else 0
        j = jax.lax.axis_index("model")
        if src == "rep" and dst == "dp":
            start = (i * d_tp + j) * rows["dp"]
        elif src == "rep" and dst == "ds":
            start = i * rows["ds"]
        elif src == "ds" and dst == "dp":
            start = j * rows["dp"]
        else:
            raise AssertionError(f"unreachable reform {src}->{dst}")
        return jax.lax.dynamic_slice_in_dim(v, start, rows[dst], axis=0)

    def _convert(v, chain):
        if chain:
            for a, b in zip(chain, chain[1:]):
                v = jax.vmap(
                    lambda t, a=a, b=b: convert_layout(t, a, b))(v)
        return v

    def _bring(v, src, dst, chain):
        # convert layouts on whichever side holds fewer rows — the
        # same min-rows discount the edge's transform cost applied
        if rows[dst] <= rows[src]:
            return _convert(_reform(v, src, dst), chain)
        return _reform(_convert(v, chain), src, dst)

    in_forms = {form_of[nid] for nid in net.order
                if net.nodes[nid].kind == "input"}
    x_form = in_forms.pop() if len(in_forms) == 1 else "rep"

    def walker(x, params):
        vals: Dict[str, Any] = {}
        for nid in net.order:
            node = net.nodes[nid]
            ch = sel.choices[nid]
            form = form_of[nid]
            if node.kind == "input":
                vals[nid] = _reform(x, x_form, form)
                continue
            ins = [_bring(vals[src], form_of[src], form,
                          sel.conversions.get((src, nid)))
                   for src in node.inputs]
            if node.kind == "conv":
                if kind_of[nid] == "tp":
                    # local leading axis of the stacked shard slab is
                    # size 1 under P("model"): [0] is this device's cut
                    p_local = jax.tree.map(lambda a: a[0], params[nid])
                    y = jax.vmap(makers[nid], in_axes=(0, None))(
                        ins[0], p_local)
                    lo = ch.l_out
                    y = jax.vmap(
                        lambda t: convert_layout(t, lo, "CHW"))(y)
                    y = jax.lax.all_gather(y, "model", axis=1,
                                           tiled=True)
                    vals[nid] = jax.vmap(
                        lambda t: convert_layout(t, "CHW", lo))(y)
                else:
                    vals[nid] = jax.vmap(makers[nid], in_axes=(0, None))(
                        ins[0], params[nid])
            else:
                layout = LAYOUT_BY_NAME[ch.l_in]
                p = params.get(nid)
                vals[nid] = jax.vmap(
                    lambda *xs, op=node.op, lay=layout, p=p:
                    op.fn(list(xs), lay, p))(*ins)
        return {nid: jax.vmap(
                    lambda t, lo=sel.choices[nid].l_out:
                    convert_layout(t, lo, "CHW"))(vals[nid])
                for nid in net.outputs()}

    def spec(form):
        if form == "dp":
            return P(batch_axes)
        if form == "ds" and d_data > 1:
            return P("data")
        return P()

    p_specs = {nid: (P("model") if (net.nodes[nid].kind == "conv"
                                    and kind_of[nid] == "tp") else P())
               for nid in packed}
    fn = shard_map(
        walker, mesh=mesh,
        in_specs=(spec(x_form), p_specs),
        out_specs={nid: spec(form_of[nid]) for nid in net.outputs()},
        check_rep=False)
    return jax.jit(fn) if jit else fn


def _build_pipeline_fn(sel: SelectionResult, net: Net,
                       makers: Dict[str, Callable], mesh, batch: int,
                       jit: bool):
    """Lower a pp-placed plan onto the GPipe fill-drain schedule.

    The solver only offers pp placements on :func:`~repro.core.
    selection.pp_chain` nets — a linear, shape-preserving chain — and
    its infinite backward-hop edge costs guarantee stages are monotone
    along the chain.  Each mesh stage therefore owns one contiguous run
    of nodes; this builder turns each run into a branch of a
    ``lax.switch`` on ``axis_index("stage")`` and streams
    ``pp_microbatches(batch, S)`` microbatches through
    :func:`~repro.runtime.pipeline_parallel.pipeline_apply`.

    Stage boundaries are wired in logical CHW: the legalizer recorded
    each cross-stage edge's conversion chain *through* CHW, so the
    producing branch applies the ``l_out -> CHW`` prefix and the
    consuming branch the ``CHW -> l_in`` suffix — the carry that
    ``ppermute`` rotates between stages is always the CHW activation
    the edge cost priced.
    """
    from ..runtime.pipeline_parallel import pipeline_apply

    mesh_shape = mesh_shape_dict(mesh)
    s = int(mesh_shape["stage"])
    n_micro = pp_microbatches(batch, s)
    mb = batch // n_micro
    order = net.order
    stage_of = {nid: Placement.parse(sel.choices[nid].placement).stage
                for nid in order}

    def _convert(v, hops):
        for a, b in zip(hops, hops[1:]):
            v = jax.vmap(lambda t, a=a, b=b: convert_layout(t, a, b))(v)
        return v

    def make_branch(s_idx):
        """One stage's program: (params dict, (mb, C, H, W) CHW carry)
        -> (mb, C, H, W) CHW carry.  Stages that own no nodes (more
        stages than layers) are identity relays."""
        def br(p, v):
            for pos, nid in enumerate(order):
                if stage_of[nid] != s_idx:
                    continue
                node = net.nodes[nid]
                ch = sel.choices[nid]
                if node.kind != "input":
                    prev = order[pos - 1]
                    chain = sel.conversions.get((prev, nid))
                    if chain:
                        hops = chain
                        if stage_of[prev] != s_idx:
                            # cross-stage edge: the wire arrived in
                            # CHW; apply only the CHW -> l_in suffix
                            hops = chain[chain.index("CHW"):]
                        v = _convert(v, hops)
                    if node.kind == "conv":
                        v = jax.vmap(makers[nid], in_axes=(0, None))(
                            v, p[nid])
                    else:
                        layout = LAYOUT_BY_NAME[ch.l_in]
                        q = p.get(nid)
                        v = jax.vmap(
                            lambda t, op=node.op, lay=layout, q=q:
                            op.fn([t], lay, q))(v)
                # exit wire: if the chain leaves this stage after nid,
                # park the carry in CHW for the boundary transfer
                nxt = order[pos + 1] if pos + 1 < len(order) else None
                if nxt is None or stage_of[nxt] != s_idx:
                    nchain = (sel.conversions.get((nid, nxt))
                              if nxt is not None else None)
                    if nchain:
                        v = _convert(
                            v, nchain[:nchain.index("CHW") + 1])
                    elif ch.l_out != "CHW":
                        v = jax.vmap(
                            lambda t, lo=ch.l_out:
                            convert_layout(t, lo, "CHW"))(v)
            return v
        return br

    branches = [make_branch(i) for i in range(s)]
    out_nid = net.outputs()[0]
    c, h, w = net.nodes[order[0]].out_shape

    def run(x, params):
        xm = x.reshape(n_micro, mb, c, h, w)
        # pipeline_apply shards stage_params' leading axis over the
        # stage axis; per-stage params are heterogeneous pytrees, so
        # ship the whole dict to every stage (leading axis = S copies)
        # and let each branch pick out its own nodes' entries
        sp = jax.tree.map(
            lambda a: jnp.stack([a] * s), params)

        def stage_fn(p, xmi):
            return jax.lax.switch(
                jax.lax.axis_index("stage"),
                [lambda t, b=b, p=p: b(p, t) for b in branches], xmi)

        y = pipeline_apply(mesh, stage_fn, sp, xm, n_micro=n_micro)
        return {out_nid: y.reshape(batch, c, h, w)}

    return jax.jit(run) if jit else run


def measure(cnet: CompiledNet, x_chw: np.ndarray, *, reps: int = 5,
            warmup: int = 1) -> Dict[str, float]:
    """Wall-time one forward pass (the paper's whole-network benchmark:
    mean of ``reps`` iterations after warmup)."""
    x = jnp.asarray(x_chw)
    for _ in range(warmup):
        jax.block_until_ready(cnet.fn(x, cnet.params))
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(cnet.fn(x, cnet.params))
        times.append(time.perf_counter() - t0)
    return {"mean_s": float(np.mean(times)),
            "min_s": float(np.min(times)),
            "std_s": float(np.std(times))}
