"""Execution of an instantiated DNN: the paper's "simple code generator
which emitted calls to primitive operations" — here it builds a single
jit'd function that walks the DAG in topological order, invoking the
selected primitive per conv layer and the explicit layout-conversion
chains the legalizer inserted on illegal edges.

With ``mesh=`` the generator emits a *mesh-sharded* executable: every
node's device placement (the ``Choice.placement`` axis solved by
``select_pbqp(..., mesh_axes=...)``) is realized as a ``NamedSharding``
constraint over the mesh's ``data`` axis — GSPMD inserts exactly the
resharding collectives the PBQP edges priced — and an all-``dp`` plan
takes a ``shard_map`` fast path (one per-shard program per device, no
partitioner round trip).  Runs on real pods and on fake CPU devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``) alike; see
docs/distributed.md.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..obs.metrics import default_registry
from ..obs.trace import get_tracer
from .graph import Net
from .layouts import LAYOUT_BY_NAME
from .primitives import convert_layout
from .selection import SelectionResult

__all__ = ["compile_plan", "CompiledNet", "measure", "compile_count",
           "mesh_shape_dict"]


def mesh_shape_dict(mesh) -> Dict[str, int]:
    """Axis name -> size for a jax Mesh.  Single definition —
    ``launch.mesh`` re-exports it for CLI-side callers."""
    return dict(zip(mesh.axis_names, mesh.devices.shape))

#: process-wide count of compile_plan() calls — executable construction is
#: the expensive step the serving LRU exists to amortise, so tests and the
#: plan-cache benchmark assert on this.  Backed by the obs registry's
#: locked Counter: PlanServer.prefetch compiles from an executor, and the
#: old ``global n; n += 1`` lost increments under that concurrency.
_COMPILE_COUNTER = default_registry().counter("compile_plan_calls")


def compile_count() -> int:
    return _COMPILE_COUNTER.value


@dataclass
class CompiledNet:
    sel: SelectionResult
    fn: Callable                      # (x, params) -> outputs dict
    params: Dict[str, Any]            # packed per-node parameters
    build_s: float = 0.0              # wall time of weight packing + wiring
    #: minibatch the executable was compiled for: 1 -> (C, H, W) in/out,
    #: > 1 -> (N, C, H, W) in and a leading N axis on every output
    batch: int = 1
    #: edges executed as fused prologues/epilogues instead of
    #: materialized convert_layout dispatches (observability for tests
    #: and the fusion benchmark)
    fused_edges: int = 0
    #: mesh the executable is sharded over (None: single device)
    mesh: Optional[Any] = None
    #: nodes realized batch-sharded over the mesh's data axis
    dp_nodes: int = 0
    #: "shard_map" (all-dp fast path) | "gspmd" (per-node constraints)
    #: | "" (no mesh)
    mesh_mode: str = ""
    #: per-conv-node maker callables (fusion-resolved wire layouts) —
    #: kept so obs.drift.InstrumentedNet can rebuild the same walk with
    #: per-node timing.  None only on hand-constructed instances.
    makers: Optional[Dict[str, Callable]] = None

    def __call__(self, x):
        return self.fn(jnp.asarray(x), self.params)


def compile_plan(sel: SelectionResult, raw_params: Dict[str, Dict],
                 jit: bool = True, fuse_across_layers: bool = False,
                 batch: int = 1, mesh: Optional[Any] = None) -> CompiledNet:
    """``fuse_across_layers=False`` (default) inserts optimization
    barriers between primitive calls: the paper's code generator emits
    *calls into a library of routines*, so no cross-layer fusion exists
    and per-layer profiled costs compose additively.  Letting XLA fuse
    across layers (True) breaks that additivity — useful as an extra
    baseline, but it is a different system than the paper's.

    ``batch > 1`` builds a *batched* executable: the single-image
    program is vmapped over a leading batch axis, so one invocation runs
    the whole tower for N images — per-image dispatch/packing overhead
    is paid once, which is exactly the amortization the batch-aware
    cost model prices (``Scenario.n``).  Input becomes (N, C, H, W) and
    every output gains a leading N axis.

    **Transform fusion pass.**  Edges the selection realized as fused
    (``sel.fusions``, see :func:`~repro.core.selection.select_pbqp` with
    ``fuse=True``) get no ``convert_layout`` dispatch at all: the
    consumer's maker is built via ``Primitive.make_fused`` to read the
    producer's layout in its prologue (kind ``"in"``), or the producer's
    to emit the consumer's layout in its epilogue (kind ``"out"``).  The
    fused call executes as ONE region — under the default per-layer
    barriers the transform can never be split back out into an HBM
    round trip.  The pass is orthogonal to ``fuse_across_layers`` and
    ``batch``: fused makers are emitted regardless of barrier placement
    and are vmap-safe, so all flag combinations compose.

    **Mesh-sharded executables.**  ``mesh`` (with ``batch > 1``)
    realizes the plan's device placements: nodes whose
    :class:`~repro.core.selection.Choice` carries ``placement="dp"``
    run batch-sharded over the mesh's ``data`` axis, ``"rep"`` nodes
    replicated.  An all-``dp`` plan compiles through ``shard_map`` (one
    per-shard vmapped program per device — the pure data-parallel fast
    path); any plan with a ``rep`` node compiles the batched program
    with one ``NamedSharding`` constraint per node, so GSPMD inserts
    exactly the resharding collectives the selection's edge costs
    priced.  Input is (N, C, H, W) as for any batched executable;
    callers pass host arrays and receive global (gathered-on-read)
    outputs, so a mesh executable is a drop-in for the single-device
    batched one (verified output-identical in tests/test_distributed.py).
    """
    _COMPILE_COUNTER.add()
    if batch < 1:
        raise ValueError(f"batch must be >= 1, got {batch}")
    if mesh is not None and batch < 2:
        raise ValueError("mesh-sharded executables are batched: pass "
                         "batch >= 2 (a single image cannot be sharded "
                         "over the data axis)")
    dp_nodes = 0
    d_mesh = 1
    if mesh is not None:
        mesh_shape = mesh_shape_dict(mesh)
        d_mesh = int(mesh_shape.get("data", 1))
        dp_nodes = sum(1 for ch in sel.choices.values()
                       if ch.placement == "dp")
        if dp_nodes and ("data" not in mesh_shape or batch % d_mesh):
            raise ValueError(
                f"plan has {dp_nodes} dp nodes but mesh {mesh_shape} "
                f"cannot shard batch {batch} over its 'data' axis")
    t0 = time.perf_counter()
    net = sel.net

    # fusion pass: effective wire layouts per conv node.  Kind "in"
    # means the consumer reads the producer's declared l_out; kind
    # "out" means the (single-consumer) producer emits the consumer's
    # l_in.  Selection guarantees an edge is fused or converted, never
    # both, so the two maps cannot conflict.
    fusions = sel.fusions
    eff_in: Dict[str, str] = {}
    eff_out: Dict[str, str] = {}
    for (src, dst), kind in fusions.items():
        if kind == "in":
            eff_in[dst] = sel.choices[src].l_out
        elif kind == "out":
            eff_out[src] = sel.choices[dst].l_in
        else:
            raise ValueError(f"unknown fusion kind {kind!r} on edge "
                             f"({src}, {dst})")

    packed: Dict[str, Any] = {}
    makers: Dict[str, Callable] = {}
    for nid in net.order:
        node = net.nodes[nid]
        ch = sel.choices[nid]
        if node.kind == "conv":
            p = raw_params[nid]
            packed[nid] = ch.primitive.prepare(node.scn, p["w"], p["b"])
            makers[nid] = ch.primitive.make_fused(
                node.scn, l_in=eff_in.get(nid, ch.l_in),
                l_out=eff_out.get(nid, ch.l_out))
        elif node.kind == "op" and nid in raw_params:
            packed[nid] = jax.tree.map(jnp.asarray, raw_params[nid])

    # Batched executables compile without the per-layer barriers: (a)
    # optimization_barrier has no vmap batching rule, and (b) the
    # barriers exist to keep per-layer *profiled* costs additive — a
    # measurement-methodology concern, while the batched path is a
    # throughput path where cross-layer fusion is desirable.
    barrier = (lambda v: v) if fuse_across_layers or batch > 1 else \
        (lambda v: jax.lax.optimization_barrier(v))

    if mesh is not None:
        fn, mode = _build_mesh_fn(sel, net, makers, mesh, d_mesh,
                                  dp_nodes, jit)
        cnet = CompiledNet(sel, fn, packed,
                           build_s=time.perf_counter() - t0, batch=batch,
                           fused_edges=len(fusions), mesh=mesh,
                           dp_nodes=dp_nodes, mesh_mode=mode,
                           makers=makers)
    else:
        run = _image_walker(sel, net, makers, barrier)
        if batch > 1:
            run = jax.vmap(run, in_axes=(0, None))
        fn = jax.jit(run) if jit else run
        cnet = CompiledNet(sel, fn, packed,
                           build_s=time.perf_counter() - t0,
                           batch=batch, fused_edges=len(fusions),
                           makers=makers)
    get_tracer().emit("compile", t0, time.perf_counter(),
                      nodes=len(net.order), batch=batch,
                      fused_edges=cnet.fused_edges,
                      mesh_mode=cnet.mesh_mode)
    return cnet


def _image_walker(sel: SelectionResult, net: Net,
                  makers: Dict[str, Callable],
                  barrier: Callable = lambda v: v) -> Callable:
    """The per-image DAG walk every executable variant shares: invoke
    the selected primitive per conv node, the op function per op node,
    the legalizer's conversion chains per mismatched edge, then convert
    outputs to logical CHW.  ``barrier`` wraps per-layer results (the
    paper's no-cross-layer-fusion discipline; identity for batched and
    mesh executables)."""
    def run(x, params):
        vals: Dict[str, Any] = {}
        for nid in net.order:
            node = net.nodes[nid]
            if node.kind == "input":
                vals[nid] = x  # inputs arrive in logical CHW
                continue
            ins = []
            for src in node.inputs:
                v = vals[src]
                chain = sel.conversions.get((src, nid))
                if chain:
                    for a, b in zip(chain, chain[1:]):
                        v = barrier(convert_layout(v, a, b))
                ins.append(v)
            if node.kind == "conv":
                vals[nid] = barrier(makers[nid](ins[0], params[nid]))
            else:
                layout = LAYOUT_BY_NAME[sel.choices[nid].l_in]
                vals[nid] = node.op.fn(ins, layout, params.get(nid))
        return {nid: convert_layout(vals[nid], sel.choices[nid].l_out,
                                    "CHW")
                for nid in net.outputs()}
    return run


def _build_mesh_fn(sel: SelectionResult, net: Net, makers: Dict[str,
                   Callable], mesh, d_mesh: int, dp_nodes: int,
                   jit: bool):
    """Emit the mesh-sharded executable for a placement-solved plan.

    Two modes (both barrier-free, like every batched executable):

    * ``shard_map`` — every node is ``dp``: split the batch once over
      the ``data`` axis and run the vmapped per-shard program
      (:func:`_image_walker`, the same walk the single-device
      executable runs) on each device.  No partitioner in the loop;
      the pure data-parallel serving fast path.
    * ``gspmd`` — mixed placements: run the batched program with one
      ``NamedSharding`` constraint per node, so GSPMD inserts exactly
      the resharding collectives the selection's edge costs priced
      (``dp -> rep``: all-gather; ``rep -> dp``: a local slice).  This
      walker is the batched per-node-vmap variant of the walk — the
      constraints must land on whole-batch values, so it cannot reuse
      the vmapped per-image program.
    """
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    if dp_nodes == len(net.order) and d_mesh > 1:
        from jax.experimental.shard_map import shard_map
        inner = jax.vmap(_image_walker(sel, net, makers),
                         in_axes=(0, None))
        fn = shard_map(inner, mesh=mesh, in_specs=(P("data"), P()),
                       out_specs=P("data"))
        return (jax.jit(fn) if jit else fn), "shard_map"

    def spec_of(nid: str) -> "NamedSharding":
        pl = sel.choices[nid].placement
        return NamedSharding(mesh, P("data") if pl == "dp" else P())

    def run_batched(x, params):
        vals: Dict[str, Any] = {}
        for nid in net.order:
            node = net.nodes[nid]
            ch = sel.choices[nid]
            if node.kind == "input":
                v = x
            else:
                ins = []
                for src in node.inputs:
                    vi = vals[src]
                    chain = sel.conversions.get((src, nid))
                    if chain:
                        for a, b in zip(chain, chain[1:]):
                            vi = jax.vmap(
                                lambda t, a=a, b=b:
                                convert_layout(t, a, b))(vi)
                    ins.append(vi)
                if node.kind == "conv":
                    v = jax.vmap(makers[nid], in_axes=(0, None))(
                        ins[0], params[nid])
                else:
                    layout = LAYOUT_BY_NAME[ch.l_in]
                    p = params.get(nid)
                    v = jax.vmap(
                        lambda *xs, op=node.op, lay=layout, p=p:
                        op.fn(list(xs), lay, p))(*ins)
            vals[nid] = jax.lax.with_sharding_constraint(v, spec_of(nid))
        return {nid: jax.vmap(
                    lambda t, lo=sel.choices[nid].l_out:
                    convert_layout(t, lo, "CHW"))(vals[nid])
                for nid in net.outputs()}

    return (jax.jit(run_batched) if jit else run_batched), "gspmd"


def measure(cnet: CompiledNet, x_chw: np.ndarray, *, reps: int = 5,
            warmup: int = 1) -> Dict[str, float]:
    """Wall-time one forward pass (the paper's whole-network benchmark:
    mean of ``reps`` iterations after warmup)."""
    x = jnp.asarray(x_chw)
    for _ in range(warmup):
        jax.block_until_ready(cnet.fn(x, cnet.params))
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(cnet.fn(x, cnet.params))
        times.append(time.perf_counter() - t0)
    return {"mean_s": float(np.mean(times)),
            "min_s": float(np.min(times)),
            "std_s": float(np.std(times))}
