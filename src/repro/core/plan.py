"""Execution of an instantiated DNN: the paper's "simple code generator
which emitted calls to primitive operations" — here it builds a single
jit'd function that walks the DAG in topological order, invoking the
selected primitive per conv layer and the explicit layout-conversion
chains the legalizer inserted on illegal edges.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .graph import Net
from .layouts import LAYOUT_BY_NAME
from .primitives import convert_layout
from .selection import SelectionResult

__all__ = ["compile_plan", "CompiledNet", "measure", "compile_count"]

#: process-wide count of compile_plan() calls — executable construction is
#: the expensive step the serving LRU exists to amortise, so tests and the
#: plan-cache benchmark assert on this.
_COMPILE_COUNT = 0


def compile_count() -> int:
    return _COMPILE_COUNT


@dataclass
class CompiledNet:
    sel: SelectionResult
    fn: Callable                      # (x, params) -> outputs dict
    params: Dict[str, Any]            # packed per-node parameters
    build_s: float = 0.0              # wall time of weight packing + wiring
    #: minibatch the executable was compiled for: 1 -> (C, H, W) in/out,
    #: > 1 -> (N, C, H, W) in and a leading N axis on every output
    batch: int = 1
    #: edges executed as fused prologues/epilogues instead of
    #: materialized convert_layout dispatches (observability for tests
    #: and the fusion benchmark)
    fused_edges: int = 0

    def __call__(self, x):
        return self.fn(jnp.asarray(x), self.params)


def compile_plan(sel: SelectionResult, raw_params: Dict[str, Dict],
                 jit: bool = True, fuse_across_layers: bool = False,
                 batch: int = 1) -> CompiledNet:
    """``fuse_across_layers=False`` (default) inserts optimization
    barriers between primitive calls: the paper's code generator emits
    *calls into a library of routines*, so no cross-layer fusion exists
    and per-layer profiled costs compose additively.  Letting XLA fuse
    across layers (True) breaks that additivity — useful as an extra
    baseline, but it is a different system than the paper's.

    ``batch > 1`` builds a *batched* executable: the single-image
    program is vmapped over a leading batch axis, so one invocation runs
    the whole tower for N images — per-image dispatch/packing overhead
    is paid once, which is exactly the amortization the batch-aware
    cost model prices (``Scenario.n``).  Input becomes (N, C, H, W) and
    every output gains a leading N axis.

    **Transform fusion pass.**  Edges the selection realized as fused
    (``sel.fusions``, see :func:`~repro.core.selection.select_pbqp` with
    ``fuse=True``) get no ``convert_layout`` dispatch at all: the
    consumer's maker is built via ``Primitive.make_fused`` to read the
    producer's layout in its prologue (kind ``"in"``), or the producer's
    to emit the consumer's layout in its epilogue (kind ``"out"``).  The
    fused call executes as ONE region — under the default per-layer
    barriers the transform can never be split back out into an HBM
    round trip.  The pass is orthogonal to ``fuse_across_layers`` and
    ``batch``: fused makers are emitted regardless of barrier placement
    and are vmap-safe, so all flag combinations compose."""
    global _COMPILE_COUNT
    _COMPILE_COUNT += 1
    if batch < 1:
        raise ValueError(f"batch must be >= 1, got {batch}")
    t0 = time.perf_counter()
    net = sel.net

    # fusion pass: effective wire layouts per conv node.  Kind "in"
    # means the consumer reads the producer's declared l_out; kind
    # "out" means the (single-consumer) producer emits the consumer's
    # l_in.  Selection guarantees an edge is fused or converted, never
    # both, so the two maps cannot conflict.
    fusions = sel.fusions
    eff_in: Dict[str, str] = {}
    eff_out: Dict[str, str] = {}
    for (src, dst), kind in fusions.items():
        if kind == "in":
            eff_in[dst] = sel.choices[src].l_out
        elif kind == "out":
            eff_out[src] = sel.choices[dst].l_in
        else:
            raise ValueError(f"unknown fusion kind {kind!r} on edge "
                             f"({src}, {dst})")

    packed: Dict[str, Any] = {}
    makers: Dict[str, Callable] = {}
    for nid in net.order:
        node = net.nodes[nid]
        ch = sel.choices[nid]
        if node.kind == "conv":
            p = raw_params[nid]
            packed[nid] = ch.primitive.prepare(node.scn, p["w"], p["b"])
            makers[nid] = ch.primitive.make_fused(
                node.scn, l_in=eff_in.get(nid, ch.l_in),
                l_out=eff_out.get(nid, ch.l_out))
        elif node.kind == "op" and nid in raw_params:
            packed[nid] = jax.tree.map(jnp.asarray, raw_params[nid])

    # Batched executables compile without the per-layer barriers: (a)
    # optimization_barrier has no vmap batching rule, and (b) the
    # barriers exist to keep per-layer *profiled* costs additive — a
    # measurement-methodology concern, while the batched path is a
    # throughput path where cross-layer fusion is desirable.
    barrier = (lambda v: v) if fuse_across_layers or batch > 1 else \
        (lambda v: jax.lax.optimization_barrier(v))

    def run(x, params):
        vals: Dict[str, Any] = {}
        for nid in net.order:
            node = net.nodes[nid]
            ch = sel.choices[nid]
            if node.kind == "input":
                vals[nid] = x  # inputs arrive in logical CHW
                continue
            ins = []
            for src in node.inputs:
                v = vals[src]
                chain = sel.conversions.get((src, nid))
                if chain:
                    for a, b in zip(chain, chain[1:]):
                        v = barrier(convert_layout(v, a, b))
                ins.append(v)
            if node.kind == "conv":
                vals[nid] = barrier(makers[nid](ins[0], params[nid]))
            else:
                layout = LAYOUT_BY_NAME[ch.l_in]
                vals[nid] = node.op.fn(ins, layout, params.get(nid))
        outs = {}
        for nid in net.outputs():
            v = vals[nid]
            lo = sel.choices[nid].l_out
            outs[nid] = convert_layout(v, lo, "CHW")
        return outs

    if batch > 1:
        run = jax.vmap(run, in_axes=(0, None))
    fn = jax.jit(run) if jit else run
    return CompiledNet(sel, fn, packed, build_s=time.perf_counter() - t0,
                       batch=batch, fused_edges=len(fusions))


def measure(cnet: CompiledNet, x_chw: np.ndarray, *, reps: int = 5,
            warmup: int = 1) -> Dict[str, float]:
    """Wall-time one forward pass (the paper's whole-network benchmark:
    mean of ``reps`` iterations after warmup)."""
    x = jnp.asarray(x_chw)
    for _ in range(warmup):
        jax.block_until_ready(cnet.fn(x, cnet.params))
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(cnet.fn(x, cnet.params))
        times.append(time.perf_counter() - t0)
    return {"mean_s": float(np.mean(times)),
            "min_s": float(np.min(times)),
            "std_s": float(np.std(times))}
