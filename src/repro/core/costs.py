"""Cost providers for the PBQP formulation.

Two interchangeable implementations of the paper's §3.1 cost stage:

* :class:`ProfiledCostModel` — measures actual execution time of every
  (primitive, scenario) pair and of every direct layout transformation
  on tensors of the real sizes, exactly as the paper does.  Results are
  cached on disk keyed by (primitive, scenario); layerwise profiling
  runs once per host and ships with the model.

* :class:`AnalyticCostModel` — deterministic roofline-style estimate
  (flops / effective-throughput + bytes / bandwidth with per-family
  efficiency factors).  Used in tests (fast, deterministic) and to price
  the TPU Pallas primitives that cannot be meaningfully timed on CPU.
  The paper notes "simple heuristics might be almost as effective" —
  this is that heuristic, and the benchmarks compare both.

A third implementation, :class:`~repro.calibrate.CalibratedCostModel`,
serves costs from a persisted, versioned :class:`~repro.calibrate.
HardwareProfile` built offline by the calibration sweep
(``python -m repro.launch.calibrate``) and falls back to the analytic
model for uncovered buckets.  It lives in :mod:`repro.calibrate` (which
imports this module, never the reverse); the shared measurement
discipline — :func:`time_callable`, :func:`measure_primitive`,
:func:`measure_transform` and the cache key helpers — is defined here so
both the online :class:`ProfiledCostModel` and the offline sweep time
things identically.  See docs/calibration.md.
"""
from __future__ import annotations

import hashlib
import json
import os
import pathlib
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .ioutil import atomic_write_text
from .layouts import LAYOUT_BY_NAME, DTGraph, default_dt_graph
from .primitives import Primitive, convert_layout, extension_token
from .scenario import Scenario

__all__ = ["CostModel", "ProfiledCostModel", "AnalyticCostModel",
           "COST_MODEL_SCHEMA", "FUSED_TRANSFORM_DISCOUNT", "time_callable",
           "measure_primitive", "measure_fused_primitive",
           "measure_transform", "prim_cost_key", "transform_cost_key",
           "fused_cost_key", "collective_cost_key", "ring_ag_bytes",
           "all_gather_time", "reduce_scatter_time", "all_reduce_time",
           "all_to_all_time", "send_time", "collective_time",
           "COLLECTIVE_KINDS"]

#: bump when the *meaning* of costs changes (units, conventions, embedding)
#: — persisted plan caches keyed on older schemas are invalidated.
#: 2: edges are priced min(materialized DT, fused prologue, fused
#:    epilogue) — plans solved under materialized-only pricing are stale.
#: 3: the placement axis covers {rep, dp, tp, pp}: tp nodes carry the
#:    channel all-gather, pp edges carry stage-boundary sends ("send"
#:    joined the collective kinds) — {dp, rep}-era plans are stale.
COST_MODEL_SCHEMA = 3

#: analytic estimate of how much of a materialized DT round trip a fused
#: prologue/epilogue still pays: the kernel's remapped read (or store)
#: covers the tensor once at strided bandwidth, while a materialized
#: transform pays a strided read + a write + its own dispatch.
FUSED_TRANSFORM_DISCOUNT = 0.25


class CostModel:
    """Interface: primitive cost + DT graph with transform costs."""

    def primitive_cost(self, prim: Primitive, scn: Scenario) -> float:
        raise NotImplementedError

    def transform_cost(self, src: str, dst: str,
                       shape_chw: Tuple[int, int, int], dtype) -> float:
        raise NotImplementedError

    # -------------------------------------------------------------
    # fused-edge pricing (per image; the PBQP edge builder scales by
    # the net's minibatch exactly as it does materialized DT costs)
    # -------------------------------------------------------------
    def fused_in_cost(self, prim: Primitive, scn: Scenario,
                      l_src: str) -> float:
        """Extra cost of ``prim`` reading ``l_src``-layout input in its
        prologue instead of its native ``l_in`` (no materialized DT).

        Default heuristic: a fused prologue is one remapped pass over
        the tensor, a fixed fraction of the materialized round trip.
        Capability (``l_src in prim.fusable_in``) is the *selection*
        layer's concern; this prices the transform assuming it fuses.
        """
        if l_src == prim.l_in:
            return 0.0
        return FUSED_TRANSFORM_DISCOUNT * self.transform_cost(
            l_src, prim.l_in, scn.in_shape_chw, scn.dtype)

    def fused_out_cost(self, prim: Primitive, scn: Scenario,
                       l_dst: str) -> float:
        """Extra cost of ``prim`` emitting ``l_dst`` in its epilogue."""
        if l_dst == prim.l_out:
            return 0.0
        return FUSED_TRANSFORM_DISCOUNT * self.transform_cost(
            prim.l_out, l_dst, scn.out_shape_chw, scn.dtype)

    # -------------------------------------------------------------
    # collective pricing (the transform kind of the distributed world:
    # resharding between device placements / sharding rules)
    # -------------------------------------------------------------
    def hardware_spec(self) -> "HardwareSpec":
        """The hardware this model prices; drives collective costs.

        Defaults to the generic CPU spec — models that know their
        target (:class:`AnalyticCostModel`) override this.
        """
        return CPU_SPEC

    def collective_cost(self, kind: str, nbytes: float, n: int) -> float:
        """Seconds for one ``kind`` collective of ``nbytes`` (global
        tensor bytes) over ``n`` chips.  Analytic ring-model default;
        :class:`repro.calibrate.CalibratedCostModel` overrides it to
        serve measured pod timings (``coll::…`` profile entries)."""
        return collective_time(self.hardware_spec(), kind, nbytes, n)

    def dt_graph(self) -> DTGraph:
        """The library's DT graph priced by this model's transform_cost."""
        g = default_dt_graph()
        out = DTGraph()
        for (s, t) in g.direct_edges:
            out.add_transform(
                s, t,
                lambda shape, dtype, s=s, t=t:
                    self.transform_cost(s, t, shape, dtype))
        return out

    # -------------------------------------------------------------
    def version(self) -> str:
        """Cache-version fingerprint of this cost model.

        Any change that could alter a primitive's cost (model class,
        hardware spec, schema) must change this string: the serving plan
        cache (repro/serving/plan_cache.py) keys persisted PBQP solutions
        on it, so a stale cost model can never serve a stale plan.

        The registry extension token is folded in for every model: a
        solve's choice space is the registry, so installing/removing an
        autotuned variant catalog (``primitives.register_extension``)
        must rotate every cached plan key even though no individual cost
        changed.
        """
        return _digest(f"schema{COST_MODEL_SCHEMA}", type(self).__name__,
                       f"ext={extension_token()}", self._version_fields())

    def _version_fields(self) -> str:
        """Subclass hook: stringify everything costs depend on."""
        return ""


def _digest(*parts: str) -> str:
    h = hashlib.sha256("|".join(parts).encode()).hexdigest()[:16]
    return h


# ----------------------------------------------------------------------
# measurement discipline (shared by ProfiledCostModel and repro.calibrate)
# ----------------------------------------------------------------------
def time_callable(fn, args, *, reps: int = 3, min_time: float = 5e-3,
                  warmup: int = 1) -> float:
    """Median-of-reps wall time of a jit'd callable (seconds).

    ``warmup`` untimed calls absorb compilation and first-touch effects;
    each of the ``reps`` timed repetitions then loops the call until at
    least ``min_time`` seconds elapse (amortizing dispatch overhead for
    microsecond-scale kernels) and records the mean per-call time.  The
    median across repetitions is robust to one-off scheduling noise.
    """
    for _ in range(max(warmup, 1)):
        out = fn(*args)
    jax.block_until_ready(out)
    times = []
    for _ in range(reps):
        n = 0
        t0 = time.perf_counter()
        el = 0.0
        while el < min_time:
            jax.block_until_ready(fn(*args))
            n += 1
            el = time.perf_counter() - t0
        times.append(el / n)
    return float(np.median(times))


#: backwards-compatible private alias (pre-calibration name)
_time_fn = time_callable


def prim_cost_key(name: str, scn: Scenario) -> str:
    """Cache/profile entry key for one (primitive, scenario) pair."""
    return f"prim::{name}::{scn.key()}"


def transform_cost_key(src: str, dst: str,
                       shape_chw: Tuple[int, int, int]) -> str:
    """Cache/profile entry key for one direct layout transform."""
    return f"dt::{src}->{dst}::{'x'.join(map(str, shape_chw))}"


def fused_cost_key(kind: str, name: str, layout: str, scn: Scenario) -> str:
    """Cache/profile entry key for one fused (primitive, layout) pair.

    ``kind`` is ``"in"`` (prologue reads ``layout``) or ``"out"``
    (epilogue emits ``layout``); the stored value is the *whole fused
    invocation* time — the fused-edge delta is recovered against the
    primitive's native ``prim_cost_key`` entry at lookup time.
    """
    if kind not in ("in", "out"):
        raise ValueError(f"kind must be 'in' or 'out', got {kind!r}")
    return f"fuse{kind}::{name}::{layout}::{scn.key()}"


def measure_primitive(prim: Primitive, scn: Scenario, *, reps: int = 3,
                      min_time: float = 5e-3) -> float:
    """On-device wall time of one (primitive, scenario) pair (seconds).

    Inputs/weights are synthesized at the scenario's real sizes, packed
    once via ``prim.prepare`` (deployment-time work, excluded from the
    measurement, as the paper ships pre-packed weights), and the jit'd
    routine is timed under :func:`time_callable`'s warmup/median-of-reps
    discipline.

    For ``scn.n > 1`` the primitive is vmapped over a leading batch axis
    and the *whole batched invocation* is timed — the same execution
    shape the batched serving path compiles (`core.plan.compile_plan`
    with ``batch > 1``), so calibrated batched costs price exactly what
    serving runs.
    """
    rng = np.random.default_rng(0)
    w = (rng.normal(size=scn.weight_shape) * 0.1).astype(np.float32)
    b = rng.normal(size=(scn.m,)).astype(np.float32)
    packed = prim.prepare(scn, w, b)
    layout = LAYOUT_BY_NAME[prim.l_in]
    if scn.n == 1:
        x = rng.normal(size=scn.in_shape_chw).astype(np.float32)
        xin = jnp.asarray(layout.to_memory(x))
        fn = jax.jit(prim.make(scn))
    else:
        xs = rng.normal(size=scn.in_shape_nchw).astype(np.float32)
        xin = jnp.asarray(np.stack([layout.to_memory(x) for x in xs]))
        fn = jax.jit(jax.vmap(prim.make(scn), in_axes=(0, None)))
    return time_callable(fn, (xin, packed), reps=reps, min_time=min_time)


def measure_fused_primitive(prim: Primitive, scn: Scenario, *,
                            l_in: Optional[str] = None,
                            l_out: Optional[str] = None,
                            reps: int = 3, min_time: float = 5e-3) -> float:
    """On-device wall time of one *fused* invocation (seconds).

    Same discipline as :func:`measure_primitive`, but the input is
    synthesized in the fused ``l_in`` layout and the timed callable is
    ``prim.make_fused(scn, l_in, l_out)`` — the exact program the fused
    execution path compiles, so measured fused-edge deltas price what
    serving runs.
    """
    rng = np.random.default_rng(0)
    w = (rng.normal(size=scn.weight_shape) * 0.1).astype(np.float32)
    b = rng.normal(size=(scn.m,)).astype(np.float32)
    packed = prim.prepare(scn, w, b)
    layout = LAYOUT_BY_NAME[l_in or prim.l_in]
    make = lambda: prim.make_fused(scn, l_in=l_in, l_out=l_out)
    if scn.n == 1:
        x = rng.normal(size=scn.in_shape_chw).astype(np.float32)
        xin = jnp.asarray(layout.to_memory(x))
        fn = jax.jit(make())
    else:
        xs = rng.normal(size=scn.in_shape_nchw).astype(np.float32)
        xin = jnp.asarray(np.stack([layout.to_memory(x) for x in xs]))
        fn = jax.jit(jax.vmap(make(), in_axes=(0, None)))
    return time_callable(fn, (xin, packed), reps=reps, min_time=min_time)


def measure_transform(src: str, dst: str,
                      shape_chw: Tuple[int, int, int], *, reps: int = 3,
                      min_time: float = 5e-3) -> float:
    """On-device wall time of one direct layout transform (seconds)."""
    rng = np.random.default_rng(0)
    x = rng.normal(size=shape_chw).astype(np.float32)
    xin = jnp.asarray(LAYOUT_BY_NAME[src].to_memory(x))
    fn = jax.jit(lambda a: convert_layout(a, src, dst))
    return time_callable(fn, (xin,), reps=reps, min_time=min_time)


class ProfiledCostModel(CostModel):
    def __init__(self, cache_path: Optional[str] = None, *,
                 reps: int = 3, min_time: float = 5e-3,
                 exclude_tags: Tuple[str, ...] = ("tpu-only",),
                 verbose: bool = False):
        self.reps = reps
        self.min_time = min_time
        self.exclude_tags = exclude_tags
        self.verbose = verbose
        self.cache_path = pathlib.Path(
            cache_path or os.environ.get(
                "REPRO_PROFILE_CACHE",
                pathlib.Path.home() / ".cache" / "repro_profile.json"))
        self._cache: Dict[str, float] = {}
        if self.cache_path.exists():
            self._cache = json.loads(self.cache_path.read_text())
        self._dirty = 0

    # -------------------------------------------------------------
    def _save(self):
        self.cache_path.parent.mkdir(parents=True, exist_ok=True)
        atomic_write_text(self.cache_path, json.dumps(self._cache))
        self._dirty = 0

    def flush(self):
        if self._dirty:
            self._save()

    def _version_fields(self) -> str:
        # Profiled numbers ARE the cost model: hash the measurements
        # themselves, so re-profiling (new host, deleted cache) can never
        # serve plans that were optimal only for the old numbers.  The
        # price is that refining the profile with new entries also
        # invalidates — a re-solve per bucket, which is milliseconds.
        content = hashlib.sha256(
            json.dumps(sorted(self._cache.items())).encode()).hexdigest()[:16]
        return (f"profile={content}|reps={self.reps}"
                f"|min_time={self.min_time}|excl={sorted(self.exclude_tags)}")

    def primitive_cost(self, prim: Primitive, scn: Scenario) -> float:
        if any(t in prim.tags for t in self.exclude_tags):
            return float("inf")
        key = prim_cost_key(prim.name, scn)
        if key in self._cache:
            return self._cache[key]
        t = measure_primitive(prim, scn, reps=self.reps,
                              min_time=self.min_time)
        if self.verbose:
            print(f"  profiled {prim.name} on {scn.key()}: {t*1e3:.3f} ms")
        self._cache[key] = t
        self._dirty += 1
        if self._dirty >= 20:
            self._save()
        return t

    def transform_cost(self, src: str, dst: str,
                       shape_chw: Tuple[int, int, int], dtype) -> float:
        from .layouts import transform_feasible
        if not transform_feasible(src, dst, shape_chw):
            return float("inf")
        key = transform_cost_key(src, dst, shape_chw)
        if key in self._cache:
            return self._cache[key]
        t = measure_transform(src, dst, shape_chw, reps=self.reps,
                              min_time=self.min_time)
        self._cache[key] = t
        self._dirty += 1
        if self._dirty >= 20:
            self._save()
        return t

    # -------------------------------------------------------------
    def _fused_cost(self, kind: str, prim: Primitive, scn: Scenario,
                    layout: str) -> float:
        """Measured fused-edge delta: fused invocation − native, >= 0.

        Measured per image (n=1) like the DT transforms — the selection
        layer scales edge matrices by the net's minibatch.
        """
        if any(t in prim.tags for t in self.exclude_tags):
            return float("inf")
        from .layouts import transform_feasible
        native = prim.l_in if kind == "in" else prim.l_out
        shape = scn.in_shape_chw if kind == "in" else scn.out_shape_chw
        if layout == native:
            return 0.0
        if not transform_feasible(layout, native, shape):
            return float("inf")
        scn1 = scn.with_(n=1)
        key = fused_cost_key(kind, prim.name, layout, scn1)
        if key not in self._cache:
            kw = {"l_in": layout} if kind == "in" else {"l_out": layout}
            t = measure_fused_primitive(prim, scn1, reps=self.reps,
                                        min_time=self.min_time, **kw)
            if self.verbose:
                print(f"  profiled fuse-{kind} {prim.name} <- {layout} on "
                      f"{scn1.key()}: {t*1e3:.3f} ms")
            self._cache[key] = t
            self._dirty += 1
            if self._dirty >= 20:
                self._save()
        return max(0.0, self._cache[key] - self.primitive_cost(prim, scn1))

    def fused_in_cost(self, prim: Primitive, scn: Scenario,
                      l_src: str) -> float:
        return self._fused_cost("in", prim, scn, l_src)

    def fused_out_cost(self, prim: Primitive, scn: Scenario,
                       l_dst: str) -> float:
        return self._fused_cost("out", prim, scn, l_dst)


# ----------------------------------------------------------------------
@dataclass
class HardwareSpec:
    name: str
    peak_flops: float          # f32 FLOP/s
    mem_bw: float              # B/s
    #: per-chip interconnect bandwidth (B/s, one direction): ICI links on
    #: a TPU pod, shared-memory "fabric" between fake CPU devices.  0
    #: means no fabric — every collective prices infinite, so selection
    #: can never pick a sharded choice on fabric-less hardware.
    link_bw: float = 0.0
    #: fraction of peak a family's GEMM-ish inner loop typically reaches
    family_eff: Dict[str, float] = field(default_factory=dict)
    #: per-*invocation* setup seconds (buffer allocation, GEMM/FFT
    #: planning, tile-transform dispatch) — paid once per call, so it
    #: amortizes over the minibatch.  This is the term that makes the
    #: optimal primitive flip with N: GEMM-based methods pay a large
    #: setup that a batch spreads out, direct loops barely any.
    family_setup: Dict[str, float] = field(default_factory=dict)


CPU_SPEC = HardwareSpec(
    name="cpu-generic",
    peak_flops=1.0e11,
    mem_bw=2.0e10,
    link_bw=1.0e10,            # fake-device "fabric": memcpy through RAM
    family_eff={"direct": 0.30, "im2": 0.55, "kn2": 0.50,
                "winograd": 0.45, "fft": 0.35, "pallas": 0.0},
    family_setup={"direct": 1e-6, "im2": 2e-5, "kn2": 1.5e-5,
                  "winograd": 3e-5, "fft": 4e-5, "pallas": 0.0},
)

TPU_V5E_SPEC = HardwareSpec(
    name="tpu-v5e",
    peak_flops=197e12 / 2,     # bf16 peak halved as an f32-ish proxy
    mem_bw=819e9,
    link_bw=50e9,              # ICI, per chip per direction
    family_eff={"direct": 0.45, "im2": 0.65, "kn2": 0.55,
                "winograd": 0.55, "fft": 0.25, "pallas": 0.70},
    family_setup={"direct": 2e-6, "im2": 5e-6, "kn2": 5e-6,
                  "winograd": 8e-6, "fft": 1e-5, "pallas": 3e-6},
)


# ----------------------------------------------------------------------
# collective pricing (shared by sharding selection, the placement axis
# of layout selection, and CalibratedCostModel's fallback path)
# ----------------------------------------------------------------------
def ring_ag_bytes(nbytes: float, n: int) -> float:
    """Ring all-gather over ``n`` chips moves (n-1)/n of the tensor per
    link (same bytes for its mirror image, reduce-scatter)."""
    return float(nbytes) * (n - 1) / max(n, 1)


def all_gather_time(spec: HardwareSpec, nbytes: float, n: int) -> float:
    """Ring all-gather seconds for an ``nbytes`` *global* tensor."""
    if n <= 1:
        return 0.0
    if spec.link_bw <= 0:
        return float("inf")
    return ring_ag_bytes(nbytes, n) / spec.link_bw


def reduce_scatter_time(spec: HardwareSpec, nbytes: float, n: int) -> float:
    """Ring reduce-scatter: byte-symmetric with the all-gather."""
    return all_gather_time(spec, nbytes, n)


def all_reduce_time(spec: HardwareSpec, nbytes: float, n: int) -> float:
    """Ring all-reduce = reduce-scatter + all-gather."""
    return 2.0 * all_gather_time(spec, nbytes, n)


def all_to_all_time(spec: HardwareSpec, nbytes: float, n: int) -> float:
    """All-to-all: every chip ships ~its whole shard across the fabric
    (the MoE dispatch/combine pattern)."""
    if n <= 1:
        return 0.0
    if spec.link_bw <= 0:
        return float("inf")
    return float(nbytes) / spec.link_bw


def send_time(spec: HardwareSpec, nbytes: float, n: int) -> float:
    """Point-to-point activation transfer (the pipeline stage-boundary
    hop): the whole tensor crosses one link.  ``n`` is the number of
    participants — a 1-wide group is a no-op transfer and must price
    0.0 so degenerate meshes stay exactly rep-equivalent."""
    if n <= 1:
        return 0.0
    if spec.link_bw <= 0:
        return float("inf")
    return float(nbytes) / spec.link_bw


COLLECTIVE_KINDS = {
    "all_gather": all_gather_time,
    "reduce_scatter": reduce_scatter_time,
    "all_reduce": all_reduce_time,
    "all_to_all": all_to_all_time,
    "send": send_time,
}


def collective_time(spec: HardwareSpec, kind: str, nbytes: float,
                    n: int) -> float:
    """Analytic time of one collective over ``n`` chips (seconds)."""
    try:
        fn = COLLECTIVE_KINDS[kind]
    except KeyError:
        raise ValueError(f"unknown collective kind {kind!r}; "
                         f"one of {sorted(COLLECTIVE_KINDS)}") from None
    return fn(spec, nbytes, n)


def collective_cost_key(kind: str, nbytes: int, n: int) -> str:
    """Cache/profile entry key for one measured collective.

    ``nbytes`` should be bucketed (pow2) by the caller so one pod sweep
    covers every payload size serving produces; stored value is seconds
    for the whole collective over ``n`` participants.
    """
    if kind not in COLLECTIVE_KINDS:
        raise ValueError(f"unknown collective kind {kind!r}; "
                         f"one of {sorted(COLLECTIVE_KINDS)}")
    return f"coll::{kind}::b{int(nbytes)}::n{int(n)}"


#: per-grid-step dispatch cost of a Pallas kernel (seconds): each tile
#: of the grid pays a fetch/issue overhead, so undersized tiles on large
#: problems price slower — the term that bounds how small a useful
#: autotuned block can be.
PALLAS_GRID_STEP_S = 2e-8


def _tile_waste(dim: int, b: int) -> float:
    """Flop inflation from padding ``dim`` up to a multiple of ``b``."""
    if dim <= 0:
        return 1.0
    return (-(-dim // b) * b) / dim


def _tile_steps(dim: int, b: int) -> int:
    return max(1, -(-dim // b))


def _clamp_block(b: int, dim: int) -> int:
    """The block size the kernel wrappers actually run: requested block
    clamped to the (>=8) problem dim — mirrors ``min(b, max(8, dim))``
    in every ``repro.kernels.*.ops`` wrapper."""
    return min(int(b), max(8, int(dim)))


def _lane_eff(b: int) -> float:
    """MXU efficiency of a tile whose minor (lane) extent is ``b``."""
    return 1.0 if b % 128 == 0 else (0.9 if b % 8 == 0 else 0.7)


def _sublane_eff(b: int) -> float:
    return 1.0 if b % 8 == 0 else 0.75


class AnalyticCostModel(CostModel):
    """Roofline estimate of one (possibly batched) invocation:

        t = max(N*flops / (eff * peak), (N*act_bytes + w_bytes) / bw)
            + setup

    with per-family algorithmic flop counts (Winograd/FFT discounts,
    im2col Toeplitz traffic, ...).  Activation traffic scales with the
    minibatch N (= ``scn.n``); weight traffic and the per-invocation
    ``setup`` do not — the two asymmetries that make primitive selection
    batch-dependent."""

    def __init__(self, spec: HardwareSpec = CPU_SPEC,
                 include_tpu_only: bool = False):
        self.spec = spec
        self.include_tpu_only = include_tpu_only

    def _version_fields(self) -> str:
        s = self.spec
        eff = ",".join(f"{k}={v}" for k, v in sorted(s.family_eff.items()))
        setup = ",".join(f"{k}={v}"
                         for k, v in sorted(s.family_setup.items()))
        return (f"spec={s.name}|flops={s.peak_flops}|bw={s.mem_bw}"
                f"|link={s.link_bw}|{eff}"
                f"|setup={setup}|tpu={self.include_tpu_only}")

    def hardware_spec(self) -> HardwareSpec:
        return self.spec

    def _alg_flops_bytes(self, prim: Primitive, scn: Scenario):
        """(total flops, per-image activation bytes, weight bytes)."""
        el = 4  # f32
        act_bytes = el * (np.prod(scn.in_shape_chw) +
                          np.prod(scn.out_shape_chw))
        w_bytes = el * np.prod(scn.weight_shape)
        f = float(scn.flops)  # whole batch (scn.macs includes n)
        fam = prim.family
        if fam == "winograd":
            # m^2 outputs per alpha^2 multiplies (2-D); 1-D variants save
            # less.  Extract tile size from the name (wino{1,2}d_f{m}x{k}).
            m_ = int(prim.name.split("_f")[1][0])
            a = m_ + scn.k - 1
            if "2d" in prim.name:
                f = f * (a * a) / (m_ * m_ * scn.k * scn.k)
                f += 2.0 * el * np.prod(scn.in_shape_nchw)  # transforms
            else:
                f = f * a / (m_ * scn.k)
            act_bytes *= 2.5  # tile workspace traffic
            w_bytes *= 2.5
        elif fam == "fft":
            c, h, w = scn.in_shape_chw
            npix = (h + scn.k) * (w + scn.k)
            f = scn.n * (10.0 * npix * np.log2(max(npix, 2))
                         * (scn.c + scn.m) + 8.0 * npix * scn.c * scn.m)
            act_bytes *= 3.0
            w_bytes *= 3.0
        elif fam == "im2":
            act_bytes += el * scn.k * scn.k * np.prod(scn.in_shape_chw)
            if "split" in prim.name:
                act_bytes *= 0.6
                w_bytes *= 0.6
        elif fam == "kn2":
            act_bytes += el * scn.k * scn.k * np.prod(scn.out_shape_chw)
        elif fam == "direct":
            if "sum2d" in prim.name:
                f *= 4.0   # per-channel dispatch overhead
            if "shift" in prim.name:
                act_bytes += el * scn.k * scn.k * np.prod(scn.out_shape_chw)
        elif fam == "pallas":
            # the Pallas kernels inherit their algorithmic cousins'
            # traffic/flop shapes: the im2col GEMM materializes a
            # K^2-inflated Toeplitz matrix through HBM, Winograd trades
            # a flop discount for transform workspace traffic, and the
            # direct/pointwise kernels stream the VMEM-resident strip
            # with no extra HBM traffic.
            if "im2col" in prim.name:
                act_bytes += el * scn.k * scn.k * np.prod(scn.in_shape_chw)
            elif "wino" in prim.name:
                m_ = int(prim.name.split("_f")[1][0])
                a = m_ + scn.k - 1
                f = f * (a * a) / (m_ * m_ * scn.k * scn.k)
                f += 2.0 * el * np.prod(scn.in_shape_nchw)
                act_bytes *= 2.5
                w_bytes *= 2.5
        return f, float(act_bytes), float(w_bytes)

    def _pallas_tile_terms(self, prim: Primitive, scn: Scenario):
        """(flop waste, MXU alignment efficiency, extra setup seconds)
        of a Pallas kernel's tiling at this scenario.

        Generated variants carry their block sizes in ``prim.params``;
        hand-written entries price at the wrappers' 128-defaults.  Both
        go through the same clamping the ops wrappers apply, so the
        model prices the tiles the kernel actually runs: padding waste
        (dims rounded up to tile multiples burn real MXU cycles on
        zeros), lane/sublane alignment (tiles off the (8, 128) register
        tiling stall the MXU), and per-grid-step dispatch (the
        software-pipeline depth cost of slicing a problem into many
        tiny tiles).
        """
        p = dict(prim.params)
        name = prim.name
        ohow = scn.out_h * scn.out_w
        if "pw_gemm" in name or "im2col" in name:
            kdim = scn.c if "pw_gemm" in name else scn.c * scn.k * scn.k
            bm = _clamp_block(p.get("bm", 128), scn.m)
            bn = _clamp_block(p.get("bn", 128), ohow)
            bk = _clamp_block(p.get("bk", 128), kdim)
            waste = (_tile_waste(scn.m, bm) * _tile_waste(ohow, bn)
                     * _tile_waste(kdim, bk))
            align = _lane_eff(bn) * _lane_eff(bk) * _sublane_eff(bm)
            steps = (_tile_steps(scn.m, bm) * _tile_steps(ohow, bn)
                     * _tile_steps(kdim, bk))
        elif "wino" in name:
            m_ = int(name.split("_f")[1][0])
            a = m_ + scn.k - 1
            ntiles = -(-scn.out_h // m_) * -(-scn.out_w // m_)
            bn = _clamp_block(p.get("bn", 128), ntiles)
            bc = _clamp_block(p.get("bc", 128), scn.c)
            waste = _tile_waste(ntiles, bn) * _tile_waste(scn.c, bc)
            align = _lane_eff(bn) * _sublane_eff(bc)
            steps = a * a * _tile_steps(ntiles, bn) * _tile_steps(scn.c, bc)
        elif "direct" in name:
            bm = _clamp_block(p.get("bm", 128), scn.m)
            kk = scn.k * scn.k
            waste = _tile_waste(scn.m, bm)
            align = _lane_eff(bm)
            steps = _tile_steps(scn.m, bm) * kk
            if p.get("unroll", 1):
                if kk >= 25:  # 5x5 fully unrolled: code-size pressure
                    align *= 0.95
            else:  # rolled tap loop: per-tap control flow
                steps += 4 * kk
        else:
            return 1.0, 1.0, 0.0
        return waste, align, PALLAS_GRID_STEP_S * steps * scn.n

    def primitive_cost(self, prim: Primitive, scn: Scenario) -> float:
        if "tpu-only" in prim.tags and not self.include_tpu_only:
            return float("inf")
        eff = self.spec.family_eff.get(prim.family, 0.3)
        if eff <= 0:
            return float("inf")
        f, act_b, w_b = self._alg_flops_bytes(prim, scn)
        setup = self.spec.family_setup.get(prim.family, 0.0)
        if prim.family == "pallas":
            waste, align, extra = self._pallas_tile_terms(prim, scn)
            f *= waste
            eff *= align
            setup += extra
        return max(f / (eff * self.spec.peak_flops),
                   (scn.n * act_b + w_b) / self.spec.mem_bw) + setup

    def transform_cost(self, src, dst, shape_chw, dtype) -> float:
        """Cost of transforming ONE image; the PBQP edge builder scales
        by the net's minibatch (see ``core.selection._build``)."""
        from .layouts import transform_feasible
        if not transform_feasible(src, dst, shape_chw):
            return float("inf")
        nbytes = 4 * int(np.prod(shape_chw))
        return 2 * nbytes / (0.25 * self.spec.mem_bw)
