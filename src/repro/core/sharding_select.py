"""PBQP sharding selection — the paper's technique at datacenter scale.

The exact analogy (docs/distributed.md §Technique mapping):

  CPU world (paper)                  TPU-pod world (this module)
  -----------------                  ---------------------------
  data layout of a tensor            PartitionSpec of a tensor
  primitive {L_in, P, L_out}         op variant + sharding rule-set
  layout transform routine           resharding collective
  DT-graph APSP cost                 collective bytes / link bandwidth
  profiled layer cost                analytic compute+comm time per rule

PBQP nodes are the tensor groups of one transformer program (embed,
residual stream, attention, FFN/MoE, head, kv-cache); domains are
feasibility-filtered sharding rule-sets; node costs price the
collectives a rule implies *inside* its group (e.g. Megatron row-
parallel out-proj => per-layer all-reduce of the activations); edge
costs price the resharding between adjacent groups (the "layout
transformation" of the distributed world).  The instance is built
through the same unified choice-space bridge
(:mod:`repro.core.choice_space`) the layout-level selection uses, and
the same exact solver the paper uses for CPU layouts finds the global
optimum.

Hardware comes from a :class:`~repro.core.costs.HardwareSpec` (default
:data:`~repro.core.costs.TPU_V5E_SPEC`): ``peak_flops`` is the
achievable matmul rate (the spec's f32-proxy peak — for TPU v5e the
bf16 peak halved, i.e. the old hardcoded 0.5-MXU-efficiency constant),
``mem_bw`` prices replicated reads, ``link_bw`` prices every collective
via the shared helpers in :mod:`repro.core.costs`.  A calibrated
profile can therefore re-price the whole instance for a different pod.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..models.sharding import MEGATRON_RULES, Rules
from . import pbqp
from .choice_space import ChoiceEdge, ChoiceNode, build_pbqp, drop_infinite
from .costs import (
    TPU_V5E_SPEC, HardwareSpec, all_gather_time, all_reduce_time,
    all_to_all_time, reduce_scatter_time, send_time,
)

__all__ = ["select_rules", "candidate_report", "ShardingChoice"]


@dataclass(frozen=True)
class ShardingChoice:
    name: str
    #: logical-axis updates this choice contributes to the global Rules
    updates: Tuple[Tuple[str, object], ...]
    #: activation "layout" on the residual stream this choice assumes
    #: ("rep" replicated over model axis, "sp" sequence-sharded)
    stream: str = "rep"


def _bytes(*dims, dtype_bytes=2):
    return float(np.prod(dims)) * dtype_bytes


def _mesh_size(mesh_shape: Dict[str, int], axis) -> int:
    if axis is None:
        return 1
    axes = (axis,) if isinstance(axis, str) else axis
    return int(np.prod([mesh_shape[a] for a in axes]))


def select_rules(cfg, shape, mesh_shape: Dict[str, int], *,
                 spec: HardwareSpec = TPU_V5E_SPEC,
                 exact: bool = True, fsdp: bool = False,
                 return_solution: bool = False):
    """Solve the sharding PBQP for (arch, shape) on a mesh.

    Returns (Rules, report) where report logs domains, costs and the
    chosen assignment (consumed by EXPERIMENTS.md §Perf).
    """
    tp = mesh_shape.get("model", 1)
    dp = _mesh_size(mesh_shape, tuple(a for a in ("pod", "data")
                                      if a in mesh_shape))
    b_local = max(shape.global_batch // dp, 1)
    t = shape.seq_len if shape.kind != "decode" else 1
    d, v = cfg.d_model, cfg.vocab
    hd = cfg.resolved_head_dim if cfg.n_heads else 0
    nl = cfg.n_layers
    act = _bytes(b_local, t, d)          # residual activation per device

    bwd = 3.0 if shape.kind == "train" else 1.0  # fwd+bwd flops factor

    def mm_time(flops: float, ways: int) -> float:
        """Matmul time when sharded ``ways`` ways (``spec.peak_flops``
        is the achievable-rate proxy, MXU efficiency included)."""
        return bwd * flops / (max(ways, 1) * spec.peak_flops)

    def xfer(nbytes: float, n: int) -> float:
        """Naive (non-ring) fabric transfer over an ``n``-wide group:
        the one-exchange collectives below that don't follow the ring
        model.  Routed through the shared guarded helper so a 1-wide
        group prices 0.0 — exactly rep-equivalent — and a fabric-less
        spec (``link_bw == 0``) prices infinite; selection then
        replicates.  (Regression: this once divided by ``link_bw``
        unconditionally, so a degenerate tp=1 mesh still paid fabric
        time and could flip plans away from the rep optimum.)"""
        return send_time(spec, nbytes, n)

    nodes: List[ChoiceNode] = []
    domains: Dict[str, List[ShardingChoice]] = {}

    def add(node: str, choices: List[Tuple[ShardingChoice, float]]):
        choices = drop_infinite(choices)
        domains[node] = [c for c, _ in choices]
        nodes.append(ChoiceNode(node, [c for c, _ in choices],
                                [c for _, c in choices]))

    # ---------------- embed ----------------
    emb = []
    if v % tp == 0:
        # vocab-sharded gather -> all-reduce of the (b,t,d) activations
        # (naive, not ring: the partitioner reassembles the one-hot
        # gather output in a single exchange)
        emb.append((ShardingChoice("embed:vocab", (("vocab", "model"),)),
                    xfer(2 * act, tp)))
    if d % tp == 0:
        emb.append((ShardingChoice("embed:dmodel",
                                   (("vocab", None),)),  # d sharded in rule
                    all_gather_time(spec, act, tp)))
    emb.append((ShardingChoice("embed:rep", (("vocab", None),)),
                0.0))  # replicated: no collective
    add("embed", emb)

    # ---------------- attention (or mamba mixer) ----------------
    attn = []
    n_tok = b_local * t
    if cfg.is_attention_free:
        d_inner = cfg.ssm_expand * d
        h_ssm = d_inner // cfg.ssm_headdim
        f_ssm = 2 * n_tok * d * (2 * d_inner + 2 * cfg.ssm_state) * nl
        if h_ssm % tp == 0:
            attn.append((ShardingChoice(
                "mixer:ssm_heads", (("ssm_heads", "model"),)),
                mm_time(f_ssm, tp) + nl * xfer(2 * act, tp)))
        attn.append((ShardingChoice("mixer:rep", (("ssm_heads", None),)),
                     mm_time(f_ssm, 1)))
    else:
        # projections + score/PV flops per layer stack
        f_proj = 2 * n_tok * d * (cfg.n_heads + 2 * cfg.n_kv_heads +
                                  cfg.n_heads) * hd * nl
        kv_len = shape.seq_len if shape.kind == "decode" else t
        f_sc = 4 * b_local * t * kv_len * cfg.n_heads * hd * nl
        f_attn = f_proj + f_sc
        if cfg.n_heads % tp == 0:
            # Megatron head-parallel: out-proj row-parallel all-reduce
            kv_ax = "model" if cfg.n_kv_heads % tp == 0 else None
            attn.append((ShardingChoice(
                "attn:heads", (("heads", "model"), ("kv_heads", kv_ax))),
                mm_time(f_attn, tp) + nl * all_reduce_time(spec, act, tp)))
        if hd % tp == 0:
            # head_dim-parallel (whisper/llava fallback): QK^T contracts
            # over the sharded head_dim -> all-reduce of the FULL score
            # tensor (B, H, T, KV) per layer.  Initially priced at 10%
            # of this (hypothesis: partitioner reassembles lazily) —
            # REFUTED by the whisper/llava dry-runs (65s/237s measured
            # collective terms); full-bytes pricing below.  §Perf H3.
            score_b = _bytes(b_local, cfg.n_heads, t, 1) * kv_len
            attn.append((ShardingChoice(
                "attn:head_dim", (("head_dim", "model"),
                                  ("heads", None), ("kv_heads", None))),
                mm_time(f_attn, tp) +
                bwd * nl * (all_reduce_time(spec, act, tp) +
                            reduce_scatter_time(spec, score_b, tp))))
        attn.append((ShardingChoice(
            "attn:rep", (("heads", None), ("kv_heads", None))),
            mm_time(f_attn, 1)))
    add("attn", attn)

    # ---------------- ffn / moe ----------------
    ffn = []
    if cfg.n_experts:
        n_moe = nl // cfg.moe_every
        f_moe = 2 * n_tok * d * cfg.d_ff * 3 * cfg.top_k * n_moe
        if cfg.n_experts % tp == 0:
            # expert parallel: two all-to-alls of the dispatched tokens
            disp = _bytes(b_local, t, d) * cfg.top_k
            ffn.append((ShardingChoice("ffn:ep", (("experts", "model"),
                                                  ("d_ff", None))),
                        mm_time(f_moe, tp) +
                        n_moe * 2 * all_to_all_time(spec, disp, tp)))
        if cfg.d_ff % tp == 0:
            ffn.append((ShardingChoice("ffn:tp", (("experts", None),
                                                  ("d_ff", "model"))),
                        mm_time(f_moe, tp) +
                        n_moe * all_reduce_time(spec, act, tp)))
    elif cfg.d_ff:
        f_ffn = 2 * n_tok * d * cfg.d_ff * 3 * nl
        if cfg.d_ff % tp == 0:
            ffn.append((ShardingChoice("ffn:tp", (("d_ff", "model"),)),
                        mm_time(f_ffn, tp) +
                        nl * all_reduce_time(spec, act, tp)))
        ffn.append((ShardingChoice("ffn:rep", (("d_ff", None),)),
                    mm_time(f_ffn, 1)))
    else:  # pure SSM: no FFN at all
        ffn.append((ShardingChoice("ffn:none", ()), 0.0))
    add("ffn", ffn)

    # ---------------- residual stream "layout" ----------------
    stream = [
        (ShardingChoice("stream:rep", (("seq", None),), stream="rep"), 0.0),
    ]
    if t % tp == 0 and t > 1:
        # sequence parallelism: norms/elementwise run seq-sharded;
        # needs all-gather before attn + reduce-scatter after — costed
        # on the edges below
        stream.append(
            (ShardingChoice("stream:sp", (("seq", "model"),), stream="sp"),
             0.0))
    add("stream", stream)

    # ---------------- kv-cache (decode shapes) ----------------
    if shape.kind == "decode" and not cfg.is_attention_free:
        kv_bytes = _bytes(cfg.n_layers, shape.global_batch, shape.seq_len,
                          cfg.n_kv_heads * hd) * 2
        cache = []
        dp_ax = tuple(a for a in ("pod", "data") if a in mesh_shape)
        if shape.global_batch % dp == 0 and shape.global_batch >= dp:
            # batch-sharded cache: no attention collectives
            cache.append((ShardingChoice(
                "cache:batch", (("kv_seq", None),)), 0.0))
        if shape.seq_len % _mesh_size(mesh_shape, dp_ax) == 0:
            # sequence-sharded cache (long-context, small batch):
            # partial-softmax psum per step, tiny (B, H) stats
            cache.append((ShardingChoice(
                "cache:seq", (("kv_seq", dp_ax),
                              ("batch", None))),
                cfg.n_layers * xfer(_bytes(shape.global_batch,
                                           cfg.n_heads, hd + 2,
                                           dtype_bytes=4),
                                    _mesh_size(mesh_shape, dp_ax))))
        cache.append((ShardingChoice(
            "cache:replicated", (("kv_seq", None),)),
            kv_bytes / spec.mem_bw))  # every chip reads the whole cache
        add("cache", cache)

    # ---------------- head ----------------
    head = []
    if v % tp == 0:
        head.append((ShardingChoice("head:vocab", ()),
                     all_gather_time(
                         spec, _bytes(b_local, t, 1, dtype_bytes=4), tp)))
    head.append((ShardingChoice("head:rep", (("vocab", None),)),
                 _bytes(d, v) / spec.mem_bw))
    add("head", head)

    # ---------------- edges: resharding between stream and groups ----
    # stream "layout" transitions are the DT-graph edges of this choice
    # space: an SP stream costs one all-gather (rep -> needs full seq)
    # plus one reduce-scatter around every sharded compute group, and
    # composes only with sharded groups.
    edges: List[ChoiceEdge] = []

    def stream_group(sc: ShardingChoice, gc: ShardingChoice) -> float:
        if sc.stream != "sp":
            return 0.0
        # SP only composes with sharded compute groups
        if gc.name.endswith(":rep"):
            return np.inf
        # per-layer all-gather + reduce-scatter of activations
        return nl * (all_gather_time(spec, act, tp) +
                     reduce_scatter_time(spec, act, tp))

    # embed/head touch the stream once (not per layer): entering or
    # leaving a seq-sharded stream costs one activation all-gather,
    # regardless of which embed/head variant sits on the other end
    sp_boundary = all_gather_time(spec, act, tp)
    edges.append(ChoiceEdge("stream", "attn", stream_group))
    edges.append(ChoiceEdge("stream", "ffn", stream_group))
    edges.append(ChoiceEdge(
        "embed", "stream",
        lambda ec, sc: sp_boundary if sc.stream == "sp" else 0.0))
    edges.append(ChoiceEdge(
        "stream", "head",
        lambda sc, hc: sp_boundary if sc.stream == "sp" else 0.0))

    pb, _ = build_pbqp(nodes, edges)
    sol = pbqp.solve(pb, exact=exact)
    chosen = {n: domains[n][sol.assignment[n]] for n in domains}

    rules = MEGATRON_RULES
    # batch divisibility: keep the largest ("pod","data") prefix whose
    # product divides the global batch (B=1 long-context: replicate)
    b_axes = []
    prod = 1
    for ax in ("pod", "data"):
        if ax in mesh_shape and shape.global_batch % (
                prod * mesh_shape[ax]) == 0:
            b_axes.append(ax)
            prod *= mesh_shape[ax]
    rules = rules.with_(batch=tuple(b_axes) if b_axes else None)
    if fsdp:
        rules = rules.with_(layers=None)
    updates = {}
    for c in chosen.values():
        updates.update(dict(c.updates))
    if chosen["embed"].name == "embed:dmodel":
        updates["d_model"] = None  # keep activations unsharded on d
    rules = rules.with_(**updates)

    report = {
        "arch": cfg.name, "shape": shape.name,
        "mesh": dict(mesh_shape), "spec": spec.name,
        "assignment": {n: c.name for n, c in chosen.items()},
        "predicted_comm_s": sol.cost,
        "optimal": sol.optimal,
        "domains": {n: [c.name for c in domains[n]] for n in domains},
    }
    if return_solution:
        return rules, report, sol
    return rules, report


def candidate_report(cfg, shape, mesh_shape) -> Dict:
    _, report = select_rules(cfg, shape, mesh_shape)
    return report
