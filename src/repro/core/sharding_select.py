"""PBQP sharding selection — the paper's technique at datacenter scale.

The exact analogy (DESIGN.md §Technique-mapping):

  CPU world (paper)                  TPU-pod world (this module)
  -----------------                  ---------------------------
  data layout of a tensor            PartitionSpec of a tensor
  primitive {L_in, P, L_out}         op variant + sharding rule-set
  layout transform routine           resharding collective
  DT-graph APSP cost                 collective bytes / link bandwidth
  profiled layer cost                analytic compute+comm time per rule

PBQP nodes are the tensor groups of one transformer program (embed,
residual stream, attention, FFN/MoE, head, kv-cache); domains are
feasibility-filtered sharding rule-sets; node costs price the
collectives a rule implies *inside* its group (e.g. Megatron row-
parallel out-proj => per-layer all-reduce of the activations); edge
costs price the resharding between adjacent groups (the "layout
transformation" of the distributed world).  The same exact solver the
paper uses for CPU layouts finds the global optimum.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..models.sharding import MEGATRON_RULES, Rules
from . import pbqp

__all__ = ["select_rules", "candidate_report", "ShardingChoice"]

# TPU v5e constants (per chip)
PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


@dataclass(frozen=True)
class ShardingChoice:
    name: str
    #: logical-axis updates this choice contributes to the global Rules
    updates: Tuple[Tuple[str, object], ...]
    #: activation "layout" on the residual stream this choice assumes
    #: ("rep" replicated over model axis, "sp" sequence-sharded)
    stream: str = "rep"


def _bytes(*dims, dtype_bytes=2):
    return float(np.prod(dims)) * dtype_bytes


def _ring_ag_bytes(nbytes, n):
    """all-gather over n chips moves (n-1)/n of the tensor per link."""
    return nbytes * (n - 1) / n


def _mesh_size(mesh_shape: Dict[str, int], axis) -> int:
    if axis is None:
        return 1
    axes = (axis,) if isinstance(axis, str) else axis
    return int(np.prod([mesh_shape[a] for a in axes]))


def select_rules(cfg, shape, mesh_shape: Dict[str, int], *,
                 exact: bool = True, fsdp: bool = False,
                 return_solution: bool = False):
    """Solve the sharding PBQP for (arch, shape) on a mesh.

    Returns (Rules, report) where report logs domains, costs and the
    chosen assignment (consumed by EXPERIMENTS.md §Perf).
    """
    tp = mesh_shape.get("model", 1)
    dp = _mesh_size(mesh_shape, tuple(a for a in ("pod", "data")
                                      if a in mesh_shape))
    b_local = max(shape.global_batch // dp, 1)
    t = shape.seq_len if shape.kind != "decode" else 1
    d, v = cfg.d_model, cfg.vocab
    hd = cfg.resolved_head_dim if cfg.n_heads else 0
    nl = cfg.n_layers
    act = _bytes(b_local, t, d)          # residual activation per device

    bwd = 3.0 if shape.kind == "train" else 1.0  # fwd+bwd flops factor
    mxu_eff = 0.5 * PEAK_FLOPS

    def mm_time(flops: float, ways: int) -> float:
        """Matmul time when sharded ``ways`` ways (0.5 MXU efficiency)."""
        return bwd * flops / (max(ways, 1) * mxu_eff)

    pb = pbqp.PBQP()
    domains: Dict[str, List[ShardingChoice]] = {}

    def add(node: str, choices: List[Tuple[ShardingChoice, float]]):
        choices = [c for c in choices if np.isfinite(c[1])] or choices
        domains[node] = [c for c, _ in choices]
        pb.add_node(node, [c for _, c in choices])

    # ---------------- embed ----------------
    emb = []
    if v % tp == 0:
        # vocab-sharded gather -> all-reduce of the (b,t,d) activations
        emb.append((ShardingChoice("embed:vocab", (("vocab", "model"),)),
                    2 * act / (LINK_BW)))
    if d % tp == 0:
        emb.append((ShardingChoice("embed:dmodel",
                                   (("vocab", None),)),  # d sharded in rule
                    _ring_ag_bytes(act, tp) / LINK_BW))
    emb.append((ShardingChoice("embed:rep", (("vocab", None),)),
                _bytes(v, d) / HBM_BW * 0.0))  # replicated: no collective
    add("embed", emb)

    # ---------------- attention (or mamba mixer) ----------------
    attn = []
    n_tok = b_local * t
    if cfg.is_attention_free:
        d_inner = cfg.ssm_expand * d
        h_ssm = d_inner // cfg.ssm_headdim
        f_ssm = 2 * n_tok * d * (2 * d_inner + 2 * cfg.ssm_state) * nl
        if h_ssm % tp == 0:
            attn.append((ShardingChoice(
                "mixer:ssm_heads", (("ssm_heads", "model"),)),
                mm_time(f_ssm, tp) + nl * 2 * act / LINK_BW))
        attn.append((ShardingChoice("mixer:rep", (("ssm_heads", None),)),
                     mm_time(f_ssm, 1)))
    else:
        # projections + score/PV flops per layer stack
        f_proj = 2 * n_tok * d * (cfg.n_heads + 2 * cfg.n_kv_heads +
                                  cfg.n_heads) * hd * nl
        kv_len = shape.seq_len if shape.kind == "decode" else t
        f_sc = 4 * b_local * t * kv_len * cfg.n_heads * hd * nl
        f_attn = f_proj + f_sc
        if cfg.n_heads % tp == 0:
            # Megatron head-parallel: out-proj row-parallel all-reduce
            kv_ax = "model" if cfg.n_kv_heads % tp == 0 else None
            attn.append((ShardingChoice(
                "attn:heads", (("heads", "model"), ("kv_heads", kv_ax))),
                mm_time(f_attn, tp) +
                nl * 2 * act * (tp - 1) / tp / LINK_BW))
        if hd % tp == 0:
            # head_dim-parallel (whisper/llava fallback): QK^T contracts
            # over the sharded head_dim -> all-reduce of the FULL score
            # tensor (B, H, T, KV) per layer.  Initially priced at 10%
            # of this (hypothesis: partitioner reassembles lazily) —
            # REFUTED by the whisper/llava dry-runs (65s/237s measured
            # collective terms); full-bytes pricing below.  §Perf H3.
            score_b = _bytes(b_local, cfg.n_heads, t, 1) * kv_len
            attn.append((ShardingChoice(
                "attn:head_dim", (("head_dim", "model"),
                                  ("heads", None), ("kv_heads", None))),
                mm_time(f_attn, tp) +
                bwd * nl * (2 * act + score_b) * (tp - 1) / tp / LINK_BW))
        attn.append((ShardingChoice(
            "attn:rep", (("heads", None), ("kv_heads", None))),
            mm_time(f_attn, 1)))
    add("attn", attn)

    # ---------------- ffn / moe ----------------
    ffn = []
    if cfg.n_experts:
        n_moe = nl // cfg.moe_every
        f_moe = 2 * n_tok * d * cfg.d_ff * 3 * cfg.top_k * n_moe
        if cfg.n_experts % tp == 0:
            # expert parallel: two all-to-alls of the dispatched tokens
            disp = _bytes(b_local, t, d) * cfg.top_k
            ffn.append((ShardingChoice("ffn:ep", (("experts", "model"),
                                                  ("d_ff", None))),
                        mm_time(f_moe, tp) + n_moe * 2 * disp / LINK_BW))
        if cfg.d_ff % tp == 0:
            ffn.append((ShardingChoice("ffn:tp", (("experts", None),
                                                  ("d_ff", "model"))),
                        mm_time(f_moe, tp) +
                        n_moe * 2 * act * (tp - 1) / tp / LINK_BW))
    elif cfg.d_ff:
        f_ffn = 2 * n_tok * d * cfg.d_ff * 3 * nl
        if cfg.d_ff % tp == 0:
            ffn.append((ShardingChoice("ffn:tp", (("d_ff", "model"),)),
                        mm_time(f_ffn, tp) +
                        nl * 2 * act * (tp - 1) / tp / LINK_BW))
        ffn.append((ShardingChoice("ffn:rep", (("d_ff", None),)),
                    mm_time(f_ffn, 1)))
    else:  # pure SSM: no FFN at all
        ffn.append((ShardingChoice("ffn:none", ()), 0.0))
    add("ffn", ffn)

    # ---------------- residual stream "layout" ----------------
    stream = [
        (ShardingChoice("stream:rep", (("seq", None),), stream="rep"), 0.0),
    ]
    if t % tp == 0 and t > 1:
        # sequence parallelism: norms/elementwise run seq-sharded;
        # needs all-gather before attn + reduce-scatter after — costed
        # on the edges below
        stream.append(
            (ShardingChoice("stream:sp", (("seq", "model"),), stream="sp"),
             0.0))
    add("stream", stream)

    # ---------------- kv-cache (decode shapes) ----------------
    if shape.kind == "decode" and not cfg.is_attention_free:
        kv_bytes = _bytes(cfg.n_layers, shape.global_batch, shape.seq_len,
                          cfg.n_kv_heads * hd) * 2
        cache = []
        dp_ax = tuple(a for a in ("pod", "data") if a in mesh_shape)
        if shape.global_batch % dp == 0 and shape.global_batch >= dp:
            # batch-sharded cache: no attention collectives
            cache.append((ShardingChoice(
                "cache:batch", (("kv_seq", None),)), 0.0))
        if shape.seq_len % _mesh_size(mesh_shape, dp_ax) == 0:
            # sequence-sharded cache (long-context, small batch):
            # partial-softmax psum per step, tiny (B, H) stats
            cache.append((ShardingChoice(
                "cache:seq", (("kv_seq", dp_ax),
                              ("batch", None))),
                cfg.n_layers * _bytes(shape.global_batch, cfg.n_heads,
                                      hd + 2, dtype_bytes=4) / LINK_BW))
        cache.append((ShardingChoice(
            "cache:replicated", (("kv_seq", None),)),
            kv_bytes / HBM_BW))  # every chip reads the whole cache
        add("cache", cache)

    # ---------------- head ----------------
    head = []
    logits = _bytes(b_local, t, v, dtype_bytes=4)
    if v % tp == 0:
        head.append((ShardingChoice("head:vocab", ()),
                     _ring_ag_bytes(_bytes(b_local, t, 1, dtype_bytes=4),
                                    tp) / LINK_BW))
    head.append((ShardingChoice("head:rep", (("vocab", None),)),
                 logits / HBM_BW / tp * 0 + _bytes(d, v) / HBM_BW))
    add("head", head)

    # ---------------- edges: resharding between stream and groups ----
    # stream "layout" transitions are the DT-graph edges: SP <-> rep
    # costs one all-gather (rep->needs full seq) or reduce-scatter.
    def stream_edge(group: str):
        M = np.zeros((len(domains["stream"]), len(domains[group])))
        for i, sc in enumerate(domains["stream"]):
            for j, gc in enumerate(domains[group]):
                if sc.stream == "sp":
                    # per-layer all-gather + reduce-scatter of activations
                    M[i, j] = nl * 2 * _ring_ag_bytes(act, tp) / LINK_BW
                    # SP only composes with sharded compute groups
                    if gc.name.endswith(":rep"):
                        M[i, j] = np.inf
                else:
                    M[i, j] = 0.0
        pb.add_edge("stream", group, M)

    stream_edge("attn")
    stream_edge("ffn")
    # embed/head connect to the stream once (not per layer)
    M = np.zeros((len(domains["embed"]), len(domains["stream"])))
    for i, ec in enumerate(domains["embed"]):
        for j, sc in enumerate(domains["stream"]):
            M[i, j] = _ring_ag_bytes(act, tp) / LINK_BW \
                if sc.stream == "sp" else 0.0
    pb.add_edge("embed", "stream", M)
    M = np.zeros((len(domains["stream"]), len(domains["head"])))
    for i, sc in enumerate(domains["stream"]):
        for j, hc in enumerate(domains["head"]):
            M[i, j] = _ring_ag_bytes(act, tp) / LINK_BW \
                if sc.stream == "sp" else 0.0
    pb.add_edge("stream", "head", M)

    sol = pbqp.solve(pb, exact=exact)
    chosen = {n: domains[n][sol.assignment[n]] for n in domains}

    rules = MEGATRON_RULES
    # batch divisibility: keep the largest ("pod","data") prefix whose
    # product divides the global batch (B=1 long-context: replicate)
    b_axes = []
    prod = 1
    for ax in ("pod", "data"):
        if ax in mesh_shape and shape.global_batch % (
                prod * mesh_shape[ax]) == 0:
            b_axes.append(ax)
            prod *= mesh_shape[ax]
    rules = rules.with_(batch=tuple(b_axes) if b_axes else None)
    if fsdp:
        rules = rules.with_(layers=None)
    updates = {}
    for c in chosen.values():
        updates.update(dict(c.updates))
    if chosen["embed"].name == "embed:dmodel":
        updates["d_model"] = None  # keep activations unsharded on d
    rules = rules.with_(**updates)

    report = {
        "arch": cfg.name, "shape": shape.name,
        "mesh": dict(mesh_shape),
        "assignment": {n: c.name for n, c in chosen.items()},
        "predicted_comm_s": sol.cost,
        "optimal": sol.optimal,
        "domains": {n: [c.name for c in domains[n]] for n in domains},
    }
    if return_solution:
        return rules, report, sol
    return rules, report


def candidate_report(cfg, shape, mesh_shape) -> Dict:
    _, report = select_rules(cfg, shape, mesh_shape)
    return report
