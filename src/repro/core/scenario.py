"""Convolutional scenarios — the paper's 6-tuple {C, H, W, delta, K, M}.

A *scenario* captures everything a convolution primitive's runtime
depends on (Section 3 of the paper): input channels C, spatial size
H x W, stride delta, kernel radix K, output channels M.  We add the
padding (the paper's benchmark networks all use explicit pads), the
dtype, and — beyond the paper — the minibatch ``n``.  The paper fixes
minibatch at 1 for its latency-sensitive deployment context, but the
optimal primitive *flips* with batch size (GEMM-based methods amortize
per-invocation packing/planning over N; direct methods do not), so a
batched server must price and select per (scenario, N).  ``n`` defaults
to 1 and a scenario's :meth:`key` is unchanged for ``n == 1``, so
single-image cost caches, calibration profiles and persisted plans stay
valid.  All costs are for the *whole batched invocation*, not per
image.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Tuple

import numpy as np

__all__ = ["Scenario", "ref_conv"]


@dataclass(frozen=True, order=True)
class Scenario:
    c: int          # input feature maps
    h: int          # input height
    w: int          # input width
    stride: int     # convolution stride (delta)
    k: int          # kernel radix (K x K)
    m: int          # output feature maps
    pad: int = -1   # -1 => "same"-style default k // 2
    dtype: str = "float32"
    n: int = 1      # minibatch (1 = the paper's setting)

    def __post_init__(self):
        if self.pad < 0:
            object.__setattr__(self, "pad", self.k // 2)
        if self.n < 1:
            raise ValueError(f"minibatch must be >= 1, got {self.n}")

    @property
    def out_h(self) -> int:
        return (self.h + 2 * self.pad - self.k) // self.stride + 1

    @property
    def out_w(self) -> int:
        return (self.w + 2 * self.pad - self.k) // self.stride + 1

    @property
    def in_shape_chw(self) -> Tuple[int, int, int]:
        return (self.c, self.h, self.w)

    @property
    def out_shape_chw(self) -> Tuple[int, int, int]:
        return (self.m, self.out_h, self.out_w)

    @property
    def weight_shape(self) -> Tuple[int, int, int, int]:
        return (self.m, self.c, self.k, self.k)

    @property
    def in_shape_nchw(self) -> Tuple[int, int, int, int]:
        return (self.n, self.c, self.h, self.w)

    @property
    def out_shape_nchw(self) -> Tuple[int, int, int, int]:
        return (self.n, self.m, self.out_h, self.out_w)

    @property
    def macs(self) -> int:
        """Multiply-accumulates of the direct algorithm (whole batch)."""
        return (self.n * self.m * self.c * self.k * self.k
                * self.out_h * self.out_w)

    @property
    def flops(self) -> int:
        return 2 * self.macs

    def with_(self, **kw) -> "Scenario":
        return replace(self, **kw)

    def key(self) -> str:
        # n is appended only for n > 1: single-image keys predate the
        # batch axis, and cost caches / calibration profiles keyed on
        # them must stay valid.
        base = (f"c{self.c}h{self.h}w{self.w}s{self.stride}"
                f"k{self.k}m{self.m}p{self.pad}{self.dtype}")
        return base if self.n == 1 else f"{base}n{self.n}"


def ref_conv(x: np.ndarray, w: np.ndarray, b: np.ndarray,
             stride: int, pad: int) -> np.ndarray:
    """Reference multi-channel multi-kernel DNN convolution (correlation).

    Pure numpy oracle.  x: (C, H, W); w: (M, C, K, K); b: (M,).
    Returns (M, H', W').  All primitives in the library are validated
    against this function.
    """
    c, h, wdt = x.shape
    m, c2, k, k2 = w.shape
    assert c == c2 and k == k2
    xp = np.pad(x, ((0, 0), (pad, pad), (pad, pad)))
    win = np.lib.stride_tricks.sliding_window_view(xp, (k, k), axis=(1, 2))
    win = win[:, ::stride, ::stride]  # (C, H', W', K, K)
    out = np.einsum("chwij,mcij->mhw", win, w, optimize=True)
    return out + b[:, None, None]
