"""Convolutional scenarios — the paper's 6-tuple {C, H, W, delta, K, M}.

A *scenario* captures everything a convolution primitive's runtime
depends on (Section 3 of the paper): input channels C, spatial size
H x W, stride delta, kernel radix K, output channels M.  We add the
padding (the paper's benchmark networks all use explicit pads) and the
dtype.  Minibatch is fixed at 1 per the paper's latency-sensitive
deployment context; the batch generalisation lives at the distributed
level (see repro/core/sharding_select.py).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Tuple

import numpy as np

__all__ = ["Scenario", "ref_conv"]


@dataclass(frozen=True, order=True)
class Scenario:
    c: int          # input feature maps
    h: int          # input height
    w: int          # input width
    stride: int     # convolution stride (delta)
    k: int          # kernel radix (K x K)
    m: int          # output feature maps
    pad: int = -1   # -1 => "same"-style default k // 2
    dtype: str = "float32"

    def __post_init__(self):
        if self.pad < 0:
            object.__setattr__(self, "pad", self.k // 2)

    @property
    def out_h(self) -> int:
        return (self.h + 2 * self.pad - self.k) // self.stride + 1

    @property
    def out_w(self) -> int:
        return (self.w + 2 * self.pad - self.k) // self.stride + 1

    @property
    def in_shape_chw(self) -> Tuple[int, int, int]:
        return (self.c, self.h, self.w)

    @property
    def out_shape_chw(self) -> Tuple[int, int, int]:
        return (self.m, self.out_h, self.out_w)

    @property
    def weight_shape(self) -> Tuple[int, int, int, int]:
        return (self.m, self.c, self.k, self.k)

    @property
    def macs(self) -> int:
        """Multiply-accumulates of the direct algorithm."""
        return self.m * self.c * self.k * self.k * self.out_h * self.out_w

    @property
    def flops(self) -> int:
        return 2 * self.macs

    def with_(self, **kw) -> "Scenario":
        return replace(self, **kw)

    def key(self) -> str:
        return (f"c{self.c}h{self.h}w{self.w}s{self.stride}"
                f"k{self.k}m{self.m}p{self.pad}{self.dtype}")


def ref_conv(x: np.ndarray, w: np.ndarray, b: np.ndarray,
             stride: int, pad: int) -> np.ndarray:
    """Reference multi-channel multi-kernel DNN convolution (correlation).

    Pure numpy oracle.  x: (C, H, W); w: (M, C, K, K); b: (M,).
    Returns (M, H', W').  All primitives in the library are validated
    against this function.
    """
    c, h, wdt = x.shape
    m, c2, k, k2 = w.shape
    assert c == c2 and k == k2
    xp = np.pad(x, ((0, 0), (pad, pad), (pad, pad)))
    win = np.lib.stride_tricks.sliding_window_view(xp, (k, k), axis=(1, 2))
    win = win[:, ::stride, ::stride]  # (C, H', W', K, K)
    out = np.einsum("chwij,mcij->mhw", win, w, optimize=True)
    return out + b[:, None, None]
