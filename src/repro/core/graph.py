"""DNN graph IR: a DAG of layers, the optimization unit of the paper.

Convolution layers carry a :class:`Scenario` and are assigned primitives
by the PBQP selection.  All other layers ("op" nodes: activation,
pooling, LRN, concat, FC, ...) follow the paper's simplifying
assumption: they are layout-polymorphic dummy nodes with zero cost whose
PBQP domain is the set of data layouts they accept.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np
from jax import lax

from .layouts import LAYOUT_BY_NAME, Layout
from .scenario import Scenario

__all__ = ["Net", "Node", "OpDef", "relu", "maxpool", "avgpool", "lrn",
           "concat", "fc", "global_avgpool", "softmax", "identity"]

#: layouts an op node accepts by default (all unblocked permutations that
#: primitives actually produce; blocked layouts are op-specific)
DEFAULT_OP_LAYOUTS = ("CHW", "HWC", "HCW")


@dataclass
class OpDef:
    """A non-convolution layer type (zero-cost in the PBQP model)."""

    name: str
    #: in_shapes (logical CHW-tuples) -> out logical shape
    shape_fn: Callable[[Sequence[Tuple[int, ...]]], Tuple[int, ...]]
    #: (xs, layout, params) -> y  — layout-polymorphic execution
    fn: Callable
    init_params: Optional[Callable] = None
    layouts: Tuple[str, ...] = DEFAULT_OP_LAYOUTS


@dataclass
class Node:
    id: str
    kind: str  # "input" | "conv" | "op"
    inputs: List[str] = field(default_factory=list)
    scn: Optional[Scenario] = None
    op: Optional[OpDef] = None
    out_shape: Tuple[int, ...] = ()  # logical (C, H, W) or (F,) after FC


class Net:
    """DAG builder + container."""

    def __init__(self, name: str):
        self.name = name
        self.nodes: Dict[str, Node] = {}
        self._order: List[str] = []

    def _add(self, node: Node) -> str:
        if node.id in self.nodes:
            raise ValueError(f"duplicate node {node.id}")
        for i in node.inputs:
            if i not in self.nodes:
                raise ValueError(f"{node.id}: unknown input {i}")
        self.nodes[node.id] = node
        self._order.append(node.id)
        return node.id

    def input(self, id: str, shape_chw: Tuple[int, int, int]) -> str:
        return self._add(Node(id, "input", [], out_shape=shape_chw))

    def conv(self, id: str, src: str, *, k: int, m: int, stride: int = 1,
             pad: int = -1) -> str:
        c, h, w = self.nodes[src].out_shape
        scn = Scenario(c=c, h=h, w=w, stride=stride, k=k, m=m, pad=pad)
        return self._add(Node(id, "conv", [src], scn=scn,
                              out_shape=scn.out_shape_chw))

    def op(self, id: str, srcs: Sequence[str], opdef: OpDef) -> str:
        shapes = [self.nodes[s].out_shape for s in srcs]
        return self._add(Node(id, "op", list(srcs), op=opdef,
                              out_shape=opdef.shape_fn(shapes)))

    # ------------------------------------------------------------------
    @property
    def order(self) -> List[str]:
        return list(self._order)

    def edges(self) -> List[Tuple[str, str]]:
        out = []
        for nid in self._order:
            for src in self.nodes[nid].inputs:
                out.append((src, nid))
        return out

    def conv_nodes(self) -> List[Node]:
        return [self.nodes[n] for n in self._order
                if self.nodes[n].kind == "conv"]

    def with_batch(self, n: int) -> "Net":
        """This net with every conv scenario's minibatch set to ``n``.

        Copy-on-write: returns ``self`` when nothing changes, otherwise
        a new ``Net`` with fresh ``Node`` objects — never a mutation, so
        a memoizing net builder can hand out one shared ``Net`` per
        shape and cached :class:`~repro.core.selection.SelectionResult`s
        keep the batch they were solved with.  Node ``out_shape``s stay
        logical per-image CHW — the batch axis lives in the scenarios
        (costing/selection) and in the compiled executable
        (``core.plan.compile_plan(..., batch=n)``), never in the graph
        topology, so node ids and warm starts line up across batch
        sizes.  ``fingerprint()`` picks the change up through
        ``Scenario.key()``, keeping batched plans cleanly keyed.
        """
        if all(node.scn.n == n for node in self.conv_nodes()):
            return self
        new = Net(self.name)
        for nid in self._order:
            nd = self.nodes[nid]
            scn = nd.scn.with_(n=n) if nd.kind == "conv" else nd.scn
            new.nodes[nid] = Node(nd.id, nd.kind, list(nd.inputs),
                                  scn, nd.op, nd.out_shape)
            new._order.append(nid)
        return new

    def outputs(self) -> List[str]:
        consumed = {s for s, _ in self.edges()}
        return [n for n in self._order if n not in consumed]

    def fingerprint(self) -> str:
        """Stable content hash of the graph: topology, scenarios, op kinds,
        accepted layouts and shapes.  Two nets with the same fingerprint
        build byte-identical PBQP instances under the same cost model, so
        the serving plan cache uses this as part of its key."""
        h = hashlib.sha256()
        for nid in self._order:
            n = self.nodes[nid]
            parts = [nid, n.kind, ",".join(n.inputs),
                     "x".join(map(str, n.out_shape))]
            if n.scn is not None:
                parts.append(n.scn.key())
            if n.op is not None:
                parts.append(n.op.name)
                parts.append(",".join(n.op.layouts))
            h.update(("|".join(parts) + "\n").encode())
        return h.hexdigest()[:16]

    def init_params(self, seed: int = 0) -> Dict[str, Dict[str, np.ndarray]]:
        """He-initialised raw weights per node (logical layouts)."""
        rng = np.random.default_rng(seed)
        params: Dict[str, Dict[str, np.ndarray]] = {}
        for nid in self._order:
            node = self.nodes[nid]
            if node.kind == "conv":
                s = node.scn
                std = float(np.sqrt(2.0 / (s.c * s.k * s.k)))
                params[nid] = {
                    "w": rng.normal(0, std, size=s.weight_shape)
                            .astype(np.float32),
                    "b": rng.normal(0, 0.01, size=(s.m,)).astype(np.float32),
                }
            elif node.kind == "op" and node.op.init_params is not None:
                in_shapes = [self.nodes[i].out_shape for i in node.inputs]
                params[nid] = node.op.init_params(rng, in_shapes)
        return params


# ----------------------------------------------------------------------
# op definitions (layout-polymorphic, zero PBQP cost)
# ----------------------------------------------------------------------
def _hw_axes(layout: Layout, ndim: int) -> Tuple[int, int]:
    return layout.perm.index(1), layout.perm.index(2)


def _c_axis(layout: Layout) -> int:
    return layout.perm.index(0)


def relu() -> OpDef:
    return OpDef("relu", lambda s: s[0],
                 lambda xs, layout, p: jnp.maximum(xs[0], 0.0),
                 layouts=DEFAULT_OP_LAYOUTS + ("HWC8",))


def identity(name: str = "identity") -> OpDef:
    return OpDef(name, lambda s: s[0], lambda xs, layout, p: xs[0],
                 layouts=DEFAULT_OP_LAYOUTS + ("HWC8",))


def _pool(kind: str, k: int, stride: int, pad: int) -> OpDef:
    def shape_fn(shapes):
        c, h, w = shapes[0]
        oh = (h + 2 * pad - k) // stride + 1
        ow = (w + 2 * pad - k) // stride + 1
        return (c, oh, ow)

    def fn(xs, layout, p):
        x = xs[0]
        ha, wa = _hw_axes(layout, x.ndim)
        window = [1] * x.ndim
        strides = [1] * x.ndim
        pads = [(0, 0)] * x.ndim
        window[ha] = window[wa] = k
        strides[ha] = strides[wa] = stride
        pads[ha] = pads[wa] = (pad, pad)
        if kind == "max":
            init = -jnp.inf
            return lax.reduce_window(x, init, lax.max, window, strides, pads)
        acc = lax.reduce_window(x, 0.0, lax.add, window, strides, pads)
        return acc / float(k * k)

    return OpDef(f"{kind}pool{k}s{stride}", shape_fn, fn,
                 layouts=DEFAULT_OP_LAYOUTS + ("HWC8",))


def maxpool(k: int, stride: int, pad: int = 0) -> OpDef:
    return _pool("max", k, stride, pad)


def avgpool(k: int, stride: int, pad: int = 0) -> OpDef:
    return _pool("avg", k, stride, pad)


def lrn(size: int = 5, alpha: float = 1e-4, beta: float = 0.75,
        bias: float = 1.0) -> OpDef:
    """AlexNet/GoogleNet local response normalisation across channels."""
    def fn(xs, layout, p):
        x = xs[0]
        ca = _c_axis(layout)
        sq = x * x
        window = [1] * x.ndim
        window[ca] = size
        pads = [(0, 0)] * x.ndim
        pads[ca] = (size // 2, size // 2)
        s = lax.reduce_window(sq, 0.0, lax.add, window, [1] * x.ndim, pads)
        return x / (bias + (alpha / size) * s) ** beta

    return OpDef(f"lrn{size}", lambda s: s[0], fn)


def concat() -> OpDef:
    """Channel concatenation (inception joins)."""
    def shape_fn(shapes):
        c = sum(s[0] for s in shapes)
        return (c,) + tuple(shapes[0][1:])

    def fn(xs, layout, p):
        return jnp.concatenate(xs, axis=_c_axis(layout))

    return OpDef("concat", shape_fn, fn)


def global_avgpool() -> OpDef:
    def fn(xs, layout, p):
        ha, wa = _hw_axes(layout, xs[0].ndim)
        return jnp.mean(xs[0], axis=(ha, wa), keepdims=True)

    return OpDef("gap", lambda s: (s[0][0], 1, 1), fn)


def fc(features: int, relu_after: bool = False) -> OpDef:
    """Fully connected layer.  Flattens in *logical CHW order* regardless
    of the arriving layout, so results are layout-invariant."""
    def shape_fn(shapes):
        return (features, 1, 1)

    def init_params(rng, in_shapes):
        n_in = int(np.prod(in_shapes[0]))
        std = float(np.sqrt(2.0 / n_in))
        return {"w": rng.normal(0, std, size=(n_in, features))
                        .astype(np.float32),
                "b": np.zeros((features,), np.float32)}

    def fn(xs, layout, p):
        x = xs[0]
        if x.ndim == 3 or x.ndim == 4:
            from .primitives import convert_layout
            x = convert_layout(x, layout.name, "CHW")
        v = x.reshape(-1)
        y = v @ p["w"] + p["b"]
        if relu_after:
            y = jnp.maximum(y, 0.0)
        # keep a (C, 1, 1) logical shape so further ops compose
        from .primitives import convert_layout
        return convert_layout(y.reshape(features, 1, 1), "CHW", layout.name)

    return OpDef(f"fc{features}", shape_fn, fn, init_params=init_params)


def softmax() -> OpDef:
    def fn(xs, layout, p):
        x = xs[0]
        ca = _c_axis(layout)
        return jnp.exp(x - lax.stop_gradient(jnp.max(x))) / jnp.sum(
            jnp.exp(x - lax.stop_gradient(jnp.max(x))))

    return OpDef("softmax", lambda s: s[0], fn)
