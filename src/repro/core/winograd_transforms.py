"""Cook-Toom / Winograd minimal-filtering transform generator.

Generates the (A, G, B) matrices of the Winograd convolution
``y = A^T [ (G g) (.) (B^T d) ]`` for arbitrary F(m, r) — m outputs from
an r-tap correlation over a tile of alpha = m + r - 1 inputs — using the
transpose theorem:

Polynomial multiplication p(x) = a(x) b(x) with deg a = m-1,
deg b = r-1 is computed exactly from evaluations at alpha-1 finite
points plus the point at infinity (leading coefficient):

    p_coeffs = V^{-1} [ (X a) (.) (Y b) ]

where V is the (alpha x alpha) "Vandermonde + infinity row" matrix, and
X, Y are its first m / r columns.  The Toeplitz operator of
multiplication-by-g applied to an m-vector is exactly the transpose of
r-tap correlation over an alpha-tile, hence

    y = X^T [ (Y g) (.) (V^{-T} d) ]
      = A^T [ (G g) (.) (B^T d) ]   with  A = X, G = Y, B^T = V^{-T}.

For good point sets (0, +-1, +-2, +-1/2, ...) and alpha <= 8 the
matrices are exact small rationals and the float64 computation is exact
to ~1e-12, verified in tests against the reference convolution.

This recovers the classical F(2,3), F(4,3) matrices (up to row scaling)
and extends uniformly to the paper's K = 5 variants (F(2,5), F(4,5)).
"""
from __future__ import annotations

import functools
from typing import Tuple

import numpy as np

__all__ = ["winograd_matrices", "GOOD_POINTS"]

#: well-conditioned interpolation points, consumed in order
GOOD_POINTS = [0.0, 1.0, -1.0, 2.0, -2.0, 0.5, -0.5, 4.0, -4.0, 0.25, -0.25]


@functools.lru_cache(maxsize=None)
def winograd_matrices(m: int, r: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Return (A, G, Bt) for F(m, r).

    A:  (alpha, m)   output transform (use A.T)
    G:  (alpha, r)   kernel transform
    Bt: (alpha, alpha) input transform  (this IS B^T)
    """
    alpha = m + r - 1
    pts = GOOD_POINTS[: alpha - 1]
    if len(pts) < alpha - 1:
        raise ValueError(f"F({m},{r}): need {alpha - 1} points")

    # V: evaluation of a degree-(alpha-1) polynomial at pts + infinity
    V = np.zeros((alpha, alpha))
    for i, a in enumerate(pts):
        V[i] = [a ** j for j in range(alpha)]
    V[alpha - 1, alpha - 1] = 1.0  # infinity row = leading coefficient

    X = V[:, :m].copy()   # evaluation of deg m-1 poly (note inf row: e_{m-1}
    Y = V[:, :r].copy()   # only if m == alpha which never holds; fix below)
    # the infinity "evaluation" of a degree-(m-1) polynomial is its own
    # leading coefficient:
    X[alpha - 1, :] = 0.0
    X[alpha - 1, m - 1] = 1.0
    Y[alpha - 1, :] = 0.0
    Y[alpha - 1, r - 1] = 1.0

    Bt = np.linalg.inv(V).T
    return X, Y, Bt
