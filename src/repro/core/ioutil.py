"""Small filesystem helpers shared by every on-disk cache in the repo."""
from __future__ import annotations

import os
import pathlib
import threading

__all__ = ["atomic_write_text"]


def atomic_write_text(path, text: str) -> None:
    """Write ``text`` to ``path`` atomically (tmp + rename).

    The tmp name is unique per (process, thread): a shared ``<name>.tmp``
    would let two writers of the same path interleave write/replace and
    race a partially-written file into place (or crash on the other's
    already-renamed tmp).  Concurrent writers each replace atomically,
    so readers always see one writer's complete content (last wins).
    """
    p = pathlib.Path(path)
    tmp = p.with_name(f"{p.name}.{os.getpid()}.{threading.get_ident()}.tmp")
    try:
        tmp.write_text(text)
        tmp.replace(p)
    finally:
        tmp.unlink(missing_ok=True)
