"""The DNN primitive library: 70+ convolution routines in 6 families.

Section 4 of the paper.  Each primitive is a 3-tuple {L_in, P, L_out}
(input layout, routine, output layout) plus a ``supports`` predicate over
scenarios.  Families:

* ``direct``   — direct-loop methods (XLA native conv under various
                 dimension orders, textbook sum-of-single-channels,
                 shift-and-add loop nests, blocked-channel variants).
* ``im2``      — im2col/im2row: Toeplitz patch matrix + one GEMM.
* ``kn2``      — kn2row/kn2col (Vasudevan et al.): K^2 accumulating GEMMs,
                 low memory, stride-1 only.
* ``winograd`` — minimal-filtering F(m, r) for K in {3, 5}; 2-D nested and
                 the low-memory 1-D row-wise variants (the paper's
                 ARM-friendly selections); stride-1 only.
* ``fft``      — frequency-domain convolution; full 2-D and the
                 low-memory sum-of-1D-rows variant.
* ``pallas``   — TPU Pallas kernels (see repro/kernels/): MXU-tiled
                 im2col GEMM and direct conv.  Registered separately so
                 that CPU profiling can exclude them (they are priced by
                 the analytic TPU cost model instead).

Weight packing (kernel transforms, GEMM transposes, layout blocking) is
done once in ``prepare`` — it is deployment-time work, excluded from the
profiled runtime, exactly as the paper ships pre-packed weights.

Every primitive is validated against ``scenario.ref_conv`` over a sweep
of scenarios in tests/test_primitives.py.
"""
from __future__ import annotations

import functools
import threading
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .layouts import LAYOUT_BY_NAME, Layout
from .scenario import Scenario
from .winograd_transforms import winograd_matrices

__all__ = ["Primitive", "build_registry", "convert_layout", "registry",
           "FUSABLE_LAYOUTS", "register_extension", "unregister_extension",
           "clear_extensions", "extension_token",
           "invalidate_registry_cache"]

#: layouts the generic jnp prologue/epilogue wrapper can absorb — every
#: permutation layout plus the blocked HWC8 (whose feasibility is gated
#: per shape by ``layouts.transform_feasible`` at pricing time).
FUSABLE_LAYOUTS = ("CHW", "HWC", "HCW", "CWH", "WCH", "WHC", "HWC8")


# ----------------------------------------------------------------------
# layout conversion (jnp; used by the legalizer's conversion layers)
# ----------------------------------------------------------------------
def convert_layout(x, src: str, dst: str):
    """Convert activation tensor between memory layouts (traced, jnp)."""
    if src == dst:
        return x
    ls, ld = LAYOUT_BY_NAME[src], LAYOUT_BY_NAME[dst]
    # -> logical CHW
    if ls.block_c:
        cpos = ls.perm.index(0)
        x = jnp.moveaxis(x, -1, cpos + 1)
        shape = list(x.shape)
        shape[cpos:cpos + 2] = [shape[cpos] * shape[cpos + 1]]
        x = x.reshape(shape)
    x = jnp.transpose(x, np.argsort(ls.perm))
    # -> destination
    x = jnp.transpose(x, ld.perm)
    if ld.block_c:
        cpos = ld.perm.index(0)
        c = x.shape[cpos]
        shape = list(x.shape)
        shape[cpos:cpos + 1] = [c // ld.block_c, ld.block_c]
        x = x.reshape(shape)
        x = jnp.moveaxis(x, cpos + 1, -1)
    return x


def _from_chw(y_chw, dst: str):
    return convert_layout(y_chw, "CHW", dst)


def _to_chw(x, src: str):
    return convert_layout(x, src, "CHW")


# ----------------------------------------------------------------------
@dataclass(frozen=True)
class Primitive:
    """One routine in the library: {L_in, P, L_out} + applicability."""

    name: str
    family: str
    l_in: str
    l_out: str
    supports: Callable[[Scenario], bool]
    #: (scenario, w(M,C,K,K) np, b(M,) np) -> pytree of packed jnp arrays
    prepare: Callable[[Scenario, np.ndarray, np.ndarray], Any]
    #: scenario -> f(x_mem, packed) -> y_mem   (pure, jit-able)
    make: Callable[[Scenario], Callable]
    tags: Tuple[str, ...] = ()
    #: layouts the routine can consume *directly* in its prologue (fused
    #: read: no materialized DT round trip on the incoming edge)
    fusable_in: Tuple[str, ...] = ()
    #: layouts the routine can emit directly in its epilogue
    fusable_out: Tuple[str, ...] = ()
    #: optional custom fused builder ``(scn, l_in, l_out) -> f(x, packed)``
    #: — Pallas primitives install kernel variants whose BlockSpec index
    #: maps remap the grid (true in-kernel prologue/epilogue fusion);
    #: jnp primitives fall back to the generic wrapper below.
    fused: Optional[Callable] = None
    #: tuning parameters of a generated variant (sorted (name, value)
    #: pairs — hashable).  Empty for hand-written entries; the analytic
    #: TPU model prices tile quantization/alignment from these, and the
    #: autotune catalog round-trips them (see repro/autotune/).
    params: Tuple[Tuple[str, int], ...] = ()

    def make_fused(self, scn: Scenario, l_in: Optional[str] = None,
                   l_out: Optional[str] = None) -> Callable:
        """Entry point consuming ``l_in``-layout input and emitting
        ``l_out``-layout output (defaults: the native layouts).

        The generic path rewrites the conversion *inside* the primitive's
        call region: executed without an optimization barrier between the
        transform and the compute (see ``core.plan``), XLA folds the
        layout remap into the kernel's first read / last write instead of
        materializing an intermediate tensor through HBM.  Primitives
        with a custom ``fused`` builder get real in-kernel fusion.
        """
        li = l_in or self.l_in
        lo = l_out or self.l_out
        if li == self.l_in and lo == self.l_out:
            return self.make(scn)
        if li != self.l_in and li not in self.fusable_in:
            raise ValueError(f"{self.name}: cannot fuse input layout {li} "
                             f"(fusable_in={self.fusable_in})")
        if lo != self.l_out and lo not in self.fusable_out:
            raise ValueError(f"{self.name}: cannot fuse output layout {lo} "
                             f"(fusable_out={self.fusable_out})")
        if self.fused is not None:
            return self.fused(scn, li, lo)
        inner = self.make(scn)
        nat_in, nat_out = self.l_in, self.l_out

        def f(x, packed):
            if li != nat_in:
                x = convert_layout(x, li, nat_in)
            y = inner(x, packed)
            if lo != nat_out:
                y = convert_layout(y, nat_out, lo)
            return y

        return f

    def __repr__(self) -> str:  # pragma: no cover
        return f"<{self.family}:{self.name} {self.l_in}->{self.l_out}>"


def _std_prepare(scn: Scenario, w: np.ndarray, b: np.ndarray):
    return {"w": jnp.asarray(w), "b": jnp.asarray(b)}


def _any(scn: Scenario) -> bool:
    return True


def _stride1(scn: Scenario) -> bool:
    return scn.stride == 1


def _pad_chw(x, p):
    return jnp.pad(x, ((0, 0), (p, p), (p, p))) if p else x


# ======================================================================
# direct family
# ======================================================================
_DN_LHS = {"CHW": "NCHW", "HWC": "NHWC", "HCW": "NHCW"}


def _direct_lax(scn: Scenario, l_in: str, l_out: str, rhs_spec: str):
    dn = lax.conv_dimension_numbers(
        (1,) + tuple(LAYOUT_BY_NAME[l_in].to_memory(np.zeros(scn.in_shape_chw)).shape),
        scn.weight_shape if rhs_spec == "OIHW" else
        (scn.k, scn.k, scn.c, scn.m),
        (_DN_LHS[l_in], rhs_spec, _DN_LHS[l_out]),
    )

    def f(x, packed):
        lhs = x[None]
        out = lax.conv_general_dilated(
            lhs, packed["w"], (scn.stride, scn.stride),
            [(scn.pad, scn.pad)] * 2, dimension_numbers=dn)
        out = out[0]
        # add bias along the M axis of the output layout
        m_axis = _DN_LHS[l_out].index("C") - 1
        bshape = [1, 1, 1]
        bshape[m_axis] = scn.m
        return out + packed["b"].reshape(bshape)

    return f


def _direct_lax_prepare(rhs_spec):
    def prep(scn, w, b):
        if rhs_spec == "HWIO":
            w = np.transpose(w, (2, 3, 1, 0))
        return {"w": jnp.asarray(w), "b": jnp.asarray(b)}
    return prep


def _sum2d(scn: Scenario):
    """Textbook sum-of-single-channels: one 2-D conv per input channel,
    accumulated with a scan.  The paper's SUM2D baseline."""
    def f(x, packed):  # x: CHW
        w, b = packed["w"], packed["b"]  # (M, C, K, K)

        def body(acc, cw):
            xc, wc = cw  # (H, W), (M, K, K)
            out = lax.conv_general_dilated(
                xc[None, None], wc[:, None], (scn.stride, scn.stride),
                [(scn.pad, scn.pad)] * 2,
                dimension_numbers=("NCHW", "OIHW", "NCHW"))
            return acc + out[0], None

        init = jnp.zeros(scn.out_shape_chw, x.dtype)
        acc, _ = lax.scan(body, init, (x, jnp.swapaxes(w, 0, 1)))
        return acc + b[:, None, None]

    return f


def _sum1d(scn: Scenario):
    """Direct conv as a sum of 1-D row convolutions (textbook variant)."""
    def f(x, packed):  # CHW
        w, b = packed["w"], packed["b"]
        xp = _pad_chw(x, scn.pad)
        oh, ow = scn.out_h, scn.out_w
        acc = jnp.zeros((scn.m, oh, ow), x.dtype)
        for i in range(scn.k):
            rows = xp[:, i:i + (oh - 1) * scn.stride + 1:scn.stride, :]
            # 1-D correlation along W for kernel row i
            out = lax.conv_general_dilated(
                rows[None], w[:, :, i, :][..., None, :],
                (1, scn.stride), [(0, 0), (0, 0)],
                dimension_numbers=("NCHW", "OIHW", "NCHW"))
            acc = acc + out[0]
        return acc + b[:, None, None]

    return f


def _shift_add(scn: Scenario, layout: str, use_scan: bool,
               l_in: Optional[str] = None, l_out: Optional[str] = None):
    """Shift-and-add loop nest over the K x K kernel positions.

    ``l_in``/``l_out`` override the wire layouts (transform fusion);
    the CHW working layout means a CHW wire fuses for free.
    """
    l_in = l_in or layout
    l_out = l_out or layout

    def f(x, packed):
        w, b = packed["w"], packed["b"]  # (M, C, K, K)
        xc = _to_chw(x, l_in)
        xp = _pad_chw(xc, scn.pad)
        oh, ow, s = scn.out_h, scn.out_w, scn.stride

        if use_scan:
            kk = scn.k * scn.k
            wflat = w.reshape(scn.m, scn.c, kk)

            def body(acc, t):
                i, j = t // scn.k, t % scn.k
                win = lax.dynamic_slice(
                    xp, (0, i, j),
                    (scn.c, (oh - 1) * s + 1, (ow - 1) * s + 1))[:, ::s, ::s]
                return acc + jnp.einsum("mc,chw->mhw", wflat[:, :, t], win), None

            acc, _ = lax.scan(body, jnp.zeros((scn.m, oh, ow), x.dtype),
                              jnp.arange(kk))
        else:
            acc = jnp.zeros((scn.m, oh, ow), x.dtype)
            for i in range(scn.k):
                for j in range(scn.k):
                    win = xp[:, i:i + (oh - 1) * s + 1:s,
                             j:j + (ow - 1) * s + 1:s]
                    acc = acc + jnp.einsum("mc,chw->mhw", w[:, :, i, j], win)
        return _from_chw(acc + b[:, None, None], l_out)

    return f


def _blocked_hwc8(scn: Scenario):
    """Shift-add over a channel-blocked HWC8 tensor (vector-friendly)."""
    def f(x, packed):  # x: (H, W, C/8, 8)
        w, b = packed["w"], packed["b"]  # w: (M/8, 8, C/8, 8, K, K)
        p, s = scn.pad, scn.stride
        xp = jnp.pad(x, ((p, p), (p, p), (0, 0), (0, 0)))
        oh, ow = scn.out_h, scn.out_w
        acc = jnp.zeros((oh, ow, scn.m // 8, 8), x.dtype)
        for i in range(scn.k):
            for j in range(scn.k):
                win = xp[i:i + (oh - 1) * s + 1:s,
                         j:j + (ow - 1) * s + 1:s]
                acc = acc + jnp.einsum("hwcb,ndcb->hwnd", win, w[..., i, j])
        return acc + b.reshape(scn.m // 8, 8)

    return f


def _blocked_prepare(scn, w, b):
    wb = w.reshape(scn.m // 8, 8, scn.c // 8, 8, scn.k, scn.k)
    return {"w": jnp.asarray(wb), "b": jnp.asarray(b)}


# ======================================================================
# im2 family
# ======================================================================
def _patches_chw(x, scn: Scenario, method: str):
    """Toeplitz patch tensor (C, K, K, OH, OW) from logical CHW input."""
    if method == "xla":
        pt = lax.conv_general_dilated_patches(
            x[None], (scn.k, scn.k), (scn.stride, scn.stride),
            [(scn.pad, scn.pad)] * 2,
            dimension_numbers=("NCHW", "OIHW", "NCHW"))[0]
        return pt.reshape(scn.c, scn.k, scn.k, scn.out_h, scn.out_w)
    # manual: stack shifted strided slices
    xp = _pad_chw(x, scn.pad)
    oh, ow, s = scn.out_h, scn.out_w, scn.stride
    rows = []
    for i in range(scn.k):
        cols = []
        for j in range(scn.k):
            cols.append(xp[:, i:i + (oh - 1) * s + 1:s,
                           j:j + (ow - 1) * s + 1:s])
        rows.append(jnp.stack(cols, axis=1))
    return jnp.stack(rows, axis=1)  # (C, K, K, OH, OW)


def _im2(scn: Scenario, l_in: str, l_out: str, method: str, trans_b: bool,
         split_c: int = 0):
    def f(x, packed):
        xc = _to_chw(x, l_in)
        pt = _patches_chw(xc, scn, method)  # (C, K, K, OH, OW)
        oh, ow = scn.out_h, scn.out_w
        if split_c:
            # low-memory: GEMM per channel chunk, accumulated
            csz = max(1, scn.c // split_c)
            acc = jnp.zeros((scn.m, oh * ow), x.dtype)
            wm = packed["w"]  # (M, C, K*K) or (C, K*K, M) if trans_b
            for c0 in range(0, scn.c, csz):
                p = pt[c0:c0 + csz].reshape(-1, oh * ow)
                if trans_b:
                    acc = acc + (p.T @ wm[c0:c0 + csz].reshape(-1, scn.m)).T
                else:
                    acc = acc + wm[:, c0:c0 + csz].reshape(scn.m, -1) @ p
            y = acc
        else:
            p = pt.reshape(scn.c * scn.k * scn.k, oh * ow)
            if trans_b:
                y = (p.T @ packed["w"]).T  # (CKK, M) weights
            else:
                y = packed["w"] @ p        # (M, CKK) weights
        y = y.reshape(scn.m, oh, ow) + packed["b"][:, None, None]
        return _from_chw(y, l_out)

    return f


def _im2_prepare(trans_b: bool, split_c: int = 0):
    def prep(scn, w, b):
        if split_c:
            wm = w.reshape(scn.m, scn.c, scn.k * scn.k)
            if trans_b:
                wm = np.transpose(wm, (1, 2, 0))  # (C, KK, M)
            return {"w": jnp.asarray(wm), "b": jnp.asarray(b)}
        wm = w.reshape(scn.m, -1)
        if trans_b:
            wm = wm.T.copy()
        return {"w": jnp.asarray(wm), "b": jnp.asarray(b)}
    return prep


def _im2row_hwc(scn: Scenario, l_out: str, method: str, trans_b: bool,
                l_in: str = "HWC"):
    """HWC-native im2row: patch rows (OH*OW, K*K*C) @ (K*K*C, M).

    ``l_in`` overrides the wire layout (transform fusion): a CHW wire
    skips the internal transpose and feeds the patch gather directly.
    """
    def f(x, packed):
        xc = _to_chw(x, l_in)
        pt = _patches_chw(xc, scn, method)  # (C, K, K, OH, OW)
        p = jnp.transpose(pt, (3, 4, 1, 2, 0)).reshape(
            scn.out_h * scn.out_w, -1)  # (OHOW, KKC)
        if trans_b:
            y = (packed["w"] @ p.T).T  # (M, KKC) @ (KKC, OHOW)
        else:
            y = p @ packed["w"]        # (KKC, M)
        y = y.reshape(scn.out_h, scn.out_w, scn.m) + packed["b"]
        if l_out == "HWC":
            return y
        return convert_layout(y, "HWC", l_out)

    return f


def _im2row_prepare(trans_b: bool):
    def prep(scn, w, b):
        wm = np.transpose(w, (2, 3, 1, 0)).reshape(-1, scn.m)  # (KKC, M)
        if trans_b:
            wm = wm.T.copy()
        return {"w": jnp.asarray(wm), "b": jnp.asarray(b)}
    return prep


# pointwise (K=1) GEMM specialisations
def _pw(scn: Scenario, layout: str, trans_b: bool):
    def f(x, packed):
        s = scn.stride
        if layout == "CHW":
            xs = x[:, ::s, ::s] if s > 1 else x
            p = xs.reshape(scn.c, -1)
            y = (p.T @ packed["w"]).T if trans_b else packed["w"] @ p
            y = y.reshape(scn.m, scn.out_h, scn.out_w) + packed["b"][:, None, None]
            return y
        elif layout == "HWC":
            xs = x[::s, ::s, :] if s > 1 else x
            p = xs.reshape(-1, scn.c)
            y = (packed["w"] @ p.T).T if trans_b else p @ packed["w"]
            return y.reshape(scn.out_h, scn.out_w, scn.m) + packed["b"]
        else:  # HCW
            xs = x[::s, :, ::s] if s > 1 else x
            y = jnp.einsum("hcw,cm->hmw", xs, packed["w"])
            return y + packed["b"][None, :, None]

    return f


def _pw_prepare(layout: str, trans_b: bool):
    def prep(scn, w, b):
        wm = w.reshape(scn.m, scn.c)
        if layout == "CHW":
            wm = wm.T.copy() if trans_b else wm
        elif layout == "HWC":
            wm = wm if trans_b else wm.T.copy()
        else:
            wm = wm.T.copy()
        return {"w": jnp.asarray(wm), "b": jnp.asarray(b)}
    return prep


# ======================================================================
# kn2 family (stride-1 only)
# ======================================================================
def _kn2(scn: Scenario, col: bool, mode: str,
         l_in: Optional[str] = None, l_out: Optional[str] = None):
    """kn2row / kn2col: one (M x C) GEMM per kernel position, shifted
    accumulation into the output.  Low memory, no Toeplitz matrix.

    ``l_in``/``l_out`` override the wire layouts (transform fusion): the
    prologue reads ``l_in`` directly — a CHW wire into kn2col skips the
    internal transpose entirely — and the epilogue emits ``l_out`` by
    retargeting the accumulation einsum where possible.
    """
    l_in = l_in or ("HWC" if col else "CHW")
    l_out = l_out or ("HWC" if col else "CHW")

    def f(x, packed):
        w, b = packed["w"], packed["b"]  # (K, K, M, C)
        xc = _to_chw(x, l_in)
        xp = _pad_chw(xc, scn.pad)
        oh, ow = scn.out_h, scn.out_w
        # the accumulation einsum can emit either HWC or CHW directly —
        # the epilogue-fusion lever; other layouts convert from CHW
        hwc_acc = l_out == "HWC"

        def one(i, j):
            win = xp[:, i:i + oh, j:j + ow]
            if hwc_acc:
                return jnp.einsum("chw,mc->hwm", win, w[i, j])
            return jnp.einsum("mc,chw->mhw", w[i, j], win)

        if mode == "scan":
            wflat = w.reshape(scn.k * scn.k, scn.m, scn.c)

            def body(acc, t):
                i, j = t // scn.k, t % scn.k
                win = lax.dynamic_slice(xp, (0, i, j), (scn.c, oh, ow))
                if hwc_acc:
                    return acc + jnp.einsum("chw,mc->hwm", win, wflat[t]), None
                return acc + jnp.einsum("mc,chw->mhw", wflat[t], win), None

            shape = (oh, ow, scn.m) if hwc_acc else (scn.m, oh, ow)
            acc, _ = lax.scan(body, jnp.zeros(shape, x.dtype),
                              jnp.arange(scn.k * scn.k))
        elif mode == "stack":
            parts = jnp.stack([one(i, j) for i in range(scn.k)
                               for j in range(scn.k)])
            acc = jnp.sum(parts, axis=0)
        else:  # unrolled accumulation
            acc = one(0, 0)
            for t in range(1, scn.k * scn.k):
                acc = acc + one(t // scn.k, t % scn.k)

        if hwc_acc:
            return acc + b
        return _from_chw(acc + b[:, None, None], l_out)

    return f


def _kn2_prepare(scn, w, b):
    return {"w": jnp.asarray(np.transpose(w, (2, 3, 0, 1)).copy()),
            "b": jnp.asarray(b)}


# ======================================================================
# winograd family (stride-1, K in {3, 5})
# ======================================================================
def _wino2d(scn: Scenario, m_: int, l_in: str, l_out: str):
    A, G, Bt = (jnp.asarray(t, jnp.float32)
                for t in winograd_matrices(m_, scn.k))
    a = m_ + scn.k - 1

    def f(x, packed):
        U = packed["w"]  # (M, C, a, a) transformed kernels
        xc = _to_chw(x, l_in)
        oh, ow = scn.out_h, scn.out_w
        nth, ntw = -(-oh // m_), -(-ow // m_)
        # pad so that tiles of alpha with stride m_ cover all outputs
        ph = (nth - 1) * m_ + a - (scn.h + 2 * scn.pad)
        pw = (ntw - 1) * m_ + a - (scn.w + 2 * scn.pad)
        xp = jnp.pad(xc, ((0, 0), (scn.pad, scn.pad + max(ph, 0)),
                          (scn.pad, scn.pad + max(pw, 0))))
        pt = lax.conv_general_dilated_patches(
            xp[None], (a, a), (m_, m_), [(0, 0), (0, 0)],
            dimension_numbers=("NCHW", "OIHW", "NCHW"))[0]
        d = pt.reshape(scn.c, a, a, nth, ntw)
        V = jnp.einsum("ai,cijtu,bj->cabtu", Bt, d, Bt)
        Q = jnp.einsum("mcab,cabtu->mabtu", U, V)
        Y = jnp.einsum("ap,mabtu,bq->mtpuq", A, Q, A)
        y = Y.reshape(scn.m, nth * m_, ntw * m_)[:, :oh, :ow]
        return _from_chw(y + packed["b"][:, None, None], l_out)

    return f


def _wino2d_prepare(m_: int):
    def prep(scn, w, b):
        A, G, Bt = winograd_matrices(m_, scn.k)
        U = np.einsum("ar,mcrs,bs->mcab", G, w, G)
        return {"w": jnp.asarray(U, jnp.float32), "b": jnp.asarray(b)}
    return prep


def _wino1d(scn: Scenario, m_: int, l_in: str, l_out: str):
    """Row-wise 1-D Winograd: F(m_, K) along W for each kernel row, with
    the K row contributions accumulated pre-output-transform.  Needs only
    O(alpha/m_) extra memory per row — the paper's ARM selections."""
    A, G, Bt = (jnp.asarray(t, jnp.float32)
                for t in winograd_matrices(m_, scn.k))
    a = m_ + scn.k - 1

    def f(x, packed):
        Ug = packed["w"]  # (K, M, C, a): per kernel row transformed taps
        xc = _to_chw(x, l_in)
        oh, ow = scn.out_h, scn.out_w
        ntw = -(-ow // m_)
        pw = (ntw - 1) * m_ + a - (scn.w + 2 * scn.pad)
        xp = jnp.pad(xc, ((0, 0), (scn.pad, scn.pad),
                          (scn.pad, scn.pad + max(pw, 0))))
        Q = jnp.zeros((scn.m, oh, ntw, a), x.dtype)
        for i in range(scn.k):
            rows = xp[:, i:i + oh, :]  # stride-1 only
            # tiles along W: (C, OH, ntw, a)
            idx = (jnp.arange(ntw)[:, None] * m_ + jnp.arange(a)[None, :])
            tiles = rows[:, :, idx]
            V = jnp.einsum("ab,chtb->chta", Bt, tiles)
            Q = Q + jnp.einsum("mca,chta->mhta", Ug[i], V)
        Y = jnp.einsum("ap,mhta->mhtp", A, Q)
        y = Y.reshape(scn.m, oh, ntw * m_)[:, :, :ow]
        return _from_chw(y + packed["b"][:, None, None], l_out)

    return f


def _wino1d_prepare(m_: int):
    def prep(scn, w, b):
        A, G, Bt = winograd_matrices(m_, scn.k)
        # (K rows, M, C, alpha)
        Ug = np.einsum("ar,mcir->imca", G, w)
        return {"w": jnp.asarray(Ug, jnp.float32), "b": jnp.asarray(b)}
    return prep


# ======================================================================
# fft family
# ======================================================================
def _next_pow2(n: int) -> int:
    return 1 << (n - 1).bit_length()


def _fft2d(scn: Scenario, l_in: str, l_out: str, pow2: bool,
           subsample: bool = False):
    def f(x, packed):
        Wf, b = packed["w"], packed["b"]
        xc = _to_chw(x, l_in)
        xp = _pad_chw(xc, scn.pad)
        hp, wp = xp.shape[1], xp.shape[2]
        fh, fw = hp + scn.k - 1, wp + scn.k - 1
        if pow2:
            fh, fw = _next_pow2(fh), _next_pow2(fw)
        Xf = jnp.fft.rfft2(xp, s=(fh, fw))
        Of = jnp.einsum("chw,mchw->mhw", Xf, Wf)
        of = jnp.fft.irfft2(Of, s=(fh, fw))
        full_oh = hp - scn.k + 1
        full_ow = wp - scn.k + 1
        y = of[:, scn.k - 1:scn.k - 1 + full_oh,
               scn.k - 1:scn.k - 1 + full_ow]
        if subsample and scn.stride > 1:
            y = y[:, ::scn.stride, ::scn.stride]
        y = y + b[:, None, None]
        return _from_chw(y.astype(x.dtype), l_out)

    return f


def _fft2d_prepare(pow2: bool):
    def prep(scn, w, b):
        hp, wp = scn.h + 2 * scn.pad, scn.w + 2 * scn.pad
        fh, fw = hp + scn.k - 1, wp + scn.k - 1
        if pow2:
            fh, fw = _next_pow2(fh), _next_pow2(fw)
        wf = np.fft.rfft2(w[:, :, ::-1, ::-1], s=(fh, fw))
        return {"w": jnp.asarray(wf), "b": jnp.asarray(b)}
    return prep


def _fft1d_sum(scn: Scenario, l_in: str, l_out: str, pow2: bool):
    """2-D conv as a sum of per-kernel-row 1-D FFT convolutions along W,
    accumulated in the frequency domain (the paper's low-memory variant)."""
    def f(x, packed):
        Wf, b = packed["w"], packed["b"]  # (K, M, C, F)
        xc = _to_chw(x, l_in)
        xp = _pad_chw(xc, scn.pad)
        wp = xp.shape[2]
        fw = wp + scn.k - 1
        if pow2:
            fw = _next_pow2(fw)
        oh = scn.out_h
        Of = None
        for i in range(scn.k):
            rows = xp[:, i:i + oh, :]
            Rf = jnp.fft.rfft(rows, n=fw, axis=-1)  # (C, OH, F)
            term = jnp.einsum("chf,mcf->mhf", Rf, Wf[i])
            Of = term if Of is None else Of + term
        of = jnp.fft.irfft(Of, n=fw, axis=-1)
        y = of[:, :, scn.k - 1:scn.k - 1 + scn.out_w]
        return _from_chw(y.astype(x.dtype) + b[:, None, None], l_out)

    return f


def _fft1d_prepare(pow2: bool):
    def prep(scn, w, b):
        wp = scn.w + 2 * scn.pad
        fw = wp + scn.k - 1
        if pow2:
            fw = _next_pow2(fw)
        wf = np.fft.rfft(w[:, :, :, ::-1], n=fw, axis=-1)  # (M, C, K, F)
        wf = np.transpose(wf, (2, 0, 1, 3)).copy()  # (K, M, C, F)
        return {"w": jnp.asarray(wf), "b": jnp.asarray(b)}
    return prep


# ======================================================================
# registry construction
# ======================================================================
def _sup(k_in=None, stride1=False, blocked=False, kmin_hw=True):
    def s(scn: Scenario) -> bool:
        if k_in is not None and scn.k not in k_in:
            return False
        if stride1 and scn.stride != 1:
            return False
        if blocked and (scn.c % 8 or scn.m % 8):
            return False
        if kmin_hw and (scn.h + 2 * scn.pad < scn.k or
                        scn.w + 2 * scn.pad < scn.k):
            return False
        return True
    return s


@functools.lru_cache(maxsize=1)
def build_registry() -> Tuple[Primitive, ...]:
    prims: List[Primitive] = []

    def add(name, family, l_in, l_out, supports, prepare, make, tags=(),
            fusable_in=FUSABLE_LAYOUTS, fusable_out=FUSABLE_LAYOUTS,
            fused=None):
        prims.append(Primitive(name, family, l_in, l_out, supports,
                               prepare, make, tuple(tags),
                               tuple(fusable_in), tuple(fusable_out),
                               fused))

    # ---------------- direct ----------------
    # direct_lax is natively layout-parameterized: a fused edge simply
    # rebuilds the conv with dimension_numbers matching the wire layout
    # — the operator consumes/emits it directly, no transpose op at all
    def _lax_fused(rhs):
        return lambda scn, li, lo: _direct_lax(scn, li, lo, rhs)

    for l_in, l_out in [("CHW", "CHW"), ("HWC", "HWC"), ("CHW", "HWC"),
                        ("HWC", "CHW"), ("HCW", "HCW")]:
        for rhs in (["OIHW", "HWIO"] if l_in in ("CHW", "HWC") else ["OIHW"]):
            add(f"direct_lax_{l_in.lower()}_{l_out.lower()}_{rhs.lower()}",
                "direct", l_in, l_out, _sup(),
                _direct_lax_prepare(rhs),
                functools.partial(_direct_lax, l_in=l_in, l_out=l_out,
                                  rhs_spec=rhs),
                fusable_in=tuple(_DN_LHS), fusable_out=tuple(_DN_LHS),
                fused=_lax_fused(rhs))
    def _shift_fused(layout, use_scan):
        return lambda scn, li, lo: _shift_add(scn, layout, use_scan,
                                              l_in=li, l_out=lo)

    add("sum2d", "direct", "CHW", "CHW", _sup(), _std_prepare, _sum2d,
        tags=("baseline",))
    add("sum1d", "direct", "CHW", "CHW", _sup(), _std_prepare, _sum1d)
    for layout in ["CHW", "HWC", "HCW"]:
        add(f"direct_shiftadd_{layout.lower()}", "direct", layout, layout,
            _sup(), _std_prepare,
            functools.partial(_shift_add, layout=layout, use_scan=False),
            fused=_shift_fused(layout, False))
    for layout in ["CHW", "HWC"]:
        add(f"direct_shiftscan_{layout.lower()}", "direct", layout, layout,
            _sup(), _std_prepare,
            functools.partial(_shift_add, layout=layout, use_scan=True),
            fused=_shift_fused(layout, True))
    add("direct_blocked_hwc8", "direct", "HWC8", "HWC8",
        _sup(blocked=True), _blocked_prepare, _blocked_hwc8)

    # ---------------- im2 ----------------
    def _im2_fused(method, trans_b, split_c=0):
        return lambda scn, li, lo: _im2(scn, li, lo, method, trans_b,
                                        split_c)

    def _im2row_fused(method, trans_b):
        return lambda scn, li, lo: _im2row_hwc(scn, lo, method, trans_b,
                                               l_in=li)

    for method in ["xla", "manual"]:
        for trans_b in [False, True]:
            t = "t" if trans_b else "n"
            add(f"im2col_{method}_{t}_chw", "im2", "CHW", "CHW", _sup(),
                _im2_prepare(trans_b),
                functools.partial(_im2, l_in="CHW", l_out="CHW",
                                  method=method, trans_b=trans_b),
                fused=_im2_fused(method, trans_b))
            add(f"im2row_{method}_{t}_hwc", "im2", "HWC", "HWC", _sup(),
                _im2row_prepare(trans_b),
                functools.partial(_im2row_hwc, l_out="HWC", method=method,
                                  trans_b=trans_b),
                fused=_im2row_fused(method, trans_b))
    add("im2col_xla_n_chw_hwc", "im2", "CHW", "HWC", _sup(),
        _im2_prepare(False),
        functools.partial(_im2, l_in="CHW", l_out="HWC", method="xla",
                          trans_b=False),
        fused=_im2_fused("xla", False))
    add("im2row_xla_n_hwc_chw", "im2", "HWC", "CHW", _sup(),
        _im2row_prepare(False),
        functools.partial(_im2row_hwc, l_out="CHW", method="xla",
                          trans_b=False),
        fused=_im2row_fused("xla", False))
    for split in [4, 8]:
        add(f"im2col_split{split}_chw", "im2", "CHW", "CHW", _sup(),
            _im2_prepare(False, split_c=split),
            functools.partial(_im2, l_in="CHW", l_out="CHW", method="xla",
                              trans_b=False, split_c=split),
            tags=("lowmem",), fused=_im2_fused("xla", False, split))
    # pointwise K=1 GEMM specialisations
    for layout in ["CHW", "HWC"]:
        for trans_b in [False, True]:
            t = "t" if trans_b else "n"
            add(f"pw_gemm_{t}_{layout.lower()}", "im2", layout, layout,
                _sup(k_in=(1,)), _pw_prepare(layout, trans_b),
                functools.partial(_pw, layout=layout, trans_b=trans_b))
    add("pw_gemm_n_hcw", "im2", "HCW", "HCW", _sup(k_in=(1,)),
        _pw_prepare("HCW", False),
        functools.partial(_pw, layout="HCW", trans_b=False))

    # ---------------- kn2 ----------------
    def _kn2_fused(col, mode):
        return lambda scn, li, lo: _kn2(scn, col, mode, l_in=li, l_out=lo)

    for col, layout in [(False, "CHW"), (True, "HWC")]:
        nm = "kn2col" if col else "kn2row"
        for mode in ["unroll", "scan", "stack"]:
            add(f"{nm}_{mode}_{layout.lower()}", "kn2", layout, layout,
                _sup(stride1=True), _kn2_prepare,
                functools.partial(_kn2, col=col, mode=mode),
                tags=("lowmem",) if mode != "stack" else (),
                fused=_kn2_fused(col, mode))

    # ---------------- winograd ----------------
    def _wino2d_fused(m_):
        return lambda scn, li, lo: _wino2d(scn, m_, li, lo)

    def _wino1d_fused(m_):
        return lambda scn, li, lo: _wino1d(scn, m_, li, lo)

    for m_ in [2, 4, 6]:
        for layout in ["CHW", "HWC"]:
            for k in ([3, 5] if m_ != 6 else [3]):
                add(f"wino2d_f{m_}x{k}_{layout.lower()}", "winograd",
                    layout, layout, _sup(k_in=(k,), stride1=True),
                    _wino2d_prepare(m_),
                    functools.partial(_wino2d, m_=m_, l_in=layout,
                                      l_out=layout),
                    fused=_wino2d_fused(m_))
    for m_ in [2, 4]:
        for layout in ["CHW", "HWC"]:
            for k in [3, 5]:
                add(f"wino1d_f{m_}x{k}_{layout.lower()}", "winograd",
                    layout, layout, _sup(k_in=(k,), stride1=True),
                    _wino1d_prepare(m_),
                    functools.partial(_wino1d, m_=m_, l_in=layout,
                                      l_out=layout),
                    tags=("lowmem",), fused=_wino1d_fused(m_))

    # ---------------- fft ----------------
    def _fft2d_fused(pow2, subsample=False):
        return lambda scn, li, lo: _fft2d(scn, li, lo, pow2, subsample)

    def _fft1d_fused(pow2):
        return lambda scn, li, lo: _fft1d_sum(scn, li, lo, pow2)

    for layout in ["CHW", "HWC"]:
        for pow2 in [False, True]:
            p = "p2" if pow2 else "ex"
            add(f"fft2d_{p}_{layout.lower()}", "fft", layout, layout,
                _sup(stride1=True), _fft2d_prepare(pow2),
                functools.partial(_fft2d, l_in=layout, l_out=layout,
                                  pow2=pow2),
                fused=_fft2d_fused(pow2))
            add(f"fft1d_sum_{p}_{layout.lower()}", "fft", layout, layout,
                _sup(stride1=True), _fft1d_prepare(pow2),
                functools.partial(_fft1d_sum, l_in=layout, l_out=layout,
                                  pow2=pow2),
                tags=("lowmem",), fused=_fft1d_fused(pow2))
    add("fft2d_strided_chw", "fft", "CHW", "CHW", _sup(), _fft2d_prepare(False),
        functools.partial(_fft2d, l_in="CHW", l_out="CHW", pow2=False,
                          subsample=True),
        fused=_fft2d_fused(False, True))

    # ---------------- pallas (TPU kernels; analytic costs) ----------------
    try:
        from ..kernels import register_pallas_primitives
        register_pallas_primitives(add, _sup)
    except ImportError:  # pragma: no cover
        pass

    names = [p.name for p in prims]
    assert len(names) == len(set(names)), "duplicate primitive names"
    return tuple(prims)


# ----------------------------------------------------------------------
# registry extensions + memoization
#
# ``registry()`` is on the hot path of every solve (``primitives_for``
# walks it once per node), so the base + extension concatenation is
# memoized; mutators below invalidate explicitly.  Extensions are how
# the autotuner (repro/autotune/) registers generated Pallas variants as
# first-class primitives without rebuilding the hand-written library.
# ----------------------------------------------------------------------
_REG_LOCK = threading.Lock()
#: name -> (primitives, token); token feeds CostModel.version() so
#: installing/removing an extension rotates every cached plan key.
_EXTENSIONS: Dict[str, Tuple[Tuple[Primitive, ...], str]] = {}
_REG_CACHE: Optional[Tuple[Primitive, ...]] = None


def invalidate_registry_cache() -> None:
    """Drop the memoized registry; next ``registry()`` rebuilds it."""
    global _REG_CACHE
    with _REG_LOCK:
        _REG_CACHE = None


def register_extension(name: str, prims: Sequence[Primitive],
                       token: str = "") -> None:
    """Install (or replace) an extension set of primitives.

    ``token`` should digest the extension's content (the autotuner
    passes the variant catalog's content hash): it is folded into
    ``extension_token()`` and hence every ``CostModel.version()``, so
    plans cached against a different variant set can never be served.
    """
    prims = tuple(prims)
    with _REG_LOCK:
        base_names = {p.name for p in build_registry()}
        for other, (ps, _) in _EXTENSIONS.items():
            if other != name:
                base_names.update(p.name for p in ps)
        names = [p.name for p in prims]
        dup = (set(names) & base_names) or \
            {n for n in names if names.count(n) > 1}
        if dup:
            raise ValueError(f"extension {name!r}: duplicate primitive "
                             f"names {sorted(dup)}")
        _EXTENSIONS[name] = (prims, str(token))
        global _REG_CACHE
        _REG_CACHE = None


def unregister_extension(name: str) -> bool:
    """Remove one extension; returns whether it was installed."""
    with _REG_LOCK:
        found = _EXTENSIONS.pop(name, None) is not None
        if found:
            global _REG_CACHE
            _REG_CACHE = None
        return found


def clear_extensions() -> None:
    """Remove every extension (tests; serve-path reset)."""
    with _REG_LOCK:
        _EXTENSIONS.clear()
        global _REG_CACHE
        _REG_CACHE = None


def extension_token() -> str:
    """Digest of the installed extensions (empty string when none).

    Folded into ``CostModel.version()`` (see ``core.costs``): the plan
    cache key moves whenever the variant set changes.
    """
    if not _EXTENSIONS:
        return ""
    return ";".join(f"{n}:{_EXTENSIONS[n][1] or len(_EXTENSIONS[n][0])}"
                    for n in sorted(_EXTENSIONS))


def registry() -> Tuple[Primitive, ...]:
    """The full primitive library: hand-written base + extensions."""
    global _REG_CACHE
    cache = _REG_CACHE
    if cache is None:
        with _REG_LOCK:
            cache = _REG_CACHE
            if cache is None:
                ext = tuple(p for n in sorted(_EXTENSIONS)
                            for p in _EXTENSIONS[n][0])
                cache = _REG_CACHE = build_registry() + ext
    return cache


def primitives_for(scn: Scenario,
                   families: Optional[Sequence[str]] = None,
                   exclude_tags: Sequence[str] = ()) -> List[Primitive]:
    out = []
    for p in registry():
        if families and p.family not in families:
            continue
        if any(t in p.tags for t in exclude_tags):
            continue
        if p.supports(scn):
            out.append(p)
    return out
