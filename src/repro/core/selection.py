"""PBQP construction, solving, legalization — Section 3 of the paper.

The embedding (built through the unified choice-space bridge of
:mod:`repro.core.choice_space`, which :mod:`repro.core.sharding_select`
shares for its resharding-collective transform kind):

* conv node  -> PBQP node whose domain is the applicable primitives;
  node cost vector = profiled execution time of each primitive.
* op node    -> PBQP node whose domain is the layouts it accepts;
  node cost vector = 0 (the paper's zero-cost dummy nodes).
* edge (u,v) -> cost matrix T[i, j] = APSP cost in the DT graph from
  u's choice-i output layout to v's choice-j input layout, measured on
  the actual tensor shape flowing along the edge (inf if no chain of
  transformations exists).

``legalize`` then bisects every edge whose endpoint layouts differ with
the explicit shortest chain of conversion layers — the cost of which the
optimum already accounts for (the paper's key point: pricing conversions
*after* selection is what makes greedy/local strategies sub-optimal).

**Device placement axis.**  With ``mesh_axes={"data": D}`` the choice
space gains a second dimension: every node's domain is primitives (or
layouts) × placements {``rep``: whole batch replicated on every device,
``dp``: batch sharded D ways over the mesh's ``data`` axis}.  Node
costs price the per-device invocation (``Scenario.n/D`` for ``dp``);
edges whose endpoints disagree on placement pay the resharding
collective (``dp -> rep``: an all-gather of the whole batched tensor —
the distributed analogue of a layout transform); ``dp`` choices on
output nodes pay the final delivery gather.  The solver therefore
trades collective time against replicated compute per layer, exactly
as it trades transform time against primitive speed.
:func:`~repro.core.plan.compile_plan` realizes placements as
``NamedSharding`` constraints on a mesh (docs/distributed.md).

docs/solver.md works a small instance through this embedding end to
end; any :class:`~repro.core.costs.CostModel` can price it, including
the measured tables of :class:`repro.calibrate.CalibratedCostModel`
(docs/calibration.md).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import pbqp
from .choice_space import ChoiceEdge, ChoiceNode, build_pbqp
from .costs import CostModel
from .graph import Net, Node
from .layouts import DTGraph, transform_feasible
from .primitives import Primitive, primitives_for
from .scenario import Scenario

__all__ = ["SelectionResult", "select_pbqp", "select_fixed",
           "select_sum2d", "select_local_optimal", "select_family_best",
           "Choice", "warm_assignment", "placements_for"]


@dataclass(frozen=True)
class Choice:
    """Resolved assignment for one node."""
    primitive: Optional[Primitive]  # None for op nodes
    l_in: str
    l_out: str
    #: device placement: "rep" (replicated over the mesh's data axis)
    #: or "dp" (batch sharded over it).  Always "rep" without a mesh.
    placement: str = "rep"


@dataclass
class SelectionResult:
    net: Net
    choices: Dict[str, Choice]
    #: per-edge conversion chains: (src, dst) -> [layout names] (len>=2)
    conversions: Dict[Tuple[str, str], List[str]]
    predicted_cost: float
    optimal: bool
    strategy: str
    solver_stats: Dict[str, int] = field(default_factory=dict)
    #: per-edge fused realizations: (src, dst) -> "in" | "out".  "in":
    #: the consumer's prologue reads the producer's layout directly;
    #: "out": the producer's epilogue emits the consumer's layout.  An
    #: edge is either here or in ``conversions``, never both.
    fusions: Dict[Tuple[str, str], str] = field(default_factory=dict)


def _conv_domain(node: Node, cost: CostModel,
                 families: Optional[Sequence[str]] = None,
                 require_finite: bool = True):
    prims = primitives_for(node.scn, families=families)
    entries = [(p, cost.primitive_cost(p, node.scn)) for p in prims]
    if require_finite:
        finite = [(p, c) for (p, c) in entries if np.isfinite(c)]
        entries = finite or entries
    if not entries:
        raise ValueError(f"no primitive supports {node.scn}")
    return entries


def _fused_options(cost: CostModel, src_node: Node, dst_node: Node,
                   cu: Choice, cv: Choice, single_consumer: bool,
                   shape) -> List[Tuple[float, str]]:
    """Fused realizations available for one (choice, choice) edge pair.

    Returns ``[(per-image cost, kind)]`` with kind ``"in"`` (consumer
    prologue reads ``cu.l_out``) or ``"out"`` (producer epilogue emits
    ``cv.l_in``).  Capability comes from the primitive registry's
    ``fusable_in``/``fusable_out`` declarations; blocked-layout
    feasibility from :func:`~repro.core.layouts.transform_feasible`.
    Epilogue fusion is only offered when the producer has a single
    consumer — a fused-out producer changes the value *every* consumer
    sees, so fan-out edges must materialize (or fuse on the consumer
    side).
    """
    opts: List[Tuple[float, str]] = []
    if cu.l_out == cv.l_in:
        return opts
    pv = cv.primitive
    if pv is not None and cu.l_out in pv.fusable_in and \
            transform_feasible(cu.l_out, pv.l_in, shape):
        opts.append((cost.fused_in_cost(pv, dst_node.scn, cu.l_out), "in"))
    pu = cu.primitive
    if pu is not None and single_consumer and cv.l_in in pu.fusable_out \
            and transform_feasible(pu.l_out, cv.l_in, shape):
        opts.append((cost.fused_out_cost(pu, src_node.scn, cv.l_in), "out"))
    return opts


def _out_degree(net: Net) -> Dict[str, int]:
    deg: Dict[str, int] = {}
    for (src, _) in net.edges():
        deg[src] = deg.get(src, 0) + 1
    return deg


def _net_batch(net: Net) -> int:
    """The net's minibatch (single definition: placement domains and
    dp shard pricing must derive it identically)."""
    return max((n.scn.n for n in net.conv_nodes()), default=1)


def placements_for(net: Net,
                   mesh_axes: Optional[Dict[str, int]]) -> List[str]:
    """Placement domain for a net on a mesh: ``["rep"]`` (no mesh, a
    degenerate data axis, or a batch the axis cannot divide) or
    ``["dp", "rep"]`` — dp first, so cost *ties* (zero-cost op nodes,
    free edges) resolve to the sharded choice: replicated execution at
    equal priced time still burns D× the compute."""
    d = int(mesh_axes.get("data", 1)) if mesh_axes else 1
    nb = _net_batch(net)
    if d > 1 and nb >= d and nb % d == 0:
        return ["dp", "rep"]
    return ["rep"]


def _build(net: Net, cost: CostModel, *,
           fixed: Optional[Dict[str, Primitive]] = None,
           families: Optional[Sequence[str]] = None,
           fuse: bool = False,
           mesh_axes: Optional[Dict[str, int]] = None):
    """Build the PBQP instance; returns (problem, domains).

    ``fixed`` pins given conv nodes to a single primitive (domain size 1)
    — used by the baseline strategies, which still get optimal *layout*
    legalization through the op nodes.

    ``fuse`` prices every edge entry as ``min(materialized DT chain,
    fused prologue, fused epilogue)`` — the solver then sees transforms
    at their fused price and can pick primitive pairs a materialized-only
    model would reject (the tentpole of the fusion subsystem).

    ``mesh_axes`` (e.g. ``{"data": 8}``) enables the device-placement
    axis: domains cross with {rep, dp}, ``dp`` node costs price the
    per-device shard (``Scenario.n/D``), placement-mismatched edges pay
    the resharding collective, and ``dp`` output nodes pay the delivery
    all-gather.  The whole construction goes through the shared
    :func:`repro.core.choice_space.build_pbqp` bridge — the same one
    :mod:`repro.core.sharding_select` builds its collective-priced
    instances with.
    """
    dt = cost.dt_graph()
    nb = _net_batch(net)
    placements = placements_for(net, mesh_axes)
    d_mesh = int(mesh_axes.get("data", 1)) if mesh_axes else 1
    outputs = set(net.outputs())

    def delivery(node: Node, pl: str) -> float:
        """Final all-gather a dp *output* node pays so the caller sees
        the full batch (rep outputs are already whole on every device)."""
        if pl != "dp" or node.id not in outputs:
            return 0.0
        nbytes = 4 * float(np.prod(node.out_shape)) * nb
        return cost.collective_cost("all_gather", nbytes, d_mesh)

    nodes: List[ChoiceNode] = []
    for nid in net.order:
        node = net.nodes[nid]
        if node.kind == "input":
            choices = [Choice(None, "CHW", "CHW", pl) for pl in placements]
            costs = [0.0] * len(choices)
        elif node.kind == "conv":
            if fixed and nid in fixed:
                p = fixed[nid]
                c = cost.primitive_cost(p, node.scn)
                entries = [(p, c if np.isfinite(c) else 1e6)]
            else:
                entries = _conv_domain(node, cost, families)
            choices, costs = [], []
            for p, c_rep in entries:
                for pl in placements:
                    choices.append(Choice(p, p.l_in, p.l_out, pl))
                    c = c_rep if pl == "rep" else cost.primitive_cost(
                        p, node.scn.with_(n=nb // d_mesh))
                    costs.append(c + delivery(node, pl))
        else:  # op
            choices = [Choice(None, l, l, pl) for l in node.op.layouts
                       for pl in placements]
            costs = [delivery(node, ch.placement) for ch in choices]
        nodes.append(ChoiceNode(nid, choices, costs))

    # Transform costs are priced per image by the DT graph and scale
    # with the images each device actually transforms: the whole
    # minibatch nb when both endpoints are replicated, the nb/D shard
    # when either endpoint is batch-sharded (GSPMD runs the transform
    # on the sharded side of a mixed edge).  A dp -> rep transition
    # additionally pays the all-gather of the whole batched tensor —
    # the resharding collective is this axis's "layout transformation".
    deg = _out_degree(net)
    edges: List[ChoiceEdge] = []
    for (src, dst) in net.edges():
        shape = net.nodes[src].out_shape
        dtcosts, idx = dt.cost_matrix(shape)
        sn, dn = net.nodes[src], net.nodes[dst]
        single = deg.get(src, 0) == 1
        img_bytes = 4 * float(np.prod(shape))

        def transition(cu: Choice, cv: Choice, *, dtcosts=dtcosts,
                       idx=idx, sn=sn, dn=dn, single=single,
                       shape=shape, img_bytes=img_bytes) -> float:
            per_img = dtcosts[idx[cu.l_out], idx[cv.l_in]]
            if fuse and cu.placement == cv.placement:
                for c, _ in _fused_options(cost, sn, dn, cu, cv,
                                           single, shape):
                    if c < per_img:
                        per_img = c
            sharded = "dp" in (cu.placement, cv.placement)
            t = per_img * (nb // d_mesh if sharded else nb)
            if cu.placement == "dp" and cv.placement == "rep":
                t += cost.collective_cost("all_gather",
                                          img_bytes * nb, d_mesh)
            return t

        edges.append(ChoiceEdge(src, dst, transition))

    pb, domains = build_pbqp(nodes, edges)
    return pb, domains, dt


def _legalize(net: Net, dt: DTGraph, choices: Dict[str, Choice], *,
              cost: Optional[CostModel] = None, fuse: bool = False
              ) -> Tuple[Dict[Tuple[str, str], List[str]],
                         Dict[Tuple[str, str], str]]:
    """Realize every mismatched edge as either a materialized conversion
    chain or a fused prologue/epilogue.

    The realization replays exactly the pricing :func:`_build` fed the
    solver — ``min(materialized, fused options)``, materialized
    preferred on ties, fused options only offered when both endpoints
    share a device placement (exactly as the edge matrices were priced)
    — so the executed plan's transform cost is the one the optimum
    accounted for.  With ``fuse=False`` (the paper's system), every
    mismatched edge materializes.
    """
    conversions: Dict[Tuple[str, str], List[str]] = {}
    fusions: Dict[Tuple[str, str], str] = {}
    deg = _out_degree(net)
    for (src, dst) in net.edges():
        lo = choices[src].l_out
        li = choices[dst].l_in
        if lo == li:
            continue
        shape = net.nodes[src].out_shape
        kind = "dt"
        if fuse and cost is not None and \
                choices[src].placement == choices[dst].placement:
            costs, idx = dt.cost_matrix(shape)
            options = [(costs[idx[lo], idx[li]], "dt")]
            options += _fused_options(cost, net.nodes[src], net.nodes[dst],
                                      choices[src], choices[dst],
                                      deg.get(src, 0) == 1, shape)
            best = min(options, key=lambda t: t[0])  # stable: dt on ties
            if np.isfinite(best[0]):
                kind = best[1]
        if kind == "dt":
            chain = dt.shortest_chain(lo, li, shape)
            if chain is None:
                raise RuntimeError(
                    f"illegal edge {src}->{dst}: no DT path {lo}->{li}")
            conversions[(src, dst)] = chain
        else:
            fusions[(src, dst)] = kind
    return conversions, fusions


def warm_assignment(prev: "SelectionResult",
                    domains: Dict[str, List[Choice]]
                    ) -> Optional[Dict[str, int]]:
    """Map a previous selection onto new PBQP domains (warm start).

    Neighbouring serving buckets share graph topology but have different
    scenarios, so per-node domains may differ; choices are matched by
    primitive name + placement (conv nodes) / input layout + placement
    (op nodes), degrading to a primitive/layout-only match when the
    previous placement no longer exists in the new domain (e.g. warm
    starting a mesh solve from a meshless plan).  Nodes whose previous
    choice no longer exists fall back to index 0 — the resulting
    assignment is still feasible-or-infinite, and an infinite warm cost
    simply disables the bound (see :func:`repro.core.pbqp.solve_warm`).
    Returns None when the topologies do not line up at all.
    """
    def matches(ch: Choice, pc: Choice, with_placement: bool) -> bool:
        if with_placement and ch.placement != pc.placement:
            return False
        if pc.primitive is None:
            return ch.primitive is None and ch.l_in == pc.l_in
        return ch.primitive is not None and \
            ch.primitive.name == pc.primitive.name

    asg: Dict[str, int] = {}
    for nid, dom in domains.items():
        pc = prev.choices.get(nid)
        if pc is None:
            return None
        idx = 0
        for with_placement in (True, False):
            hit = next((i for i, ch in enumerate(dom)
                        if matches(ch, pc, with_placement)), None)
            if hit is not None:
                idx = hit
                break
        asg[nid] = idx
    return asg


def select_pbqp(net: Net, cost: CostModel, *, exact: bool = True,
                families: Optional[Sequence[str]] = None,
                warm_start: Optional["SelectionResult"] = None,
                fuse: bool = False,
                mesh_axes: Optional[Dict[str, int]] = None
                ) -> SelectionResult:
    """The paper's approach: globally optimal primitive selection.

    ``warm_start`` seeds the branch-and-bound incumbent with a previous
    :class:`SelectionResult` for a structurally-identical net (e.g. the
    neighbouring scenario bucket in the serving plan cache) — same optimum,
    typically far fewer branch-and-bound nodes.

    ``fuse=True`` enables transform fusion: edges are priced
    ``min(materialized DT, fused prologue, fused epilogue)`` and the
    result carries per-edge fused realizations that
    :func:`~repro.core.plan.compile_plan` turns into fused calls.  Off
    by default — the materialized system is the paper's.

    ``mesh_axes`` (e.g. ``mesh_shape_dict(mesh)``) additionally solves
    the device-placement axis over the mesh's ``data`` axis; realize the
    result with ``compile_plan(..., mesh=mesh, batch=nb)``.
    """
    pb, domains, dt = _build(net, cost, families=families, fuse=fuse,
                             mesh_axes=mesh_axes)
    if warm_start is not None:
        warm = warm_assignment(warm_start, domains)
        sol = pbqp.solve_warm(pb, warm, exact=exact)
    else:
        sol = pbqp.solve(pb, exact=exact)
    choices = {nid: domains[nid][sol.assignment[nid]] for nid in net.order}
    conversions, fusions = _legalize(net, dt, choices, cost=cost, fuse=fuse)
    return SelectionResult(net, choices, conversions, sol.cost, sol.optimal,
                           "pbqp", sol.stats, fusions)


def select_fixed(net: Net, cost: CostModel,
                 pick: Dict[str, Primitive], strategy: str, *,
                 fuse: bool = False) -> SelectionResult:
    """Pin conv nodes to given primitives; op-node layouts still get the
    optimal legalization (restricted PBQP over layouts only)."""
    pb, domains, dt = _build(net, cost, fixed=pick, fuse=fuse)
    sol = pbqp.solve(pb, exact=True)
    choices = {nid: domains[nid][sol.assignment[nid]] for nid in net.order}
    conversions, fusions = _legalize(net, dt, choices, cost=cost, fuse=fuse)
    return SelectionResult(net, choices, conversions, sol.cost, sol.optimal,
                           strategy, sol.stats, fusions)


def _sum2d_prim() -> Primitive:
    from .primitives import registry
    return next(p for p in registry() if p.name == "sum2d")


def select_sum2d(net: Net, cost: CostModel) -> SelectionResult:
    """The paper's baseline: every conv is the textbook SUM2D routine."""
    p = _sum2d_prim()
    pick = {n.id: p for n in net.conv_nodes()}
    return select_fixed(net, cost, pick, "sum2d")


def select_local_optimal(net: Net, cost: CostModel,
                         canonical: str = "CHW") -> SelectionResult:
    """The paper's 'local optimal': canonical layout everywhere, fastest
    primitive that natively consumes and produces that layout."""
    pick = {}
    for node in net.conv_nodes():
        cands = [p for p in primitives_for(node.scn)
                 if p.l_in == canonical and p.l_out == canonical]
        costs = [(cost.primitive_cost(p, node.scn), p) for p in cands]
        costs = [(c, p) for c, p in costs if np.isfinite(c)]
        if not costs:
            raise ValueError(
                f"select_local_optimal: no {canonical}->{canonical} "
                f"primitive has finite cost for node {node.id!r} "
                f"({node.scn}); the canonical-layout strategy cannot "
                f"cover this scenario under this cost model")
        pick[node.id] = min(costs, key=lambda t: t[0])[1]
    return select_fixed(net, cost, pick, "local_optimal")


def select_family_best(net: Net, cost: CostModel,
                       family: str) -> SelectionResult:
    """The paper's per-family bars: replace SUM2D with the family's
    fastest variant when that variant is faster (node cost only — layout
    transformation costs are NOT considered in the pick, which is
    exactly the trap Section 5.8 demonstrates)."""
    sum2d = _sum2d_prim()
    pick = {}
    for node in net.conv_nodes():
        base_c = cost.primitive_cost(sum2d, node.scn)
        cands = [p for p in primitives_for(node.scn, families=[family])]
        best, best_c = sum2d, base_c
        for p in cands:
            c = cost.primitive_cost(p, node.scn)
            if np.isfinite(c) and c < best_c:
                best, best_c = p, c
        pick[node.id] = best
    return select_fixed(net, cost, pick, f"family_{family}")
