"""PBQP construction, solving, legalization — Section 3 of the paper.

The embedding (built through the unified choice-space bridge of
:mod:`repro.core.choice_space`, which :mod:`repro.core.sharding_select`
shares for its resharding-collective transform kind):

* conv node  -> PBQP node whose domain is the applicable primitives;
  node cost vector = profiled execution time of each primitive.
* op node    -> PBQP node whose domain is the layouts it accepts;
  node cost vector = 0 (the paper's zero-cost dummy nodes).
* edge (u,v) -> cost matrix T[i, j] = APSP cost in the DT graph from
  u's choice-i output layout to v's choice-j input layout, measured on
  the actual tensor shape flowing along the edge (inf if no chain of
  transformations exists).

``legalize`` then bisects every edge whose endpoint layouts differ with
the explicit shortest chain of conversion layers — the cost of which the
optimum already accounts for (the paper's key point: pricing conversions
*after* selection is what makes greedy/local strategies sub-optimal).

**Device placement axis.**  With ``mesh_axes`` (e.g. ``{"data": 2,
"model": 4, "stage": 2}``) the choice space gains a second dimension:
every node's domain crosses primitives (or layouts) with the
structured :class:`~repro.core.choice_space.Placement` domain
{``rep``, ``dp``, ``tp``, ``pp<stage>``}:

* ``rep`` — whole batch replicated on every device.
* ``dp`` — batch sharded over every non-stage axis (``data`` ×
  ``model`` flattened, width D_dp); node costs price the per-device
  shard (``Scenario.n/D_dp``).
* ``tp`` — batch sharded over ``data`` AND conv weights sharded over
  ``model`` (output channels, ``Scenario.m/D_tp``); the node
  additionally pays the intra-node ring all-gather that reassembles
  the channel dimension (op nodes carry ``tp`` as the matching
  data-sharded/model-replicated form at zero extra cost, so runs of
  tp layers wire up for free).
* ``pp<s>`` — the node is resident on pipeline stage ``s``; compute
  is discounted by the GPipe fill-drain overlap factor
  ``(M + S - 1)/(S M)``, edges crossing a stage boundary pay the
  activation send, and backward hops price infinite — the monotone
  stage constraint, encoded so :func:`_legalize` never sees one.

Edges whose endpoints disagree on placement pay the resharding
collective (e.g. ``dp -> rep``: an all-gather of the whole batched
tensor — the distributed analogue of a layout transform); sharded
output nodes pay the final delivery gather.  The solver therefore
trades collective time against replicated compute per layer, exactly
as it trades transform time against primitive speed.
:func:`~repro.core.plan.compile_plan` realizes placements on a mesh:
dp/rep as ``NamedSharding`` constraints, tp as explicit shard_map
collectives over the weight axis, contiguous pp stage runs on
:func:`~repro.runtime.pipeline_parallel.pipeline_apply`
(docs/distributed.md).

docs/solver.md works a small instance through this embedding end to
end; any :class:`~repro.core.costs.CostModel` can price it, including
the measured tables of :class:`repro.calibrate.CalibratedCostModel`
(docs/calibration.md).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import AbstractSet, Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import pbqp
from .choice_space import ChoiceEdge, ChoiceNode, Placement, build_pbqp
from .costs import CostModel
from .graph import Net, Node
from .layouts import DTGraph, transform_feasible
from .primitives import Primitive, primitives_for
from .scenario import Scenario

__all__ = ["SelectionResult", "select_pbqp", "select_fixed",
           "select_sum2d", "select_local_optimal", "select_family_best",
           "Choice", "Placement", "PlacementPricing", "warm_assignment",
           "placements_for", "pp_chain", "pp_microbatches"]


@dataclass(frozen=True)
class Choice:
    """Resolved assignment for one node."""
    primitive: Optional[Primitive]  # None for op nodes
    l_in: str
    l_out: str
    #: device placement: "rep" (replicated over the mesh's data axis)
    #: or "dp" (batch sharded over it).  Always "rep" without a mesh.
    placement: str = "rep"


@dataclass
class SelectionResult:
    net: Net
    choices: Dict[str, Choice]
    #: per-edge conversion chains: (src, dst) -> [layout names] (len>=2)
    conversions: Dict[Tuple[str, str], List[str]]
    predicted_cost: float
    optimal: bool
    strategy: str
    solver_stats: Dict[str, int] = field(default_factory=dict)
    #: per-edge fused realizations: (src, dst) -> "in" | "out".  "in":
    #: the consumer's prologue reads the producer's layout directly;
    #: "out": the producer's epilogue emits the consumer's layout.  An
    #: edge is either here or in ``conversions``, never both.
    fusions: Dict[Tuple[str, str], str] = field(default_factory=dict)


def _conv_domain(node: Node, cost: CostModel,
                 families: Optional[Sequence[str]] = None,
                 require_finite: bool = True,
                 banned: Optional[AbstractSet[str]] = None):
    """Candidate (primitive, cost) entries for one conv node.

    ``banned`` prices the named primitives infinite — the circuit
    breaker's quarantine lever (docs/reliability.md): an infinite entry
    is dropped by the finite filter exactly like an unpriceable one, so
    the solver routes around a quarantined kernel.  If quarantine would
    empty the domain the ban is ignored (a degraded plan beats no plan).
    """
    prims = primitives_for(node.scn, families=families)
    entries = [(p, np.inf if banned and p.name in banned
                else cost.primitive_cost(p, node.scn)) for p in prims]
    if require_finite:
        finite = [(p, c) for (p, c) in entries if np.isfinite(c)]
        if not finite and banned:
            # every survivor is quarantined: lift the ban rather than
            # hand the solver an all-infinite (infeasible) node
            entries = [(p, cost.primitive_cost(p, node.scn))
                       for p in prims]
            finite = [(p, c) for (p, c) in entries if np.isfinite(c)]
        entries = finite or entries
    if not entries:
        raise ValueError(f"no primitive supports {node.scn}")
    return entries


def _fused_options(cost: CostModel, src_node: Node, dst_node: Node,
                   cu: Choice, cv: Choice, single_consumer: bool,
                   shape) -> List[Tuple[float, str]]:
    """Fused realizations available for one (choice, choice) edge pair.

    Returns ``[(per-image cost, kind)]`` with kind ``"in"`` (consumer
    prologue reads ``cu.l_out``) or ``"out"`` (producer epilogue emits
    ``cv.l_in``).  Capability comes from the primitive registry's
    ``fusable_in``/``fusable_out`` declarations; blocked-layout
    feasibility from :func:`~repro.core.layouts.transform_feasible`.
    Epilogue fusion is only offered when the producer has a single
    consumer — a fused-out producer changes the value *every* consumer
    sees, so fan-out edges must materialize (or fuse on the consumer
    side).
    """
    opts: List[Tuple[float, str]] = []
    if cu.l_out == cv.l_in:
        return opts
    pv = cv.primitive
    if pv is not None and cu.l_out in pv.fusable_in and \
            transform_feasible(cu.l_out, pv.l_in, shape):
        opts.append((cost.fused_in_cost(pv, dst_node.scn, cu.l_out), "in"))
    pu = cu.primitive
    if pu is not None and single_consumer and cv.l_in in pu.fusable_out \
            and transform_feasible(pu.l_out, cv.l_in, shape):
        opts.append((cost.fused_out_cost(pu, src_node.scn, cv.l_in), "out"))
    return opts


def _out_degree(net: Net) -> Dict[str, int]:
    deg: Dict[str, int] = {}
    for (src, _) in net.edges():
        deg[src] = deg.get(src, 0) + 1
    return deg


def _net_batch(net: Net) -> int:
    """The net's minibatch (single definition: placement domains and
    dp shard pricing must derive it identically)."""
    return max((n.scn.n for n in net.conv_nodes()), default=1)


def _mesh_dims(mesh_axes: Optional[Dict[str, int]]
               ) -> Tuple[int, int, int]:
    """``(d_data, d_tp, s_pp)`` of a ``mesh_axes`` dict; absent axes
    are 1-wide.  ``data`` shards batches, ``model`` shards weights,
    ``stage`` holds pipeline stages."""
    if not mesh_axes:
        return 1, 1, 1
    return (int(mesh_axes.get("data", 1)),
            int(mesh_axes.get("model", 1)),
            int(mesh_axes.get("stage", 1)))


def pp_microbatches(nb: int, s: int) -> int:
    """Microbatch count for a batch of ``nb`` over ``s`` pipeline
    stages: the largest divisor of ``nb`` not exceeding ``2s`` — enough
    microbatches to keep the fill-drain bubble small, few enough that
    per-microbatch dispatch overhead stays bounded.  Pure function of
    (nb, s): pricing and :func:`~repro.core.plan.compile_plan` must
    derive it identically."""
    target = min(nb, max(2 * s, 1))
    for m in range(target, 0, -1):
        if nb % m == 0:
            return m
    return 1


def pp_chain(net: Net) -> Optional[List[str]]:
    """The net's node ids in order iff it is pipelineable: a single
    linear chain (every node consumes exactly the previous node), a
    single output (the last node), and every node shape-preserving —
    the fixed carry shape :func:`~repro.runtime.pipeline_parallel.
    pipeline_apply` rotates between stages.  Returns None otherwise;
    pp placements are only offered on pipelineable nets."""
    order = net.order
    if not order:
        return None
    in_shape = net.nodes[order[0]].out_shape
    prev: Optional[str] = None
    for i, nid in enumerate(order):
        node = net.nodes[nid]
        if i == 0:
            if node.kind != "input":
                return None
        elif list(node.inputs) != [prev]:
            return None
        if tuple(node.out_shape) != tuple(in_shape):
            return None
        prev = nid
    if net.outputs() != [order[-1]]:
        return None
    return list(order)


def placements_for(net: Net,
                   mesh_axes: Optional[Dict[str, int]]) -> List[str]:
    """Generic placement domain for a net on a mesh.  Sharded kinds
    first and ``rep`` last, so cost *ties* (zero-cost op nodes, free
    edges) resolve to the sharded choice: replicated execution at equal
    priced time still burns D× the compute.  Kinds are offered only
    when feasible: ``dp`` needs the flattened data×model width to
    divide the batch, ``tp`` needs a >1 ``model`` axis and a
    data-divisible batch (per-primitive weight divisibility is filtered
    per node), ``pp`` needs a >1 ``stage`` axis and a pipelineable net
    (:func:`pp_chain`)."""
    d_data, d_tp, s_pp = _mesh_dims(mesh_axes)
    nb = _net_batch(net)
    d_dp = d_data * d_tp
    out: List[str] = []
    if d_dp > 1 and nb >= d_dp and nb % d_dp == 0:
        out.append(Placement("dp"))
    if d_tp > 1 and nb >= d_data and nb % d_data == 0:
        out.append(Placement("tp"))
    if s_pp > 1 and pp_chain(net) is not None:
        out.extend(Placement("pp", s) for s in range(s_pp))
    out.append(Placement("rep"))
    return out


class PlacementPricing:
    """Placement-axis pricing, stated once.

    Both the PBQP builder (:func:`_build`) and the observability
    itemizer (:func:`repro.obs.drift.plan_predictions`) derive every
    placement cost term from this class, so the drift detector's
    predicted ledger is exactly the objective the solver minimized.

    Terms:

    * ``conv_cost`` — per-device compute of a primitive under a
      placement, plus the placement's intra-node extras (tp channel
      all-gather, output delivery gather, pp balance prior).
    * ``transform_images`` — how many images an edge's layout
      transform actually touches (the sharded side of a mixed edge;
      the overlap-discounted batch inside a pipeline).
    * ``edge_collective`` — the resharding collective between unlike
      placements, the pp stage-boundary send, and the infinite
      entries that encode pipeline monotonicity.
    """

    #: stage-balance prior weight (seconds per stage of imbalance).
    #: Monotone chains make every stage split cost-identical under the
    #: additive objective, so this epsilon tie-breaks toward the
    #: balanced split the fill-drain discount assumes.  It must exceed
    #: the branch-and-bound prune tolerance (1e-9 relative) to survive
    #: the solve, and stays ~1000x below real node costs (~µs) so it
    #: never decides anything but ties.
    PP_EPS = 1e-8

    def __init__(self, net: Net, cost: CostModel,
                 mesh_axes: Optional[Dict[str, int]]):
        self.net = net
        self.cost = cost
        self.nb = _net_batch(net)
        self.d_data, self.d_tp, self.s_pp = _mesh_dims(mesh_axes)
        self.d_dp = self.d_data * self.d_tp
        self.outputs = set(net.outputs())
        self.base = [Placement.parse(p)
                     for p in placements_for(net, mesh_axes)]
        self.n_micro = pp_microbatches(self.nb, self.s_pp)
        self.ppf = ((self.n_micro + self.s_pp - 1)
                    / (self.s_pp * self.n_micro)) if self.s_pp > 1 else 1.0
        self.pos = {nid: i for i, nid in enumerate(net.order)}

    # ---------------- node domains ----------------
    def node_placements(self, node: Node) -> List[Placement]:
        """Per-node filter of the generic domain: the input node spans
        from stage 0, output nodes to stage S-1 (so a pipelined plan
        covers the whole mesh), and inputs never carry tp (data-sharded
        entry is dp's job; a reshard edge prices the difference)."""
        out = []
        for pl in self.base:
            if pl.kind == "pp":
                if node.kind == "input" and pl.stage != 0:
                    continue
                if node.id in self.outputs and pl.stage != self.s_pp - 1:
                    continue
            if pl.kind == "tp" and node.kind == "input":
                continue
            out.append(pl)
        return out

    def tp_feasible(self, node: Node, prim: Primitive) -> bool:
        """tp shards ``prim``'s output channels D_tp ways: the shard
        scenario must divide evenly, stay supported, and be
        CHW-convertible on both sides of the channel all-gather."""
        scn = node.scn
        if self.d_tp <= 1 or scn.m % self.d_tp != 0:
            return False
        scn_tp = scn.with_(m=scn.m // self.d_tp)
        if not prim.supports(scn_tp):
            return False
        return transform_feasible(prim.l_out, "CHW",
                                  scn_tp.out_shape_chw) and \
            transform_feasible("CHW", prim.l_out, scn.out_shape_chw)

    # ---------------- node cost terms ----------------
    def conv_cost(self, node: Node, prim: Primitive, pl: Placement,
                  c_rep: float) -> Tuple[float, float]:
        """``(compute, extra)`` seconds for one conv choice: per-device
        compute under the placement, and the placement's collective /
        prior terms (tp channel gather, delivery, pp balance)."""
        k = pl.kind
        if k == "dp":
            compute = self.cost.primitive_cost(
                prim, node.scn.with_(n=self.nb // self.d_dp))
        elif k == "tp":
            scn_tp = node.scn.with_(n=self.nb // self.d_data,
                                    m=node.scn.m // self.d_tp)
            compute = self.cost.primitive_cost(prim, scn_tp)
        elif k == "pp":
            compute = c_rep * self.ppf
        else:
            compute = c_rep
        return compute, self.node_extra(node, pl)

    def node_extra(self, node: Node, pl: Placement) -> float:
        """Non-compute node terms: the tp channel all-gather, the
        output delivery gather, and the pp balance prior."""
        extra = self.balance_eps(node, pl)
        img = 4.0 * float(np.prod(node.out_shape))
        if pl.kind == "tp" and node.kind == "conv":
            # reassemble the channel shards within each data group
            extra += self.cost.collective_cost(
                "all_gather", img * (self.nb // self.d_data), self.d_tp)
        extra += self.delivery(node, pl)
        return extra

    def delivery(self, node: Node, pl: Placement) -> float:
        """Final all-gather a sharded *output* node pays so the caller
        sees the full batch (rep outputs are already whole)."""
        if node.id not in self.outputs:
            return 0.0
        nbytes = 4.0 * float(np.prod(node.out_shape)) * self.nb
        if pl.kind == "dp":
            return self.cost.collective_cost("all_gather", nbytes,
                                             self.d_dp)
        if pl.kind == "tp":
            return self.cost.collective_cost("all_gather", nbytes,
                                             self.d_data)
        if pl.kind == "pp":
            # pipeline_apply's final psum broadcast of the last stage
            return self.cost.collective_cost("all_gather", nbytes,
                                             self.s_pp)
        return 0.0

    def balance_eps(self, node: Node, pl: Placement) -> float:
        if pl.kind != "pp":
            return 0.0
        n = max(len(self.net.order), 1)
        ideal = min(self.s_pp - 1, self.pos[node.id] * self.s_pp // n)
        return self.PP_EPS * abs(pl.stage - ideal)

    # ---------------- edge terms ----------------
    def rows(self, pl: Placement) -> int:
        """Images materialized per device under a placement."""
        if pl.kind == "dp":
            return self.nb // self.d_dp
        if pl.kind == "tp":
            return self.nb // self.d_data
        return self.nb

    def transform_images(self, pu: Placement, pv: Placement) -> float:
        """Images an edge's layout transform touches: the sharded side
        of a mixed edge (GSPMD transforms before gathering / after
        slicing), the overlap-discounted whole batch inside a
        pipeline."""
        if pu.kind == "pp" or pv.kind == "pp":
            return self.nb * self.ppf
        return float(min(self.rows(pu), self.rows(pv)))

    def edge_collective(self, pu: Placement, pv: Placement,
                        img_bytes: float) -> float:
        """Resharding / stage-boundary collective seconds for one edge.
        ``inf`` encodes the illegal transitions: entering or leaving
        the pipeline mid-net, and backward stage hops (the monotone
        stage constraint)."""
        ku, kv = pu.kind, pv.kind
        if (ku == "pp") != (kv == "pp"):
            return float("inf")
        if ku == "pp":
            if pv.stage < pu.stage:
                return float("inf")
            if pv.stage == pu.stage:
                return 0.0
            # each boundary ships the whole activation batch once
            # (as n_micro microbatch sends; linear in bytes)
            return (pv.stage - pu.stage) * self.cost.collective_cost(
                "send", img_bytes * self.nb, 2)
        if ku == kv:
            return 0.0
        if ku == "dp" and kv == "rep":
            return self.cost.collective_cost(
                "all_gather", img_bytes * self.nb, self.d_dp)
        if ku == "dp" and kv == "tp":
            # gather the model-axis batch shards within each data group
            return self.cost.collective_cost(
                "all_gather", img_bytes * (self.nb // self.d_data),
                self.d_tp)
        if ku == "tp" and kv == "rep":
            return self.cost.collective_cost(
                "all_gather", img_bytes * self.nb, self.d_data)
        # rep->dp, rep->tp, tp->dp: a local slice, free
        return 0.0


def _build(net: Net, cost: CostModel, *,
           fixed: Optional[Dict[str, Primitive]] = None,
           families: Optional[Sequence[str]] = None,
           fuse: bool = False,
           mesh_axes: Optional[Dict[str, int]] = None,
           banned: Optional[AbstractSet[str]] = None):
    """Build the PBQP instance; returns (problem, domains).

    ``fixed`` pins given conv nodes to a single primitive (domain size 1)
    — used by the baseline strategies, which still get optimal *layout*
    legalization through the op nodes.

    ``fuse`` prices every edge entry as ``min(materialized DT chain,
    fused prologue, fused epilogue)`` — the solver then sees transforms
    at their fused price and can pick primitive pairs a materialized-only
    model would reject (the tentpole of the fusion subsystem).

    ``mesh_axes`` (e.g. ``{"data": 2, "model": 4, "stage": 2}``)
    enables the device-placement axis: domains cross with the
    feasibility-filtered {rep, dp, tp, pp<stage>} domain and every
    placement cost term comes from :class:`PlacementPricing` — the same
    object :func:`repro.obs.drift.plan_predictions` itemizes from, so
    the observed ledger always matches the solved objective.  The whole
    construction goes through the shared
    :func:`repro.core.choice_space.build_pbqp` bridge — the same one
    :mod:`repro.core.sharding_select` builds its collective-priced
    instances with.
    """
    dt = cost.dt_graph()
    pm = PlacementPricing(net, cost, mesh_axes)

    nodes: List[ChoiceNode] = []
    for nid in net.order:
        node = net.nodes[nid]
        pls = pm.node_placements(node)
        if node.kind == "input":
            choices = [Choice(None, "CHW", "CHW", pl) for pl in pls]
            costs = [pm.node_extra(node, pl) for pl in pls]
        elif node.kind == "conv":
            if fixed and nid in fixed:
                p = fixed[nid]
                c = cost.primitive_cost(p, node.scn)
                entries = [(p, c if np.isfinite(c) else 1e6)]
            else:
                entries = _conv_domain(node, cost, families, banned=banned)
            choices, costs = [], []
            for p, c_rep in entries:
                for pl in pls:
                    if pl.kind == "tp" and not pm.tp_feasible(node, p):
                        continue
                    compute, extra = pm.conv_cost(node, p, pl, c_rep)
                    choices.append(Choice(p, p.l_in, p.l_out, pl))
                    costs.append(compute + extra)
        else:  # op
            choices = [Choice(None, l, l, pl) for l in node.op.layouts
                       for pl in pls]
            costs = [pm.node_extra(node, Placement.parse(ch.placement))
                     for ch in choices]
        nodes.append(ChoiceNode(nid, choices, costs))

    # Transform costs are priced per image by the DT graph and scale
    # with the images each device actually transforms
    # (PlacementPricing.transform_images); placement-mismatched edges
    # additionally pay the resharding collective — the distributed
    # "layout transformation" — and pp stage boundaries pay the
    # activation send through the CHW boundary wire.
    deg = _out_degree(net)
    edges: List[ChoiceEdge] = []
    for (src, dst) in net.edges():
        shape = net.nodes[src].out_shape
        dtcosts, idx = dt.cost_matrix(shape)
        sn, dn = net.nodes[src], net.nodes[dst]
        single = deg.get(src, 0) == 1
        img_bytes = 4 * float(np.prod(shape))

        def transition(cu: Choice, cv: Choice, *, dtcosts=dtcosts,
                       idx=idx, sn=sn, dn=dn, single=single,
                       shape=shape, img_bytes=img_bytes) -> float:
            pu = Placement.parse(cu.placement)
            pv = Placement.parse(cv.placement)
            coll = pm.edge_collective(pu, pv, img_bytes)
            if not np.isfinite(coll):
                return coll
            if pu.kind == "pp" and pv.kind == "pp" and \
                    pu.stage != pv.stage:
                # stage boundaries wire CHW activations between
                # devices: price the via-CHW conversion route
                per_img = dtcosts[idx[cu.l_out], idx["CHW"]] + \
                    dtcosts[idx["CHW"], idx[cv.l_in]]
            else:
                per_img = dtcosts[idx[cu.l_out], idx[cv.l_in]]
                if fuse and cu.placement == cv.placement \
                        and pu.kind != "tp":
                    for c, _ in _fused_options(cost, sn, dn, cu, cv,
                                               single, shape):
                        if c < per_img:
                            per_img = c
            return per_img * pm.transform_images(pu, pv) + coll

        edges.append(ChoiceEdge(src, dst, transition))

    pb, domains = build_pbqp(nodes, edges)
    return pb, domains, dt


def _legalize(net: Net, dt: DTGraph, choices: Dict[str, Choice], *,
              cost: Optional[CostModel] = None, fuse: bool = False
              ) -> Tuple[Dict[Tuple[str, str], List[str]],
                         Dict[Tuple[str, str], str]]:
    """Realize every mismatched edge as either a materialized conversion
    chain or a fused prologue/epilogue.

    The realization replays exactly the pricing :func:`_build` fed the
    solver — ``min(materialized, fused options)``, materialized
    preferred on ties, fused options only offered when both endpoints
    share a device placement and neither is tp (exactly as the edge
    matrices were priced; shard-level blocked layouts make fused
    feasibility diverge from the full-shape check, so tp edges always
    materialize) — so the executed plan's transform cost is the one the
    optimum accounted for.  Edges that cross a pipeline stage boundary
    wire CHW activations between devices: their chain is the glued
    shortest path through CHW (recorded even when the endpoint layouts
    agree), which the pipeline executor splits at CHW into the
    producer stage's exit hops and the consumer stage's entry hops.
    With ``fuse=False`` (the paper's system), every mismatched edge
    materializes.
    """
    conversions: Dict[Tuple[str, str], List[str]] = {}
    fusions: Dict[Tuple[str, str], str] = {}
    deg = _out_degree(net)
    for (src, dst) in net.edges():
        cu, cv = choices[src], choices[dst]
        pu = Placement.parse(cu.placement)
        pv = Placement.parse(cv.placement)
        lo = cu.l_out
        li = cv.l_in
        if pu.kind == "pp" and pv.kind == "pp" and pu.stage != pv.stage:
            shape = net.nodes[src].out_shape
            p1 = dt.shortest_chain(lo, "CHW", shape) \
                if lo != "CHW" else ["CHW"]
            p2 = dt.shortest_chain("CHW", li, shape) \
                if li != "CHW" else ["CHW"]
            if p1 is None or p2 is None:
                raise RuntimeError(
                    f"illegal stage boundary {src}->{dst}: no DT path "
                    f"through CHW ({lo}->{li})")
            chain = list(p1) + list(p2)[1:]
            if len(chain) >= 2:
                conversions[(src, dst)] = chain
            continue
        if lo == li:
            continue
        shape = net.nodes[src].out_shape
        kind = "dt"
        if fuse and cost is not None and \
                cu.placement == cv.placement and pu.kind != "tp":
            costs, idx = dt.cost_matrix(shape)
            options = [(costs[idx[lo], idx[li]], "dt")]
            options += _fused_options(cost, net.nodes[src], net.nodes[dst],
                                      choices[src], choices[dst],
                                      deg.get(src, 0) == 1, shape)
            best = min(options, key=lambda t: t[0])  # stable: dt on ties
            if np.isfinite(best[0]):
                kind = best[1]
        if kind == "dt":
            chain = dt.shortest_chain(lo, li, shape)
            if chain is None:
                raise RuntimeError(
                    f"illegal edge {src}->{dst}: no DT path {lo}->{li}")
            conversions[(src, dst)] = chain
        else:
            fusions[(src, dst)] = kind
    return conversions, fusions


def warm_assignment(prev: "SelectionResult",
                    domains: Dict[str, List[Choice]]
                    ) -> Optional[Dict[str, int]]:
    """Map a previous selection onto new PBQP domains (warm start).

    Neighbouring serving buckets share graph topology but have different
    scenarios, so per-node domains may differ; choices are matched by
    primitive name + placement (conv nodes) / input layout + placement
    (op nodes), degrading to a primitive/layout-only match when the
    previous placement no longer exists in the new domain (e.g. warm
    starting a mesh solve from a meshless plan).  Nodes whose previous
    choice no longer exists fall back to index 0 — the resulting
    assignment is still feasible-or-infinite, and an infinite warm cost
    simply disables the bound (see :func:`repro.core.pbqp.solve_warm`).
    Returns None when the topologies do not line up at all.
    """
    def matches(ch: Choice, pc: Choice, with_placement: bool) -> bool:
        if with_placement and ch.placement != pc.placement:
            return False
        if pc.primitive is None:
            return ch.primitive is None and ch.l_in == pc.l_in
        return ch.primitive is not None and \
            ch.primitive.name == pc.primitive.name

    asg: Dict[str, int] = {}
    for nid, dom in domains.items():
        pc = prev.choices.get(nid)
        if pc is None:
            return None
        idx = 0
        for with_placement in (True, False):
            hit = next((i for i, ch in enumerate(dom)
                        if matches(ch, pc, with_placement)), None)
            if hit is not None:
                idx = hit
                break
        asg[nid] = idx
    return asg


def select_pbqp(net: Net, cost: CostModel, *, exact: bool = True,
                families: Optional[Sequence[str]] = None,
                warm_start: Optional["SelectionResult"] = None,
                fuse: bool = False,
                mesh_axes: Optional[Dict[str, int]] = None,
                banned: Optional[AbstractSet[str]] = None,
                deadline_s: Optional[float] = None,
                bb_budget: int = 200_000) -> SelectionResult:
    """The paper's approach: globally optimal primitive selection.

    ``warm_start`` seeds the branch-and-bound incumbent with a previous
    :class:`SelectionResult` for a structurally-identical net (e.g. the
    neighbouring scenario bucket in the serving plan cache) — same optimum,
    typically far fewer branch-and-bound nodes.

    ``fuse=True`` enables transform fusion: edges are priced
    ``min(materialized DT, fused prologue, fused epilogue)`` and the
    result carries per-edge fused realizations that
    :func:`~repro.core.plan.compile_plan` turns into fused calls.  Off
    by default — the materialized system is the paper's.

    ``mesh_axes`` (e.g. ``mesh_shape_dict(mesh)``) additionally solves
    the device-placement axis over the mesh's ``data`` axis; realize the
    result with ``compile_plan(..., mesh=mesh, batch=nb)``.

    ``banned`` prices the named primitives infinite (circuit-breaker
    quarantine — see :func:`_conv_domain`); ``deadline_s`` turns the
    solve *anytime* — past the wall-clock allowance branch-and-bound
    stops and the RN heuristic completes the assignment
    (``solver_stats["DEADLINE"]`` records the degradation); ``bb_budget``
    caps branch-and-bound node expansions the same way.
    """
    pb, domains, dt = _build(net, cost, families=families, fuse=fuse,
                             mesh_axes=mesh_axes, banned=banned)
    if warm_start is not None:
        warm = warm_assignment(warm_start, domains)
        sol = pbqp.solve_warm(pb, warm, exact=exact, bb_budget=bb_budget,
                              deadline_s=deadline_s)
    else:
        sol = pbqp.solve(pb, exact=exact, bb_budget=bb_budget,
                         deadline_s=deadline_s)
    choices = {nid: domains[nid][sol.assignment[nid]] for nid in net.order}
    conversions, fusions = _legalize(net, dt, choices, cost=cost, fuse=fuse)
    return SelectionResult(net, choices, conversions, sol.cost, sol.optimal,
                           "pbqp", sol.stats, fusions)


def select_fixed(net: Net, cost: CostModel,
                 pick: Dict[str, Primitive], strategy: str, *,
                 fuse: bool = False) -> SelectionResult:
    """Pin conv nodes to given primitives; op-node layouts still get the
    optimal legalization (restricted PBQP over layouts only)."""
    pb, domains, dt = _build(net, cost, fixed=pick, fuse=fuse)
    sol = pbqp.solve(pb, exact=True)
    choices = {nid: domains[nid][sol.assignment[nid]] for nid in net.order}
    conversions, fusions = _legalize(net, dt, choices, cost=cost, fuse=fuse)
    return SelectionResult(net, choices, conversions, sol.cost, sol.optimal,
                           strategy, sol.stats, fusions)


def _sum2d_prim() -> Primitive:
    from .primitives import registry
    return next(p for p in registry() if p.name == "sum2d")


def select_sum2d(net: Net, cost: CostModel) -> SelectionResult:
    """The paper's baseline: every conv is the textbook SUM2D routine."""
    p = _sum2d_prim()
    pick = {n.id: p for n in net.conv_nodes()}
    return select_fixed(net, cost, pick, "sum2d")


def select_local_optimal(net: Net, cost: CostModel,
                         canonical: str = "CHW",
                         banned: Optional[AbstractSet[str]] = None
                         ) -> SelectionResult:
    """The paper's 'local optimal': canonical layout everywhere, fastest
    primitive that natively consumes and produces that layout.

    ``banned`` excludes quarantined primitives from the per-node pick —
    the greedy rung of the serving fallback ladder must not re-select
    the kernel whose crash demoted the request to it."""
    pick = {}
    for node in net.conv_nodes():
        cands = [p for p in primitives_for(node.scn)
                 if p.l_in == canonical and p.l_out == canonical
                 and not (banned and p.name in banned)]
        costs = [(cost.primitive_cost(p, node.scn), p) for p in cands]
        costs = [(c, p) for c, p in costs if np.isfinite(c)]
        if not costs:
            raise ValueError(
                f"select_local_optimal: no {canonical}->{canonical} "
                f"primitive has finite cost for node {node.id!r} "
                f"({node.scn}); the canonical-layout strategy cannot "
                f"cover this scenario under this cost model")
        pick[node.id] = min(costs, key=lambda t: t[0])[1]
    return select_fixed(net, cost, pick, "local_optimal")


def select_family_best(net: Net, cost: CostModel,
                       family: str) -> SelectionResult:
    """The paper's per-family bars: replace SUM2D with the family's
    fastest variant when that variant is faster (node cost only — layout
    transformation costs are NOT considered in the pick, which is
    exactly the trap Section 5.8 demonstrates)."""
    sum2d = _sum2d_prim()
    pick = {}
    for node in net.conv_nodes():
        base_c = cost.primitive_cost(sum2d, node.scn)
        cands = [p for p in primitives_for(node.scn, families=[family])]
        best, best_c = sum2d, base_c
        for p in cands:
            c = cost.primitive_cost(p, node.scn)
            if np.isfinite(c) and c < best_c:
                best, best_c = p, c
        pick[node.id] = best
    return select_fixed(net, cost, pick, f"family_{family}")
