"""PBQP construction, solving, legalization — Section 3 of the paper.

The embedding:

* conv node  -> PBQP node whose domain is the applicable primitives;
  node cost vector = profiled execution time of each primitive.
* op node    -> PBQP node whose domain is the layouts it accepts;
  node cost vector = 0 (the paper's zero-cost dummy nodes).
* edge (u,v) -> cost matrix T[i, j] = APSP cost in the DT graph from
  u's choice-i output layout to v's choice-j input layout, measured on
  the actual tensor shape flowing along the edge (inf if no chain of
  transformations exists).

``legalize`` then bisects every edge whose endpoint layouts differ with
the explicit shortest chain of conversion layers — the cost of which the
optimum already accounts for (the paper's key point: pricing conversions
*after* selection is what makes greedy/local strategies sub-optimal).

docs/solver.md works a small instance through this embedding end to
end; any :class:`~repro.core.costs.CostModel` can price it, including
the measured tables of :class:`repro.calibrate.CalibratedCostModel`
(docs/calibration.md).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import pbqp
from .costs import CostModel
from .graph import Net, Node
from .layouts import DTGraph, transform_feasible
from .primitives import Primitive, primitives_for
from .scenario import Scenario

__all__ = ["SelectionResult", "select_pbqp", "select_fixed",
           "select_sum2d", "select_local_optimal", "select_family_best",
           "Choice", "warm_assignment"]


@dataclass(frozen=True)
class Choice:
    """Resolved assignment for one node."""
    primitive: Optional[Primitive]  # None for op nodes
    l_in: str
    l_out: str


@dataclass
class SelectionResult:
    net: Net
    choices: Dict[str, Choice]
    #: per-edge conversion chains: (src, dst) -> [layout names] (len>=2)
    conversions: Dict[Tuple[str, str], List[str]]
    predicted_cost: float
    optimal: bool
    strategy: str
    solver_stats: Dict[str, int] = field(default_factory=dict)
    #: per-edge fused realizations: (src, dst) -> "in" | "out".  "in":
    #: the consumer's prologue reads the producer's layout directly;
    #: "out": the producer's epilogue emits the consumer's layout.  An
    #: edge is either here or in ``conversions``, never both.
    fusions: Dict[Tuple[str, str], str] = field(default_factory=dict)


def _conv_domain(node: Node, cost: CostModel,
                 families: Optional[Sequence[str]] = None,
                 require_finite: bool = True):
    prims = primitives_for(node.scn, families=families)
    entries = [(p, cost.primitive_cost(p, node.scn)) for p in prims]
    if require_finite:
        finite = [(p, c) for (p, c) in entries if np.isfinite(c)]
        entries = finite or entries
    if not entries:
        raise ValueError(f"no primitive supports {node.scn}")
    return entries


def _edge_matrix(dt: DTGraph, shape, out_layouts: Sequence[str],
                 in_layouts: Sequence[str]) -> np.ndarray:
    costs, idx = dt.cost_matrix(shape)
    M = np.zeros((len(out_layouts), len(in_layouts)))
    for i, lo in enumerate(out_layouts):
        for j, li in enumerate(in_layouts):
            M[i, j] = costs[idx[lo], idx[li]]
    return M


def _fused_options(cost: CostModel, src_node: Node, dst_node: Node,
                   cu: Choice, cv: Choice, single_consumer: bool,
                   shape) -> List[Tuple[float, str]]:
    """Fused realizations available for one (choice, choice) edge pair.

    Returns ``[(per-image cost, kind)]`` with kind ``"in"`` (consumer
    prologue reads ``cu.l_out``) or ``"out"`` (producer epilogue emits
    ``cv.l_in``).  Capability comes from the primitive registry's
    ``fusable_in``/``fusable_out`` declarations; blocked-layout
    feasibility from :func:`~repro.core.layouts.transform_feasible`.
    Epilogue fusion is only offered when the producer has a single
    consumer — a fused-out producer changes the value *every* consumer
    sees, so fan-out edges must materialize (or fuse on the consumer
    side).
    """
    opts: List[Tuple[float, str]] = []
    if cu.l_out == cv.l_in:
        return opts
    pv = cv.primitive
    if pv is not None and cu.l_out in pv.fusable_in and \
            transform_feasible(cu.l_out, pv.l_in, shape):
        opts.append((cost.fused_in_cost(pv, dst_node.scn, cu.l_out), "in"))
    pu = cu.primitive
    if pu is not None and single_consumer and cv.l_in in pu.fusable_out \
            and transform_feasible(pu.l_out, cv.l_in, shape):
        opts.append((cost.fused_out_cost(pu, src_node.scn, cv.l_in), "out"))
    return opts


def _out_degree(net: Net) -> Dict[str, int]:
    deg: Dict[str, int] = {}
    for (src, _) in net.edges():
        deg[src] = deg.get(src, 0) + 1
    return deg


def _build(net: Net, cost: CostModel, *,
           fixed: Optional[Dict[str, Primitive]] = None,
           families: Optional[Sequence[str]] = None,
           fuse: bool = False):
    """Build the PBQP instance; returns (problem, domains).

    ``fixed`` pins given conv nodes to a single primitive (domain size 1)
    — used by the baseline strategies, which still get optimal *layout*
    legalization through the op nodes.

    ``fuse`` prices every edge entry as ``min(materialized DT chain,
    fused prologue, fused epilogue)`` — the solver then sees transforms
    at their fused price and can pick primitive pairs a materialized-only
    model would reject (the tentpole of the fusion subsystem).
    """
    dt = cost.dt_graph()
    pb = pbqp.PBQP()
    domains: Dict[str, List[Choice]] = {}

    for nid in net.order:
        node = net.nodes[nid]
        if node.kind == "input":
            domains[nid] = [Choice(None, "CHW", "CHW")]
            pb.add_node(nid, [0.0])
        elif node.kind == "conv":
            if fixed and nid in fixed:
                p = fixed[nid]
                c = cost.primitive_cost(p, node.scn)
                domains[nid] = [Choice(p, p.l_in, p.l_out)]
                pb.add_node(nid, [c if np.isfinite(c) else 1e6])
            else:
                entries = _conv_domain(node, cost, families)
                domains[nid] = [Choice(p, p.l_in, p.l_out)
                                for p, _ in entries]
                pb.add_node(nid, [c for _, c in entries])
        else:  # op
            lays = list(node.op.layouts)
            domains[nid] = [Choice(None, l, l) for l in lays]
            pb.add_node(nid, [0.0] * len(lays))

    # Transform costs are priced per image by the DT graph; a batched
    # net moves nb times the activation bytes along every edge, so the
    # edge matrices scale with the net's minibatch (node costs already
    # price the whole batched invocation via Scenario.n).
    nb = max((n.scn.n for n in net.conv_nodes()), default=1)
    deg = _out_degree(net)
    for (src, dst) in net.edges():
        shape = net.nodes[src].out_shape
        M = _edge_matrix(dt, shape,
                         [c.l_out for c in domains[src]],
                         [c.l_in for c in domains[dst]])
        if fuse:
            sn, dn = net.nodes[src], net.nodes[dst]
            single = deg.get(src, 0) == 1
            for i, cu in enumerate(domains[src]):
                for j, cv in enumerate(domains[dst]):
                    for c, _ in _fused_options(cost, sn, dn, cu, cv,
                                               single, shape):
                        if c < M[i, j]:
                            M[i, j] = c
        pb.add_edge(src, dst, M * nb if nb > 1 else M)

    return pb, domains, dt


def _legalize(net: Net, dt: DTGraph, choices: Dict[str, Choice], *,
              cost: Optional[CostModel] = None, fuse: bool = False
              ) -> Tuple[Dict[Tuple[str, str], List[str]],
                         Dict[Tuple[str, str], str]]:
    """Realize every mismatched edge as either a materialized conversion
    chain or a fused prologue/epilogue.

    The realization replays exactly the pricing :func:`_build` fed the
    solver — ``min(materialized, fused options)``, materialized
    preferred on ties — so the executed plan's transform cost is the one
    the optimum accounted for.  With ``fuse=False`` (the paper's
    system), every mismatched edge materializes.
    """
    conversions: Dict[Tuple[str, str], List[str]] = {}
    fusions: Dict[Tuple[str, str], str] = {}
    deg = _out_degree(net)
    for (src, dst) in net.edges():
        lo = choices[src].l_out
        li = choices[dst].l_in
        if lo == li:
            continue
        shape = net.nodes[src].out_shape
        kind = "dt"
        if fuse and cost is not None:
            costs, idx = dt.cost_matrix(shape)
            options = [(costs[idx[lo], idx[li]], "dt")]
            options += _fused_options(cost, net.nodes[src], net.nodes[dst],
                                      choices[src], choices[dst],
                                      deg.get(src, 0) == 1, shape)
            best = min(options, key=lambda t: t[0])  # stable: dt on ties
            if np.isfinite(best[0]):
                kind = best[1]
        if kind == "dt":
            chain = dt.shortest_chain(lo, li, shape)
            if chain is None:
                raise RuntimeError(
                    f"illegal edge {src}->{dst}: no DT path {lo}->{li}")
            conversions[(src, dst)] = chain
        else:
            fusions[(src, dst)] = kind
    return conversions, fusions


def warm_assignment(prev: "SelectionResult",
                    domains: Dict[str, List[Choice]]
                    ) -> Optional[Dict[str, int]]:
    """Map a previous selection onto new PBQP domains (warm start).

    Neighbouring serving buckets share graph topology but have different
    scenarios, so per-node domains may differ; choices are matched by
    primitive name (conv nodes) / input layout (op nodes).  Nodes whose
    previous choice no longer exists fall back to index 0 — the resulting
    assignment is still feasible-or-infinite, and an infinite warm cost
    simply disables the bound (see :func:`repro.core.pbqp.solve_warm`).
    Returns None when the topologies do not line up at all.
    """
    asg: Dict[str, int] = {}
    for nid, dom in domains.items():
        pc = prev.choices.get(nid)
        if pc is None:
            return None
        idx = 0
        for i, ch in enumerate(dom):
            if pc.primitive is None:
                if ch.primitive is None and ch.l_in == pc.l_in:
                    idx = i
                    break
            elif ch.primitive is not None and \
                    ch.primitive.name == pc.primitive.name:
                idx = i
                break
        asg[nid] = idx
    return asg


def select_pbqp(net: Net, cost: CostModel, *, exact: bool = True,
                families: Optional[Sequence[str]] = None,
                warm_start: Optional["SelectionResult"] = None,
                fuse: bool = False) -> SelectionResult:
    """The paper's approach: globally optimal primitive selection.

    ``warm_start`` seeds the branch-and-bound incumbent with a previous
    :class:`SelectionResult` for a structurally-identical net (e.g. the
    neighbouring scenario bucket in the serving plan cache) — same optimum,
    typically far fewer branch-and-bound nodes.

    ``fuse=True`` enables transform fusion: edges are priced
    ``min(materialized DT, fused prologue, fused epilogue)`` and the
    result carries per-edge fused realizations that
    :func:`~repro.core.plan.compile_plan` turns into fused calls.  Off
    by default — the materialized system is the paper's.
    """
    pb, domains, dt = _build(net, cost, families=families, fuse=fuse)
    if warm_start is not None:
        warm = warm_assignment(warm_start, domains)
        sol = pbqp.solve_warm(pb, warm, exact=exact)
    else:
        sol = pbqp.solve(pb, exact=exact)
    choices = {nid: domains[nid][sol.assignment[nid]] for nid in net.order}
    conversions, fusions = _legalize(net, dt, choices, cost=cost, fuse=fuse)
    return SelectionResult(net, choices, conversions, sol.cost, sol.optimal,
                           "pbqp", sol.stats, fusions)


def select_fixed(net: Net, cost: CostModel,
                 pick: Dict[str, Primitive], strategy: str, *,
                 fuse: bool = False) -> SelectionResult:
    """Pin conv nodes to given primitives; op-node layouts still get the
    optimal legalization (restricted PBQP over layouts only)."""
    pb, domains, dt = _build(net, cost, fixed=pick, fuse=fuse)
    sol = pbqp.solve(pb, exact=True)
    choices = {nid: domains[nid][sol.assignment[nid]] for nid in net.order}
    conversions, fusions = _legalize(net, dt, choices, cost=cost, fuse=fuse)
    return SelectionResult(net, choices, conversions, sol.cost, sol.optimal,
                           strategy, sol.stats, fusions)


def _sum2d_prim() -> Primitive:
    from .primitives import registry
    return next(p for p in registry() if p.name == "sum2d")


def select_sum2d(net: Net, cost: CostModel) -> SelectionResult:
    """The paper's baseline: every conv is the textbook SUM2D routine."""
    p = _sum2d_prim()
    pick = {n.id: p for n in net.conv_nodes()}
    return select_fixed(net, cost, pick, "sum2d")


def select_local_optimal(net: Net, cost: CostModel,
                         canonical: str = "CHW") -> SelectionResult:
    """The paper's 'local optimal': canonical layout everywhere, fastest
    primitive that natively consumes and produces that layout."""
    pick = {}
    for node in net.conv_nodes():
        cands = [p for p in primitives_for(node.scn)
                 if p.l_in == canonical and p.l_out == canonical]
        costs = [(cost.primitive_cost(p, node.scn), p) for p in cands]
        costs = [(c, p) for c, p in costs if np.isfinite(c)]
        if not costs:
            raise ValueError(
                f"select_local_optimal: no {canonical}->{canonical} "
                f"primitive has finite cost for node {node.id!r} "
                f"({node.scn}); the canonical-layout strategy cannot "
                f"cover this scenario under this cost model")
        pick[node.id] = min(costs, key=lambda t: t[0])[1]
    return select_fixed(net, cost, pick, "local_optimal")


def select_family_best(net: Net, cost: CostModel,
                       family: str) -> SelectionResult:
    """The paper's per-family bars: replace SUM2D with the family's
    fastest variant when that variant is faster (node cost only — layout
    transformation costs are NOT considered in the pick, which is
    exactly the trap Section 5.8 demonstrates)."""
    sum2d = _sum2d_prim()
    pick = {}
    for node in net.conv_nodes():
        base_c = cost.primitive_cost(sum2d, node.scn)
        cands = [p for p in primitives_for(node.scn, families=[family])]
        best, best_c = sum2d, base_c
        for p in cands:
            c = cost.primitive_cost(p, node.scn)
            if np.isfinite(c) and c < best_c:
                best, best_c = p, c
        pick[node.id] = best
    return select_fixed(net, cost, pick, f"family_{family}")
