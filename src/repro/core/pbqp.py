"""Partitioned Boolean Quadratic Programming (PBQP) solver.

This is the computational heart of the paper (Anderson & Gregg 2017):
primitive selection in the presence of data-layout transformations is
embedded into PBQP and solved with a reduction-based solver in the style
of Scholz/Eckstein/Hames [LCTES'02, CC'03, SAS'06].

A PBQP instance is an undirected graph.  Every node ``u`` has a cost
vector ``c_u`` of length ``k_u`` (one entry per candidate assignment —
for us: one per applicable primitive/sharding).  Every edge ``(u, v)``
carries a cost matrix ``C_uv`` of shape ``(k_u, k_v)`` (for us: the
data-layout / resharding transition cost between the two chosen
primitives).  The objective is to pick one assignment per node
minimising::

    sum_u c_u[x_u]  +  sum_{(u,v)} C_uv[x_u, x_v]

The solver applies the optimality-preserving reductions R0 (isolated
node), RI (degree-1 node) and RII (degree-2 node) until the graph is
trivial.  If nodes of degree >= 3 remain, it either

* branches exactly (branch-and-bound over the smallest-domain high-degree
  node, re-entering the reduction engine on each sub-problem), or
* applies the RN heuristic (locally-minimal choice, not optimality
  preserving) when ``exact=False`` or the B&B budget is exhausted.

Infinite costs (``np.inf``) encode illegal combinations (e.g. no chain of
layout transformations exists between two layouts).  The solver treats a
fully-infinite optimum as infeasibility and raises :class:`Infeasible`.

The implementation is pure numpy — it runs in micro/milliseconds for
DNN-sized graphs (the paper reports < 1s per network; we match that, see
benchmarks/bench_solver.py).

docs/solver.md walks through the reductions, the branch-and-bound
pruning argument, and warm starting with a small worked example.
"""
from __future__ import annotations

import heapq
import itertools
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, Hashable, List, Optional, Sequence, Tuple

import numpy as np

from ..obs.trace import get_tracer

__all__ = [
    "PBQP",
    "Solution",
    "Infeasible",
    "solve",
    "solve_warm",
    "brute_force",
]


class Infeasible(Exception):
    """Raised when every full assignment has infinite cost."""


@dataclass
class Solution:
    """Result of a PBQP solve."""

    cost: float
    assignment: Dict[Hashable, int]
    #: True if produced purely by optimality-preserving reductions / exact
    #: branch-and-bound; False if the RN heuristic fired.
    optimal: bool
    #: number of reduction steps of each kind, for diagnostics
    stats: Dict[str, int] = field(default_factory=dict)


class PBQP:
    """A PBQP problem instance under construction.

    Nodes are identified by arbitrary hashable ids.  Edge matrices are
    oriented: ``add_edge(u, v, M)`` means ``M[i, j]`` is the cost of
    assigning choice ``i`` to ``u`` and choice ``j`` to ``v``.  Parallel
    edges are summed.
    """

    def __init__(self) -> None:
        self._costs: Dict[Hashable, np.ndarray] = {}
        self._edges: Dict[Tuple[Hashable, Hashable], np.ndarray] = {}

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------
    def add_node(self, u: Hashable, costs: Sequence[float]) -> None:
        c = np.asarray(costs, dtype=np.float64)
        if c.ndim != 1 or c.size == 0:
            raise ValueError(f"node {u!r}: cost vector must be 1-D, non-empty")
        if u in self._costs:
            raise ValueError(f"duplicate node {u!r}")
        self._costs[u] = c.copy()

    def add_edge(self, u: Hashable, v: Hashable, matrix: np.ndarray) -> None:
        for node in (u, v):
            if node not in self._costs:
                raise ValueError(
                    f"edge {u!r}->{v!r}: unknown node {node!r}")
        if u == v:
            # A self loop is just a node-cost adjustment along the diagonal.
            M = np.asarray(matrix, dtype=np.float64)
            k = len(self._costs[u])
            if M.shape != (k, k):
                raise ValueError(
                    f"edge {u!r}->{v!r}: matrix shape {M.shape} "
                    f"incompatible with domains ({k}, {k})")
            self._costs[u] = self._costs[u] + np.diag(M)
            return
        M = np.asarray(matrix, dtype=np.float64)
        ku, kv = len(self._costs[u]), len(self._costs[v])
        key, mat = ((u, v), M) if self._key_lt(u, v) else ((v, u), M.T)
        a, b = key
        if mat.shape != (len(self._costs[a]), len(self._costs[b])):
            raise ValueError(
                f"edge {u!r}->{v!r}: matrix shape {M.shape} incompatible with "
                f"domains ({ku}, {kv})"
            )
        if key in self._edges:
            self._edges[key] = self._edges[key] + mat
        else:
            self._edges[key] = mat.copy()

    def set_node_cost(self, u: Hashable, costs: Sequence[float]) -> None:
        """Replace node ``u``'s cost vector in place (same domain size).

        This is the mutation hook of the incremental re-solve workflow:
        neighbouring serving buckets share graph structure and differ only
        in a subset of node cost vectors, so callers update those vectors
        and re-solve with :func:`solve_warm`.
        """
        c = np.asarray(costs, dtype=np.float64)
        if u not in self._costs:
            raise KeyError(f"unknown node {u!r}")
        if c.shape != self._costs[u].shape:
            raise ValueError(
                f"node {u!r}: new cost shape {c.shape} != {self._costs[u].shape}")
        self._costs[u] = c.copy()

    def copy(self) -> "PBQP":
        new = PBQP()
        new._costs = {u: c.copy() for u, c in self._costs.items()}
        new._edges = {k: M.copy() for k, M in self._edges.items()}
        return new

    @staticmethod
    def _key_lt(u, v) -> bool:
        return str((type(u).__name__, u)) < str((type(v).__name__, v))

    # ------------------------------------------------------------------
    # accessors
    # ------------------------------------------------------------------
    @property
    def nodes(self) -> List[Hashable]:
        return list(self._costs)

    def domain(self, u: Hashable) -> int:
        return len(self._costs[u])

    def node_cost(self, u: Hashable) -> np.ndarray:
        return self._costs[u]

    def edge_cost(self, u: Hashable, v: Hashable) -> Optional[np.ndarray]:
        if self._key_lt(u, v):
            M = self._edges.get((u, v))
            return M
        M = self._edges.get((v, u))
        return None if M is None else M.T

    def evaluate(self, assignment: Dict[Hashable, int]) -> float:
        """Total cost of a full assignment."""
        total = 0.0
        for u, c in self._costs.items():
            total += c[assignment[u]]
        for (u, v), M in self._edges.items():
            total += M[assignment[u], assignment[v]]
        return float(total)

    # ------------------------------------------------------------------
    def solve(self, exact: bool = True, bb_budget: int = 200_000,
              deadline_s: Optional[float] = None) -> Solution:
        return solve(self, exact=exact, bb_budget=bb_budget,
                     deadline_s=deadline_s)

    def solve_warm(self, warm: Dict[Hashable, int], *, exact: bool = True,
                   bb_budget: int = 200_000,
                   deadline_s: Optional[float] = None) -> Solution:
        return solve_warm(self, warm, exact=exact, bb_budget=bb_budget,
                          deadline_s=deadline_s)


# ----------------------------------------------------------------------
# solver internals: work on a mutable adjacency representation
# ----------------------------------------------------------------------
class _Graph:
    def __init__(self, pb: PBQP):
        self.costs: Dict[Hashable, np.ndarray] = {u: c.copy() for u, c in pb._costs.items()}
        # adj[u][v] = matrix oriented (u, v)
        self.adj: Dict[Hashable, Dict[Hashable, np.ndarray]] = {u: {} for u in self.costs}
        for (u, v), M in pb._edges.items():
            self.adj[u][v] = M.copy()
            self.adj[v][u] = M.T  # view; kept consistent manually below
        self.base = 0.0  # accumulated constant cost

    def degree(self, u) -> int:
        return len(self.adj[u])

    def remove_node(self, u) -> None:
        for v in list(self.adj[u]):
            del self.adj[v][u]
        del self.adj[u]
        del self.costs[u]

    def set_edge(self, u, v, M: np.ndarray) -> None:
        self.adj[u][v] = M
        self.adj[v][u] = M.T

    def add_to_edge(self, u, v, M: np.ndarray) -> None:
        if v in self.adj[u]:
            self.set_edge(u, v, self.adj[u][v] + M)
        else:
            self.set_edge(u, v, M)

    def prune_trivial_edges(self) -> None:
        """Drop edges whose matrix is constant (fold the constant into base)."""
        for u in list(self.adj):
            for v in list(self.adj[u]):
                M = self.adj[u][v]
                finite = M[np.isfinite(M)]
                if finite.size == M.size and M.size and np.all(M == M.flat[0]):
                    self.base += float(M.flat[0])
                    del self.adj[u][v]
                    del self.adj[v][u]


def solve(pb: PBQP, exact: bool = True, bb_budget: int = 200_000,
          upper_bound: Optional[float] = None,
          deadline_s: Optional[float] = None) -> Solution:
    """Solve a PBQP instance.

    exact=True attempts an exact solve: RI/RII reductions are always
    optimality preserving; remaining degree->=3 nodes are handled by
    branch-and-bound with a node budget.  If the budget is exhausted the
    solver falls back to the RN heuristic for the remaining component and
    flags the solution as non-optimal.

    ``upper_bound`` is an optional *achievable* total-cost bound (e.g. the
    cost of a known feasible assignment).  Branch-and-bound prunes any
    sub-problem whose admissible lower bound strictly exceeds it, which is
    optimality preserving: the branch containing an optimum has a lower
    bound <= optimum <= upper_bound and thus survives.

    ``deadline_s`` makes the solve *anytime*: a wall-clock allowance
    (relative seconds) checked at every branch-and-bound entry.  When it
    expires, the search stops where it is and the RN heuristic completes
    the remaining component — a valid full assignment comes back no
    matter how hard the instance is, flagged ``optimal=False`` with
    ``stats["DEADLINE"] = 1``.  Exhausting ``bb_budget`` degrades the
    same way; neither ever raises.  This is the serving fallback
    ladder's "heuristic solve under a deadline" rung
    (docs/reliability.md).

    Emits a ``pbqp.solve`` trace span (repro.obs.trace) carrying the
    instance size and the B&B work actually done: ``bb`` nodes entered,
    ``prunes`` sub-problems cut by the bound test.
    """
    with get_tracer().span("pbqp.solve", nodes=len(pb._costs),
                           edges=len(pb._edges),
                           warm=upper_bound is not None) as sp:
        sol = _solve_impl(pb, exact, bb_budget, upper_bound, deadline_s)
        sp.set(cost=sol.cost, optimal=sol.optimal,
               bb=sol.stats.get("BB", 0),
               prunes=sol.stats.get("PRUNE", 0),
               deadline=sol.stats.get("DEADLINE", 0))
        return sol


def _solve_impl(pb: PBQP, exact: bool, bb_budget: int,
                upper_bound: Optional[float],
                deadline_s: Optional[float] = None) -> Solution:
    g = _Graph(pb)
    g.prune_trivial_edges()
    stats = {"R0": 0, "RI": 0, "RII": 0, "RN": 0, "BB": 0, "PRUNE": 0}
    t_end = (time.perf_counter() + deadline_s) \
        if deadline_s is not None else None
    # backtrack stack: callables applied in reverse to extend assignment
    trail: List[Callable[[Dict[Hashable, int]], None]] = []
    optimal = True

    budget = [bb_budget]

    def reduce_all() -> None:
        """Apply R0/RI/RII to a fixpoint."""
        work = [u for u in g.costs if g.degree(u) <= 2]
        in_work = set(work)
        while work:
            u = work.pop()
            in_work.discard(u)
            if u not in g.costs:
                continue
            d = g.degree(u)
            if d > 2:
                continue
            if d == 0:
                _r0(g, u, trail, stats)
            elif d == 1:
                v = _ri(g, u, trail, stats)
                if g.degree(v) <= 2 and v not in in_work:
                    work.append(v)
                    in_work.add(v)
            else:
                v, w = _rii(g, u, trail, stats)
                for n in (v, w):
                    if n in g.costs and g.degree(n) <= 2 and n not in in_work:
                        work.append(n)
                        in_work.add(n)

    reduce_all()

    while g.costs:
        # All remaining nodes have degree >= 3.
        if exact and budget[0] > 0 and not _expired(t_end):
            ok = _branch_and_bound(g, trail, stats, budget, upper_bound,
                                   t_end)
            if not ok:
                optimal = False
                if _expired(t_end):
                    stats["DEADLINE"] = 1
                _rn(g, trail, stats)
        else:
            optimal = False
            if _expired(t_end):
                stats["DEADLINE"] = 1
            _rn(g, trail, stats)
        reduce_all()

    if not np.isfinite(g.base):
        raise Infeasible("every assignment has infinite cost")

    assignment: Dict[Hashable, int] = {}
    for bt in reversed(trail):
        bt(assignment)
    cost = pb.evaluate(assignment)
    if not np.isfinite(cost):
        raise Infeasible("optimal assignment has infinite cost")
    return Solution(cost=cost, assignment=assignment, optimal=optimal, stats=stats)


def solve_warm(pb: PBQP, warm: Optional[Dict[Hashable, int]], *,
               exact: bool = True, bb_budget: int = 200_000,
               deadline_s: Optional[float] = None) -> Solution:
    """Incremental re-solve seeded by a previous solution.

    ``warm`` is a (possibly stale) full assignment — typically the optimum
    of a neighbouring instance that shares this instance's graph but had
    different node cost vectors.  Its cost *on this instance* is a valid
    achievable upper bound, so branch-and-bound starts with a tight
    incumbent instead of infinity and prunes most of the search tree.  The
    reductions (R0/RI/RII) and the bound-pruning are all optimality
    preserving, so the result is exactly as optimal as a fresh
    ``solve(exact=True)`` (verified bit-identical-cost in
    tests/test_warm_start.py).

    An invalid or infeasible warm assignment silently degrades to a cold
    solve — warm starting is a pure acceleration, never a correctness
    hazard.  ``stats['WARM']`` records whether the bound was usable;
    ``stats['WARM_DIST']`` the seed distance (number of nodes where the
    final assignment differs from the warm seed — 0 means the seed was
    already optimal for this instance).  A ``pbqp.solve_warm`` trace
    span reports both, around the inner ``pbqp.solve`` span.
    """
    with get_tracer().span("pbqp.solve_warm",
                           nodes=len(pb._costs)) as sp:
        ub: Optional[float] = None
        if warm is not None and set(warm) == set(pb._costs):
            if all(0 <= warm[u] < pb.domain(u) for u in warm):
                cand = pb.evaluate(warm)
                if np.isfinite(cand):
                    ub = cand
        sol = solve(pb, exact=exact, bb_budget=bb_budget, upper_bound=ub,
                    deadline_s=deadline_s)
        sol.stats["WARM"] = int(ub is not None)
        sol.stats["WARM_DIST"] = (
            sum(1 for u, i in sol.assignment.items() if warm[u] != i)
            if ub is not None else len(sol.assignment))
        sp.set(warm=sol.stats["WARM"], warm_dist=sol.stats["WARM_DIST"],
               bb=sol.stats.get("BB", 0),
               prunes=sol.stats.get("PRUNE", 0))
        return sol


def _r0(g: _Graph, u, trail, stats) -> None:
    c = g.costs[u]
    i = int(np.argmin(c))
    g.base += float(c[i])
    g.remove_node(u)
    stats["R0"] += 1
    trail.append(lambda asg, u=u, i=i: asg.__setitem__(u, i))


def _ri(g: _Graph, u, trail, stats):
    """Degree-1 reduction: fold u into its unique neighbour v."""
    (v, M), = g.adj[u].items()  # M oriented (u, v)
    cu = g.costs[u]
    # delta[j] = min_i cu[i] + M[i, j]; keep the argmin for backtracking
    tot = cu[:, None] + M
    best_i = np.argmin(tot, axis=0)
    delta = tot[best_i, np.arange(tot.shape[1])]
    g.costs[v] = g.costs[v] + delta
    g.remove_node(u)
    stats["RI"] += 1

    def bt(asg, u=u, v=v, best_i=best_i):
        asg[u] = int(best_i[asg[v]])

    trail.append(bt)
    return v


def _rii(g: _Graph, u, trail, stats):
    """Degree-2 reduction: fold u into an edge between its neighbours."""
    (v, Mv), (w, Mw) = g.adj[u].items()  # oriented (u, v), (u, w)
    cu = g.costs[u]
    kv, kw = Mv.shape[1], Mw.shape[1]
    # tot[i, j, k] = cu[i] + Mv[i, j] + Mw[i, k]
    tot = cu[:, None, None] + Mv[:, :, None] + Mw[:, None, :]
    best_i = np.argmin(tot, axis=0)  # (kv, kw)
    delta = np.min(tot, axis=0)
    g.remove_node(u)
    g.add_to_edge(v, w, delta)  # oriented (v, w)
    stats["RII"] += 1

    def bt(asg, u=u, v=v, w=w, best_i=best_i):
        asg[u] = int(best_i[asg[v], asg[w]])

    trail.append(bt)
    return v, w


def _rn(g: _Graph, trail, stats) -> None:
    """Heuristic reduction of one degree->=3 node (not optimality preserving).

    Picks the max-degree node and the assignment minimising its local cost
    (node cost + sum over neighbours of the best-case edge+neighbour cost),
    then folds the fixed choice's edge rows into the neighbours' vectors.
    """
    u = max(g.costs, key=lambda n: (g.degree(n), -g.costs[n].size))
    cu = g.costs[u].copy()
    local = cu.copy()
    for v, M in g.adj[u].items():
        local = local + np.min(M + g.costs[v][None, :], axis=1)
    i = int(np.argmin(local))
    g.base += float(cu[i])
    for v, M in list(g.adj[u].items()):
        g.costs[v] = g.costs[v] + M[i, :]
    g.remove_node(u)
    stats["RN"] += 1
    trail.append(lambda asg, u=u, i=i: asg.__setitem__(u, i))


def _expired(t_end: Optional[float]) -> bool:
    """Has the anytime wall-clock deadline passed?  (None: never.)"""
    return t_end is not None and time.perf_counter() >= t_end


def _lower_bound(g: _Graph) -> float:
    """Cheap admissible lower bound: node minima + half edge minima."""
    lb = g.base
    for c in g.costs.values():
        lb += float(np.min(c))
    for u in g.adj:
        for v, M in g.adj[u].items():
            if str((type(u).__name__, u)) < str((type(v).__name__, v)):
                lb += float(np.min(M))
    return lb


def _branch_and_bound(g: _Graph, trail, stats, budget,
                      ub: Optional[float] = None,
                      t_end: Optional[float] = None) -> bool:
    """Exactly resolve ONE degree->=3 node by enumerating its domain.

    For each choice we recursively solve the reduced sub-problem (full
    solver recursion on a copy).  Returns False if the budget or the
    wall-clock deadline (``t_end``, absolute perf_counter seconds) is
    exhausted (caller falls back to RN).  ``ub`` is an optional
    achievable global upper bound (warm start); sub-problems with lower
    bound > ub are pruned without losing any optimum.
    """
    # Pick the highest-degree node with the smallest domain: cheap to
    # enumerate, high simplification payoff.
    u = min(g.costs, key=lambda n: (g.costs[n].size, -g.degree(n)))
    k = g.costs[u].size
    if budget[0] < k or _expired(t_end):
        return False
    budget[0] -= k
    stats["BB"] += 1

    best_cost = np.inf
    best_choice = -1
    best_sub: Optional[Tuple[List[Callable], Dict]] = None

    for i in range(k):
        if not np.isfinite(g.costs[u][i]):
            continue
        sub = _clone(g)
        # fix u := i
        sub.base += float(sub.costs[u][i])
        for v, M in list(sub.adj[u].items()):
            sub.costs[v] = sub.costs[v] + M[i, :]
        sub.remove_node(u)
        lb = _lower_bound(sub)
        # ub tolerance: lb and the warm cost are summed in different
        # orders, so an exactly-optimal warm bound could otherwise prune
        # the optimal branch by a rounding ulp (-> spurious Infeasible).
        if lb >= best_cost or \
                (ub is not None and lb > ub + 1e-9 * max(1.0, abs(ub))):
            stats["PRUNE"] += 1
            continue
        sub_trail: List[Callable] = []
        sub_stats = {"R0": 0, "RI": 0, "RII": 0, "RN": 0, "BB": 0,
                     "PRUNE": 0}
        ok = _solve_rec(sub, sub_trail, sub_stats, budget, ub, t_end)
        if not ok:
            return False
        if sub.base < best_cost:
            best_cost = sub.base
            best_choice = i
            best_sub = (sub_trail, sub_stats)

    if best_choice < 0:
        # Every choice of u is infinite (or every branch infeasible):
        # this whole component has no finite assignment.  Record a
        # *total* fallback assignment covering u AND every remaining
        # node — an empty sub-trail would leave those nodes out of the
        # assignment and turn the top-level ``pb.evaluate`` into a
        # KeyError; with the trail complete, evaluate() reports inf and
        # solve() raises Infeasible (its base check fires first anyway,
        # since base becomes inf below).
        remaining = [n for n in g.costs if n != u]
        best_choice = 0
        best_sub = ([lambda asg, ns=tuple(remaining):
                     asg.update({n: 0 for n in ns})], {})
        best_cost = np.inf

    sub_trail, sub_stats = best_sub
    for key, val in sub_stats.items():
        stats[key] += val
    # Splice: u's choice, then the winning sub-problem's backtracks.
    trail.append(lambda asg, u=u, i=best_choice: asg.__setitem__(u, i))
    trail.extend(sub_trail)
    # Mutate g to empty: the sub-solve has fully consumed the graph.
    g.costs.clear()
    g.adj.clear()
    g.base = best_cost
    return True


def _solve_rec(g: _Graph, trail, stats, budget,
               ub: Optional[float] = None,
               t_end: Optional[float] = None) -> bool:
    """Run reductions + B&B to completion on g (used inside B&B)."""
    def reduce_all():
        work = [u for u in g.costs if g.degree(u) <= 2]
        in_work = set(work)
        while work:
            u = work.pop()
            in_work.discard(u)
            if u not in g.costs:
                continue
            d = g.degree(u)
            if d > 2:
                continue
            if d == 0:
                _r0(g, u, trail, stats)
            elif d == 1:
                v = _ri(g, u, trail, stats)
                if g.degree(v) <= 2 and v not in in_work:
                    work.append(v); in_work.add(v)
            else:
                v, w = _rii(g, u, trail, stats)
                for n in (v, w):
                    if n in g.costs and g.degree(n) <= 2 and n not in in_work:
                        work.append(n); in_work.add(n)

    reduce_all()
    while g.costs:
        if budget[0] <= 0 or _expired(t_end):
            return False
        if not _branch_and_bound(g, trail, stats, budget, ub, t_end):
            return False
        reduce_all()
    return True


def _clone(g: _Graph) -> _Graph:
    new = _Graph.__new__(_Graph)
    new.costs = {u: c.copy() for u, c in g.costs.items()}
    new.adj = {u: {} for u in g.costs}
    seen = set()
    for u in g.adj:
        for v, M in g.adj[u].items():
            if (v, u) in seen:
                continue
            seen.add((u, v))
            new.adj[u][v] = M.copy()
            new.adj[v][u] = new.adj[u][v].T
    new.base = g.base
    return new


# ----------------------------------------------------------------------
# brute force (testing oracle)
# ----------------------------------------------------------------------
def brute_force(pb: PBQP) -> Solution:
    """Exhaustive minimum — exponential; for testing only."""
    nodes = pb.nodes
    domains = [range(pb.domain(u)) for u in nodes]
    best = np.inf
    best_asg: Optional[Dict[Hashable, int]] = None
    for combo in itertools.product(*domains):
        asg = dict(zip(nodes, combo))
        c = pb.evaluate(asg)
        if c < best:
            best = c
            best_asg = asg
    if best_asg is None or not np.isfinite(best):
        raise Infeasible("every assignment has infinite cost")
    return Solution(cost=float(best), assignment=best_asg, optimal=True)
