"""Unified choice-space PBQP construction — one builder for every
transformation kind.

The paper's core claim is that implementation selection and data-format
transformation are ONE joint optimization problem.  This module is that
claim as code: a single, transform-kind-agnostic bridge from a *choice
space* (per-entity choice domains with setup costs, plus pluggable
transition pricing between adjacent entities) to a
:class:`~repro.core.pbqp.PBQP` instance.  Two very different selection
problems build through it:

* **Layout-level selection** (:mod:`repro.core.selection`): entities are
  the layers of a conv net, choices are primitives (or accepted layouts,
  for op nodes), and transitions price
  ``min(materialized DT conversion chain, fused prologue/epilogue)``.
* **Sharding-level selection** (:mod:`repro.core.sharding_select`):
  entities are the tensor groups of a transformer program, choices are
  sharding rule-sets, and transitions price resharding collectives —
  the "layout transformation" of the distributed world.

Either way the objective the solver sees is the paper's::

    sum_u setup(choice_u)  +  sum_{(u,v)} transition(choice_u, choice_v)

and the same exact reduction/branch-and-bound engine
(:func:`repro.core.pbqp.solve`) finds the global optimum.
``docs/distributed.md`` maps the two instantiations side by side.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import (
    Any, Callable, Dict, Hashable, List, Sequence, Tuple,
)

import numpy as np

from . import pbqp

__all__ = ["ChoiceNode", "ChoiceEdge", "build_pbqp", "drop_infinite"]


@dataclass
class ChoiceNode:
    """One entity's choice domain.

    ``costs[i]`` is the setup cost of picking ``choices[i]`` for this
    entity alone (a primitive's invocation time; a sharding rule's
    intra-group collective time).  Infinite costs mark choices the
    solver may only take when nothing finite exists.
    """
    id: Hashable
    choices: Sequence[Any]
    costs: Sequence[float]

    def __post_init__(self):
        if len(self.choices) != len(self.costs):
            raise ValueError(
                f"node {self.id!r}: {len(self.choices)} choices but "
                f"{len(self.costs)} costs")
        if not self.choices:
            raise ValueError(f"node {self.id!r}: empty choice domain")


@dataclass
class ChoiceEdge:
    """Transition pricing between two adjacent entities.

    ``transition(cu, cv)`` returns the cost of moving data produced
    under choice ``cu`` (of ``src``) into the form choice ``cv`` (of
    ``dst``) consumes — a layout-conversion chain, a fused variant, a
    resharding collective, ``inf`` when no transformation exists.
    Scaling (minibatch, per-layer repeat counts) belongs inside
    ``transition``: both callers scale per pair.
    """
    src: Hashable
    dst: Hashable
    transition: Callable[[Any, Any], float]


def build_pbqp(nodes: Sequence[ChoiceNode], edges: Sequence[ChoiceEdge],
               ) -> Tuple[pbqp.PBQP, Dict[Hashable, List[Any]]]:
    """Materialize a choice space as a PBQP instance.

    Returns ``(problem, domains)`` where ``domains[id]`` lists the node's
    choice objects in the order the solver's assignment indexes them —
    the caller recovers the winning choices as
    ``{id: domains[id][sol.assignment[id]]}``.
    """
    pb = pbqp.PBQP()
    domains: Dict[Hashable, List[Any]] = {}
    for node in nodes:
        domains[node.id] = list(node.choices)
        pb.add_node(node.id, [float(c) for c in node.costs])
    for edge in edges:
        cu, cv = domains[edge.src], domains[edge.dst]
        M = np.empty((len(cu), len(cv)), dtype=np.float64)
        for i, a in enumerate(cu):
            for j, b in enumerate(cv):
                M[i, j] = edge.transition(a, b)
        pb.add_edge(edge.src, edge.dst, M)
    return pb, domains


def drop_infinite(entries: Sequence[Tuple[Any, float]]
                  ) -> List[Tuple[Any, float]]:
    """Drop infinite-cost choices — unless that would empty the domain.

    A domain of only-infinite choices is kept intact so the solver can
    report :class:`~repro.core.pbqp.Infeasible` (or legalize through
    edges) instead of the builder crashing on a degenerate instance.
    """
    finite = [(c, v) for (c, v) in entries if np.isfinite(v)]
    return finite or list(entries)
