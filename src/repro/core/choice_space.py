"""Unified choice-space PBQP construction — one builder for every
transformation kind.

The paper's core claim is that implementation selection and data-format
transformation are ONE joint optimization problem.  This module is that
claim as code: a single, transform-kind-agnostic bridge from a *choice
space* (per-entity choice domains with setup costs, plus pluggable
transition pricing between adjacent entities) to a
:class:`~repro.core.pbqp.PBQP` instance.  Two very different selection
problems build through it:

* **Layout-level selection** (:mod:`repro.core.selection`): entities are
  the layers of a conv net, choices are primitives (or accepted layouts,
  for op nodes), and transitions price
  ``min(materialized DT conversion chain, fused prologue/epilogue)``.
* **Sharding-level selection** (:mod:`repro.core.sharding_select`):
  entities are the tensor groups of a transformer program, choices are
  sharding rule-sets, and transitions price resharding collectives —
  the "layout transformation" of the distributed world.

Either way the objective the solver sees is the paper's::

    sum_u setup(choice_u)  +  sum_{(u,v)} transition(choice_u, choice_v)

and the same exact reduction/branch-and-bound engine
(:func:`repro.core.pbqp.solve`) finds the global optimum.
``docs/distributed.md`` maps the two instantiations side by side.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import (
    Any, Callable, Dict, Hashable, List, Sequence, Tuple,
)

import numpy as np

from . import pbqp

__all__ = ["ChoiceNode", "ChoiceEdge", "Placement", "build_pbqp",
           "drop_infinite"]


class Placement(str):
    """A device-placement choice, as a structured string.

    The placement axis of the choice space covers four kinds:

    ``rep``
        replicated — every device holds the full tensor/batch.
    ``dp``
        data-parallel — the batch is sharded over every non-stage mesh
        axis (``data`` x ``model`` flattened).
    ``tp``
        tensor-parallel — the batch is sharded over the ``data`` axis
        and conv weights are sharded over the ``model`` axis
        (output-channel split); the node pays the intra-node
        all-gather that reassembles the channel dimension.
    ``pp<stage>``
        pipeline-parallel — the node is resident on pipeline stage
        ``<stage>`` of the ``stage`` mesh axis; edges that cross a
        stage boundary pay the activation send.

    Subclassing :class:`str` keeps the whole pre-existing surface
    working unchanged: ``choice.placement == "dp"`` comparisons,
    dict/set hashing, JSON plan-cache round trips, and
    ``dataclasses.replace(choice, placement="dp")`` in tests all see a
    plain string.  The structure (``kind``, ``stage``) rides along as
    attributes.
    """

    KINDS = ("rep", "dp", "tp", "pp")

    def __new__(cls, kind: str, stage: int = 0):
        if kind not in cls.KINDS:
            raise ValueError(f"unknown placement kind {kind!r}")
        if kind == "pp":
            if stage < 0:
                raise ValueError(f"negative pipeline stage {stage}")
            s = f"pp{stage}"
        else:
            stage = 0
            s = kind
        self = super().__new__(cls, s)
        self.kind = kind
        self.stage = int(stage)
        return self

    @classmethod
    def parse(cls, s: "str | Placement") -> "Placement":
        """Recover the structured form from its canonical string
        (idempotent on :class:`Placement` instances)."""
        if isinstance(s, Placement):
            return s
        if s in ("rep", "dp", "tp"):
            return cls(s)
        if s.startswith("pp") and s[2:].isdigit():
            return cls("pp", int(s[2:]))
        raise ValueError(f"unparsable placement {s!r}")


@dataclass
class ChoiceNode:
    """One entity's choice domain.

    ``costs[i]`` is the setup cost of picking ``choices[i]`` for this
    entity alone (a primitive's invocation time; a sharding rule's
    intra-group collective time).  Infinite costs mark choices the
    solver may only take when nothing finite exists.
    """
    id: Hashable
    choices: Sequence[Any]
    costs: Sequence[float]

    def __post_init__(self):
        if len(self.choices) != len(self.costs):
            raise ValueError(
                f"node {self.id!r}: {len(self.choices)} choices but "
                f"{len(self.costs)} costs")
        if not self.choices:
            raise ValueError(f"node {self.id!r}: empty choice domain")


@dataclass
class ChoiceEdge:
    """Transition pricing between two adjacent entities.

    ``transition(cu, cv)`` returns the cost of moving data produced
    under choice ``cu`` (of ``src``) into the form choice ``cv`` (of
    ``dst``) consumes — a layout-conversion chain, a fused variant, a
    resharding collective, ``inf`` when no transformation exists.
    Scaling (minibatch, per-layer repeat counts) belongs inside
    ``transition``: both callers scale per pair.
    """
    src: Hashable
    dst: Hashable
    transition: Callable[[Any, Any], float]


def build_pbqp(nodes: Sequence[ChoiceNode], edges: Sequence[ChoiceEdge],
               ) -> Tuple[pbqp.PBQP, Dict[Hashable, List[Any]]]:
    """Materialize a choice space as a PBQP instance.

    Returns ``(problem, domains)`` where ``domains[id]`` lists the node's
    choice objects in the order the solver's assignment indexes them —
    the caller recovers the winning choices as
    ``{id: domains[id][sol.assignment[id]]}``.
    """
    pb = pbqp.PBQP()
    domains: Dict[Hashable, List[Any]] = {}
    for node in nodes:
        domains[node.id] = list(node.choices)
        pb.add_node(node.id, [float(c) for c in node.costs])
    for edge in edges:
        cu, cv = domains[edge.src], domains[edge.dst]
        M = np.empty((len(cu), len(cv)), dtype=np.float64)
        for i, a in enumerate(cu):
            for j, b in enumerate(cv):
                M[i, j] = edge.transition(a, b)
        pb.add_edge(edge.src, edge.dst, M)
    return pb, domains


def drop_infinite(entries: Sequence[Tuple[Any, float]]
                  ) -> List[Tuple[Any, float]]:
    """Drop infinite-cost choices — unless that would empty the domain.

    A domain of only-infinite choices is kept intact so the solver can
    report :class:`~repro.core.pbqp.Infeasible` (or legalize through
    edges) instead of the builder crashing on a degenerate instance.
    """
    finite = [(c, v) for (c, v) in entries if np.isfinite(v)]
    return finite or list(entries)
