"""Data layouts and the data-layout-transformation (DT) graph.

Section 3.1 of the paper: the set of direct layout-transformation
routines forms a directed graph over layouts.  Chains of transformations
give the transitive closure; the cost of converting layout A -> B is the
shortest path in the DT graph under per-edge costs (measured execution
time of each direct transform on the actual tensor sizes).  Unreachable
pairs have infinite cost.

Layouts here are permutations of the logical (C, H, W) activation tensor
axes, plus *blocked* variants (e.g. HWC8 = H x W x C/8 x 8, the vector-
friendly blocking used by vectorised primitives).  On TPU the same
machinery is reused at the distributed level where "layouts" are
shardings — see repro/core/sharding_select.py.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "Layout",
    "CHW", "CWH", "HCW", "HWC", "WCH", "WHC", "HWC8",
    "ALL_LAYOUTS",
    "DTGraph",
    "default_dt_graph",
]


@dataclass(frozen=True)
class Layout:
    """A concrete in-memory arrangement of a logical (C, H, W) tensor.

    ``perm[i]`` is the logical axis (0=C, 1=H, 2=W) stored at memory
    position ``i``; i.e. ``mem = np.transpose(x_chw, perm)``.
    ``block_c`` > 0 means the C axis
    is additionally blocked into (C // block_c, ..., block_c) with the
    block innermost (vector-register friendly; the analogue of the
    NCHWc layouts used by MKL-DNN / oneDNN).
    """

    name: str
    perm: Tuple[int, int, int]  # logical axis stored at each memory position
    block_c: int = 0

    def to_memory(self, x_chw: np.ndarray) -> np.ndarray:
        """Convert a logical CHW array into this layout (reference impl)."""
        x = np.transpose(x_chw, self.perm)
        if self.block_c:
            # find where C sits in memory order
            cpos = self.perm.index(0)
            c = x.shape[cpos]
            if c % self.block_c:
                raise ValueError(f"C={c} not divisible by block {self.block_c}")
            shape = list(x.shape)
            shape[cpos:cpos + 1] = [c // self.block_c, self.block_c]
            x = x.reshape(shape)
            # move the block axis innermost
            x = np.moveaxis(x, cpos + 1, -1)
        return x

    def from_memory(self, x_mem: np.ndarray) -> np.ndarray:
        """Inverse of :meth:`to_memory` — back to logical CHW."""
        x = x_mem
        if self.block_c:
            cpos = self.perm.index(0)
            x = np.moveaxis(x, -1, cpos + 1)
            shape = list(x.shape)
            shape[cpos:cpos + 2] = [shape[cpos] * shape[cpos + 1]]
            x = x.reshape(shape)
        inv = np.argsort(self.perm)
        return np.transpose(x, inv)

    def __repr__(self) -> str:  # pragma: no cover
        return f"Layout({self.name})"


_AXES = "CHW"


def _perm_layout(order: str) -> Layout:
    return Layout(order, tuple(_AXES.index(a) for a in order))


CHW = _perm_layout("CHW")
CWH = _perm_layout("CWH")
HCW = _perm_layout("HCW")
HWC = _perm_layout("HWC")
WCH = _perm_layout("WCH")
WHC = _perm_layout("WHC")
HWC8 = Layout("HWC8", HWC.perm, block_c=8)

#: the paper's three main layouts + blocked variant; CWH/WCH/WHC exist in
#: the DT graph but no primitive uses them natively (they exercise the
#: "chain of transformations" path).
ALL_LAYOUTS: List[Layout] = [CHW, HCW, HWC, CWH, WCH, WHC, HWC8]
LAYOUT_BY_NAME: Dict[str, Layout] = {l.name: l for l in ALL_LAYOUTS}


def transform_feasible(src: str, dst: str,
                       shape_chw: Tuple[int, int, int]) -> bool:
    """Blocked layouts require the channel count to divide the block."""
    for name in (src, dst):
        lay = LAYOUT_BY_NAME.get(name)
        if lay is not None and lay.block_c and shape_chw[0] % lay.block_c:
            return False
    return True


class DTGraph:
    """Data-layout transformation graph with APSP cost/chain queries.

    Nodes: layout names.  Directed edges: direct transformation routines
    with a cost function ``(scenario) -> seconds`` (or a constant).  The
    all-pairs shortest path is computed lazily per cost key and cached.
    """

    def __init__(self) -> None:
        self._nodes: List[str] = []
        self._edges: Dict[Tuple[str, str], Callable] = {}

    def add_layout(self, name: str) -> None:
        if name not in self._nodes:
            self._nodes.append(name)

    def add_transform(self, src: str, dst: str, cost_fn: Callable) -> None:
        """Register a direct transform routine src -> dst.

        ``cost_fn(shape_chw, dtype) -> float`` returns the (profiled or
        modelled) execution cost for a logical-CHW shaped tensor.
        """
        self.add_layout(src)
        self.add_layout(dst)
        self._edges[(src, dst)] = cost_fn

    @property
    def layouts(self) -> List[str]:
        return list(self._nodes)

    @property
    def direct_edges(self) -> List[Tuple[str, str]]:
        return list(self._edges)

    # ------------------------------------------------------------------
    def cost_matrix(self, shape_chw: Tuple[int, int, int],
                    dtype=np.float32) -> Tuple[np.ndarray, Dict[str, int]]:
        """APSP cost matrix for converting a tensor of this shape.

        Returns ``(costs, index)`` where ``costs[i, j]`` is the min total
        cost of converting layout i -> j (0 on the diagonal, inf if
        unreachable) and ``index`` maps layout name -> row.
        """
        idx = {n: i for i, n in enumerate(self._nodes)}
        n = len(self._nodes)
        d = np.full((n, n), np.inf)
        np.fill_diagonal(d, 0.0)
        for (s, t), fn in self._edges.items():
            c = float(fn(shape_chw, dtype))
            if c < d[idx[s], idx[t]]:
                d[idx[s], idx[t]] = c
        # Floyd-Warshall (layout count is tiny)
        for k in range(n):
            d = np.minimum(d, d[:, k:k + 1] + d[k:k + 1, :])
        return d, idx

    def shortest_chain(self, src: str, dst: str,
                       shape_chw: Tuple[int, int, int],
                       dtype=np.float32) -> Optional[List[str]]:
        """The actual layout chain realising the APSP cost (for the
        legalizer, which must materialise conversion layers)."""
        if src == dst:
            return [src]
        idx = {n: i for i, n in enumerate(self._nodes)}
        n = len(self._nodes)
        d = np.full((n, n), np.inf)
        np.fill_diagonal(d, 0.0)
        nxt = -np.ones((n, n), dtype=np.int64)
        for (s, t), fn in self._edges.items():
            c = float(fn(shape_chw, dtype))
            si, ti = idx[s], idx[t]
            if c < d[si, ti]:
                d[si, ti] = c
                nxt[si, ti] = ti
        for i in range(n):
            nxt[i, i] = i
        for k in range(n):
            for i in range(n):
                for j in range(n):
                    if d[i, k] + d[k, j] < d[i, j]:
                        d[i, j] = d[i, k] + d[k, j]
                        nxt[i, j] = nxt[i, k]
        si, ti = idx[src], idx[dst]
        if not np.isfinite(d[si, ti]):
            return None
        path = [si]
        while path[-1] != ti:
            path.append(int(nxt[path[-1], ti]))
        names = self._nodes
        return [names[p] for p in path]


# ----------------------------------------------------------------------
# default DT graph: transforms between the permutation layouts
# ----------------------------------------------------------------------
def _transpose_cost(shape_chw, dtype, *, passes: float = 1.0) -> float:
    """Analytic fallback cost of a layout transform: bytes moved twice
    (read + write) at an effective strided-copy bandwidth."""
    c, h, w = shape_chw
    nbytes = c * h * w * np.dtype(dtype).itemsize
    eff_bw = 4e9  # strided transpose is far from streaming bandwidth
    return passes * 2 * nbytes / eff_bw


def default_dt_graph(profile: bool = False) -> DTGraph:
    """The DT graph shipped with the primitive library.

    Deliberately *not* complete: CHW <-> HWC and CHW <-> HCW have direct
    routines, but e.g. HWC -> HCW must chain through CHW, and the blocked
    HWC8 layout is reachable only from HWC.  This mirrors the paper's
    observation that real libraries provide a limited set of direct
    transforms and chains must be constructed.
    """
    g = DTGraph()
    direct = [
        ("CHW", "HWC"), ("HWC", "CHW"),
        ("CHW", "HCW"), ("HCW", "CHW"),
        ("CHW", "CWH"), ("CWH", "CHW"),
        ("HWC", "WHC"), ("WHC", "HWC"),
        ("CWH", "WCH"), ("WCH", "CWH"),
        ("HWC", "HWC8"), ("HWC8", "HWC"),
    ]
    for s, t in direct:
        g.add_transform(s, t, _transpose_cost)
    return g
