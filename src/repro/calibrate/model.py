"""CalibratedCostModel: serve measured costs, fall back analytically.

The paper's headline result depends on *measured* per-primitive and
per-transform costs; the analytic roofline is only the "simple
heuristic" it is compared against.  This model closes that gap for the
serving path: costs come from a :class:`~repro.calibrate.profile.
HardwareProfile` measured offline on the target device, and any
(primitive, scenario) or transform the sweep did not cover falls back to
a configurable analytic model — selection never fails just because
coverage is partial.

Scenario lookup goes through :func:`repro.serving.bucketing.
bucket_scenario`: per-layer scenarios are canonicalized onto the same
finite bucket grid the sweep measured, so one sweep prices every request
shape the serving tier can produce (the sweep and the model must agree
on the :class:`~repro.serving.bucketing.BucketPolicy`).

``version()`` folds in the profile's content hash, the bucket policy and
the fallback's own version: *any* recalibration — a new device, a new
measurement, an edited table — changes the version string, and the
serving plan cache (keyed on it) re-solves instead of serving plans that
were optimal only for the old numbers.  docs/calibration.md walks
through this invalidation chain end to end.
"""
from __future__ import annotations

from typing import Optional, Tuple

from ..core.costs import (
    AnalyticCostModel, CostModel, collective_cost_key, fused_cost_key,
    prim_cost_key, transform_cost_key,
)
from ..core.layouts import transform_feasible
from ..core.primitives import Primitive
from ..core.scenario import Scenario
from ..serving.bucketing import BucketPolicy, bucket_scenario, bucket_shape
from .profile import HardwareProfile, device_fingerprint

__all__ = ["CalibratedCostModel"]


class CalibratedCostModel(CostModel):
    """Measured cost tables with analytic fallback for uncovered buckets.

    Parameters
    ----------
    profile:
        The measured table (``HardwareProfile.load(path)``).
    fallback:
        Prices anything the profile does not cover; defaults to
        :class:`~repro.core.costs.AnalyticCostModel`.
    policy:
        Bucket policy mapping scenarios onto the profile's grid; must
        match the policy the sweep was planned with.
    check_device:
        When True (default), a profile measured on a different device
        class than the current process raises ``ValueError`` — measured
        numbers are only transferable when you say so (``check_device=
        False``, the PolyDL-style cross-device transfer case).
    exclude_tags:
        Primitives carrying any of these tags are priced infinite, table
        entry or not.  Defaults to ``("tpu-only",)`` unless the profile
        was measured on a TPU — a CPU profile must never legitimize a
        Pallas kernel (any CPU timing of one is interpret-mode noise).
    """

    def __init__(self, profile: HardwareProfile, *,
                 fallback: Optional[CostModel] = None,
                 policy: Optional[BucketPolicy] = None,
                 check_device: bool = True,
                 exclude_tags: Optional[Tuple[str, ...]] = None) -> None:
        if check_device and profile.device != device_fingerprint():
            raise ValueError(
                f"profile measured on {profile.device!r} but this process "
                f"runs on {device_fingerprint()!r}; pass check_device="
                f"False to transfer it anyway")
        self.profile = profile
        self.fallback = fallback or AnalyticCostModel()
        self.policy = policy or BucketPolicy()
        if exclude_tags is None:
            exclude_tags = () if profile.device.startswith("tpu") \
                else ("tpu-only",)
        self.exclude_tags = tuple(exclude_tags)
        #: lookup accounting: how often the table actually served
        self.table_hits = 0
        self.fallback_hits = 0

    # -----------------------------------------------------------------
    def _version_fields(self) -> str:
        return (f"profile={self.profile.content_hash()}"
                f"|policy={self.policy!r}"
                f"|excl={sorted(self.exclude_tags)}"
                f"|fallback={self.fallback.version()}")

    # -----------------------------------------------------------------
    def primitive_cost(self, prim: Primitive, scn: Scenario) -> float:
        if any(t in prim.tags for t in self.exclude_tags):
            return float("inf")
        b = bucket_scenario(scn, self.policy)
        v = self.profile.get(prim_cost_key(prim.name, b))
        if v is not None:
            self.table_hits += 1
            return v
        self.fallback_hits += 1
        return self.fallback.primitive_cost(prim, scn)

    def transform_cost(self, src: str, dst: str,
                       shape_chw: Tuple[int, int, int], dtype) -> float:
        if not transform_feasible(src, dst, shape_chw):
            return float("inf")
        bshape = bucket_shape(shape_chw, self.policy)
        v = self.profile.get(transform_cost_key(src, dst, bshape))
        if v is not None:
            self.table_hits += 1
            return v
        self.fallback_hits += 1
        return self.fallback.transform_cost(src, dst, shape_chw, dtype)

    # -----------------------------------------------------------------
    def _fused_cost(self, kind: str, prim: Primitive, scn: Scenario,
                    layout: str) -> float:
        """Measured fused-edge delta from the profile's fused-pair
        entries (``fuse{in,out}::…``, timed by the sweep with
        :func:`~repro.core.costs.measure_fused_primitive`): whole fused
        invocation minus the native invocation, clamped at zero.  Falls
        back to the fallback model's estimate when either entry is
        uncovered — selection never fails on partial coverage.
        """
        if any(t in prim.tags for t in self.exclude_tags):
            return float("inf")
        native = prim.l_in if kind == "in" else prim.l_out
        shape = scn.in_shape_chw if kind == "in" else scn.out_shape_chw
        if layout == native:
            return 0.0
        if not transform_feasible(layout, native, shape):
            return float("inf")
        b = bucket_scenario(scn.with_(n=1), self.policy)
        fused = self.profile.get(fused_cost_key(kind, prim.name, layout, b))
        nat = self.profile.get(prim_cost_key(prim.name, b))
        if fused is not None and nat is not None:
            self.table_hits += 1
            return max(0.0, fused - nat)
        self.fallback_hits += 1
        if kind == "in":
            return self.fallback.fused_in_cost(prim, scn, layout)
        return self.fallback.fused_out_cost(prim, scn, layout)

    def fused_in_cost(self, prim: Primitive, scn: Scenario,
                      l_src: str) -> float:
        return self._fused_cost("in", prim, scn, l_src)

    def fused_out_cost(self, prim: Primitive, scn: Scenario,
                       l_dst: str) -> float:
        return self._fused_cost("out", prim, scn, l_dst)

    # -----------------------------------------------------------------
    def collective_cost(self, kind: str, nbytes: float, n: int) -> float:
        """Measured collective timings when the profile has them.

        Payload sizes bucket to the next power of two (the same
        round-up-only discipline request shapes get, via
        :func:`~repro.serving.bucketing.round_dim`), so log-many
        ``coll::`` entries price every tensor serving or sharding
        selection can produce.  The calibration sweep does not yet
        *measure* collectives (that needs a multi-chip pod run);
        entries arrive from a pod-side timing pass loaded into the
        profile by hand or by future tooling.  Uncovered (kind, bucket,
        n) triples fall back to the fallback model's analytic ring
        estimate — collective pricing never fails on partial coverage.
        """
        if n <= 1:
            return 0.0
        from ..serving.bucketing import round_dim
        bucket = round_dim(int(nbytes), "pow2", 1, 1, 1 << 62)
        v = self.profile.get(collective_cost_key(kind, bucket, n))
        if v is not None:
            self.table_hits += 1
            return v
        self.fallback_hits += 1
        return self.fallback.collective_cost(kind, nbytes, n)

    # -----------------------------------------------------------------
    def coverage(self) -> dict:
        """Lookup accounting since construction (for logs/benchmarks)."""
        total = self.table_hits + self.fallback_hits
        return {"table_hits": self.table_hits,
                "fallback_hits": self.fallback_hits,
                "table_rate": self.table_hits / total if total else 0.0}
