"""Measured-cost calibration subsystem.

Closes the loop between the kernels this repo ships and the PBQP
decisions it makes: the paper's selections are only optimal with respect
to *measured* per-primitive and per-transform costs, so this package
sweeps every registered kernel variant across a grid of scenario
buckets, times them on-device, and persists the results as versioned
per-device cost tables that drive selection at serving time.

* :mod:`.profile` — :class:`HardwareProfile`: the on-disk table, keyed
  by device fingerprint + primitive-registry hash;
* :mod:`.sweep`   — resumable plan/run split over (primitive, bucket)
  pairs, layout transforms and standalone kernel microbenchmarks;
* :mod:`.model`   — :class:`CalibratedCostModel`: serves measured
  costs with analytic fallback for uncovered buckets, and folds the
  profile's content hash into ``CostModel.version()`` so recalibration
  invalidates the serving plan cache.

Entry points: ``python -m repro.launch.calibrate`` (build a profile),
``python -m repro.launch.serve --profile <path>`` (serve with it),
``python -m benchmarks.bench_calibration`` (analytic-vs-measured
selection deltas).  See docs/calibration.md.
"""
from .model import CalibratedCostModel
from .profile import (
    PROFILE_SCHEMA, HardwareProfile, device_fingerprint, registry_hash,
)
from .sweep import (
    GRIDS, SweepItem, plan_sweep, run_sweep, scenario_grid,
    scenarios_from_net,
)

__all__ = [
    "CalibratedCostModel",
    "PROFILE_SCHEMA", "HardwareProfile", "device_fingerprint",
    "registry_hash",
    "GRIDS", "SweepItem", "plan_sweep", "run_sweep", "scenario_grid",
    "scenarios_from_net",
]
