"""Calibration sweep: plan and run on-device measurements.

Two phases, so coverage is inspectable before any timing happens:

* :func:`plan_sweep` enumerates every measurement a profile should hold
  — one :class:`SweepItem` per applicable (primitive, scenario-bucket)
  pair, per feasible direct layout transform at each bucketed tensor
  shape, and per standalone Pallas kernel microbenchmark
  (``benchmark_entry`` in each :mod:`repro.kernels` subpackage).  This
  is what ``launch/calibrate.py --dry-run`` prints.

* :func:`run_sweep` executes the items against a
  :class:`~repro.calibrate.profile.HardwareProfile`, skipping keys the
  profile already holds — interrupting a sweep loses at most
  ``save_every`` measurements, and re-running the CLI resumes where it
  stopped.

Scenario grids are *bucket* grids: the same canonicalization
(:func:`repro.serving.bucketing.bucket_scenario`) the
:class:`~repro.calibrate.model.CalibratedCostModel` applies at lookup
time is applied at plan time, so every measured key is reachable from a
live scenario.  :func:`scenarios_from_net` plans the exact buckets one
network needs — the cheap way to calibrate for a known workload.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

from ..core.costs import (
    fused_cost_key, measure_fused_primitive, measure_primitive,
    measure_transform, prim_cost_key, transform_cost_key,
)
from ..core.layouts import default_dt_graph, transform_feasible
from ..core.primitives import primitives_for
from ..core.scenario import Scenario
from ..serving.bucketing import BucketPolicy, bucket_scenario, bucket_shape
from .profile import HardwareProfile

__all__ = ["SweepItem", "scenario_grid", "scenarios_from_net",
           "plan_sweep", "run_sweep", "GRIDS"]


@dataclass(frozen=True)
class SweepItem:
    """One planned measurement: a profile key plus how to produce it."""

    kind: str    # "prim" | "dt" | "kernel"
    key: str     # HardwareProfile entry key
    label: str   # human-readable (family:name @ scenario)
    #: (reps, min_time) -> seconds; only called by run_sweep, so planning
    #: (and --dry-run) never allocates tensors or compiles anything
    measure: Callable[[int, float], float]


# ----------------------------------------------------------------------
# scenario grids
# ----------------------------------------------------------------------
#: named grids for the CLI; (channels, spatial sizes, ks, strides, m-mults)
GRIDS: Dict[str, Tuple[Sequence[int], Sequence[int], Sequence[int],
                       Sequence[int], Sequence[int]]] = {
    "tiny": ((8,), (16,), (3,), (1,), (2,)),
    "small": ((8, 16), (16, 32), (1, 3), (1,), (1, 2)),
    "default": ((8, 16, 32, 64), (16, 32, 64), (1, 3, 5), (1, 2), (1, 2)),
}


def scenario_grid(name: str = "default", *,
                  policy: Optional[BucketPolicy] = None,
                  batches: Sequence[int] = (1,)) -> List[Scenario]:
    """The named bucket grid (deduplicated, canonicalized).

    ``batches`` adds a minibatch axis: every spatial/channel bucket is
    emitted once per batch bucket, so one sweep can price both the
    latency (N=1) and throughput (N>1) serving paths — batched entries
    time the vmapped whole-batch invocation (see
    :func:`repro.core.costs.measure_primitive`).
    """
    try:
        channels, sizes, ks, strides, m_mults = GRIDS[name]
    except KeyError:
        raise ValueError(f"unknown grid {name!r}; one of {sorted(GRIDS)}")
    policy = policy or BucketPolicy()
    out, seen = [], set()
    for c in channels:
        for hw in sizes:
            for k in ks:
                for s in strides:
                    for mm in m_mults:
                        for n in batches:
                            scn = bucket_scenario(
                                Scenario(c=c, h=hw, w=hw, stride=s, k=k,
                                         m=c * mm, n=n), policy)
                            if scn.key() not in seen:
                                seen.add(scn.key())
                                out.append(scn)
    return out


def scenarios_from_net(net, *, policy: Optional[BucketPolicy] = None,
                       batches: Sequence[int] = (1,)) -> List[Scenario]:
    """The bucketed scenarios of one network's conv layers (one per
    batch bucket in ``batches``)."""
    policy = policy or BucketPolicy()
    out, seen = [], set()
    for node in net.conv_nodes():
        for n in batches:
            scn = bucket_scenario(node.scn.with_(n=n), policy)
            if scn.key() not in seen:
                seen.add(scn.key())
                out.append(scn)
    return out


# ----------------------------------------------------------------------
# planning
# ----------------------------------------------------------------------
def _kernel_benchmarks():
    """The six kernel packages' ``benchmark_entry`` hooks (lazy import)."""
    from ..kernels import (
        conv_direct, conv_im2col, flash_attention, layout_transform,
        matmul, winograd_gemm,
    )
    return [("conv_direct", conv_direct.benchmark_entry),
            ("conv_im2col", conv_im2col.benchmark_entry),
            ("winograd_gemm", winograd_gemm.benchmark_entry),
            ("matmul", matmul.benchmark_entry),
            ("flash_attention", flash_attention.benchmark_entry),
            ("layout_transform", layout_transform.benchmark_entry)]


#: layouts fused-pair measurements cover by default: the layouts
#: primitives natively produce/consume — the ones fused edges can
#: actually carry in a selected plan (sweeping all 7 would mostly time
#: pairs no optimum ever uses)
FUSE_SWEEP_LAYOUTS = ("CHW", "HWC", "HCW", "HWC8")


def plan_sweep(scenarios: Sequence[Scenario], *,
               families: Optional[Sequence[str]] = None,
               exclude_tags: Sequence[str] = ("tpu-only",),
               dt: bool = True, kernels: bool = False, fused: bool = True,
               fuse_layouts: Sequence[str] = FUSE_SWEEP_LAYOUTS,
               policy: Optional[BucketPolicy] = None) -> List[SweepItem]:
    """Enumerate the measurements a profile over ``scenarios`` needs.

    ``exclude_tags`` defaults to skipping ``tpu-only`` primitives — on
    CPU they run in Pallas interpret mode, whose timings price nothing
    real.  ``kernels`` adds the standalone kernel microbenchmarks (the
    CLI enables them on TPU, where the numbers are meaningful).

    ``fused`` plans one measurement per (primitive, fusable layout)
    pair — the whole fused invocation via
    :func:`~repro.core.costs.measure_fused_primitive`, keyed
    ``fuse{in,out}::…`` — so :class:`~repro.calibrate.model.
    CalibratedCostModel` can serve *measured* fused-edge deltas instead
    of the analytic discount.  Only single-image scenarios plan fused
    pairs (deltas are per image; the selection layer scales by batch).

    Batched scenarios (``scn.n > 1``) plan one *prim* measurement per
    (primitive, scenario, batch-bucket) — the key carries the batch via
    ``Scenario.key()``.  Layout-transform (*dt*) measurements stay
    per-image: transform cost is linear in the batch, so the selection
    layer scales the single-image number instead of re-measuring it at
    every N.
    """
    policy = policy or BucketPolicy()
    items: List[SweepItem] = []
    seen = set()

    def add(item: SweepItem) -> None:
        if item.key not in seen:
            seen.add(item.key)
            items.append(item)

    shapes = set()
    for raw in scenarios:
        scn = bucket_scenario(raw, policy)
        shapes.add(bucket_shape(scn.in_shape_chw, policy))
        shapes.add(bucket_shape(scn.out_shape_chw, policy))
        for p in primitives_for(scn, families=families,
                                exclude_tags=exclude_tags):
            add(SweepItem(
                "prim", prim_cost_key(p.name, scn),
                f"{p.family}:{p.name} @ {scn.key()}",
                lambda reps, min_time, p=p, scn=scn:
                    measure_primitive(p, scn, reps=reps,
                                      min_time=min_time)))
            if fused and scn.n == 1:
                for kind, caps, native, shape in (
                        ("in", p.fusable_in, p.l_in, scn.in_shape_chw),
                        ("out", p.fusable_out, p.l_out, scn.out_shape_chw)):
                    for lay in caps:
                        if lay == native or lay not in fuse_layouts:
                            continue
                        if not transform_feasible(lay, native, shape):
                            continue
                        kw = {"l_in": lay} if kind == "in" \
                            else {"l_out": lay}
                        add(SweepItem(
                            "fuse", fused_cost_key(kind, p.name, lay, scn),
                            f"fuse-{kind}:{p.name} {lay} @ {scn.key()}",
                            lambda reps, min_time, p=p, scn=scn, kw=kw:
                                measure_fused_primitive(
                                    p, scn, reps=reps, min_time=min_time,
                                    **kw)))
        if kernels:
            for kname, entry in _kernel_benchmarks():
                builder = entry(scn)
                if builder is None:
                    continue
                add(SweepItem(
                    "kernel", f"kernel::{kname}::{scn.key()}",
                    f"kernel:{kname} @ {scn.key()}",
                    lambda reps, min_time, b=builder:
                        _measure_kernel(b, reps, min_time)))

    if dt:
        for (s, t) in default_dt_graph().direct_edges:
            for shape in sorted(shapes):
                if not transform_feasible(s, t, shape):
                    continue
                add(SweepItem(
                    "dt", transform_cost_key(s, t, shape),
                    f"dt:{s}->{t} @ {'x'.join(map(str, shape))}",
                    lambda reps, min_time, s=s, t=t, shape=shape:
                        measure_transform(s, t, shape, reps=reps,
                                          min_time=min_time)))
    return items


def _measure_kernel(builder, reps: int, min_time: float) -> float:
    from ..core.costs import time_callable
    fn, args = builder()
    return time_callable(fn, args, reps=reps, min_time=min_time)


# ----------------------------------------------------------------------
# execution
# ----------------------------------------------------------------------
def run_sweep(profile: HardwareProfile, items: Sequence[SweepItem], *,
              reps: Optional[int] = None,
              min_time: Optional[float] = None,
              save_path=None, save_every: int = 20,
              max_entries: Optional[int] = None,
              progress: Optional[Callable[[int, int, SweepItem, float],
                                          None]] = None,
              measure: Optional[Callable[[SweepItem], float]] = None
              ) -> Dict[str, int]:
    """Measure every item the profile does not already hold.

    Resumable by construction: covered keys are skipped, and the profile
    is saved every ``save_every`` measurements (plus once at the end)
    when ``save_path`` is given.  ``measure`` overrides how an item is
    timed (tests inject a stub; the default calls ``item.measure`` with
    the profile's recorded reps/min_time discipline).
    Returns ``{"measured", "skipped", "remaining"}``.
    """
    reps = profile.reps if reps is None else reps
    min_time = profile.min_time if min_time is None else min_time
    todo = [it for it in items if it.key not in profile]
    skipped = len(items) - len(todo)
    capped = todo if max_entries is None else todo[:max_entries]
    measured = 0
    for i, item in enumerate(capped):
        t = (measure(item) if measure is not None
             else item.measure(reps, min_time))
        profile.put(item.key, t)
        measured += 1
        if progress is not None:
            progress(i, len(capped), item, t)
        if save_path is not None and measured % save_every == 0:
            profile.save(save_path)
    if save_path is not None and measured:
        profile.save(save_path)
    return {"measured": measured, "skipped": skipped,
            "remaining": len(todo) - measured}
