"""HardwareProfile: versioned per-device cost tables on disk.

A profile is the artifact of one calibration sweep (``python -m
repro.launch.calibrate``): a flat table mapping measurement keys to
seconds, stamped with everything needed to decide whether the numbers
are trustworthy *here and now*:

* ``device`` — fingerprint of the device the sweep ran on (platform,
  device kind, device count).  A profile measured on one device class
  must not silently price another.
* ``registry`` — hash of the primitive registry (names, families,
  layouts, tags) at calibration time.  Adding/renaming primitives does
  not invalidate existing measurements, but the mismatch is visible so
  the CLI can warn/re-sweep coverage.
* ``schema`` — bumped when the entry key format or units change.

Entry keys are exactly the :mod:`repro.core.costs` cache keys
(``prim::<name>::<scenario-key>`` and ``dt::<src>-><dst>::<CxHxW>``),
plus ``kernel::<name>::<scenario-key>`` for the standalone Pallas kernel
microbenchmarks — so a profile doubles as a readable record of what was
measured where.

:meth:`HardwareProfile.content_hash` digests the whole table; the
:class:`~repro.calibrate.model.CalibratedCostModel` folds it into
``CostModel.version()``, which is part of the serving plan-cache key —
recalibrating therefore invalidates every cached PBQP plan priced by the
old numbers (see docs/calibration.md).
"""
from __future__ import annotations

import datetime
import hashlib
import json
import pathlib
from dataclasses import dataclass, field
from typing import Dict, Iterable, Optional

from ..core.ioutil import atomic_write_text

__all__ = ["PROFILE_SCHEMA", "HardwareProfile", "device_fingerprint",
           "registry_hash"]

#: bump when the entry key format or the units of values change
PROFILE_SCHEMA = 1


def device_fingerprint() -> str:
    """Stable id of the device this process would measure on."""
    import jax
    d = jax.devices()[0]
    kind = str(getattr(d, "device_kind", d.platform)).replace(" ", "_")
    return f"{d.platform}:{kind}:n{jax.device_count()}"


def registry_hash() -> str:
    """Content hash of the primitive registry (coverage identity)."""
    from ..core.primitives import registry
    h = hashlib.sha256()
    for p in sorted(registry(), key=lambda p: p.name):
        h.update(f"{p.name}|{p.family}|{p.l_in}|{p.l_out}"
                 f"|{','.join(sorted(p.tags))}\n".encode())
    return h.hexdigest()[:16]


@dataclass
class HardwareProfile:
    """One device's measured cost table (see module docstring)."""

    device: str
    registry: str
    schema: int = PROFILE_SCHEMA
    created: str = ""
    #: measurement discipline the sweep used (recorded for reproduction)
    reps: int = 3
    min_time: float = 5e-3
    #: measurement key -> seconds
    entries: Dict[str, float] = field(default_factory=dict)

    # -----------------------------------------------------------------
    @classmethod
    def new(cls, *, reps: int = 3, min_time: float = 5e-3,
            device: Optional[str] = None) -> "HardwareProfile":
        """Fresh empty profile fingerprinting the current process."""
        return cls(device=device or device_fingerprint(),
                   registry=registry_hash(),
                   created=datetime.datetime.now(datetime.timezone.utc)
                   .isoformat(timespec="seconds"),
                   reps=reps, min_time=min_time)

    # -----------------------------------------------------------------
    def get(self, key: str) -> Optional[float]:
        return self.entries.get(key)

    def put(self, key: str, seconds: float) -> None:
        self.entries[key] = float(seconds)

    def __contains__(self, key: str) -> bool:
        return key in self.entries

    def __len__(self) -> int:
        return len(self.entries)

    def covered(self, keys: Iterable[str]) -> int:
        return sum(1 for k in keys if k in self.entries)

    # -----------------------------------------------------------------
    def content_hash(self) -> str:
        """Digest of everything that could change a served cost.

        Any new/changed measurement changes this hash, which changes
        ``CalibratedCostModel.version()``, which invalidates persisted
        PBQP plans priced by the old table.
        """
        h = hashlib.sha256()
        h.update(f"{self.schema}|{self.device}|{self.registry}".encode())
        for k in sorted(self.entries):
            h.update(f"{k}={self.entries[k]!r}\n".encode())
        return h.hexdigest()[:16]

    # -----------------------------------------------------------------
    def to_payload(self) -> Dict:
        return {
            "schema": self.schema,
            "device": self.device,
            "registry": self.registry,
            "created": self.created,
            "reps": self.reps,
            "min_time": self.min_time,
            "entries": dict(sorted(self.entries.items())),
        }

    @classmethod
    def from_payload(cls, payload: Dict) -> "HardwareProfile":
        if payload.get("schema") != PROFILE_SCHEMA:
            raise ValueError(
                f"profile schema {payload.get('schema')!r} != "
                f"{PROFILE_SCHEMA}; re-run the calibration sweep")
        return cls(device=str(payload["device"]),
                   registry=str(payload["registry"]),
                   schema=int(payload["schema"]),
                   created=str(payload.get("created", "")),
                   reps=int(payload.get("reps", 3)),
                   min_time=float(payload.get("min_time", 5e-3)),
                   entries={str(k): float(v)
                            for k, v in payload["entries"].items()})

    def save(self, path) -> None:
        """Atomic write with writer-unique tmp names, like every cache
        in this repo (``core.ioutil.atomic_write_text``)."""
        p = pathlib.Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        atomic_write_text(p, json.dumps(self.to_payload(), indent=1))

    @classmethod
    def load(cls, path) -> "HardwareProfile":
        return cls.from_payload(json.loads(pathlib.Path(path).read_text()))
