"""Reliability layer: chaos-tested serving for the PBQP serve path.

The paper guarantees a *valid* primitive/layout assignment when the
solver finishes; production serving (ROADMAP north star) also has to
survive the solver *not* finishing, the plan cache corrupting, kernels
crashing or emitting NaN, and workers dying.  This package holds the
four mechanisms, wired through :class:`~repro.serving.server.PlanServer`
and :class:`~repro.serving.scheduler.ContinuousScheduler`:

* :mod:`.faults`     — deterministic, seedable :class:`FaultInjector`
  over scheduled fault plans (sites: plan_cache, solve, compile,
  kernel, worker), generalizing ``train_loop``'s ``fault_hook``;
* :mod:`.fallback`   — the solve :class:`FallbackLadder` (exact ->
  anytime-under-deadline -> greedy -> reference jnp) plus the bounded
  jittered :func:`retry_call` used for compile retries;
* :mod:`.quarantine` — the per-(primitive, bucket)
  :class:`PrimitiveQuarantine` circuit breaker and the NaN-attribution
  walk :func:`diagnose_nonfinite`;
* :mod:`.errors`     — the typed failures (:class:`InjectedFault`,
  :class:`KernelFailure`, :class:`ShedError`).

docs/reliability.md is the narrative: fault taxonomy, ladder table,
quarantine lifecycle, shed semantics; benchmarks/bench_chaos.py is the
proof under a scheduled fault storm.
"""
from .errors import InjectedFault, KernelFailure, ShedError
from .fallback import (RUNGS, FallbackLadder, reference_selection,
                       retry_call)
from .faults import SITES, FaultInjector, FaultSpec, parse_fault_plan
from .quarantine import PrimitiveQuarantine, diagnose_nonfinite

__all__ = [
    "InjectedFault", "KernelFailure", "ShedError",
    "RUNGS", "FallbackLadder", "reference_selection", "retry_call",
    "SITES", "FaultInjector", "FaultSpec", "parse_fault_plan",
    "PrimitiveQuarantine", "diagnose_nonfinite",
]
