"""The solve-side fallback ladder: exact -> anytime -> greedy -> reference.

De Prado et al. (PAPERS.md) observe that search-based primitive
selection is only deployable with a fallback to known-good primitives;
this module is that ladder for the PBQP serve path.  Each rung trades
plan quality for availability and is strictly harder to break than the
one above it:

========== ===========================================================
rung       what runs
========== ===========================================================
exact      ``select_pbqp(exact=True)`` — the paper's optimum (possibly
           warm-started), finished within budget and deadline
anytime    the same solve, degraded: the wall-clock deadline or B&B
           budget expired and the RN heuristic completed the
           assignment best-so-far (``optimal=False``) — also the rung
           a server configured with ``exact=False`` always serves from
greedy     :func:`~repro.core.selection.select_local_optimal` — the
           paper's canonical-layout baseline; no branch-and-bound, no
           edge reasoning, millisecond-safe
reference  :func:`reference_selection` — hand-built plan on the
           textbook ``sum2d`` jnp primitive in CHW everywhere; no
           solver involvement at all, cannot fail as long as the net
           itself is well-formed
========== ===========================================================

Every demotion is counted in the metrics registry (``ladder_<rung>``
counters) and emitted as a trace event, so a fleet quietly serving
greedy plans is visible in ``tools/obs_report.py`` long before anyone
reads a log.  A :class:`~repro.reliability.faults.FaultInjector` can
fail the solve rung (kind ``raise``) or shrink its B&B budget (kind
``budget``) to force demotions deterministically.
"""
from __future__ import annotations

import random
import time
from typing import AbstractSet, Callable, Dict, Optional, Tuple

import numpy as np

from ..core.costs import CostModel
from ..core.graph import Net
from ..core.layouts import default_dt_graph
from ..core.selection import (Choice, SelectionResult,
                              select_local_optimal, select_pbqp)
from ..obs.trace import get_tracer
from .errors import InjectedFault
from .faults import FaultInjector

__all__ = ["RUNGS", "FallbackLadder", "reference_selection", "retry_call"]

#: ladder rungs, best to last-resort; counter names are ``ladder_<rung>``
RUNGS = ("exact", "anytime", "greedy", "reference")


class FallbackLadder:
    """Run a selection down the ladder until a rung holds.

    Parameters
    ----------
    cost:
        Cost model for every rung that prices anything.
    exact:
        Rung-0 solver mode (a ``False`` server never produces the
        ``exact`` rung — its solves classify as ``anytime``).
    deadline_s:
        Wall-clock allowance per solve; makes branch-and-bound anytime
        (None: no deadline, budget only).
    bb_budget:
        Branch-and-bound node budget for the solve rung.
    counters:
        Optional :class:`~repro.serving.metrics.ServingCounters`-style
        sink; each selection bumps ``ladder_<rung>``.
    fault_injector:
        Optional chaos hook (site ``solve``).
    """

    def __init__(self, cost: CostModel, *, exact: bool = True,
                 deadline_s: Optional[float] = None,
                 bb_budget: int = 200_000,
                 counters=None,
                 fault_injector: Optional[FaultInjector] = None) -> None:
        self.cost = cost
        self.exact = exact
        self.deadline_s = deadline_s
        self.bb_budget = int(bb_budget)
        self.counters = counters
        self.faults = fault_injector

    # -----------------------------------------------------------------
    def select(self, net: Net, *, bucket: str = "",
               warm_start: Optional[SelectionResult] = None,
               fuse: bool = False,
               mesh_axes: Optional[Dict[str, int]] = None,
               banned: Optional[AbstractSet[str]] = None
               ) -> Tuple[SelectionResult, str]:
        """Select a plan for ``net``, degrading as needed.

        Returns ``(selection, rung)``.  Never raises short of the
        reference rung itself failing (a malformed net).
        """
        budget = self.bb_budget
        fail_solve = False
        if self.faults is not None:
            spec = self.faults.check("solve", key=bucket)
            if spec is not None:
                if spec.kind == "budget":
                    budget = max(0, int(spec.value))
                else:
                    fail_solve = True
        sel: Optional[SelectionResult] = None
        rung = "reference"
        try:
            if fail_solve:
                raise InjectedFault("solve", "raise", bucket)
            sel = select_pbqp(net, self.cost, exact=self.exact,
                              warm_start=warm_start, fuse=fuse,
                              mesh_axes=mesh_axes, banned=banned,
                              deadline_s=self.deadline_s,
                              bb_budget=budget)
            rung = "exact" if sel.optimal else "anytime"
        except Exception:
            try:
                sel = select_local_optimal(net, self.cost, banned=banned)
                rung = "greedy"
            except Exception:
                sel = reference_selection(net, self.cost)
                rung = "reference"
        if self.counters is not None:
            self.counters.add(**{f"ladder_{rung}": 1})
        if rung != "exact":
            # demotions are span *events*: cheap, always-on, and they
            # surface in trace summaries next to the solve spans
            now = time.perf_counter()
            get_tracer().emit("ladder_demotion", now, now,
                              rung=rung, bucket=bucket)
        return sel, rung


# ----------------------------------------------------------------------
def reference_selection(net: Net,
                        cost: Optional[CostModel] = None
                        ) -> SelectionResult:
    """Solver-free last-resort plan: ``sum2d`` in CHW, everywhere.

    Builds the assignment by hand — the textbook jnp reference
    primitive for every conv node, CHW layouts wherever the op allows
    them — and legalizes the few mismatched edges over the default DT
    graph.  No PBQP instance, no reductions, no cost-model pricing on
    the critical path (``cost`` only prices ``predicted_cost`` for
    observability; any pricing failure degrades to a nominal constant,
    never an exception).
    """
    from ..core.primitives import registry
    ref = next(p for p in registry() if p.name == "sum2d")
    choices: Dict[str, Choice] = {}
    for nid in net.order:
        node = net.nodes[nid]
        if node.kind == "conv":
            choices[nid] = Choice(ref, ref.l_in, ref.l_out)
        elif node.kind == "input":
            choices[nid] = Choice(None, "CHW", "CHW")
        else:
            lay = "CHW" if "CHW" in node.op.layouts else node.op.layouts[0]
            choices[nid] = Choice(None, lay, lay)

    try:
        dt = cost.dt_graph() if cost is not None else default_dt_graph()
    except Exception:
        dt = default_dt_graph()
    conversions: Dict[Tuple[str, str], list] = {}
    for (src, dst) in net.edges():
        lo, li = choices[src].l_out, choices[dst].l_in
        if lo == li:
            continue
        shape = net.nodes[src].out_shape
        chain = dt.shortest_chain(lo, li, shape)
        if chain is None:
            raise RuntimeError(
                f"reference plan: no DT path {lo}->{li} on edge "
                f"{src}->{dst}")
        conversions[(src, dst)] = list(chain)

    predicted = 1e-3
    if cost is not None:
        try:
            nb = max((n.scn.n for n in net.conv_nodes()), default=1)
            total = sum(float(cost.primitive_cost(ref, n.scn))
                        for n in net.conv_nodes())
            for (src, dst), chain in conversions.items():
                shape = net.nodes[src].out_shape
                total += nb * sum(
                    float(cost.transform_cost(a, b, shape, "float32"))
                    for a, b in zip(chain, chain[1:]))
            if np.isfinite(total) and total > 0:
                predicted = total
        except Exception:
            pass
    return SelectionResult(net=net, choices=choices,
                           conversions=conversions,
                           predicted_cost=predicted, optimal=False,
                           strategy="reference", solver_stats={})


# ----------------------------------------------------------------------
def retry_call(fn: Callable, *, retries: int, base_delay_s: float,
               rng: Optional[random.Random] = None,
               on_retry: Optional[Callable[[int, BaseException],
                                           None]] = None):
    """Bounded retry with jittered exponential backoff.

    Runs ``fn()`` up to ``1 + retries`` times.  Attempt ``k`` (1-based)
    sleeps ``base_delay_s * 2**(k-1) * U[1, 2)`` first — the jitter is
    drawn from ``rng`` (seeded by the caller) so chaos runs replay
    deterministically.  ``on_retry(attempt, exc)`` fires before each
    sleep; the final failure re-raises.
    """
    if retries < 0:
        raise ValueError("retries must be >= 0")
    rng = rng or random.Random()
    attempt = 0
    while True:
        try:
            return fn()
        except Exception as exc:
            attempt += 1
            if attempt > retries:
                raise
            if on_retry is not None:
                on_retry(attempt, exc)
            time.sleep(base_delay_s * (2 ** (attempt - 1))
                       * (1.0 + rng.random()))
