"""Deterministic, seedable fault injection for the serve path.

Generalizes the ``fault_hook(step)`` escape hatch of
:func:`repro.runtime.train_loop.train` into a *fault plan*: a list of
:class:`FaultSpec` entries, each naming an instrumented **site** on the
solve -> compile -> serve path, a fault **kind**, and a deterministic
window of invocations in which it fires.  The injector is plugged into
:class:`~repro.serving.server.PlanServer` /
:class:`~repro.serving.scheduler.ContinuousScheduler` (and threaded to
the disk cache and fallback ladder); with no injector armed every hook
is a single ``is None`` check.

Sites (see docs/reliability.md for the taxonomy):

========== ==============================================================
site       instrumented where / meaningful kinds
========== ==============================================================
plan_cache ``PlanDiskCache.get``: ``corrupt`` truncates the cache file
           on disk mid-read (exercising the corrupt-entry recovery path)
solve      fallback-ladder solves: ``raise`` fails the PBQP rung,
           ``budget`` overrides the B&B node budget to ``value``
compile    ``PlanServer.compiled_for``: ``raise`` fails the XLA
           compile attempt (retry / ladder territory)
kernel     guarded execution: ``raise`` crashes the executable call,
           ``nan`` poisons its outputs (circuit-breaker territory);
           ``match`` names the primitive to blame
worker     ``ContinuousScheduler._run_batch``: ``raise`` kills the
           worker slot mid-dispatch (the group is re-queued)
========== ==============================================================

Determinism: every site keeps a monotonically increasing invocation
counter and a spec fires on counter values in ``[start, start+count)``
(``count=0``: no upper edge), optionally thinned by probability ``p``
drawn from one seeded :class:`random.Random`.  Replaying the same
workload against the same plan and seed fires the same faults — the
chaos benchmark's output-equivalence gate depends on that.
"""
from __future__ import annotations

import json
import pathlib
import random
import threading
from dataclasses import dataclass, field
from typing import Iterable, List, Optional, Sequence, Tuple

from .errors import InjectedFault

__all__ = ["FaultSpec", "FaultInjector", "parse_fault_plan", "SITES"]

#: instrumented fault sites, in serve-path order
SITES = ("plan_cache", "solve", "compile", "kernel", "worker")


@dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault: where, what, and when."""

    site: str            # one of SITES
    kind: str = "raise"  # raise | nan | corrupt | budget | delay
    start: int = 0       # first site-invocation index that fires
    count: int = 1       # window length in invocations (0 = unbounded)
    p: float = 1.0       # fire probability inside the window
    match: str = ""      # substring filter on the site key (e.g. a
    #                      primitive name or bucket key); "" matches all
    value: float = 0.0   # kind parameter: budget override, delay seconds

    def __post_init__(self) -> None:
        if self.site not in SITES:
            raise ValueError(f"unknown fault site {self.site!r}; "
                             f"expected one of {SITES}")
        if not 0.0 <= self.p <= 1.0:
            raise ValueError(f"fault probability {self.p} outside [0, 1]")
        if self.start < 0 or self.count < 0:
            raise ValueError("fault window must be non-negative")


class FaultInjector:
    """Seeded, thread-safe fault scheduler over a plan of specs.

    ``check(site, key)`` advances the site's invocation clock and
    returns the first spec whose window covers this invocation (or
    None).  Callers interpret the spec at the site: raise, poison
    outputs, corrupt a file, shrink a budget.  ``raise_if`` is the
    convenience for pure raise/delay sites.

    ``fired`` logs every fault that actually fired as
    ``(site, kind, invocation, key)`` — the chaos benchmark uses it to
    time recovery windows.
    """

    def __init__(self, plan: Iterable[FaultSpec] = (), seed: int = 0) -> None:
        self.plan: Tuple[FaultSpec, ...] = tuple(plan)
        self._rng = random.Random(seed)
        self._tick = {}
        self._lock = threading.Lock()
        self.fired: List[Tuple[str, str, int, str]] = []

    def check(self, site: str, key: str = "") -> Optional[FaultSpec]:
        with self._lock:
            t = self._tick.get(site, 0)
            self._tick[site] = t + 1
            for spec in self.plan:
                if spec.site != site:
                    continue
                if spec.match and spec.match not in key:
                    continue
                if t < spec.start:
                    continue
                if spec.count and t >= spec.start + spec.count:
                    continue
                if spec.p < 1.0 and self._rng.random() >= spec.p:
                    continue
                self.fired.append((site, spec.kind, t, key))
                return spec
        return None

    def raise_if(self, site: str, key: str = "") -> None:
        """Fire the site's scheduled fault as an exception (if any)."""
        spec = self.check(site, key)
        if spec is not None:
            if spec.kind == "delay":
                import time
                time.sleep(spec.value)
                return
            raise InjectedFault(site, spec.kind, key)

    def ticks(self, site: str) -> int:
        """How many times the site's clock has advanced (diagnostics)."""
        with self._lock:
            return self._tick.get(site, 0)

    def __repr__(self) -> str:  # pragma: no cover
        return f"FaultInjector({len(self.plan)} specs, " \
               f"{len(self.fired)} fired)"


def parse_fault_plan(text: str) -> List[FaultSpec]:
    """Parse a fault plan from a JSON file path or an inline spec string.

    If ``text`` names an existing file it must hold a JSON list of
    :class:`FaultSpec` field dicts.  Otherwise it is the inline DSL the
    serve CLI's ``--fault-plan`` accepts: comma-separated entries of
    the form ``site:kind[@start[+count]][~match][=value]`` — e.g.
    ``kernel:nan@5+3~winograd_2,compile:raise@0+2`` schedules NaN
    poisoning of winograd_2 kernels on guarded executions 5-7 and
    compile failures on the first two compile attempts.
    """
    path = pathlib.Path(text)
    if path.exists() and path.is_file():
        specs = json.loads(path.read_text())
        if not isinstance(specs, list):
            raise ValueError(f"fault plan {text}: expected a JSON list")
        return [FaultSpec(**d) for d in specs]
    out: List[FaultSpec] = []
    for entry in filter(None, (s.strip() for s in text.split(","))):
        head, value = entry.split("=", 1) if "=" in entry else (entry, "0")
        head, match = head.split("~", 1) if "~" in head else (head, "")
        head, window = head.split("@", 1) if "@" in head else (head, "0+1")
        if ":" not in head:
            raise ValueError(f"fault entry {entry!r}: expected site:kind")
        site, kind = head.split(":", 1)
        start_s, count_s = window.split("+", 1) if "+" in window \
            else (window, "1")
        out.append(FaultSpec(site=site.strip(), kind=kind.strip(),
                             start=int(start_s), count=int(count_s),
                             match=match.strip(), value=float(value)))
    if not out:
        raise ValueError(f"empty fault plan {text!r}")
    return out
