"""Typed errors of the reliability layer.

Every degradation the serve path can take has a distinct exception
type, so callers (and tests) can tell an injected chaos fault from a
real kernel failure from an admission-control rejection without string
matching.  docs/reliability.md maps each to its recovery path.
"""
from __future__ import annotations

__all__ = ["InjectedFault", "KernelFailure", "ShedError"]


class InjectedFault(RuntimeError):
    """A fault fired by :class:`~repro.reliability.faults.FaultInjector`.

    Never raised in production — only when a fault plan is armed.  The
    serve path treats it exactly like the real failure it simulates
    (that equivalence is the point of chaos testing).
    """

    def __init__(self, site: str, kind: str, key: str = "") -> None:
        super().__init__(f"injected {kind} fault at {site}"
                         + (f" ({key})" if key else ""))
        self.site = site
        self.kind = kind
        self.key = key


class KernelFailure(RuntimeError):
    """A compiled kernel crashed or produced non-finite outputs, and the
    retry-after-quarantine budget is spent.

    Carries the blamed primitive (``primitive`` may be None when the
    failure could not be attributed to a single kernel) and the bucket
    the executable was compiled for.
    """

    def __init__(self, bucket: str, primitive=None, detail: str = "") -> None:
        super().__init__(
            f"kernel failure in bucket {bucket}"
            + (f" (primitive {primitive})" if primitive else "")
            + (f": {detail}" if detail else ""))
        self.bucket = bucket
        self.primitive = primitive


class ShedError(RuntimeError):
    """Admission control rejected a request: the modeled backlog says its
    deadline cannot be met, so serving it would only burn capacity on a
    guaranteed SLO miss.

    ``eta_s`` is the modeled completion delay the scheduler projected;
    ``slack_s`` the time the deadline actually allowed.
    """

    def __init__(self, eta_s: float, slack_s: float) -> None:
        super().__init__(
            f"request shed at admission: modeled completion in "
            f"{eta_s * 1e3:.1f}ms exceeds the {slack_s * 1e3:.1f}ms "
            f"deadline slack")
        self.eta_s = float(eta_s)
        self.slack_s = float(slack_s)
