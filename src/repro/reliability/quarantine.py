"""Per-(primitive, bucket) circuit breaker for crashing/NaN kernels.

A compiled kernel that crashes or emits non-finite values must not be
re-selected by the very solve that made it optimal — the cost model
knows speed, not health.  :class:`PrimitiveQuarantine` tracks failures
per (primitive name, bucket key); at ``threshold`` failures the pair
trips into quarantine, after which

* the primitive is **priced infinite** in that bucket's choice space
  (``select_pbqp(..., banned=quarantine.banned_for(bucket))`` — see
  :func:`repro.core.selection._conv_domain`), and
* the bucket's **cache keys rotate**: :meth:`version_token` folds the
  active quarantine set into the cost-version string every plan-cache
  tier keys on, so the poisoned plan evicts exactly like a stale
  cost model does in the drift workflow (PR 6's rotation mechanism,
  reused).  Releasing the quarantine rotates back — if the set returns
  to empty the token is ``""`` and the original on-disk plan becomes a
  cache *hit* again, which is the recovery path the chaos benchmark
  demonstrates end to end.

The breaker holds no references into the server; the server drives it
(record failure -> evict its in-memory tiers -> warm-start re-solve).

:func:`diagnose_nonfinite` is the attribution tool for *real* NaN
failures: a per-node re-execution of the compiled plan's own makers
(the walk :class:`repro.obs.drift.InstrumentedNet` rebuilds, minus the
timing) that returns the first conv primitive producing non-finite
output from finite input.
"""
from __future__ import annotations

import hashlib
import threading
from typing import Dict, FrozenSet, List, Optional, Tuple

import numpy as np

__all__ = ["PrimitiveQuarantine", "diagnose_nonfinite"]


class PrimitiveQuarantine:
    """Thread-safe circuit-breaker state: failures, trips, releases."""

    def __init__(self, threshold: int = 1) -> None:
        if threshold < 1:
            raise ValueError("threshold must be >= 1")
        self.threshold = int(threshold)
        self._failures: Dict[Tuple[str, str], int] = {}
        #: (primitive, bucket) -> epoch at which the breaker tripped
        self._active: Dict[Tuple[str, str], int] = {}
        self._epoch = 0
        self._lock = threading.Lock()

    # -----------------------------------------------------------------
    def record_failure(self, primitive: str, bucket: str) -> bool:
        """Count one failure; True if this call trips the breaker."""
        with self._lock:
            k = (primitive, bucket)
            n = self._failures.get(k, 0) + 1
            self._failures[k] = n
            if n >= self.threshold and k not in self._active:
                self._epoch += 1
                self._active[k] = self._epoch
                return True
            return False

    def release(self, primitive: str, bucket: str) -> bool:
        """Half-open the breaker: allow the primitive again.

        Clears the failure count too, so the next failure must re-earn
        the threshold.  Returns True if a quarantine was actually
        lifted (the bucket's cache keys rotate again as a result).
        """
        with self._lock:
            k = (primitive, bucket)
            self._failures.pop(k, None)
            return self._active.pop(k, None) is not None

    # -----------------------------------------------------------------
    def is_quarantined(self, primitive: str, bucket: str) -> bool:
        with self._lock:
            return (primitive, bucket) in self._active

    def banned_for(self, bucket: str) -> FrozenSet[str]:
        """Primitive names quarantined in this bucket (solver ban set)."""
        with self._lock:
            return frozenset(p for (p, b) in self._active if b == bucket)

    def active(self) -> List[Tuple[str, str]]:
        """All (primitive, bucket) pairs currently quarantined."""
        with self._lock:
            return sorted(self._active)

    def version_token(self, bucket: str) -> str:
        """Cache-key suffix for this bucket's plan keys.

        Deterministic digest of the bucket's active quarantine entries
        (primitive + trip epoch).  Empty when nothing is quarantined —
        so a fully-recovered bucket keys back onto its original plans.
        """
        with self._lock:
            items = sorted((p, e) for (p, b), e in self._active.items()
                           if b == bucket)
        if not items:
            return ""
        digest = hashlib.sha256(repr(items).encode()).hexdigest()[:8]
        return f"+quar={digest}"


# ----------------------------------------------------------------------
def diagnose_nonfinite(cnet, x) -> Optional[str]:
    """Blame the first kernel producing non-finite output from finite input.

    Re-executes the compiled plan node by node with its own makers
    (conversion chains materialized between), checking every conv
    node's output for NaN/Inf.  Returns that node's primitive name, or
    None when the failure cannot be pinned on a single conv kernel
    (non-finite *input*, an op node, or a plan compiled without makers
    / with a mesh — attribution needs the single-device walk).
    """
    import jax
    import jax.numpy as jnp

    from ..core.primitives import convert_layout

    if cnet.mesh is not None or not cnet.makers:
        return None
    sel, batch, params = cnet.sel, cnet.batch, cnet.params
    net = sel.net
    x = jnp.asarray(x)
    if not bool(jnp.isfinite(x).all()):
        return None

    def vm(fn, n_in: int = 1, with_params: bool = False):
        if batch == 1:
            return fn
        axes = (0,) * n_in + ((None,) if with_params else ())
        return jax.vmap(fn, in_axes=axes)

    vals = {}
    cur = None
    try:
        for nid in net.order:
            cur = nid
            node = net.nodes[nid]
            if node.kind == "input":
                vals[nid] = x
                continue
            ins = []
            for src in node.inputs:
                v = vals[src]
                chain = sel.conversions.get((src, nid))
                if chain:
                    for a, b in zip(chain, chain[1:]):
                        v = vm(lambda t, a=a, b=b:
                               convert_layout(t, a, b))(v)
                ins.append(v)
            if node.kind == "conv":
                out = vm(cnet.makers[nid], with_params=True)(
                    ins[0], params[nid])
                if not bool(jnp.isfinite(out).all()):
                    return sel.choices[nid].primitive.name \
                        if sel.choices[nid].primitive else None
            else:
                from ..core.layouts import LAYOUT_BY_NAME
                layout = LAYOUT_BY_NAME[sel.choices[nid].l_in]
                p = params.get(nid)
                out = vm(lambda *vs, op=node.op, lay=layout, p=p:
                         op.fn(list(vs), lay, p), len(node.inputs))(*ins)
                if not bool(jnp.isfinite(jnp.asarray(out)).all()):
                    return None  # an op node went bad: not a kernel
            vals[nid] = out
    except Exception:
        # the walk itself crashed: blame the node being executed, if it
        # was a conv kernel
        node = net.nodes.get(cur) if cur is not None else None
        if node is not None and node.kind == "conv":
            ch = sel.choices[cur]
            return ch.primitive.name if ch.primitive else None
        return None
    return None
