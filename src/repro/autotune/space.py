"""Tunable parameter spaces: the shared vocabulary of the autotuner.

Each of the six Pallas kernel packages declares its sweepable block/
tile/unroll axes and a validity predicate in its own ``space.py`` (see
e.g. :mod:`repro.kernels.conv_im2col.space`) as a
:class:`TunableSpace`.  Spaces come in two kinds:

* **registering** spaces (``make_primitive`` set) — each valid
  configuration becomes a first-class :class:`~repro.core.primitives.
  Primitive` in the ``pallas`` family, inheriting the hand-written
  entry's layouts and ``fusable_in/fusable_out``, so PBQP selects among
  generated variants exactly like hand-written kernels.

* **kernel-only** spaces (``benchmark``/``analytic`` set) — the kernel
  is not a convolution primitive (flash attention, layout transforms);
  its winning configurations are recorded in the variant catalog as
  ``kernel::`` entries for the ops layer, not registered with PBQP.

This module deliberately imports nothing from :mod:`repro.kernels` —
the kernel packages import *it*, and :mod:`repro.autotune.generate`
collects their ``SPACE`` objects lazily, so there is no import cycle.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

__all__ = ["TunableSpace", "variant_suffix", "variant_name",
           "params_tuple"]


def variant_suffix(params: Dict[str, int],
                   order: Tuple[str, ...]) -> str:
    """Deterministic ``bm64_bn128_bk32``-style suffix (axis order)."""
    return "_".join(f"{a}{params[a]}" for a in order if a in params)


def variant_name(base: str, params: Dict[str, int],
                 order: Tuple[str, ...]) -> str:
    """Registry name of one generated variant: ``<base>@<suffix>``."""
    return f"{base}@{variant_suffix(params, order)}"


def params_tuple(params: Dict[str, int],
                 order: Tuple[str, ...]) -> Tuple[Tuple[str, int], ...]:
    """Hashable ``Primitive.params`` form, in axis order."""
    return tuple((a, int(params[a])) for a in order if a in params)


@dataclass(frozen=True)
class TunableSpace:
    """One kernel package's sweepable configuration space."""

    #: kernel package name (``conv_im2col``, ``flash_attention``, ...)
    kernel: str
    #: ordered (axis name, candidate values); order fixes variant names
    axes: Tuple[Tuple[str, Tuple[int, ...]], ...]
    #: static validity: VMEM fit of the tile working set, MXU alignment
    #: — anything decidable from the parameters alone.  Per-scenario
    #: applicability lives in the generated primitive's ``supports``.
    valid: Callable[[Dict[str, int]], bool]
    #: registering spaces: params -> Primitive (None for kernel-only)
    make_primitive: Optional[Callable] = None
    #: kernel-only spaces: (scn, params) -> zero-arg builder -> (fn,
    #: args), or None when the scenario does not apply
    benchmark: Optional[Callable] = None
    #: kernel-only spaces: (scn, params, HardwareSpec) -> seconds
    analytic: Optional[Callable] = None

    @property
    def registers(self) -> bool:
        return self.make_primitive is not None

    @property
    def axis_order(self) -> Tuple[str, ...]:
        return tuple(a for a, _ in self.axes)

    def configs(self) -> List[Dict[str, int]]:
        """Every valid configuration, in deterministic axis order."""
        names = [a for a, _ in self.axes]
        out = []
        for combo in itertools.product(*(vs for _, vs in self.axes)):
            params = dict(zip(names, combo))
            if self.valid(params):
                out.append(params)
        return out

    def name_for(self, base: str, params: Dict[str, int]) -> str:
        return variant_name(base, params, self.axis_order)
