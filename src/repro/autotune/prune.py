"""Pareto dominance pruning over the bucket sweep.

A generated variant earns a registry slot only if there is at least one
scenario bucket where nothing else (hand-written entry or sibling
variant) is at least as good everywhere and better somewhere.  Pruning
is restricted to *groups* of candidates with identical PBQP-visible
structure — same layouts, same fusable sets, same support over the
sweep buckets — so removing a dominated candidate only removes
node-cost columns that another candidate weakly improves on: the PBQP
optimum provably never needs the pruned variant (the property tests in
tests/test_autotune.py check exactly this).

The rule is deterministic and order-free: candidate ``v`` is pruned iff
some candidate ``u`` in the same group covers ``v``'s buckets with
``cost_u <= cost_v`` everywhere, and either is strictly better
somewhere or ties everywhere and wins the name tiebreak (hand-written
entries always win ties).  Measurement order cannot change the result —
only the (key -> cost) table matters.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Hashable, List, Optional, Sequence, Tuple

from ..core.primitives import Primitive
from ..core.scenario import Scenario

__all__ = ["Candidate", "candidates_from_costs", "prune_dominated",
           "group_key"]


def group_key(prim: Primitive,
              support: Tuple[str, ...]) -> Hashable:
    """Candidates are comparable only within identical PBQP structure."""
    return (prim.family, prim.l_in, prim.l_out,
            tuple(prim.fusable_in), tuple(prim.fusable_out), support)


@dataclass(frozen=True)
class Candidate:
    """One entrant in the dominance tournament."""

    name: str
    #: hand-written entries compete but are never pruned
    prunable: bool
    group: Hashable
    #: bucket key -> measured/priced seconds; missing = unsupported
    costs: Tuple[Tuple[str, float], ...]

    def cost_map(self) -> Dict[str, float]:
        return dict(self.costs)


def candidates_from_costs(prims: Sequence[Primitive],
                          buckets: Sequence[Scenario],
                          cost_of) -> List[Candidate]:
    """Build candidates from a cost lookup ``(prim, scn) -> float|None``
    (typically a tuned :class:`~repro.calibrate.profile.HardwareProfile`
    read through ``prim_cost_key``)."""
    out = []
    for p in prims:
        costs = []
        support = []
        for scn in buckets:
            if not p.supports(scn):
                continue
            support.append(scn.key())
            c = cost_of(p, scn)
            if c is not None and c == c and c != float("inf"):
                costs.append((scn.key(), float(c)))
        out.append(Candidate(name=p.name, prunable=bool(p.params),
                             group=group_key(p, tuple(support)),
                             costs=tuple(sorted(costs))))
    return out


def prune_dominated(cands: Sequence[Candidate]
                    ) -> Tuple[List[str], Dict[str, str]]:
    """Returns ``(survivor names, pruned name -> dominating name)``.

    Order-free: every candidate is compared against every other in its
    group; dominance (with the deterministic tiebreak) is transitive,
    so a candidate pruned by another pruned candidate is still covered
    by some survivor.
    """
    by_group: Dict[Hashable, List[Candidate]] = {}
    for c in sorted(cands, key=lambda c: c.name):
        by_group.setdefault(c.group, []).append(c)

    survivors: List[str] = []
    pruned: Dict[str, str] = {}
    for group in by_group.values():
        for v in group:
            if not v.prunable:
                survivors.append(v.name)
                continue
            vc = v.cost_map()
            dominator: Optional[str] = None
            for u in group:
                if u.name == v.name:
                    continue
                uc = u.cost_map()
                if not vc or not set(vc) <= set(uc):
                    continue
                if any(uc[b] > vc[b] for b in vc):
                    continue
                strict = any(uc[b] < vc[b] for b in vc)
                tie_win = (not strict
                           and (not u.prunable or u.name < v.name))
                if strict or tie_win:
                    dominator = u.name
                    break
            if dominator is None:
                survivors.append(v.name)
            else:
                pruned[v.name] = dominator
    return sorted(survivors), pruned
