"""Tuning sweep: measure candidate variants through the calibrate
machinery.

The tuner reuses the calibration subsystem wholesale: measurements are
:class:`~repro.calibrate.sweep.SweepItem`\\ s executed by
:func:`~repro.calibrate.sweep.run_sweep` against a resumable
:class:`~repro.calibrate.profile.HardwareProfile`, keyed with the same
``prim::<name>::<bucket>`` keys the :class:`~repro.calibrate.model.
CalibratedCostModel` serves — so a tuning profile *is* a calibration
profile covering the generated variants.

On real TPU hardware items time the kernels (``measure_primitive`` /
the space's benchmark builder).  On CPU the Pallas kernels only run in
interpret mode, whose timings price nothing real — there
:func:`analytic_measurer` injects the tile-aware analytic TPU model
through ``run_sweep(measure=...)``, which keeps the whole pipeline
(resume, budget caps, pruning, catalog) deterministic and exercisable
anywhere.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax

from ..calibrate.sweep import SweepItem
from ..core.costs import (
    AnalyticCostModel, HardwareSpec, TPU_V5E_SPEC, measure_primitive,
    prim_cost_key, time_callable,
)
from ..core.primitives import Primitive, registry
from ..core.scenario import Scenario
from ..serving.bucketing import BucketPolicy, bucket_scenario
from .space import TunableSpace, variant_suffix

__all__ = ["plan_tune_sweep", "analytic_measurer", "kernel_variant_key",
           "default_measure_mode"]


def kernel_variant_key(space: TunableSpace, params: Dict[str, int],
                       scn: Scenario) -> str:
    """Profile key of one kernel-only variant measurement."""
    suffix = variant_suffix(params, space.axis_order)
    return f"kernel::{space.kernel}@{suffix}::{scn.key()}"


def default_measure_mode() -> str:
    """``real`` on TPU, ``analytic`` everywhere else."""
    return "real" if jax.devices()[0].platform == "tpu" else "analytic"


def plan_tune_sweep(variants: Sequence[Primitive],
                    scenarios: Sequence[Scenario], *,
                    kernel_only: Sequence[Tuple[TunableSpace,
                                                List[Dict[str, int]]]] = (),
                    include_base: bool = True,
                    policy: Optional[BucketPolicy] = None):
    """Enumerate the tuning measurements.

    Returns ``(items, index)``: the :class:`SweepItem` list for
    ``run_sweep`` plus an index ``key -> ("prim", prim, scn) |
    ("kernel", space, params, scn)`` that the analytic measurer and the
    dominance pruner use to interpret profile entries.

    ``include_base`` adds the hand-written ``pallas``-family entries as
    competitors: a variant that never beats its hand-written cousin on
    any bucket is dominated and pruned, keeping the catalog tight.
    """
    policy = policy or BucketPolicy()
    buckets: List[Scenario] = []
    seen = set()
    for raw in scenarios:
        scn = bucket_scenario(raw, policy)
        if scn.key() not in seen:
            seen.add(scn.key())
            buckets.append(scn)

    pool: List[Primitive] = list(variants)
    if include_base:
        vnames = {p.name for p in variants}
        pool += [p for p in registry()
                 if p.family == "pallas" and not p.params
                 and p.name not in vnames]

    items: List[SweepItem] = []
    index: Dict[str, tuple] = {}

    def add(item: SweepItem, entry: tuple) -> None:
        if item.key not in index:
            index[item.key] = entry
            items.append(item)

    for p in pool:
        for scn in buckets:
            if not p.supports(scn):
                continue
            add(SweepItem(
                "prim", prim_cost_key(p.name, scn),
                f"{p.family}:{p.name} @ {scn.key()}",
                lambda reps, min_time, p=p, scn=scn:
                    measure_primitive(p, scn, reps=reps,
                                      min_time=min_time)),
                ("prim", p, scn))

    for space, cfgs in kernel_only:
        for params in cfgs:
            for scn in buckets:
                builder = space.benchmark(scn, params) \
                    if space.benchmark else None
                if builder is None:
                    continue
                add(SweepItem(
                    "kernel", kernel_variant_key(space, params, scn),
                    f"kernel:{space.kernel}"
                    f"@{variant_suffix(params, space.axis_order)}"
                    f" @ {scn.key()}",
                    lambda reps, min_time, b=builder:
                        _measure_builder(b, reps, min_time)),
                    ("kernel", space, params, scn))
    return items, index


def _measure_builder(builder, reps: int, min_time: float) -> float:
    fn, args = builder()
    return time_callable(fn, args, reps=reps, min_time=min_time)


def analytic_measurer(index: Dict[str, tuple],
                      spec: HardwareSpec = TPU_V5E_SPEC
                      ) -> Callable[[SweepItem], float]:
    """``run_sweep(measure=...)`` override pricing items analytically.

    Uses the tile-aware :class:`AnalyticCostModel` (padding waste, MXU
    alignment, grid-step dispatch — see ``core.costs``), so different
    block configurations price deterministically differently and the
    dominance structure is real even without TPU hardware.
    """
    cm = AnalyticCostModel(spec, include_tpu_only=True)

    def measure(item: SweepItem) -> float:
        entry = index[item.key]
        if entry[0] == "prim":
            _, prim, scn = entry
            return cm.primitive_cost(prim, scn)
        _, space, params, scn = entry
        return space.analytic(scn, params, spec)

    return measure
