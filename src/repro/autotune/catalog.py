"""VariantCatalog: the versioned artifact of one tuning run.

A catalog records, per generated variant: its kernel package, its
parameters, its per-bucket costs, and whether dominance pruning kept
it.  Like a :class:`~repro.calibrate.profile.HardwareProfile` it is
stamped with the device fingerprint and the *base* registry hash (the
hand-written library it extends), and exposes a ``content_hash`` —
``install()`` passes that hash as the registry extension token, which
``CostModel.version()`` folds into every serving plan-cache key, so
cached plans invalidate whenever the variant set changes.

Kernel-only spaces (flash attention, layout transforms) contribute
``kernels`` entries: the winning parameters per bucket, for the ops
layer to consult — they are not registered with PBQP.
"""
from __future__ import annotations

import datetime
import hashlib
import json
import pathlib
from dataclasses import dataclass, field
from typing import Dict, List

from ..core.ioutil import atomic_write_text
from ..core.primitives import (
    Primitive, build_registry, register_extension, unregister_extension,
)
from .generate import spaces

__all__ = ["CATALOG_SCHEMA", "VariantCatalog", "base_registry_hash",
           "EXTENSION_NAME"]

#: bump when the payload layout or the meaning of entries changes
CATALOG_SCHEMA = 1

#: registry extension slot catalogs install into
EXTENSION_NAME = "autotune"


def base_registry_hash() -> str:
    """Hash of the hand-written registry (without extensions) — the
    library a catalog's variants were tuned against."""
    h = hashlib.sha256()
    for p in sorted(build_registry(), key=lambda p: p.name):
        h.update(f"{p.name}|{p.family}|{p.l_in}|{p.l_out}"
                 f"|{','.join(sorted(p.tags))}\n".encode())
    return h.hexdigest()[:16]


@dataclass
class VariantCatalog:
    """Winners (and pruned losers, for the record) of one tuning run."""

    device: str
    registry: str
    schema: int = CATALOG_SCHEMA
    created: str = ""
    #: how candidates were priced: "real" (measured) or "analytic"
    measure: str = "analytic"
    #: variant name -> {kernel, params, pruned, pruned_by, costs}
    variants: Dict[str, Dict] = field(default_factory=dict)
    #: kernel-only winners: "<kernel>::<bucket>" -> {params, seconds}
    kernels: Dict[str, Dict] = field(default_factory=dict)

    # -----------------------------------------------------------------
    @classmethod
    def new(cls, *, device: str, measure: str = "analytic"
            ) -> "VariantCatalog":
        return cls(device=device, registry=base_registry_hash(),
                   created=datetime.datetime.now(datetime.timezone.utc)
                   .isoformat(timespec="seconds"),
                   measure=measure)

    # -----------------------------------------------------------------
    def survivors(self) -> List[str]:
        return sorted(n for n, e in self.variants.items()
                      if not e.get("pruned") and e.get("costs"))

    def build_primitives(self) -> List[Primitive]:
        """Reconstruct the surviving variants' Primitive objects from
        their recorded parameters via the declaring spaces."""
        sp = spaces()
        out = []
        for name in self.survivors():
            e = self.variants[name]
            space = sp[e["kernel"]]
            prim = space.make_primitive(
                {k: int(v) for k, v in e["params"].items()})
            if prim.name != name:
                raise ValueError(
                    f"catalog variant {name!r} rebuilt as {prim.name!r}; "
                    f"parameter spaces changed — re-run the tuner")
            out.append(prim)
        return out

    def install(self) -> int:
        """Register the surviving variants; returns how many.

        The extension token is the catalog content hash: every
        ``CostModel.version()`` — and therefore every serving
        plan-cache key — moves with the catalog.
        """
        prims = self.build_primitives()
        register_extension(EXTENSION_NAME, prims,
                           token=self.content_hash())
        return len(prims)

    @staticmethod
    def uninstall() -> bool:
        return unregister_extension(EXTENSION_NAME)

    # -----------------------------------------------------------------
    def content_hash(self) -> str:
        h = hashlib.sha256()
        h.update(f"{self.schema}|{self.device}|{self.registry}"
                 f"|{self.measure}".encode())
        for n in sorted(self.variants):
            e = self.variants[n]
            h.update(f"{n}|{e.get('pruned')}|"
                     f"{json.dumps(e.get('params'), sort_keys=True)}|"
                     f"{json.dumps(e.get('costs'), sort_keys=True)}\n"
                     .encode())
        for k in sorted(self.kernels):
            h.update(f"{k}|{json.dumps(self.kernels[k], sort_keys=True)}\n"
                     .encode())
        return h.hexdigest()[:16]

    # -----------------------------------------------------------------
    def to_payload(self) -> Dict:
        return {
            "schema": self.schema,
            "device": self.device,
            "registry": self.registry,
            "created": self.created,
            "measure": self.measure,
            "variants": {k: self.variants[k]
                         for k in sorted(self.variants)},
            "kernels": {k: self.kernels[k] for k in sorted(self.kernels)},
        }

    @classmethod
    def from_payload(cls, payload: Dict) -> "VariantCatalog":
        if payload.get("schema") != CATALOG_SCHEMA:
            raise ValueError(
                f"catalog schema {payload.get('schema')!r} != "
                f"{CATALOG_SCHEMA}; re-run the tuner")
        return cls(device=str(payload["device"]),
                   registry=str(payload["registry"]),
                   schema=int(payload["schema"]),
                   created=str(payload.get("created", "")),
                   measure=str(payload.get("measure", "analytic")),
                   variants=dict(payload.get("variants", {})),
                   kernels=dict(payload.get("kernels", {})))

    def save(self, path) -> None:
        p = pathlib.Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        atomic_write_text(p, json.dumps(self.to_payload(), indent=1))

    @classmethod
    def load(cls, path) -> "VariantCatalog":
        return cls.from_payload(json.loads(pathlib.Path(path).read_text()))
