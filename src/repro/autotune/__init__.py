"""Autotuned Pallas variant generation (see docs/autotune.md).

Pipeline: each kernel package declares its tunable block/tile/unroll
axes in a ``space.py`` (:mod:`repro.autotune.space`); the tuner
enumerates valid configurations (:mod:`.generate`), measures or
analytically prices them per scenario bucket through the calibrate
machinery (:mod:`.measure`, resumable
:class:`~repro.calibrate.profile.HardwareProfile`), prunes
Pareto-dominated variants (:mod:`.prune`), and persists the winners in
a versioned :class:`~repro.autotune.catalog.VariantCatalog` whose
``install()`` registers them as first-class PBQP primitives via
``core.primitives.register_extension`` — rotating every serving
plan-cache key through the extension token.

CLI: ``python -m repro.launch.tune``.
"""
from .catalog import CATALOG_SCHEMA, EXTENSION_NAME, VariantCatalog, \
    base_registry_hash
from .generate import generate_variants, kernel_spaces, spaces
from .measure import analytic_measurer, kernel_variant_key, \
    plan_tune_sweep
from .prune import Candidate, candidates_from_costs, group_key, \
    prune_dominated
from .space import TunableSpace, params_tuple, variant_name, \
    variant_suffix
from .tuner import TuneResult, plan_only, tune

__all__ = [
    "CATALOG_SCHEMA", "EXTENSION_NAME", "VariantCatalog",
    "base_registry_hash", "generate_variants", "kernel_spaces", "spaces",
    "analytic_measurer", "kernel_variant_key", "plan_tune_sweep",
    "Candidate", "candidates_from_costs", "group_key", "prune_dominated",
    "TunableSpace", "params_tuple", "variant_name", "variant_suffix",
    "TuneResult", "plan_only", "tune",
]
