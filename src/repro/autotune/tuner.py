"""The tuning pipeline: generate -> measure -> prune -> catalog.

One call, :func:`tune`, runs the whole loop the ``launch/tune.py`` CLI,
the primitives benchmark, and the smoke tests share.  Resumable like
calibration: measurements land in a :class:`HardwareProfile` keyed by
the same ``prim::``/``kernel::`` keys, covered keys are skipped, and a
``budget`` caps how many *new* measurements one invocation performs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from ..calibrate.profile import HardwareProfile
from ..calibrate.sweep import run_sweep
from ..core.costs import TPU_V5E_SPEC, prim_cost_key
from ..core.primitives import Primitive
from ..core.scenario import Scenario
from ..serving.bucketing import BucketPolicy, bucket_scenario
from .catalog import VariantCatalog
from .generate import kernel_spaces, spaces
from .measure import (
    analytic_measurer, default_measure_mode, kernel_variant_key,
    plan_tune_sweep,
)
from .prune import candidates_from_costs, prune_dominated

__all__ = ["TuneResult", "tune", "plan_only"]


@dataclass
class TuneResult:
    catalog: VariantCatalog
    profile: HardwareProfile
    #: run_sweep stats: measured / skipped / remaining
    sweep: Dict[str, int]
    #: generated / surviving / pruned counts
    generated: int = 0
    surviving: int = 0
    pruned: int = 0


def _candidate_pool(kernels: Optional[Sequence[str]],
                    max_per_kernel: Optional[int]
                    ) -> Tuple[List[Primitive], Dict[str, tuple]]:
    """Generated variants plus ``name -> (kernel, params)`` origins."""
    variants: List[Primitive] = []
    origin: Dict[str, tuple] = {}
    for kname, space in sorted(spaces().items()):
        if not space.registers:
            continue
        if kernels and kname not in kernels:
            continue
        cfgs = space.configs()
        if max_per_kernel is not None:
            cfgs = cfgs[:max_per_kernel]
        for cfg in cfgs:
            prim = space.make_primitive(cfg)
            variants.append(prim)
            origin[prim.name] = (kname, cfg)
    return variants, origin


def _buckets(scenarios: Sequence[Scenario],
             policy: BucketPolicy) -> List[Scenario]:
    out, seen = [], set()
    for raw in scenarios:
        scn = bucket_scenario(raw, policy)
        if scn.key() not in seen:
            seen.add(scn.key())
            out.append(scn)
    return out


def plan_only(scenarios: Sequence[Scenario], *,
              kernels: Optional[Sequence[str]] = None,
              max_per_kernel: Optional[int] = None,
              policy: Optional[BucketPolicy] = None):
    """What a tune run would measure (the CLI's ``--dry-run``)."""
    policy = policy or BucketPolicy()
    variants, origin = _candidate_pool(kernels, max_per_kernel)
    items, index = plan_tune_sweep(
        variants, scenarios, kernel_only=kernel_spaces(kernels),
        policy=policy)
    return variants, items, index


def tune(scenarios: Sequence[Scenario], *,
         kernels: Optional[Sequence[str]] = None,
         max_per_kernel: Optional[int] = None,
         measure_mode: str = "auto",
         profile: Optional[HardwareProfile] = None,
         profile_path=None,
         budget: Optional[int] = None,
         reps: int = 3, min_time: float = 5e-3,
         save_every: int = 20,
         policy: Optional[BucketPolicy] = None,
         progress: Optional[Callable] = None) -> TuneResult:
    """Run one (resumable) tuning pass and return the catalog.

    ``measure_mode``: ``"real"`` times kernels on the current device,
    ``"analytic"`` prices them with the tile-aware TPU model,
    ``"auto"`` picks real on TPU and analytic elsewhere (CPU interpret
    timings of Pallas kernels are noise — see docs/autotune.md).
    """
    policy = policy or BucketPolicy()
    mode = default_measure_mode() if measure_mode == "auto" \
        else measure_mode
    if mode not in ("real", "analytic"):
        raise ValueError(f"measure_mode {mode!r}")

    variants, origin = _candidate_pool(kernels, max_per_kernel)
    konly = kernel_spaces(kernels)
    items, index = plan_tune_sweep(variants, scenarios,
                                   kernel_only=konly, policy=policy)
    buckets = _buckets(scenarios, policy)

    if profile is None:
        profile = HardwareProfile.new(reps=reps, min_time=min_time)
    measure = analytic_measurer(index, TPU_V5E_SPEC) \
        if mode == "analytic" else None
    sweep = run_sweep(profile, items, reps=reps, min_time=min_time,
                      save_path=profile_path, save_every=save_every,
                      max_entries=budget, progress=progress,
                      measure=measure)

    # ---- dominance pruning over everything the profile now covers ----
    pool = list(variants)
    vnames = set(origin)
    from ..core.primitives import registry
    pool += [p for p in registry()
             if p.family == "pallas" and not p.params
             and p.name not in vnames]
    cands = candidates_from_costs(
        pool, buckets,
        lambda p, s: profile.get(prim_cost_key(p.name, s)))
    survivors, pruned = prune_dominated(cands)
    surviving = set(survivors)

    catalog = VariantCatalog.new(device=profile.device, measure=mode)
    by_name = {c.name: c for c in cands}
    for name, (kname, cfg) in sorted(origin.items()):
        c = by_name[name]
        costs = dict(c.costs)
        catalog.variants[name] = {
            "kernel": kname,
            "params": {k: int(v) for k, v in cfg.items()},
            "pruned": name not in surviving,
            **({"pruned_by": pruned[name]} if name in pruned else {}),
            "costs": costs,
        }

    # ---- kernel-only winners: best config per bucket ----
    for space, cfgs in konly:
        for scn in buckets:
            best = None
            for params in cfgs:
                sec = profile.get(kernel_variant_key(space, params, scn))
                if sec is None:
                    continue
                if best is None or sec < best[1]:
                    best = (params, sec)
            if best is not None:
                catalog.kernels[f"{space.kernel}::{scn.key()}"] = {
                    "params": {k: int(v) for k, v in best[0].items()},
                    "seconds": best[1],
                }

    n_surv = len(catalog.survivors())
    return TuneResult(catalog=catalog, profile=profile, sweep=sweep,
                      generated=len(variants), surviving=n_surv,
                      pruned=len(origin) - n_surv)
