"""Variant generation: enumerate every space's valid configurations.

Kernel space modules are imported lazily (they import
:mod:`repro.autotune.space`, never the reverse), so this module is the
single point where the autotuner learns what is tunable.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

from ..core.primitives import Primitive
from .space import TunableSpace

__all__ = ["spaces", "generate_variants", "kernel_spaces"]


def spaces() -> Dict[str, TunableSpace]:
    """All six kernel packages' declared spaces, keyed by package."""
    from ..kernels.conv_direct import space as conv_direct
    from ..kernels.conv_im2col import space as conv_im2col
    from ..kernels.flash_attention import space as flash_attention
    from ..kernels.layout_transform import space as layout_transform
    from ..kernels.matmul import space as matmul
    from ..kernels.winograd_gemm import space as winograd_gemm
    mods = (conv_direct, conv_im2col, winograd_gemm, matmul,
            flash_attention, layout_transform)
    return {m.SPACE.kernel: m.SPACE for m in mods}


def generate_variants(kernels: Optional[Sequence[str]] = None,
                      max_per_kernel: Optional[int] = None
                      ) -> List[Primitive]:
    """Candidate primitives from every *registering* space.

    ``kernels`` filters by package name; ``max_per_kernel`` caps each
    space deterministically (the leading slice of its config order) —
    the CLI's ``--budget`` lever for quick sweeps.
    """
    out: List[Primitive] = []
    for kname, space in sorted(spaces().items()):
        if not space.registers:
            continue
        if kernels and kname not in kernels:
            continue
        cfgs = space.configs()
        if max_per_kernel is not None:
            cfgs = cfgs[:max_per_kernel]
        out.extend(space.make_primitive(p) for p in cfgs)
    names = [p.name for p in out]
    assert len(names) == len(set(names)), "duplicate variant names"
    return out


def kernel_spaces(kernels: Optional[Sequence[str]] = None
                  ) -> List[Tuple[TunableSpace, List[Dict[str, int]]]]:
    """(space, configs) for every *kernel-only* space."""
    out = []
    for kname, space in sorted(spaces().items()):
        if space.registers:
            continue
        if kernels and kname not in kernels:
            continue
        out.append((space, space.configs()))
    return out
