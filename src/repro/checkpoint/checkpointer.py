"""Fault-tolerant checkpointing: atomic, resumable, rotation-managed.

Layout:  <dir>/step_<n>/arrays.npz + meta.json, with a two-phase commit
(write to step_<n>.tmp, fsync, rename) so a node failure mid-write never
corrupts the latest checkpoint.  On a real cluster each host writes its
own param shards (addressable-shard iteration); on this single-process
container that degenerates to full arrays, same code path.
"""
from __future__ import annotations

import json
import pathlib
import shutil
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["Checkpointer"]


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[Dict] = None):
        leaves, treedef = jax.tree.flatten(tree)
        tmp = self.dir / f"step_{step}.tmp"
        final = self.dir / f"step_{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir()
        arrays = {}
        for i, leaf in enumerate(leaves):
            arr = np.asarray(jax.device_get(leaf))
            if arr.dtype == jnp.bfloat16:
                arrays[f"a{i}__bf16"] = arr.astype(np.float32)
            else:
                arrays[f"a{i}"] = arr
        np.savez(tmp / "arrays.npz", **arrays)
        meta = {"step": step, "n_leaves": len(leaves),
                "treedef": str(treedef), "time": time.time(),
                "extra": extra or {}}
        (tmp / "meta.json").write_text(json.dumps(meta))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)   # atomic commit
        self._rotate()

    def _rotate(self):
        steps = sorted(self.steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    def steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not p.is_dir():
                continue
            try:
                out.append(int(p.name.split("_")[1]))
            except ValueError:
                continue
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, like: Any, step: Optional[int] = None
                ) -> Tuple[int, Any, Dict]:
        """Restore into the structure (and dtypes) of ``like``."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        final = self.dir / f"step_{step}"
        meta = json.loads((final / "meta.json").read_text())
        data = np.load(final / "arrays.npz")
        leaves_like, treedef = jax.tree.flatten(like)
        leaves = []
        for i, ref in enumerate(leaves_like):
            if f"a{i}__bf16" in data:
                arr = jnp.asarray(data[f"a{i}__bf16"], jnp.bfloat16)
            else:
                arr = jnp.asarray(data[f"a{i}"])
            if hasattr(ref, "dtype") and arr.dtype != ref.dtype:
                arr = arr.astype(ref.dtype)
            leaves.append(arr)
        return meta["step"], treedef.unflatten(leaves), meta["extra"]
