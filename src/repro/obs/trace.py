"""Request-scoped tracing: nested spans emitted as thread-safe JSONL.

A *span* is one timed region of the serve path (``infer``,
``plan``, ``pbqp.solve``, ``compile``, ``execute``, ``crop``,
``queue_wait`` — docs/observability.md lists the schema).  Spans nest
through a :mod:`contextvars` variable, so the parent/child structure is
correct across the thread pool the :class:`~repro.serving.server.
PlanServer` resolves misses on: each worker thread carries its own
current-span context.

Tracing is OFF by default and the disabled path is a few attribute
reads — the serve hot path stays uninstrumented-cost until someone
calls :func:`configure` (the ``--trace`` flag of ``launch/serve.py``).
Finished spans are written as one JSON line each (children appear
before their parent, which closes last); the writer holds a lock, so
concurrent requests interleave whole lines, never bytes.

This module is intentionally stdlib-only: :mod:`repro.core` imports it
(``pbqp.solve`` / ``compile_plan`` open spans), so it must never import
back into core.
"""
from __future__ import annotations

import contextlib
import contextvars
import io
import json
import pathlib
import threading
import time
from typing import Any, Dict, Iterator, List, Optional, Union

__all__ = ["Span", "Tracer", "get_tracer", "configure", "NULL_SPAN"]


class Span:
    """One open region; ``set(**attrs)`` attaches attributes."""

    __slots__ = ("name", "trace_id", "span_id", "parent_id", "t0", "attrs")

    def __init__(self, name: str, trace_id: int, span_id: int,
                 parent_id: Optional[int], attrs: Dict[str, Any]):
        self.name = name
        self.trace_id = trace_id
        self.span_id = span_id
        self.parent_id = parent_id
        self.t0 = time.perf_counter()
        self.attrs = attrs

    def set(self, **attrs) -> None:
        self.attrs.update(attrs)


class _NullSpan:
    """What call sites get when tracing is disabled: ``set`` is a no-op."""

    __slots__ = ()

    def set(self, **attrs) -> None:
        pass


NULL_SPAN = _NullSpan()


class Tracer:
    """Span factory + JSONL sink.

    ``sink`` is a path (opened append), a file-like object, or a
    ``list`` (records appended as dicts — the test/in-memory sink).
    """

    def __init__(self, sink: Union[None, str, pathlib.Path, list,
                                   io.IOBase] = None,
                 enabled: bool = False) -> None:
        self.enabled = enabled
        self._lock = threading.Lock()
        self._ids = 0
        self._current: contextvars.ContextVar[Optional[Span]] = \
            contextvars.ContextVar("obs_current_span", default=None)
        self._records: Optional[List[Dict[str, Any]]] = None
        self._fh = None
        if isinstance(sink, list):
            self._records = sink
        elif isinstance(sink, (str, pathlib.Path)):
            self._fh = open(sink, "a")
        elif sink is not None:
            self._fh = sink

    # -----------------------------------------------------------------
    def _next_id(self) -> int:
        with self._lock:
            self._ids += 1
            return self._ids

    def _emit(self, rec: Dict[str, Any]) -> None:
        with self._lock:
            if self._records is not None:
                self._records.append(rec)
            if self._fh is not None:
                self._fh.write(json.dumps(rec) + "\n")

    @contextlib.contextmanager
    def span(self, name: str, **attrs) -> Iterator[Union[Span, _NullSpan]]:
        """Open a span; a span with no live parent starts a new trace."""
        if not self.enabled:
            yield NULL_SPAN
            return
        parent = self._current.get()
        sid = self._next_id()
        sp = Span(name, parent.trace_id if parent else sid, sid,
                  parent.span_id if parent else None, dict(attrs))
        token = self._current.set(sp)
        try:
            yield sp
        finally:
            self._current.reset(token)
            self._emit({"name": sp.name, "trace": sp.trace_id,
                        "span": sp.span_id, "parent": sp.parent_id,
                        "t0": sp.t0,
                        "dur_s": time.perf_counter() - sp.t0,
                        **sp.attrs})

    def emit(self, name: str, t0: float, t1: float, **attrs) -> None:
        """Record a span from explicit timestamps (e.g. queue wait:
        the region opened in ``enqueue`` and closed in ``flush``, on
        different call stacks, so a context manager cannot cover it).
        Parented to the caller's current span."""
        if not self.enabled:
            return
        parent = self._current.get()
        sid = self._next_id()
        self._emit({"name": name,
                    "trace": parent.trace_id if parent else sid,
                    "span": sid,
                    "parent": parent.span_id if parent else None,
                    "t0": t0, "dur_s": t1 - t0, **attrs})

    def flush(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.flush()


#: process-wide tracer; disabled (and sink-less) until configure()
_TRACER = Tracer()


def get_tracer() -> Tracer:
    return _TRACER


def configure(sink=None, enabled: bool = True) -> Tracer:
    """Replace the global tracer (typically once, at process start)."""
    global _TRACER
    _TRACER = Tracer(sink, enabled=enabled)
    return _TRACER
