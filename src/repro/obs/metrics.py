"""Counter/gauge/histogram registry with Prometheus-style exposition.

The metric surface of the serving stack: :class:`~repro.serving.metrics.
ServingCounters` is a view over one of these registries, the
``compile_plan`` call counter lives in the process-default registry,
and :meth:`PlanServer.stats` reports latency percentiles straight from
the phase histograms registered here.

Everything is thread-safe in the strongest sense the tests assert on:
N threads doing M increments each land exactly N*M — one lock per
metric, taken for the handful of arithmetic ops an update is.
Histograms are bucketed (geometric bounds, microseconds to minutes by
default), so memory is constant per metric regardless of sample count;
percentiles are estimated by linear interpolation inside the bucket the
rank falls into (exact min/max are tracked, so p0/p100 are exact).

Stdlib-only by design — :mod:`repro.core` imports this module.
"""
from __future__ import annotations

import bisect
import math
import threading
from typing import Dict, Iterable, List, Optional, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "default_registry", "DEFAULT_BUCKETS"]

#: geometric latency bounds (seconds): 1 us .. ~67 s, factor 2 — 27
#: buckets cover every phase the serve path times, at <=2x resolution
DEFAULT_BUCKETS: Tuple[float, ...] = tuple(
    1e-6 * 2 ** i for i in range(27))

Labels = Tuple[Tuple[str, str], ...]


def _label_key(labels: Optional[Dict[str, str]]) -> Labels:
    return tuple(sorted((k, str(v)) for k, v in (labels or {}).items()))


def _label_str(labels: Labels) -> str:
    if not labels:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in labels) + "}"


class Counter:
    """Monotonic sum (ints stay ints; floats accumulate seconds)."""

    __slots__ = ("_lock", "_value")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._value = 0

    def add(self, v=1) -> None:
        with self._lock:
            self._value += v

    @property
    def value(self):
        with self._lock:
            return self._value


class Gauge:
    """Last-write-wins scalar."""

    __slots__ = ("_lock", "_value")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, v) -> None:
        with self._lock:
            self._value = v

    @property
    def value(self):
        with self._lock:
            return self._value


class Histogram:
    """Fixed-bucket histogram with count/sum/min/max and percentiles."""

    __slots__ = ("_lock", "bounds", "counts", "count", "sum", "min", "max")

    def __init__(self, bounds: Iterable[float] = DEFAULT_BUCKETS) -> None:
        self._lock = threading.Lock()
        self.bounds = tuple(sorted(bounds))
        if not self.bounds:
            raise ValueError("histogram needs at least one bucket bound")
        # counts[i] counts samples <= bounds[i]; counts[-1] the overflow
        self.counts = [0] * (len(self.bounds) + 1)
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def record(self, v: float) -> None:
        i = bisect.bisect_left(self.bounds, v)
        with self._lock:
            self.counts[i] += 1
            self.count += 1
            self.sum += v
            if v < self.min:
                self.min = v
            if v > self.max:
                self.max = v

    # -----------------------------------------------------------------
    def percentile(self, q: float) -> float:
        """Estimated q-th percentile (q in [0, 100]).

        Linear interpolation inside the bucket containing the rank;
        clamped to the observed min/max so a one-sample histogram
        reports that sample, not a bucket bound.  NaN when empty.
        """
        with self._lock:
            if self.count == 0:
                return math.nan
            rank = q / 100.0 * self.count
            cum = 0
            for i, c in enumerate(self.counts):
                if c == 0:
                    continue
                lo_b = self.bounds[i - 1] if i > 0 else 0.0
                hi_b = self.bounds[i] if i < len(self.bounds) else self.max
                if cum + c >= rank:
                    frac = (rank - cum) / c
                    v = lo_b + frac * (hi_b - lo_b)
                    return min(max(v, self.min), self.max)
                cum += c
            return self.max

    def quantiles(self) -> Dict[str, float]:
        return {"p50": self.percentile(50), "p95": self.percentile(95),
                "p99": self.percentile(99)}

    def snapshot(self) -> Dict[str, float]:
        with self._lock:
            count, total = self.count, self.sum
            mn = self.min if count else math.nan
            mx = self.max if count else math.nan
        d = {"count": count, "sum": total, "min": mn, "max": mx}
        d.update(self.quantiles())
        return d


class MetricsRegistry:
    """Get-or-create metric store, keyed by (name, sorted labels).

    One registry per :class:`~repro.serving.server.PlanServer` (so
    per-server counters stay independent, as the acceptance tests
    assert) plus the process-wide :func:`default_registry` for global
    facts like the compile count.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._metrics: Dict[Tuple[str, str, Labels], object] = {}

    def _get(self, kind: str, name: str,
             labels: Optional[Dict[str, str]], factory):
        key = (kind, name, _label_key(labels))
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = factory()
                self._metrics[key] = m
            return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", name, labels, Counter)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", name, labels, Gauge)

    def histogram(self, name: str,
                  buckets: Iterable[float] = DEFAULT_BUCKETS,
                  **labels) -> Histogram:
        return self._get("histogram", name, labels,
                         lambda: Histogram(buckets))

    def find_histogram(self, name: str, **labels) -> Optional[Histogram]:
        """Histogram lookup WITHOUT creation (None if never recorded).

        Readers that merely *consult* a histogram — e.g. the scheduler
        estimating batch latency from observed samples — must not leave
        empty metrics behind in the exposition, so they look up through
        here instead of the get-or-create :meth:`histogram`.
        """
        key = ("histogram", name, _label_key(labels))
        with self._lock:
            m = self._metrics.get(key)
        return m  # type: ignore[return-value]

    # -----------------------------------------------------------------
    def snapshot(self) -> Dict[str, object]:
        """Flat name(+labels) -> value/summary dict."""
        with self._lock:
            items = list(self._metrics.items())
        out: Dict[str, object] = {}
        for (kind, name, labels), m in items:
            key = name + _label_str(labels)
            if kind == "histogram":
                out[key] = m.snapshot()
            else:
                out[key] = m.value
        return out

    def prometheus_text(self) -> str:
        """Prometheus text exposition (histograms as summaries)."""
        with self._lock:
            items = sorted(self._metrics.items(), key=lambda kv: kv[0])
        lines: List[str] = []
        seen_type = set()
        for (kind, name, labels), m in items:
            ptype = {"counter": "counter", "gauge": "gauge",
                     "histogram": "summary"}[kind]
            if name not in seen_type:
                lines.append(f"# TYPE {name} {ptype}")
                seen_type.add(name)
            ls = _label_str(labels)
            if kind == "histogram":
                for q in (50, 95, 99):
                    ql = dict(labels)
                    ql["quantile"] = f"0.{q}"
                    lines.append(f"{name}{_label_str(_label_key(ql))} "
                                 f"{m.percentile(q)}")
                lines.append(f"{name}_sum{ls} {m.sum}")
                lines.append(f"{name}_count{ls} {m.count}")
            else:
                lines.append(f"{name}{ls} {m.value}")
        return "\n".join(lines) + "\n"


_DEFAULT = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    """The process-wide registry (process-scoped facts only)."""
    return _DEFAULT
