"""Predicted-vs-observed cost drift detection for compiled plans.

The PBQP optimum is only as good as the cost model it was solved
against.  This module closes that loop:

* :class:`InstrumentedNet` — an instrumented execution mode for a
  :class:`~repro.core.plan.CompiledNet`: the same DAG walk the plain
  executable runs, but with every node kernel and every layout-
  conversion chain compiled as its *own* jit'd callable and wall-timed
  (blocked) per invocation.  Per-node observed seconds come out of
  every call; outputs are identical to the plain executable (verified
  in tests/test_observability.py).
* :func:`plan_predictions` — the exact per-node and per-edge costs the
  solver's objective summed for a plan: the chosen primitive's cost at
  the node's (batched) scenario, plus its incoming conversion chains /
  fused transforms priced the way ``selection._build`` priced them.
* :class:`DriftDetector` — per (node, primitive, layout, bucket) entry:
  EWMA of the observed time and of ``log(observed / predicted)`` (the
  *drift score*); entries whose |score| exceeds ``log(threshold)`` are
  flagged, and :meth:`DriftDetector.recalibrate` writes their observed
  EWMAs back into a :class:`~repro.calibrate.HardwareProfile` — ONLY
  the flagged entries — which changes the profile's content hash and
  therefore the :class:`~repro.calibrate.CalibratedCostModel` version,
  invalidating every cached plan priced by the stale numbers (the
  invalidation chain of docs/calibration.md, now driven by runtime
  evidence instead of manual re-sweeps).

The whole-plan comparison uses the *modeled* total — conv kernels plus
mismatched-edge transforms, the terms the objective actually contains.
Op nodes (relu, pool, ...) are the paper's zero-cost dummy nodes; their
observed time is reported separately as ``unmodeled_s`` so it can never
masquerade as kernel drift.  docs/observability.md works the
recalibration loop end to end.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.costs import CostModel, prim_cost_key, transform_cost_key
from ..core.layouts import LAYOUT_BY_NAME
from ..core.plan import CompiledNet
from ..core.primitives import convert_layout
from ..core.selection import Placement, PlacementPricing, SelectionResult
from ..serving.bucketing import BucketPolicy, bucket_scenario

__all__ = ["InstrumentedNet", "plan_predictions", "DriftEntry",
           "DriftDetector", "RestrictedCostModel", "recalibration_loop"]


def _net_batch(sel: SelectionResult) -> int:
    return max((n.scn.n for n in sel.net.conv_nodes()), default=1)


# ----------------------------------------------------------------------
# predicted costs, per node and per edge — the objective, itemized
# ----------------------------------------------------------------------
def plan_predictions(sel: SelectionResult, cost: CostModel,
                     mesh_axes: Optional[Dict[str, int]] = None
                     ) -> Dict[str, Dict[Tuple, float]]:
    """Itemize the solver's objective for one plan.

    Returns ``{"node": {nid: s}, "edge": {(src, dst): s},
    "collective": {...}}`` — node entries are the chosen primitive's
    compute at the node's (batched, placement-sharded) scenario,
    exactly what ``selection._build`` put in the cost vector; edge
    entries are the realized conversion chain (per-image hop costs x
    the images the transform touches) or the fused transform.

    For a placement-solved plan pass the ``mesh_axes`` it was solved
    for: the collective terms are then itemized under ``"collective"``
    — ``("node", nid)`` for intra-node terms (the tp channel
    all-gather, the output delivery gather, the pp balance prior) and
    ``("edge", src, dst)`` for resharding / stage-boundary transfers —
    all derived from the same :class:`~repro.core.selection.
    PlacementPricing` the solver priced with.  Without ``mesh_axes``
    only mesh-less (all-``rep``) plans are supported.
    """
    placed = any(ch.placement != "rep" for ch in sel.choices.values())
    if placed and mesh_axes is None:
        raise ValueError("plan_predictions models mesh-less plans only "
                         "unless mesh_axes= names the topology the "
                         "plan was solved for (device placements add "
                         "collective terms)")
    nb = _net_batch(sel)
    net = sel.net
    pm = PlacementPricing(net, cost, mesh_axes) if placed else None
    pl_of = {nid: Placement.parse(ch.placement)
             for nid, ch in sel.choices.items()}

    nodes: Dict[Tuple, float] = {}
    for node in net.conv_nodes():
        prim = sel.choices[node.id].primitive
        c_rep = float(cost.primitive_cost(prim, node.scn))
        if pm is None:
            nodes[node.id] = c_rep
        else:
            compute, _ = pm.conv_cost(node, prim, pl_of[node.id], c_rep)
            nodes[node.id] = float(compute)

    def scale(src: str, dst: str) -> float:
        if pm is None:
            return float(nb)
        return float(pm.transform_images(pl_of[src], pl_of[dst]))

    edges: Dict[Tuple, float] = {}
    for (src, dst), chain in sel.conversions.items():
        shape = net.nodes[src].out_shape
        per_img = sum(cost.transform_cost(a, b, shape, "float32")
                      for a, b in zip(chain, chain[1:]))
        edges[(src, dst)] = float(per_img) * scale(src, dst)
    for (src, dst), kind in sel.fusions.items():
        cu, cv = sel.choices[src], sel.choices[dst]
        if kind == "in":
            per_img = cost.fused_in_cost(cv.primitive,
                                         net.nodes[dst].scn, cu.l_out)
        else:
            per_img = cost.fused_out_cost(cu.primitive,
                                          net.nodes[src].scn, cv.l_in)
        edges[(src, dst)] = float(per_img) * scale(src, dst)

    coll: Dict[Tuple, float] = {}
    if pm is not None:
        for nid in net.order:
            extra = pm.node_extra(net.nodes[nid], pl_of[nid])
            if extra:
                coll[("node", nid)] = float(extra)
        for dst in net.order:
            for src in net.nodes[dst].inputs:
                img = 4.0 * float(np.prod(net.nodes[src].out_shape))
                c = pm.edge_collective(pl_of[src], pl_of[dst], img)
                if c:
                    coll[("edge", src, dst)] = float(c)
    return {"node": nodes, "edge": edges, "collective": coll}


# ----------------------------------------------------------------------
# instrumented execution: one jit'd callable per node/conversion
# ----------------------------------------------------------------------
class InstrumentedNet:
    """Per-node timed execution of a compiled plan.

    Construction compiles (and warms up) one jit'd callable per conv
    kernel, op, conversion chain and output conversion; each
    :meth:`__call__` then walks the DAG blocking on every step and
    returns ``(outputs, timings)`` with ``timings = {"node": {nid: s},
    "edge": {(src, dst): s}, "unmodeled_s": s}`` — ``unmodeled_s`` is
    the op-node + output-conversion remainder the cost model prices at
    zero.  Observed node seconds include per-call dispatch (unlike the
    ``min_time``-amortized calibration sweep); the drift workflow is
    self-consistent because recalibrated entries come from the same
    instrumented measurement (docs/observability.md#semantics).
    """

    def __init__(self, cnet: CompiledNet, warmup: bool = True) -> None:
        if cnet.mesh is not None:
            raise ValueError("instrumented execution is single-device; "
                             "compile the plan without a mesh")
        if not cnet.makers:
            raise ValueError("CompiledNet carries no per-node makers; "
                             "build it with repro.core.plan.compile_plan")
        self.cnet = cnet
        sel, batch = cnet.sel, cnet.batch
        net = sel.net

        def vm(fn, n_in: int = 1, with_params: bool = False):
            if batch == 1:
                return fn
            axes = (0,) * n_in + ((None,) if with_params else ())
            return jax.vmap(fn, in_axes=axes)

        self._convert: Dict[Tuple[str, str], Callable] = {}
        for (src, dst), chain in sel.conversions.items():
            def run_chain(v, chain=tuple(chain)):
                for a, b in zip(chain, chain[1:]):
                    v = convert_layout(v, a, b)
                return v
            self._convert[(src, dst)] = jax.jit(vm(run_chain))

        self._node: Dict[str, Callable] = {}
        self._out: Dict[str, Callable] = {}
        for nid in net.order:
            node = net.nodes[nid]
            if node.kind == "input":
                continue
            if node.kind == "conv":
                self._node[nid] = jax.jit(
                    vm(cnet.makers[nid], with_params=True))
            else:
                layout = LAYOUT_BY_NAME[sel.choices[nid].l_in]
                p = cnet.params.get(nid)
                def run_op(*ins, op=node.op, lay=layout, p=p):
                    return op.fn(list(ins), lay, p)
                self._node[nid] = jax.jit(vm(run_op, len(node.inputs)))
        for nid in net.outputs():
            lo = sel.choices[nid].l_out
            self._out[nid] = jax.jit(
                vm(lambda v, lo=lo: convert_layout(v, lo, "CHW")))

        if warmup:
            in_shape = net.nodes[net.order[0]].out_shape
            zeros = np.zeros(in_shape if batch == 1
                             else (batch, *in_shape), np.float32)
            self(zeros)

    # -----------------------------------------------------------------
    def __call__(self, x) -> Tuple[Dict[str, np.ndarray],
                                   Dict[str, Any]]:
        sel, params = self.cnet.sel, self.cnet.params
        net = sel.net
        node_s: Dict[str, float] = {}
        edge_s: Dict[Tuple[str, str], float] = {}
        unmodeled = 0.0
        vals: Dict[str, Any] = {}

        def timed(fn, *args):
            t0 = time.perf_counter()
            out = fn(*args)
            jax.block_until_ready(out)
            return out, time.perf_counter() - t0

        for nid in net.order:
            node = net.nodes[nid]
            if node.kind == "input":
                vals[nid] = jnp.asarray(x)
                continue
            ins = []
            for src in node.inputs:
                v = vals[src]
                conv = self._convert.get((src, nid))
                if conv is not None:
                    v, dt = timed(conv, v)
                    edge_s[(src, nid)] = dt
                ins.append(v)
            if node.kind == "conv":
                vals[nid], dt = timed(self._node[nid], ins[0], params[nid])
                node_s[nid] = dt
            else:
                vals[nid], dt = timed(self._node[nid], *ins)
                node_s[nid] = dt
                unmodeled += dt
        outs: Dict[str, np.ndarray] = {}
        for nid, fn in self._out.items():
            v, dt = timed(fn, vals[nid])
            unmodeled += dt
            outs[nid] = np.asarray(v)
        return outs, {"node": node_s, "edge": edge_s,
                      "unmodeled_s": unmodeled}


# ----------------------------------------------------------------------
# drift scoring
# ----------------------------------------------------------------------
@dataclass
class DriftEntry:
    """EWMA state for one (node, primitive, layout, bucket) entry."""

    kind: str                 # "node" (conv kernel) | "edge" (transform)
    nid: str                  # node id, or "src->dst" for an edge
    primitive: str            # primitive name / "convert"
    layout: str               # "l_in->l_out" wire layouts
    bucket: str               # calibration bucket key
    predicted_s: float
    #: device placement of the node ("rep"/"dp"/"tp"/"pp<stage>"), or
    #: "src->dst" placements for an edge
    placement: str = "rep"
    ewma_observed_s: float = 0.0
    drift: float = 0.0        # EWMA of log(observed / predicted)
    n: int = 0
    #: recalibration target: profile key this entry's observation
    #: re-prices, and the per-image divisor (edges are priced per image)
    profile_key: Optional[str] = None
    per_image_div: int = 1

    def ratio(self) -> float:
        return math.exp(self.drift)


class DriftDetector:
    """Accumulate instrumented observations against a cost model.

    ``threshold`` is a *ratio*: an entry is flagged when its EWMA
    observed/predicted ratio leaves ``[1/threshold, threshold]``.
    ``alpha`` is the EWMA weight of each new observation.
    """

    def __init__(self, cost: CostModel, *, alpha: float = 0.3,
                 threshold: float = 1.5,
                 policy: Optional[BucketPolicy] = None) -> None:
        if threshold <= 1.0:
            raise ValueError("threshold is a ratio > 1")
        self.cost = cost
        self.alpha = alpha
        self.log_threshold = math.log(threshold)
        self.policy = policy or BucketPolicy()
        self.entries: Dict[Tuple[str, str], DriftEntry] = {}
        #: whole-plan EWMAs (modeled terms only)
        self.predicted_total = 0.0
        self.observed_total = 0.0
        self.unmodeled_s = 0.0
        self.runs = 0

    # -----------------------------------------------------------------
    def _update(self, e: DriftEntry, observed: float) -> None:
        if e.n == 0:
            e.ewma_observed_s = observed
            e.drift = math.log(max(observed, 1e-12) /
                               max(e.predicted_s, 1e-12))
        else:
            a = self.alpha
            e.ewma_observed_s += a * (observed - e.ewma_observed_s)
            e.drift += a * (math.log(max(observed, 1e-12) /
                                     max(e.predicted_s, 1e-12)) - e.drift)
        e.n += 1

    def observe(self, sel: SelectionResult,
                timings: Dict[str, Any]) -> None:
        """Fold one :class:`InstrumentedNet` run into the EWMAs."""
        pred = plan_predictions(sel, self.cost)
        nb = _net_batch(sel)
        net = sel.net
        obs_total = pred_total = 0.0
        for node in net.conv_nodes():
            nid = node.id
            if nid not in timings["node"]:
                continue
            ch = sel.choices[nid]
            b = bucket_scenario(node.scn, self.policy)
            key = ("node", nid)
            e = self.entries.get(key)
            if e is None:
                e = DriftEntry(
                    "node", nid, ch.primitive.name,
                    f"{ch.l_in}->{ch.l_out}", b.key(),
                    predicted_s=pred["node"][nid],
                    placement=str(ch.placement),
                    profile_key=prim_cost_key(ch.primitive.name, b))
                self.entries[key] = e
            e.predicted_s = pred["node"][nid]
            self._update(e, timings["node"][nid])
            obs_total += timings["node"][nid]
            pred_total += e.predicted_s
        for (src, dst), dt in timings["edge"].items():
            if (src, dst) not in pred["edge"]:
                continue
            chain = sel.conversions.get((src, dst))
            key = ("edge", f"{src}->{dst}")
            e = self.entries.get(key)
            if e is None:
                shape = net.nodes[src].out_shape
                pkey = None
                if chain is not None and len(chain) == 2:
                    # single-hop chains recalibrate the dt:: entry
                    # directly; multi-hop observations cannot be split
                    # across hops, so they report but never re-price
                    from ..serving.bucketing import bucket_shape
                    pkey = transform_cost_key(
                        chain[0], chain[1],
                        bucket_shape(shape, self.policy))
                e = DriftEntry(
                    "edge", f"{src}->{dst}", "convert",
                    "->".join(chain) if chain else "fused",
                    "x".join(map(str, net.nodes[src].out_shape)),
                    predicted_s=pred["edge"][(src, dst)],
                    placement=f"{sel.choices[src].placement}->"
                              f"{sel.choices[dst].placement}",
                    profile_key=pkey, per_image_div=nb)
                self.entries[key] = e
            e.predicted_s = pred["edge"][(src, dst)]
            self._update(e, dt)
            obs_total += dt
            pred_total += e.predicted_s
        a = self.alpha if self.runs else 1.0
        self.observed_total += a * (obs_total - self.observed_total)
        self.predicted_total += a * (pred_total - self.predicted_total)
        self.unmodeled_s += a * (timings.get("unmodeled_s", 0.0)
                                 - self.unmodeled_s)
        self.runs += 1

    # -----------------------------------------------------------------
    def flagged(self) -> List[DriftEntry]:
        return [e for e in self.entries.values()
                if abs(e.drift) > self.log_threshold]

    def plan_ratio(self) -> float:
        """Observed/predicted ratio of the modeled plan total."""
        return self.observed_total / max(self.predicted_total, 1e-12)

    def plan_within_threshold(self) -> bool:
        return abs(math.log(max(self.plan_ratio(), 1e-12))) \
            <= self.log_threshold

    def report(self) -> List[Dict[str, Any]]:
        """Per-entry rows, most drifted first (the obs_report table)."""
        rows = []
        for e in sorted(self.entries.values(),
                        key=lambda e: -abs(e.drift)):
            rows.append({
                "kind": e.kind, "node": e.nid, "primitive": e.primitive,
                "layout": e.layout, "bucket": e.bucket,
                "placement": e.placement,
                "predicted_ms": e.predicted_s * 1e3,
                "observed_ms": e.ewma_observed_s * 1e3,
                "ratio": e.ratio(), "drift": e.drift, "n": e.n,
                "flagged": abs(e.drift) > self.log_threshold,
            })
        return rows

    def recommendation(self) -> Dict[str, Any]:
        flagged = self.flagged()
        return {
            "recalibrate": bool(flagged),
            "flagged": [e.nid for e in flagged],
            "plan_ratio": self.plan_ratio(),
            "plan_within_threshold": self.plan_within_threshold(),
            "runs": self.runs,
        }

    # -----------------------------------------------------------------
    def recalibrate(self, profile) -> List[str]:
        """Write flagged entries' observed EWMAs into ``profile``.

        Touches ONLY flagged entries (un-drifted measurements stay
        exactly as the sweep produced them) and returns the re-priced
        keys.  The profile's content hash — and with it
        ``CalibratedCostModel.version()`` and every plan-cache key —
        changes iff this returns a non-empty list.
        """
        updated = []
        for e in self.flagged():
            if e.profile_key is None:
                continue
            profile.put(e.profile_key,
                        e.ewma_observed_s / max(e.per_image_div, 1))
            updated.append(e.profile_key)
        return updated


# ----------------------------------------------------------------------
# the recalibration workflow
# ----------------------------------------------------------------------
class RestrictedCostModel(CostModel):
    """Delegate to an inner model, restricting conv primitives to an
    allowlist (everything else priced infinite, so the selection domain
    shrinks to the allowed names).

    The recalibration loop re-prices a primitive only once the solver
    has *selected* it — with the full ~60-primitive registry the solver
    hops to a new analytically-underpriced candidate every round and
    takes dozens of rounds to run the pool dry.  Demos and tests bound
    that exploration by restricting the candidate set; production
    serving does the same thing over time simply by having a sweep-
    calibrated profile where few candidates are grossly mispriced.
    """

    def __init__(self, inner: CostModel, allowed) -> None:
        self.inner = inner
        self.allowed = frozenset(allowed)

    def primitive_cost(self, prim, scn) -> float:
        if prim.name not in self.allowed:
            return float("inf")
        return self.inner.primitive_cost(prim, scn)

    def transform_cost(self, src, dst, shape_chw, dtype) -> float:
        return self.inner.transform_cost(src, dst, shape_chw, dtype)

    def fused_in_cost(self, prim, scn, l_src) -> float:
        return self.inner.fused_in_cost(prim, scn, l_src)

    def fused_out_cost(self, prim, scn, l_dst) -> float:
        return self.inner.fused_out_cost(prim, scn, l_dst)

    def hardware_spec(self):
        return self.inner.hardware_spec()

    def collective_cost(self, kind, nbytes, n) -> float:
        return self.inner.collective_cost(kind, nbytes, n)

    def version(self) -> str:
        return self.inner.version() + "+allow=" + \
            ",".join(sorted(self.allowed))


def recalibration_loop(net, raw_params, x, profile, *,
                       allowed=None, policy: Optional[BucketPolicy] = None,
                       threshold: float = 2.0, runs: int = 4,
                       max_rounds: int = 8, alpha: float = 0.3,
                       exact: bool = True) -> Dict[str, Any]:
    """Iterate solve → instrument → flag → recalibrate to a fixed point.

    One round: price the net with ``CalibratedCostModel(profile)``
    (optionally restricted to the ``allowed`` primitive names), solve,
    compile, run ``runs`` instrumented passes, and fold them into a
    fresh :class:`DriftDetector`.  If anything is flagged, write the
    flagged observations back into ``profile`` and go again — a newly
    priced entry can change the optimum, so the loop continues until a
    round produces no *recalibratable* flags (or ``max_rounds``).

    Returns ``{"rounds": [...], "selection", "detector", "converged"}``
    — ``converged`` means the final plan's every modeled term matched
    its observation within ``threshold``.  This is the workflow of
    docs/observability.md: run it once against an empty profile to
    calibrate from live traffic, and re-run it whenever the detector
    recommends recalibration.
    """
    from ..calibrate.model import CalibratedCostModel
    from ..core.plan import compile_plan
    from ..core.selection import select_pbqp

    policy = policy or BucketPolicy()
    rounds: List[Dict[str, Any]] = []
    sel = det = None
    for rnd in range(max_rounds):
        cost: CostModel = CalibratedCostModel(profile, policy=policy)
        if allowed is not None:
            cost = RestrictedCostModel(cost, allowed)
        sel = select_pbqp(net, cost, exact=exact)
        cnet = compile_plan(sel, raw_params)
        inst = InstrumentedNet(cnet)
        det = DriftDetector(cost, alpha=alpha, threshold=threshold,
                            policy=policy)
        for _ in range(runs):
            _, tm = inst(x)
            det.observe(sel, tm)
        flagged = det.flagged()
        rounds.append({
            "round": rnd,
            "primitives": {n.id: sel.choices[n.id].primitive.name
                           for n in net.conv_nodes()},
            "plan_ratio": det.plan_ratio(),
            "flagged": sorted(e.nid for e in flagged),
            "predicted_cost": sel.predicted_cost,
        })
        if not any(e.profile_key for e in flagged):
            break
        det.recalibrate(profile)
    return {"rounds": rounds, "selection": sel, "detector": det,
            "converged": det is not None and not det.flagged()}
