"""Observability: tracing, metrics, and cost-drift detection.

Three pillars (docs/observability.md):

* :mod:`repro.obs.trace` — request-scoped spans over the whole
  solve→compile→serve path, emitted as thread-safe JSONL;
* :mod:`repro.obs.metrics` — counter/gauge/histogram registry with
  latency percentiles and Prometheus-style text exposition;
* :mod:`repro.obs.drift` — instrumented per-node execution of compiled
  plans, predicted-vs-observed EWMA drift scores, and targeted
  recalibration of the flagged calibration entries.

``trace`` and ``metrics`` are stdlib-only so :mod:`repro.core` can
import them.  ``drift`` imports back into core/serving, so it is
loaded lazily here (module ``__getattr__``) — importing
:mod:`repro.obs` from inside core never recurses.
"""
from __future__ import annotations

from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      default_registry)
from .trace import Span, Tracer, configure, get_tracer

__all__ = [
    "Span", "Tracer", "get_tracer", "configure",
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "default_registry",
    "drift", "InstrumentedNet", "DriftDetector", "plan_predictions",
]

#: names resolved from the lazily-imported drift module
_DRIFT_NAMES = ("InstrumentedNet", "DriftDetector", "DriftEntry",
                "plan_predictions")


def __getattr__(name):
    if name == "drift" or name in _DRIFT_NAMES:
        import importlib
        drift = importlib.import_module(".drift", __name__)
        if name == "drift":
            return drift
        return getattr(drift, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
