"""Plan-cache serving subsystem.

Amortizes the two per-scenario costs the paper pays offline — the PBQP
solve and kernel compilation — across a *stream* of request shapes:

* :mod:`.bucketing`  — canonicalize shapes (and batch sizes) into a
  bounded bucket set;
* :mod:`.plan_cache` — persistent selections + compiled-executable LRU;
* :mod:`.server`     — the per-request :class:`PlanServer` dispatcher
  (bucket -> cache lookup -> (miss) warm-started solve + compile ->
  execute), the batched :meth:`PlanServer.infer_batch` path and the
  micro-batching admission queue, with hit/miss/latency counters in
  :mod:`.metrics`;
* :mod:`.scheduler`  — :class:`ContinuousScheduler`: continuous
  batching with per-request deadlines, SLO-aware partial launches and
  elastic worker scaling (docs/serving.md);
* :mod:`.towers`     — shape-parameterized demo nets for tests/examples.

See the "Serving architecture" section of the README for the design.
"""
from .bucketing import (
    BucketPolicy, bucket_key, bucket_scenario, bucket_shape, round_dim,
)
from .metrics import ServingCounters
from .plan_cache import (
    LRU, PlanDiskCache, plan_key, selection_from_payload,
    selection_to_payload,
)
from .scheduler import ContinuousScheduler
from .server import PlanServer
from .towers import (bottleneck_tower, conv_stack, conv_tower,
                     uniform_stack)

__all__ = [
    "BucketPolicy", "bucket_key", "bucket_shape", "bucket_scenario",
    "round_dim",
    "ServingCounters",
    "LRU", "PlanDiskCache", "plan_key",
    "selection_from_payload", "selection_to_payload",
    "ContinuousScheduler",
    "PlanServer", "conv_tower", "conv_stack", "bottleneck_tower",
    "uniform_stack",
]
