"""Continuous batching with SLO-aware scheduling over a PlanServer.

The micro-batching admission queue of PR 3 (``PlanServer.enqueue`` /
``flush``) is a *barrier*: everything enqueued waits for the next
``flush()`` call, all of it launches at once, and nothing else can
launch until the caller flushes again.  The batch-size-vs-latency
policy that implies — "batch = whatever arrived in one tick" — was an
accident of the serve loop's tick length, not a solved tradeoff.

:class:`ContinuousScheduler` replaces the barrier with *continuous*
batching: producers ``submit()`` single requests (optionally carrying a
deadline) and a dispatcher thread admits queued work into in-flight
bucket groups the moment a worker slot frees.  A bucket group launches
when the first of three triggers fires:

* **full** — the group reached the bucket policy's ``max_n``: the
  batched executable is maximally utilized, waiting longer buys
  nothing.
* **deadline** — the oldest queued request's slack (deadline minus now)
  dropped to ``safety ×`` the *modeled* latency of launching the group
  at its current size.  The model is the calibrated/analytic cost
  model's prediction for the bucket's plan (``SelectionResult.
  predicted_cost``) until the bucket has real samples, then the
  observed per-bucket p95 from the ``execute`` phase histograms in
  :mod:`repro.obs.metrics` — predicted-until-measured, the same
  fallback direction the cost tables use.
* **window** — ``batch_window_s`` elapsed since the oldest request
  queued.  This bounds the latency of deadline-less traffic and is the
  explicit batch-size-vs-p99 knob: a wider window coalesces more
  requests per invocation (throughput), a narrower one launches
  smaller batches sooner (tail latency).  docs/serving.md quantifies
  the tradeoff.

Launched groups execute through :meth:`~repro.serving.server.
PlanServer.infer_batch` on a worker pool whose size an
:class:`~repro.runtime.elastic.ElasticController` retargets every
dispatch round from observed backlog — scale up when queueing builds,
scale down after sustained calm — and the scheduler mirrors the target
into :meth:`~repro.serving.server.PlanServer.resize_workers` so the
server's prefetch pool tracks load too.

Everything the SLO story needs to be falsifiable is counted in the
server's :class:`~repro.serving.metrics.ServingCounters`: per-request
end-to-end latency histograms (``request`` phase, per batch bucket),
launch-reason counters, and ``deadline_met``/``deadline_miss`` whose
ratio is the *goodput* the load benchmark (benchmarks/bench_load.py)
gates in CI.
"""
from __future__ import annotations

import time
from collections import OrderedDict, deque
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass
from threading import Condition, Thread
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..reliability.errors import ShedError
from .bucketing import bucket_key, bucket_shape
from .metrics import LATENCY_METRIC

__all__ = ["ContinuousScheduler"]

Shape = Tuple[int, int, int]

#: launch trigger -> ServingCounters field
_REASON_COUNTER = {
    "full": "sched_full_launches",
    "deadline": "sched_deadline_launches",
    "window": "sched_window_launches",
}


@dataclass
class _Pending:
    """One queued request: payload, resolution future, timing."""
    x: np.ndarray
    fut: Future
    t_submit: float
    deadline: Optional[float]  # absolute perf_counter seconds, or None
    #: times this request was re-queued after its worker slot died
    #: (bounded at 1: a request that kills two workers is the poison)
    requeues: int = 0


class ContinuousScheduler:
    """SLO-aware continuous batcher over a :class:`PlanServer`.

    Parameters
    ----------
    server:
        The plan server whose ``infer_batch`` executes launched groups
        (and whose counters/registry record the scheduler's metrics).
    batch_window_s:
        Maximum time a deadline-less request waits for co-batchable
        arrivals before a partial batch launches anyway.
    slo_s:
        Default SLO applied to every ``submit`` that does not pass its
        own (None: no deadline unless the submit carries one).
    safety:
        Slack multiplier on the modeled batch latency: a deadline
        launch fires when ``slack <= safety * modeled``.  > 1 hedges
        model error toward meeting the deadline.
    elastic:
        Worker-pool policy (:class:`~repro.runtime.elastic.
        ElasticController`); a fresh single-worker..4-worker controller
        when None.
    min_model_samples:
        Observed ``execute`` samples a bucket needs before its
        histogram p95 replaces the cost model's prediction.
    shed:
        Deadline-aware load shedding (docs/reliability.md): a submit
        whose deadline the *modeled* backlog already makes unmeetable
        is rejected at admission with :class:`~repro.reliability.
        ShedError` instead of queued to certainly miss — an early typed
        "no" the client can retry elsewhere beats a late wrong "yes".
        Off by default (every request is admitted, deadline misses are
        counted, the PR 7 behavior).
    shed_safety:
        Multiplier on the modeled completion estimate the shed check
        compares against the deadline; > 1 sheds earlier (hedging model
        optimism), < 1 admits more marginal requests.
    """

    def __init__(self, server, *, batch_window_s: float = 0.02,
                 slo_s: Optional[float] = None, safety: float = 1.5,
                 elastic=None, min_model_samples: int = 3,
                 shed: bool = False, shed_safety: float = 1.0) -> None:
        if batch_window_s <= 0:
            raise ValueError(f"batch_window_s must be > 0, "
                             f"got {batch_window_s}")
        if elastic is None:
            # lazy import: repro.runtime pulls in the model stack, which
            # serving must not require at import time
            from ..runtime.elastic import ElasticController
            elastic = ElasticController()
        self.server = server
        self.policy = server.policy
        self.batch_window_s = float(batch_window_s)
        self.default_slo_s = slo_s
        self.safety = float(safety)
        self.min_model_samples = int(min_model_samples)
        self.shed = bool(shed)
        self.shed_safety = float(shed_safety)
        #: the server's chaos hook drives the scheduler's worker site
        #: too — one fault plan covers the whole serve stack
        self.fault_injector = getattr(server, "fault_injector", None)
        self.elastic = elastic
        self._queues: "OrderedDict[Shape, Deque[_Pending]]" = OrderedDict()
        self._cond = Condition()
        self._inflight = 0
        self._closed = False
        self._workers_applied = elastic.workers
        server.resize_workers(elastic.workers)
        self._exec = ThreadPoolExecutor(max_workers=elastic.max_workers,
                                        thread_name_prefix="sched-batch")
        self._dispatcher = Thread(target=self._dispatch_loop,
                                  name="sched-dispatch", daemon=True)
        self._dispatcher.start()

    # -----------------------------------------------------------------
    # producer side
    # -----------------------------------------------------------------
    def submit(self, x_chw: np.ndarray, *, slo_s: Optional[float] = None,
               deadline: Optional[float] = None) -> Future:
        """Queue one request; returns a Future resolving to its output
        dict (same payload as :meth:`PlanServer.infer`).

        ``slo_s`` turns into an absolute deadline ``now + slo_s``;
        ``deadline`` passes one directly (``time.perf_counter``
        seconds).  With neither (and no scheduler-level default), the
        request has no deadline and launches on the full/window
        triggers only.
        """
        x = np.asarray(x_chw, np.float32)
        if x.ndim != 3:
            raise ValueError(f"expected (C, H, W) input, got {x.shape}")
        now = time.perf_counter()
        if deadline is None:
            slo = slo_s if slo_s is not None else self.default_slo_s
            deadline = now + slo if slo is not None else None
        fut: Future = Future()
        bshape = bucket_shape(x.shape, self.policy)
        with self._cond:
            if self._closed:
                raise RuntimeError("ContinuousScheduler is closed")
            if self.shed and deadline is not None:
                eta = self._shed_eta_locked(bshape)
                if now + eta > deadline:
                    self.server.counters.add(shed_requests=1)
                    raise ShedError(eta, deadline - now)
            self._queues.setdefault(bshape, deque()).append(
                _Pending(x, fut, now, deadline))
            self.server.counters.add(sched_submits=1)
            self._cond.notify_all()
        return fut

    def _shed_eta_locked(self, bshape: Shape) -> float:
        """Modeled completion time for a request admitted *now*.

        Serial waves the backlog implies — this request's group, every
        group already queued (any bucket), and everything in flight,
        over the applied worker count — times the modeled latency of
        the request's own bucket.  Deliberately coarse: admission
        control needs a monotone load signal, not a simulation (the
        same modeled-latency source the deadline launch trigger uses,
        so the two SLO mechanisms agree on what "too slow" means).
        """
        qlen = len(self._queues.get(bshape, ()))
        est = self._modeled_latency(bshape,
                                    self.policy.bucket_n(qlen + 1))
        groups = 1 + self._inflight + sum(
            (len(q) + self.policy.max_n - 1) // self.policy.max_n
            for q in self._queues.values())
        waves = -(-groups // max(1, self._workers_applied))
        return self.shed_safety * est * waves

    def submit_many(self, xs: Sequence[np.ndarray], *,
                    slo_s: Optional[float] = None) -> List[Future]:
        """Submit a burst; same-bucket members co-batch naturally."""
        return [self.submit(x, slo_s=slo_s) for x in xs]

    def prewarm(self, shapes: Sequence[Shape],
                batches: Sequence[int] = (1,)) -> None:
        """Solve + compile the (bucket, batch-bucket) executables ahead
        of traffic (blocking).  Cold XLA compiles take longer than any
        sane SLO, so a server that cares about goodput warms the
        buckets its traffic mix will hit before opening the doors."""
        futs = [self.server.prefetch(s, n=n) for s in shapes
                for n in batches]
        for f in futs:
            f.result()

    # -----------------------------------------------------------------
    # dispatcher
    # -----------------------------------------------------------------
    def _dispatch_loop(self) -> None:
        while True:
            launches: List[Tuple[Shape, List[_Pending], str]] = []
            with self._cond:
                now = time.perf_counter()
                self._apply_elastic_locked()
                while self._inflight < self._workers_applied:
                    picked = self._pick_batch_locked(now)
                    if picked is None:
                        break
                    launches.append(picked)
                    self._inflight += 1
                if not launches:
                    if self._closed and not self._queued_locked() \
                            and self._inflight == 0:
                        return
                    self._cond.wait(timeout=self._next_wake_locked(now))
                    continue
            for bshape, group, reason in launches:
                self._exec.submit(self._run_batch, bshape, group, reason)

    def _queued_locked(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def _apply_elastic_locked(self) -> None:
        queued = self._queued_locked()
        target = self.elastic.desired_workers(queued, self._inflight)
        reg = self.server.counters.registry
        reg.gauge("sched_queue_depth").set(queued)
        reg.gauge("sched_workers").set(target)
        if target != self._workers_applied:
            self._workers_applied = target
            self.server.counters.add(worker_resizes=1)
            self.server.resize_workers(target)

    def _launch_at(self, bshape: Shape, q: "Deque[_Pending]",
                   now: float) -> Tuple[float, str]:
        """Earliest time this bucket's group should launch, and why.

        ``-inf`` (full group, or draining on close) means "now".  The
        deadline trigger backs off the oldest deadline by ``safety ×``
        the modeled latency of the group at its *current* size — as
        arrivals grow the group, both the trigger time and the batch
        it would launch are re-evaluated every round.
        """
        if len(q) >= self.policy.max_n or self._closed:
            return -np.inf, "full" if len(q) >= self.policy.max_n \
                else "window"
        head = q[0]
        at = head.t_submit + self.batch_window_s
        reason = "window"
        deadlines = [p.deadline for p in q if p.deadline is not None]
        if deadlines:
            est = self._modeled_latency(bshape,
                                        self.policy.bucket_n(len(q)))
            dl_at = min(deadlines) - self.safety * est
            if dl_at < at:
                at, reason = dl_at, "deadline"
        return at, reason

    def _pick_batch_locked(self, now: float
                           ) -> Optional[Tuple[Shape, List[_Pending], str]]:
        """Pop the most overdue launchable bucket group, if any."""
        best: Optional[Tuple[float, Shape, str]] = None
        for bshape, q in self._queues.items():
            if not q:
                continue
            at, reason = self._launch_at(bshape, q, now)
            if at <= now and (best is None or at < best[0]):
                best = (at, bshape, reason)
        if best is None:
            return None
        _, bshape, reason = best
        q = self._queues[bshape]
        group = [q.popleft() for _ in range(min(len(q),
                                                self.policy.max_n))]
        if not q:
            del self._queues[bshape]
        return bshape, group, reason

    def _next_wake_locked(self, now: float) -> Optional[float]:
        """Sleep until the earliest pending trigger (None: until
        notified — nothing is queued, so only a submit or a completion
        can create work)."""
        soonest: Optional[float] = None
        for bshape, q in self._queues.items():
            if not q:
                continue
            at, _ = self._launch_at(bshape, q, now)
            if soonest is None or at < soonest:
                soonest = at
        if soonest is None:
            return None
        return min(max(soonest - now, 1e-3), 1.0)

    # -----------------------------------------------------------------
    # latency model
    # -----------------------------------------------------------------
    def _modeled_latency(self, bshape: Shape, nb: int) -> float:
        """Expected wall time of one batched invocation of this bucket.

        Observed per-bucket ``execute`` p95 once the bucket has
        ``min_model_samples`` real samples; before that, the cost
        model's prediction for the bucket's solved plan (which is a
        memory-cached dict hit after the bucket's first solve).
        """
        h = self.server.counters.registry.find_histogram(
            LATENCY_METRIC, phase="execute",
            bucket=bucket_key(bshape, nb))
        if h is not None and h.count >= self.min_model_samples:
            return max(float(h.percentile(95)), 1e-6)
        try:
            sel = self.server.plan_for(bshape, n=nb)
            return max(float(sel.predicted_cost), 1e-6)
        except Exception:
            # an unpriceable bucket must not kill the dispatcher; treat
            # its latency as one batching window (conservative: the
            # deadline trigger then fires a window early)
            return self.batch_window_s

    # -----------------------------------------------------------------
    # worker side
    # -----------------------------------------------------------------
    def _run_batch(self, bshape: Shape, group: List[_Pending],
                   reason: str) -> None:
        if self.fault_injector is not None:
            spec = self.fault_injector.check(
                "worker", key=bucket_key(bshape,
                                         self.policy.bucket_n(len(group))))
            if spec is not None:
                self._worker_died(bshape, group, spec)
                return
        try:
            outs = self.server.infer_batch([p.x for p in group])
        except BaseException as exc:  # noqa: BLE001 — must resolve futs
            for p in group:
                p.fut.set_exception(exc)
        else:
            done = time.perf_counter()
            bkey = bucket_key(bshape,
                              self.policy.bucket_n(len(group)))
            met = miss = 0
            for p in group:
                self.server.counters.add(_bucket=bkey,
                                         request_s=done - p.t_submit)
                if p.deadline is not None:
                    if done <= p.deadline:
                        met += 1
                    else:
                        miss += 1
            self.server.counters.add(
                sched_batches=1, deadline_met=met, deadline_miss=miss,
                **{_REASON_COUNTER[reason]: 1})
            for p, out in zip(group, outs):
                p.fut.set_result(out)
        finally:
            with self._cond:
                self._inflight -= 1
                self._cond.notify_all()

    def _worker_died(self, bshape: Shape, group: List[_Pending],
                     spec) -> None:
        """An injected worker-slot death mid-dispatch.

        The group's requests go back to the *front* of their bucket
        queue (they are the oldest work — deadline ordering must hold),
        each at most once: a request that has already killed a worker
        is treated as the poison and fails with
        :class:`~repro.reliability.InjectedFault` rather than cycling
        through the pool forever.
        """
        from ..reliability.errors import InjectedFault
        self.server.counters.add(worker_deaths=1)
        requeued = 0
        with self._cond:
            q = self._queues.setdefault(bshape, deque())
            for p in reversed(group):
                if p.requeues < 1:
                    p.requeues += 1
                    q.appendleft(p)
                    requeued += 1
                else:
                    p.fut.set_exception(InjectedFault(
                        "worker", spec.kind, spec.match))
            if not q:
                del self._queues[bshape]
            self._inflight -= 1
            self._cond.notify_all()
        if requeued:
            self.server.counters.add(worker_requeues=requeued)

    # -----------------------------------------------------------------
    def stats(self) -> Dict[str, float]:
        """Server stats plus the scheduler's live queue/worker view."""
        d = self.server.stats()
        with self._cond:
            d["sched_queued"] = self._queued_locked()
            d["sched_inflight"] = self._inflight
            d["sched_workers"] = self._workers_applied
        return d

    def close(self, drain: bool = True, timeout: float = 60.0) -> None:
        """Stop the dispatcher.  ``drain=True`` (default) launches
        everything still queued first, so no submitted future is left
        unresolved; ``drain=False`` cancels queued work instead."""
        with self._cond:
            if self._closed:
                return
            self._closed = True
            if not drain:
                for q in self._queues.values():
                    for p in q:
                        p.fut.cancel()
                self._queues.clear()
            self._cond.notify_all()
        self._dispatcher.join(timeout=timeout)
        self._exec.shutdown(wait=True)
