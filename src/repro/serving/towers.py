"""Shape-parameterized demo networks for the plan server.

A :class:`~repro.serving.server.PlanServer` needs a *net builder*: a
callable mapping a bucket shape (C, H, W) to a :class:`~repro.core.
graph.Net`.  Any of the paper's networks work (``lambda s: vgg("A")``
ignores the shape); these small towers are sized for tests, examples and
the vision-token bridge in the LM serving loop, where compiling VGG per
bucket would dominate the demo.

Crucially, the builder must return the *same node ids* for every shape —
that is what lets the server warm-start a new bucket's PBQP solve from a
neighbouring bucket's optimum.
"""
from __future__ import annotations

from typing import Tuple

from ..core.graph import Net, fc, global_avgpool, maxpool, relu

__all__ = ["conv_tower", "conv_stack", "uniform_stack",
           "bottleneck_tower"]


def conv_tower(shape_chw: Tuple[int, int, int], *, depth: int = 3,
               width: int = 16, k: int = 3, features: int = 64) -> Net:
    """A small conv/relu/pool tower ending in a feature vector.

    Channel width doubles per stage; spatial size halves per stage.  For
    inputs with ``min(h, w) >= 2**depth`` (guarantee it via the bucket
    policy's ``min_hw``) node ids depend only on ``depth``, never on the
    input shape, so selections for neighbouring buckets line up; smaller
    inputs drop the trailing pools (and warm starts degrade to cold
    solves, which is correct, just slower).
    """
    c, h, w = shape_chw
    net = Net(f"tower{depth}w{width}")
    x = net.input("data", (c, h, w))
    for i in range(depth):
        m = width << i
        x = net.conv(f"conv{i}", x, k=k, m=m, pad=k // 2)
        x = net.op(f"relu{i}", [x], relu())
        _, ch, cw = net.nodes[x].out_shape
        if min(ch, cw) >= 2:  # pool whenever legal (2x2, stride 2)
            x = net.op(f"pool{i}", [x], maxpool(2, 2))
    x = net.op("gap", [x], global_avgpool())
    net.op("feat", [x], fc(features))
    return net


def conv_stack(shape_chw: Tuple[int, int, int], *, depth: int = 2,
               width: int = 8, k: int = 3) -> Net:
    """A conv/relu stack that *keeps spatial extent* (stride 1, "same"
    pad, no pooling/GAP/FC).

    Its outputs are (M, H, W) feature maps, which makes it the right
    fixture for everything that reasons about spatial cropping: a
    request zero-padded into its bucket produces, after cropping, the
    same values as a run at the request's own shape (weights depend only
    on (C, K, M), so bucket-net and request-net share them when C
    matches).  Also the throughput fixture for the batched-serving
    benchmark, where global ops would hide the conv work.
    """
    c, h, w = shape_chw
    net = Net(f"stack{depth}w{width}")
    x = net.input("data", (c, h, w))
    for i in range(depth):
        m = width << i
        x = net.conv(f"conv{i}", x, k=k, m=m, pad=k // 2)
        x = net.op(f"relu{i}", [x], relu())
    return net


def uniform_stack(shape_chw: Tuple[int, int, int], *, depth: int = 4,
                  k: int = 3) -> Net:
    """A *shape-preserving* conv/relu chain: every layer maps
    ``(C, H, W) -> (C, H, W)`` (``m == c``, stride 1, "same" pad).

    This is the pipelineable fixture: a single linear chain whose
    activations all share one shape, which is exactly what
    :func:`~repro.core.selection.pp_chain` demands — the pipeline
    executor rotates a fixed-shape carry between stages.  The pp
    placement axis is only ever *offered* on nets like this one.
    """
    c, h, w = shape_chw
    net = Net(f"uniform{depth}c{c}")
    x = net.input("data", (c, h, w))
    for i in range(depth):
        x = net.conv(f"conv{i}", x, k=k, m=c, pad=k // 2)
        x = net.op(f"relu{i}", [x], relu())
    return net


def bottleneck_tower(shape_chw: Tuple[int, int, int], *,
                     head_depth: int = 3, head_width: int = 8,
                     body_depth: int = 2, body_width: int = 512,
                     k: int = 3) -> Net:
    """A tower built to exceed one device's arithmetic-intensity sweet
    spot: a thin widening head shrinks the spatial extent to 1x1, then
    fat ``body_width``-channel convs run at 1x1 spatial — each body
    layer streams a ``body_width^2 k^2`` weight tensor over almost no
    activations, so it is *weight-bandwidth* bound.  dp replicates
    those weights on every device and gains nothing; tp shards them
    ``D_tp`` ways and cuts the per-device traffic by the same factor —
    the mixed tp+dp-beats-pure-dp headline fixture of
    ``benchmarks/bench_parallelism.py``.
    """
    c, h, w = shape_chw
    net = Net(f"bottleneck{head_depth}x{body_depth}w{body_width}")
    x = net.input("data", (c, h, w))
    for i in range(head_depth):
        m = head_width << i
        x = net.conv(f"head{i}", x, k=k, m=m, pad=k // 2)
        x = net.op(f"hrelu{i}", [x], relu())
        _, ch, cw = net.nodes[x].out_shape
        if min(ch, cw) >= 2:
            x = net.op(f"hpool{i}", [x], maxpool(2, 2))
    # crush whatever spatial extent remains to 1x1
    _, ch, cw = net.nodes[x].out_shape
    if min(ch, cw) >= 2:
        x = net.op("crush", [x], maxpool(min(ch, cw), min(ch, cw)))
    for i in range(body_depth):
        x = net.conv(f"body{i}", x, k=k, m=body_width, pad=k // 2)
        x = net.op(f"brelu{i}", [x], relu())
    return net
