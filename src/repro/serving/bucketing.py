"""Scenario bucketing: canonicalize a stream of request shapes.

The paper solves primitive selection once, offline, for a fixed scenario
tuple {C, H, W, delta, K, M}.  A server sees *arbitrary* input shapes; a
separate PBQP solve + kernel compile per exact shape would make plan
count (and XLA executable count) grow without bound.  Bucketing rounds
every incoming (C, H, W) request shape up to a canonical bucket shape —
by default to powers of two, clamped to a configurable range — so the
set of distinct plans stays small and every request maps onto one.

Rounding is always *up*: a request is embedded into its bucket by zero
padding (never cropped), so the bucketed network dominates the request
spatially.  A shape larger than ``max_*`` keeps its rounded value rather
than being cropped — boundedness is a traffic assumption, correctness is
not negotiable.

The bucket is also the serving stack's *co-batching equivalence
relation*: requests sharing a bucket shape can share one batched
executable invocation, which is what :meth:`~repro.serving.server.
PlanServer.infer_batch` groups by and what the continuous scheduler
(:mod:`repro.serving.scheduler`) keys its pending queues on — so
``max_n`` doubles as the scheduler's full-group launch threshold, and
``bucket_n`` prices the batch a group *would* launch at when its
deadline slack is evaluated (docs/serving.md).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

from ..core.scenario import Scenario

__all__ = ["BucketPolicy", "bucket_shape", "bucket_key", "round_dim",
           "bucket_scenario"]


def _next_pow2(n: int) -> int:
    return 1 << (max(n, 1) - 1).bit_length()


def _round_up(v: int, mode: str, step: int, lo: int, hi: int) -> int:
    if mode == "exact":
        return max(v, 1)
    if mode == "pow2":
        r = _next_pow2(max(v, lo))
    elif mode == "linear":
        r = -(-max(v, lo) // step) * step
    else:
        raise ValueError(f"unknown bucketing mode {mode!r}")
    # clamp to the configured ceiling, but never below the request itself
    return max(min(r, hi), v)


@dataclass(frozen=True)
class BucketPolicy:
    """How request shapes collapse into buckets.

    ``spatial`` / ``channel`` / ``batch`` modes: ``"pow2"`` (round up to
    a power of two — log-many buckets over any traffic), ``"linear"``
    (round up to a multiple of ``*_step``), ``"exact"`` (no rounding;
    one bucket per distinct shape — plan count unbounded, useful for
    benchmarks).

    The ``batch`` axis buckets minibatch sizes the same way spatial
    dims bucket: a group of N coalesced same-bucket requests runs on
    the executable compiled for the N-bucket (zero rows pad the batch),
    so the number of distinct batched executables stays logarithmic in
    the largest batch.  Like every other axis, rounding never goes
    *down*: a batch above ``max_n`` keeps its own size rather than
    being clamped (boundedness is a traffic assumption — the server's
    ``infer_batch`` chunks groups at ``max_n``, so it never requests
    such a bucket; correctness is not negotiable).
    """

    spatial: str = "pow2"
    channel: str = "pow2"
    batch: str = "pow2"
    spatial_step: int = 32
    channel_step: int = 16
    batch_step: int = 4
    min_hw: int = 8
    max_hw: int = 512
    min_c: int = 1
    max_c: int = 1024
    min_n: int = 1
    max_n: int = 64

    def bucket(self, shape_chw: Tuple[int, int, int]) -> Tuple[int, int, int]:
        return bucket_shape(shape_chw, self)

    def bucket_n(self, n: int) -> int:
        """Canonical batch bucket for a group of ``n`` requests
        (round-up-only, like :func:`bucket_shape`: above ``max_n`` the
        request's own size wins — clamping *down* would price or
        compile a smaller batch than is actually running).
        """
        if n < 1:
            raise ValueError(f"bad batch size {n}")
        return _round_up(n, self.batch, self.batch_step,
                         self.min_n, self.max_n)


def bucket_shape(shape_chw: Tuple[int, int, int],
                 policy: BucketPolicy) -> Tuple[int, int, int]:
    """Canonical bucket shape (>= request in every dimension)."""
    c, h, w = (int(v) for v in shape_chw)
    if min(c, h, w) < 1:
        raise ValueError(f"bad request shape {shape_chw}")
    return (
        _round_up(c, policy.channel, policy.channel_step,
                  policy.min_c, policy.max_c),
        _round_up(h, policy.spatial, policy.spatial_step,
                  policy.min_hw, policy.max_hw),
        _round_up(w, policy.spatial, policy.spatial_step,
                  policy.min_hw, policy.max_hw),
    )


def bucket_key(bucket_chw: Tuple[int, int, int], n: int = 1) -> str:
    """Human-readable stable key for a bucket (used in cache file names).

    The batch bucket is appended only for ``n > 1`` so single-image keys
    (and the plans persisted under them before the batch axis existed)
    are unchanged.
    """
    c, h, w = bucket_chw
    base = f"c{c}h{h}w{w}"
    return base if n == 1 else f"{base}n{n}"


def round_dim(v: int, mode: str, step: int, lo: int, hi: int) -> int:
    """Round one dimension up under a bucketing mode (public helper).

    Same semantics as the per-axis rounding inside :func:`bucket_shape`:
    never below the request value, clamped to ``hi`` only when the
    request itself fits under it.
    """
    return _round_up(v, mode, step, lo, hi)


def bucket_scenario(scn: Scenario, policy: BucketPolicy) -> Scenario:
    """Canonicalize a convolution scenario into its calibration bucket.

    The spatial/channel input dimensions round up exactly like request
    shapes (:func:`bucket_shape`); the output-channel count M rounds
    under the channel mode; the minibatch rounds under the batch mode
    (:meth:`BucketPolicy.bucket_n`).  Stride, kernel radix, padding and
    dtype are preserved — they change which primitives even apply, so
    they are bucket identity, not something to round.  Used by
    :class:`repro.calibrate.CalibratedCostModel` to map arbitrary
    per-layer scenarios onto the finite grid a
    :class:`~repro.calibrate.HardwareProfile` was measured on.
    """
    c, h, w = bucket_shape(scn.in_shape_chw, policy)
    m = round_dim(scn.m, policy.channel, policy.channel_step,
                  policy.min_c, policy.max_c)
    return scn.with_(c=c, h=h, w=w, m=m, n=policy.bucket_n(scn.n))
