"""Scenario bucketing: canonicalize a stream of request shapes.

The paper solves primitive selection once, offline, for a fixed scenario
tuple {C, H, W, delta, K, M}.  A server sees *arbitrary* input shapes; a
separate PBQP solve + kernel compile per exact shape would make plan
count (and XLA executable count) grow without bound.  Bucketing rounds
every incoming (C, H, W) request shape up to a canonical bucket shape —
by default to powers of two, clamped to a configurable range — so the
set of distinct plans stays small and every request maps onto one.

Rounding is always *up*: a request is embedded into its bucket by zero
padding (never cropped), so the bucketed network dominates the request
spatially.  A shape larger than ``max_*`` keeps its rounded value rather
than being cropped — boundedness is a traffic assumption, correctness is
not negotiable.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

__all__ = ["BucketPolicy", "bucket_shape", "bucket_key"]


def _next_pow2(n: int) -> int:
    return 1 << (max(n, 1) - 1).bit_length()


def _round_up(v: int, mode: str, step: int, lo: int, hi: int) -> int:
    if mode == "exact":
        return max(v, 1)
    if mode == "pow2":
        r = _next_pow2(max(v, lo))
    elif mode == "linear":
        r = -(-max(v, lo) // step) * step
    else:
        raise ValueError(f"unknown bucketing mode {mode!r}")
    # clamp to the configured ceiling, but never below the request itself
    return max(min(r, hi), v)


@dataclass(frozen=True)
class BucketPolicy:
    """How request shapes collapse into buckets.

    ``spatial`` / ``channel`` modes: ``"pow2"`` (round up to a power of
    two — log-many buckets over any traffic), ``"linear"`` (round up to a
    multiple of ``*_step``), ``"exact"`` (no rounding; one bucket per
    distinct shape — plan count unbounded, useful for benchmarks).
    """

    spatial: str = "pow2"
    channel: str = "pow2"
    spatial_step: int = 32
    channel_step: int = 16
    min_hw: int = 8
    max_hw: int = 512
    min_c: int = 1
    max_c: int = 1024

    def bucket(self, shape_chw: Tuple[int, int, int]) -> Tuple[int, int, int]:
        return bucket_shape(shape_chw, self)


def bucket_shape(shape_chw: Tuple[int, int, int],
                 policy: BucketPolicy) -> Tuple[int, int, int]:
    """Canonical bucket shape (>= request in every dimension)."""
    c, h, w = (int(v) for v in shape_chw)
    if min(c, h, w) < 1:
        raise ValueError(f"bad request shape {shape_chw}")
    return (
        _round_up(c, policy.channel, policy.channel_step,
                  policy.min_c, policy.max_c),
        _round_up(h, policy.spatial, policy.spatial_step,
                  policy.min_hw, policy.max_hw),
        _round_up(w, policy.spatial, policy.spatial_step,
                  policy.min_hw, policy.max_hw),
    )


def bucket_key(bucket_chw: Tuple[int, int, int]) -> str:
    """Human-readable stable key for a bucket (used in cache file names)."""
    c, h, w = bucket_chw
    return f"c{c}h{h}w{w}"
