"""Serving counters: hit/miss/latency accounting for the plan cache.

One :class:`ServingCounters` per :class:`~repro.serving.server.
PlanServer`.  Everything the plan-cache benchmark and the acceptance
tests assert on lives here — e.g. "two requests in the same bucket
trigger exactly one PBQP solve and one compile" is
``counters.solves == 1 and counters.compiles == 1``.

Since the observability PR this is a *view* over a
:class:`repro.obs.metrics.MetricsRegistry` rather than a bag of ints
behind one lock: every count is a registry :class:`~repro.obs.metrics.
Counter` (still exactly-once under concurrency — the threaded hammer in
tests/test_observability.py pins that down) and every ``*_s`` wall-time
field additionally feeds per-phase latency *histograms*, so
:meth:`PlanServer.stats` can report p50/p95/p99 per phase (and per
batch bucket) instead of only accumulated totals.  The ``snapshot()``
keys and int-ness are unchanged — callers of the old dataclass see the
same dict.
"""
from __future__ import annotations

from typing import Dict, Optional

from ..obs.metrics import MetricsRegistry

__all__ = ["ServingCounters", "COUNT_FIELDS", "TIME_FIELDS",
           "LATENCY_METRIC"]

#: monotonically-counted events (ints in ``snapshot()``)
COUNT_FIELDS = (
    "requests",
    # plan lookups that hit (memory or disk) vs required a PBQP solve
    "plan_mem_hits", "plan_disk_hits", "plan_misses",
    # compiled-executable LRU
    "exec_hits", "exec_misses", "exec_evictions",
    # batched execution: executable invocations serving > 0 requests
    # each, and how many requests shared an invocation with another
    "batch_calls", "coalesced",
    # solver / compiler work actually performed
    "solves", "warm_solves", "compiles", "mesh_compiles",
    # continuous-batching scheduler (repro.serving.scheduler): requests
    # submitted, batches launched, and why each batch launched — the
    # group filled its batch bucket, the oldest request's deadline
    # slack crossed the modeled batch latency, or the batching window
    # expired with no other trigger
    "sched_submits", "sched_batches",
    "sched_full_launches", "sched_deadline_launches",
    "sched_window_launches",
    # per-request SLO accounting (requests that carried a deadline)
    "deadline_met", "deadline_miss",
    # elastic worker-pool resizes applied by the scheduler
    "worker_resizes",
    # --- reliability layer (repro.reliability, docs/reliability.md) ---
    # corrupt/truncated/stale-schema plan-cache files detected (and
    # deleted) on read; each one re-solves
    "plan_cache_corrupt",
    # fallback-ladder rung served per plan selection: exact PBQP,
    # anytime (deadline/budget-degraded solve), greedy local-optimal,
    # or the solver-free reference plan
    "ladder_exact", "ladder_anytime", "ladder_greedy", "ladder_reference",
    # compile attempts retried after a transient failure, and plans
    # demoted down the ladder because every retry failed
    "compile_retries", "compile_fallbacks",
    # guarded-execution failures (crash or non-finite outputs), and
    # (primitive, bucket) circuit-breaker trips they caused
    "kernel_failures", "quarantines",
    # admission control: requests rejected because the modeled backlog
    # made their deadline unmeetable (scheduler shed=True)
    "shed_requests",
    # scheduler worker slots that died mid-dispatch, and the requests
    # re-queued (once each) to survive them
    "worker_deaths", "worker_requeues",
)
#: accumulated wall time (seconds); each also records one histogram
#: sample per ``add`` under phase = field name minus the ``_s`` suffix
#: (``request_s`` is the scheduler's submit -> result latency, i.e.
#: queueing + batching + execution as one end-to-end sample)
TIME_FIELDS = ("solve_s", "compile_s", "execute_s", "request_s")
#: histogram metric name the phase/bucket latency samples land in
LATENCY_METRIC = "serving_latency_seconds"


class ServingCounters:
    """Registry-backed serving counters (same ``add``/``snapshot`` API
    as the pre-observability dataclass, plus latency percentiles).

    ``add(..., _bucket="8x3x32x32")`` labels the wall-time histogram
    samples of that call with the batch bucket, so percentiles can be
    split per bucket; the scalar accumulation is unaffected.
    """

    def __init__(self, registry: Optional[MetricsRegistry] = None) -> None:
        #: the backing registry — shared with the owning PlanServer so
        #: ``stats()`` and Prometheus exposition read the same store
        self.registry = registry if registry is not None \
            else MetricsRegistry()
        for f in COUNT_FIELDS + TIME_FIELDS:
            self.registry.counter(f)

    def __getattr__(self, name: str):
        # attribute reads (`counters.solves`) keep working on the view
        if name in COUNT_FIELDS or name in TIME_FIELDS:
            return self.registry.counter(name).value
        raise AttributeError(name)

    def add(self, _bucket: Optional[str] = None, **kw) -> None:
        for k, v in kw.items():
            if k in COUNT_FIELDS:
                if v:
                    self.registry.counter(k).add(int(v))
            elif k in TIME_FIELDS:
                self.registry.counter(k).add(float(v))
                phase = k[:-2]
                self.registry.histogram(
                    LATENCY_METRIC, phase=phase).record(float(v))
                if _bucket is not None:
                    self.registry.histogram(
                        LATENCY_METRIC, phase=phase,
                        bucket=_bucket).record(float(v))
            else:
                raise AttributeError(f"unknown counter {k!r}")

    # -----------------------------------------------------------------
    def snapshot(self) -> Dict[str, float]:
        d: Dict[str, float] = {}
        for f in COUNT_FIELDS:
            d[f] = int(self.registry.counter(f).value)
        for f in TIME_FIELDS:
            d[f] = float(self.registry.counter(f).value)
        d["plan_hits"] = d["plan_mem_hits"] + d["plan_disk_hits"]
        total = d["plan_hits"] + d["plan_misses"]
        d["plan_hit_rate"] = d["plan_hits"] / total if total else 0.0
        total = d["exec_hits"] + d["exec_misses"]
        d["exec_hit_rate"] = d["exec_hits"] / total if total else 0.0
        # goodput: deadline-met fraction over deadline-carrying requests
        total = d["deadline_met"] + d["deadline_miss"]
        d["goodput"] = d["deadline_met"] / total if total else 1.0
        # degradations: selections served from any rung below exact
        d["ladder_demotions"] = (d["ladder_anytime"] + d["ladder_greedy"]
                                 + d["ladder_reference"])
        return d

    def phase_quantiles(self) -> Dict[str, Dict[str, float]]:
        """Per-phase (and per phase+bucket) latency percentiles.

        Returns ``{"solve": {"count", "p50", "p95", "p99", ...},
        "execute[bucket=8x3x32x32]": {...}, ...}`` — one entry per
        phase histogram that has recorded at least one sample.
        """
        out: Dict[str, Dict[str, float]] = {}
        for key, snap in self.registry.snapshot().items():
            if not key.startswith(LATENCY_METRIC) or \
                    not isinstance(snap, dict) or not snap.get("count"):
                continue
            labels = dict(
                kv.split("=", 1) for kv in
                key[len(LATENCY_METRIC):].strip("{}").replace('"', "")
                .split(",") if "=" in kv)
            name = labels.pop("phase", "?")
            if labels:
                name += "[" + ",".join(f"{k}={v}" for k, v in
                                       sorted(labels.items())) + "]"
            out[name] = snap
        return out
