"""Serving counters: hit/miss/latency accounting for the plan cache.

One mutable :class:`ServingCounters` per :class:`~repro.serving.server.
PlanServer`.  Everything the plan-cache benchmark and the acceptance
tests assert on lives here — e.g. "two requests in the same bucket
trigger exactly one PBQP solve and one compile" is
``counters.solves == 1 and counters.compiles == 1``.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict

__all__ = ["ServingCounters"]


@dataclass
class ServingCounters:
    requests: int = 0
    #: plan lookups that hit (memory or disk) vs required a PBQP solve
    plan_mem_hits: int = 0
    plan_disk_hits: int = 0
    plan_misses: int = 0
    #: compiled-executable LRU
    exec_hits: int = 0
    exec_misses: int = 0
    exec_evictions: int = 0
    #: batched execution: executable invocations serving > 0 requests
    #: each, and how many requests shared an invocation with another
    batch_calls: int = 0
    coalesced: int = 0
    #: solver / compiler work actually performed
    solves: int = 0
    warm_solves: int = 0          # of which seeded by a neighbouring bucket
    compiles: int = 0
    #: of which emitted mesh-sharded (dp-placement-carrying) executables
    mesh_compiles: int = 0
    #: accumulated wall time (seconds)
    solve_s: float = 0.0
    compile_s: float = 0.0
    execute_s: float = 0.0
    _lock: threading.Lock = field(default_factory=threading.Lock,
                                  repr=False)

    def add(self, **kw) -> None:
        with self._lock:
            for k, v in kw.items():
                setattr(self, k, getattr(self, k) + v)

    def snapshot(self) -> Dict[str, float]:
        with self._lock:
            d = {k: v for k, v in self.__dict__.items()
                 if not k.startswith("_")}
        d["plan_hits"] = d["plan_mem_hits"] + d["plan_disk_hits"]
        total = d["plan_hits"] + d["plan_misses"]
        d["plan_hit_rate"] = d["plan_hits"] / total if total else 0.0
        total = d["exec_hits"] + d["exec_misses"]
        d["exec_hit_rate"] = d["exec_hits"] / total if total else 0.0
        return d
