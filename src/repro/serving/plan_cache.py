"""Persistent plan cache: PBQP selections on disk, executables in memory.

Two tiers with very different economics:

* **Disk tier** — a :class:`SelectionResult` is a few hundred bytes of
  JSON (per-node primitive names + layouts + conversion chains).  It is
  keyed by ``(net fingerprint, bucket key, cost-model version)`` hashed
  into a file name, so a changed network, a different bucket, or a bumped
  cost model each miss cleanly instead of serving a stale plan.

* **Memory tier** — compiled executables (:class:`~repro.core.plan.
  CompiledNet`) hold XLA programs and packed weights; they are *not*
  serializable and are the expensive artifact.  A small LRU
  (:class:`LRU`) bounds live executables while hot buckets stay resident.

The JSON payload stores primitive *names*; deserialization resolves them
against the live registry and fails loudly (``KeyError``) if a plan
references a primitive that no longer exists — which is exactly the
cost-model-version bump case the key is meant to prevent.
"""
from __future__ import annotations

import hashlib
import json
import logging
import pathlib
from collections import OrderedDict
from typing import Any, Callable, Dict, List, Optional, Tuple

from ..core.graph import Net
from ..core.ioutil import atomic_write_text
from ..core.primitives import registry
from ..core.selection import Choice, Placement, SelectionResult

__all__ = ["PLAN_SCHEMA", "plan_key", "selection_to_payload",
           "selection_from_payload", "PlanDiskCache", "LRU"]

#: bump when the payload format below changes shape
#: 2: per-edge fused realizations ("fusions") joined the payload; v1
#:    plans predate fused-edge pricing and must re-solve
#: 3: per-node device placements joined the choices (the unified
#:    choice-space mesh axis); v2 plans predate placement solving
#: 4: placements grew structure — tp and pp<stage> joined {dp, rep}
#:    and round-trip as their canonical strings; v3 plans were solved
#:    over the two-kind domain and must re-solve
PLAN_SCHEMA = 4


def plan_key(net_fingerprint: str, bucket_key: str,
             cost_version: str) -> str:
    """Cache key: every component that could change the optimal plan."""
    raw = f"{PLAN_SCHEMA}|{net_fingerprint}|{bucket_key}|{cost_version}"
    return hashlib.sha256(raw.encode()).hexdigest()[:24]


# ----------------------------------------------------------------------
# SelectionResult <-> JSON
# ----------------------------------------------------------------------
def selection_to_payload(sel: SelectionResult) -> Dict[str, Any]:
    return {
        "schema": PLAN_SCHEMA,
        "choices": {
            nid: [ch.primitive.name if ch.primitive else None,
                  ch.l_in, ch.l_out, ch.placement]
            for nid, ch in sel.choices.items()},
        "conversions": [[src, dst, chain]
                        for (src, dst), chain in sel.conversions.items()],
        "fusions": [[src, dst, kind]
                    for (src, dst), kind in sel.fusions.items()],
        "predicted_cost": sel.predicted_cost,
        "optimal": sel.optimal,
        "strategy": sel.strategy,
        "solver_stats": dict(sel.solver_stats),
    }


def selection_from_payload(payload: Dict[str, Any],
                           net: Net) -> SelectionResult:
    if payload.get("schema") != PLAN_SCHEMA:
        raise ValueError(f"plan schema {payload.get('schema')} != "
                         f"{PLAN_SCHEMA}")
    by_name = {p.name: p for p in registry()}
    choices: Dict[str, Choice] = {}
    for nid, (pname, l_in, l_out, placement) in payload["choices"].items():
        prim = by_name[pname] if pname is not None else None
        # placements persist as canonical strings ("rep", "dp", "tp",
        # "pp<stage>"); parse restores the structured form
        choices[nid] = Choice(prim, l_in, l_out,
                              Placement.parse(str(placement)))
    conversions: Dict[Tuple[str, str], List[str]] = {
        (src, dst): list(chain)
        for src, dst, chain in payload["conversions"]}
    fusions: Dict[Tuple[str, str], str] = {
        (src, dst): str(kind)
        for src, dst, kind in payload["fusions"]}
    return SelectionResult(
        net=net, choices=choices, conversions=conversions,
        predicted_cost=float(payload["predicted_cost"]),
        optimal=bool(payload["optimal"]),
        strategy=str(payload["strategy"]),
        solver_stats={k: int(v)
                      for k, v in payload["solver_stats"].items()},
        fusions=fusions)


# ----------------------------------------------------------------------
_log = logging.getLogger(__name__)


class PlanDiskCache:
    """One JSON file per plan under ``root``; atomic writes.

    A truncated/corrupt file or a stale-schema payload is a *miss*, not
    an error: the bad file is logged, deleted, counted in ``corrupt``
    (and surfaced via ``on_corrupt`` into the server's
    ``plan_cache_corrupt`` counter), and the caller re-solves — a torn
    write or a bit flip must never take down the request path.

    ``fault_injector`` (site ``plan_cache``, kind ``corrupt``) truncates
    the real file on disk just before the read, so chaos tests exercise
    exactly this recovery path, not a simulation of it.
    """

    def __init__(self, root, *,
                 on_corrupt: Optional[Callable[[str], None]] = None,
                 fault_injector=None) -> None:
        self.root = pathlib.Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.hits = 0
        self.misses = 0
        self.corrupt = 0
        self.on_corrupt = on_corrupt
        self.fault_injector = fault_injector

    def _path(self, key: str) -> pathlib.Path:
        return self.root / f"plan_{key}.json"

    def get(self, key: str) -> Optional[Dict[str, Any]]:
        p = self._path(key)
        if self.fault_injector is not None and p.exists():
            spec = self.fault_injector.check("plan_cache", key=key)
            if spec is not None and spec.kind == "corrupt":
                try:
                    raw = p.read_text()
                    p.write_text(raw[: len(raw) // 2])
                except OSError:
                    pass
        if not p.exists():
            self.misses += 1
            return None
        try:
            payload = json.loads(p.read_text())
        except (OSError, json.JSONDecodeError) as exc:
            return self.discard(key, f"unreadable JSON ({exc})")
        if not isinstance(payload, dict) \
                or payload.get("schema") != PLAN_SCHEMA:
            got = payload.get("schema") if isinstance(payload, dict) \
                else type(payload).__name__
            return self.discard(key, f"schema {got!r} != {PLAN_SCHEMA}")
        self.hits += 1
        return payload

    def discard(self, key: str, why: str) -> None:
        """Treat the entry as corrupt: log, delete, count, miss."""
        _log.warning("plan cache entry %s corrupt (%s): deleting, "
                     "will re-solve", key, why)
        try:
            self._path(key).unlink()
        except OSError:
            pass
        self.corrupt += 1
        self.misses += 1
        if self.on_corrupt is not None:
            self.on_corrupt(key)
        return None

    def put(self, key: str, payload: Dict[str, Any]) -> None:
        """Atomic write, safe under concurrent writers of the same key
        (writer-unique tmp names — see ``core.ioutil.atomic_write_text``;
        both writers produce equivalent payloads for the same key, so
        last-replace-wins is correct)."""
        atomic_write_text(self._path(key), json.dumps(payload))

    def __len__(self) -> int:
        return len(list(self.root.glob("plan_*.json")))


# ----------------------------------------------------------------------
class LRU:
    """Tiny ordered-dict LRU for compiled executables."""

    def __init__(self, capacity: int) -> None:
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self._d: "OrderedDict[Any, Any]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, key):
        if key in self._d:
            self._d.move_to_end(key)
            self.hits += 1
            return self._d[key]
        self.misses += 1
        return None

    def put(self, key, value) -> None:
        if key in self._d:
            self._d.move_to_end(key)
        self._d[key] = value
        while len(self._d) > self.capacity:
            self._d.popitem(last=False)
            self.evictions += 1

    def pop(self, key):
        """Drop an entry without touching the hit/miss counters (the
        quarantine eviction path: a poisoned executable must not linger
        until capacity pressure finds it)."""
        return self._d.pop(key, None)

    def __contains__(self, key) -> bool:
        return key in self._d

    def __len__(self) -> int:
        return len(self._d)
