"""PlanServer: the per-request dispatcher of the serving subsystem.

Request path (the bridge between ``core/selection.py`` and
``runtime/serve_loop.py``)::

    request shape --bucket--> bucket shape
        --> compiled-executable LRU hit?     -> execute
        --> persistent plan cache hit?       -> compile, execute
        --> PBQP solve (warm-started from the nearest solved bucket),
            persist plan, compile, execute

Misses can be taken off the caller's thread with :meth:`PlanServer.
prefetch` (async solve+compile); the synchronous :meth:`infer` is what
the LM serving loop calls per request.  Cache bookkeeping (and the
millisecond-scale PBQP solve) runs under one lock, but the expensive
XLA compile + warm-up happens outside it behind a per-bucket future:
hot-bucket requests never stall behind a cold bucket compiling, and
concurrent requests racing into the same cold bucket still trigger
exactly one solve and one compile (the acceptance property
tests/test_serving.py pins down via the counters).
"""
from __future__ import annotations

import time
from concurrent.futures import Future, ThreadPoolExecutor
from threading import RLock
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from ..core import plan as plan_mod
from ..core.costs import CostModel
from ..core.graph import Net
from ..core.plan import CompiledNet, compile_plan
from ..core.selection import SelectionResult, select_pbqp
from .bucketing import BucketPolicy, bucket_key, bucket_shape
from .metrics import ServingCounters
from .plan_cache import (
    LRU, PlanDiskCache, plan_key, selection_from_payload,
    selection_to_payload,
)

__all__ = ["PlanServer"]

Shape = Tuple[int, int, int]


class PlanServer:
    """Serve per-request primitive-selection plans and executables.

    Parameters
    ----------
    net_builder:
        ``(C, H, W) -> Net`` — must yield identical node ids across
        shapes (see :mod:`repro.serving.towers`) so warm starts line up.
    cost_model:
        Prices primitives and layout transforms; its :meth:`~repro.core.
        costs.CostModel.version` participates in the persistent cache key.
    cache_dir:
        Directory for the persistent plan cache; ``None`` disables the
        disk tier (plans still cached in memory for the process lifetime).
    lru_capacity:
        Max live compiled executables.
    """

    def __init__(self, net_builder: Callable[[Shape], Net],
                 cost_model: CostModel, *,
                 policy: Optional[BucketPolicy] = None,
                 cache_dir=None, lru_capacity: int = 8,
                 exact: bool = True, params_seed: int = 0,
                 jit: bool = True, max_workers: int = 2) -> None:
        self.net_builder = net_builder
        self.cost = cost_model
        self.cost_version = cost_model.version()
        self.policy = policy or BucketPolicy()
        self.exact = exact
        self.params_seed = params_seed
        self.jit = jit
        self.counters = ServingCounters()
        self._plans: Dict[Shape, SelectionResult] = {}
        self._compiled = LRU(lru_capacity)
        self._building: Dict[Shape, Future] = {}
        self._disk = PlanDiskCache(cache_dir) if cache_dir else None
        self._lock = RLock()
        self._pool = ThreadPoolExecutor(max_workers=max_workers,
                                        thread_name_prefix="planserver")

    # -----------------------------------------------------------------
    # plan tier
    # -----------------------------------------------------------------
    def plan_for(self, shape_chw: Shape) -> SelectionResult:
        """Bucket the shape and return its (cached or fresh) selection."""
        bshape = bucket_shape(shape_chw, self.policy)
        with self._lock:
            return self._plan_locked(bshape)

    def _plan_locked(self, bshape: Shape) -> SelectionResult:
        sel = self._plans.get(bshape)
        if sel is not None:
            self.counters.add(plan_mem_hits=1)
            return sel
        net = self.net_builder(bshape)
        key = plan_key(net.fingerprint(), bucket_key(bshape),
                       self.cost_version)
        if self._disk is not None:
            payload = self._disk.get(key)
            if payload is not None:
                try:
                    sel = selection_from_payload(payload, net)
                except (KeyError, ValueError):
                    sel = None  # unknown primitive / schema: re-solve
            if sel is not None:
                self.counters.add(plan_disk_hits=1)
                self._plans[bshape] = sel
                return sel
        self.counters.add(plan_misses=1)
        warm = self._nearest_plan(bshape)
        t0 = time.perf_counter()
        sel = select_pbqp(net, self.cost, exact=self.exact, warm_start=warm)
        self.counters.add(solves=1, solve_s=time.perf_counter() - t0,
                          warm_solves=int(sel.solver_stats.get("WARM", 0)))
        self._plans[bshape] = sel
        if self._disk is not None:
            self._disk.put(key, selection_to_payload(sel))
        return sel

    def _nearest_plan(self, bshape: Shape) -> Optional[SelectionResult]:
        """Closest already-solved bucket in log-shape space (warm start)."""
        if not self._plans:
            return None
        def dist(other: Shape) -> float:
            return sum(abs(np.log2(a / b)) for a, b in zip(bshape, other))
        return self._plans[min(self._plans, key=dist)]

    # -----------------------------------------------------------------
    # executable tier
    # -----------------------------------------------------------------
    def compiled_for(self, shape_chw: Shape) -> CompiledNet:
        bshape = bucket_shape(shape_chw, self.policy)
        with self._lock:
            cnet = self._compiled.get(bshape)
            if cnet is not None:
                self.counters.add(exec_hits=1)
                return cnet
            racing = self._building.get(bshape)
            if racing is None:
                fut = Future()
                self._building[bshape] = fut
                self.counters.add(exec_misses=1)
        if racing is not None:
            # another thread is building this bucket: wait, don't duplicate
            return racing.result()
        try:
            with self._lock:
                sel = self._plan_locked(bshape)
            params = sel.net.init_params(self.params_seed)
            t0 = time.perf_counter()
            # XLA compile + warm-up outside the lock: hot buckets must
            # not stall behind a cold bucket compiling
            cnet = compile_plan(sel, params, jit=self.jit)
            _block(cnet(np.zeros(bshape, np.float32)))
            with self._lock:
                ev0 = self._compiled.evictions
                self._compiled.put(bshape, cnet)
                self._building.pop(bshape, None)
                self.counters.add(
                    compiles=1, compile_s=time.perf_counter() - t0,
                    exec_evictions=self._compiled.evictions - ev0)
            fut.set_result(cnet)
            return cnet
        except BaseException as exc:
            with self._lock:
                self._building.pop(bshape, None)
            fut.set_exception(exc)
            raise

    def prefetch(self, shape_chw: Shape) -> Future:
        """Async solve+compile for a bucket (returns a Future[CompiledNet]).

        Misses are resolved on the server's worker pool so the caller's
        latency-sensitive loop never blocks on a cold bucket."""
        return self._pool.submit(self.compiled_for, shape_chw)

    # -----------------------------------------------------------------
    # request path
    # -----------------------------------------------------------------
    def infer(self, x_chw: np.ndarray) -> Dict[str, np.ndarray]:
        """Execute one request: bucket, pad, run, return output arrays."""
        x = np.asarray(x_chw, np.float32)
        if x.ndim != 3:
            raise ValueError(f"expected (C, H, W) input, got {x.shape}")
        cnet = self.compiled_for(x.shape)
        bshape = bucket_shape(x.shape, self.policy)
        pads = [(0, b - s) for b, s in zip(bshape, x.shape)]
        xb = np.pad(x, pads)
        t0 = time.perf_counter()
        out = cnet(xb)
        out = {nid: np.asarray(v) for nid, v in out.items()}
        self.counters.add(requests=1,
                          execute_s=time.perf_counter() - t0)
        return out

    # -----------------------------------------------------------------
    def stats(self) -> Dict[str, float]:
        d = self.counters.snapshot()
        d["buckets"] = len(self._plans)
        d["live_executables"] = len(self._compiled)
        if self._disk is not None:
            d["disk_plans"] = len(self._disk)
        return d

    def close(self) -> None:
        self._pool.shutdown(wait=True)


def _block(outs) -> None:
    import jax
    jax.block_until_ready(outs)
