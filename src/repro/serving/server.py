"""PlanServer: the per-request dispatcher of the serving subsystem.

Request path (the bridge between ``core/selection.py`` and
``runtime/serve_loop.py``)::

    request shape --bucket--> (bucket shape, batch bucket)
        --> compiled-executable LRU hit?     -> execute
        --> persistent plan cache hit?       -> compile, execute
        --> PBQP solve (warm-started from the nearest solved bucket),
            persist plan, compile, execute

Every tier is keyed on the *pair* (bucket shape, batch bucket): the
optimal primitive assignment flips with minibatch (``Scenario.n``), so
an N=8 plan is a different plan — and a different executable — than the
N=1 plan for the same spatial bucket.

Three execution entry points:

* :meth:`PlanServer.infer` — one image, the latency path.  Outputs are
  cropped back to the *request's* extent (the request was zero-padded
  into its bucket; bucket-shaped outputs would leak padding).
* :meth:`PlanServer.infer_batch` — a list of images, the throughput
  path: requests group by bucket and each group runs as ONE batched
  executable invocation (vmapped tower, zero rows padding the batch to
  its pow2 bucket).
* :meth:`PlanServer.enqueue` / :meth:`PlanServer.flush` — the
  micro-batching admission queue: producers enqueue single images and
  get a Future; ``flush()`` coalesces everything pending through
  :meth:`infer_batch`.  This is the *barrier-flush* primitive; the
  production path layers :class:`~repro.serving.scheduler.
  ContinuousScheduler` on :meth:`infer_batch` instead — continuous
  batching with per-request deadlines and SLO-aware partial launches
  (docs/serving.md) — which is what the LM serve loop now admits
  through.

With a device ``mesh``, batched buckets solve the unified choice space
(primitive × layout × device placement — ``select_pbqp(...,
mesh_axes=)``) over the full placement domain the topology admits
({rep, dp} plus tp on a ``model`` axis and pipeline stages on a
``stage`` axis), sharded plans compile mesh-sharded
(``compile_plan(..., mesh=)``), the mesh topology fingerprint joins
every cache key (a plan solved for one topology is never served to
another), and :meth:`infer_batch` runs each bucket group sharded
across the mesh.  See docs/distributed.md.

Misses can be taken off the caller's thread with :meth:`PlanServer.
prefetch` (async solve+compile).  Cache bookkeeping (and the
millisecond-scale PBQP solve) runs under one lock, but the expensive
XLA compile + warm-up happens outside it behind a per-bucket future:
hot-bucket requests never stall behind a cold bucket compiling, and
concurrent requests racing into the same cold bucket still trigger
exactly one solve and one compile (the acceptance property
tests/test_serving.py pins down via the counters).
"""
from __future__ import annotations

import random
import time
from collections import OrderedDict
from concurrent.futures import Future, ThreadPoolExecutor
from threading import RLock
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core import plan as plan_mod
from ..core.costs import CostModel
from ..core.graph import Net
from ..core.plan import CompiledNet, compile_plan
from ..core.selection import SelectionResult, select_local_optimal
from ..launch.mesh import mesh_fingerprint, mesh_shape_dict
from ..obs.trace import get_tracer
from ..reliability import (FallbackLadder, FaultInjector, KernelFailure,
                           PrimitiveQuarantine, diagnose_nonfinite,
                           reference_selection, retry_call)
from ..reliability.errors import InjectedFault
from .bucketing import BucketPolicy, bucket_key, bucket_shape
from .metrics import ServingCounters
from .plan_cache import (
    LRU, PlanDiskCache, plan_key, selection_from_payload,
    selection_to_payload,
)

__all__ = ["PlanServer"]

Shape = Tuple[int, int, int]
#: internal cache key: spatial bucket + batch bucket
PlanKey = Tuple[int, int, int, int]


class PlanServer:
    """Serve per-request primitive-selection plans and executables.

    Parameters
    ----------
    net_builder:
        ``(C, H, W) -> Net`` — must yield identical node ids across
        shapes (see :mod:`repro.serving.towers`) so warm starts line up.
        The server applies the batch bucket via ``Net.with_batch``.
    cost_model:
        Prices primitives and layout transforms; its :meth:`~repro.core.
        costs.CostModel.version` participates in the persistent cache key.
    cache_dir:
        Directory for the persistent plan cache; ``None`` disables the
        disk tier (plans still cached in memory for the process lifetime).
    lru_capacity:
        Max live compiled executables (batched ones count like any other).
    """

    def __init__(self, net_builder: Callable[[Shape], Net],
                 cost_model: CostModel, *,
                 policy: Optional[BucketPolicy] = None,
                 cache_dir=None, lru_capacity: int = 8,
                 exact: bool = True, params_seed: int = 0,
                 jit: bool = True, max_workers: int = 2,
                 fuse: bool = False, mesh=None,
                 fault_injector: Optional[FaultInjector] = None,
                 solve_deadline_s: Optional[float] = None,
                 quarantine: Optional[PrimitiveQuarantine] = None,
                 compile_retries: int = 2,
                 compile_backoff_s: float = 0.05,
                 kernel_retries: int = 1,
                 guard_outputs: bool = True) -> None:
        self.net_builder = net_builder
        self.cost = cost_model
        self.fuse = fuse
        #: device mesh for batched executables: batch-bucket solves gain
        #: the placement axis over the mesh's axes (dp on the batch
        #: axes, tp on "model", pp stages on "stage"), and sharded
        #: plans compile mesh-sharded (``infer_batch`` then runs each
        #: bucket group sharded across the mesh)
        self.mesh = mesh
        self._mesh_axes = mesh_shape_dict(mesh) if mesh is not None \
            else None
        # a fused and an unfused plan for the same bucket are different
        # plans (edges priced and realized differently), and so is the
        # same bucket solved for a different mesh topology — fold both
        # into the version string every cache tier keys on
        self.cost_version = cost_model.version() + \
            ("+fuse" if fuse else "") + \
            (f"+mesh={mesh_fingerprint(mesh)}" if mesh is not None else "")
        self.policy = policy or BucketPolicy()
        self.exact = exact
        self.params_seed = params_seed
        self.jit = jit
        self.counters = ServingCounters()
        # --- reliability layer (docs/reliability.md) ---
        self.fault_injector = fault_injector
        self.quarantine = quarantine if quarantine is not None \
            else PrimitiveQuarantine()
        self.compile_retries = int(compile_retries)
        self.compile_backoff_s = float(compile_backoff_s)
        self.kernel_retries = int(kernel_retries)
        self.guard_outputs = guard_outputs
        #: solve rungs: exact (or anytime under the deadline) -> greedy
        #: -> reference; every selection goes through the ladder
        self.ladder = FallbackLadder(
            cost_model, exact=exact, deadline_s=solve_deadline_s,
            counters=self.counters, fault_injector=fault_injector)
        #: seeded so chaos runs replay their retry backoff exactly
        self._retry_rng = random.Random(params_seed)
        #: prior plan of a bucket whose plan-tier entry was evicted by a
        #: quarantine trip — the warm-start incumbent for the re-solve
        self._quar_warm: Dict[PlanKey, SelectionResult] = {}
        self._plans: Dict[PlanKey, SelectionResult] = {}
        self._compiled = LRU(lru_capacity)
        self._building: Dict[PlanKey, Future] = {}
        self._disk = PlanDiskCache(
            cache_dir,
            on_corrupt=lambda _k: self.counters.add(plan_cache_corrupt=1),
            fault_injector=fault_injector) if cache_dir else None
        self._lock = RLock()
        self._pool = ThreadPoolExecutor(max_workers=max_workers,
                                        thread_name_prefix="planserver")
        #: request-shape -> output-node expected shapes (crop targets)
        self._out_shapes = LRU(512)
        #: micro-batching admission queue: (image, future, enqueue time)
        self._queue: List[Tuple[np.ndarray, Future, float]] = []
        self._closed = False

    # -----------------------------------------------------------------
    # plan tier
    # -----------------------------------------------------------------
    def plan_for(self, shape_chw: Shape, n: int = 1) -> SelectionResult:
        """Bucket the shape (and batch) and return its selection."""
        bshape = bucket_shape(shape_chw, self.policy)
        nb = self.policy.bucket_n(n)
        with self._lock:
            return self._plan_locked(bshape, nb)

    def _plan_locked(self, bshape: Shape, nb: int) -> SelectionResult:
        bkey = bucket_key(bshape, nb)
        with get_tracer().span("plan", bucket=bkey) as sp:
            pkey: PlanKey = (*bshape, nb)
            sel = self._plans.get(pkey)
            if sel is not None:
                self.counters.add(plan_mem_hits=1)
                sp.set(source="mem")
                return sel
            net = self.net_builder(bshape).with_batch(nb)
            # active quarantines rotate the cache key per bucket (PR 6's
            # cost-version rotation, scoped): a plan solved around a
            # banned primitive never collides with the healthy plan, and
            # when the quarantine lifts the token empties — the original
            # on-disk plan becomes a hit again, which *is* recovery
            key = plan_key(net.fingerprint(), bkey, self.cost_version
                           + self.quarantine.version_token(bkey))
            if self._disk is not None:
                payload = self._disk.get(key)
                if payload is not None:
                    try:
                        sel = selection_from_payload(payload, net)
                    except (KeyError, ValueError) as exc:
                        # unknown primitive / malformed payload: same
                        # corrupt-entry path as unreadable JSON
                        self._disk.discard(key, f"payload invalid ({exc})")
                        sel = None
                if sel is not None:
                    self.counters.add(plan_disk_hits=1)
                    self._plans[pkey] = sel
                    sp.set(source="disk")
                    return sel
            self.counters.add(plan_misses=1)
            banned = self.quarantine.banned_for(bkey)
            # warm start: the bucket's own pre-quarantine plan beats the
            # nearest-bucket incumbent when re-solving after a trip
            warm = self._quar_warm.pop(pkey, None) or \
                self._nearest_plan(pkey)
            t0 = time.perf_counter()
            # the ladder runs select_pbqp (which opens the nested
            # pbqp.solve/solve_warm spans) and degrades on failure:
            # exact -> anytime -> greedy -> reference
            sel, rung = self.ladder.select(
                net, bucket=bkey, warm_start=warm, fuse=self.fuse,
                mesh_axes=self._mesh_axes, banned=banned or None)
            self.counters.add(
                _bucket=bkey, solves=1,
                solve_s=time.perf_counter() - t0,
                warm_solves=int(sel.solver_stats.get("WARM", 0)))
            sp.set(source="solve", rung=rung,
                   warm_dist=sel.solver_stats.get("WARM_DIST", -1))
            self._plans[pkey] = sel
            if self._disk is not None:
                self._disk.put(key, selection_to_payload(sel))
            return sel

    def _nearest_plan(self, pkey: PlanKey) -> Optional[SelectionResult]:
        """Closest already-solved bucket in log-shape space (warm start).

        The batch bucket is one more axis of that space: the N=1
        optimum of the same spatial bucket is usually an excellent
        incumbent for the N=8 solve.
        """
        if not self._plans:
            return None

        def dist(other: PlanKey) -> float:
            return sum(abs(np.log2(a / b)) for a, b in zip(pkey, other))

        return self._plans[min(self._plans, key=dist)]

    # -----------------------------------------------------------------
    # executable tier
    # -----------------------------------------------------------------
    def compiled_for(self, shape_chw: Shape, n: int = 1) -> CompiledNet:
        bshape = bucket_shape(shape_chw, self.policy)
        nb = self.policy.bucket_n(n)
        pkey: PlanKey = (*bshape, nb)
        with self._lock:
            cnet = self._compiled.get(pkey)
            if cnet is not None:
                self.counters.add(exec_hits=1)
                return cnet
            racing = self._building.get(pkey)
            if racing is None:
                fut = Future()
                self._building[pkey] = fut
                self.counters.add(exec_misses=1)
        if racing is not None:
            # another thread is building this bucket: wait, don't duplicate
            return racing.result()
        try:
            with self._lock:
                sel = self._plan_locked(bshape, nb)
            t0 = time.perf_counter()
            # XLA compile + warm-up outside the lock: hot buckets must
            # not stall behind a cold bucket compiling.
            cnet = self._compile_with_retry(sel, bshape, nb)
            with self._lock:
                ev0 = self._compiled.evictions
                self._compiled.put(pkey, cnet)
                self._building.pop(pkey, None)
                self.counters.add(
                    _bucket=bucket_key(bshape, nb),
                    compiles=1, compile_s=time.perf_counter() - t0,
                    mesh_compiles=int(cnet.mesh is not None),
                    exec_evictions=self._compiled.evictions - ev0)
            fut.set_result(cnet)
            return cnet
        except BaseException as exc:
            with self._lock:
                self._building.pop(pkey, None)
            fut.set_exception(exc)
            raise

    def _compile_with_retry(self, sel: SelectionResult, bshape: Shape,
                            nb: int) -> CompiledNet:
        """Compile + warm up ``sel``, surviving transient failures.

        Each attempt (``1 + compile_retries`` total) backs off with
        seeded jitter (:func:`~repro.reliability.retry_call`).  If every
        retry fails the *plan itself* is demoted one-shot down the
        ladder (greedy, then reference) and compiled with the same
        retry budget — a plan that cannot compile must not take the
        bucket down with it.  The fault injector's ``compile`` site
        fires inside each attempt, so chaos runs exercise the real
        retry and demotion paths.
        """
        bkey = bucket_key(bshape, nb)

        def build(s: SelectionResult) -> CompiledNet:
            if self.fault_injector is not None:
                self.fault_injector.raise_if("compile", key=bkey)
            params = s.net.init_params(self.params_seed)
            # Mesh-sharded compilation only when the plan actually
            # carries sharded (dp/tp/pp) nodes — an all-rep plan on a
            # mesh is just the plain executable.
            mesh = self.mesh if nb > 1 and any(
                ch.placement != "rep" for ch in s.choices.values()) \
                else None
            cnet = compile_plan(s, params, jit=self.jit, batch=nb,
                                mesh=mesh)
            warm_in = np.zeros(bshape if nb == 1 else (nb, *bshape),
                               np.float32)
            _block(cnet(warm_in))
            return cnet

        def on_retry(attempt: int, exc: BaseException) -> None:
            self.counters.add(compile_retries=1)

        try:
            return retry_call(lambda: build(sel),
                              retries=self.compile_retries,
                              base_delay_s=self.compile_backoff_s,
                              rng=self._retry_rng, on_retry=on_retry)
        except Exception:
            if sel.strategy == "reference":
                raise  # already the last rung: nothing left to demote to
            self.counters.add(compile_fallbacks=1)
            fb, rung = self._compile_fallback_plan(sel, bkey)
            now = time.perf_counter()
            get_tracer().emit("ladder_demotion", now, now, rung=rung,
                              bucket=bkey, stage="compile")
            return retry_call(lambda: build(fb),
                              retries=self.compile_retries,
                              base_delay_s=self.compile_backoff_s,
                              rng=self._retry_rng, on_retry=on_retry)

    def _compile_fallback_plan(self, sel: SelectionResult, bkey: str
                               ) -> Tuple[SelectionResult, str]:
        """Demote a plan that would not compile: greedy, else reference.

        Not persisted to any cache tier — the demotion is scoped to the
        executable being built, so once the transient trouble clears the
        bucket's next (evicted/re-keyed) build compiles the real plan.
        """
        banned = self.quarantine.banned_for(bkey)
        try:
            fb = select_local_optimal(sel.net, self.cost,
                                      banned=banned or None)
            rung = "greedy"
        except Exception:
            fb = reference_selection(sel.net, self.cost)
            rung = "reference"
        self.counters.add(**{f"ladder_{rung}": 1})
        return fb, rung

    def prefetch(self, shape_chw: Shape, n: int = 1) -> Future:
        """Async solve+compile for a bucket (returns a Future[CompiledNet]).

        Misses are resolved on the server's worker pool so the caller's
        latency-sensitive loop never blocks on a cold bucket."""
        return self._pool.submit(self.compiled_for, shape_chw, n)

    def resize_workers(self, n: int) -> None:
        """Retarget the worker pool's concurrency (elastic scaling).

        Called by the continuous-batching scheduler when its
        :class:`~repro.runtime.elastic.ElasticController` observes a
        load shift, so prefetch parallelism tracks the launch slots.
        Growth takes effect on the next submission (the executor spawns
        threads lazily up to its max); shrinking caps new spawns —
        threads already running finish their work and go idle, which is
        the semantics a serving pool wants (never abandon a compile
        mid-flight).
        """
        n = max(1, int(n))
        with self._lock:
            # ThreadPoolExecutor consults _max_workers on every submit;
            # retargeting it is the supported-in-practice resize lever
            # (there is no public API).
            self._pool._max_workers = n

    @property
    def worker_target(self) -> int:
        """Current concurrency target of the worker pool."""
        with self._lock:
            return self._pool._max_workers

    # -----------------------------------------------------------------
    # guarded execution + quarantine
    # -----------------------------------------------------------------
    def _execute_guarded(self, cnet: CompiledNet, xb, bshape: Shape,
                         nb: int
                         ) -> Tuple[Dict[str, np.ndarray], CompiledNet]:
        """Run the executable under the kernel circuit breaker.

        Crashes and non-finite outputs count as kernel failures: the
        culprit primitive is attributed (the injected spec's target, or
        :func:`~repro.reliability.diagnose_nonfinite` for real NaNs) and
        fed to the quarantine.  A *tripped* breaker evicts the bucket's
        plan + executable, re-solves with the culprit banned (warm-
        started from the poisoned plan), recompiles, and retries the
        request — up to ``kernel_retries`` times — so the caller gets a
        correct answer from the degraded plan instead of an error.  An
        unattributable failure re-raises: retrying the identical plan
        would loop.  Returns ``(outputs, executable)``; the executable
        may differ from the argument after a quarantine re-solve.
        """
        if not self.guard_outputs and self.fault_injector is None:
            return {nid: np.asarray(v)
                    for nid, v in cnet(xb).items()}, cnet
        bkey = bucket_key(bshape, nb)
        attempts = 0
        while True:
            out: Optional[Dict[str, np.ndarray]] = None
            failure: Optional[BaseException] = None
            culprit: Optional[str] = None
            try:
                out = {nid: np.asarray(v)
                       for nid, v in cnet(xb).items()}
            except Exception as exc:
                failure = exc
            if self.fault_injector is not None:
                # keyed on bucket + the plan's conv primitives so a
                # spec's ``match`` can target one primitive by name
                prims = sorted({ch.primitive.name
                                for ch in cnet.sel.choices.values()
                                if ch.primitive is not None})
                spec = self.fault_injector.check(
                    "kernel", key=f"{bkey}|{','.join(prims)}")
                if spec is not None:
                    culprit = next(
                        (p for p in prims if spec.match in p), None) \
                        if spec.match else (prims[0] if prims else None)
                    if spec.kind == "delay":
                        time.sleep(spec.value)
                        culprit = None
                    elif spec.kind == "nan" and out is not None:
                        out = {nid: np.full_like(v, np.nan)
                               for nid, v in out.items()}
                    else:
                        failure = InjectedFault("kernel", spec.kind,
                                                culprit or bkey)
                        out = None
            if out is not None:
                if not self.guard_outputs:
                    return out, cnet
                if all(np.isfinite(v).all() for v in out.values()):
                    return out, cnet
                failure = KernelFailure(bkey, culprit,
                                        "non-finite outputs")
            # ---- failure path ----
            self.counters.add(kernel_failures=1)
            if culprit is None:
                culprit = diagnose_nonfinite(cnet, xb)
            tripped = culprit is not None and \
                self._quarantine_bucket(bshape, nb, culprit)
            attempts += 1
            if not tripped or attempts > self.kernel_retries:
                if failure is not None:
                    raise failure
                raise KernelFailure(bkey, culprit)
            # the trip rotated the bucket's cache key and evicted its
            # plan + executable: this re-solves (culprit banned, warm-
            # started from the poisoned plan), recompiles, and retries
            cnet = self.compiled_for(bshape, n=nb)

    def _quarantine_bucket(self, bshape: Shape, nb: int,
                           primitive: str) -> bool:
        """Record a kernel failure; on a breaker trip evict the bucket.

        The plan tier and executable LRU are keyed on the raw
        (bucket, batch) pair — they never see the quarantine token — so
        the trip must evict them explicitly.  The evicted plan is
        stashed as the warm-start incumbent for the banned re-solve.
        """
        pkey: PlanKey = (*bshape, nb)
        bkey = bucket_key(bshape, nb)
        tripped = self.quarantine.record_failure(primitive, bkey)
        if tripped:
            with self._lock:
                old = self._plans.pop(pkey, None)
                if old is not None:
                    self._quar_warm[pkey] = old
                self._compiled.pop(pkey)
            self.counters.add(quarantines=1)
            now = time.perf_counter()
            get_tracer().emit("quarantine", now, now,
                              primitive=primitive, bucket=bkey)
        return tripped

    def release_quarantine(self, primitive: str, shape_chw: Shape,
                           n: int = 1) -> bool:
        """Lift a quarantine for the shape's bucket (half-open retry).

        Evicts the bucket's in-memory tiers so the next request
        re-keys — with the quarantine set empty again the rotation
        token vanishes and the bucket's *original* disk plan is a hit.
        Returns True if a quarantine was actually lifted.
        """
        bshape = bucket_shape(shape_chw, self.policy)
        nb = self.policy.bucket_n(n)
        if not self.quarantine.release(primitive,
                                       bucket_key(bshape, nb)):
            return False
        with self._lock:
            self._plans.pop((*bshape, nb), None)
            self._compiled.pop((*bshape, nb))
            self._quar_warm.pop((*bshape, nb), None)
        return True

    # -----------------------------------------------------------------
    # output cropping
    # -----------------------------------------------------------------
    def _expected_out_shapes(self, req_shape: Shape) -> Dict[str, tuple]:
        """Output-node shapes of the net built at the *request* shape.

        The request is zero-padded into its bucket, so bucket-run
        outputs that keep spatial extent must be cropped back to what a
        run at the request shape would produce.  Building the net is
        pure graph math (no tracing/compiling); a small LRU memoizes it
        per request shape.
        """
        with self._lock:
            got = self._out_shapes.get(req_shape)
        if got is not None:
            return got
        net = self.net_builder(req_shape)
        shapes = {nid: tuple(net.nodes[nid].out_shape)
                  for nid in net.outputs()}
        with self._lock:
            self._out_shapes.put(req_shape, shapes)
        return shapes

    @staticmethod
    def _crop(v: np.ndarray, expected: tuple) -> np.ndarray:
        """Crop a bucket-run output down to the request's extent.

        Only applies when the ranks line up and every expected dim fits
        inside the actual one — global ops (GAP, FC) already produce
        request-independent shapes and pass through untouched.
        """
        if v.ndim != len(expected):
            return v
        if all(a == e for a, e in zip(v.shape, expected)):
            return v
        if any(e > a for a, e in zip(v.shape, expected)):
            return v
        return v[tuple(slice(0, e) for e in expected)]

    # -----------------------------------------------------------------
    # request paths
    # -----------------------------------------------------------------
    def infer(self, x_chw: np.ndarray) -> Dict[str, np.ndarray]:
        """Execute one request: bucket, pad, run, crop, return outputs."""
        x = np.asarray(x_chw, np.float32)
        if x.ndim != 3:
            raise ValueError(f"expected (C, H, W) input, got {x.shape}")
        tracer = get_tracer()
        with tracer.span("infer", shape="x".join(map(str, x.shape))):
            cnet = self.compiled_for(x.shape)
            bshape = bucket_shape(x.shape, self.policy)
            bkey = bucket_key(bshape, cnet.batch)
            pads = [(0, b - s) for b, s in zip(bshape, x.shape)]
            xb = np.pad(x, pads)
            if cnet.batch > 1:
                # a policy whose batch bucket for n=1 is > 1 (linear
                # batch mode, min_n > 1) hands the single request a
                # batched executable: embed the image as row 0, zero
                # rows pad
                xb = np.concatenate(
                    [xb[None], np.zeros((cnet.batch - 1, *bshape),
                                        np.float32)])
            expected = self._expected_out_shapes(x.shape)
            t0 = time.perf_counter()
            with tracer.span("execute", bucket=bkey):
                out, cnet = self._execute_guarded(cnet, xb, bshape,
                                                  cnet.batch)
            with tracer.span("crop"):
                out = {nid: self._crop(
                           v[0] if cnet.batch > 1 else v,
                           expected.get(nid, ()))
                       for nid, v in out.items()}
            self.counters.add(_bucket=bkey, requests=1,
                              execute_s=time.perf_counter() - t0)
            return out

    def infer_batch(self, xs: Sequence[np.ndarray]
                    ) -> List[Dict[str, np.ndarray]]:
        """Execute a batch of requests, one executable call per bucket.

        Requests group by spatial bucket; each group (chunked at
        ``policy.max_n``) is stacked into a zero-padded (N', C', H', W')
        tensor — N' the group's pow2 batch bucket — and runs through the
        batched executable in ONE invocation.  Per-request outputs are
        sliced off the batch axis and cropped exactly like
        :meth:`infer`, so ``infer_batch(xs)[i] == infer(xs[i])`` up to
        float reassociation.  Returns one output dict per request, in
        input order.
        """
        imgs = [np.asarray(x, np.float32) for x in xs]
        for x in imgs:
            if x.ndim != 3:
                raise ValueError(f"expected (C, H, W) inputs, got {x.shape}")
        if not imgs:
            return []
        with get_tracer().span("infer_batch", requests=len(imgs)) as sp:
            return self._infer_batch_traced(imgs, sp)

    def _infer_batch_traced(self, imgs: List[np.ndarray], sp
                            ) -> List[Dict[str, np.ndarray]]:
        tracer = get_tracer()
        groups: "OrderedDict[Shape, List[int]]" = OrderedDict()
        for i, x in enumerate(imgs):
            groups.setdefault(bucket_shape(x.shape, self.policy),
                              []).append(i)
        chunks: List[Tuple[Shape, int, List[int]]] = []
        for bshape, idxs in groups.items():
            for start in range(0, len(idxs), self.policy.max_n):
                chunk = idxs[start:start + self.policy.max_n]
                chunks.append((bshape, self.policy.bucket_n(len(chunk)),
                               chunk))
        # overlap cold solves+compiles of *distinct* (bucket, batch)
        # executables on the worker pool: a flush spanning G cold
        # groups then waits for the slowest compile, not the sum
        specs = {(bshape, nb) for bshape, nb, _ in chunks}
        prefetched = {spec: self.prefetch(*spec) for spec in specs} \
            if len(specs) > 1 else {}
        results: List[Optional[Dict[str, np.ndarray]]] = [None] * len(imgs)
        seen_specs = set()
        for bshape, nb, chunk in chunks:
            if prefetched:
                cnet = prefetched[(bshape, nb)].result()
                if (bshape, nb) in seen_specs:
                    # the sequential path would have taken an LRU hit
                    # here; keep the counters path-independent
                    self.counters.add(exec_hits=1)
                seen_specs.add((bshape, nb))
            else:
                cnet = self.compiled_for(bshape, n=nb)
            xb = np.zeros((nb, *bshape), np.float32)
            for row, i in enumerate(chunk):
                x = imgs[i]
                xb[row, :x.shape[0], :x.shape[1], :x.shape[2]] = x
            bkey = bucket_key(bshape, nb)
            t0 = time.perf_counter()
            with tracer.span("execute", bucket=bkey,
                             coalesced=len(chunk)):
                out, cnet = self._execute_guarded(
                    cnet, xb if nb > 1 else xb[0], bshape, nb)
            # coalesced counts per *invocation*: requests that
            # shared this executable call with at least one other
            self.counters.add(_bucket=bkey, batch_calls=1,
                              coalesced=len(chunk) - 1,
                              execute_s=time.perf_counter() - t0)
            with tracer.span("crop"):
                for row, i in enumerate(chunk):
                    expected = self._expected_out_shapes(imgs[i].shape)
                    results[i] = {
                        nid: self._crop(v[row] if nb > 1 else v,
                                        expected.get(nid, ()))
                        for nid, v in out.items()}
        self.counters.add(requests=len(imgs))
        sp.set(invocations=len(chunks))
        return results  # type: ignore[return-value]

    # -----------------------------------------------------------------
    # micro-batching admission queue
    # -----------------------------------------------------------------
    def enqueue(self, x_chw: np.ndarray) -> Future:
        """Queue one image for the next :meth:`flush`; returns a Future
        resolving to its output dict (same payload as :meth:`infer`)."""
        x = np.asarray(x_chw, np.float32)
        if x.ndim != 3:
            raise ValueError(f"expected (C, H, W) input, got {x.shape}")
        fut: Future = Future()
        with self._lock:
            if self._closed:
                # after close() no flush will ever run: a silently
                # queued future would hang its waiter forever
                raise RuntimeError("PlanServer is closed")
            self._queue.append((x, fut, time.perf_counter()))
        return fut

    def flush(self) -> int:
        """Coalesce everything enqueued into batched executable calls.

        All pending same-bucket images share one tower invocation
        (:meth:`infer_batch`); each Future resolves with its request's
        cropped outputs.  Returns the number of requests served.
        """
        with self._lock:
            pending, self._queue = self._queue, []
        if not pending:
            return 0
        with get_tracer().span("flush", requests=len(pending)):
            # queue wait: enqueue() timestamp to the moment the flush
            # drained it — opened and closed on different call stacks,
            # so it is emitted from explicit timestamps, parented here
            t_drain = time.perf_counter()
            tracer = get_tracer()
            for _, _, t_enq in pending:
                tracer.emit("queue_wait", t_enq, t_drain)
            try:
                outs = self.infer_batch([x for x, _, _ in pending])
            except BaseException as exc:
                for _, fut, _ in pending:
                    fut.set_exception(exc)
                raise
            for (_, fut, _), out in zip(pending, outs):
                fut.set_result(out)
            return len(pending)

    # -----------------------------------------------------------------
    def stats(self) -> Dict[str, float]:
        d = self.counters.snapshot()
        d["buckets"] = len(self._plans)
        d["live_executables"] = len(self._compiled)
        #: active circuit-breaker entries, as "primitive@bucket" strings
        d["quarantined"] = [f"{p}@{b}"
                            for p, b in self.quarantine.active()]
        if self._disk is not None:
            d["disk_plans"] = len(self._disk)
        #: histogram-backed latency percentiles per phase — entries
        #: like "execute[bucket=8x3x32x32]" split them per batch bucket
        d["phases"] = self.counters.phase_quantiles()
        return d

    def metrics_text(self) -> str:
        """Prometheus text exposition of this server's registry."""
        return self.counters.registry.prometheus_text()

    def close(self) -> None:
        # Drain the admission queue: enqueued-but-unflushed futures
        # would otherwise never resolve and their waiters would hang.
        # The closed flag makes a racing enqueue() raise instead of
        # landing a future in a queue nobody will ever flush.
        with self._lock:
            self._closed = True
            pending, self._queue = self._queue, []
        for _, fut, _ in pending:
            fut.cancel()
        self._pool.shutdown(wait=True)


def _block(outs) -> None:
    import jax
    jax.block_until_ready(outs)
