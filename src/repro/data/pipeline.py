"""Deterministic synthetic LM data pipeline.

Produces shardable token batches keyed by (seed, step): every host can
independently materialise its own shard of the global batch without
coordination — the property that makes restart-from-checkpoint exactly
reproducible (runtime/train_loop.py replays from the step counter).

A Zipfian unigram mixture with short-range induction structure (repeated
bigrams) gives the model something learnable so the example trainer's
loss visibly decreases.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["SyntheticLM", "make_batch"]


def make_batch(cfg, shape, step: int, *, seed: int = 0,
               dtype=jnp.bfloat16) -> Dict[str, jnp.ndarray]:
    """Materialise the full global batch for ``step`` (host-sliced by the
    caller when running multi-host)."""
    b, t = shape.global_batch, shape.seq_len
    rng = np.random.default_rng(np.uint64(seed * 1_000_003 + step))
    v = cfg.vocab
    # zipf-ish unigram over a 4k head of the vocab
    head = min(v, 4096)
    ranks = np.arange(1, head + 1)
    probs = 1.0 / ranks
    probs /= probs.sum()
    toks = rng.choice(head, size=(b, t + 1), p=probs).astype(np.int32)
    # induction structure: copy a shifted window so attention has signal
    half = t // 2
    toks[:, half:half * 2] = toks[:, :half]
    batch = {"tokens": jnp.asarray(toks[:, :-1]),
             "labels": jnp.asarray(toks[:, 1:])}
    if cfg.family == "encdec":
        frames = rng.normal(size=(b, cfg.enc_seq, cfg.d_model)) * 0.02
        batch["frames"] = jnp.asarray(frames, dtype)
    if cfg.family == "vlm":
        patches = rng.normal(size=(b, cfg.n_patches, cfg.d_model)) * 0.02
        batch["patches"] = jnp.asarray(patches, dtype)
    return batch


@dataclass
class SyntheticLM:
    """Iterator facade with prefetch-shape semantics of a real pipeline."""

    cfg: object
    shape: object
    seed: int = 0
    start_step: int = 0

    def __iter__(self) -> Iterator[Dict[str, jnp.ndarray]]:
        step = self.start_step
        while True:
            yield make_batch(self.cfg, self.shape, step, seed=self.seed)
            step += 1
