"""Pallas TPU kernels for the performance hot-spots.

Each kernel lives in its own subpackage with:
  kernel.py — pl.pallas_call + explicit BlockSpec VMEM tiling
  ops.py    — jit'd general wrapper (padding, batching)
  ref.py    — pure-jnp oracle used by the allclose tests
  bench.py  — ``benchmark_entry(scn)``: the calibration sweep hook
              (repro.calibrate.sweep) — returns a zero-arg builder
              producing a ``(fn, args)`` timing closure at the
              scenario's tensor sizes, or None when unsupported

``register_pallas_primitives`` plugs the convolution kernels into the
paper's primitive registry as the ``pallas`` family; they are tagged
``tpu-only`` so the CPU profiler skips them (the analytic TPU cost model
prices them instead).
"""
from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np


def register_pallas_primitives(add, _sup) -> None:
    from ..core.scenario import Scenario
    from . import conv_direct, conv_im2col, winograd_gemm
    from .matmul import ops as mm_ops

    def vmem_ok(scn: Scenario) -> bool:
        # the direct kernel keeps the padded input strip in VMEM
        hp = scn.h + 2 * scn.pad
        wp = scn.w + 2 * scn.pad
        return hp * wp * scn.c * 4 <= 8 * 2 ** 20

    # ---- direct NHWC ----
    def direct_prepare(scn, w, b):
        return {"w": jnp.asarray(np.transpose(w, (2, 3, 1, 0)).copy()),
                "b": jnp.asarray(b)}

    def direct_make(scn):
        def f(x, packed):  # x: HWC
            return conv_direct.conv_direct(
                x, packed["w"], packed["b"], stride=scn.stride, pad=scn.pad)
        return f

    def direct_fused(scn, l_in, l_out):
        # in-kernel prologue/epilogue: the CHW strip is transposed while
        # VMEM-resident and CHW output is stored through a remapped out
        # BlockSpec (see kernels/conv_direct/kernel.py)
        def f(x, packed):
            return conv_direct.conv_direct(
                x, packed["w"], packed["b"], stride=scn.stride,
                pad=scn.pad, in_layout=l_in, out_layout=l_out)
        return f

    base = _sup()
    add("pallas_direct_hwc", "pallas", "HWC", "HWC",
        lambda s: base(s) and vmem_ok(s), direct_prepare, direct_make,
        tags=("tpu-only",), fusable_in=("CHW",), fusable_out=("CHW",),
        fused=direct_fused)

    # ---- im2col GEMM ----
    def im2_prepare(scn, w, b):
        return {"w": jnp.asarray(w), "b": jnp.asarray(b)}

    def im2_make(scn):
        def f(x, packed):  # x: CHW
            return conv_im2col.conv_im2col(
                x, packed["w"], packed["b"], stride=scn.stride, pad=scn.pad)
        return f

    def im2_fused(scn, l_in, l_out):
        # HWC input feeds the Toeplitz gather directly; HWC output runs
        # the GEMM with the transposed-output epilogue BlockSpec
        def f(x, packed):
            return conv_im2col.conv_im2col(
                x, packed["w"], packed["b"], stride=scn.stride,
                pad=scn.pad, in_layout=l_in, out_layout=l_out)
        return f

    add("pallas_im2col_chw", "pallas", "CHW", "CHW", base,
        im2_prepare, im2_make, tags=("tpu-only",),
        fusable_in=("HWC",), fusable_out=("HWC",), fused=im2_fused)

    # ---- winograd F(2,3)/F(4,3) ----
    for m_ in (2, 4):
        def wino_prepare(scn, w, b, m_=m_):
            return {"u": winograd_gemm.prepare_kernel(w, m_),
                    "b": jnp.asarray(b)}

        def wino_make(scn, m_=m_):
            def f(x, packed):  # x: CHW
                return winograd_gemm.conv_winograd(
                    x, packed["u"], packed["b"], m_=m_, k=scn.k,
                    stride=scn.stride, pad=scn.pad)
            return f

        def wino_fused(scn, l_in, l_out, m_=m_):
            # the inverse output transform emits HWC itself (reordered
            # einsum) — epilogue fusion with zero extra passes
            def f(x, packed):
                return winograd_gemm.conv_winograd(
                    x, packed["u"], packed["b"], m_=m_, k=scn.k,
                    stride=scn.stride, pad=scn.pad, in_layout=l_in,
                    out_layout=l_out)
            return f

        add(f"pallas_wino_f{m_}x3_chw", "pallas", "CHW", "CHW",
            _sup(k_in=(3,), stride1=True), wino_prepare, wino_make,
            tags=("tpu-only",), fusable_in=("HWC",), fusable_out=("HWC",),
            fused=wino_fused)

    # ---- pointwise (K=1) MXU GEMM ----
    def pw_prepare(scn, w, b):
        return {"w": jnp.asarray(w.reshape(scn.m, scn.c)),
                "b": jnp.asarray(b)}

    def pw_make(scn):
        def f(x, packed):  # x: CHW
            s = scn.stride
            xs = x[:, ::s, ::s] if s > 1 else x
            y = mm_ops.matmul(packed["w"], xs.reshape(scn.c, -1))
            y = y.reshape(scn.m, scn.out_h, scn.out_w)
            return y + packed["b"][:, None, None]
        return f

    def pw_fused(scn, l_in, l_out):
        # the GEMM kernel's layout-parameterized entry points absorb
        # both ends: an HWC input is consumed as the (OHOW, C) LHS and
        # an HWC output is emitted via the transposed-output epilogue —
        # no standalone transpose in any combination
        def f(x, packed):
            s = scn.stride
            w = packed["w"]  # (M, C)
            if l_in == "HWC":
                xs = x[::s, ::s, :] if s > 1 else x
                p = xs.reshape(-1, scn.c)  # (OHOW, C)
                if l_out == "HWC":
                    y = mm_ops.matmul(p, w.T)          # (OHOW, M)
                    y = y.reshape(scn.out_h, scn.out_w, scn.m)
                    return y + packed["b"]
                y = mm_ops.matmul(p, w.T, out_layout="nm")  # (M, OHOW)
                y = y.reshape(scn.m, scn.out_h, scn.out_w)
                return y + packed["b"][:, None, None]
            xs = x[:, ::s, ::s] if s > 1 else x
            p = xs.reshape(scn.c, -1)  # (C, OHOW)
            if l_out == "HWC":
                y = mm_ops.matmul(w, p, out_layout="nm")   # (OHOW, M)
                y = y.reshape(scn.out_h, scn.out_w, scn.m)
                return y + packed["b"]
            y = mm_ops.matmul(w, p).reshape(scn.m, scn.out_h, scn.out_w)
            return y + packed["b"][:, None, None]
        return f

    add("pallas_pw_gemm_chw", "pallas", "CHW", "CHW", _sup(k_in=(1,)),
        pw_prepare, pw_make, tags=("tpu-only",),
        fusable_in=("HWC",), fusable_out=("HWC",), fused=pw_fused)
