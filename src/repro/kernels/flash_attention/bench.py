"""Calibration benchmark entry for the Pallas flash-attention kernel.

A convolution scenario induces an attention problem over its output
pixels: sequence length ``OH*OW`` (each output position attends over the
feature map, the vision-tower-into-LM case the serving loop exercises),
4 heads, head dim 64.
"""
from __future__ import annotations

import numpy as np

from ...core.scenario import Scenario

_HEADS = 4
_HEAD_DIM = 64
_MAX_SEQ = 1024


def benchmark_entry(scn: Scenario):
    """Zero-arg builder timing the scenario-induced attention."""
    seq = min(scn.out_h * scn.out_w, _MAX_SEQ)
    if seq < 1:
        return None

    def build():
        import jax.numpy as jnp

        from .ops import flash_attention
        rng = np.random.default_rng(0)
        shape = (1, _HEADS, seq, _HEAD_DIM)
        q, k, v = (jnp.asarray(rng.normal(size=shape), jnp.float32)
                   for _ in range(3))
        return flash_attention, (q, k, v)

    return build
