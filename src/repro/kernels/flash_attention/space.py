"""Tunable space of the flash-attention kernel (autotune hook).

Flash attention is not a convolution primitive, so this is a
*kernel-only* space: winning (bq, bk) tiles per scenario bucket are
recorded in the variant catalog as ``kernel::`` entries for the ops
layer, not registered with PBQP.  The scenario-induced attention
problem matches :mod:`.bench` (sequence = OH*OW capped, 4 heads, head
dim 64).
"""
from __future__ import annotations

import numpy as np

from ...autotune.space import TunableSpace

_HEADS = 4
_HEAD_DIM = 64
_MAX_SEQ = 1024

AXES = (("bq", (64, 128, 256)),
        ("bk", (64, 128, 256)))


def _valid(p) -> bool:
    bq, bk = p["bq"], p["bk"]
    if bq % 8 or bk % 8:
        return False
    # per step: q/o tiles (bq, D), k/v tiles (bk, D), scores (bq, bk)
    return (2 * bq * _HEAD_DIM + 2 * bk * _HEAD_DIM + bq * bk) * 4 \
        <= 2 * 2 ** 20


def _seq(scn) -> int:
    return min(scn.out_h * scn.out_w, _MAX_SEQ)


def _benchmark(scn, params):
    seq = _seq(scn)
    if seq < 8:
        return None
    bq, bk = params["bq"], params["bk"]

    def build():
        import functools

        import jax.numpy as jnp

        from .ops import flash_attention
        rng = np.random.default_rng(0)
        shape = (1, _HEADS, seq, _HEAD_DIM)
        q, k, v = (jnp.asarray(rng.normal(size=shape), jnp.float32)
                   for _ in range(3))
        fn = functools.partial(flash_attention, bq=bq, bk=bk)
        return fn, (q, k, v)

    return build


def _analytic(scn, params, spec) -> float:
    """Roofline of the scenario-induced attention at these tiles."""
    seq = _seq(scn)
    if seq < 8:
        return float("inf")
    bq = min(params["bq"], max(8, seq))
    bk = min(params["bk"], max(8, seq))
    sq = -(-seq // bq) * bq
    sk = -(-seq // bk) * bk
    flops = 4.0 * _HEADS * sq * sk * _HEAD_DIM
    eff = spec.family_eff.get("pallas", 0.5)
    lane = 1.0 if bk % 128 == 0 else (0.9 if bk % 8 == 0 else 0.7)
    steps = _HEADS * (sq // bq) * (sk // bk)
    bytes_ = 4.0 * 4 * _HEADS * seq * _HEAD_DIM
    return max(flops / (eff * lane * spec.peak_flops),
               bytes_ / spec.mem_bw) + 2e-8 * steps


SPACE = TunableSpace(kernel="flash_attention", axes=AXES, valid=_valid,
                     benchmark=_benchmark, analytic=_analytic)
