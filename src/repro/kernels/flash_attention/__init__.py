from .bench import benchmark_entry
from .kernel import flash_attention_pallas
from .ops import flash_attention
from .ref import attention_ref

__all__ = ["benchmark_entry", "flash_attention", "flash_attention_pallas", "attention_ref"]
