"""jit'd wrapper: batching, GQA plumbing, seq padding for flash attention."""
from __future__ import annotations

import functools

import jax

from ..common import pad_to
from .kernel import flash_attention_pallas


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "softcap",
                                    "bq", "bk", "scale"))
def flash_attention(q, k, v, *, scale=None, causal: bool = False,
                    window: int = 0, softcap: float = 0.0,
                    bq: int = 128, bk: int = 128):
    """Multi-head attention via the Pallas flash kernel.

    q: (B, Hq, Lq, D); k, v: (B, Hkv, Lk, D) -> (B, Hq, Lq, D).
    Handles GQA (Hq % Hkv == 0) and arbitrary Lq/Lk via padding; padded
    KV positions are masked inside the kernel via ``lk_valid``.
    """
    b, hq, lq, d = q.shape
    scale = float(scale if scale is not None else d ** -0.5)
    lk = k.shape[2]
    bq_ = min(bq, max(8, lq))
    bk_ = min(bk, max(8, lk))
    qp, _ = pad_to(q, 2, bq_)
    kp, _ = pad_to(k, 2, bk_)
    vp, _ = pad_to(v, 2, bk_)

    def one(qb, kb, vb):
        return flash_attention_pallas(
            qb, kb, vb, scale=scale, causal=causal, window=window,
            softcap=softcap, bq=bq_, bk=bk_, lk_valid=lk)

    out = jax.vmap(one)(qp, kp, vp)
    return out[:, :, :lq, :]
