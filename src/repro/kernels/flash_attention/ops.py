"""jit'd wrapper: batching, GQA plumbing, seq padding for flash attention."""
from __future__ import annotations

import functools

import jax

from ..common import pad_to
from .kernel import flash_attention_pallas


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "softcap",
                                    "bq", "bk", "scale", "layout"))
def flash_attention(q, k, v, *, scale=None, causal: bool = False,
                    window: int = 0, softcap: float = 0.0,
                    bq: int = 128, bk: int = 128, layout: str = "HLD"):
    """Multi-head attention via the Pallas flash kernel.

    ``layout="HLD"`` (native): q is (B, Hq, Lq, D), k/v are
    (B, Hkv, Lk, D) -> (B, Hq, Lq, D).  ``layout="LHD"`` is the fused
    sequence-major entry point: q is (B, Lq, Hq, D) and the output
    comes back (B, Lq, Hq, D) — the head/sequence remap happens in the
    kernel's BlockSpec index maps, not as a materialized transpose.
    Handles GQA (Hq % Hkv == 0) and arbitrary Lq/Lk via padding; padded
    KV positions are masked inside the kernel via ``lk_valid``.
    """
    assert layout in ("HLD", "LHD")
    seq_major = layout == "LHD"
    seq_axis = 1 if seq_major else 2
    if seq_major:
        b, lq, hq, d = q.shape
        lk = k.shape[1]
    else:
        b, hq, lq, d = q.shape
        lk = k.shape[2]
    scale = float(scale if scale is not None else d ** -0.5)
    bq_ = min(bq, max(8, lq))
    bk_ = min(bk, max(8, lk))
    qp, _ = pad_to(q, seq_axis, bq_)
    kp, _ = pad_to(k, seq_axis, bk_)
    vp, _ = pad_to(v, seq_axis, bk_)

    def one(qb, kb, vb):
        return flash_attention_pallas(
            qb, kb, vb, scale=scale, causal=causal, window=window,
            softcap=softcap, bq=bq_, bk=bk_, lk_valid=lk,
            seq_major=seq_major)

    out = jax.vmap(one)(qp, kp, vp)
    return out[:, :lq] if seq_major else out[:, :, :lq, :]
