"""Flash attention Pallas kernel (online softmax over KV blocks).

TPU adaptation notes (vs the CUDA flash-attention the idea comes from):
no warps/shared-memory banking — instead the KV loop is the innermost
*sequential* grid dimension and the running (m, l, acc) statistics live
in VMEM scratch that persists across grid steps.  Block shapes are
MXU/VPU aligned (bq x d and bk x d tiles, d = head_dim).

Supports:
  * causal masking              (decoder LMs)
  * sliding-window masking      (gemma2 local layers, window W)
  * logit soft-capping          (gemma2: cap * tanh(logits / cap))
  * GQA                         (kv-head = q-head // group, via index_map)

Grid: (n_q_heads, Lq / bq, Lk / bk) — KV innermost.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import use_interpret

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int, softcap: float,
                  bq: int, bk: int, lk_valid: int, seq_major: bool = False):
    # seq_major: tensors are (L, H, D) and blocks arrive (b, 1, d) — the
    # head axis is squeezed here in the prologue/epilogue instead of a
    # materialized (L, H, D) -> (H, L, D) transpose outside the kernel.
    sq = (lambda ref: ref[:, 0]) if seq_major else (lambda ref: ref[0])
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _compute():
        q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        q = sq(q_ref).astype(jnp.float32) * scale
        k = sq(k_ref).astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        mask = k_pos < lk_valid
        if causal:
            mask = jnp.logical_and(mask, q_pos >= k_pos)
        if window > 0:
            mask = jnp.logical_and(mask, q_pos - k_pos < window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_cur), 0.0)
        alpha = jnp.exp(m_prev - m_cur)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p, sq(v_ref).astype(jnp.float32))
        m_ref[...] = m_cur

    # Skip fully-masked KV blocks (causal: block entirely in the future;
    # window: block entirely before the window).  The conditions are
    # traced scalars over program ids, so pl.when elides the compute.
    if causal or window > 0:
        run = ki * bk <= qi * bq + bq - 1 if causal else (ki >= 0)
        if window > 0:
            run = jnp.logical_and(run, qi * bq - window < (ki + 1) * bk)
        pl.when(run)(_compute)
    else:
        _compute()

    @pl.when(ki == pl.num_programs(2) - 1)
    def _finish():
        l = l_ref[...]
        out = (acc_ref[...] / jnp.where(l == 0.0, 1.0, l)).astype(
            o_ref.dtype)
        if seq_major:
            o_ref[:, 0] = out
        else:
            o_ref[0] = out


def flash_attention_pallas(q, k, v, *, scale: float, causal: bool = False,
                           window: int = 0, softcap: float = 0.0,
                           bq: int = 128, bk: int = 128, lk_valid=None,
                           seq_major: bool = False, interpret=None):
    """q: (Hq, Lq, D); k, v: (Hkv, Lk, D).  Lq % bq == Lk % bk == 0.

    ``lk_valid``: true KV length before padding (positions beyond it are
    masked out).  GQA is expressed in the BlockSpec index map (kv head =
    q head // group) so KV tiles are fetched once per group, not
    replicated.

    ``seq_major=True`` is the layout-parameterized fused entry point:
    q is (Lq, Hq, D) and k/v are (Lk, Hkv, D) — the layout token/
    projection stacks produce naturally.  The BlockSpec index maps fetch
    (b, 1, d) tiles from the sequence-major arrays and the kernel
    squeezes the head axis in its prologue, so no head-major transpose
    is ever materialized; the output is emitted (Lq, Hq, D) the same
    way in the epilogue.
    """
    if seq_major:
        lq, hq, d = q.shape
        lk, hkv, _ = k.shape
    else:
        hq, lq, d = q.shape
        hkv, lk, _ = k.shape
    assert hq % hkv == 0 and lq % bq == 0 and lk % bk == 0
    group = hq // hkv
    if interpret is None:
        interpret = use_interpret()
    if lk_valid is None:
        lk_valid = lk

    grid = (hq, lq // bq, lk // bk)
    kern = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, bq=bq, bk=bk, lk_valid=lk_valid,
        seq_major=seq_major)
    if seq_major:
        in_specs = [
            pl.BlockSpec((bq, 1, d), lambda h, i, j: (i, h, 0)),
            pl.BlockSpec((bk, 1, d), lambda h, i, j: (j, h // group, 0)),
            pl.BlockSpec((bk, 1, d), lambda h, i, j: (j, h // group, 0)),
        ]
        out_spec = pl.BlockSpec((bq, 1, d), lambda h, i, j: (i, h, 0))
        out_shape = (lq, hq, d)
    else:
        in_specs = [
            pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j: (h // group, j, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j: (h // group, j, 0)),
        ]
        out_spec = pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0))
        out_shape = (hq, lq, d)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct(out_shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
