"""Pure-jnp oracle for flash attention (dense softmax attention)."""
import jax.numpy as jnp
import numpy as np


def attention_ref(q, k, v, *, scale=None, causal: bool = False,
                  window: int = 0, softcap: float = 0.0):
    """q: (B, Hq, Lq, D); k, v: (B, Hkv, Lk, D)."""
    b, hq, lq, d = q.shape
    hkv, lk = k.shape[1], k.shape[2]
    scale = scale if scale is not None else d ** -0.5
    group = hq // hkv
    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) * scale
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    qpos = np.arange(lq)[:, None]
    kpos = np.arange(lk)[None, :]
    mask = np.ones((lq, lk), bool)
    if causal:
        mask &= qpos >= kpos
    if window > 0:
        mask &= (qpos - kpos) < window
    s = jnp.where(jnp.asarray(mask), s, -1e30)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(jnp.float32))
    return out.astype(q.dtype)
