"""Shared helpers for the Pallas TPU kernels.

All kernels are written for TPU (pl.pallas_call + BlockSpec VMEM tiling,
MXU-aligned block shapes) and validated on CPU via interpret mode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def use_interpret() -> bool:
    """Pallas interpret mode: execute kernel bodies in Python on CPU."""
    return jax.devices()[0].platform != "tpu"


def pad_to(x, axis: int, multiple: int, value=0.0):
    """Pad ``axis`` of x up to a multiple; returns (padded, orig_size)."""
    n = x.shape[axis]
    rem = (-n) % multiple
    if rem == 0:
        return x, n
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads, constant_values=value), n


def cdiv(a: int, b: int) -> int:
    return -(-a // b)
