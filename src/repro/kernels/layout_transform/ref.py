"""Pure-jnp oracles for the layout transforms."""
import jax.numpy as jnp


def chw_to_hwc_ref(x):
    return jnp.transpose(x, (1, 2, 0))


def hwc_to_chw_ref(x):
    return jnp.transpose(x, (2, 0, 1))
