"""Tiled data-layout transformation (CHW <-> HWC) Pallas kernel.

The paper's DT-graph edges are executed by routines like this one: a
blocked transpose that reads (C, bh, bw) tiles and writes (bh, bw, C)
tiles, keeping both tiles VMEM-resident so HBM sees only two streaming
passes.  On TPU the (8, 128) sublane/lane register tiling makes the
choice of which axis lands innermost *the* performance lever — exactly
the paper's thesis that layout is a first-class optimization decision.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import use_interpret


def _chw_to_hwc_kernel(x_ref, o_ref):
    o_ref[...] = jnp.transpose(x_ref[...], (1, 2, 0))


def _hwc_to_chw_kernel(x_ref, o_ref):
    o_ref[...] = jnp.transpose(x_ref[...], (2, 0, 1))


def chw_to_hwc_pallas(x, *, bh: int = 8, bw: int = 128, interpret=None):
    """x: (C, H, W) -> (H, W, C); H % bh == W % bw == 0."""
    c, h, w = x.shape
    assert h % bh == 0 and w % bw == 0
    if interpret is None:
        interpret = use_interpret()
    return pl.pallas_call(
        _chw_to_hwc_kernel,
        grid=(h // bh, w // bw),
        in_specs=[pl.BlockSpec((c, bh, bw), lambda i, j: (0, i, j))],
        out_specs=pl.BlockSpec((bh, bw, c), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((h, w, c), x.dtype),
        interpret=interpret,
    )(x)


def hwc_to_chw_pallas(x, *, bh: int = 8, bw: int = 128, interpret=None):
    """x: (H, W, C) -> (C, H, W); H % bh == W % bw == 0."""
    h, w, c = x.shape
    assert h % bh == 0 and w % bw == 0
    if interpret is None:
        interpret = use_interpret()
    return pl.pallas_call(
        _hwc_to_chw_kernel,
        grid=(h // bh, w // bw),
        in_specs=[pl.BlockSpec((bh, bw, c), lambda i, j: (i, j, 0))],
        out_specs=pl.BlockSpec((c, bh, bw), lambda i, j: (0, i, j)),
        out_shape=jax.ShapeDtypeStruct((c, h, w), x.dtype),
        interpret=interpret,
    )(x)


# ----------------------------------------------------------------------
# blocked-layout fusion: one-shot CHW <-> HWC8 tiles.  The DT graph only
# reaches HWC8 through HWC (two materialized passes); these kernels fold
# the permute and the channel blocking into a single grid so HBM sees
# one read and one write.
# ----------------------------------------------------------------------
def _chw_to_hwc8_kernel(x_ref, o_ref):
    x = x_ref[...]
    c, bh, bw = x.shape
    o_ref[...] = jnp.transpose(x, (1, 2, 0)).reshape(bh, bw, c // 8, 8)


def _hwc8_to_chw_kernel(x_ref, o_ref):
    x = x_ref[...]
    bh, bw, cb, blk = x.shape
    o_ref[...] = jnp.transpose(x.reshape(bh, bw, cb * blk), (2, 0, 1))


def chw_to_hwc8_pallas(x, *, bh: int = 8, bw: int = 128, interpret=None):
    """x: (C, H, W) -> (H, W, C/8, 8); C % 8 == H % bh == W % bw == 0."""
    c, h, w = x.shape
    assert c % 8 == 0 and h % bh == 0 and w % bw == 0
    if interpret is None:
        interpret = use_interpret()
    return pl.pallas_call(
        _chw_to_hwc8_kernel,
        grid=(h // bh, w // bw),
        in_specs=[pl.BlockSpec((c, bh, bw), lambda i, j: (0, i, j))],
        out_specs=pl.BlockSpec((bh, bw, c // 8, 8),
                               lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((h, w, c // 8, 8), x.dtype),
        interpret=interpret,
    )(x)


def hwc8_to_chw_pallas(x, *, bh: int = 8, bw: int = 128, interpret=None):
    """x: (H, W, C/8, 8) -> (C, H, W); H % bh == W % bw == 0."""
    h, w, cb, blk = x.shape
    assert blk == 8 and h % bh == 0 and w % bw == 0
    c = cb * blk
    if interpret is None:
        interpret = use_interpret()
    return pl.pallas_call(
        _hwc8_to_chw_kernel,
        grid=(h // bh, w // bw),
        in_specs=[pl.BlockSpec((bh, bw, cb, blk),
                               lambda i, j: (i, j, 0, 0))],
        out_specs=pl.BlockSpec((c, bh, bw), lambda i, j: (0, i, j)),
        out_shape=jax.ShapeDtypeStruct((c, h, w), x.dtype),
        interpret=interpret,
    )(x)
