"""Tunable space of the tiled layout-transform kernels (autotune hook).

Kernel-only space: the transpose kernels take (bh, bw) spatial tiles
(currently hardcoded 8/128 in the ops wrappers); winning tiles per
bucket land in the variant catalog as ``kernel::`` entries.  Transforms
are pure data movement, so the analytic model is bandwidth-only with
padding waste.
"""
from __future__ import annotations

import numpy as np

from ...autotune.space import TunableSpace

AXES = (("bh", (8, 16, 32)),
        ("bw", (64, 128, 256)))


def _valid(p) -> bool:
    bh, bw = p["bh"], p["bw"]
    if bh % 8 or bw % 8:
        return False
    return bh * bw * 4 <= 2 ** 20  # one tile per step, both copies


def _benchmark(scn, params):
    bh, bw = params["bh"], params["bw"]

    def build():
        import jax
        import jax.numpy as jnp

        from ..common import pad_to
        from .kernel import chw_to_hwc_pallas
        rng = np.random.default_rng(0)
        c, h, w = scn.in_shape_chw
        x = jnp.asarray(rng.normal(size=(c, h, w)), jnp.float32)
        bh_ = min(bh, max(8, h)) if h >= 8 else h
        bw_ = min(bw, max(8, w)) if w >= 8 else w

        def fn(a):
            xp, _ = pad_to(a, 1, bh_)
            xp, _ = pad_to(xp, 2, bw_)
            return chw_to_hwc_pallas(xp, bh=bh_, bw=bw_)[:h, :w, :]

        return jax.jit(fn), (x,)

    return build


def _analytic(scn, params, spec) -> float:
    c, h, w = scn.in_shape_chw
    bh = min(params["bh"], max(8, h))
    bw = min(params["bw"], max(8, w))
    hp = -(-h // bh) * bh
    wp = -(-w // bw) * bw
    nbytes = 2.0 * 4 * c * hp * wp  # read + write, padded
    lane = 1.0 if bw % 128 == 0 else (0.9 if bw % 8 == 0 else 0.7)
    steps = (hp // bh) * (wp // bw)
    return nbytes / (lane * spec.mem_bw) + 2e-8 * steps


SPACE = TunableSpace(kernel="layout_transform", axes=AXES, valid=_valid,
                     benchmark=_benchmark, analytic=_analytic)
