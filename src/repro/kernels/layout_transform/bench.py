"""Calibration benchmark entry for the tiled layout-transform kernels."""
from __future__ import annotations

import numpy as np

from ...core.scenario import Scenario


def benchmark_entry(scn: Scenario):
    """Zero-arg builder timing CHW->HWC on the scenario's input tensor."""
    def build():
        import jax.numpy as jnp

        from .ops import chw_to_hwc
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=scn.in_shape_chw), jnp.float32)
        return chw_to_hwc, (x,)

    return build
