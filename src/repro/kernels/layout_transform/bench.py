"""Calibration benchmark entry for the tiled layout-transform kernels."""
from __future__ import annotations

import numpy as np

from ...core.scenario import Scenario


def benchmark_entry(scn: Scenario):
    """Zero-arg builder timing the tiled transform on the scenario's
    input tensor, via the :func:`~repro.kernels.layout_transform.ops.
    convert` dispatcher — the one-shot CHW->HWC8 kernel when the
    channel count allows blocking, the CHW->HWC transpose otherwise."""
    def build():
        import jax
        import jax.numpy as jnp

        from .ops import convert
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=scn.in_shape_chw), jnp.float32)
        dst = "HWC8" if scn.c % 8 == 0 else "HWC"
        fn = jax.jit(lambda a: convert(a, "CHW", dst))
        return fn, (x,)

    return build
