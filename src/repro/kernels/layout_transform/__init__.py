from .bench import benchmark_entry
from .kernel import (
    chw_to_hwc8_pallas, chw_to_hwc_pallas, hwc8_to_chw_pallas,
    hwc_to_chw_pallas,
)
from .ops import chw_to_hwc, chw_to_hwc8, convert, hwc8_to_chw, hwc_to_chw
from .ref import chw_to_hwc_ref, hwc_to_chw_ref

__all__ = ["benchmark_entry", "chw_to_hwc", "hwc_to_chw", "chw_to_hwc8",
           "hwc8_to_chw", "convert", "chw_to_hwc_pallas", "hwc_to_chw_pallas",
           "chw_to_hwc8_pallas", "hwc8_to_chw_pallas", "chw_to_hwc_ref",
           "hwc_to_chw_ref"]
