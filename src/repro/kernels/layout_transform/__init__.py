from .bench import benchmark_entry
from .kernel import chw_to_hwc_pallas, hwc_to_chw_pallas
from .ops import chw_to_hwc, hwc_to_chw
from .ref import chw_to_hwc_ref, hwc_to_chw_ref

__all__ = ["benchmark_entry", "chw_to_hwc", "hwc_to_chw", "chw_to_hwc_pallas",
           "hwc_to_chw_pallas", "chw_to_hwc_ref", "hwc_to_chw_ref"]
