"""jit'd wrappers for the tiled layout-transform kernels (any shape)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..common import pad_to
from .kernel import (
    chw_to_hwc8_pallas, chw_to_hwc_pallas, hwc8_to_chw_pallas,
    hwc_to_chw_pallas,
)


@jax.jit
def chw_to_hwc(x):
    c, h, w = x.shape
    bh = 8 if h >= 8 else h
    bw = 128 if w >= 128 else w
    xp, _ = pad_to(x, 1, bh)
    xp, _ = pad_to(xp, 2, bw)
    return chw_to_hwc_pallas(xp, bh=bh, bw=bw)[:h, :w, :]


@jax.jit
def hwc_to_chw(x):
    h, w, c = x.shape
    bh = 8 if h >= 8 else h
    bw = 128 if w >= 128 else w
    xp, _ = pad_to(x, 0, bh)
    xp, _ = pad_to(xp, 1, bw)
    return hwc_to_chw_pallas(xp, bh=bh, bw=bw)[:, :h, :w]


@jax.jit
def chw_to_hwc8(x):
    """One-shot (C, H, W) -> (H, W, C/8, 8); C % 8 == 0, any H/W.

    Non-aligned spatial extents are zero-padded up to the tile grid and
    cropped back after the kernel — the padding/cropping mirrors how
    every other kernel wrapper legalizes odd shapes.
    """
    c, h, w = x.shape
    bh = 8 if h >= 8 else h
    bw = 128 if w >= 128 else w
    xp, _ = pad_to(x, 1, bh)
    xp, _ = pad_to(xp, 2, bw)
    return chw_to_hwc8_pallas(xp, bh=bh, bw=bw)[:h, :w]


@jax.jit
def hwc8_to_chw(x):
    """One-shot (H, W, C/8, 8) -> (C, H, W); any H/W (padded + cropped)."""
    h, w, cb, blk = x.shape
    bh = 8 if h >= 8 else h
    bw = 128 if w >= 128 else w
    xp, _ = pad_to(x, 0, bh)
    xp, _ = pad_to(xp, 1, bw)
    return hwc8_to_chw_pallas(xp, bh=bh, bw=bw)[:, :h, :w]


#: direct tiled kernels by (src, dst) layout-name pair
_DIRECT = {
    ("CHW", "HWC"): chw_to_hwc,
    ("HWC", "CHW"): hwc_to_chw,
    ("CHW", "HWC8"): chw_to_hwc8,
    ("HWC8", "CHW"): hwc8_to_chw,
}


def convert(x, src: str, dst: str):
    """Layout-parameterized entry point: tiled one-shot transform when a
    direct kernel exists for (src, dst), traced ``convert_layout``
    otherwise — callers get the best available route without caring
    which pairs have dedicated kernels."""
    if src == dst:
        return x
    fn = _DIRECT.get((src, dst))
    if fn is not None:
        return fn(x)
    from ...core.primitives import convert_layout
    return convert_layout(x, src, dst)
