"""jit'd wrappers for the tiled layout-transform kernels (any shape)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..common import pad_to
from .kernel import chw_to_hwc_pallas, hwc_to_chw_pallas


@jax.jit
def chw_to_hwc(x):
    c, h, w = x.shape
    bh = 8 if h >= 8 else h
    bw = 128 if w >= 128 else w
    xp, _ = pad_to(x, 1, bh)
    xp, _ = pad_to(xp, 2, bw)
    return chw_to_hwc_pallas(xp, bh=bh, bw=bw)[:h, :w, :]


@jax.jit
def hwc_to_chw(x):
    h, w, c = x.shape
    bh = 8 if h >= 8 else h
    bw = 128 if w >= 128 else w
    xp, _ = pad_to(x, 0, bh)
    xp, _ = pad_to(xp, 1, bw)
    return hwc_to_chw_pallas(xp, bh=bh, bw=bw)[:, :h, :w]
