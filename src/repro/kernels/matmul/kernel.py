"""MXU-tiled GEMM Pallas kernel (with optional fused bias + ReLU).

Grid (M/bm, N/bn, K/bk); K is the innermost (sequential) grid dimension
so the f32 VMEM accumulator carries across K steps.  Block shapes default
to 128x128x128: MXU-aligned (the MXU is a 128x128 systolic array) and
small enough that x-block + y-block + acc fit comfortably in the ~16 MB
of VMEM (128*128*4 B * 3 = 192 KiB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import use_interpret


def _mm_kernel(x_ref, y_ref, o_ref, acc_ref, *, fuse_relu: bool,
               trans_lhs: bool, trans_out: bool):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    if trans_lhs:  # fused prologue: LHS tile arrives K-major, remap here
        x = x.T
    acc_ref[...] += jnp.dot(x, y_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _store():
        acc = acc_ref[...]
        if fuse_relu:
            acc = jnp.maximum(acc, 0.0)
        if trans_out:  # fused epilogue: emit the (N, M) output layout
            acc = acc.T
        o_ref[...] = acc.astype(o_ref.dtype)


def _mm_bias_kernel(x_ref, y_ref, b_ref, o_ref, acc_ref, *, fuse_relu: bool,
                    trans_lhs: bool, trans_out: bool):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    if trans_lhs:
        x = x.T
    acc_ref[...] += jnp.dot(x, y_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _store():
        acc = acc_ref[...] + b_ref[...].astype(jnp.float32)
        if fuse_relu:
            acc = jnp.maximum(acc, 0.0)
        if trans_out:
            acc = acc.T
        o_ref[...] = acc.astype(o_ref.dtype)


def matmul_pallas(x, y, bias=None, *, bm: int = 128, bn: int = 128,
                  bk: int = 128, fuse_relu: bool = False,
                  lhs_layout: str = "mk", out_layout: str = "mn",
                  out_dtype=None, interpret=None):
    """``x @ y (+ bias)`` with all dims REQUIRED to be block multiples
    (use ops.matmul for the padded general entry point).

    Layout-parameterized entry point (transform fusion):

    * ``lhs_layout="km"`` — ``x`` is stored transposed, shape (K, M).
      The BlockSpec index map fetches (bk, bm) tiles and the kernel
      transposes them in its prologue, VMEM-resident: no materialized
      transpose pass over the LHS.
    * ``out_layout="nm"`` — the output is emitted transposed, shape
      (N, M): the epilogue stores accumulator tiles through a remapped
      (bn, bm) out BlockSpec.
    """
    assert lhs_layout in ("mk", "km") and out_layout in ("mn", "nm")
    trans_lhs = lhs_layout == "km"
    trans_out = out_layout == "nm"
    if trans_lhs:
        k, m = x.shape
    else:
        m, k = x.shape
    k2, n = y.shape
    assert k == k2 and m % bm == 0 and n % bn == 0 and k % bk == 0
    if interpret is None:
        interpret = use_interpret()
    out_dtype = out_dtype or x.dtype

    grid = (m // bm, n // bn, k // bk)
    in_specs = [
        pl.BlockSpec((bk, bm), lambda i, j, kk: (kk, i)) if trans_lhs
        else pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
    ]
    args = (x, y)
    kw = dict(fuse_relu=fuse_relu, trans_lhs=trans_lhs,
              trans_out=trans_out)
    if bias is not None:
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)))
        args = (x, y, bias.reshape(1, n))
        kern = functools.partial(_mm_bias_kernel, **kw)
    else:
        kern = functools.partial(_mm_kernel, **kw)

    out_spec = pl.BlockSpec((bn, bm), lambda i, j, kk: (j, i)) if trans_out \
        else pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j))
    out_shape = (n, m) if trans_out else (m, n)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct(out_shape, out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(*args)
