"""MXU-tiled GEMM Pallas kernel (with optional fused bias + ReLU).

Grid (M/bm, N/bn, K/bk); K is the innermost (sequential) grid dimension
so the f32 VMEM accumulator carries across K steps.  Block shapes default
to 128x128x128: MXU-aligned (the MXU is a 128x128 systolic array) and
small enough that x-block + y-block + acc fit comfortably in the ~16 MB
of VMEM (128*128*4 B * 3 = 192 KiB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import use_interpret


def _mm_kernel(x_ref, y_ref, o_ref, acc_ref, *, fuse_relu: bool):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], y_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _store():
        acc = acc_ref[...]
        if fuse_relu:
            acc = jnp.maximum(acc, 0.0)
        o_ref[...] = acc.astype(o_ref.dtype)


def _mm_bias_kernel(x_ref, y_ref, b_ref, o_ref, acc_ref, *, fuse_relu: bool):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], y_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _store():
        acc = acc_ref[...] + b_ref[...].astype(jnp.float32)
        if fuse_relu:
            acc = jnp.maximum(acc, 0.0)
        o_ref[...] = acc.astype(o_ref.dtype)


def matmul_pallas(x, y, bias=None, *, bm: int = 128, bn: int = 128,
                  bk: int = 128, fuse_relu: bool = False,
                  out_dtype=None, interpret=None):
    """``x @ y (+ bias)`` with all dims REQUIRED to be block multiples
    (use ops.matmul for the padded general entry point)."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2 and m % bm == 0 and n % bn == 0 and k % bk == 0
    if interpret is None:
        interpret = use_interpret()
    out_dtype = out_dtype or x.dtype

    grid = (m // bm, n // bn, k // bk)
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
    ]
    args = (x, y)
    if bias is not None:
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)))
        args = (x, y, bias.reshape(1, n))
        kern = functools.partial(_mm_bias_kernel, fuse_relu=fuse_relu)
    else:
        kern = functools.partial(_mm_kernel, fuse_relu=fuse_relu)

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(*args)
