"""jit'd wrapper around the Pallas GEMM: pads to block multiples."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..common import pad_to
from .kernel import matmul_pallas


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "fuse_relu",
                                             "lhs_layout", "out_layout"))
def matmul(x, y, bias=None, *, bm: int = 128, bn: int = 128, bk: int = 128,
           fuse_relu: bool = False, lhs_layout: str = "mk",
           out_layout: str = "mn"):
    """General ``x @ y (+ bias)`` via the Pallas kernel, any shapes.

    ``lhs_layout="km"`` consumes a transposed (K, M) LHS in the kernel
    prologue; ``out_layout="nm"`` emits the transposed (N, M) product in
    the epilogue — no separate transpose pass in either case.
    """
    if lhs_layout == "km":
        k, m = x.shape
    else:
        m, k = x.shape
    _, n = y.shape
    bm_ = min(bm, max(8, m))
    bn_ = min(bn, max(8, n))
    bk_ = min(bk, max(8, k))
    xp, _ = pad_to(x, 1 if lhs_layout == "km" else 0, bm_)
    xp, _ = pad_to(xp, 0 if lhs_layout == "km" else 1, bk_)
    yp, _ = pad_to(y, 0, bk_)
    yp, _ = pad_to(yp, 1, bn_)
    bp = None
    if bias is not None:
        bp, _ = pad_to(bias, 0, bn_)
    out = matmul_pallas(xp, yp, bp, bm=bm_, bn=bn_, bk=bk_,
                        fuse_relu=fuse_relu, lhs_layout=lhs_layout,
                        out_layout=out_layout)
    return out[:n, :m] if out_layout == "nm" else out[:m, :n]
