"""Pure-jnp oracle for the Pallas GEMM."""
import jax.numpy as jnp


def matmul_ref(x, y, bias=None, fuse_relu: bool = False):
    out = jnp.dot(x.astype(jnp.float32), y.astype(jnp.float32))
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    if fuse_relu:
        out = jnp.maximum(out, 0.0)
    return out.astype(x.dtype)
