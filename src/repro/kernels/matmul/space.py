"""Tunable space of the MXU GEMM kernel (autotune hook).

Registered variants are pointwise (K=1) convolutions — the (M, C) x
(C, OHOW) GEMM the hand-written ``pallas_pw_gemm_chw`` entry runs —
tiled (bm, bn, bk).  ``bk`` doubles as the software-pipeline depth knob:
the kernel's grid walks K in ``bk`` steps.
"""
from __future__ import annotations

import functools

import jax.numpy as jnp

from ...autotune.space import TunableSpace, params_tuple
from ...core.primitives import Primitive, _sup
from .ops import matmul

BASE_NAME = "pallas_pw_gemm_chw"

_VMEM_BYTES = 4 * 2 ** 20

AXES = (("bm", (32, 64, 128, 256)),
        ("bn", (64, 128, 256, 512)),
        ("bk", (32, 64, 128, 256)))


def _valid(p) -> bool:
    bm, bn, bk = p["bm"], p["bn"], p["bk"]
    if any(b % 8 for b in (bm, bn, bk)):
        return False
    return (bm * bk + bk * bn + 2 * bm * bn) * 4 <= _VMEM_BYTES


def _prepare(scn, w, b):
    return {"w": jnp.asarray(w.reshape(scn.m, scn.c)),
            "b": jnp.asarray(b)}


def _make(scn, *, bm, bn, bk):
    def f(x, packed):  # x: CHW
        s = scn.stride
        xs = x[:, ::s, ::s] if s > 1 else x
        y = matmul(packed["w"], xs.reshape(scn.c, -1), bm=bm, bn=bn, bk=bk)
        y = y.reshape(scn.m, scn.out_h, scn.out_w)
        return y + packed["b"][:, None, None]
    return f


def _fused(bm, bn, bk):
    mm = functools.partial(matmul, bm=bm, bn=bn, bk=bk)

    def build(scn, l_in, l_out):
        def f(x, packed):
            s = scn.stride
            w = packed["w"]  # (M, C)
            if l_in == "HWC":
                xs = x[::s, ::s, :] if s > 1 else x
                p = xs.reshape(-1, scn.c)  # (OHOW, C)
                if l_out == "HWC":
                    y = mm(p, w.T).reshape(scn.out_h, scn.out_w, scn.m)
                    return y + packed["b"]
                y = mm(p, w.T, out_layout="nm")
                return (y.reshape(scn.m, scn.out_h, scn.out_w)
                        + packed["b"][:, None, None])
            xs = x[:, ::s, ::s] if s > 1 else x
            p = xs.reshape(scn.c, -1)  # (C, OHOW)
            if l_out == "HWC":
                y = mm(w, p, out_layout="nm")
                return (y.reshape(scn.out_h, scn.out_w, scn.m)
                        + packed["b"])
            y = mm(w, p).reshape(scn.m, scn.out_h, scn.out_w)
            return y + packed["b"][:, None, None]
        return f
    return build


def _make_primitive(params) -> Primitive:
    bm, bn, bk = params["bm"], params["bn"], params["bk"]
    return Primitive(
        name=SPACE.name_for(BASE_NAME, params),
        family="pallas", l_in="CHW", l_out="CHW",
        supports=_sup(k_in=(1,)), prepare=_prepare,
        make=functools.partial(_make, bm=bm, bn=bn, bk=bk),
        tags=("tpu-only", "autotuned"),
        fusable_in=("HWC",), fusable_out=("HWC",),
        fused=_fused(bm, bn, bk),
        params=params_tuple(params, SPACE.axis_order))


SPACE = TunableSpace(kernel="matmul", axes=AXES, valid=_valid,
                     make_primitive=_make_primitive)
