from .bench import benchmark_entry
from .kernel import matmul_pallas
from .ops import matmul
from .ref import matmul_ref

__all__ = ["benchmark_entry", "matmul", "matmul_pallas", "matmul_ref"]
