"""Calibration benchmark entry for the Pallas GEMM.

A convolution scenario induces the GEMM the im2col lowering would run:
``(M, C*K*K) @ (C*K*K, OH*OW)`` — timing the raw kernel at exactly those
dimensions isolates the MXU GEMM from the patch extraction around it.
"""
from __future__ import annotations

import numpy as np

from ...core.scenario import Scenario


def benchmark_entry(scn: Scenario):
    """Zero-arg builder timing the scenario-induced GEMM."""
    mm, kk, nn = scn.m, scn.c * scn.k * scn.k, scn.out_h * scn.out_w
    if min(mm, kk, nn) < 1:
        return None

    def build():
        import jax.numpy as jnp

        from .ops import matmul
        rng = np.random.default_rng(0)
        a = jnp.asarray(rng.normal(size=(mm, kk)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(kk, nn)), jnp.float32)
        return matmul, (a, b)

    return build
