"""im2col convolution: Pallas GEMM with fused bias over the patch matrix.

Patch extraction (the Toeplitz build) is bandwidth-bound gather work that
XLA's fusion handles well; the O(M * CKK * OHOW) GEMM is the hot spot and
runs on the MXU via the fused bias matmul kernel.  This mirrors the
paper's im2 family where the GEMM call dominates.
"""
from __future__ import annotations

from ..matmul.kernel import matmul_pallas

# the kernel itself is the fused-bias GEMM; re-exported for clarity
im2col_gemm_pallas = matmul_pallas
