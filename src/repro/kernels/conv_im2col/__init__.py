from .bench import benchmark_entry
from .kernel import im2col_gemm_pallas
from .ops import conv_im2col
from .ref import conv_im2col_ref

__all__ = ["benchmark_entry", "conv_im2col", "im2col_gemm_pallas", "conv_im2col_ref"]
