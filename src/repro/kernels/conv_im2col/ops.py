"""jit'd im2col convolution with the Pallas GEMM core."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from ..common import pad_to
from .kernel import im2col_gemm_pallas


@functools.partial(jax.jit, static_argnames=("stride", "pad", "bm", "bn",
                                             "bk", "in_layout",
                                             "out_layout"))
def conv_im2col(x, w, b, *, stride: int = 1, pad: int = 0, bm: int = 128,
                bn: int = 128, bk: int = 128, in_layout: str = "CHW",
                out_layout: str = "CHW"):
    """im2col conv, layout-parameterized (transform fusion entry point).

    ``in_layout="HWC"`` accepts (H, W, C) input — the transpose feeds
    straight into the Toeplitz gather, which XLA fuses (no materialized
    CHW copy).  ``out_layout="HWC"`` returns (OH, OW, M) by running the
    GEMM with the kernel's transposed-output epilogue (``out_layout=
    "nm"`` BlockSpec remap) instead of transposing the product.
    w: (M, C, K, K); b: (M,).
    """
    if in_layout == "HWC":
        x = jnp.transpose(x, (2, 0, 1))
    c, h, wd = x.shape
    m, _, k, _ = w.shape
    oh = (h + 2 * pad - k) // stride + 1
    ow = (wd + 2 * pad - k) // stride + 1
    pt = lax.conv_general_dilated_patches(
        x[None], (k, k), (stride, stride), [(pad, pad)] * 2,
        dimension_numbers=("NCHW", "OIHW", "NCHW"))[0]
    pmat = pt.reshape(c * k * k, oh * ow)
    wmat = w.reshape(m, c * k * k)

    mm, kk, nn = m, c * k * k, oh * ow
    bm_ = min(bm, max(8, mm))
    bn_ = min(bn, max(8, nn))
    bk_ = min(bk, max(8, kk))
    wp, _ = pad_to(wmat, 0, bm_)
    wp, _ = pad_to(wp, 1, bk_)
    pp, _ = pad_to(pmat, 0, bk_)
    pp, _ = pad_to(pp, 1, bn_)

    if out_layout == "HWC":
        out = im2col_gemm_pallas(wp, pp, None, bm=bm_, bn=bn_, bk=bk_,
                                 out_layout="nm")
        out = out[:nn, :mm] + b[None, :]
        return out.reshape(oh, ow, m)
    out = im2col_gemm_pallas(wp, pp, None, bm=bm_, bn=bn_, bk=bk_)
    out = out[:mm, :nn] + b[:, None]
    return out.reshape(m, oh, ow)
