"""jit'd im2col convolution with the Pallas GEMM core."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from ..common import pad_to
from .kernel import im2col_gemm_pallas


@functools.partial(jax.jit, static_argnames=("stride", "pad", "bm", "bn",
                                             "bk"))
def conv_im2col(x, w, b, *, stride: int = 1, pad: int = 0, bm: int = 128,
                bn: int = 128, bk: int = 128):
    """x: (C, H, W); w: (M, C, K, K); b: (M,) -> (M, OH, OW)."""
    c, h, wd = x.shape
    m, _, k, _ = w.shape
    oh = (h + 2 * pad - k) // stride + 1
    ow = (wd + 2 * pad - k) // stride + 1
    pt = lax.conv_general_dilated_patches(
        x[None], (k, k), (stride, stride), [(pad, pad)] * 2,
        dimension_numbers=("NCHW", "OIHW", "NCHW"))[0]
    pmat = pt.reshape(c * k * k, oh * ow)
    wmat = w.reshape(m, c * k * k)

    mm, kk, nn = m, c * k * k, oh * ow
    bm_ = min(bm, max(8, mm))
    bn_ = min(bn, max(8, nn))
    bk_ = min(bk, max(8, kk))
    wp, _ = pad_to(wmat, 0, bm_)
    wp, _ = pad_to(wp, 1, bk_)
    pp, _ = pad_to(pmat, 0, bk_)
    pp, _ = pad_to(pp, 1, bn_)
    bp, _ = pad_to(b, 0, bn_)  # unused pad target; bias applies to M rows

    out = im2col_gemm_pallas(wp, pp, None, bm=bm_, bn=bn_, bk=bk_)
    out = out[:mm, :nn] + b[:, None]
    return out.reshape(m, oh, ow)
