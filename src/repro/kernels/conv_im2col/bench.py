"""Calibration benchmark entry for the im2col Pallas-GEMM convolution."""
from __future__ import annotations

import numpy as np

from ...core.scenario import Scenario


def benchmark_entry(scn: Scenario):
    """Zero-arg builder timing ``conv_im2col`` at this scenario, or None."""
    if scn.h + 2 * scn.pad < scn.k or scn.w + 2 * scn.pad < scn.k:
        return None

    def build():
        import jax.numpy as jnp

        from .ops import conv_im2col
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=scn.in_shape_chw), jnp.float32)
        w = jnp.asarray(rng.normal(size=scn.weight_shape) * 0.1,
                        jnp.float32)
        b = jnp.asarray(rng.normal(size=(scn.m,)), jnp.float32)
        fn = lambda x, w, b: conv_im2col(x, w, b, stride=scn.stride,
                                         pad=scn.pad)
        return fn, (x, w, b)

    return build
