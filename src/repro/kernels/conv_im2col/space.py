"""Tunable space of the im2col GEMM kernel (autotune hook).

The kernel is a (M, CKK) x (CKK, OHOW) GEMM tiled (bm, bn, bk); the
working set per grid step is the LHS/RHS/accumulator tiles.  Variants
inherit ``pallas_im2col_chw``'s layouts and fusable sets — the fused
entry points already take the block sizes through the ops wrapper.
"""
from __future__ import annotations

import functools

import jax.numpy as jnp

from ...autotune.space import TunableSpace, params_tuple
from ...core.primitives import Primitive, _sup
from .ops import conv_im2col

BASE_NAME = "pallas_im2col_chw"

#: f32 VMEM budget for one grid step's tiles (conservative half-VMEM)
_VMEM_BYTES = 4 * 2 ** 20

AXES = (("bm", (32, 64, 128, 256)),
        ("bn", (64, 128, 256, 512)),
        ("bk", (32, 64, 128, 256)))


def _valid(p) -> bool:
    bm, bn, bk = p["bm"], p["bn"], p["bk"]
    if any(b % 8 for b in (bm, bn, bk)):  # MXU sublane alignment
        return False
    tiles = bm * bk + bk * bn + 2 * bm * bn  # lhs + rhs + out + f32 acc
    return tiles * 4 <= _VMEM_BYTES


def _prepare(scn, w, b):
    return {"w": jnp.asarray(w), "b": jnp.asarray(b)}


def _make(scn, *, bm, bn, bk):
    def f(x, packed):  # x: CHW
        return conv_im2col(x, packed["w"], packed["b"], stride=scn.stride,
                           pad=scn.pad, bm=bm, bn=bn, bk=bk)
    return f


def _fused(bm, bn, bk):
    def build(scn, l_in, l_out):
        def f(x, packed):
            return conv_im2col(x, packed["w"], packed["b"],
                               stride=scn.stride, pad=scn.pad,
                               bm=bm, bn=bn, bk=bk,
                               in_layout=l_in, out_layout=l_out)
        return f
    return build


def _make_primitive(params) -> Primitive:
    bm, bn, bk = params["bm"], params["bn"], params["bk"]
    return Primitive(
        name=SPACE.name_for(BASE_NAME, params),
        family="pallas", l_in="CHW", l_out="CHW",
        supports=_sup(), prepare=_prepare,
        make=functools.partial(_make, bm=bm, bn=bn, bk=bk),
        tags=("tpu-only", "autotuned"),
        fusable_in=("HWC",), fusable_out=("HWC",),
        fused=_fused(bm, bn, bk),
        params=params_tuple(params, SPACE.axis_order))


SPACE = TunableSpace(kernel="conv_im2col", axes=AXES, valid=_valid,
                     make_primitive=_make_primitive)
