"""Pure-jnp oracle for the im2col convolution."""
import jax.numpy as jnp
from jax import lax


def conv_im2col_ref(x, w, b, *, stride: int = 1, pad: int = 0):
    out = lax.conv_general_dilated(
        x[None], w, (stride, stride), [(pad, pad)] * 2,
        dimension_numbers=("NCHW", "OIHW", "NCHW"))[0]
    return out + b[:, None, None]
