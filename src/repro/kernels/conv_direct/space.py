"""Tunable space of the direct NHWC kernel (autotune hook).

Axes: ``bm`` — output-channel tile (the grid dimension); ``unroll`` —
fully unrolled K x K tap loop (1) vs the rolled ``fori_loop`` variant
(0), which trades per-tap control flow for a smaller kernel program.
The input strip must fit VMEM, which depends on the scenario — that
check lives in the generated primitive's ``supports``, same as the
hand-written entry.
"""
from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np

from ...autotune.space import TunableSpace, params_tuple
from ...core.primitives import Primitive, _sup
from .ops import conv_direct

BASE_NAME = "pallas_direct_hwc"

AXES = (("bm", (32, 64, 128, 256)),
        ("unroll", (0, 1)))


def _valid(p) -> bool:
    return p["bm"] % 8 == 0


def _vmem_ok(scn) -> bool:
    # the kernel keeps the padded input strip in VMEM (see
    # kernels/__init__.py::register_pallas_primitives)
    hp = scn.h + 2 * scn.pad
    wp = scn.w + 2 * scn.pad
    return hp * wp * scn.c * 4 <= 8 * 2 ** 20


def _supports(scn) -> bool:
    return _sup()(scn) and _vmem_ok(scn)


def _prepare(scn, w, b):
    return {"w": jnp.asarray(np.transpose(w, (2, 3, 1, 0)).copy()),
            "b": jnp.asarray(b)}


def _make(scn, *, bm, unroll):
    def f(x, packed):  # x: HWC
        return conv_direct(x, packed["w"], packed["b"], stride=scn.stride,
                           pad=scn.pad, bm=bm, unroll=bool(unroll))
    return f


def _fused(bm, unroll):
    def build(scn, l_in, l_out):
        def f(x, packed):
            return conv_direct(x, packed["w"], packed["b"],
                               stride=scn.stride, pad=scn.pad, bm=bm,
                               unroll=bool(unroll),
                               in_layout=l_in, out_layout=l_out)
        return f
    return build


def _make_primitive(params) -> Primitive:
    bm, unroll = params["bm"], params["unroll"]
    return Primitive(
        name=SPACE.name_for(BASE_NAME, params),
        family="pallas", l_in="HWC", l_out="HWC",
        supports=_supports, prepare=_prepare,
        make=functools.partial(_make, bm=bm, unroll=unroll),
        tags=("tpu-only", "autotuned"),
        fusable_in=("CHW",), fusable_out=("CHW",),
        fused=_fused(bm, unroll),
        params=params_tuple(params, SPACE.axis_order))


SPACE = TunableSpace(kernel="conv_direct", axes=AXES, valid=_valid,
                     make_primitive=_make_primitive)
