from .bench import benchmark_entry
from .kernel import conv_direct_pallas
from .ops import conv_direct
from .ref import conv_direct_ref

__all__ = ["benchmark_entry", "conv_direct", "conv_direct_pallas", "conv_direct_ref"]
