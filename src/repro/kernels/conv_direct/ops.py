"""jit'd wrapper for the direct NHWC Pallas convolution."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..common import pad_to
from .kernel import conv_direct_pallas


@functools.partial(jax.jit, static_argnames=("stride", "pad", "bm"))
def conv_direct(x, w, b, *, stride: int = 1, pad: int = 0, bm: int = 128):
    """x: (H, W, C); w: (K, K, C, M); b: (M,) -> (OH, OW, M)."""
    h, wd, c = x.shape
    k, _, _, m = w.shape
    xp = jnp.pad(x, ((pad, pad), (pad, pad), (0, 0)))
    oh = (h + 2 * pad - k) // stride + 1
    ow = (wd + 2 * pad - k) // stride + 1
    bm_ = min(bm, max(8, m))
    wp, _ = pad_to(w, 3, bm_)
    bp, _ = pad_to(b, 0, bm_)
    out = conv_direct_pallas(xp, wp, bp, stride=stride, bm=bm_)
    return out[:, :m].reshape(oh, ow, m)
