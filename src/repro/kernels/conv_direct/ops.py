"""jit'd wrapper for the direct NHWC Pallas convolution."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..common import pad_to
from .kernel import conv_direct_pallas


@functools.partial(jax.jit, static_argnames=("stride", "pad", "bm",
                                             "in_layout", "out_layout",
                                             "unroll"))
def conv_direct(x, w, b, *, stride: int = 1, pad: int = 0, bm: int = 128,
                in_layout: str = "HWC", out_layout: str = "HWC",
                unroll: bool = True):
    """Direct conv, layout-parameterized (transform fusion entry point).

    ``in_layout="HWC"``: x is (H, W, C); ``"CHW"``: x is (C, H, W) and
    the kernel prologue remaps it in VMEM.  ``out_layout`` selects
    (OH, OW, M) vs (M, OH, OW) — the CHW output is stored through the
    kernel's remapped epilogue BlockSpec.  w: (K, K, C, M); b: (M,).
    """
    if in_layout == "CHW":
        c, h, wd = x.shape
        xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad)))
    else:
        h, wd, c = x.shape
        xp = jnp.pad(x, ((pad, pad), (pad, pad), (0, 0)))
    k, _, _, m = w.shape
    oh = (h + 2 * pad - k) // stride + 1
    ow = (wd + 2 * pad - k) // stride + 1
    bm_ = min(bm, max(8, m))
    wp, _ = pad_to(w, 3, bm_)
    bp, _ = pad_to(b, 0, bm_)
    out = conv_direct_pallas(xp, wp, bp, stride=stride, bm=bm_,
                             in_layout=in_layout, out_layout=out_layout,
                             unroll=unroll)
    if out_layout == "CHW":
        return out[:m].reshape(m, oh, ow)
    return out[:, :m].reshape(oh, ow, m)
