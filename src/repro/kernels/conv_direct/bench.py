"""Calibration benchmark entry for the direct NHWC Pallas convolution."""
from __future__ import annotations

import numpy as np

from ...core.scenario import Scenario


def benchmark_entry(scn: Scenario):
    """Zero-arg builder timing ``conv_direct`` at this scenario, or None.

    The builder defers tensor allocation and jit to measurement time so
    sweep planning (and ``--dry-run``) stays free.
    """
    if scn.h + 2 * scn.pad < scn.k or scn.w + 2 * scn.pad < scn.k:
        return None

    def build():
        import jax.numpy as jnp

        from .ops import conv_direct
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(scn.h, scn.w, scn.c)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(scn.k, scn.k, scn.c, scn.m)) * 0.1,
                        jnp.float32)
        b = jnp.asarray(rng.normal(size=(scn.m,)), jnp.float32)
        fn = lambda x, w, b: conv_direct(x, w, b, stride=scn.stride,
                                         pad=scn.pad)
        return fn, (x, w, b)

    return build
