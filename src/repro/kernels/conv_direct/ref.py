"""Pure-jnp oracle for the direct NHWC convolution."""
import jax.numpy as jnp
from jax import lax


def conv_direct_ref(x, w, b, *, stride: int = 1, pad: int = 0):
    """x: (H, W, C); w: (K, K, C, M); b: (M,) -> (OH, OW, M)."""
    out = lax.conv_general_dilated(
        x[None], w, (stride, stride), [(pad, pad)] * 2,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))[0]
    return out + b
