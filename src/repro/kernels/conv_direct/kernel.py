"""Direct NHWC convolution Pallas kernel.

TPU adaptation of the direct-loop family: instead of a 6-deep scalar
loop nest (CPU) the kernel keeps the input strip in VMEM and performs
one MXU matmul per kernel tap: for each (i, j) in K x K the shifted
(OH*OW, C) window is multiplied with the (C, bm) weight slice and
accumulated in an f32 VMEM scratch.  Grid is over output-channel tiles
(bm, MXU-lane aligned); the spatial extent of one image layer fits VMEM
for DNN-typical layer sizes (checked by the registry's supports()).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import use_interpret


def _conv_kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, k: int, stride: int,
                 oh: int, ow: int, c: int, chw_in: bool, chw_out: bool,
                 unroll: bool = True):
    acc_ref[...] = jnp.zeros_like(acc_ref)
    span_h = (oh - 1) * stride + 1
    span_w = (ow - 1) * stride + 1
    xa = x_ref[...]  # whole strip lives in VMEM
    if chw_in:
        # fused prologue: the producer handed us CHW; remap to the
        # kernel's HWC working order while the strip is VMEM-resident
        # (no HBM transpose round trip)
        xa = jnp.transpose(xa, (1, 2, 0))
    if unroll:
        # fully unrolled K x K tap loop: one static MXU dot per tap
        for i in range(k):
            for j in range(k):
                win = jax.lax.slice(
                    xa, (i, j, 0), (i + span_h, j + span_w, c),
                    (stride, stride, 1))
                acc_ref[...] += jnp.dot(
                    win.reshape(oh * ow, c), w_ref[i, j],
                    preferred_element_type=jnp.float32)
    else:
        # rolled tap loop (autotune variant): one fori_loop iteration
        # per tap — smaller program at the price of per-tap control flow
        wa = w_ref[...]  # (K, K, C, bm)
        bm = wa.shape[3]

        def tap(t, _):
            i, j = t // k, t % k
            win = jax.lax.dynamic_slice(
                xa, (i, j, 0), (span_h, span_w, c))[::stride, ::stride]
            wt = jax.lax.dynamic_slice(
                wa, (i, j, 0, 0), (1, 1, c, bm)).reshape(c, bm)
            acc_ref[...] += jnp.dot(win.reshape(oh * ow, c), wt,
                                    preferred_element_type=jnp.float32)
            return 0

        jax.lax.fori_loop(0, k * k, tap, 0)
    out = acc_ref[...] + b_ref[...].astype(jnp.float32)
    if chw_out:
        # fused epilogue: emit the consumer's CHW layout through the
        # remapped (bm, OH*OW) out BlockSpec
        out = out.T
    o_ref[...] = out.astype(o_ref.dtype)


def conv_direct_pallas(x, w, b, *, stride: int = 1, bm: int = 128,
                       in_layout: str = "HWC", out_layout: str = "HWC",
                       unroll: bool = True, interpret=None):
    """Pre-padded single-image direct conv; w: (K, K, C, M), M % bm == 0.

    Layout-parameterized entry point: ``in_layout`` is the layout the
    input strip arrives in — ``"HWC"`` (native, shape (Hp, Wp, C)) or
    ``"CHW"`` (shape (C, Hp, Wp), transposed in the kernel prologue).
    ``out_layout`` picks the emitted layout: ``"HWC"`` returns
    (OH*OW, M), ``"CHW"`` returns (M, OH*OW) stored via a remapped out
    BlockSpec in the epilogue.  The ops wrapper reshapes to spatial.
    """
    assert in_layout in ("HWC", "CHW") and out_layout in ("HWC", "CHW")
    chw_in = in_layout == "CHW"
    chw_out = out_layout == "CHW"
    if chw_in:
        c, hp, wp = x.shape
    else:
        hp, wp, c = x.shape
    k, _, _, m = w.shape
    assert m % bm == 0
    oh = (hp - k) // stride + 1
    ow = (wp - k) // stride + 1
    if interpret is None:
        interpret = use_interpret()

    kern = functools.partial(_conv_kernel, k=k, stride=stride, oh=oh,
                             ow=ow, c=c, chw_in=chw_in, chw_out=chw_out,
                             unroll=unroll)
    in_spec = pl.BlockSpec((c, hp, wp), lambda mi: (0, 0, 0)) if chw_in \
        else pl.BlockSpec((hp, wp, c), lambda mi: (0, 0, 0))
    out_spec = pl.BlockSpec((bm, oh * ow), lambda mi: (mi, 0)) if chw_out \
        else pl.BlockSpec((oh * ow, bm), lambda mi: (0, mi))
    out_shape = (m, oh * ow) if chw_out else (oh * ow, m)
    return pl.pallas_call(
        kern,
        grid=(m // bm,),
        in_specs=[
            in_spec,
            pl.BlockSpec((k, k, c, bm), lambda mi: (0, 0, 0, mi)),
            pl.BlockSpec((1, bm), lambda mi: (0, mi)),
        ],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct(out_shape, x.dtype),
        scratch_shapes=[pltpu.VMEM((oh * ow, bm), jnp.float32)],
        interpret=interpret,
    )(x, w, b.reshape(1, m))
