"""Tunable space of the Winograd batched-GEMM kernel (autotune hook).

Axes: ``m_`` — the F(m, 3) output tile (2 or 4; changes the offline
kernel transform, so it is part of ``prepare``); ``bn`` — spatial-tile
block of the batched GEMM; ``bc`` — input-channel block.
"""
from __future__ import annotations

import functools

import jax.numpy as jnp

from ...autotune.space import TunableSpace, params_tuple
from ...core.primitives import Primitive, _sup
from .ops import conv_winograd, prepare_kernel

BASE_NAME = "pallas_wino_chw"

_VMEM_BYTES = 4 * 2 ** 20

AXES = (("m_", (2, 4)),
        ("bn", (32, 64, 128, 256)),
        ("bc", (32, 64, 128)))


def _valid(p) -> bool:
    m_, bn, bc = p["m_"], p["bn"], p["bc"]
    if bn % 8 or bc % 8:
        return False
    a2 = (m_ + 2) ** 2  # alpha^2 for k=3
    # per grid step: V tile (bc, bn), U slice (M<=256, bc), acc (M, bn)
    return a2 * (bc * bn + 256 * bc + 256 * bn) * 4 <= 4 * _VMEM_BYTES


def _prepare(m_):
    def prep(scn, w, b):
        return {"u": prepare_kernel(w, m_), "b": jnp.asarray(b)}
    return prep


def _make(scn, *, m_, bn, bc):
    def f(x, packed):  # x: CHW
        return conv_winograd(x, packed["u"], packed["b"], m_=m_, k=scn.k,
                             stride=scn.stride, pad=scn.pad, bn=bn, bc=bc)
    return f


def _fused(m_, bn, bc):
    def build(scn, l_in, l_out):
        def f(x, packed):
            return conv_winograd(x, packed["u"], packed["b"], m_=m_,
                                 k=scn.k, stride=scn.stride, pad=scn.pad,
                                 bn=bn, bc=bc,
                                 in_layout=l_in, out_layout=l_out)
        return f
    return build


def _make_primitive(params) -> Primitive:
    m_, bn, bc = params["m_"], params["bn"], params["bc"]
    # keep the hand-written entries' name shape (pallas_wino_f{m}x3_…)
    # so the analytic model's tile parser reads the F(m, 3) config
    base = f"pallas_wino_f{m_}x3_chw"
    pt = params_tuple(params, SPACE.axis_order)
    return Primitive(
        name=SPACE.name_for(base, {k: v for k, v in params.items()
                                   if k != "m_"}),
        family="pallas", l_in="CHW", l_out="CHW",
        supports=_sup(k_in=(3,), stride1=True),
        prepare=_prepare(m_),
        make=functools.partial(_make, m_=m_, bn=bn, bc=bc),
        tags=("tpu-only", "autotuned"),
        fusable_in=("HWC",), fusable_out=("HWC",),
        fused=_fused(m_, bn, bc),
        params=pt)


SPACE = TunableSpace(kernel="winograd_gemm", axes=AXES, valid=_valid,
                     make_primitive=_make_primitive)
