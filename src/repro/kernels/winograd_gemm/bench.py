"""Calibration benchmark entry for the Winograd Pallas-bGEMM convolution."""
from __future__ import annotations

import numpy as np

from ...core.scenario import Scenario


def benchmark_entry(scn: Scenario):
    """Zero-arg builder timing ``conv_winograd`` (F(2,3)), or None.

    Winograd restrictions: K = 3, stride 1 (same predicate as the
    registered ``pallas_wino_*`` primitives).
    """
    if scn.k != 3 or scn.stride != 1:
        return None
    if scn.h + 2 * scn.pad < scn.k or scn.w + 2 * scn.pad < scn.k:
        return None

    def build():
        import jax.numpy as jnp

        from .ops import conv_winograd, prepare_kernel
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=scn.in_shape_chw), jnp.float32)
        w = (rng.normal(size=scn.weight_shape) * 0.1).astype(np.float32)
        u = prepare_kernel(w, 2)  # packing is deployment-time, untimed
        b = jnp.asarray(rng.normal(size=(scn.m,)), jnp.float32)
        fn = lambda x, u, b: conv_winograd(x, u, b, m_=2, k=scn.k,
                                           stride=1, pad=scn.pad)
        return fn, (x, u, b)

    return build
