"""Batched point-wise GEMM Pallas kernel for Winograd convolution.

The Winograd data flow is  V = B^T d B  (input transform, cheap),
Q[p] = U[p] @ V[p]  for each of the alpha^2 transform points p (this is
>95% of the FLOPs), then  y = A^T Q A.  This kernel implements the
batched GEMM stage with MXU tiling; transforms stay in XLA (they are
bandwidth-bound elementwise-ish work that XLA fuses well — the division
of labour the paper's Intel selections imply).

Grid: (P, N/bn, C/bc) with the contraction innermost; U tile (M, bc),
V tile (bc, bn), f32 VMEM accumulator of (M, bn).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import use_interpret


def _bgemm_kernel(u_ref, v_ref, o_ref, acc_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(u_ref[0], v_ref[0],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _store():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def winograd_bgemm_pallas(u, v, *, bn: int = 128, bc: int = 128,
                          interpret=None):
    """u: (P, M, C), v: (P, C, N) -> (P, M, N);  C % bc == N % bn == 0."""
    p, m, c = u.shape
    _, _, n = v.shape
    assert v.shape == (p, c, n) and n % bn == 0 and c % bc == 0
    if interpret is None:
        interpret = use_interpret()

    return pl.pallas_call(
        _bgemm_kernel,
        grid=(p, n // bn, c // bc),
        in_specs=[
            pl.BlockSpec((1, m, bc), lambda pp, j, kk: (pp, 0, kk)),
            pl.BlockSpec((1, bc, bn), lambda pp, j, kk: (pp, kk, j)),
        ],
        out_specs=pl.BlockSpec((1, m, bn), lambda pp, j, kk: (pp, 0, j)),
        out_shape=jax.ShapeDtypeStruct((p, m, n), u.dtype),
        scratch_shapes=[pltpu.VMEM((m, bn), jnp.float32)],
        interpret=interpret,
    )(u, v)
