"""Full Winograd F(m, 3) convolution with the Pallas batched-GEMM core."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ...core.winograd_transforms import winograd_matrices
from ..common import pad_to
from .kernel import winograd_bgemm_pallas


def prepare_kernel(w, m_: int = 2):
    """Offline kernel transform: (M, C, K, K) -> (alpha^2, M, C)."""
    mm, c, k, _ = w.shape
    A, G, Bt = winograd_matrices(m_, k)
    U = np.einsum("ar,mcrs,bs->abmc", G, np.asarray(w), G)
    return jnp.asarray(U.reshape((m_ + k - 1) ** 2, mm, c), jnp.float32)


@functools.partial(jax.jit, static_argnames=("m_", "k", "stride", "pad",
                                             "bn", "bc", "in_layout",
                                             "out_layout"))
def conv_winograd(x, u, b, *, m_: int = 2, k: int = 3, stride: int = 1,
                  pad: int = 1, bn: int = 128, bc: int = 128,
                  in_layout: str = "CHW", out_layout: str = "CHW"):
    """x: (C, H, W); u: prepared kernels (alpha^2, M, C); b: (M,).

    Returns (M, OH, OW).  stride must be 1 (Winograd restriction).

    Layout-parameterized (transform fusion): ``in_layout="HWC"`` feeds
    the transpose straight into the input-transform patch gather (XLA
    fuses it — the transforms are already XLA-side by design);
    ``out_layout="HWC"`` reorders the *output transform's* einsum so the
    inverse transform itself emits (OH, OW, M) — the epilogue produces
    the consumer's layout with no extra pass over the output.
    """
    assert stride == 1
    assert in_layout in ("CHW", "HWC") and out_layout in ("CHW", "HWC")
    if in_layout == "HWC":
        x = jnp.transpose(x, (2, 0, 1))
    c, h, wd = x.shape
    _, m, _ = u.shape
    a = m_ + k - 1
    A, G, Bt = (jnp.asarray(t, jnp.float32) for t in winograd_matrices(m_, k))
    oh, ow = h + 2 * pad - k + 1, wd + 2 * pad - k + 1
    nth, ntw = -(-oh // m_), -(-ow // m_)
    ph = (nth - 1) * m_ + a - (h + 2 * pad)
    pw = (ntw - 1) * m_ + a - (wd + 2 * pad)
    xp = jnp.pad(x, ((0, 0), (pad, pad + max(ph, 0)),
                     (pad, pad + max(pw, 0))))
    pt = lax.conv_general_dilated_patches(
        xp[None], (a, a), (m_, m_), [(0, 0), (0, 0)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"))[0]
    d = pt.reshape(c, a, a, nth * ntw)
    V = jnp.einsum("ai,ciju,bj->abcu", Bt, d, Bt).reshape(a * a, c, -1)

    n = nth * ntw
    bc_ = min(bc, max(8, c))
    bn_ = min(bn, max(8, n))
    Vp, _ = pad_to(V, 1, bc_)
    Vp, _ = pad_to(Vp, 2, bn_)
    Up, _ = pad_to(u, 2, bc_)
    Q = winograd_bgemm_pallas(Up, Vp, bn=bn_, bc=bc_)[:, :, :n]

    Q = Q.reshape(a, a, m, nth, ntw)
    if out_layout == "HWC":
        Y = jnp.einsum("ap,abmtu,bq->tpuqm", A, Q, A)
        y = Y.reshape(nth * m_, ntw * m_, m)[:oh, :ow, :]
        return y + b
    Y = jnp.einsum("ap,abmtu,bq->mtpuq", A, Q, A)
    y = Y.reshape(m, nth * m_, ntw * m_)[:, :oh, :ow]
    return y + b[:, None, None]
