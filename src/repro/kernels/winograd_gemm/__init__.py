from .bench import benchmark_entry
from .kernel import winograd_bgemm_pallas
from .ops import conv_winograd, prepare_kernel
from .ref import bgemm_ref, conv_ref

__all__ = ["benchmark_entry", "winograd_bgemm_pallas", "conv_winograd", "prepare_kernel",
           "bgemm_ref", "conv_ref"]
