"""Pure-jnp oracles for the Winograd batched GEMM and full conv."""
import jax.numpy as jnp
from jax import lax


def bgemm_ref(u, v):
    """u: (P, M, C), v: (P, C, N) -> (P, M, N)."""
    return jnp.einsum("pmc,pcn->pmn", u.astype(jnp.float32),
                      v.astype(jnp.float32)).astype(u.dtype)


def conv_ref(x, w, b, *, pad: int = 1):
    """Direct conv oracle for the full Winograd path.  x: (C, H, W),
    w: (M, C, K, K)."""
    out = lax.conv_general_dilated(
        x[None], w, (1, 1), [(pad, pad)] * 2,
        dimension_numbers=("NCHW", "OIHW", "NCHW"))[0]
    return out + b[:, None, None]
