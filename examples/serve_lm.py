"""Serve a small model with batched requests (continuous batching),
with half the requests carrying images that flow through the plan-cache
serving subsystem (PlanServer: bucketed scenarios -> cached PBQP plan ->
cached compiled executable -> vision tokens).

  PYTHONPATH=src python examples/serve_lm.py
"""
import sys
import time

import numpy as np

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.costs import AnalyticCostModel
from repro.models import init_params
from repro.runtime import Request, ServeLoop
from repro.serving import BucketPolicy, PlanServer, conv_tower


def main():
    cfg = get_config("tinyllama-1.1b").scaled_down(
        n_layers=4, d_model=256, d_ff=512, vocab=2048)
    params = init_params(cfg, jax.random.key(0), jnp.float32)

    # One PlanServer amortizes PBQP solves + XLA compiles across all
    # image-carrying requests: arbitrary image sizes collapse into
    # power-of-two buckets, each solved and compiled at most once.
    plan_server = PlanServer(
        lambda s: conv_tower(s, depth=2, width=8),
        AnalyticCostModel(),
        policy=BucketPolicy(min_hw=8, max_hw=128), lru_capacity=4)
    loop = ServeLoop(cfg, params, max_batch=4, max_seq=96,
                     plan_server=plan_server, image_tokens=4)

    rng = np.random.default_rng(0)
    reqs = []
    for i in range(10):
        pixels = None
        if i % 2 == 0:  # every other request is multimodal
            hw = int(rng.integers(12, 48))
            pixels = rng.normal(size=(3, hw, hw)).astype(np.float32)
        reqs.append(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab,
                                size=int(rng.integers(4, 32)))
            .astype(np.int32),
            max_new_tokens=16, pixels=pixels))
    t0 = time.perf_counter()
    loop.run(reqs)
    dt = time.perf_counter() - t0
    tok = sum(len(r.tokens) for r in reqs)
    print(f"served {len(reqs)} requests ({tok} tokens) in {dt:.2f}s "
          f"with 4-slot continuous batching")
    for r in reqs[:4]:
        print(f"  req {r.rid}: {len(r.prompt)}-token prompt -> "
              f"{len(r.tokens)} new tokens, {r.latency_s*1e3:.0f} ms")
    s = plan_server.stats()
    print(f"plan cache: {s['requests']} images -> {s['buckets']} buckets, "
          f"{s['solves']} PBQP solves ({s['warm_solves']} warm-started), "
          f"{s['compiles']} compiles, exec hit rate "
          f"{s['exec_hit_rate']:.0%}")
    plan_server.close()


if __name__ == "__main__":
    main()
