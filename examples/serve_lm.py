"""Serve a small model with batched requests (continuous batching).

  PYTHONPATH=src python examples/serve_lm.py
"""
import sys
import time

import numpy as np

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import init_params
from repro.runtime import Request, ServeLoop


def main():
    cfg = get_config("tinyllama-1.1b").scaled_down(
        n_layers=4, d_model=256, d_ff=512, vocab=2048)
    params = init_params(cfg, jax.random.key(0), jnp.float32)
    loop = ServeLoop(cfg, params, max_batch=4, max_seq=96)

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab,
                                        size=int(rng.integers(4, 32)))
                    .astype(np.int32),
                    max_new_tokens=16)
            for i in range(10)]
    t0 = time.perf_counter()
    loop.run(reqs)
    dt = time.perf_counter() - t0
    tok = sum(len(r.tokens) for r in reqs)
    print(f"served {len(reqs)} requests ({tok} tokens) in {dt:.2f}s "
          f"with 4-slot continuous batching")
    for r in reqs[:4]:
        print(f"  req {r.rid}: {len(r.prompt)}-token prompt -> "
              f"{len(r.tokens)} new tokens, {r.latency_s*1e3:.0f} ms")


if __name__ == "__main__":
    main()
