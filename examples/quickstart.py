"""Quickstart: the paper's pipeline end-to-end on AlexNet.

  PYTHONPATH=src python examples/quickstart.py [--profile]

1. build the AlexNet layer graph,
2. cost every applicable primitive per conv scenario (profiled or
   analytic),
3. solve the PBQP for the globally-optimal primitive+layout assignment,
4. legalize (insert layout-conversion chains on illegal edges),
5. compile + execute both the SUM2D baseline and the PBQP plan, verify
   they agree numerically, and report the speedup.
"""
import argparse
import sys

import numpy as np

sys.path.insert(0, "src")

from repro.convnets import alexnet
from repro.core.costs import AnalyticCostModel, ProfiledCostModel
from repro.core.plan import compile_plan, measure
from repro.core.selection import select_pbqp, select_sum2d


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--profile", action="store_true",
                    help="profile real execution times (slower, faithful)")
    ap.add_argument("--scale", type=float, default=0.5)
    args = ap.parse_args()

    net = alexnet(scale=args.scale)
    cost = ProfiledCostModel() if args.profile else AnalyticCostModel()
    print(f"== {net.name}: {len(net.conv_nodes())} conv layers ==")

    sel = select_pbqp(net, cost)
    print(f"PBQP optimum found (optimal={sel.optimal}), predicted "
          f"{sel.predicted_cost*1e3:.2f} ms; "
          f"{len(sel.conversions)} layout conversions inserted")
    for node in net.conv_nodes():
        ch = sel.choices[node.id]
        print(f"  {node.id:8s} {node.scn.key():30s} -> "
              f"{ch.primitive.name} [{ch.l_in}->{ch.l_out}]")

    params = net.init_params(seed=0)
    x = np.random.default_rng(0).normal(
        size=net.nodes["data"].out_shape).astype(np.float32)

    base = compile_plan(select_sum2d(net, cost), params)
    opt = compile_plan(sel, params)
    out_b, out_o = base(x), opt(x)
    for k in out_b:
        np.testing.assert_allclose(np.asarray(out_b[k]),
                                   np.asarray(out_o[k]), rtol=2e-3,
                                   atol=2e-3)
    print("numerics: PBQP plan == SUM2D baseline (allclose)")

    tb = measure(base, x, reps=3)
    to = measure(opt, x, reps=3)
    print(f"SUM2D baseline: {tb['mean_s']*1e3:8.1f} ms")
    print(f"PBQP optimum:   {to['mean_s']*1e3:8.1f} ms "
          f"({tb['mean_s']/to['mean_s']:.2f}x speedup)")


if __name__ == "__main__":
    main()
