"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps
through the fault-tolerant loop (checkpoint/restart, stragglers logged).

  PYTHONPATH=src python examples/train_lm.py [--steps 300]

The config is a family-faithful reduction of TinyLlama (GQA, swiglu,
rope) at ~100M params; data is the deterministic synthetic pipeline, so
the loss curve is reproducible run-to-run and across restarts.
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.optim import adamw, warmup_cosine
from repro.runtime import TrainLoopConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = get_config("tinyllama-1.1b").scaled_down(
        n_layers=8, d_model=768, d_ff=2048, vocab=8192,
        n_heads=12, n_kv_heads=4, head_dim=64)
    from repro.models import param_count
    print(f"model: {cfg.name}, {param_count(cfg)/1e6:.1f}M params")

    shape = ShapeConfig("train", seq_len=args.seq,
                        global_batch=args.batch, kind="train")
    opt = adamw(warmup_cosine(3e-4, 50, args.steps))
    metrics = []
    train(cfg, shape, opt,
          loop=TrainLoopConfig(total_steps=args.steps, ckpt_every=100,
                               ckpt_dir=args.ckpt_dir, log_every=20),
          dtype=jnp.float32, metrics_out=metrics)
    first = sum(m["loss"] for m in metrics[:10]) / 10
    last = sum(m["loss"] for m in metrics[-10:]) / 10
    print(f"loss: {first:.3f} -> {last:.3f} over {args.steps} steps")


if __name__ == "__main__":
    main()
