"""The paper's technique at datacenter scale: PBQP sharding selection.

  PYTHONPATH=src python examples/select_sharding.py [--arch kimi-k2-1t-a32b]

Shows the solver choosing per-tensor-group sharding rules (TP vs EP vs
replication vs sequence-parallel stream) for each architecture x shape
on the production mesh, with the priced collective costs.
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.configs import ARCHS, SHAPES
from repro.core.sharding_select import select_rules

MESH = {"pod": 2, "data": 16, "model": 16}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    args = ap.parse_args()
    archs = [args.arch] if args.arch else list(ARCHS)

    for arch in archs:
        cfg = ARCHS[arch]
        print(f"\n== {arch} on mesh {MESH} ==")
        for sname, shape in SHAPES.items():
            if sname == "long_500k" and not cfg.sub_quadratic:
                print(f"  {sname:12s} skipped (full attention)")
                continue
            rules, rep = select_rules(cfg, shape, MESH)
            asg = " ".join(f"{k}={v.split(':')[1]}"
                           for k, v in rep["assignment"].items())
            print(f"  {sname:12s} comm={rep['predicted_comm_s']*1e3:9.2f}ms"
                  f"  {asg}")


if __name__ == "__main__":
    main()
