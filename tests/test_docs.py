"""The documentation set exists and its internal links resolve.

Runs tools/check_md_links.py exactly as the CI docs job does, so a
broken relative link or anchor fails tier-1 locally too.
"""
import pathlib
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent


def test_docs_exist():
    for name in ("architecture.md", "solver.md", "calibration.md",
                 "observability.md", "autotune.md"):
        assert (REPO / "docs" / name).exists(), f"docs/{name} missing"


def test_markdown_links_resolve():
    proc = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_md_links.py"),
         str(REPO / "docs"), str(REPO / "README.md")],
        capture_output=True, text=True, cwd=REPO)
    assert proc.returncode == 0, \
        f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"


def test_docstrings_cross_link_solver_doc():
    """The satellite requirement: pbqp.py and selection.py point readers
    at docs/solver.md."""
    for mod in ("pbqp", "selection"):
        src = (REPO / "src" / "repro" / "core" / f"{mod}.py").read_text()
        assert "docs/solver.md" in src, f"core/{mod}.py lost its doc link"
