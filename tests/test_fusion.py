"""Transform-fusion subsystem tests.

Covers the tentpole end to end: fused prologue/epilogue entry points
match the materialized reference for every fusable layout pair
(including the in-kernel Pallas variants), fusion-aware PBQP pricing
never worsens the optimum and provably flips assignments when fused
costs are visible, the compile_plan fusion pass elides convert_layout
while staying correct under vmap/batch and composing with
``fuse_across_layers``, the plan payload round-trips fused edges, and a
fused PlanServer serves identical cropped outputs.
"""
import numpy as np
import pytest

from repro.core.costs import (
    AnalyticCostModel, fused_cost_key, prim_cost_key,
)
from repro.core.layouts import transform_feasible
from repro.core.plan import compile_plan
from repro.core.primitives import convert_layout, registry
from repro.core.scenario import Scenario
from repro.core.selection import select_fixed, select_pbqp

COST = AnalyticCostModel()
#: C divisible by 8 so blocked HWC8 legs are feasible; odd spatial
SCN = Scenario(c=16, h=9, w=11, stride=1, k=3, m=16)
SCN_K1 = Scenario(c=16, h=9, w=11, stride=1, k=1, m=16)

BY_NAME = {p.name: p for p in registry()}

#: one representative per jnp family (each has a distinct internal
#: working layout / custom fused builder)
REPRESENTATIVE = [
    "direct_lax_chw_chw_oihw",
    "direct_shiftadd_hwc",
    "im2col_xla_n_chw",
    "im2row_xla_n_hwc",
    "kn2col_unroll_hwc",
    "kn2row_unroll_chw",
    "wino2d_f2x3_chw",
    "fft1d_sum_ex_hwc",
]


def _run_native(prim, scn, x_chw, w, b):
    """Native invocation on a logical-CHW input, output back as CHW."""
    packed = prim.prepare(scn, w, b)
    xin = convert_layout(x_chw, "CHW", prim.l_in)
    y = prim.make(scn)(xin, packed)
    return np.asarray(convert_layout(y, prim.l_out, "CHW"))


class TestFusedMatchesMaterialized:
    """Every fused prologue/epilogue equals convert_layout + native."""

    @pytest.mark.parametrize("name", REPRESENTATIVE)
    def test_fused_in_all_layouts(self, name):
        prim = BY_NAME[name]
        scn = SCN
        rng = np.random.default_rng(0)
        x = rng.normal(size=scn.in_shape_chw).astype(np.float32)
        w = (rng.normal(size=scn.weight_shape) * 0.1).astype(np.float32)
        b = rng.normal(size=(scn.m,)).astype(np.float32)
        ref = _run_native(prim, scn, x, w, b)
        packed = prim.prepare(scn, w, b)
        for lay in prim.fusable_in:
            if not transform_feasible(lay, prim.l_in, scn.in_shape_chw):
                continue
            xin = convert_layout(x, "CHW", lay)
            y = prim.make_fused(scn, l_in=lay)(xin, packed)
            got = np.asarray(convert_layout(y, prim.l_out, "CHW"))
            np.testing.assert_allclose(
                got, ref, rtol=2e-3, atol=2e-3,
                err_msg=f"{name} fused-in from {lay}")

    @pytest.mark.parametrize("name", REPRESENTATIVE)
    def test_fused_out_all_layouts(self, name):
        prim = BY_NAME[name]
        scn = SCN
        rng = np.random.default_rng(1)
        x = rng.normal(size=scn.in_shape_chw).astype(np.float32)
        w = (rng.normal(size=scn.weight_shape) * 0.1).astype(np.float32)
        b = rng.normal(size=(scn.m,)).astype(np.float32)
        ref = _run_native(prim, scn, x, w, b)
        packed = prim.prepare(scn, w, b)
        xin = convert_layout(x, "CHW", prim.l_in)
        for lay in prim.fusable_out:
            if not transform_feasible(prim.l_out, lay, scn.out_shape_chw):
                continue
            y = prim.make_fused(scn, l_out=lay)(xin, packed)
            got = np.asarray(convert_layout(y, lay, "CHW"))
            np.testing.assert_allclose(
                got, ref, rtol=2e-3, atol=2e-3,
                err_msg=f"{name} fused-out to {lay}")

    def test_fused_both_ends(self):
        """Simultaneous prologue + epilogue fusion (HWC8 included)."""
        prim = BY_NAME["im2col_xla_n_chw"]
        scn = SCN
        rng = np.random.default_rng(2)
        x = rng.normal(size=scn.in_shape_chw).astype(np.float32)
        w = (rng.normal(size=scn.weight_shape) * 0.1).astype(np.float32)
        b = rng.normal(size=(scn.m,)).astype(np.float32)
        ref = _run_native(prim, scn, x, w, b)
        packed = prim.prepare(scn, w, b)
        for li, lo in [("HWC", "HCW"), ("HWC8", "HWC8"), ("WHC", "CWH")]:
            xin = convert_layout(x, "CHW", li)
            y = prim.make_fused(scn, l_in=li, l_out=lo)(xin, packed)
            got = np.asarray(convert_layout(y, lo, "CHW"))
            np.testing.assert_allclose(got, ref, rtol=2e-3, atol=2e-3,
                                       err_msg=f"fused {li}->{lo}")

    def test_unfusable_layout_raises(self):
        prim = BY_NAME["pallas_direct_hwc"]  # fusable_in == ("CHW",)
        with pytest.raises(ValueError, match="cannot fuse input layout"):
            prim.make_fused(SCN, l_in="WHC")

    def test_native_layouts_return_plain_maker(self):
        prim = BY_NAME["im2col_xla_n_chw"]
        assert prim.make_fused(SCN) is not None  # no error, native path


class TestPallasFusedKernels:
    """The in-kernel (BlockSpec index-map) fused entry points."""

    @pytest.mark.parametrize("name,scn", [
        ("pallas_direct_hwc", SCN),
        ("pallas_im2col_chw", SCN),
        ("pallas_wino_f2x3_chw", SCN),
        ("pallas_pw_gemm_chw", SCN_K1),
    ])
    def test_fused_matches_native(self, name, scn):
        prim = BY_NAME[name]
        rng = np.random.default_rng(3)
        x = rng.normal(size=scn.in_shape_chw).astype(np.float32)
        w = (rng.normal(size=scn.weight_shape) * 0.1).astype(np.float32)
        b = rng.normal(size=(scn.m,)).astype(np.float32)
        ref = _run_native(prim, scn, x, w, b)
        packed = prim.prepare(scn, w, b)
        for li in prim.fusable_in:
            xin = convert_layout(x, "CHW", li)
            y = prim.make_fused(scn, l_in=li)(xin, packed)
            got = np.asarray(convert_layout(y, prim.l_out, "CHW"))
            np.testing.assert_allclose(got, ref, rtol=2e-2, atol=2e-2,
                                       err_msg=f"{name} fused-in {li}")
        for lo in prim.fusable_out:
            xin = convert_layout(x, "CHW", prim.l_in)
            y = prim.make_fused(scn, l_out=lo)(xin, packed)
            got = np.asarray(convert_layout(y, lo, "CHW"))
            np.testing.assert_allclose(got, ref, rtol=2e-2, atol=2e-2,
                                       err_msg=f"{name} fused-out {lo}")


def _alt_tower(depth=4, c=8, hw=12, k=1):
    from repro.core.graph import Net
    net = Net(f"alt{depth}")
    x = net.input("data", (c, hw, hw))
    for i in range(depth):
        x = net.conv(f"conv{i}", x, k=k, m=c)
    return net


def _alt_selection(net, fuse):
    """Fixed alternating-layout assignment: every edge mismatches."""
    pick = {}
    for i, node in enumerate(net.conv_nodes()):
        pick[node.id] = BY_NAME["pw_gemm_n_hwc" if i % 2 == 0
                                else "pw_gemm_n_chw"]
    return select_fixed(net, COST, pick, "alt", fuse=fuse)


class TestPlacementFusionInteraction:
    def test_legalize_never_fuses_across_placements(self):
        """_legalize must replay _build's pricing exactly: fused
        realizations are only offered when both endpoints share a
        device placement (regression: it once fused placement-
        mismatched edges the solver had priced materialized +
        collective, desynchronizing predicted_cost from the emitted
        program)."""
        from dataclasses import replace

        from repro.core import selection as sel_mod
        net = _alt_tower()
        s = _alt_selection(net, fuse=True)
        assert s.fusions  # fixture sanity: fused edges exist
        dt = COST.dt_graph()
        (src, dst) = next(iter(s.fusions))
        mixed = dict(s.choices)
        mixed[src] = replace(mixed[src], placement="dp")
        conv, fus = sel_mod._legalize(net, dt, mixed, cost=COST,
                                      fuse=True)
        assert (src, dst) not in fus
        assert (src, dst) in conv
        # with placements agreeing, the same edge still fuses
        _, fus2 = sel_mod._legalize(net, dt, dict(s.choices),
                                    cost=COST, fuse=True)
        assert (src, dst) in fus2


class TestFusionSelection:
    def test_fused_pricing_never_worse(self):
        from repro.serving.towers import conv_tower
        net = conv_tower((3, 24, 24), depth=2, width=8)
        s0 = select_pbqp(net, COST, fuse=False)
        s1 = select_pbqp(net, COST, fuse=True)
        assert s1.predicted_cost <= s0.predicted_cost + 1e-12
        assert s1.optimal

    def test_fixed_alternating_realizes_fusions(self):
        net = _alt_tower()
        s_mat = _alt_selection(net, fuse=False)
        s_fus = _alt_selection(net, fuse=True)
        assert len(s_mat.conversions) == len(net.edges())
        assert not s_mat.fusions
        assert s_fus.fusions, "fused pricing should fuse mismatched edges"
        # an edge is realized exactly once: fused or materialized
        assert not set(s_fus.fusions) & set(s_fus.conversions)
        assert s_fus.predicted_cost < s_mat.predicted_cost

    def test_fused_out_requires_single_consumer(self):
        """Fan-out edges must not fuse on the producer side."""
        from repro.core.graph import Net, concat
        net = Net("fanout")
        x = net.input("data", (8, 12, 12))
        a = net.conv("conva", x, k=1, m=8)
        net.op("join", [a, a], concat())
        s = select_pbqp(net, COST, fuse=True)
        for (src, dst), kind in s.fusions.items():
            assert not (src == "conva" and kind == "out")

    def test_flip_with_calibrated_fused_costs(self):
        """The bench's provable flip, as a regression test: fused edge
        pricing changes the PBQP assignment itself."""
        import importlib
        bench = importlib.import_module("benchmarks.bench_plan_cache")
        net = bench._fusion_tower(4, 16, 16)
        prof, policy = bench._fusion_profile(
            net, fast=10e-6, slow=20e-6, dt_s=10e-6, fuse_extra=0.5e-6)
        from repro.calibrate import CalibratedCostModel
        cm = CalibratedCostModel(prof, policy=policy)
        s_mat = select_pbqp(net, cm, fuse=False)
        s_fus = select_pbqp(net, cm, fuse=True)
        flipped = [n.id for n in net.conv_nodes()
                   if s_mat.choices[n.id].primitive.name
                   != s_fus.choices[n.id].primitive.name]
        assert flipped, "fused edge costs must flip at least one node"
        assert s_fus.predicted_cost < s_mat.predicted_cost


class TestFusionExecution:
    def test_fused_execution_matches_materialized(self):
        net = _alt_tower()
        params = net.init_params(0)
        x = np.random.default_rng(0).normal(
            size=net.nodes["data"].out_shape).astype(np.float32)
        ref = compile_plan(_alt_selection(net, False), params)(x)
        sel = _alt_selection(net, True)
        cn = compile_plan(sel, params)
        assert cn.fused_edges == len(sel.fusions) > 0
        got = cn(x)
        for k in ref:
            np.testing.assert_allclose(np.asarray(got[k]),
                                       np.asarray(ref[k]),
                                       rtol=2e-3, atol=2e-3)

    def test_fusion_pass_composes_with_fuse_across_layers(self):
        """Satellite regression: both flags set still produces a fused
        executable with correct outputs."""
        net = _alt_tower()
        params = net.init_params(1)
        x = np.random.default_rng(1).normal(
            size=net.nodes["data"].out_shape).astype(np.float32)
        sel = _alt_selection(net, True)
        ref = compile_plan(_alt_selection(net, False), params)(x)
        cn = compile_plan(sel, params, fuse_across_layers=True)
        assert cn.fused_edges == len(sel.fusions) > 0
        got = cn(x)
        for k in ref:
            np.testing.assert_allclose(np.asarray(got[k]),
                                       np.asarray(ref[k]),
                                       rtol=2e-3, atol=2e-3)

    def test_fusion_pass_correct_under_vmap(self):
        """Fused executables vmap cleanly (batch > 1)."""
        net = _alt_tower()
        params = net.init_params(2)
        sel = _alt_selection(net, True)
        xs = np.random.default_rng(2).normal(
            size=(4,) + tuple(net.nodes["data"].out_shape)
        ).astype(np.float32)
        single = compile_plan(sel, params)
        batched = compile_plan(sel, params, batch=4)
        assert batched.fused_edges == len(sel.fusions) > 0
        out_b = batched(xs)
        for i in range(4):
            out_1 = single(xs[i])
            for k in out_1:
                np.testing.assert_allclose(np.asarray(out_b[k])[i],
                                           np.asarray(out_1[k]),
                                           rtol=2e-3, atol=2e-3)


class TestPayloadAndServing:
    def test_payload_roundtrips_fusions(self):
        from repro.serving.plan_cache import (
            selection_from_payload, selection_to_payload,
        )
        net = _alt_tower()
        sel = _alt_selection(net, True)
        assert sel.fusions
        back = selection_from_payload(selection_to_payload(sel), net)
        assert back.fusions == sel.fusions
        assert back.conversions == sel.conversions
        assert {k: (c.primitive.name if c.primitive else None)
                for k, c in back.choices.items()} == \
               {k: (c.primitive.name if c.primitive else None)
                for k, c in sel.choices.items()}

    def test_old_schema_payload_rejected(self):
        from repro.serving.plan_cache import (
            selection_from_payload, selection_to_payload,
        )
        net = _alt_tower()
        payload = selection_to_payload(_alt_selection(net, False))
        payload["schema"] = 1
        with pytest.raises(ValueError, match="plan schema"):
            selection_from_payload(payload, net)

    def test_fused_server_serves_identical_cropped_outputs(self):
        from repro.serving import BucketPolicy, PlanServer, conv_stack
        req = np.random.default_rng(5).normal(
            size=(4, 13, 15)).astype(np.float32)
        outs = []
        versions = []
        for fuse in (False, True):
            srv = PlanServer(lambda s: conv_stack(s, depth=2, width=8),
                             AnalyticCostModel(),
                             policy=BucketPolicy(min_hw=8, max_hw=64),
                             fuse=fuse)
            outs.append(srv.infer(req))
            versions.append(srv.cost_version)
            srv.close()
        assert versions[0] != versions[1]  # distinct plan-cache keys
        for k in outs[0]:
            assert outs[0][k].shape == outs[1][k].shape
            np.testing.assert_allclose(outs[0][k], outs[1][k],
                                       rtol=2e-3, atol=2e-3)


class TestCalibratedFusedCosts:
    def test_calibrated_serves_fused_delta_with_fallback(self):
        from repro.calibrate import CalibratedCostModel, HardwareProfile
        prim = BY_NAME["im2col_xla_n_chw"]
        from repro.serving.bucketing import BucketPolicy, bucket_scenario
        policy = BucketPolicy()
        b = bucket_scenario(SCN, policy)
        prof = HardwareProfile.new()
        prof.put(prim_cost_key(prim.name, b), 10e-6)
        prof.put(fused_cost_key("in", prim.name, "HWC", b), 12e-6)
        cm = CalibratedCostModel(prof, policy=policy)
        assert cm.fused_in_cost(prim, SCN, "HWC") == pytest.approx(2e-6)
        assert cm.fused_in_cost(prim, SCN, "CHW") == 0.0
        # uncovered layout falls back to the analytic estimate
        fb = cm.fallback.fused_in_cost(prim, SCN, "HCW")
        assert cm.fused_in_cost(prim, SCN, "HCW") == pytest.approx(fb)
        # a fused measurement faster than native clamps at zero
        prof.put(fused_cost_key("out", prim.name, "HWC", b), 8e-6)
        assert cm.fused_out_cost(prim, SCN, "HWC") == 0.0

    def test_sweep_plans_fused_pairs(self):
        from repro.calibrate import plan_sweep
        items = plan_sweep([SCN], families=["im2"], dt=False)
        kinds = {it.kind for it in items}
        assert "fuse" in kinds
        fuse_items = [it for it in items if it.kind == "fuse"]
        assert all(it.key.startswith(("fusein::", "fuseout::"))
                   for it in fuse_items)
        # batched scenarios plan no fused pairs (deltas are per image)
        items_b = plan_sweep([SCN.with_(n=4)], families=["im2"], dt=False)
        assert not any(it.kind == "fuse" for it in items_b)
        # and the flag can disable them
        items_off = plan_sweep([SCN], families=["im2"], dt=False,
                               fused=False)
        assert not any(it.kind == "fuse" for it in items_off)

    def test_run_sweep_measures_fused_items(self):
        from repro.calibrate import HardwareProfile, plan_sweep, run_sweep
        items = plan_sweep([SCN], families=["kn2"], dt=False)
        prof = HardwareProfile.new()
        report = run_sweep(prof, items, measure=lambda it: 1e-6)
        assert report["measured"] == len(items)
        assert all(it.key in prof for it in items)
