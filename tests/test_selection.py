"""End-to-end selection tests: PBQP build, solve, legalize, execute.

Uses the deterministic AnalyticCostModel so tests don't profile.
Numerical equivalence across strategies is the key system invariant: a
plan is a *performance* choice, never a semantics choice.
"""
import numpy as np
import pytest

from repro.convnets import NETWORKS, alexnet, googlenet, vgg
from repro.core.costs import AnalyticCostModel
from repro.core.plan import compile_plan
from repro.core.selection import (
    select_family_best, select_local_optimal, select_pbqp, select_sum2d,
)

COST = AnalyticCostModel()


@pytest.fixture(scope="module")
def small_alexnet():
    return alexnet(scale=0.3)


@pytest.fixture(scope="module")
def small_googlenet():
    return googlenet(scale=0.2)


class TestSelection:
    def test_pbqp_beats_or_ties_baselines(self, small_alexnet):
        net = small_alexnet
        pb = select_pbqp(net, COST)
        s2 = select_sum2d(net, COST)
        lo = select_local_optimal(net, COST)
        assert pb.optimal
        assert pb.predicted_cost <= lo.predicted_cost + 1e-12
        assert pb.predicted_cost <= s2.predicted_cost + 1e-12
        # SUM2D is the textbook baseline: strictly worse here
        assert pb.predicted_cost < s2.predicted_cost

    def test_family_strategies_between(self, small_alexnet):
        net = small_alexnet
        pb = select_pbqp(net, COST)
        for fam in ["direct", "im2", "kn2", "winograd", "fft"]:
            r = select_family_best(net, COST, fam)
            assert pb.predicted_cost <= r.predicted_cost + 1e-12

    def test_every_conv_assigned_and_legal(self, small_googlenet):
        net = small_googlenet
        r = select_pbqp(net, COST)
        assert r.optimal
        for node in net.conv_nodes():
            ch = r.choices[node.id]
            assert ch.primitive is not None
            assert ch.primitive.supports(node.scn)
        # all conversions reference real DT chains
        for (u, v), chain in r.conversions.items():
            assert chain[0] == r.choices[u].l_out
            assert chain[-1] == r.choices[v].l_in
            assert len(chain) >= 2

    def test_restricting_families_changes_selection(self, small_alexnet):
        r = select_pbqp(small_alexnet, COST, families=["direct"])
        fams = {r.choices[n.id].primitive.family
                for n in small_alexnet.conv_nodes()}
        assert fams == {"direct"}

    def test_local_optimal_reports_uncoverable_scenario(self, small_alexnet):
        """No finite canonical-layout primitive -> a descriptive error,
        not a bare ``min() arg is an empty sequence``."""
        from repro.core.costs import AnalyticCostModel, HardwareSpec
        dead = AnalyticCostModel(HardwareSpec(
            name="dead", peak_flops=1.0, mem_bw=1.0,
            family_eff={f: 0.0 for f in
                        ["direct", "im2", "kn2", "winograd", "fft",
                         "pallas"]}))
        with pytest.raises(ValueError, match="no CHW->CHW primitive"):
            select_local_optimal(small_alexnet, dead)


class TestExecution:
    @pytest.mark.parametrize("strategy", ["pbqp", "sum2d", "local",
                                          "winograd", "im2"])
    def test_strategies_numerically_equivalent(self, small_alexnet,
                                               strategy):
        net = small_alexnet
        params = net.init_params(seed=3)
        rng = np.random.default_rng(0)
        x = rng.normal(size=net.nodes["data"].out_shape).astype(np.float32)

        ref_sel = select_sum2d(net, COST)
        ref = compile_plan(ref_sel, params)(x)

        if strategy == "pbqp":
            sel = select_pbqp(net, COST)
        elif strategy == "sum2d":
            sel = ref_sel
        elif strategy == "local":
            sel = select_local_optimal(net, COST)
        else:
            sel = select_family_best(net, COST, strategy)
        got = compile_plan(sel, params)(x)
        for k in ref:
            np.testing.assert_allclose(
                np.asarray(got[k]), np.asarray(ref[k]), rtol=2e-3,
                atol=2e-3, err_msg=f"{strategy} diverges at {k}")

    def test_googlenet_executes(self, small_googlenet):
        net = small_googlenet
        params = net.init_params(seed=1)
        sel = select_pbqp(net, COST)
        cn = compile_plan(sel, params)
        rng = np.random.default_rng(0)
        x = rng.normal(size=net.nodes["data"].out_shape).astype(np.float32)
        out = cn(x)
        (prob,) = out.values()
        p = np.asarray(prob).reshape(-1)
        assert p.shape == (1000,)
        assert np.isfinite(p).all()
        assert abs(p.sum() - 1.0) < 1e-3

    def test_vgg_topologies(self):
        for cfg in ["A", "B", "C", "D", "E"]:
            net = vgg(cfg, scale=0.15)
            convs = net.conv_nodes()
            n = {"A": 8, "B": 10, "C": 13, "D": 13, "E": 16}[cfg]
            assert len(convs) == n, cfg
            if cfg == "C":
                assert sum(1 for c in convs if c.scn.k == 1) == 3

    def test_alexnet_conv_scenarios_match_paper(self):
        net = alexnet(1.0)
        scns = {n.id: n.scn for n in net.conv_nodes()}
        assert scns["conv1"].k == 11 and scns["conv1"].stride == 4
        assert scns["conv1"].out_h == 55
        assert scns["conv2"].k == 5 and scns["conv2"].c == 96
        assert scns["conv5"].m == 256
        assert net.nodes["pool5"].out_shape == (256, 6, 6)

    def test_googlenet_concat_channels(self):
        net = googlenet(1.0)
        assert net.nodes["i3a_concat"].out_shape[0] == 256
        assert net.nodes["i4e_concat"].out_shape[0] == 832
        assert net.nodes["i5b_concat"].out_shape[0] == 1024
        assert len(net.conv_nodes()) == 57
