"""Graceful degradation when `hypothesis` is not installed.

Importing this module's ``given``/``settings``/``st`` instead of
hard-importing hypothesis keeps the suite *collectable* on minimal
installs (the seed repo died at collection): property-based tests are
individually skipped with a clear reason, while plain unit tests in the
same files keep running.  With hypothesis available, callers never reach
this module.

Usage in a test file::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_fallback import given, settings, st
"""
import pytest

_REASON = "hypothesis not installed (property test skipped)"


class _Strategies:
    """Stand-in for ``hypothesis.strategies``: every strategy factory
    returns an inert placeholder; ``composite`` mirrors the decorator
    protocol so ``@st.composite``-built strategies stay callable."""

    @staticmethod
    def composite(fn):
        return lambda *a, **k: None

    def __getattr__(self, name):
        return lambda *a, **k: None


st = _Strategies()


def given(*args, **kwargs):
    def deco(fn):
        return pytest.mark.skip(reason=_REASON)(fn)
    return deco


def settings(*args, **kwargs):
    def deco(fn):
        return fn
    return deco
