"""Batch-aware selection and batched serving (PR acceptance criteria).

Covers the batch axis end to end: Scenario.n and key back-compat,
batched analytic pricing (weight/setup amortization), the N=1 -> N=8
selection flip, batch bucketing, batched executables, the
PlanServer.infer_batch path with its one-solve-one-compile-per-
(bucket, batch) property (the CI smoke job runs this file), output
cropping back to request extent, the micro-batching admission queue,
and the serve loop coalescing a tick's images into one invocation.
"""
import numpy as np
import pytest

from repro.core.costs import AnalyticCostModel
from repro.core.plan import compile_plan
from repro.core.scenario import Scenario
from repro.core.selection import select_pbqp
from repro.serving import (
    BucketPolicy, PlanServer, bucket_key, bucket_scenario, conv_stack,
    conv_tower,
)

CM = AnalyticCostModel()
POLICY = BucketPolicy(min_hw=8, max_hw=64)
SCN = Scenario(c=8, h=16, w=16, stride=1, k=3, m=16)


class TestScenarioBatch:
    def test_default_batch_is_paper_setting(self):
        assert SCN.n == 1

    def test_key_backward_compatible(self):
        """n=1 keys must not change: calibration profiles and persisted
        plans from before the batch axis stay valid."""
        assert SCN.key() == "c8h16w16s1k3m16p1float32"
        assert SCN.with_(n=1).key() == SCN.key()
        assert SCN.with_(n=8).key() == SCN.key() + "n8"

    def test_macs_scale_with_batch(self):
        assert SCN.with_(n=4).macs == 4 * SCN.macs
        assert SCN.with_(n=4).in_shape_nchw == (4, 8, 16, 16)

    def test_bad_batch_rejected(self):
        with pytest.raises(ValueError):
            SCN.with_(n=0)


class TestBatchedCosts:
    def test_batched_cost_amortizes_per_invocation_work(self):
        """Per-image cost strictly falls with N for every family: the
        per-invocation setup (and weight traffic) amortizes."""
        from repro.core.primitives import primitives_for
        for prim in primitives_for(SCN):
            c1 = CM.primitive_cost(prim, SCN)
            c8 = CM.primitive_cost(prim, SCN.with_(n=8))
            if not np.isfinite(c1):
                continue
            assert c8 > c1          # a batch costs more in total...
            assert c8 / 8 < c1      # ...but less per image

    def test_with_batch_is_copy_on_write(self):
        # a memoizing net_builder may hand the server one shared Net
        # per shape: with_batch must never mutate it (cached plans
        # reference it), and with_batch(n) at the current n is free
        net = conv_tower((4, 32, 32), depth=2, width=8)
        fp1 = net.fingerprint()
        assert net.with_batch(1) is net
        net8 = net.with_batch(8)
        assert net8 is not net
        assert all(nd.scn.n == 8 for nd in net8.conv_nodes())
        assert all(nd.scn.n == 1 for nd in net.conv_nodes())
        assert net.fingerprint() == fp1 != net8.fingerprint()
        assert net8.order == net.order  # ids line up for warm starts
        assert net8.with_batch(1).fingerprint() == fp1

    def test_selection_flips_with_batch(self):
        """ACCEPTANCE: select_pbqp picks a different primitive for at
        least one tower node when N goes 1 -> 8 (analytic model)."""
        picks = {}
        for n in (1, 8):
            net = conv_tower((4, 32, 32), depth=2, width=8).with_batch(n)
            sel = select_pbqp(net, CM)
            assert sel.optimal
            picks[n] = {nd.id: sel.choices[nd.id].primitive.name
                        for nd in net.conv_nodes()}
        assert picks[1] != picks[8], picks

    def test_version_tracks_setup_constants(self):
        from repro.core.costs import CPU_SPEC, HardwareSpec
        spec = HardwareSpec(
            name=CPU_SPEC.name, peak_flops=CPU_SPEC.peak_flops,
            mem_bw=CPU_SPEC.mem_bw, family_eff=dict(CPU_SPEC.family_eff),
            family_setup={**CPU_SPEC.family_setup, "im2": 1.0})
        assert AnalyticCostModel(spec).version() != CM.version()


class TestBatchBucketing:
    def test_bucket_n_pow2(self):
        assert POLICY.bucket_n(1) == 1
        assert POLICY.bucket_n(3) == 4
        assert POLICY.bucket_n(8) == 8
        assert POLICY.bucket_n(9) == 16

    def test_bucket_n_never_rounds_down(self):
        # like the spatial axes: above the ceiling the request wins —
        # clamping down would price/compile a smaller batch than runs
        p = BucketPolicy(max_n=8)
        assert p.bucket_n(6) == 8
        assert p.bucket_n(100) == 100
        with pytest.raises(ValueError):
            p.bucket_n(0)

    def test_bucket_key_batch_suffix(self):
        assert bucket_key((4, 32, 32)) == "c4h32w32"
        assert bucket_key((4, 32, 32), 1) == "c4h32w32"
        assert bucket_key((4, 32, 32), 8) == "c4h32w32n8"

    def test_bucket_scenario_buckets_batch(self):
        b = bucket_scenario(SCN.with_(n=3), POLICY)
        assert b.n == 4
        assert bucket_scenario(b, POLICY) == b


class TestBatchedCompile:
    def test_batched_executable_matches_per_image_runs(self):
        net = conv_stack((4, 16, 16), depth=2, width=8)
        sel = select_pbqp(net, CM)
        params = net.init_params(0)
        single = compile_plan(sel, params)
        batched = compile_plan(sel, params, batch=4)
        assert batched.batch == 4
        rng = np.random.default_rng(0)
        xs = rng.normal(size=(4, 4, 16, 16)).astype(np.float32)
        outb = batched(xs)
        for i in range(4):
            o1 = single(xs[i])
            for k in o1:
                np.testing.assert_allclose(
                    np.asarray(outb[k][i]), np.asarray(o1[k]),
                    rtol=2e-3, atol=2e-3)

    def test_bad_batch_rejected(self):
        net = conv_stack((4, 16, 16), depth=1, width=8)
        sel = select_pbqp(net, CM)
        with pytest.raises(ValueError):
            compile_plan(sel, net.init_params(0), batch=0)


def _server(builder=None, **kw):
    kw.setdefault("policy", POLICY)
    kw.setdefault("lru_capacity", 8)
    builder = builder or (lambda s: conv_tower(s, depth=2, width=8))
    return PlanServer(builder, CM, **kw)


class TestInferBatch:
    def test_one_solve_one_compile_per_bucket_and_batch(self):
        """CI smoke property: N in {1, 4} over one spatial bucket costs
        exactly one solve + one compile per (net, bucket, batch-bucket),
        asserted via ServingCounters."""
        srv = _server()
        rng = np.random.default_rng(0)
        xs = [rng.normal(size=(3, 20 + i, 20)).astype(np.float32)
              for i in range(4)]                  # one bucket, nb=4
        outs = srv.infer_batch(xs)
        assert len(outs) == 4
        srv.infer(xs[0])                          # same bucket, nb=1
        s = srv.stats()
        assert s["requests"] == 5
        assert s["solves"] == 2                   # (bucket, 4), (bucket, 1)
        assert s["compiles"] == 2
        assert s["batch_calls"] == 1
        assert s["coalesced"] == 3
        # a second batched wave is pure execution
        srv.infer_batch(xs)
        s = srv.stats()
        assert s["solves"] == 2 and s["compiles"] == 2
        assert s["exec_hits"] >= 1
        srv.close()

    def test_batched_outputs_match_sequential(self):
        srv = _server(lambda s: conv_stack(s, depth=2, width=8))
        rng = np.random.default_rng(1)
        xs = [rng.normal(size=(4, int(rng.integers(10, 30)),
                               int(rng.integers(10, 30))))
              .astype(np.float32) for _ in range(6)]  # mixed buckets
        seq = [srv.infer(x) for x in xs]
        bat = srv.infer_batch(xs)
        for i in range(len(xs)):
            assert set(seq[i]) == set(bat[i])
            for k in seq[i]:
                assert seq[i][k].shape == bat[i][k].shape
                np.testing.assert_allclose(bat[i][k], seq[i][k],
                                           rtol=2e-3, atol=2e-3)
        srv.close()

    def test_groups_larger_than_max_n_are_chunked(self):
        srv = _server(policy=BucketPolicy(min_hw=8, max_hw=64, max_n=4))
        xs = [np.zeros((3, 20, 20), np.float32)] * 6
        outs = srv.infer_batch(xs)
        assert len(outs) == 6
        s = srv.stats()
        assert s["batch_calls"] == 2              # nb=4 chunk + nb=2 chunk
        assert s["coalesced"] == 4                # 3 in chunk 1, 1 in chunk 2
        assert s["solves"] == 2 and s["compiles"] == 2
        srv.close()

    def test_infer_works_when_batch_bucket_of_one_exceeds_one(self):
        """Regression: a policy whose batch bucket for n=1 is > 1
        (linear batch mode) hands infer a batched executable; the image
        must ride row 0, not crash the vmapped program."""
        srv = _server(lambda s: conv_stack(s, depth=1, width=8),
                      policy=BucketPolicy(min_hw=8, max_hw=64,
                                          batch="linear", batch_step=4))
        rng = np.random.default_rng(4)
        x = rng.normal(size=(4, 16, 16)).astype(np.float32)
        out = srv.infer(x)
        (v,) = out.values()
        assert v.shape == (8, 16, 16)
        # row-0 embedding matches the batched path's answer
        ref = srv.infer_batch([x])[0]
        for k in ref:
            np.testing.assert_allclose(out[k], ref[k], rtol=2e-3,
                                       atol=2e-3)
        srv.close()

    def test_empty_batch(self):
        srv = _server()
        assert srv.infer_batch([]) == []
        srv.close()


class TestOutputCropping:
    def test_infer_crops_to_request_extent(self):
        """Bucketed output slices match an exact run on the unpadded
        shape (satellite fix: infer used to return bucket-shaped
        outputs, leaking padding)."""
        builder = lambda s: conv_stack(s, depth=1, width=8)
        srv = _server(builder)
        rng = np.random.default_rng(2)
        x = rng.normal(size=(4, 20, 20)).astype(np.float32)  # bucket 32x32
        out = srv.infer(x)
        # reference: the same net compiled at the request's own shape
        # (identical weights: conv params depend only on C, K, M)
        net = builder((4, 20, 20))
        ref = compile_plan(select_pbqp(net, CM),
                           net.init_params(srv.params_seed))(x)
        for nid, v in ref.items():
            assert out[nid].shape == np.asarray(v).shape == (8, 20, 20)
            np.testing.assert_allclose(out[nid], np.asarray(v),
                                       rtol=2e-3, atol=2e-3)
        srv.close()

    def test_deep_stack_crops_shape_and_interior(self):
        """Depth >= 2: the crop restores the request's shape, and the
        interior matches the exact run.  Border columns of deep layers
        legitimately see bucket padding (conv bias makes the padded
        region nonzero after layer 1) — pad-and-crop bucketing trades
        exact borders for executable reuse, like any padded batching."""
        builder = lambda s: conv_stack(s, depth=2, width=8)
        srv = _server(builder)
        rng = np.random.default_rng(2)
        x = rng.normal(size=(4, 20, 20)).astype(np.float32)
        out = srv.infer(x)
        net = builder((4, 20, 20))
        ref = compile_plan(select_pbqp(net, CM),
                           net.init_params(srv.params_seed))(x)
        for nid, v in ref.items():
            v = np.asarray(v)
            assert out[nid].shape == v.shape == (16, 20, 20)
            np.testing.assert_allclose(out[nid][:, 1:-1, 1:-1],
                                       v[:, 1:-1, 1:-1],
                                       rtol=2e-3, atol=2e-3)
        srv.close()

    def test_exact_bucket_request_is_untouched(self):
        srv = _server(lambda s: conv_stack(s, depth=1, width=8))
        x = np.zeros((4, 32, 32), np.float32)     # already a bucket shape
        out = srv.infer(x)
        (v,) = out.values()
        assert v.shape == (8, 32, 32)
        srv.close()

    def test_global_outputs_pass_through(self):
        # conv_tower ends in GAP+FC: output shape is request-independent
        srv = _server()
        o1 = srv.infer(np.zeros((3, 20, 20), np.float32))
        o2 = srv.infer(np.zeros((3, 27, 31), np.float32))
        assert {k: v.shape for k, v in o1.items()} == \
            {k: v.shape for k, v in o2.items()}
        srv.close()


class TestMicroBatchQueue:
    def test_flush_coalesces_same_bucket(self):
        srv = _server(lambda s: conv_stack(s, depth=1, width=8))
        rng = np.random.default_rng(3)
        xs = [rng.normal(size=(4, 18, 18)).astype(np.float32)
              for _ in range(3)]
        futs = [srv.enqueue(x) for x in xs]
        assert srv.flush() == 3
        s = srv.stats()
        assert s["batch_calls"] == 1 and s["requests"] == 3
        for x, fut in zip(xs, futs):
            out = fut.result(timeout=60)
            ref = srv.infer(x)
            for k in ref:
                np.testing.assert_allclose(out[k], ref[k],
                                           rtol=2e-3, atol=2e-3)
        srv.close()

    def test_flush_empty_queue(self):
        srv = _server()
        assert srv.flush() == 0
        srv.close()

    def test_close_cancels_unflushed_futures(self):
        # a waiter on an enqueued-but-never-flushed future must not
        # hang when the server shuts down
        from concurrent.futures import CancelledError
        srv = _server()
        fut = srv.enqueue(np.zeros((3, 16, 16), np.float32))
        srv.close()
        assert fut.cancelled()
        with pytest.raises(CancelledError):
            fut.result(timeout=1)
        # and late producers fail loudly instead of queueing forever
        with pytest.raises(RuntimeError, match="closed"):
            srv.enqueue(np.zeros((3, 16, 16), np.float32))


class TestServeLoopCoalescing:
    def test_tick_images_share_one_invocation(self):
        import jax
        import jax.numpy as jnp
        from repro.configs import get_config
        from repro.models import init_params
        from repro.runtime import Request, ServeLoop

        cfg = get_config("tinyllama-1.1b").scaled_down(
            n_layers=2, d_model=64, d_ff=128, vocab=256)
        params = init_params(cfg, jax.random.key(0), jnp.float32)
        srv = _server()
        loop = ServeLoop(cfg, params, max_batch=2, max_seq=64,
                         plan_server=srv, image_tokens=3)
        rng = np.random.default_rng(0)
        reqs = [Request(rid=i, prompt=np.arange(4, dtype=np.int32),
                        max_new_tokens=2,
                        pixels=rng.normal(size=(3, 18, 18))
                        .astype(np.float32))
                for i in range(2)]
        loop.run(reqs)
        for r in reqs:
            assert r.done and r.pixels is None
            assert len(r.prompt) == 4 + 3
        s = srv.stats()
        # both images admitted in tick 1: ONE batched tower invocation
        assert s["batch_calls"] == 1
        assert s["requests"] == 2
        assert s["coalesced"] == 1
        assert s["solves"] == 1 and s["compiles"] == 1
        loop.close()
        srv.close()
