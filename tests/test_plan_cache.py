"""Plan-cache tests: hit/miss accounting, disk round-trip, invalidation."""
import json

import numpy as np
import pytest

from repro.core.costs import (
    CPU_SPEC, AnalyticCostModel, HardwareSpec, ProfiledCostModel,
)
from repro.core.selection import select_pbqp
from repro.serving import (
    LRU, PlanDiskCache, conv_tower, plan_key, selection_from_payload,
    selection_to_payload,
)

CM = AnalyticCostModel()


def _small_selection():
    net = conv_tower((4, 16, 16), depth=2, width=8)
    return net, select_pbqp(net, CM, exact=True)


class TestSerialization:
    def test_disk_round_trip(self, tmp_path):
        net, sel = _small_selection()
        cache = PlanDiskCache(tmp_path)
        key = plan_key(net.fingerprint(), "c4h16w16", CM.version())
        cache.put(key, selection_to_payload(sel))
        back = selection_from_payload(cache.get(key), net)
        assert back.predicted_cost == pytest.approx(sel.predicted_cost)
        assert back.optimal == sel.optimal
        assert back.strategy == sel.strategy
        assert set(back.choices) == set(sel.choices)
        for nid, ch in sel.choices.items():
            b = back.choices[nid]
            assert (ch.primitive.name if ch.primitive else None) == \
                (b.primitive.name if b.primitive else None)
            assert (ch.l_in, ch.l_out) == (b.l_in, b.l_out)
        assert back.conversions == sel.conversions

    def test_payload_is_json(self):
        _, sel = _small_selection()
        payload = selection_to_payload(sel)
        json.dumps(payload)  # must be pure-JSON serializable

    @pytest.mark.parametrize("mesh_axes,want_kinds", [
        ({"data": 2, "model": 4}, {"dp", "tp"}),
        ({"stage": 4}, {"pp"}),
    ])
    def test_structured_placements_round_trip(self, tmp_path, mesh_axes,
                                              want_kinds):
        """tp and pp<stage> placements survive the JSON disk tier as
        their canonical strings and come back as structured Placement
        instances (the PR's headline cache-round-trip criterion)."""
        from repro.core.selection import Placement
        from repro.serving.towers import bottleneck_tower, uniform_stack

        if "stage" in mesh_axes:
            net = uniform_stack((8, 8, 8), depth=6).with_batch(8)
        else:
            net = bottleneck_tower((4, 16, 16)).with_batch(8)
        sel = select_pbqp(net, CM, mesh_axes=mesh_axes)
        kinds = {Placement.parse(c.placement).kind
                 for c in sel.choices.values()}
        assert want_kinds <= kinds, kinds
        cache = PlanDiskCache(tmp_path)
        key = plan_key(net.fingerprint(), "b8", CM.version())
        cache.put(key, selection_to_payload(sel))
        # the disk tier is real JSON: force a serialize/parse cycle
        back = selection_from_payload(
            json.loads(json.dumps(cache.get(key))), net)
        assert back.predicted_cost == pytest.approx(sel.predicted_cost)
        for nid, ch in sel.choices.items():
            b = back.choices[nid]
            assert b.placement == ch.placement
            assert isinstance(b.placement, Placement)
            assert Placement.parse(b.placement).stage == \
                Placement.parse(ch.placement).stage

    def test_unknown_primitive_rejected(self):
        net, sel = _small_selection()
        payload = selection_to_payload(sel)
        nid = next(n for n, v in payload["choices"].items()
                   if v[0] is not None)
        payload["choices"][nid][0] = "no_such_primitive"
        with pytest.raises(KeyError):
            selection_from_payload(payload, net)

    def test_schema_mismatch_rejected(self):
        net, sel = _small_selection()
        payload = selection_to_payload(sel)
        payload["schema"] = -1
        with pytest.raises(ValueError):
            selection_from_payload(payload, net)


def _payload(**kw):
    """A schema-valid cache payload (get() treats others as corrupt)."""
    from repro.serving.plan_cache import PLAN_SCHEMA
    return {"schema": PLAN_SCHEMA, **kw}


class TestDiskCacheAccounting:
    def test_hit_miss_counters(self, tmp_path):
        cache = PlanDiskCache(tmp_path)
        assert cache.get("abc") is None
        assert (cache.hits, cache.misses) == (0, 1)
        cache.put("abc", _payload(x=1))
        assert cache.get("abc") == _payload(x=1)
        assert (cache.hits, cache.misses) == (1, 1)
        assert len(cache) == 1

    def test_corrupt_file_is_a_miss(self, tmp_path):
        cache = PlanDiskCache(tmp_path)
        cache.put("abc", _payload(x=1))
        (tmp_path / "plan_abc.json").write_text("{not json")
        assert cache.get("abc") is None
        assert cache.misses == 1
        assert cache.corrupt == 1
        assert not (tmp_path / "plan_abc.json").exists()  # deleted
        # and a subsequent put repairs the entry
        cache.put("abc", _payload(x=2))
        assert cache.get("abc") == _payload(x=2)

    def test_stale_schema_is_a_miss(self, tmp_path):
        cache = PlanDiskCache(tmp_path)
        cache.put("abc", {"schema": 1, "x": 1})   # ancient format
        assert cache.get("abc") is None
        assert cache.corrupt == 1

    def test_concurrent_puts_same_key(self, tmp_path):
        """Satellite fix: writers used to share one plan_<key>.tmp name,
        so concurrent puts of the same key could race a partial file
        into place or crash on each other's renamed tmp.  With
        per-writer tmp names every interleaving leaves a valid JSON
        payload from one of the writers and no tmp litter."""
        import threading

        cache = PlanDiskCache(tmp_path)
        errors = []

        def writer(i):
            try:
                for _ in range(50):
                    cache.put("shared",
                              _payload(writer=i, x=list(range(64))))
            except BaseException as e:  # noqa: BLE001 - record any crash
                errors.append(e)

        threads = [threading.Thread(target=writer, args=(i,))
                   for i in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors
        payload = cache.get("shared")
        assert payload is not None and payload["x"] == list(range(64))
        assert not list(tmp_path.glob("*.tmp"))  # no leftover tmp files


class TestKeyInvalidation:
    def test_cost_model_version_changes_key(self):
        """Bumping the cost model must invalidate persisted plans."""
        net, _ = _small_selection()
        fp, bk = net.fingerprint(), "c4h16w16"
        base = plan_key(fp, bk, AnalyticCostModel().version())
        other_spec = HardwareSpec(
            name=CPU_SPEC.name, peak_flops=CPU_SPEC.peak_flops * 2,
            mem_bw=CPU_SPEC.mem_bw, family_eff=dict(CPU_SPEC.family_eff))
        assert plan_key(fp, bk, AnalyticCostModel(other_spec).version()) \
            != base
        assert plan_key(fp, bk, ProfiledCostModel(
            cache_path="/tmp/x.json").version()) != base

    def test_version_is_stable(self):
        assert AnalyticCostModel().version() == \
            AnalyticCostModel().version()

    def test_net_fingerprint_tracks_shape_and_topology(self):
        a = conv_tower((4, 16, 16), depth=2, width=8)
        b = conv_tower((4, 16, 16), depth=2, width=8)
        assert a.fingerprint() == b.fingerprint()
        assert conv_tower((4, 32, 32), depth=2, width=8).fingerprint() \
            != a.fingerprint()
        assert conv_tower((4, 16, 16), depth=3, width=8).fingerprint() \
            != a.fingerprint()

    def test_bucket_changes_key(self):
        net, _ = _small_selection()
        v = CM.version()
        assert plan_key(net.fingerprint(), "c4h16w16", v) != \
            plan_key(net.fingerprint(), "c4h32w32", v)


class TestLRU:
    def test_hit_miss_eviction(self):
        lru = LRU(2)
        assert lru.get("a") is None
        lru.put("a", 1)
        lru.put("b", 2)
        assert lru.get("a") == 1      # refreshes "a"
        lru.put("c", 3)               # evicts "b" (least recent)
        assert lru.get("b") is None
        assert lru.get("a") == 1 and lru.get("c") == 3
        assert lru.evictions == 1
        assert (lru.hits, lru.misses) == (3, 2)
        assert len(lru) == 2

    def test_capacity_validation(self):
        with pytest.raises(ValueError):
            LRU(0)
