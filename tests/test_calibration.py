"""Calibration subsystem tests (acceptance criteria of the subsystem):

* HardwareProfile round-trips to disk byte-stably;
* CalibratedCostModel serves measured buckets and falls back to the
  analytic model for uncovered ones;
* a recalibrated profile changes ``version()`` and therefore invalidates
  previously persisted serving plans;
* sweeps are resumable (covered keys are never re-measured).
"""
import numpy as np
import pytest

from repro.calibrate import (
    CalibratedCostModel, HardwareProfile, device_fingerprint, plan_sweep,
    registry_hash, run_sweep, scenario_grid, scenarios_from_net,
)
from repro.core.costs import (
    AnalyticCostModel, prim_cost_key, time_callable, transform_cost_key,
)
from repro.core.primitives import primitives_for
from repro.core.scenario import Scenario
from repro.serving import BucketPolicy, PlanServer, bucket_scenario, \
    conv_tower

POLICY = BucketPolicy(min_hw=8, max_hw=64)
SCN = Scenario(c=8, h=16, w=16, stride=1, k=3, m=16)


def _profile(**entries):
    p = HardwareProfile.new(reps=1, min_time=1e-4)
    for k, v in entries.items():
        p.put(k, v)
    return p


class TestProfile:
    def test_round_trip(self, tmp_path):
        p = _profile(**{prim_cost_key("sum2d", SCN): 1.25e-3,
                        transform_cost_key("CHW", "HWC", (8, 16, 16)):
                        3e-5})
        path = tmp_path / "hw.json"
        p.save(path)
        q = HardwareProfile.load(path)
        assert q.entries == p.entries
        assert (q.device, q.registry) == (p.device, p.registry)
        assert q.content_hash() == p.content_hash()
        assert q.device == device_fingerprint()
        assert q.registry == registry_hash()

    def test_schema_mismatch_rejected(self, tmp_path):
        p = _profile()
        payload = p.to_payload()
        payload["schema"] = 99
        with pytest.raises(ValueError):
            HardwareProfile.from_payload(payload)

    def test_content_hash_tracks_entries(self):
        a, b = _profile(), _profile()
        assert a.content_hash() == b.content_hash()
        a.put("prim::x::y", 1.0)
        assert a.content_hash() != b.content_hash()
        b.put("prim::x::y", 2.0)
        assert a.content_hash() != b.content_hash()
        b.put("prim::x::y", 1.0)
        assert a.content_hash() == b.content_hash()


class TestBucketScenario:
    def test_rounds_up_pow2(self):
        scn = Scenario(c=5, h=13, w=14, stride=2, k=3, m=12)
        b = bucket_scenario(scn, POLICY)
        assert (b.c, b.h, b.w, b.m) == (8, 16, 16, 16)
        assert (b.stride, b.k, b.pad, b.dtype) == (2, 3, 1, "float32")

    def test_fixpoint(self):
        b = bucket_scenario(SCN, POLICY)
        assert bucket_scenario(b, POLICY) == b


class TestCalibratedModel:
    def test_serves_table_for_covered_bucket(self):
        prof = _profile(**{prim_cost_key("sum2d", SCN): 42e-3})
        cm = CalibratedCostModel(prof, policy=POLICY)
        sum2d = next(p for p in primitives_for(SCN) if p.name == "sum2d")
        # a non-canonical scenario bucketing into the measured one
        req = Scenario(c=5, h=13, w=14, stride=1, k=3, m=12)
        assert cm.primitive_cost(sum2d, req) == 42e-3
        assert cm.table_hits == 1 and cm.fallback_hits == 0

    def test_falls_back_for_uncovered_bucket(self):
        prof = _profile()
        fallback = AnalyticCostModel()
        cm = CalibratedCostModel(prof, fallback=fallback, policy=POLICY)
        sum2d = next(p for p in primitives_for(SCN) if p.name == "sum2d")
        assert cm.primitive_cost(sum2d, SCN) == \
            fallback.primitive_cost(sum2d, SCN)
        assert cm.fallback_hits == 1
        assert cm.coverage()["table_rate"] == 0.0

    def test_transform_cost_table_and_fallback(self):
        shape = (8, 16, 16)
        prof = _profile(**{transform_cost_key("CHW", "HWC", shape): 7e-5})
        fallback = AnalyticCostModel()
        cm = CalibratedCostModel(prof, fallback=fallback, policy=POLICY)
        assert cm.transform_cost("CHW", "HWC", shape, np.float32) == 7e-5
        assert cm.transform_cost("CHW", "HCW", shape, np.float32) == \
            fallback.transform_cost("CHW", "HCW", shape, np.float32)
        # blocked layout infeasible for C % 8 != 0, table or not
        assert cm.transform_cost("HWC", "HWC8", (5, 16, 16),
                                 np.float32) == float("inf")

    def test_version_tracks_recalibration(self):
        a = CalibratedCostModel(_profile(), policy=POLICY)
        prof2 = _profile(**{prim_cost_key("sum2d", SCN): 1e-3})
        b = CalibratedCostModel(prof2, policy=POLICY)
        assert a.version() != b.version()
        # and differs from the pure-analytic model's version
        assert a.version() != AnalyticCostModel().version()

    def test_tpu_only_guarded_even_when_table_poisoned(self):
        """A CPU profile must never legitimize a Pallas kernel, even if
        someone managed to store an (interpret-mode) timing for one."""
        from repro.core.primitives import registry
        pallas = next(p for p in registry() if "tpu-only" in p.tags)
        prof = _profile(**{prim_cost_key(pallas.name, SCN): 1e-6})
        cm = CalibratedCostModel(prof, policy=POLICY)
        assert cm.primitive_cost(pallas, SCN) == float("inf")

    def test_collective_cost_table_and_fallback(self):
        """Measured pod collectives (``coll::`` entries) are served
        with pow2 byte-bucketing; uncovered triples fall back to the
        analytic ring model (docs/distributed.md)."""
        from repro.core.costs import collective_cost_key
        prof = _profile(**{
            collective_cost_key("all_gather", 1 << 20, 8): 123e-6})
        fallback = AnalyticCostModel()
        cm = CalibratedCostModel(prof, fallback=fallback, policy=POLICY)
        # any payload rounding up into the 1 MiB bucket hits the table
        assert cm.collective_cost("all_gather", 1_000_000, 8) == \
            pytest.approx(123e-6)
        assert cm.table_hits == 1 and cm.fallback_hits == 0
        # uncovered kind / participant count: analytic fallback
        assert cm.collective_cost("all_reduce", 1 << 20, 8) == \
            pytest.approx(fallback.collective_cost(
                "all_reduce", 1 << 20, 8))
        assert cm.fallback_hits == 1
        # degenerate fabric: one participant is always free
        assert cm.collective_cost("all_gather", 1 << 20, 1) == 0.0

    def test_device_mismatch_rejected_unless_transfer(self):
        prof = _profile()
        prof.device = "tpu:TPU_v5e:n8"
        with pytest.raises(ValueError):
            CalibratedCostModel(prof)
        cm = CalibratedCostModel(prof, check_device=False)
        assert cm.profile.device == "tpu:TPU_v5e:n8"


class TestSweep:
    def test_plan_excludes_tpu_only_by_default(self):
        items = plan_sweep([SCN], policy=POLICY)
        assert not any("pallas" in it.label for it in items)
        assert len({it.key for it in items}) == len(items)
        kinds = {it.kind for it in items}
        assert kinds == {"prim", "dt", "fuse"}

    def test_plan_kernels_adds_benchmark_entries(self):
        items = plan_sweep([SCN], families=["direct"], exclude_tags=(),
                           dt=False, kernels=True, policy=POLICY)
        names = {it.key.split("::")[1] for it in items
                 if it.kind == "kernel"}
        assert {"conv_direct", "conv_im2col", "winograd_gemm", "matmul",
                "flash_attention", "layout_transform"} <= names

    def test_run_sweep_resumes_and_saves(self, tmp_path):
        items = plan_sweep([SCN], families=["direct"], dt=False,
                           policy=POLICY)
        prof = HardwareProfile.new()
        path = tmp_path / "hw.json"
        calls = []

        def stub(item):
            calls.append(item.key)
            return 1e-3

        r1 = run_sweep(prof, items, save_path=path, save_every=2,
                       max_entries=3, measure=stub)
        assert r1 == {"measured": 3, "skipped": 0,
                      "remaining": len(items) - 3}
        assert path.exists() and len(HardwareProfile.load(path)) == 3
        r2 = run_sweep(prof, items, save_path=path, measure=stub)
        assert r2["skipped"] == 3 and r2["remaining"] == 0
        # no key measured twice across the two runs
        assert len(calls) == len(set(calls)) == len(items)

    def test_scenario_sources(self):
        grid = scenario_grid("tiny", policy=POLICY)
        assert grid and all(bucket_scenario(s, POLICY) == s for s in grid)
        net_scns = scenarios_from_net(conv_tower((8, 16, 16), depth=2,
                                                 width=8), policy=POLICY)
        assert len(net_scns) == len({s.key() for s in net_scns}) == 2


class TestPlanCacheInvalidation:
    """Recalibration must invalidate persisted PBQP plans end to end."""

    def _serve(self, prof, cache_dir):
        srv = PlanServer(lambda s: conv_tower(s, depth=1, width=8),
                         CalibratedCostModel(prof, policy=POLICY),
                         policy=POLICY, cache_dir=cache_dir,
                         lru_capacity=2)
        srv.infer(np.zeros((3, 10, 10), np.float32))
        stats = srv.stats()
        srv.close()
        return stats

    def test_same_profile_hits_new_profile_resolves(self, tmp_path):
        prof = _profile(**{prim_cost_key("sum2d", SCN): 1e-3})
        cold = self._serve(prof, tmp_path)
        assert cold["solves"] == 1
        warm = self._serve(prof, tmp_path)
        assert warm["solves"] == 0 and warm["plan_disk_hits"] == 1
        recal = _profile(**{prim_cost_key("sum2d", SCN): 2e-3})
        fresh = self._serve(recal, tmp_path)
        assert fresh["solves"] == 1 and fresh["plan_disk_hits"] == 0


def test_time_callable_counts_and_medians():
    calls = []

    def fn(x):
        calls.append(x)
        return np.asarray(x)

    t = time_callable(fn, (1.0,), reps=3, min_time=1e-5, warmup=2)
    assert t > 0.0
    assert len(calls) >= 5  # 2 warmup + >= 1 per timed repetition
